"""Jit'd public API over the Pallas stencil kernels.

``stencil_run(name, x, steps)`` executes T time steps of the named stencil;
block sizes default to the codesign-planned VMEM tiling
(:func:`repro.kernels.stencil_common.plan_block_rows`) and can be overridden
with explicitly optimized values (what `repro.core`'s software-parameter
solve produces).
"""

from __future__ import annotations

import functools
from types import ModuleType
from typing import Dict

import jax

from . import gradient2d, heat2d, heat3d, jacobi2d, laplacian2d, laplacian3d
from .stencil_common import plan_block_rows, time_loop

__all__ = ["KERNELS", "stencil_step", "stencil_run", "kernel_flops", "tuned_block_rows"]

KERNELS: Dict[str, ModuleType] = {
    m.NAME: m
    for m in (jacobi2d, heat2d, laplacian2d, gradient2d, heat3d, laplacian3d)
}


def kernel_flops(name: str, shape, steps: int = 1) -> float:
    """Useful flops of a run (interior points only -- borders are copies)."""
    mod = KERNELS[name]
    interior = 1.0
    for d in shape:
        interior *= max(d - 2 * mod.HALO, 0)
    return mod.FLOPS_PER_POINT * interior * steps


def tuned_block_rows(name: str, shape, dtype) -> int:
    """The default software parameter: the eq.-(9)/(11) VMEM-fit solve."""
    del name  # all current kernels have halo 1 and 4 resident bands
    return plan_block_rows(shape, dtype)


def stencil_step(name: str, x: jax.Array, block_rows=None, interpret=None):
    """One un-jitted stencil application (used by tests)."""
    return KERNELS[name].step(x, block_rows=block_rows, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("name", "steps", "block_rows", "interpret"))
def stencil_run(
    name: str,
    x: jax.Array,
    steps: int = 1,
    block_rows: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """T time steps of the named stencil (Dirichlet borders)."""
    mod = KERNELS[name]
    step = functools.partial(mod.step, block_rows=block_rows, interpret=interpret)
    return time_loop(step, x, steps)
