"""Batched serving demo: prefill a batch of prompts, greedy-decode with the
KV cache, verify against the cache-less reference.

Run: PYTHONPATH=src python examples/serve_tiny_lm.py [--arch mixtral-8x22b]
(any registered arch; the reduced config is used)
"""

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_arch
from repro.models import forward, init_model
from repro.serve import generate

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="mixtral-8x22b")
ap.add_argument("--requests", type=int, default=4)
ap.add_argument("--prompt-len", type=int, default=24)
ap.add_argument("--gen-len", type=int, default=12)
args = ap.parse_args()

cfg = get_arch(args.arch).reduced()
if cfg.moe:
    cfg = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
params = init_model(cfg, jax.random.PRNGKey(0))

batch = {
    "tokens": jax.random.randint(
        jax.random.PRNGKey(1), (args.requests, args.prompt_len), 0, cfg.vocab
    )
}
if cfg.frontend or cfg.enc_dec:
    batch["frontend"] = (
        jax.random.normal(
            jax.random.PRNGKey(2), (args.requests, cfg.n_frontend_tokens, cfg.d_model)
        )
        * 0.05
    )

t0 = time.perf_counter()
out = generate(params, cfg, batch, steps=args.gen_len)
out.block_until_ready()
dt = time.perf_counter() - t0
print(f"{args.arch} (reduced): {args.requests} requests x {args.gen_len} tokens")
print(f"throughput: {args.requests*args.gen_len/dt:.1f} tok/s (CPU, incl. compile)")
print("generations:\n", np.asarray(out))

# consistency check vs teacher-forced full recompute (no cache)
toks = batch["tokens"]
for _ in range(args.gen_len):
    logits, _, _ = forward(params, cfg, dict(batch, tokens=toks))
    toks = jnp.concatenate(
        [toks, jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)], axis=1
    )
ref = toks[:, args.prompt_len :]
match = np.array_equal(np.asarray(out), np.asarray(ref))
print("cache decode == cache-less reference:", match)
assert match
