"""Training substrate: jitted train step, fault-tolerant trainer loop."""

from .train_step import TrainConfig, make_train_step, init_train_state  # noqa: F401
from .trainer import Trainer, TrainerConfig  # noqa: F401
