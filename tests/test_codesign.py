"""Codesign driver + solver + Pareto tests (paper §IV-§V)."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # soft dep: skips, not errors

from repro.core import (
    GTX980,
    MAXWELL,
    MAXWELL_GPU,
    STENCILS,
    ProblemSize,
    codesign,
    enumerate_hw_space,
    evaluate_fixed_hw,
    pareto_mask,
    refine_point,
    solve_cell,
    stencil_time,
)
from repro.core.codesign import HardwareSpace, STOCK
from repro.core.solver import LATTICE_2D, TileLattice, decode_index
from repro.core.workload import Workload, WorkloadCell, paper_sizes, paper_workload


def tiny_hw():
    n_sm = np.array([4.0, 16.0, 32.0])
    n_v = np.array([64.0, 128.0, 256.0])
    m_sm = np.array([48.0, 96.0, 192.0])
    area = MAXWELL.area(n_sm, n_v, m_sm)
    return HardwareSpace(n_sm, n_v, m_sm, area)


TINY_LATTICE = TileLattice(t_s1=(2, 8), t_s2=(32, 128), t_t=(4, 16), k=(1, 4))


def test_solve_cell_matches_bruteforce():
    """The vectorized lattice solve equals a python-loop brute force."""
    spec = STENCILS["jacobi2d"]
    size = ProblemSize(4096, 4096, 1024)
    hw = tiny_hw()
    t, idx = solve_cell(spec, MAXWELL_GPU, size, hw.n_sm, hw.n_v, hw.m_sm, TINY_LATTICE)
    g = TINY_LATTICE.grid()
    for h in range(3):
        times = [
            float(
                stencil_time(
                    spec, MAXWELL_GPU, size, hw.n_sm[h], hw.n_v[h], hw.m_sm[h],
                    g["t_s1"][j], g["t_s2"][j], g["t_t"][j], g["k"][j], g["t_s3"][j],
                )
            )
            for j in range(TINY_LATTICE.size)
        ]
        assert t[h] == pytest.approx(min(times), rel=1e-12)


def test_separability_equals_joint():
    """Eq. (18): solving cells independently == joint minimization, because
    the workload objective is a fixed positive combination of cell times."""
    wl = paper_workload(["jacobi2d"])
    cells = wl.cells[:3]
    wl_small = Workload(
        "t", tuple(WorkloadCell(c.stencil, c.size, 1 / 3) for c in cells)
    )
    hw = tiny_hw()
    res = codesign(wl_small, hw=hw, lattice_2d=TINY_LATTICE)
    # joint brute force: every combination of per-cell tile choices
    g = TINY_LATTICE.grid()
    for h in range(3):
        per_cell_best = []
        for c in wl_small.cells:
            times = stencil_time(
                c.stencil, MAXWELL_GPU, c.size,
                hw.n_sm[h], hw.n_v[h], hw.m_sm[h],
                g["t_s1"], g["t_s2"], g["t_t"], g["k"], g["t_s3"],
            )
            per_cell_best.append(times.min())
        joint = sum(per_cell_best) / 3
        assert res.weighted_time()[h] == pytest.approx(joint, rel=1e-12)


def test_reweighting_for_free():
    """§V.B: new frequencies re-reduce cached cell times (no re-solve)."""
    wl = paper_workload(["jacobi2d", "heat2d"])
    hw = tiny_hw()
    res = codesign(wl, hw=hw, lattice_2d=TINY_LATTICE)
    C = len(wl.cells)
    one_hot = np.zeros(C)
    one_hot[5] = 1.0
    wt = res.weighted_time(one_hot)
    assert wt == pytest.approx(res.cell_time[5], rel=1e-12)


def test_stock_baseline_feasible():
    wt, gf = evaluate_fixed_hw(paper_workload(["jacobi2d"]), STOCK["gtx980"])
    assert np.isfinite(wt) and gf > 100  # stock GTX-980 runs jacobi fine


def test_enumerate_respects_budget_and_alignment():
    hw = enumerate_hw_space(max_area=450.0)
    assert len(hw) > 0
    assert np.all(hw.area <= 450.0)
    assert np.all(hw.n_sm % 2 == 0)
    assert np.all(hw.n_v % 32 == 0)
    assert np.all((hw.m_sm % 48 == 0) | np.isin(hw.m_sm, (12, 24, 36)))


def test_refine_never_worse():
    spec = STENCILS["heat2d"]
    size = ProblemSize(8192, 8192, 2048)
    hw = (16.0, 128.0, 96.0)
    t0, i = solve_cell(
        spec, MAXWELL_GPU, size,
        np.array([hw[0]]), np.array([hw[1]]), np.array([hw[2]]), LATTICE_2D,
    )
    sw0 = decode_index(LATTICE_2D, int(i[0]))
    t1, sw1 = refine_point(spec, MAXWELL_GPU, size, hw, sw0)
    assert t1 <= t0[0] * (1 + 1e-12)
    assert np.isfinite(t1)


# ---------------------------------------------------------------------------
# Pareto properties
# ---------------------------------------------------------------------------
def test_pareto_no_dominated_point():
    rng = np.random.default_rng(42)
    cost = rng.uniform(100, 650, size=500)
    perf = rng.uniform(100, 5000, size=500)
    m = pareto_mask(cost, perf)
    front_c, front_p = cost[m], perf[m]
    for i in range(len(cost)):
        dominated = np.any((front_c <= cost[i]) & (front_p > perf[i]))
        if m[i]:
            # a front point may not be dominated by another front point
            dom_by_front = np.any(
                (front_c <= cost[i]) & (front_p > perf[i])
            )
            assert not dom_by_front
        else:
            assert dominated or np.any((front_c <= cost[i]) & (front_p >= perf[i]))


@settings(max_examples=100, deadline=None)
@given(
    st.lists(
        st.tuples(
            st.floats(1, 1e3, allow_nan=False), st.floats(1, 1e4, allow_nan=False)
        ),
        min_size=1,
        max_size=60,
    )
)
def test_pareto_front_is_monotone(points):
    cost = np.array([p[0] for p in points])
    perf = np.array([p[1] for p in points])
    m = pareto_mask(cost, perf)
    assert m.any()
    idx = np.nonzero(m)[0]
    order = np.argsort(cost[idx], kind="stable")
    sorted_perf = perf[idx][order]
    sorted_cost = cost[idx][order]
    # strictly increasing performance along increasing cost
    assert np.all(np.diff(sorted_perf) > 0) or len(idx) == 1
    # some point achieving the global best performance is on the front
    assert np.any(m & (perf == perf.max()))
    # no duplicate costs on the front
    assert len(np.unique(sorted_cost)) == len(sorted_cost)
