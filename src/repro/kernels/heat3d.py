"""Heat-3D: explicit 7-point diffusion step."""

from __future__ import annotations

import jax

from .stencil_common import stencil3d_call

NAME = "heat3d"
DIMS = 3
HALO = 1
ALPHA = 0.125
FLOPS_PER_POINT = 15.0


def update(ext: jax.Array, h: int) -> jax.Array:
    c = ext[h:-h, h:-h, h:-h]
    u = ext[: -2 * h, h:-h, h:-h]
    d = ext[2 * h :, h:-h, h:-h]
    n = ext[h:-h, : -2 * h, h:-h]
    s = ext[h:-h, 2 * h :, h:-h]
    w = ext[h:-h, h:-h, : -2 * h]
    e = ext[h:-h, h:-h, 2 * h :]
    return c + ALPHA * (u + d + n + s + e + w - 6.0 * c)


def step(x, block_rows=None, interpret=None):
    return stencil3d_call(x, update, HALO, block_rows, interpret)
