"""Command-line front end for the codesign query service.

Quickstart (first call sweeps once and persists the artifact; every later
call -- any frequency mix, budget, what-if -- is a warm re-reduction):

    python -m repro.service.cli query --stencil heat2d --max-area 450
    python -m repro.service.cli query --freq heat2d=3 --freq jacobi2d=1 \\
        --top-k 5 --pareto --fix n_sm=16
    python -m repro.service.cli build --downsample 4     # pre-warm a store
    python -m repro.service.cli ls

The store location is ``--store``, else ``$REPRO_STORE``, else
``~/.cache/repro/codesign-store``.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

from .query import QueryRequest
from .server import CodesignServer
from .store import ArtifactStore

DEFAULT_STORE = os.environ.get(
    "REPRO_STORE", os.path.join(os.path.expanduser("~"), ".cache", "repro", "codesign-store")
)


def _add_server_args(p: argparse.ArgumentParser) -> None:
    p.add_argument("--store", default=DEFAULT_STORE, help="artifact store directory")
    p.add_argument("--max-hw-area", type=float, default=650.0,
                   help="hardware-space enumeration budget (mm^2)")
    p.add_argument("--downsample", type=int, default=1,
                   help="keep every Nth hardware point (quick demos)")
    p.add_argument(
        "--engine", choices=("auto", "jax", "sharded", "numpy"), default="auto"
    )
    p.add_argument(
        "--devices", type=int, default=None,
        help="sharded engine: first N attached devices (default: all)",
    )


def _server(args) -> CodesignServer:
    return CodesignServer(
        ArtifactStore(args.store),
        max_area=args.max_hw_area,
        downsample=args.downsample,
        engine=args.engine,
        devices=args.devices,
        batch_window=0.0,  # CLI is single-threaded; no rendezvous needed
    )


def _freqs(args):
    freqs = {}
    for name in args.stencil or []:
        freqs[name] = freqs.get(name, 0.0) + 1.0
    for spec in args.freq or []:
        name, _, w = spec.partition("=")
        if not w:
            raise SystemExit(f"--freq wants name=weight, got {spec!r}")
        freqs[name] = freqs.get(name, 0.0) + float(w)
    return freqs or None


def _fix(args):
    fix = {}
    for spec in args.fix or []:
        name, _, v = spec.partition("=")
        if not v:
            raise SystemExit(f"--fix wants param=value, got {spec!r}")
        fix[name] = float(v)
    return fix or None


def cmd_query(args) -> None:
    srv = _server(args)
    was_warm = srv.warm
    req = QueryRequest(
        freqs=_freqs(args),
        max_area=args.max_area,
        min_area=args.min_area,
        top_k=args.top_k,
        pareto=args.pareto,
        fix=_fix(args),
    )
    t0 = time.perf_counter()
    resp = srv.query(req)
    dt = time.perf_counter() - t0
    feasible = resp.best_index >= 0
    out = {
        "artifact_key": resp.artifact_key,
        "warm": was_warm,
        "query_s": round(dt, 4),
        "feasible": feasible,
        "best": {**resp.best_point, "index": resp.best_index,
                 "gflops": resp.best_gflops,
                 "weighted_time_s": resp.best_weighted_time} if feasible else None,
        "top_k": resp.top_k,
    }
    if resp.pareto_indices is not None:
        out["pareto"] = {
            "count": int(resp.pareto_indices.size),
            "indices": [int(i) for i in resp.pareto_indices],
        }
    if resp.baseline_best_index is not None:
        out["what_if"] = {
            "baseline_best_index": resp.baseline_best_index,
            "baseline_best_gflops": resp.baseline_best_gflops,
            "delta_gflops": resp.best_gflops - resp.baseline_best_gflops,
        }
    if args.json:
        json.dump(out, f := sys.stdout, indent=1)
        f.write("\n")
        return
    b = out["best"]
    print(f"artifact {resp.artifact_key} ({'warm' if was_warm else 'cold build'}), "
          f"query {dt*1e3:.1f} ms")
    if resp.best_index < 0:
        print("no design satisfies the requested constraints "
              "(budget/fix select an empty subspace)")
        return
    print(f"best:  n_SM={b['n_sm']:3d} n_V={b['n_v']:4d} M_SM={b['m_sm']:4.0f}kB "
          f"area={b['area']:6.1f}mm^2  {b['gflops']:8.1f} GFLOP/s")
    for r in resp.top_k[1:]:
        print(f"       n_SM={r['n_sm']:3d} n_V={r['n_v']:4d} M_SM={r['m_sm']:4.0f}kB "
              f"area={r['area']:6.1f}mm^2  {r['gflops']:8.1f} GFLOP/s")
    if "pareto" in out:
        print(f"pareto front: {out['pareto']['count']} of {len(srv.hw)} designs")
    if "what_if" in out:
        w = out["what_if"]
        print(f"what-if delta vs unrestricted best: {w['delta_gflops']:+.1f} GFLOP/s")


def cmd_build(args) -> None:
    srv = _server(args)
    t0 = time.perf_counter()
    srv.ensure_artifact()
    print(f"artifact {srv.key}: "
          f"{'already stored' if srv.stats['artifact_loads'] else 'built'} "
          f"({time.perf_counter()-t0:.1f}s, {len(srv.hw)} hw points, "
          f"{len(srv.workload.cells)} cells)")


def cmd_ls(args) -> None:
    store = ArtifactStore(args.store)
    rows = store.entries()
    if not rows:
        print(f"(no artifacts under {store.root})")
        return
    for r in rows:
        print(f"{r['key']}  v{r['format_version']}  {r['workload']:16s} "
              f"{r['cells']:4d} cells x {r['hw']:6d} hw  engine={r['engine']}  "
              f"[{','.join(r['stencils'])}]")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        prog="repro.service.cli", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    sub = ap.add_subparsers(dest="cmd", required=True)

    q = sub.add_parser("query", help="answer a codesign query (sweeps on first miss)")
    _add_server_args(q)
    q.add_argument("--stencil", action="append",
                   help="stencil to weight 1.0 (repeatable)")
    q.add_argument("--freq", action="append", metavar="NAME=W",
                   help="explicit stencil weight (repeatable)")
    q.add_argument("--max-area", type=float, default=np.inf,
                   help="area budget for the answer (mm^2)")
    q.add_argument("--min-area", type=float, default=0.0)
    q.add_argument("--top-k", type=int, default=1)
    q.add_argument("--pareto", action="store_true", help="include the Pareto front")
    q.add_argument("--fix", action="append", metavar="PARAM=VALUE",
                   help="what-if subspace, e.g. n_sm=16 (repeatable)")
    q.add_argument("--json", action="store_true", help="machine-readable output")
    q.set_defaults(fn=cmd_query)

    b = sub.add_parser("build", help="pre-warm the default paper-workload artifact")
    _add_server_args(b)
    b.set_defaults(fn=cmd_build)

    ls = sub.add_parser("ls", help="list stored artifacts")
    ls.add_argument("--store", default=DEFAULT_STORE)
    ls.set_defaults(fn=cmd_ls)

    args = ap.parse_args(argv)
    args.fn(args)


if __name__ == "__main__":
    main()
