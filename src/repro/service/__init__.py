"""High-throughput codesign query service over precomputed sweep artifacts.

The eq.-18 separability decomposition caches per-cell/per-hardware optima
as a ``(cells x hardware)`` matrix; once persisted, every workload
question is a cheap vectorized re-reduction ("sensitivity for free",
paper §V.B). This package turns that observation into a serving system:

* :mod:`repro.service.store`  -- versioned, content-addressed on-disk
  artifacts (compressed npz + JSON manifest, mmap-backed lazy loads);
* :mod:`repro.service.query`  -- ``QueryRequest -> QueryResponse``
  re-reductions (mixes, top-k, Pareto, what-ifs) with an LRU;
* :mod:`repro.service.server` -- thread-safe in-process server that
  microbatches concurrent queries into one ``(B, C) @ (C, H)`` matmul and
  falls back to the sweep engine exactly once on artifact miss;
* :mod:`repro.service.cli`    -- ``python -m repro.service.cli query ...``.
"""

from .query import QueryEngine, QueryRequest, QueryResponse  # noqa: F401
from .server import CodesignServer  # noqa: F401
from .store import Artifact, ArtifactStore, artifact_spec, spec_key  # noqa: F401
