"""Chaos: portfolio routing degrades member-by-member, never a 500.

Arms :mod:`repro.service.faults` ``route.member.<hw>`` points while
routing through a live portfolio: a failing member design falls back to
the group's next-preferred member with a structured ``degraded: true``
answer, per-member circuit breakers open after repeated failures, and
only when *every* member is down does the route fail -- as a structured
503 ``portfolio_exhausted``, not an internal error.
"""

import threading

import numpy as np
import pytest

from repro.core.timemodel import GPUS_BY_NAME
from repro.service import faults, wire
from repro.service.client import GatewayClient
from repro.service.errors import ERROR_HTTP_STATUS
from repro.service.gateway import Gateway, serve_http
from repro.service.portfolio import (
    PortfolioExhaustedError,
    PortfolioServer,
    RouteRequest,
    build_portfolio,
)
from repro.service.resilience import GatewayResilience
from repro.service.server import CodesignServer
from repro.service.store import ArtifactStore


@pytest.fixture(autouse=True)
def _clean_faults():
    faults.reset()
    yield
    faults.reset()


@pytest.fixture(scope="module")
def fleet(tmp_path_factory):
    """A store holding one sweep + one genuinely multi-member portfolio."""
    root = str(tmp_path_factory.mktemp("chaos-store"))
    store = ArtifactStore(root)
    srv = CodesignServer(
        store, gpu=GPUS_BY_NAME["gtx980"], downsample=64, engine="numpy",
        batch_window=0.0,
    )
    srv.ensure_artifact()
    area = np.asarray(store.get(srv.key).hw_area, np.float64)
    art, res = build_portfolio(
        store, srv.key, 2, float(area.sum()), objective="throughput"
    )
    assert len(res.members) >= 2, "chaos needs a multi-member portfolio"
    return root, store, srv.key, art.key


def _server(store, sweep_key, portfolio_key, **res_kw):
    return PortfolioServer(
        store.get(portfolio_key),
        store.get(sweep_key),
        resilience=GatewayResilience(**res_kw) if res_kw else None,
    )


def _cell_assigned_to_slot0(ps):
    """A cell label whose primary member is slot 0 (exists: slot 0 is the
    fastest member for at least one group in a multi-member optimum)."""
    for label, g in ps._groups.items():
        if g["slot"] == 0:
            return label
    raise AssertionError("no group routed to member slot 0")


def test_failed_member_degrades_to_next_preference(fleet):
    root, store, sweep_key, portfolio_key = fleet
    ps = _server(store, sweep_key, portfolio_key)
    cell = _cell_assigned_to_slot0(ps)
    primary = ps.members[0]

    healthy = ps.route(RouteRequest(cell=cell))
    assert healthy.hw_index == primary and not healthy.degraded

    faults.enable(f"route.member.{primary}", error=OSError("member on fire"))
    try:
        resp = ps.route(RouteRequest(cell=cell))
    finally:
        faults.reset()
    assert resp.degraded and resp.fallback_from == (primary,)
    assert resp.hw_index != primary
    assert resp.hw_index in ps.members
    assert resp.gflops > 0 and np.isfinite(resp.time_s)

    # fault cleared -> back to the primary, un-degraded
    again = ps.route(RouteRequest(cell=cell))
    assert again == healthy


def test_breaker_opens_and_recovers(fleet):
    root, store, sweep_key, portfolio_key = fleet
    ps = _server(store, sweep_key, portfolio_key,
                 breaker_threshold=2, breaker_cooldown_s=0.05)
    cell = _cell_assigned_to_slot0(ps)
    primary = ps.members[0]

    # two raw failures open the per-member breaker...
    faults.enable(f"route.member.{primary}", error=OSError("flaky"), count=2)
    for _ in range(2):
        assert ps.route(RouteRequest(cell=cell)).degraded
    # ...so the third route degrades WITHOUT touching the member (the
    # fault budget is exhausted; a read would have succeeded)
    resp = ps.route(RouteRequest(cell=cell))
    assert resp.degraded and resp.fallback_from == (primary,)

    # after the cooldown the half-open probe succeeds and routing heals
    import time

    time.sleep(0.06)
    assert not ps.route(RouteRequest(cell=cell)).degraded


def test_all_members_down_is_structured_exhaustion(fleet):
    root, store, sweep_key, portfolio_key = fleet
    ps = _server(store, sweep_key, portfolio_key)
    cell = next(iter(ps.cell_labels()))
    for hw in ps.members:
        faults.enable(f"route.member.{hw}", error=OSError("fleet outage"))
    with pytest.raises(PortfolioExhaustedError) as exc:
        ps.route(RouteRequest(cell=cell))
    assert exc.value.code == "portfolio_exhausted"
    assert ERROR_HTTP_STATUS[exc.value.code] == 503
    assert exc.value.retry_after_s == 1.0


def test_http_route_degrades_never_500(fleet):
    root, store, sweep_key, portfolio_key = fleet
    gw = Gateway([root], batch_window=0.0)
    httpd = serve_http(gw)
    threading.Thread(target=httpd.serve_forever, daemon=True).start()
    try:
        host, port = httpd.server_address[:2]
        client = GatewayClient(f"http://{host}:{port}", retry=None)
        oracle = PortfolioServer(store.get(portfolio_key), store.get(sweep_key))
        cell = _cell_assigned_to_slot0(oracle)
        primary = oracle.members[0]

        faults.enable(f"route.member.{primary}", error=OSError("down"))
        resp = client.route(cell, artifact=portfolio_key)
        assert resp.degraded and primary in resp.fallback_from
        assert resp.hw_index != primary

        # every member down -> structured 503, never an internal 500
        for hw in oracle.members:
            faults.enable(f"route.member.{hw}", error=OSError("down"))
        body, status = client._request(
            "/v1/route",
            wire.encode_route_request(
                RouteRequest(cell=cell), artifact=portfolio_key
            ),
        )
        assert status == 503
        with pytest.raises(wire.RemoteError) as exc:
            wire.decode_route_response(body, http_status=status)
        assert exc.value.code == "portfolio_exhausted"

        faults.reset()
        healthy = client.route(cell, artifact=portfolio_key)
        assert not healthy.degraded and healthy.hw_index == primary
    finally:
        httpd.shutdown()
        httpd.server_close()
