"""Golden wire corpus: every endpoint's canonical bytes, locked on disk.

``tests/golden/`` holds the exact request/response bytes for each
endpoint envelope -- ``/v1/query``, ``/v1/query_many``, ``/v1/route``,
the structured error shape, and the ``/v1/metrics`` JSON rendering. The
builders below reconstruct each envelope from fixed values; the test
asserts the encoder still produces the committed bytes. Any diff here is
a WIRE-BREAKING change: old clients will see different bytes. If the
break is intentional, bump ``WIRE_VERSION``, regenerate with

    REPRO_UPDATE_GOLDEN=1 pytest tests/test_golden.py

and say so loudly in the changelog. Decoders are additionally checked as
exact inverses over the corpus (decode . encode == identity), so the
corpus doubles as a decoder regression net.
"""

import os
import pathlib

import numpy as np
import pytest

from repro.obs.metrics import Registry
from repro.service import wire
from repro.service.portfolio import RouteRequest, RouteResponse
from repro.service.query import QueryRequest, QueryResponse

GOLDEN_DIR = pathlib.Path(__file__).parent / "golden"
UPDATE = os.environ.get("REPRO_UPDATE_GOLDEN") == "1"


# ---------------------------------------------------------------------------
# fixed envelope builders (pure values -> bytes; no sweeps, no clocks)
# ---------------------------------------------------------------------------


def _query_request() -> bytes:
    return wire.encode_request(
        QueryRequest(
            freqs={"heat2d": 2.0, "jacobi2d": 1.0},
            max_area=450.0,
            min_area=60.0,
            top_k=3,
            pareto=True,
            fix={"n_sm": 16.0},
        ),
        artifact="0123456789abcdef0123",
        route={"gpu": "titanx", "workload": "paper-8-2048"},
        deadline_ms=250.0,
    )


def _query_many_request() -> bytes:
    return wire.encode_request_many(
        [
            (QueryRequest(freqs={"heat2d": 1.0}), None, {"gpu": "gtx980"}),
            (QueryRequest(max_area=650.0, top_k=2), "0123456789abcdef0123", None),
        ]
    )


def _route_request() -> bytes:
    return wire.encode_route_request(
        RouteRequest(cell="llama3-8b:decode"),
        artifact="fedcba98765432100123",
        route={"gpu": "tpu_v5e"},
        deadline_ms=100.0,
    )


def _query_response() -> bytes:
    # exercises the $f non-finite tagging (infeasible -> -inf gflops)
    # alongside a normal answer's full field surface
    return wire.encode_response(
        QueryResponse(
            artifact_key="0123456789abcdef0123",
            best_index=7,
            best_gflops=1063.25,
            best_weighted_time=7.0625,
            best_point={"area": 61.5, "m_sm": 432.0, "n_sm": 2.0, "n_v": 320.0},
            top_k=[
                {"area": 61.5, "gflops": 1063.25, "index": 7.0},
                {"area": 80.0, "gflops": 990.5, "index": 12.0},
            ],
            pareto_indices=np.array([2, 7, 12], np.int64),
            baseline_best_index=3,
            baseline_best_gflops=-np.inf,
            cached=True,
            batch_size=4,
        )
    )


def _query_many_response() -> bytes:
    ok = QueryResponse(
        artifact_key="0123456789abcdef0123",
        best_index=-1,
        best_gflops=-np.inf,
        best_weighted_time=np.inf,
        best_point={},
        top_k=[],
    )
    return wire.encode_response_many(
        [ok, ("unknown_artifact", "no artifact matches selector {'gpu': 'rtx'}")]
    )


def _route_response() -> bytes:
    return wire.encode_route_response(
        RouteResponse(
            portfolio_key="fedcba98765432100123",
            sweep_key="0123456789abcdef0123",
            cell="heat2d",
            cell_indices=(0, 6, 12),
            hw_index=42,
            member_slot=1,
            point={"area": 61.5, "m_sm": 432.0, "n_sm": 2.0, "n_v": 320.0},
            time_s=7.0625,
            gflops=1063.25,
            degraded=True,
            fallback_from=(17,),
        )
    )


def _error() -> bytes:
    return wire.encode_error(
        "portfolio_exhausted", "every member design failed for cell 'heat2d'"
    )


def _metrics_json() -> bytes:
    # a private registry with one of each family kind and fixed
    # observations: the canonical /v1/metrics?format=json rendering
    reg = Registry(disabled=False)
    c = reg.counter("repro_requests_total", "requests", labels=("endpoint",))
    c.labels(endpoint="/v1/route").inc(3)
    c.labels(endpoint="/v1/query").inc(5)
    g = reg.gauge("repro_pool_servers", "resident servers")
    g.set(2)
    h = reg.histogram("repro_route_seconds", "route latency",
                      buckets=(0.001, 0.01, 0.1))
    for v in (0.0005, 0.002, 0.05):
        h.observe(v)
    return reg.render_json()


def _slo_json() -> bytes:
    # a fixed-clock SLOTracker fed a fixed request mix: the canonical
    # /v1/slo?format=json rendering (burn rates, latency estimates,
    # per-route status), no wall clock anywhere
    from repro.obs.slo import SLOTracker

    t = [0.0]
    tracker = SLOTracker(clock=lambda: t[0])
    for i in range(20):
        t[0] = float(i)
        tracker.record("/v1/query", 0.004 + 0.001 * (i % 3), ok=True)
        tracker.record("/v1/route", 0.002, ok=(i % 10 != 0))
    t[0] = 30.0
    tracker.record("/v1/query", 0.250, ok=False)  # one slow 5xx outlier
    return wire.encode_slo_response(tracker.report(now=30.0))


CORPUS = {
    "query_request.json": _query_request,
    "query_many_request.json": _query_many_request,
    "route_request.json": _route_request,
    "query_response.json": _query_response,
    "query_many_response.json": _query_many_response,
    "route_response.json": _route_response,
    "error.json": _error,
    "metrics.json": _metrics_json,
    "slo.json": _slo_json,
}


@pytest.mark.parametrize("name", sorted(CORPUS))
def test_golden_bytes_stable(name):
    got = CORPUS[name]()
    path = GOLDEN_DIR / name
    if UPDATE:
        GOLDEN_DIR.mkdir(exist_ok=True)
        path.write_bytes(got)
    assert path.exists(), (
        f"missing golden file {path}; generate with REPRO_UPDATE_GOLDEN=1"
    )
    want = path.read_bytes()
    assert got == want, (
        f"{name}: wire bytes changed -- this breaks deployed clients. "
        "If intentional, bump WIRE_VERSION and regenerate the corpus "
        "(REPRO_UPDATE_GOLDEN=1)."
    )


def test_golden_decoders_invert_corpus():
    """decode(encode(x)) == x over the committed bytes (not just today's
    encoder output), so decoder drift is caught even when encoders hold."""
    req, artifact, route, deadline = wire.decode_route_request_full(
        (GOLDEN_DIR / "route_request.json").read_bytes()
    )
    assert req == RouteRequest(cell="llama3-8b:decode")
    assert artifact == "fedcba98765432100123"
    assert route == {"gpu": "tpu_v5e"} and deadline == 100.0

    resp = wire.decode_route_response(
        (GOLDEN_DIR / "route_response.json").read_bytes()
    )
    assert resp.degraded and resp.fallback_from == (17,)
    assert wire.encode_route_response(resp) == (
        GOLDEN_DIR / "route_response.json"
    ).read_bytes()

    q = wire.decode_response((GOLDEN_DIR / "query_response.json").read_bytes())
    assert q.baseline_best_gflops == -np.inf  # $f tag round-trips
    assert wire.encode_response(q) == (
        GOLDEN_DIR / "query_response.json"
    ).read_bytes()

    many = wire.decode_response_many(
        (GOLDEN_DIR / "query_many_response.json").read_bytes()
    )
    assert isinstance(many[0], QueryResponse)
    assert isinstance(many[1], wire.RemoteError)
    assert many[1].code == "unknown_artifact" and many[1].http_status == 404

    qreq, art, rt = wire.decode_request(
        (GOLDEN_DIR / "query_request.json").read_bytes()
    )
    assert art == "0123456789abcdef0123" and rt["gpu"] == "titanx"
    assert qreq.top_k == 3 and qreq.fix == {"n_sm": 16.0}

    with pytest.raises(wire.RemoteError) as exc:
        wire.decode_route_response((GOLDEN_DIR / "error.json").read_bytes(),
                                   http_status=503)
    assert exc.value.code == "portfolio_exhausted"
