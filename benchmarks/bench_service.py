"""Codesign query service: queries/sec cold (artifact miss -> full eq.-18
sweep) vs warm (stored artifact -> vectorized re-reductions).

Cold is measured against a throwaway store so the number is honest even
when CI restored the persistent artifact cache; warm is measured against
the persistent store with a fresh server (artifact mmap-loaded from disk,
LRU cold), then with the LRU primed, then through the stacked
``query_many`` matmul. The warm/cold ratio is asserted >= 100x -- the
entire point of persisting the separability matrix."""

from __future__ import annotations

import os
import shutil
import tempfile
import time

import numpy as np

from repro.service import ArtifactStore, CodesignServer, QueryRequest

from .common import ARTIFACTS, SMOKE_HW_STRIDE, emit, skey, smoke

#: distinct frequency mixes per warm pass (all LRU misses on the first lap)
N_MIXES = 64

STENCIL_NAMES = (
    "jacobi2d", "heat2d", "laplacian2d", "gradient2d", "heat3d", "laplacian3d",
)


def _mixes(rng: np.random.Generator, n: int):
    return [
        QueryRequest(
            freqs=dict(zip(STENCIL_NAMES, rng.uniform(0.05, 1.0, size=6))),
            max_area=650.0,
            top_k=3,
        )
        for _ in range(n)
    ]


def run() -> None:
    downsample = SMOKE_HW_STRIDE if smoke() else 1
    rng = np.random.default_rng(2017)

    # --- cold: throwaway store, one query pays sweep + persist + reduce ----
    tmp = tempfile.mkdtemp(prefix="bench-service-cold-")
    try:
        cold_srv = CodesignServer(
            ArtifactStore(tmp), downsample=downsample, batch_window=0.0
        )
        assert not cold_srv.warm
        t0 = time.perf_counter()
        cold_resp = cold_srv.query(_mixes(rng, 1)[0])
        t_cold = time.perf_counter() - t0
        assert cold_srv.stats["artifact_builds"] == 1
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
    emit(
        "service_cold", t_cold * 1e6,
        f"miss path: sweep + persist + query = {t_cold:.2f}s "
        f"({1.0/t_cold:.3f} q/s), best {cold_resp.best_gflops:.0f} GFLOP/s",
    )

    # --- warm: persistent store (CI caches it between steps/runs) ---------
    root = os.path.join(ARTIFACTS, skey("service"))
    store = ArtifactStore(root)
    CodesignServer(store, downsample=downsample, batch_window=0.0).ensure_artifact()

    srv = CodesignServer(store, downsample=downsample, batch_window=0.0)
    assert srv.warm, "persistent artifact should be on disk by now"
    reqs = _mixes(rng, N_MIXES)
    t0 = time.perf_counter()
    for r in reqs:
        srv.query(r)
    t_warm = time.perf_counter() - t0
    assert srv.stats["artifact_builds"] == 0
    qps_warm = len(reqs) / t_warm
    emit(
        "service_warm", t_warm / len(reqs) * 1e6,
        f"{len(reqs)} distinct mixes (LRU cold): {qps_warm:.0f} q/s",
    )

    t0 = time.perf_counter()
    for r in reqs:
        srv.query(r)
    t_lru = time.perf_counter() - t0
    emit(
        "service_warm_lru", t_lru / len(reqs) * 1e6,
        f"same mixes again (LRU hot): {len(reqs)/t_lru:.0f} q/s",
    )

    batch = _mixes(rng, N_MIXES)
    t0 = time.perf_counter()
    srv.query_many(batch)
    t_batch = time.perf_counter() - t0
    emit(
        "service_batched", t_batch / len(batch) * 1e6,
        f"one stacked (B={len(batch)}) matmul: {len(batch)/t_batch:.0f} q/s",
    )

    ratio = qps_warm / (1.0 / t_cold)
    emit(
        "service_speedup", t_cold * 1e6,
        f"warm/cold queries-per-sec ratio {ratio:.0f}x "
        f"(acceptance floor 100x)",
    )
    assert ratio >= 100.0, f"warm path only {ratio:.1f}x cold"
