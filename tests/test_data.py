"""Data pipeline: determinism, label alignment, modality stubs."""

import numpy as np

from repro.configs import get_arch
from repro.configs.base import ShapeSpec
from repro.data import DataConfig, SyntheticPipeline, make_batch

SHAPE = ShapeSpec("tiny", 32, 4, "train")


def test_deterministic_across_restarts():
    cfg = get_arch("llama3-8b").reduced()
    b1 = make_batch(cfg, SHAPE, DataConfig(seed=3), step=17)
    b2 = make_batch(cfg, SHAPE, DataConfig(seed=3), step=17)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
    b3 = make_batch(cfg, SHAPE, DataConfig(seed=4), step=17)
    assert not np.array_equal(np.asarray(b1["tokens"]), np.asarray(b3["tokens"]))


def test_labels_are_shifted_tokens():
    cfg = get_arch("llama3-8b").reduced()
    b = make_batch(cfg, SHAPE, DataConfig(), step=0)
    assert b["tokens"].shape == (4, 32) and b["labels"].shape == (4, 32)
    # deterministic copy-structure: tokens repeat with the configured period
    toks = np.asarray(b["tokens"])
    assert (toks >= 0).all() and (toks < cfg.vocab).all()


def test_vlm_batch_pads_vision_labels():
    cfg = get_arch("qwen2-vl-2b").reduced()
    b = make_batch(cfg, SHAPE, DataConfig(), step=0)
    nf = cfg.n_frontend_tokens
    assert b["frontend"].shape == (4, nf, cfg.d_model)
    labels = np.asarray(b["labels"])
    assert labels.shape == (4, nf + 32)
    assert (labels[:, :nf] == -1).all()  # vision slots are ignored in loss


def test_encdec_batch_has_frames():
    cfg = get_arch("whisper-medium").reduced()
    b = make_batch(cfg, SHAPE, DataConfig(), step=0)
    assert b["frontend"].shape == (4, cfg.n_frontend_tokens, cfg.d_model)


def test_pipeline_resumes_mid_stream():
    cfg = get_arch("llama3-8b").reduced()
    full = [b for _, b in zip(range(5), SyntheticPipeline(cfg, SHAPE))]
    resumed = [b for _, b in zip(range(2), SyntheticPipeline(cfg, SHAPE, start_step=3))]
    np.testing.assert_array_equal(
        np.asarray(full[3]["tokens"]), np.asarray(resumed[0]["tokens"])
    )
    np.testing.assert_array_equal(
        np.asarray(full[4]["tokens"]), np.asarray(resumed[1]["tokens"])
    )
