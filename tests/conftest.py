"""Shared test fixtures + hypothesis profiles."""

import os

import pytest

from _hypothesis_compat import HAVE_HYPOTHESIS

if HAVE_HYPOTHESIS:
    # CI runs `pytest --hypothesis-profile=ci`: derandomized (a red lane
    # must reproduce on re-run) with the wall-clock deadline disabled
    # (shared runners stall; flaking on scheduler noise helps no one).
    # Local runs keep hypothesis defaults -- randomized exploration is
    # the point of running the properties on a developer machine.
    from hypothesis import settings as _hyp_settings

    _hyp_settings.register_profile(
        "ci", deadline=None, derandomize=True, max_examples=50
    )


@pytest.fixture
def subprocess_env():
    """os.environ copy with src/ prepended to PYTHONPATH.

    Subprocess-spawning tests need this: pytest's ``pythonpath = ["src"]``
    config applies only in-process, so a bare-pytest run (no
    ``pip install -e``) would leave children unable to import ``repro``.
    """
    env = dict(os.environ)
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), os.pardir, "src"))
    prev = env.get("PYTHONPATH")
    env["PYTHONPATH"] = src + (os.pathsep + prev if prev else "")
    return env
