"""Measurement + calibration loop: time the tile-parameterized Pallas
stencils over a grid, refit the time model's machine parameters from the
timings, and land the per-stencil predicted-vs-measured error
before/after refit in a JSON artifact and the ``BENCH_sweep.json``
trajectory. A synthetic-recovery stage asserts the fit itself is sound
(model-generated timings from perturbed starting parameters must recover
the generating machine) -- the empirical-loop analogue of the sweep
suite's engine-parity asserts."""

from __future__ import annotations

import time

from repro.core.timemodel import MAXWELL_GPU, STENCILS, with_c_iter, with_machine_params
from repro.measure import fit_machine_params, measure_grid, synthetic_records
from repro.measure.calibrate import RECOVERY_RTOL
from repro.measure.harness import default_grid

from .common import append_trajectory, cache_json, emit, skey, smoke


def run() -> None:
    # --- stage 1: the measurement grid (Pallas kernels, interpret on CPU) --
    grid = default_grid(smoke=smoke())
    n_cfg = sum(len(v) for v in grid.values())
    t0 = time.perf_counter()
    measured = measure_grid(grid, warmup=1, repeats=2)
    t_grid = time.perf_counter() - t0
    emit(
        "measure_grid", t_grid / n_cfg * 1e6,
        f"{len(measured.records)} records / {n_cfg} configs in {t_grid:.1f}s "
        f"(backend={measured.backend}, interpret={measured.interpret})",
    )

    # --- stage 2: refit machine parameters from the harness timings -------
    t0 = time.perf_counter()
    cal = fit_machine_params(measured, iters=600 if smoke() else 1500)
    t_fit = time.perf_counter() - t0
    mean_before = sum(cal.errors_before.values()) / len(cal.errors_before)
    mean_after = sum(cal.errors_after.values()) / len(cal.errors_after)
    emit(
        "measure_fit", t_fit * 1e6,
        f"log-space loss {cal.loss_before:.3g} -> {cal.loss_after:.3g}; "
        f"mean |rel err| {mean_before:.1%} -> {mean_after:.1%} "
        f"over {cal.n_records} records",
    )
    assert cal.loss_after < cal.loss_before, "refit must reduce the fit loss"
    cache_json(
        skey("measure_calibration"),
        lambda: {
            "records": len(measured.records),
            "backend": measured.backend,
            "interpret": measured.interpret,
            "calibration": cal.to_payload(),
        },
        force=True,
    )

    # --- stage 3: synthetic recovery (the fit's own acceptance check) -----
    truth_gpu = with_machine_params(
        MAXWELL_GPU, bw_gmem=150.0e9, launch_overhead=8.0e-6
    )
    truth_st = {
        n: with_c_iter(st, st.c_iter * (1.0 + 0.25 * (i + 1)))
        for i, (n, st) in enumerate(STENCILS.items())
    }
    t0 = time.perf_counter()
    rec = fit_machine_params(
        synthetic_records(truth_gpu, truth_st), gpu0=MAXWELL_GPU
    )
    t_syn = time.perf_counter() - t0
    err = rec.param_rel_error(truth_gpu, truth_st)
    emit(
        "measure_synthetic_recovery", t_syn * 1e6,
        f"max param rel err {err:.2e} (acceptance < {RECOVERY_RTOL})",
    )
    assert err < RECOVERY_RTOL, f"synthetic recovery off by {err:.1%}"

    append_trajectory(
        "sweep",
        {
            "suite": "measure",
            "smoke": smoke(),
            "records": len(measured.records),
            "backend": measured.backend,
            "interpret": measured.interpret,
            "grid_s": round(t_grid, 3),
            "fit_s": round(t_fit, 3),
            "loss_before": cal.loss_before,
            "loss_after": cal.loss_after,
            "rel_err_before": {k: round(v, 4) for k, v in cal.errors_before.items()},
            "rel_err_after": {k: round(v, 4) for k, v in cal.errors_after.items()},
            "synthetic_recovery_rel_err": err,
        },
    )
