#!/usr/bin/env python
"""CI smoke lane for the fleet gateway: real processes, real sockets.

End-to-end, through the actual CLI entry points (no test fixtures):

1. build two tiny artifacts into one store -- same workload, two GPU
   targets (gtx980 + titanx), so routing has a genuine choice to make;
2. start ``python -m repro.service.cli serve`` as a child process and
   read the bound port off its stdout;
3. for each GPU: query over HTTP and assert the raw response bytes are
   **byte-identical** to the in-process ``CodesignServer`` oracle for the
   same artifact + request (the acceptance criterion), and that the
   response routed to the correct artifact key;
4. scrape ``GET /v1/metrics`` and assert the observability layer counted
   exactly the traffic issued: the ``/v1/query`` request counter matches
   the byte-identity step's query count, per-artifact hit counters and
   ``/v1/artifacts`` advisory ``hits``/``last_access`` rows agree, and
   the Prometheus text exposition parses line by line;
5. scrape ``GET /v1/slo`` and assert the ``/v1/query`` objective block
   carries 5m/1h windows with finite burn rates, a count equal to the
   queries issued, a legal status, and that ``/v1/healthz`` surfaces the
   same worst-route status in its ``slo`` field;
6. assert the structured error paths answer as documented
   (unknown artifact -> 404 ``unknown_artifact``, malformed JSON -> 400
   ``bad_request``) without taking the server down;
7. assert ``serve`` on a missing store exits non-zero with a one-line
   error (no traceback).

Exit 0 and print PASS only if every check holds.

Usage: python scripts/gateway_smoke.py [--store DIR] [--downsample N]
"""

from __future__ import annotations

import argparse
import os
import re
import subprocess
import sys
import tempfile

# runnable with or without `pip install -e .` (CI installs; dev may not)
_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
if os.path.isdir(_SRC) and _SRC not in sys.path:
    sys.path.insert(0, _SRC)

from repro.service import ArtifactStore, CodesignServer, GatewayClient  # noqa: E402
from repro.service import wire  # noqa: E402
from repro.service.query import QueryRequest  # noqa: E402

CLI = [sys.executable, "-m", "repro.service.cli"]
GPUS = ("gtx980", "titanx")


def _env() -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    env["PYTHONUNBUFFERED"] = "1"
    return env


def check(ok: bool, what: str) -> None:
    print(f"  {'ok' if ok else 'FAIL'}: {what}")
    if not ok:
        raise SystemExit(f"gateway smoke failed at: {what}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--store", default=None, help="store dir (default: temp)")
    ap.add_argument("--downsample", type=int, default=48,
                    help="hw-space thinning for the tiny builds")
    args = ap.parse_args()
    store_root = args.store or tempfile.mkdtemp(prefix="gateway-smoke-")

    print(f"[1/7] building {len(GPUS)} artifacts under {store_root}")
    for gpu in GPUS:
        subprocess.run(
            CLI + ["build", "--store", store_root, "--gpu", gpu,
                   "--engine", "numpy", "--downsample", str(args.downsample)],
            check=True, env=_env(), timeout=600,
        )

    # in-process oracles over the SAME stored artifacts (warm; never sweep)
    store = ArtifactStore(store_root)
    oracles = {}
    for row in store.entries():
        art = store.get(row["key"])
        oracles[row["gpu"]] = CodesignServer.from_artifact(store, art, batch_window=0.0)
    check(set(oracles) == set(GPUS), f"store holds one artifact per GPU {GPUS}")

    print("[2/7] starting the gateway (CLI serve, port 0)")
    proc = subprocess.Popen(
        CLI + ["serve", "--store", store_root, "--port", "0"],
        stdout=subprocess.PIPE, text=True, env=_env(),
    )
    try:
        url = None
        for line in proc.stdout:  # the bound port is printed last
            m = re.search(r"serving on (http://\S+)", line)
            if m:
                url = m.group(1)
                break
        check(url is not None, "serve printed its bound address")
        client = GatewayClient(url)
        check(client.health()["artifacts"] == len(GPUS), "healthz sees both artifacts")

        print(f"[3/7] HTTP vs in-process oracle at {url}")
        requests = [
            QueryRequest(freqs={"heat2d": 3.0, "jacobi2d": 1.0}, max_area=450.0,
                         top_k=3, use_cache=False),
            QueryRequest(freqs={"heat3d": 1.0}, pareto=True, fix={"n_sm": 16.0},
                         use_cache=False),
            QueryRequest(max_area=1.0, use_cache=False),  # infeasible: -inf
        ]
        for gpu, oracle in oracles.items():
            for req in requests:
                raw = client.query_bytes(req, route={"gpu": gpu})
                want = wire.encode_response(oracle.query(req))
                check(raw == want, f"byte-identical answer (gpu={gpu})")
                resp = wire.decode_response(raw)
                check(resp.artifact_key == oracle.key,
                      f"routed to the {gpu} artifact")

        print("[4/7] metrics scrape agrees with the traffic issued")
        n_queries = len(oracles) * len(requests)
        snap = client.metrics()  # canonical-JSON snapshot
        got = sum(s["value"]
                  for s in snap["repro_gateway_requests_total"]["samples"]
                  if s["labels"].get("route") == "/v1/query")
        check(got == n_queries,
              f"/v1/query request counter == {n_queries} queries issued")
        per_art = {s["labels"]["artifact"]: s["value"]
                   for s in snap["repro_gateway_artifact_requests_total"]["samples"]}
        check(all(per_art.get(o.key) == len(requests) for o in oracles.values()),
              f"per-artifact hit counters == {len(requests)} each")
        text = client.metrics("prometheus")
        sample_re = re.compile(r'^[a-zA-Z_:][a-zA-Z0-9_:]*(\{[^}]*\})? -?[0-9.e+-]+$')
        lines = [ln for ln in text.splitlines() if ln and not ln.startswith("#")]
        check(bool(lines) and all(sample_re.match(ln) for ln in lines),
              "prometheus text exposition parses line by line")
        check("# TYPE repro_gateway_requests_total counter" in text,
              "prometheus text carries TYPE metadata")
        rows = {r["key"]: r for r in client.artifacts()}
        check(all(rows[o.key]["hits"] == len(requests)
                  and rows[o.key]["last_access"] is not None
                  for o in oracles.values()),
              "/v1/artifacts rows carry matching hits + last_access")

        print("[5/7] /v1/slo scrape: objectives + burn rates over the traffic")
        import math
        slo = client.slo()
        q = slo["routes"].get("/v1/query")
        check(q is not None, "/v1/slo reports the /v1/query route")
        check(set(q["windows"]) == {"5m", "1h"}, "slo windows are 5m + 1h")
        check(all(math.isfinite(w["availability_burn"])
                  and math.isfinite(w["latency_burn"])
                  for w in q["windows"].values()),
              "burn rates are finite numbers")
        check(q["windows"]["1h"]["count"] == n_queries,
              f"slo 1h window counted the {n_queries} queries issued")
        check(q["status"] in ("ok", "burning", "violated"),
              "route status is a legal value")
        check(client.health()["slo"] in ("ok", "burning", "violated"),
              "healthz carries the fleet slo status")
        prom = client.slo("prometheus")
        check("repro_slo_burn_rate" in prom,
              "prometheus rendering exposes repro_slo_burn_rate")

        print("[6/7] structured error paths")
        try:
            client.query(requests[0], artifact="0" * 20)
            check(False, "unknown artifact must raise")
        except wire.RemoteError as e:
            check(e.code == "unknown_artifact" and e.http_status == 404,
                  "unknown artifact -> 404 unknown_artifact")
        bad = client._http("/v1/query", b"{not json")
        try:
            wire.decode_response(bad, client._last_status)
            check(False, "malformed JSON must raise")
        except wire.RemoteError as e:
            check(e.code == "bad_request" and client._last_status == 400,
                  "malformed JSON -> 400 bad_request")
        check(client.health()["ok"], "gateway still healthy after errors")
    finally:
        proc.terminate()
        proc.wait(timeout=30)

    print("[7/7] serve on a missing store exits cleanly")
    r = subprocess.run(
        CLI + ["serve", "--store", os.path.join(store_root, "nope"), "--port", "0"],
        capture_output=True, text=True, env=_env(), timeout=120,
    )
    check(r.returncode == 2 and "error:" in r.stderr and "Traceback" not in r.stderr,
          "missing store -> exit 2, one-line error, no traceback")

    print("PASS: gateway smoke (routing + HTTP transport + metrics + error paths)")


if __name__ == "__main__":
    main()
