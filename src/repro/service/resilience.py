"""Resilience primitives for the serving stack: deadlines, admission
control, circuit breaking, and the client retry policy.

The ROADMAP's north star is a gateway that could take public traffic;
what separates that from a demo is how the *worst minute* goes. Without
this module, one slow artifact load holds a handler thread for as long
as the disk feels like, a thundering herd exhausts the
``ThreadingHTTPServer``'s accept loop before anything says no, and a
wedged store flock parks a request forever. The primitives here are the
reflexes; :mod:`repro.obs` (PR 7) is the instruments; the wiring through
the request path lives in :mod:`.gateway`, :mod:`.server`, :mod:`.store`
and :mod:`.client`.

Four independent mechanisms (each usable and testable on its own --
every class takes an injectable ``clock``/``rng``/``sleep`` seam, so the
tests never sleep):

* **deadline propagation** -- a request's ``deadline_ms`` envelope field
  (or ``X-Repro-Deadline-Ms`` header) becomes a :class:`Deadline` bound
  to a contextvar for the request's duration (:func:`deadline_scope`).
  Every stage downstream -- routing, pool build, store open, the
  microbatch rendezvous, the build lock -- calls the free function
  :func:`check_deadline` (a no-op when no deadline is in flight) and
  fails fast with a structured ``deadline_exceeded`` (HTTP 504) instead
  of piling work behind a caller that has already given up;
* **token-bucket admission control with load shedding**
  (:class:`TokenBucket`, :class:`AdmissionController`) -- a global
  bucket and bounded per-client buckets (keyed by ``X-Repro-Client`` or
  the remote address) gate ``/v1/query`` + ``/v1/query_many``; over
  budget answers ``rate_limited`` (429 + ``Retry-After``), and an
  in-flight watermark sheds with ``shed`` (503) *before* the thread
  pool exhausts;
* **circuit breakers** (:class:`CircuitBreaker`) -- around per-artifact
  server builds and store I/O. After ``threshold`` consecutive
  infrastructure failures a key's circuit opens and requests fail fast
  with ``circuit_open`` (503 + ``Retry-After``); after ``cooldown_s``
  one half-open probe is let through and its outcome closes or re-opens
  the circuit. Structured :class:`~.errors.GatewayError` outcomes
  (client errors, deadline hits) do NOT count as failures -- only raw
  exceptions (the infrastructure actually breaking) trip the breaker;
* **client retry policy** (:class:`RetryPolicy`) -- bounded exponential
  backoff with full jitter, honoring ``Retry-After``. The policy object
  only *computes delays*; :class:`repro.service.client.GatewayClient`
  applies it, retrying idempotent failures only (429 / 503 /
  connection reset) and never timeouts.

Every resilience event lands in the :mod:`repro.obs` metrics registry
(sheds, rejections, deadline hits by stage, breaker transitions), so a
``GET /v1/metrics`` scrape tells the whole story. Knobs, the error-code
table, and tuning guidance are documented in ``docs/resilience.md``.
"""

from __future__ import annotations

import contextlib
import contextvars
import math
import threading
import time
from collections import OrderedDict
from typing import Dict, Iterator, Optional

from repro.obs import get_logger
from repro.obs.metrics import get_registry as _obs_registry

from .errors import ERROR_HTTP_STATUS, GatewayError

__all__ = [
    "DEADLINE_HEADER",
    "CLIENT_HEADER",
    "Deadline",
    "DeadlineExceededError",
    "RateLimitedError",
    "ShedError",
    "CircuitOpenError",
    "TokenBucket",
    "AdmissionController",
    "CircuitBreaker",
    "RetryPolicy",
    "GatewayResilience",
    "deadline_scope",
    "current_deadline",
    "check_deadline",
    "remaining_s",
]

#: request header carrying the caller's total time budget (milliseconds,
#: positive float). The envelope field ``deadline_ms`` means the same
#: thing; when both are present the smaller budget wins.
DEADLINE_HEADER = "X-Repro-Deadline-Ms"

#: request header naming the client for per-client admission buckets;
#: the remote address is the fallback key.
CLIENT_HEADER = "X-Repro-Client"

# ---- observability (repro.obs; no-ops under REPRO_OBS_DISABLED=1) --------
_LOG = get_logger("repro.resilience")
_REG = _obs_registry()
_M_DEADLINE = _REG.counter(
    "repro_resilience_deadline_exceeded_total",
    "requests failed because their deadline budget ran out, by the "
    "pipeline stage that noticed",
    labels=("stage",),
)
_M_REJECTED = _REG.counter(
    "repro_resilience_rejections_total",
    "admission-control rejections, by reason "
    "(rate_limited_global | rate_limited_client | shed)",
    labels=("reason",),
)
_M_INFLIGHT = _REG.gauge(
    "repro_gateway_inflight_requests",
    "query requests currently admitted and executing (the load-shed "
    "watermark watches this)",
)
_M_BREAKER_STATE = _REG.gauge(
    "repro_resilience_breaker_state",
    "circuit state per breaker key (0=closed, 1=open, 2=half-open)",
    labels=("key",),
)
_M_BREAKER_TRANSITIONS = _REG.counter(
    "repro_resilience_breaker_transitions_total",
    "circuit state transitions, by breaker key and destination state",
    labels=("key", "to"),
)


# ---------------------------------------------------------------------------
# structured errors (the wire codes live in .errors.ERROR_HTTP_STATUS)
# ---------------------------------------------------------------------------
class DeadlineExceededError(GatewayError):
    """The request's ``deadline_ms`` budget ran out before the answer was
    ready; the message names the stage that noticed (HTTP 504). Not
    retryable as-is: the same budget would burn the same way."""

    code = "deadline_exceeded"
    http_status = ERROR_HTTP_STATUS["deadline_exceeded"]


class RateLimitedError(GatewayError):
    """Admission control's token bucket (global or per-client) is out of
    budget (HTTP 429). ``retry_after_s`` says when the bucket will have
    a token again; the HTTP handler surfaces it as ``Retry-After``."""

    code = "rate_limited"
    http_status = ERROR_HTTP_STATUS["rate_limited"]

    def __init__(self, message: str, retry_after_s: float = 1.0):
        super().__init__(message)
        self.retry_after_s = float(retry_after_s)


class ShedError(GatewayError):
    """The gateway is over its in-flight watermark and shed this request
    rather than queue it behind work it cannot finish (HTTP 503).
    Retryable after a short backoff."""

    code = "shed"
    http_status = ERROR_HTTP_STATUS["shed"]

    def __init__(self, message: str, retry_after_s: float = 1.0):
        super().__init__(message)
        self.retry_after_s = float(retry_after_s)


class CircuitOpenError(GatewayError):
    """The key's circuit breaker is open: recent attempts kept failing,
    so the gateway fails fast instead of hammering a broken dependency
    (HTTP 503). ``retry_after_s`` is the remaining cooldown before a
    half-open probe is allowed."""

    code = "circuit_open"
    http_status = ERROR_HTTP_STATUS["circuit_open"]

    def __init__(self, message: str, retry_after_s: float = 1.0):
        super().__init__(message)
        self.retry_after_s = float(retry_after_s)


# ---------------------------------------------------------------------------
# deadlines
# ---------------------------------------------------------------------------
class Deadline:
    """A monotonic-clock time budget, created once at request ingress.

    Stages *check* it (:meth:`check` raises :class:`DeadlineExceededError`
    past expiry) or *cap* their own waits by :meth:`remaining_s`; nobody
    extends it. The injectable ``clock`` keeps tests sleep-free."""

    __slots__ = ("budget_ms", "_expires", "_clock")

    def __init__(self, budget_ms: float, clock=time.monotonic):
        budget_ms = float(budget_ms)
        if not math.isfinite(budget_ms) or budget_ms <= 0:
            raise ValueError(f"deadline budget must be a positive finite "
                             f"number of ms, got {budget_ms!r}")
        self.budget_ms = budget_ms
        self._clock = clock
        self._expires = clock() + budget_ms / 1000.0

    def remaining_s(self) -> float:
        """Seconds of budget left (never negative)."""
        return max(0.0, self._expires - self._clock())

    @property
    def expired(self) -> bool:
        return self._clock() >= self._expires

    def check(self, stage: str) -> None:
        """Raise ``deadline_exceeded`` (and count it, labeled by stage)
        when the budget is gone; free when it is not."""
        if self.expired:
            _M_DEADLINE.labels(stage=stage).inc()
            raise DeadlineExceededError(
                f"deadline of {self.budget_ms:g}ms exceeded at stage "
                f"{stage!r}"
            )

    def __repr__(self) -> str:
        return (f"Deadline(budget_ms={self.budget_ms:g}, "
                f"remaining_s={self.remaining_s():.3f})")


#: the in-flight request's deadline. A contextvar (not an argument
#: threaded through every signature) so the store and server layers can
#: stay deadline-aware without their APIs knowing about HTTP ingress;
#: contextvars propagate into `with` blocks and down the call stack but
#: NOT into unrelated threads, so concurrent requests never share one.
_CURRENT_DEADLINE: contextvars.ContextVar[Optional[Deadline]] = (
    contextvars.ContextVar("repro_deadline", default=None)
)


@contextlib.contextmanager
def deadline_scope(deadline: Optional[Deadline]) -> Iterator[None]:
    """Bind ``deadline`` as the current request's budget for the dynamic
    extent of the block (``None`` explicitly clears an inherited one)."""
    token = _CURRENT_DEADLINE.set(deadline)
    try:
        yield
    finally:
        _CURRENT_DEADLINE.reset(token)


def current_deadline() -> Optional[Deadline]:
    """The in-flight request's :class:`Deadline`, or None."""
    return _CURRENT_DEADLINE.get()


def check_deadline(stage: str) -> None:
    """Stage checkpoint: raise ``deadline_exceeded`` iff a deadline is in
    flight and spent. The no-deadline fast path is one contextvar read,
    cheap enough for every hop of the request pipeline."""
    d = _CURRENT_DEADLINE.get()
    if d is not None:
        d.check(stage)


def remaining_s(default: Optional[float] = None) -> Optional[float]:
    """Seconds left on the in-flight deadline, or ``default`` when no
    deadline is set -- the cap for bounded waits (rendezvous windows,
    lock timeouts)."""
    d = _CURRENT_DEADLINE.get()
    return default if d is None else d.remaining_s()


# ---------------------------------------------------------------------------
# admission control
# ---------------------------------------------------------------------------
class TokenBucket:
    """Classic token bucket: ``burst`` capacity, refilled at ``rate``
    tokens/second. ``rate=0`` (or ``inf``) disables the bucket entirely
    (always admits) -- the unconfigured default costs one comparison.

    Thread-safe; time comes from the injectable ``clock``."""

    def __init__(self, rate: float, burst: Optional[float] = None,
                 clock=time.monotonic):
        self.rate = float(rate)
        if self.rate < 0:
            raise ValueError("rate must be >= 0 (0 disables the bucket)")
        self.burst = float(burst) if burst is not None else max(1.0, self.rate)
        if self.burst <= 0 and self._limiting:
            raise ValueError("burst must be > 0")
        self._clock = clock
        self._tokens = self.burst
        self._last = clock()
        self._mu = threading.Lock()

    @property
    def _limiting(self) -> bool:
        return self.rate > 0 and math.isfinite(self.rate)

    def try_acquire(self, n: float = 1.0) -> float:
        """Take ``n`` tokens if available. Returns ``0.0`` on admit, else
        the seconds until ``n`` tokens will exist (the Retry-After
        hint). Never blocks."""
        if not self._limiting:
            return 0.0
        with self._mu:
            now = self._clock()
            self._tokens = min(
                self.burst, self._tokens + (now - self._last) * self.rate
            )
            self._last = now
            if self._tokens >= n:
                self._tokens -= n
                return 0.0
            return (n - self._tokens) / self.rate


class AdmissionController:
    """Front-door admission for the query routes: shed on queue depth
    first (the cheapest overload signal), then the global bucket, then
    the caller's bucket.

    Parameters
    ----------
    global_rate / global_burst:
        Token budget shared by every caller (requests/second); ``0``
        disables the global bucket (the default).
    client_rate / client_burst:
        Per-client-key budget; ``0`` disables (the default). Client
        buckets live in an LRU bounded by ``max_clients`` so a key-
        scanning client cannot grow memory without bound.
    max_inflight:
        The load-shed watermark: when this many admitted requests are
        still executing, new ones answer ``shed`` (503) instead of
        queueing. ``0`` disables shedding.
    """

    def __init__(
        self,
        global_rate: float = 0.0,
        global_burst: Optional[float] = None,
        client_rate: float = 0.0,
        client_burst: Optional[float] = None,
        max_inflight: int = 0,
        max_clients: int = 1024,
        clock=time.monotonic,
    ):
        self._clock = clock
        self.global_bucket = TokenBucket(global_rate, global_burst, clock)
        self.client_rate = float(client_rate)
        self.client_burst = client_burst
        self.max_inflight = int(max_inflight)
        self.max_clients = int(max_clients)
        self._clients: "OrderedDict[str, TokenBucket]" = OrderedDict()
        self._inflight = 0
        self._mu = threading.Lock()

    @property
    def inflight(self) -> int:
        with self._mu:
            return self._inflight

    def _client_bucket(self, client: str) -> Optional[TokenBucket]:
        if self.client_rate <= 0 or not math.isfinite(self.client_rate):
            return None
        with self._mu:
            bucket = self._clients.get(client)
            if bucket is None:
                bucket = TokenBucket(
                    self.client_rate, self.client_burst, self._clock
                )
                self._clients[client] = bucket
            self._clients.move_to_end(client)
            while len(self._clients) > self.max_clients:
                self._clients.popitem(last=False)
        return bucket

    @contextlib.contextmanager
    def admit(self, client: str) -> Iterator[None]:
        """Admit one request for ``client`` (held for its duration) or
        raise :class:`ShedError` / :class:`RateLimitedError`."""
        with self._mu:
            if 0 < self.max_inflight <= self._inflight:
                _M_REJECTED.labels(reason="shed").inc()
                raise ShedError(
                    f"gateway over its in-flight watermark "
                    f"({self._inflight} >= {self.max_inflight}); shedding",
                    retry_after_s=1.0,
                )
            self._inflight += 1
            _M_INFLIGHT.set(self._inflight)
        try:
            wait = self.global_bucket.try_acquire()
            if wait > 0:
                _M_REJECTED.labels(reason="rate_limited_global").inc()
                raise RateLimitedError(
                    f"global rate limit "
                    f"({self.global_bucket.rate:g} req/s) exceeded",
                    retry_after_s=wait,
                )
            bucket = self._client_bucket(client)
            if bucket is not None:
                wait = bucket.try_acquire()
                if wait > 0:
                    _M_REJECTED.labels(reason="rate_limited_client").inc()
                    raise RateLimitedError(
                        f"client {client!r} over its rate limit "
                        f"({bucket.rate:g} req/s)",
                        retry_after_s=wait,
                    )
            yield
        finally:
            with self._mu:
                self._inflight -= 1
                _M_INFLIGHT.set(self._inflight)


# ---------------------------------------------------------------------------
# circuit breaker
# ---------------------------------------------------------------------------
class CircuitBreaker:
    """Per-key fail-fast switch around an unreliable dependency.

    closed --(``threshold`` consecutive failures)--> open
    open --(``cooldown_s`` elapsed)--> half-open (ONE probe admitted)
    half-open --(probe ok)--> closed | --(probe fails)--> open

    What counts as a failure is deliberate: only *raw* exceptions -- the
    dependency actually breaking (I/O errors, corrupt artifacts). A
    structured :class:`~.errors.GatewayError` is a classified outcome
    (the caller's key was wrong, their deadline ran out) and neither
    trips nor resets the breaker. :class:`CircuitOpenError` raised by
    the breaker itself is likewise transparent."""

    CLOSED, OPEN, HALF_OPEN = "closed", "open", "half_open"
    _STATE_GAUGE = {CLOSED: 0, OPEN: 1, HALF_OPEN: 2}

    def __init__(self, key: str, threshold: int = 5, cooldown_s: float = 30.0,
                 clock=time.monotonic):
        if threshold < 1:
            raise ValueError("threshold must be >= 1")
        self.key = str(key)
        self.threshold = int(threshold)
        self.cooldown_s = float(cooldown_s)
        self._clock = clock
        self._mu = threading.Lock()
        self._state = self.CLOSED
        self._failures = 0  # consecutive, while closed
        self._opened_at = 0.0
        self._probing = False  # a half-open probe is in flight

    @property
    def state(self) -> str:
        with self._mu:
            return self._state

    def _transition(self, to: str) -> None:
        # callers hold self._mu
        if self._state != to:
            _LOG.info("breaker_transition", key=self.key[:12],
                      frm=self._state, to=to)
            _M_BREAKER_TRANSITIONS.labels(key=self.key, to=to).inc()
        self._state = to
        _M_BREAKER_STATE.labels(key=self.key).set(self._STATE_GAUGE[to])

    @contextlib.contextmanager
    def call(self) -> Iterator[None]:
        """Guard one attempt against the dependency: raises
        :class:`CircuitOpenError` while open, records the wrapped
        block's outcome otherwise."""
        probe = False
        with self._mu:
            if self._state == self.OPEN:
                elapsed = self._clock() - self._opened_at
                if elapsed < self.cooldown_s:
                    raise CircuitOpenError(
                        f"circuit for {self.key[:12]!r} is open "
                        f"({self._failures} consecutive failures); "
                        f"half-open probe in "
                        f"{self.cooldown_s - elapsed:.1f}s",
                        retry_after_s=self.cooldown_s - elapsed,
                    )
                self._transition(self.HALF_OPEN)
            if self._state == self.HALF_OPEN:
                if self._probing:  # one probe at a time; the rest wait out
                    raise CircuitOpenError(
                        f"circuit for {self.key[:12]!r} is half-open with "
                        f"a probe in flight",
                        retry_after_s=self.cooldown_s,
                    )
                self._probing = True
                probe = True
        try:
            yield
        except GatewayError:
            # a classified outcome, not the dependency breaking: leave the
            # breaker state alone (a probe slot is released, not judged)
            with self._mu:
                if probe:
                    self._probing = False
            raise
        except BaseException:
            with self._mu:
                if probe:
                    self._probing = False
                self._failures += 1
                if self._state == self.HALF_OPEN or (
                    self._state == self.CLOSED
                    and self._failures >= self.threshold
                ):
                    self._opened_at = self._clock()
                    self._transition(self.OPEN)
            raise
        else:
            with self._mu:
                if probe:
                    self._probing = False
                self._failures = 0
                self._transition(self.CLOSED)


# ---------------------------------------------------------------------------
# client retry policy
# ---------------------------------------------------------------------------
class RetryPolicy:
    """Bounded exponential backoff with full jitter (delay computation
    only -- the transport applies it).

    ``delay(attempt, rng, retry_after_s)``: attempt 1 is the first
    *retry*. The exponential ramp is ``base_s * 2**(attempt-1)`` capped
    at ``max_s``, jittered down to ``[ (1-jitter)*d, d ]`` with the
    caller's ``rng`` (injectable, so tests are deterministic). A server
    ``Retry-After`` hint overrides the computed delay (still capped at
    ``max_s`` -- a confused server must not park the client for an
    hour)."""

    def __init__(self, max_retries: int = 3, base_s: float = 0.05,
                 max_s: float = 2.0, jitter: float = 0.5):
        if max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        if not 0.0 <= jitter <= 1.0:
            raise ValueError("jitter must be in [0, 1]")
        self.max_retries = int(max_retries)
        self.base_s = float(base_s)
        self.max_s = float(max_s)
        self.jitter = float(jitter)

    def delay(self, attempt: int, rng,
              retry_after_s: Optional[float] = None) -> float:
        if retry_after_s is not None:
            return max(0.0, min(float(retry_after_s), self.max_s))
        d = min(self.max_s, self.base_s * (2.0 ** (attempt - 1)))
        return d * (1.0 - self.jitter * rng.random())

    def __repr__(self) -> str:
        return (f"RetryPolicy(max_retries={self.max_retries}, "
                f"base_s={self.base_s:g}, max_s={self.max_s:g}, "
                f"jitter={self.jitter:g})")


# ---------------------------------------------------------------------------
# the gateway-side bundle
# ---------------------------------------------------------------------------
class GatewayResilience:
    """Everything a :class:`~.gateway.Gateway` needs to defend itself,
    in one object: the admission controller for the HTTP front door and
    a registry of per-key circuit breakers for artifact builds / store
    I/O. The defaults are deliberately permissive (no rate limits, a
    high shed watermark) so an unconfigured gateway behaves exactly like
    the pre-resilience one on the happy path -- the knobs exist for
    operators (``serve --rate-limit ...``; see ``docs/resilience.md``)."""

    def __init__(
        self,
        global_rate: float = 0.0,
        global_burst: Optional[float] = None,
        client_rate: float = 0.0,
        client_burst: Optional[float] = None,
        max_inflight: int = 128,
        max_clients: int = 1024,
        breaker_threshold: int = 5,
        breaker_cooldown_s: float = 30.0,
        clock=time.monotonic,
    ):
        self.admission = AdmissionController(
            global_rate=global_rate,
            global_burst=global_burst,
            client_rate=client_rate,
            client_burst=client_burst,
            max_inflight=max_inflight,
            max_clients=max_clients,
            clock=clock,
        )
        self.breaker_threshold = int(breaker_threshold)
        self.breaker_cooldown_s = float(breaker_cooldown_s)
        self._clock = clock
        self._breakers: Dict[str, CircuitBreaker] = {}
        self._mu = threading.Lock()

    def breaker(self, key: str) -> CircuitBreaker:
        """The (lazily created) circuit breaker guarding one key."""
        with self._mu:
            b = self._breakers.get(key)
            if b is None:
                b = CircuitBreaker(
                    key,
                    threshold=self.breaker_threshold,
                    cooldown_s=self.breaker_cooldown_s,
                    clock=self._clock,
                )
                self._breakers[key] = b
            return b
