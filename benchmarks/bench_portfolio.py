"""Fleet portfolio codesign (docs/portfolio.md): time the K-design subset
search over the paper workload's sweep, NumPy float64 oracle vs the jitted
JAX scorer, and check the two engines land on the same fleet objective."""

from __future__ import annotations

import time

import numpy as np

from repro.core import codesign, enumerate_hw_space
from repro.core.portfolio import optimize_portfolio, portfolio_candidates
from repro.core.workload import paper_workload

from .common import SMOKE_HW_STRIDE, emit, smoke

K = 2
BUDGET = 900.0  # mm^2 fleet budget, the docs' running example


def run() -> dict:
    hw = enumerate_hw_space().downsample(SMOKE_HW_STRIDE if smoke() else 4)
    t0 = time.perf_counter()
    res = codesign(paper_workload(), hw=hw, engine="numpy")
    solve_s = time.perf_counter() - t0

    # the dominance prefilter is what makes C(n, K) enumerable: report how
    # hard it squeezes the swept space before any subset is scored
    n_cand = int(portfolio_candidates(
        np.asarray(res.hw.area, np.float64),
        np.asarray(res.cell_time, np.float64)).sum())

    t0 = time.perf_counter()
    p_np = optimize_portfolio(res, k=K, budget=BUDGET, objective="throughput")
    numpy_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    p_jax = optimize_portfolio(res, k=K, budget=BUDGET,
                               objective="throughput", engine="jax")
    jax_s = time.perf_counter() - t0

    # engines may name different members on a float32-level tie, but the
    # fleet objective itself must agree (tests/test_portfolio.py holds the
    # stronger bit-level contract; this is the perf lane's sanity check)
    rel = abs(p_jax.fleet_gflops - p_np.fleet_gflops) / p_np.fleet_gflops
    assert rel < 1e-5, (p_np.members, p_jax.members, rel)

    _, single = res.best(max_area=BUDGET)
    emit(
        f"portfolio_numpy_k{K}", numpy_s * 1e6,
        f"{len(hw)} hw -> {n_cand} candidates; fleet "
        f"{p_np.fleet_gflops:.0f} GFLOP/s @ {p_np.total_area:.0f} mm^2",
    )
    emit(
        f"portfolio_jax_k{K}", jax_s * 1e6,
        f"{numpy_s / jax_s:.1f}x vs numpy; members {list(p_jax.members)}",
    )
    emit(
        "portfolio_vs_single", numpy_s * 1e6,
        f"fleet {p_np.fleet_gflops:.0f} vs best single {single:.0f} GFLOP/s "
        f"under {BUDGET:.0f} mm^2",
    )
    return {
        "suite": "portfolio",
        "smoke": smoke(),
        "k": K,
        "budget_mm2": BUDGET,
        "n_hw": int(len(hw)),
        "n_candidates": n_cand,
        "sweep_solve_s": round(solve_s, 4),
        "numpy_s": round(numpy_s, 4),
        "jax_s": round(jax_s, 4),
        "members": [int(i) for i in p_np.members],
        "fleet_gflops": round(p_np.fleet_gflops, 1),
        "single_gflops": round(float(single), 1),
    }
