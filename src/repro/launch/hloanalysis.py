"""Scan-aware accounting over optimized HLO text.

Why this exists: ``compiled.cost_analysis()`` (HloCostAnalysis) visits a
``while`` body ONCE -- but our stacks are ``lax.scan``s over layers, so both
FLOPs and collective bytes would be undercounted by the layer count (32-61x)
if read naively. This module parses the optimized HLO dump into its
computation graph, derives each while loop's trip count from its condition
computation, and accumulates

* dot FLOPs          (2 * prod(result) * contracted extent), and
* collective operand bytes per op kind,

with every computation expanded through ``calls=``/``to_apply=``/
``condition=``/``body=`` edges and while bodies multiplied by their trip
count. Fusions are expanded too (CPU emits dot fusions), so nothing is
double-counted: only leaf ``dot``/collective instructions contribute.

This is text-based on purpose: it needs nothing beyond ``compiled.as_text()``
and is validated against analytic FLOP counts in tests/test_hloanalysis.py.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

__all__ = ["analyze_hlo", "HloTotals"]

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COMP_START = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*->.*\{\s*$")
_INSTR = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+)$")
_SHAPE = re.compile(r"\b(f64|f32|f16|bf16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|s16|u16|s8|u8|pred|c64|c128)\[([0-9,]*)\]")
_OP = re.compile(r"^(?:\([^=]*\)|\S+)\s+([\w\-]+)\(")
_CALLEE = re.compile(r"(?:calls|to_apply|body|condition|true_computation|false_computation)=%?([\w\.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_CONST_INT = re.compile(r"\bconstant\((\d+)\)")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")


def _shapes_in(text: str) -> List[Tuple[str, Tuple[int, ...]]]:
    out = []
    for t, dims in _SHAPE.findall(text):
        shape = tuple(int(d) for d in dims.split(",") if d)
        out.append((t, shape))
    return out


def _nbytes(shapes) -> float:
    total = 0.0
    for t, shape in shapes:
        n = 1
        for d in shape:
            n *= d
        total += n * _DTYPE_BYTES[t]
    return total


@dataclasses.dataclass
class _Instr:
    name: str
    op: str
    result_shapes: list
    line: str


@dataclasses.dataclass
class _Comp:
    name: str
    instrs: List[_Instr]
    symbols: Dict[str, list]  # instr name -> result shapes


@dataclasses.dataclass
class HloTotals:
    dot_flops: float = 0.0
    collective_bytes: float = 0.0
    materialized_bytes: float = 0.0  # fusion-boundary HBM-traffic proxy
    per_collective: Dict[str, Dict[str, float]] = dataclasses.field(default_factory=dict)
    while_trips: List[int] = dataclasses.field(default_factory=list)

    def add(self, other: "HloTotals", mult: float = 1.0) -> None:
        self.dot_flops += other.dot_flops * mult
        self.collective_bytes += other.collective_bytes * mult
        self.materialized_bytes += other.materialized_bytes * mult
        for k, v in other.per_collective.items():
            rec = self.per_collective.setdefault(k, {"count": 0.0, "bytes": 0.0})
            rec["count"] += v["count"] * mult
            rec["bytes"] += v["bytes"] * mult


#: ops that do not materialize a new buffer (aliases/metadata/control).
#: while/conditional results alias their carries (the interior ops are
#: counted when the body computations are walked).
_NO_MATERIALIZE = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "while",
    "conditional",
}


def _parse_computations(text: str) -> Tuple[Dict[str, _Comp], Optional[str]]:
    comps: Dict[str, _Comp] = {}
    entry: Optional[str] = None
    cur: Optional[_Comp] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_START.match(line.strip())
            if m:
                cur = _Comp(m.group(1), [], {})
                if line.strip().startswith("ENTRY"):
                    entry = cur.name
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        # strip /*index=N*/ comments: they contain '=' and break op matching
        rhs = re.sub(r"/\*.*?\*/", " ", rhs)
        line = re.sub(r"/\*.*?\*/", " ", line)
        op_m = _OP.match(rhs)
        op = op_m.group(1) if op_m else rhs.split()[0]
        # result shapes: the segment before the op token
        cut = rhs.find(op + "(") if op_m else len(rhs)
        result_shapes = _shapes_in(rhs[: cut if cut > 0 else len(rhs)])
        instr = _Instr(name, op, result_shapes, line)
        cur.instrs.append(instr)
        cur.symbols[name] = result_shapes
    return comps, entry


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


def _operand_names(line: str, op: str) -> List[str]:
    i = line.find(op + "(")
    if i < 0:
        return []
    args = line[i + len(op) + 1 :]
    depth, end = 1, len(args)
    for j, ch in enumerate(args):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                end = j
                break
    return re.findall(r"%([\w\.\-]+)", args[:end])


def _dot_flops(instr: _Instr, comp: _Comp) -> float:
    """2 * prod(result) * contracted extent (from lhs shape + dims)."""
    result_elems = 1.0
    for _, shape in instr.result_shapes[:1]:
        for d in shape:
            result_elems *= d
    m = _CONTRACT.search(instr.line)
    contracted = 1.0
    if m:
        dims = [int(d) for d in m.group(1).split(",") if d]
        ops = _operand_names(instr.line, "dot")
        if ops:
            lhs_shapes = comp.symbols.get(ops[0]) or []
            if lhs_shapes:
                _, lhs = lhs_shapes[0]
                for d in dims:
                    if d < len(lhs):
                        contracted *= lhs[d]
    return 2.0 * result_elems * contracted


def _trip_count(cond: _Comp) -> int:
    """Max integer constant in the loop condition (jax scans: compare-LT)."""
    best = 1
    for instr in cond.instrs:
        for m in _CONST_INT.finditer(instr.line):
            best = max(best, int(m.group(1)))
    return best


def _inline_computations(comps: Dict[str, _Comp]) -> set:
    """Computations reached via calls=/to_apply= (fusion bodies, reduce
    combiners, ...): their instructions live in registers/VMEM, not HBM.
    While bodies and conditional branches are NOT inline -- their values
    materialize every iteration."""
    inline = set()
    for comp in comps.values():
        for instr in comp.instrs:
            if instr.op in ("while", "conditional"):
                continue
            for callee in _CALLEE.finditer(instr.line):
                kind, cname = callee.group(0).split("=")[0], callee.group(1)
                if kind in ("calls", "to_apply"):
                    inline.add(cname)
    # transitively: anything called from an inline computation is inline
    changed = True
    while changed:
        changed = False
        for name in list(inline):
            comp = comps.get(name)
            if not comp:
                continue
            for instr in comp.instrs:
                for callee in _CALLEE.finditer(instr.line):
                    cname = callee.group(1)
                    if cname not in inline:
                        inline.add(cname)
                        changed = True
    return inline


def _totals(
    comp_name: str, comps: Dict[str, _Comp], memo: Dict[str, HloTotals],
    inline: Optional[set] = None,
) -> HloTotals:
    if comp_name in memo:
        return memo[comp_name]
    memo[comp_name] = HloTotals()  # cycle guard
    comp = comps.get(comp_name)
    if comp is None:
        return memo[comp_name]
    if inline is None:
        inline = set()
    is_fusion_boundary = comp_name not in inline
    tot = HloTotals()
    for instr in comp.instrs:
        base_op = instr.op.replace("-start", "")
        if is_fusion_boundary and instr.op not in _NO_MATERIALIZE:
            # each materialized tensor is written once and read ~once.
            # dynamic-update-slice (and fusions rooted in one -- XLA names
            # them so) updates in place: count the smallest operand (the
            # update slice), not the full buffer, or grad-stack writes in
            # layer scans would be overcounted by the layer count.
            nbytes = _nbytes(instr.result_shapes)
            if "dynamic-update-slice" in instr.op or "dynamic-update-slice" in instr.name:
                op_sizes = [
                    _nbytes(comp.symbols[o])
                    for o in _operand_names(instr.line, instr.op)
                    if o in comp.symbols and comp.symbols[o]
                ]
                if op_sizes:
                    nbytes = min(op_sizes)
            tot.materialized_bytes += 2.0 * nbytes
        if base_op in _COLLECTIVES:
            nbytes = _nbytes(instr.result_shapes)
            g = _group_size(instr.line)
            if base_op == "all-gather":
                nbytes /= max(g, 1)
            elif base_op == "reduce-scatter":
                nbytes *= max(g, 1)
            rec = tot.per_collective.setdefault(base_op, {"count": 0.0, "bytes": 0.0})
            rec["count"] += 1
            rec["bytes"] += nbytes
            tot.collective_bytes += nbytes
        elif instr.op == "dot":
            tot.dot_flops += _dot_flops(instr, comp)
        if instr.op == "while":
            body = cond = None
            for callee in _CALLEE.finditer(instr.line):
                kind = callee.group(0).split("=")[0]
                if kind == "body":
                    body = callee.group(1)
                elif kind == "condition":
                    cond = callee.group(1)
            trips = _trip_count(comps[cond]) if cond in comps else 1
            tot.while_trips.append(trips)
            if body:
                tot.add(_totals(body, comps, memo, inline), mult=trips)
        else:
            seen = set()
            for callee in _CALLEE.finditer(instr.line):
                kind, cname = callee.group(0).split("=")[0], callee.group(1)
                if kind in ("body", "condition") or cname in seen:
                    continue
                seen.add(cname)
                tot.add(_totals(cname, comps, memo, inline))
            b = _BRANCHES.search(instr.line)
            if b:
                for cname in re.findall(r"%?([\w\.\-]+)", b.group(1)):
                    tot.add(_totals(cname, comps, memo, inline))
    memo[comp_name] = tot
    return tot


def analyze_hlo(text: str) -> HloTotals:
    """Loop-expanded totals for the entry computation."""
    comps, entry = _parse_computations(text)
    if entry is None:
        return HloTotals()
    inline = _inline_computations(comps)
    return _totals(entry, comps, {}, inline)
