"""Optimizer substrate: AdamW + schedules + gradient compression."""

from .adamw import AdamWConfig, adamw_init, adamw_update, global_norm, lr_at  # noqa: F401
from .compression import (  # noqa: F401
    CompressionState,
    compress_grads,
    compressed_psum,
    dequantize_int8,
    quantize_int8,
)
