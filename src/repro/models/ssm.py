"""Mamba2 block (SSD -- state-space duality, arXiv:2405.21060).

The SSD formulation is the TPU-friendly one: the selective scan becomes
chunked matmuls (MXU food) + one short inter-chunk recurrence:

* intra-chunk: ``Y_diag[t] = sum_{s<=t} (C_t . B_s) * exp(cum_t - cum_s)
  * dt_s * x_s`` -- an (Q x Q) masked matmul per chunk;
* chunk states: ``S_c = sum_s exp(cum_last - cum_s) * dt_s * B_s (x) x_s``;
* inter-chunk: ``S_c = exp(sum_c) * S_{c-1} + S_c_local`` via ``lax.scan``;
* off-diagonal: ``Y_off[t] = (C_t . S_{c-1}) * exp(cum_t)``.

Decode is the O(1) recurrent update on the carried state -- this is why the
ssm/hybrid architectures run the ``long_500k`` shape: the "KV cache" is a
constant-size ``(B, H, P, N)`` state plus a (d_conv-1)-deep conv window.

``ssd_reference`` is the naive per-token recurrence used as the test oracle.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .layers import dense_init, rmsnorm

__all__ = ["ssm_init", "ssm_apply", "ssd_reference", "ssm_state_shapes"]


def _dims(cfg: ArchConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    conv_ch = d_inner + 2 * s.n_groups * s.d_state
    return d_inner, n_heads, conv_ch


def ssm_init(key, cfg: ArchConfig, dtype) -> Dict:
    """Projections are kept as separate matrices (wz/wx/wbc/wdt, split convs)
    rather than one fused in_proj so the tensor-parallel rules can shard the
    d_inner-sized outputs over the ``model`` axis while the small B/C/dt
    streams stay replicated."""
    s = cfg.ssm
    d = cfg.d_model
    d_inner, h, _ = _dims(cfg)
    bc_ch = 2 * s.n_groups * s.d_state
    k1, k2, k3, k4, k5, k6 = jax.random.split(key, 6)
    return {
        "wz": dense_init(k1, (d, d_inner), dtype),
        "wx": dense_init(k2, (d, d_inner), dtype),
        "wbc": dense_init(k3, (d, bc_ch), dtype),
        "wdt": dense_init(k5, (d, h), dtype),
        "conv_x_w": dense_init(jax.random.fold_in(k2, 1), (s.d_conv, d_inner), dtype, scale=0.5),
        "conv_x_b": jnp.zeros((d_inner,), dtype),
        "conv_bc_w": dense_init(jax.random.fold_in(k3, 1), (s.d_conv, bc_ch), dtype, scale=0.5),
        "conv_bc_b": jnp.zeros((bc_ch,), dtype),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "a_log": jnp.log(
            jnp.linspace(1.0, 16.0, h, dtype=jnp.float32)
        ),  # A in [-16, -1]
        "d_skip": jnp.ones((h,), jnp.float32),
        "norm_w": jnp.ones((d_inner,), dtype),
        "out_proj": dense_init(k4, (d_inner, d), dtype),
    }


def ssm_state_shapes(cfg: ArchConfig, batch: int):
    """Decode-cache shapes (the SSM analogue of a KV cache)."""
    s = cfg.ssm
    d_inner, h, _ = _dims(cfg)
    return {
        "conv_x": (batch, s.d_conv - 1, d_inner),
        "conv_bc": (batch, s.d_conv - 1, 2 * s.n_groups * s.d_state),
        "ssm": (batch, h, s.head_dim, s.d_state),
    }


def _segsum(x):
    """exp-arg matrix: out[..., t, s] = sum_{s < r <= t} x[..., r] (t >= s)."""
    t = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((t, t), bool))
    return jnp.where(mask, out, -jnp.inf)


def _ssd_chunked(xdt, dta, b_mat, c_mat, chunk: int, state0):
    """Chunked SSD scan.

    xdt: (B,L,H,P) -- dt-weighted inputs; dta: (B,L,H) -- dt*A decays;
    b_mat/c_mat: (B,L,H,N) (groups already broadcast to heads);
    state0: (B,H,P,N) or None. Returns (y (B,L,H,P), state (B,H,P,N)).
    """
    bsz, l, h, p = xdt.shape
    n = b_mat.shape[-1]
    pad = (-l) % chunk
    if pad:
        xdt = jnp.pad(xdt, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dta = jnp.pad(dta, ((0, 0), (0, pad), (0, 0)))
        b_mat = jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c_mat = jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
    lc = xdt.shape[1]
    nc = lc // chunk
    xdt_c = xdt.reshape(bsz, nc, chunk, h, p)
    dta_c = dta.reshape(bsz, nc, chunk, h)
    b_c = b_mat.reshape(bsz, nc, chunk, h, n)
    c_c = c_mat.reshape(bsz, nc, chunk, h, n)

    cum = jnp.cumsum(dta_c, axis=2)  # (B,nc,Q,H)

    # intra-chunk (diagonal blocks)
    larg = _segsum(jnp.moveaxis(dta_c, 3, 2))  # (B,nc,H,Q,Q)
    lmat = jnp.exp(larg)
    scores = jnp.einsum("bcthn,bcshn->bchts", c_c, b_c) * lmat.astype(c_c.dtype)
    y_diag = jnp.einsum("bchts,bcshp->bcthp", scores, xdt_c)

    # per-chunk final states
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)  # (B,nc,Q,H)
    states = jnp.einsum(
        "bcshn,bcsh,bcshp->bchpn", b_c, decay_to_end.astype(b_c.dtype), xdt_c
    )  # (B,nc,H,P,N)

    # inter-chunk recurrence
    chunk_decay = jnp.exp(cum[:, :, -1, :])  # (B,nc,H)
    if state0 is None:
        state0 = jnp.zeros((bsz, h, p, n), xdt.dtype)

    def step(s_prev, inp):
        dec, st = inp  # (B,H), (B,H,P,N)
        s_new = s_prev * dec[..., None, None].astype(s_prev.dtype) + st
        return s_new, s_prev

    final, prevs = jax.lax.scan(
        step,
        state0,
        (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(states, 1, 0)),
    )
    prev_states = jnp.moveaxis(prevs, 0, 1)  # (B,nc,H,P,N) state before chunk

    # off-diagonal contribution from carried state
    in_decay = jnp.exp(cum)  # (B,nc,Q,H)
    y_off = jnp.einsum(
        "bcthn,bchpn->bcthp", c_c * in_decay[..., None].astype(c_c.dtype), prev_states
    )

    y = (y_diag + y_off).reshape(bsz, lc, h, p)[:, :l]
    return y, final


def ssd_reference(xdt, dta, b_mat, c_mat, state0=None):
    """Naive per-token recurrence (oracle): S_t = exp(dta_t) S + B_t (x) xdt_t;
    y_t = C_t . S_t. Shapes as in :func:`_ssd_chunked`."""
    bsz, l, h, p = xdt.shape
    n = b_mat.shape[-1]
    if state0 is None:
        state0 = jnp.zeros((bsz, h, p, n), xdt.dtype)

    def step(s, inp):
        xt, at, bt, ct = inp
        s = s * jnp.exp(at)[..., None, None].astype(s.dtype) + jnp.einsum(
            "bhp,bhn->bhpn", xt, bt
        )
        y = jnp.einsum("bhpn,bhn->bhp", s, ct)
        return s, y

    final, ys = jax.lax.scan(
        step,
        state0,
        (
            jnp.moveaxis(xdt, 1, 0),
            jnp.moveaxis(dta, 1, 0),
            jnp.moveaxis(b_mat, 1, 0),
            jnp.moveaxis(c_mat, 1, 0),
        ),
    )
    return jnp.moveaxis(ys, 0, 1), final


def _causal_conv(u, w, b, conv_state):
    """Depthwise causal conv. u: (B,S,C); w: (K,C); returns (y, new_state)."""
    k = w.shape[0]
    bsz, s, c = u.shape
    if conv_state is None:
        ext = jnp.pad(u, ((0, 0), (k - 1, 0), (0, 0)))
    else:
        ext = jnp.concatenate([conv_state.astype(u.dtype), u], axis=1)
    y = sum(
        ext[:, i : i + s, :] * w[i][None, None, :] for i in range(k)
    ) + b[None, None, :]
    new_state = ext[:, -(k - 1) :, :] if k > 1 else None
    return y, new_state


def ssm_apply(
    params: Dict,
    cfg: ArchConfig,
    x: jnp.ndarray,
    cache: Optional[Dict] = None,
) -> Tuple[jnp.ndarray, Optional[Dict]]:
    """Mamba2 block. x: (B, S, d_model) -> (y, updated cache or None).

    cache = {"conv": (B, K-1, C), "ssm": (B, H, P, N)} for decode/prefill.
    """
    s_cfg = cfg.ssm
    d_inner, h, _ = _dims(cfg)
    g, n, p = s_cfg.n_groups, s_cfg.d_state, s_cfg.head_dim
    bsz, seq, _ = x.shape

    z = jnp.einsum("bsd,de->bse", x, params["wz"])
    xc = jnp.einsum("bsd,de->bse", x, params["wx"])
    bc_raw = jnp.einsum("bsd,de->bse", x, params["wbc"])
    dt_raw = jnp.einsum("bsd,de->bse", x, params["wdt"])

    conv_x_state = cache["conv_x"] if cache is not None else None
    conv_bc_state = cache["conv_bc"] if cache is not None else None
    xs, new_conv_x = _causal_conv(
        xc, params["conv_x_w"], params["conv_x_b"], conv_x_state
    )
    bc, new_conv_bc = _causal_conv(
        bc_raw, params["conv_bc_w"], params["conv_bc_b"], conv_bc_state
    )
    xs = jax.nn.silu(xs)
    bm, cm = jnp.split(jax.nn.silu(bc), [g * n], axis=-1)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])  # (B,S,H)
    a = -jnp.exp(params["a_log"])  # (H,)
    dta = dt * a  # (B,S,H)

    xh = xs.reshape(bsz, seq, h, p)
    xdt = xh * dt[..., None].astype(xh.dtype)
    # broadcast groups to heads
    rep = h // g
    bmh = jnp.repeat(bm.reshape(bsz, seq, g, n), rep, axis=2)
    cmh = jnp.repeat(cm.reshape(bsz, seq, g, n), rep, axis=2)

    state0 = cache["ssm"] if cache is not None else None
    if seq == 1 and cache is not None:
        # O(1) decode update
        st = state0 * jnp.exp(dta[:, 0])[..., None, None].astype(state0.dtype)
        st = st + jnp.einsum("bhp,bhn->bhpn", xdt[:, 0], bmh[:, 0])
        y = jnp.einsum("bhpn,bhn->bhp", st, cmh[:, 0])[:, None]
        final = st
    else:
        # keep decays in f32 inside the scan; cast at the consumption points
        y, final = _ssd_chunked(xdt, dta, bmh, cmh, s_cfg.chunk, state0)

    y = y + xh * params["d_skip"][None, None, :, None].astype(xh.dtype)
    y = y.reshape(bsz, seq, d_inner)
    y = rmsnorm(params["norm_w"], y * jax.nn.silu(z))
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"])

    new_cache = None
    if cache is not None:
        new_cache = {
            "conv_x": new_conv_x.astype(cache["conv_x"].dtype),
            "conv_bc": new_conv_bc.astype(cache["conv_bc"].dtype),
            "ssm": final,
        }
    return out, new_cache
