"""Shared structured-error vocabulary of the serving stack.

This module is a dependency **leaf**: it imports nothing from the rest of
:mod:`repro.service`, so every layer -- the wire codec, the store, the
per-artifact servers, the gateway, and the resilience machinery -- can
name the same error base class and the same code -> HTTP status registry
without import cycles (the store cannot import :mod:`.wire`, which
transitively imports the store; both can import this).

Two things live here:

* :data:`ERROR_HTTP_STATUS` -- THE code -> HTTP status registry. The
  gateway's exception classes and HTTP handler answer with these
  statuses, and the batched client-side decoder re-derives per-element
  statuses from it (a ``/v1/query_many`` element arrives under the
  envelope's own HTTP 200, but its ``RemoteError`` must classify exactly
  like its single-query twin). One table, both directions: adding an
  error code means adding it here. Re-exported as
  ``repro.service.wire.ERROR_HTTP_STATUS`` for clients.
* :class:`GatewayError` -- the base of every structured server-side
  failure. Each subclass pins its wire ``code`` and reads its
  ``http_status`` from the registry, so the two can never disagree;
  ``tests/test_wire_errors.py`` walks the subclass tree and asserts it.

The full error-code table (what each code means, when it is returned,
whether a client should retry) is documented in ``docs/serving.md`` and
``docs/resilience.md``.
"""

from __future__ import annotations

__all__ = ["ERROR_HTTP_STATUS", "GatewayError"]

ERROR_HTTP_STATUS = {
    "bad_request": 400,
    "unsupported_version": 400,
    "wrong_artifact_kind": 400,
    "ambiguous_workload": 400,
    "unknown_artifact": 404,
    "not_found": 404,
    # portfolio routing (docs/portfolio.md): unknown_cell is the route
    # twin of unknown_artifact; portfolio_exhausted means every member
    # design's breaker/read failed -- retryable with backoff.
    "unknown_cell": 404,
    # observability endpoints (docs/observability.md): unknown_route is
    # a /v1/debug/exemplars?route= filter naming a route the gateway
    # does not serve -- a caller typo, not a retryable condition.
    "unknown_route": 404,
    "ambiguous_route": 409,
    "portfolio_exhausted": 503,
    # resilience layer (docs/resilience.md): 429/503 are retryable with
    # backoff (the response carries Retry-After); 504 means the caller's
    # own deadline_ms budget ran out -- retrying with the same budget
    # would just burn it again.
    "rate_limited": 429,
    "shed": 503,
    "circuit_open": 503,
    "build_lock_timeout": 503,
    "deadline_exceeded": 504,
    "internal": 500,
}


class GatewayError(Exception):
    """Base of the serving stack's structured failures; every subclass
    pins the wire error ``code``, and the HTTP status comes from the
    shared :data:`ERROR_HTTP_STATUS` registry (one table serves the
    server side and the batched client-side decoder, so the two can
    never disagree about how a code classifies).

    Subclasses that are *retryable after a delay* additionally carry a
    ``retry_after_s`` attribute; the HTTP handler surfaces it as a
    ``Retry-After`` header and :class:`repro.service.client
    .GatewayClient`'s retry policy honors it."""

    code = "internal"
    http_status = ERROR_HTTP_STATUS["internal"]
