"""Roofline summary over the dry-run artifacts (EXPERIMENTS.md §Roofline
is generated from the same data; this bench prints the headline numbers)."""

from __future__ import annotations

import time

from repro.configs.base import SHAPES
from repro.launch.roofline import analyze_cell, load_cells

from .common import ARTIFACTS, emit

DRYRUN = ARTIFACTS + "/dryrun"


def run() -> None:
    t0 = time.perf_counter()
    rows = []
    for rec in load_cells(DRYRUN, "single"):
        row = analyze_cell(rec, SHAPES)
        if row:
            rows.append(row)
    us = (time.perf_counter() - t0) * 1e6
    if not rows:
        emit("roofline", us, "skipped (run repro.launch.dryrun first)")
        return
    emit("roofline_cells", us, f"{len(rows)} cells analyzed (single-pod)")
    by_dom = {}
    for r in rows:
        by_dom.setdefault(r["dominant"], []).append(r)
    for dom, rs in sorted(by_dom.items()):
        emit(
            f"roofline_{dom}_bound", us,
            f"{len(rs)} cells; median roofline fraction "
            f"{sorted(x['roofline_fraction'] for x in rs)[len(rs)//2]:.3f}",
        )
    best = max(rows, key=lambda r: r["roofline_fraction"])
    worst = min(rows, key=lambda r: r["roofline_fraction"])
    emit(
        "roofline_best", us,
        f"{best['arch']}/{best['shape']}: {best['roofline_fraction']:.3f} ({best['dominant']}-bound)",
    )
    emit(
        "roofline_worst", us,
        f"{worst['arch']}/{worst['shape']}: {worst['roofline_fraction']:.3f} ({worst['dominant']}-bound)",
    )
