"""JAX sweep engine vs the NumPy reference oracle (repro.core.solver).

The engines must agree cell-by-cell on the eq.-18 inner solves: identical
feasibility, identical optima up to float32 evaluation noise, and -- where
their argmins differ -- only on exact ties (the jax-chosen candidate must
re-evaluate, in the oracle's float64 model, to the oracle's optimum)."""

import numpy as np
import pytest

from repro.core import MAXWELL, MAXWELL_GPU, STENCILS, ProblemSize, codesign
from repro.core import enumerate_hw_space
from repro.core import sweep
from repro.core.solver import LATTICE_2D, LATTICE_3D, TileLattice, solve_cell
from repro.core.timemodel import stencil_time
from repro.core.workload import paper_workload

pytestmark = pytest.mark.skipif(not sweep.HAVE_JAX, reason="jax not installed")

#: float32 evaluation noise bound: disagreements beyond this are real bugs.
RTOL = 1e-5


def small_hw(step=16):
    """Downsampled paper hardware space (~300 points)."""
    return enumerate_hw_space(MAXWELL, max_area=650.0).downsample(step)


def assert_argmin_equivalent(st, size, lattice, hw, t_np, i_np, t_jax, i_jax):
    """Engines may pick different candidates only when both achieve the
    oracle optimum (ties); feasibility must match exactly."""
    assert np.array_equal(i_np < 0, i_jax < 0), "feasibility sets differ"
    feas = i_np >= 0
    assert np.allclose(t_jax[feas], t_np[feas], rtol=RTOL)
    g = lattice.grid()
    for h in np.nonzero(feas & (i_np != i_jax))[0]:
        j = i_jax[h]
        t_alt = float(
            stencil_time(
                st, MAXWELL_GPU, size, hw.n_sm[h], hw.n_v[h], hw.m_sm[h],
                g["t_s1"][j], g["t_s2"][j], g["t_t"][j], g["k"][j], g["t_s3"][j],
            )
        )
        assert t_alt == pytest.approx(t_np[h], rel=RTOL), (
            f"hw {h}: jax candidate {j} is not tied with the oracle optimum"
        )


@pytest.mark.parametrize(
    "name,size,lattice",
    [
        ("jacobi2d", ProblemSize(4096, 4096, 1024), LATTICE_2D),
        ("heat2d", ProblemSize(8192, 8192, 2048), LATTICE_2D),
        ("heat3d", ProblemSize(512, 512, 256, s3=512), LATTICE_3D),
    ],
)
def test_sweep_matches_numpy_oracle(name, size, lattice):
    st = STENCILS[name]
    hw = small_hw()
    t_np, i_np = solve_cell(st, MAXWELL_GPU, size, hw.n_sm, hw.n_v, hw.m_sm, lattice)
    t_jax, i_jax = sweep.sweep_cell(
        st, MAXWELL_GPU, size, hw.n_sm, hw.n_v, hw.m_sm, lattice
    )
    assert np.isfinite(t_np).any()  # the comparison must not be vacuous
    assert_argmin_equivalent(st, size, lattice, hw, t_np, i_np, t_jax, i_jax)


def test_sweep_cells_batches_all_sizes_in_one_dispatch():
    """The extra vmap axis: a (P, 4) size batch must reproduce P separate
    sweep_cell calls exactly, for every chunking regime (incl. the scaled
    default and a chunk that does not divide H)."""
    from repro.core.workload import paper_sizes

    st = STENCILS["heat2d"]
    hw = small_hw(step=13)  # not a multiple of any chunk below
    sizes = np.array(
        [(s.s1, s.s2, s.s3, s.t) for s in paper_sizes(st.dims)], np.float64
    )
    refs = [
        sweep.sweep_cell(
            st, MAXWELL_GPU, ProblemSize(s1=r[0], s2=r[1], t=r[3], s3=r[2]),
            hw.n_sm, hw.n_v, hw.m_sm, LATTICE_2D,
        )
        for r in sizes
    ]
    for chunk in (None, 7, 0):
        t, i = sweep.sweep_cells(
            st, MAXWELL_GPU, sizes, hw.n_sm, hw.n_v, hw.m_sm, LATTICE_2D, chunk
        )
        assert t.shape == (len(sizes), len(hw))
        for p, (t_ref, i_ref) in enumerate(refs):
            np.testing.assert_allclose(t[p], t_ref, rtol=0)
            np.testing.assert_array_equal(i[p], i_ref)


def test_codesign_jax_groups_match_oracle_per_cell():
    """The driver's one-dispatch-per-stencil-family path must equal the
    NumPy per-cell oracle on the full multi-size workload."""
    wl = paper_workload(["heat2d", "heat3d"], name="grouped")
    hw = small_hw(step=48)
    res_jax = codesign(wl, hw=hw, engine="jax")
    res_np = codesign(wl, hw=hw, engine="numpy")
    assert np.array_equal(
        np.isfinite(res_jax.cell_time), np.isfinite(res_np.cell_time)
    )
    feas = np.isfinite(res_np.cell_time)
    np.testing.assert_allclose(
        res_jax.cell_time[feas], res_np.cell_time[feas], rtol=RTOL
    )


def test_chunking_is_invisible():
    """lax.map slab size (incl. padding remainders) must not change results."""
    st = STENCILS["jacobi2d"]
    size = ProblemSize(4096, 4096, 1024)
    hw = small_hw(step=11)  # deliberately not a multiple of any chunk
    ref_t, ref_i = sweep.sweep_cell(
        st, MAXWELL_GPU, size, hw.n_sm, hw.n_v, hw.m_sm, LATTICE_2D, chunk=0
    )
    for chunk in (1, 7, 64, 10**9):
        t, i = sweep.sweep_cell(
            st, MAXWELL_GPU, size, hw.n_sm, hw.n_v, hw.m_sm, LATTICE_2D, chunk=chunk
        )
        np.testing.assert_array_equal(i, ref_i)
        np.testing.assert_allclose(t, ref_t, rtol=0)


def test_infeasible_hardware_marked():
    """A scratchpad too small for any tile must yield +inf / -1, same as
    the oracle."""
    st = STENCILS["heat3d"]
    size = ProblemSize(512, 512, 256, s3=512)
    n_sm, n_v, m_sm = np.array([16.0]), np.array([128.0]), np.array([0.001])
    t_jax, i_jax = sweep.sweep_cell(st, MAXWELL_GPU, size, n_sm, n_v, m_sm, LATTICE_3D)
    t_np, i_np = solve_cell(st, MAXWELL_GPU, size, n_sm, n_v, m_sm, LATTICE_3D)
    assert not np.isfinite(t_np[0]) and i_np[0] == -1
    assert not np.isfinite(t_jax[0]) and i_jax[0] == -1


def test_codesign_engine_parity():
    """Full driver stack: both engines produce the same workload-level
    reductions (weighted time, GFLOP/s, best design) on a small space."""
    wl = paper_workload(["jacobi2d", "heat3d"], name="parity")
    hw = small_hw(step=32)
    res_np = codesign(wl, hw=hw, engine="numpy")
    res_jax = codesign(wl, hw=hw, engine="jax")
    np.testing.assert_allclose(res_jax.weighted_time(), res_np.weighted_time(), rtol=RTOL)
    np.testing.assert_allclose(res_jax.gflops(), res_np.gflops(), rtol=RTOL)
    i_np, g_np = res_np.best(max_area=450.0)
    i_jax, g_jax = res_jax.best(max_area=450.0)
    assert g_jax == pytest.approx(g_np, rel=RTOL)


def test_codesign_rejects_unknown_engine():
    wl = paper_workload(["jacobi2d"])
    with pytest.raises(ValueError, match="unknown engine"):
        codesign(wl, hw=small_hw(step=64), engine="fortran")


def test_refine_points_batched():
    """Batched descent: never worse than the lattice optimum, alignment
    constraints intact, and locally exact (no single aligned step helps)."""
    st = STENCILS["heat2d"]
    size = ProblemSize(8192, 8192, 2048)
    hw = small_hw(step=64)
    t0, i0 = sweep.sweep_cell(st, MAXWELL_GPU, size, hw.n_sm, hw.n_v, hw.m_sm, LATTICE_2D)
    feas = np.nonzero(i0 >= 0)[0][:8]
    g = LATTICE_2D.grid()
    sw0 = np.stack([[g[k][i0[h]] for k in sweep.SW_NAMES] for h in feas])
    hw_rows = np.stack([[hw.n_sm[h], hw.n_v[h], hw.m_sm[h]] for h in feas])
    sizes = np.tile((size.s1, size.s2, size.s3, size.t), (len(feas), 1))
    t_ref, sw_ref = sweep.refine_points(st, MAXWELL_GPU, sizes, hw_rows, sw0)
    assert np.all(np.isfinite(t_ref))
    assert np.all(t_ref <= t0[feas] * (1 + 1e-5))
    assert np.all(sw_ref[:, 1] % 32 == 0)  # eq. (13): warp-aligned t_s2
    assert np.all(sw_ref[:, 2] % 2 == 0)  # eq. (15): even t_t
    # local exactness in the float64 oracle model: no aligned step improves
    for p, h in enumerate(feas):
        cur = float(
            stencil_time(
                st, MAXWELL_GPU, size, hw.n_sm[h], hw.n_v[h], hw.m_sm[h],
                *sw_ref[p],
            )
        )
        for d, step in enumerate(sweep.SW_STEPS):
            for delta in (step, -step):
                cand = sw_ref[p].copy()
                cand[d] = max(cand[d] + delta, sweep.SW_MINS[d])
                t_cand = float(
                    stencil_time(
                        st, MAXWELL_GPU, size,
                        hw.n_sm[h], hw.n_v[h], hw.m_sm[h], *cand,
                    )
                )
                assert t_cand >= cur * (1 - 1e-5)


def test_refine_points_zero_rounds_returns_start():
    """max_rounds=0 must return the start points untouched (same contract
    as the oracle refine_point), with their float64 times -- not NaN."""
    st = STENCILS["jacobi2d"]
    size = ProblemSize(4096, 4096, 1024)
    sw0 = np.array([[8.0, 64.0, 16.0, 2.0, 1.0], [4.0, 32.0, 8.0, 1.0, 1.0]])
    hw_rows = np.tile((16.0, 128.0, 96.0), (2, 1))
    sizes = np.tile((size.s1, size.s2, size.s3, size.t), (2, 1))
    t, sw = sweep.refine_points(st, MAXWELL_GPU, sizes, hw_rows, sw0, max_rounds=0)
    np.testing.assert_array_equal(sw, sw0)
    want = [
        float(stencil_time(st, MAXWELL_GPU, size, 16.0, 128.0, 96.0, *row))
        for row in sw0
    ]
    np.testing.assert_allclose(t, want, rtol=1e-12)


def test_sweep_steps_match_oracle_table():
    """The batched descent's step/bound tables are derived from the NumPy
    oracle's _STEPS -- alignment semantics cannot drift apart."""
    from repro.core.solver import _STEPS

    assert sweep.SW_STEPS == tuple(float(_STEPS[k]) for k in sweep.SW_NAMES)
    assert sweep.SW_MINS[0] == 1.0
    assert sweep.SW_MINS[1:] == sweep.SW_STEPS[1:]


def test_result_refine_batches_all_cells():
    """CodesignResult.refine polishes every cell at a reported design point
    and never regresses the lattice optimum."""
    wl = paper_workload(["jacobi2d", "heat3d"], name="refine")
    hw = small_hw(step=32)
    res = codesign(wl, hw=hw, engine="jax")
    i, _ = res.best(max_area=650.0)
    times, tiles = res.refine(i)
    lattice_times = res.cell_time[:, i]
    assert np.all(times <= lattice_times * (1 + 1e-5))
    for ci in range(len(times)):
        if np.isfinite(times[ci]):
            assert set(tiles[ci]) == set(sweep.SW_NAMES)


def test_traceable_time_model_grad_and_vmap():
    """The rewritten time model is a first-class jax citizen: vmap works and
    jit produces the same numbers as the NumPy path."""
    import jax
    import jax.numpy as jnp

    st = STENCILS["jacobi2d"]
    size = ProblemSize(4096, 4096, 1024)

    def f(t_s1):
        return stencil_time(
            st, MAXWELL_GPU, size, 16.0, 128.0, 96.0, t_s1, 64.0, 16.0, 2.0,
            1.0, xp=jnp,
        )

    xs = jnp.arange(1.0, 9.0)
    got = jax.jit(jax.vmap(f))(xs)
    want = stencil_time(
        st, MAXWELL_GPU, size, 16.0, 128.0, 96.0, np.arange(1.0, 9.0), 64.0,
        16.0, 2.0, 1.0,
    )
    np.testing.assert_allclose(np.asarray(got, np.float64), want, rtol=1e-6)
