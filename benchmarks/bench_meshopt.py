"""The TPU codesign bridge (beyond-paper): eq.-18 mesh/software optimization
for three representative cells, with the analytic Pareto of chips vs step
time (the Fig.-3 analogue on the fleet)."""

from __future__ import annotations

import time

from repro.configs.base import SHAPES, get_arch
from repro.core.meshopt import optimize
from repro.models.model import active_params, count_params

from .common import emit, smoke

CELLS = [
    ("llama3-8b", "train_4k"),
    ("deepseek-v3-671b", "train_4k"),
    ("mixtral-8x22b", "decode_32k"),
]


def run() -> None:
    cells = CELLS[:1] if smoke() else CELLS
    for arch, shape_name in cells:
        cfg = get_arch(arch)
        shape = SHAPES[shape_name]
        t0 = time.perf_counter()
        n, na = count_params(cfg), active_params(cfg)
        plans = optimize(cfg, shape, n, na, chips=256, top_k=3)
        us = (time.perf_counter() - t0) * 1e6
        if not plans:
            emit(f"meshopt_{arch}_{shape_name}", us, "no feasible plan at 256 chips")
            continue
        p = plans[0]
        mp = p["plan"]
        emit(
            f"meshopt_{arch}_{shape_name}", us,
            f"best: data={mp['data']} model={mp['model']} mb={mp['microbatches']} "
            f"remat={mp['remat']} fsdp={mp['fsdp']} -> {p['bound_s']*1e3:.1f} ms/step "
            f"({p['dominant']}-bound; {len(plans)} feasible shown)",
        )
