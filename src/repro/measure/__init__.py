"""Empirical measurement + time-model calibration (predict -> measure -> refit).

The paper's argument rests on its analytical execution-time model tracking
real stencil kernels (§IV.B measures per-stencil machine parameters, §V
validates predicted vs. observed times on the GTX-980 / Titan X). This
package closes that loop for the reproduction:

* :mod:`repro.measure.harness`   -- runs the tile-parameterized Pallas
  stencils (:mod:`repro.kernels.pallas_stencils`) over a (stencil, problem
  size, tile) grid with warmup/repeat/median timing discipline and device
  sync, emitting :class:`~repro.measure.harness.MeasurementRecord` rows;
* :mod:`repro.measure.calibrate` -- a JAX gradient fit (log-space
  least squares through the traceable :mod:`repro.core.timemodel`) that
  refits the machine parameters -- per-stencil ``C_iter``, global-memory
  bandwidth, launch overhead -- from measurements and reports per-stencil
  predicted-vs-measured error before/after;
* :mod:`repro.measure.cli`       -- ``python -m repro.measure.cli
  run|fit|build``: persist measurement runs and calibrated hardware as
  content-addressed artifacts (``kind: "measurement"`` /
  ``"calibration"`` manifests in the :class:`repro.service.store
  .ArtifactStore`), then build a *calibrated* sweep artifact the fleet
  gateway routes ``/v1/query`` what-ifs against.

Walkthrough with CLI examples: ``docs/calibration.md``.
"""

from .calibrate import (  # noqa: F401
    CalibrationResult,
    fit_machine_params,
    predicted_times,
    synthetic_records,
)
from .harness import (  # noqa: F401
    MeasurementRecord,
    MeasurementRun,
    default_grid,
    measure_grid,
    measure_one,
)
