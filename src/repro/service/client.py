"""Thin HTTP client for a codesign gateway (stdlib only).

The client is a pure transport shim: it encodes with
:mod:`repro.service.wire`, POSTs, and decodes -- so a
:class:`~repro.service.query.QueryResponse` obtained here is the same
object (field for field, and on the wire byte for byte) the in-process
:class:`~repro.service.server.CodesignServer` would have returned.

    from repro.service import GatewayClient, QueryRequest

    c = GatewayClient("http://127.0.0.1:8932")
    c.artifacts()                                   # routing index rows
    c.query(QueryRequest(freqs={"heat2d": 1.0}),    # routed by selector
            route={"gpu": "titanx"})
    c.query_many([(QueryRequest(freqs={"heat2d": 1.0}), None, {"gpu": "titanx"}),
                  (QueryRequest(freqs={"jacobi2d": 1.0}), None, {"gpu": "gtx980"})])

Transport: one persistent ``http.client.HTTPConnection`` per client,
reused across requests (the gateway speaks HTTP/1.1 keep-alive). The
previous ``urllib`` implementation opened a fresh TCP connection per
request -- connection setup was most of the measured ~7-10x wire tax
(ROADMAP; before/after QPS lands in ``BENCH_sweep.json`` via
``benchmarks/bench_service.py``). A request that fails on a *reused*
connection (the server closed its keep-alive side) is retried once on a
fresh connection; a fresh-connection failure propagates. ``keepalive=
False`` restores the connection-per-request behavior for A/B measurement.

Structured gateway failures raise :class:`repro.service.wire.RemoteError`
with the server's error ``code`` (``unknown_artifact``, ``bad_request``,
``ambiguous_route``, ``internal``); transport-level failures surface as
``urllib.error.URLError`` (the exception type callers already handle).
The client is thread-compatible (an internal lock serializes requests);
use one client per thread for parallelism.

**Retries** (``docs/resilience.md``): by default the client retries
*idempotent* failures -- HTTP 429/503 (the gateway's ``rate_limited`` /
``shed`` / ``circuit_open`` / ``build_lock_timeout`` answers, honoring
``Retry-After``) and connection resets (the request provably never
produced a response) -- under a bounded exponential-backoff-with-jitter
:class:`~repro.service.resilience.RetryPolicy`. Timeouts are **never**
retried: a timed-out request may still be executing server-side, and
re-sending would double both the wait and the server's work. Pass
``retry=None`` to disable, or your own policy to tune; ``sleep`` and
``rng`` are injectable so tests assert the backoff schedule without
sleeping.
"""

from __future__ import annotations

import http.client
import json
import random
import threading
import time
import urllib.error
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple, Union
from urllib.parse import urlsplit

from . import wire
from .portfolio import RouteRequest, RouteResponse
from .query import QueryRequest, QueryResponse
from .resilience import RetryPolicy
from repro.obs.trace import TRACE_HEADER

__all__ = ["GatewayClient"]

#: HTTP statuses the retry policy may re-send: the gateway only answers
#: these for requests it REFUSED to start (rate_limited / shed /
#: circuit_open / build_lock_timeout), so a retry can never double work.
_RETRYABLE_STATUSES = frozenset({429, 503})


def _retryable_exception(exc: BaseException) -> bool:
    """True for transport failures where the request provably never got a
    response: connection reset / aborted / broken pipe (including
    ``http.client.RemoteDisconnected``, a ``ConnectionResetError``
    subclass). Timeouts are excluded by construction -- ``TimeoutError``
    is not in this family -- as is ``ConnectionRefusedError`` (the server
    is down; backoff won't bring it up and callers should fail fast)."""
    return isinstance(
        exc, (ConnectionResetError, ConnectionAbortedError, BrokenPipeError)
    ) and not isinstance(exc, TimeoutError)


class GatewayClient:
    """Client for one gateway base URL (e.g. ``http://host:port``).

    Parameters
    ----------
    retry:
        The :class:`~repro.service.resilience.RetryPolicy` for idempotent
        failures (the default sentinel builds the stock policy: 3 retries,
        50ms base, 2s cap, full jitter); ``None`` disables retries.
    sleep / rng:
        Injection points for the backoff sleep and jitter randomness
        (tests pass a recording fake and a seeded ``random.Random``).
    """

    def __init__(
        self,
        base_url: str,
        timeout: float = 30.0,
        keepalive: bool = True,
        retry: Union[RetryPolicy, None, str] = "default",
        sleep=time.sleep,
        rng: Optional[random.Random] = None,
    ):
        parts = urlsplit(base_url if "//" in base_url else f"http://{base_url}")
        if parts.scheme not in ("http", "https"):
            raise ValueError(f"unsupported URL scheme {parts.scheme!r} in {base_url!r}")
        if not parts.hostname:
            raise ValueError(f"no host in gateway URL {base_url!r}")
        self.base_url = base_url.rstrip("/")
        self.timeout = float(timeout)
        self.keepalive = bool(keepalive)
        self._host = parts.hostname
        self._port = parts.port  # None -> scheme default
        self._path_prefix = parts.path.rstrip("/")
        self._conn_cls = (
            http.client.HTTPSConnection if parts.scheme == "https"
            else http.client.HTTPConnection
        )
        self._conn: Optional[http.client.HTTPConnection] = None
        self._mu = threading.Lock()
        self._last_status = 0  # HTTP status of the most recent call
        self._last_trace_id = ""  # X-Repro-Trace echoed by the most recent call
        if retry == "default":
            retry = RetryPolicy()
        self.retry: Optional[RetryPolicy] = retry
        self._sleep = sleep
        self._rng = rng if rng is not None else random.Random()
        self.stats: Dict[str, int] = {"retries": 0}

    # ---- transport --------------------------------------------------------
    def _drop(self) -> None:
        if self._conn is not None:
            try:
                self._conn.close()
            except OSError:
                pass
            self._conn = None

    def close(self) -> None:
        """Drop the persistent connection (idempotent)."""
        with self._mu:
            self._drop()

    def __enter__(self) -> "GatewayClient":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def _request(
        self,
        path: str,
        body: Optional[bytes] = None,
        headers: Optional[Mapping[str, str]] = None,
    ) -> Tuple[bytes, int]:
        """One request; returns ``(raw body, HTTP status)``. HTTP error
        statuses still carry wire payloads -- the body is returned (not
        raised) so the decoder can surface the server's structured code.
        The status is *returned* rather than read back from shared state:
        two threads sharing a client must never pair one request's body
        with the other's status.

        This is also where the retry policy lives: idempotent failures
        (connection reset before any response; 429/503 refusals, honoring
        the ``Retry-After`` hint) re-send under bounded backoff. Every
        request is re-sent from its original ``body`` bytes, so a retried
        answer is byte-identical to a first-try answer."""
        method = "POST" if body is not None else "GET"
        hdrs = {"Content-Type": "application/json", **(headers or {})}
        policy = self.retry
        with self._mu:
            tries = 0  # policy retries consumed (stale-socket retry is free)
            while True:
                try:
                    data, status, retry_after = self._exchange(
                        method, path, body, hdrs
                    )
                except urllib.error.URLError as e:
                    reason = e.reason if isinstance(
                        getattr(e, "reason", None), BaseException
                    ) else e
                    if (
                        policy is not None
                        and tries < policy.max_retries
                        and _retryable_exception(reason)
                    ):
                        tries += 1
                        self.stats["retries"] += 1
                        self._sleep(policy.delay(tries, self._rng))
                        continue
                    raise
                if (
                    policy is not None
                    and status in _RETRYABLE_STATUSES
                    and tries < policy.max_retries
                ):
                    tries += 1
                    self.stats["retries"] += 1
                    self._sleep(
                        policy.delay(tries, self._rng, retry_after_s=retry_after)
                    )
                    continue
                return data, status

    def _exchange(
        self,
        method: str,
        path: str,
        body: Optional[bytes],
        hdrs: Dict[str, str],
    ) -> Tuple[bytes, int, Optional[float]]:
        """One HTTP exchange (with the free stale-keep-alive retry);
        returns ``(body, status, Retry-After seconds or None)``. Caller
        holds ``_mu``."""
        for attempt in (0, 1):
            reused = self._conn is not None
            conn = self._conn or self._conn_cls(
                self._host, self._port, timeout=self.timeout
            )
            self._conn = None
            try:
                conn.request(method, self._path_prefix + path, body, hdrs)
                resp = conn.getresponse()
                data = resp.read()
                self._last_status = resp.status
                self._last_trace_id = resp.getheader(TRACE_HEADER, "")
            except (http.client.HTTPException, OSError) as e:
                try:
                    conn.close()
                except OSError:
                    pass
                # this retry covers ONLY a stale keep-alive socket (server
                # closed its side: reset/EOF before a response). A
                # timeout is not staleness -- re-sending would double
                # both the effective timeout and the server's work.
                if reused and attempt == 0 and not isinstance(e, TimeoutError):
                    continue
                raise urllib.error.URLError(e) from e
            if self.keepalive and not resp.will_close:
                self._conn = conn
            else:
                conn.close()
            ra_raw = resp.getheader("Retry-After")
            try:
                retry_after = float(ra_raw) if ra_raw else None
            except ValueError:
                retry_after = None  # HTTP-date form: fall back to backoff
            return data, resp.status, retry_after
        raise AssertionError("unreachable")  # pragma: no cover

    def _http(self, path: str, body: Optional[bytes] = None) -> bytes:
        """Body-only transport entry point (kept for callers that pair it
        with :attr:`_last_status` single-threadedly, e.g. smoke scripts)."""
        return self._request(path, body)[0]

    def query_bytes(
        self,
        request: QueryRequest,
        artifact: Optional[str] = None,
        route: Optional[Mapping[str, Any]] = None,
    ) -> bytes:
        """The raw response body for one query -- the byte-identity tests'
        entry point (no decode/re-encode in between)."""
        return self._http(
            "/v1/query", wire.encode_request(request, artifact=artifact, route=route)
        )

    def query_many_bytes(
        self,
        queries: Sequence[
            Tuple[QueryRequest, Optional[str], Optional[Mapping[str, Any]]]
        ],
    ) -> bytes:
        """Raw ``/v1/query_many`` body (byte-identity entry point)."""
        return self._http("/v1/query_many", wire.encode_request_many(queries))

    def route_bytes(
        self,
        request: RouteRequest,
        artifact: Optional[str] = None,
        route: Optional[Mapping[str, Any]] = None,
    ) -> bytes:
        """Raw ``/v1/route`` body (the portfolio byte-identity tests'
        entry point)."""
        return self._http(
            "/v1/route",
            wire.encode_route_request(request, artifact=artifact, route=route),
        )

    # ---- API --------------------------------------------------------------
    def query(
        self,
        request: QueryRequest,
        artifact: Optional[str] = None,
        route: Optional[Mapping[str, Any]] = None,
        deadline_ms: Optional[float] = None,
    ) -> QueryResponse:
        """Answer one request over HTTP; raises
        :class:`~repro.service.wire.RemoteError` on structured failures.
        ``deadline_ms`` rides the request envelope: the gateway abandons
        the request (HTTP 504, code ``deadline_exceeded``) once the budget
        is spent. The budget is per attempt, not across retries."""
        body, status = self._request(
            "/v1/query",
            wire.encode_request(
                request, artifact=artifact, route=route, deadline_ms=deadline_ms
            ),
        )
        return wire.decode_response(body, http_status=status)

    def route(
        self,
        request: Union[RouteRequest, str],
        artifact: Optional[str] = None,
        route: Optional[Mapping[str, Any]] = None,
        deadline_ms: Optional[float] = None,
    ) -> RouteResponse:
        """Route one workload cell through a portfolio artifact
        (``POST /v1/route``). ``request`` may be a bare cell label for
        convenience; ``artifact``/``route`` resolve the portfolio the
        same way :meth:`query` resolves a sweep (but among ``kind:
        "portfolio"`` manifests)."""
        if isinstance(request, str):
            request = RouteRequest(cell=request)
        body, status = self._request(
            "/v1/route",
            wire.encode_route_request(
                request, artifact=artifact, route=route, deadline_ms=deadline_ms
            ),
        )
        return wire.decode_route_response(body, http_status=status)

    def query_traced(
        self,
        request: QueryRequest,
        artifact: Optional[str] = None,
        route: Optional[Mapping[str, Any]] = None,
        trace_id: Optional[str] = None,
    ) -> Tuple[QueryResponse, Optional[Dict[str, Any]]]:
        """Like :meth:`query` but with ``"trace": true`` in the envelope:
        returns ``(response, span_tree)`` where the span tree is the
        gateway's ``gateway.request`` root (``trace_id``, ``dur_us``,
        nested ``children``) for THIS request. Pass ``trace_id`` to
        correlate with client-side logs; otherwise the gateway mints one
        (echoed in the ``X-Repro-Trace`` response header, readable via
        :attr:`last_trace_id`). Tracing adds a ``"trace"`` field to the
        response envelope, so the bytes intentionally differ from an
        untraced answer; the decoded :class:`QueryResponse` is identical."""
        hdrs = {TRACE_HEADER: trace_id} if trace_id else None
        body, status = self._request(
            "/v1/query",
            wire.encode_request(request, artifact=artifact, route=route, trace=True),
            headers=hdrs,
        )
        return wire.decode_response_traced(body, http_status=status)

    @property
    def last_trace_id(self) -> str:
        """``X-Repro-Trace`` from the most recent response (empty before
        the first call). Single-threaded pairing only, like
        ``_last_status``."""
        return self._last_trace_id

    def metrics(self, fmt: str = "json") -> Union[Dict[str, Any], str]:
        """Scrape ``GET /v1/metrics``: ``fmt="json"`` returns the decoded
        snapshot dict, ``fmt="prometheus"`` the text exposition as str."""
        if fmt == "json":
            return self._json("/v1/metrics?format=json")
        raw, status = self._request(f"/v1/metrics?format={fmt}")
        if not 200 <= status < 300:
            raise wire.RemoteError(
                "bad_request", raw[:200].decode("utf-8", "replace"), status
            )
        return raw.decode("utf-8")

    def slo(self, fmt: str = "json") -> Union[Dict[str, Any], str]:
        """Scrape ``GET /v1/slo``: ``fmt="json"`` returns the decoded
        burn-rate report (see :class:`repro.obs.slo.SLOTracker.report`),
        ``fmt="prometheus"`` the gauge-only text exposition as str."""
        if fmt == "json":
            raw, status = self._request("/v1/slo?format=json")
            return wire.decode_slo_response(raw, http_status=status)
        raw, status = self._request(f"/v1/slo?format={fmt}")
        if not 200 <= status < 300:
            raise wire.RemoteError(
                "bad_request", raw[:200].decode("utf-8", "replace"), status
            )
        return raw.decode("utf-8")

    def exemplars(self, route: Optional[str] = None) -> Dict[str, Any]:
        """Fetch the tail-exemplar rings (``GET /v1/debug/exemplars``):
        slowest-N span trees plus the recent-error ring, per route. Pass
        ``route`` to filter to one route's rings (an unknown route raises
        :class:`~repro.service.wire.RemoteError` code ``unknown_route``)."""
        path = "/v1/debug/exemplars"
        if route is not None:
            from urllib.parse import quote

            path += f"?route={quote(route, safe='')}"
        raw, status = self._request(path)
        return wire.decode_exemplars_response(raw, http_status=status)

    def query_many(
        self,
        queries: Sequence[
            Union[
                QueryRequest,
                Tuple[QueryRequest, Optional[str], Optional[Mapping[str, Any]]],
            ]
        ],
        artifact: Optional[str] = None,
        route: Optional[Mapping[str, Any]] = None,
        deadline_ms: Optional[float] = None,
    ) -> List[Union[QueryResponse, wire.RemoteError]]:
        """Answer N queries in one HTTP round trip (``POST
        /v1/query_many``). Each element is a bare :class:`QueryRequest`
        (routed by the shared ``artifact``/``route`` arguments) or an
        explicit ``(request, artifact, route)`` triple. Per-query failures
        come back as :class:`~repro.service.wire.RemoteError` *values* in
        the result list -- only envelope-level failures raise. Batches
        larger than the wire cap (:data:`wire.MAX_BATCH`) are split
        transparently into consecutive round trips, results concatenated
        in input order; an envelope-level failure of a *later* chunk is
        reported as that chunk's per-query errors rather than raised, so
        earlier chunks' completed answers are never discarded (only a
        first-chunk envelope failure raises, matching the single-request
        contract)."""
        triples = [
            q if isinstance(q, tuple) else (q, artifact, route) for q in queries
        ]
        out: List[Union[QueryResponse, wire.RemoteError]] = []
        for lo in range(0, len(triples), wire.MAX_BATCH):
            chunk = triples[lo : lo + wire.MAX_BATCH]
            try:
                body, status = self._request(
                    "/v1/query_many",
                    wire.encode_request_many(chunk, deadline_ms=deadline_ms),
                )
                out.extend(wire.decode_response_many(body, http_status=status))
            except wire.RemoteError as e:
                if lo == 0:
                    raise
                out.extend([e] * len(chunk))
            except (wire.WireError, urllib.error.URLError) as e:
                # transport died / undecodable envelope mid-way: the same
                # rule -- answered chunks are never discarded
                if lo == 0:
                    raise
                err = wire.RemoteError("transport_error", str(e), 0)
                out.extend([err] * len(chunk))
        return out

    def _json(self, path: str, body: Optional[bytes] = None) -> Dict[str, Any]:
        """GET/POST a JSON endpoint; a non-2xx answer raises the server's
        structured error as :class:`RemoteError` instead of a KeyError on
        the missing success fields."""
        raw, status = self._request(path, body)
        if not 200 <= status < 300:
            try:
                err = json.loads(raw).get("error") or {}
            except ValueError:
                err = {}
            raise wire.RemoteError(
                str(err.get("code", "unknown")),
                str(err.get("message", raw[:200].decode("utf-8", "replace"))),
                status,
            )
        return json.loads(raw)

    def artifacts(self) -> List[Dict[str, Any]]:
        """Routing rows for every artifact the gateway serves."""
        return self._json("/v1/artifacts")["artifacts"]

    def health(self) -> Dict[str, Any]:
        return self._json("/v1/healthz")

    def refresh(self) -> int:
        """Ask the gateway to re-scan its store roots; returns the indexed
        artifact count."""
        return self._json("/v1/refresh", b"")["artifacts"]
