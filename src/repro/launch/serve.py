"""Serving launcher: batched prefill + greedy decode.

``python -m repro.launch.serve --arch mixtral-8x22b --reduced --requests 8``
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_arch
from repro.models import init_model
from repro.serve import generate


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=4, help="batch of prompts")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = init_model(cfg, jax.random.PRNGKey(args.seed))
    batch = {
        "tokens": jax.random.randint(
            jax.random.PRNGKey(1), (args.requests, args.prompt_len), 0, cfg.vocab
        )
    }
    if cfg.frontend or cfg.enc_dec:
        batch["frontend"] = (
            jax.random.normal(
                jax.random.PRNGKey(2),
                (args.requests, cfg.n_frontend_tokens, cfg.d_model),
            )
            * 0.05
        )
    t0 = time.perf_counter()
    out = generate(params, cfg, batch, steps=args.gen_len)
    out.block_until_ready()
    dt = time.perf_counter() - t0
    total = args.requests * args.gen_len
    print(f"generated {total} tokens in {dt:.2f}s ({total/dt:.1f} tok/s)")
    print(jnp.asarray(out)[:2])


if __name__ == "__main__":
    main()
