"""Checkpointing: atomic, async, mesh-shape-agnostic restore."""

from .checkpoint import (  # noqa: F401
    AsyncCheckpointer,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)
