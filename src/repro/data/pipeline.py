"""Deterministic synthetic LM data pipeline.

Real-cluster posture with laptop-scale contents: batches are produced
per-host (each host materializes only its slice, as a multi-host input
pipeline must), deterministically from (seed, step) -- restart/elastic
resume re-produce identical batches with no data-loader state to
checkpoint. Tokens follow a mixed-unigram + copy-structure distribution so
the LM loss has learnable signal (pure uniform noise would have nothing to
fit); modality frontends are stubbed with deterministic pseudo-embeddings.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding

from ..configs.base import ArchConfig, ShapeSpec
from ..sharding.partition import batch_specs

__all__ = ["DataConfig", "make_batch", "SyntheticPipeline"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    copy_period: int = 16  # tokens repeat with this period (learnable)
    noise: float = 0.15  # fraction of positions replaced by noise


def _host_tokens(cfg: ArchConfig, shape: ShapeSpec, dcfg: DataConfig, step: int, batch: int, seq: int) -> np.ndarray:
    """(batch, seq+1) int32, deterministic in (seed, step)."""
    rng = np.random.default_rng(np.uint64(dcfg.seed * 1_000_003 + step))
    base = rng.integers(0, cfg.vocab, size=(batch, dcfg.copy_period), dtype=np.int64)
    reps = -(-(seq + 1) // dcfg.copy_period)
    toks = np.tile(base, (1, reps))[:, : seq + 1]
    noise_mask = rng.random((batch, seq + 1)) < dcfg.noise
    noise = rng.integers(0, cfg.vocab, size=(batch, seq + 1), dtype=np.int64)
    toks = np.where(noise_mask, noise, toks)
    return toks.astype(np.int32)


def make_batch(
    cfg: ArchConfig,
    shape: ShapeSpec,
    dcfg: DataConfig,
    step: int,
    mesh: Optional[Mesh] = None,
    batch_override: Optional[int] = None,
    seq_override: Optional[int] = None,
) -> Dict[str, jax.Array]:
    """One global training batch: tokens, labels (+frontend embeddings)."""
    b = batch_override or shape.global_batch
    s = seq_override or shape.seq_len
    toks = _host_tokens(cfg, shape, dcfg, step, b, s)
    batch: Dict[str, np.ndarray] = {
        "tokens": toks[:, :-1],
        "labels": toks[:, 1:].copy(),
    }
    if cfg.frontend == "vision":
        rng = np.random.default_rng(np.uint64(dcfg.seed * 7 + step))
        nf = cfg.n_frontend_tokens
        batch["frontend"] = (
            rng.standard_normal((b, nf, cfg.d_model)).astype(np.float32) * 0.02
        )
        # the model prepends Nf vision slots; logits at slot i predict
        # sequence position i+1-Nf, so pad labels on the left with ignore
        batch["labels"] = np.concatenate(
            [np.full((b, nf), -1, np.int32), batch["labels"]], axis=1
        )
    elif cfg.enc_dec:
        rng = np.random.default_rng(np.uint64(dcfg.seed * 13 + step))
        batch["frontend"] = (
            rng.standard_normal((b, cfg.n_frontend_tokens, cfg.d_model)).astype(
                np.float32
            )
            * 0.02
        )
    arrs = {k: jnp.asarray(v) for k, v in batch.items()}
    if mesh is not None:
        specs = batch_specs(cfg, mesh)
        arrs = {
            k: jax.device_put(v, NamedSharding(mesh, specs.get(k, specs["tokens"])))
            for k, v in arrs.items()
        }
    return arrs


class SyntheticPipeline:
    """Iterator facade used by the trainer; stateless w.r.t. restarts."""

    def __init__(
        self,
        cfg: ArchConfig,
        shape: ShapeSpec,
        dcfg: DataConfig = DataConfig(),
        mesh: Optional[Mesh] = None,
        start_step: int = 0,
        batch_override: Optional[int] = None,
        seq_override: Optional[int] = None,
    ):
        self.cfg, self.shape, self.dcfg, self.mesh = cfg, shape, dcfg, mesh
        self.step = start_step
        self.batch_override = batch_override
        self.seq_override = seq_override

    def __iter__(self) -> Iterator[Dict[str, jax.Array]]:
        return self

    def __next__(self) -> Dict[str, jax.Array]:
        b = make_batch(
            self.cfg, self.shape, self.dcfg, self.step, self.mesh,
            self.batch_override, self.seq_override,
        )
        self.step += 1
        return b
