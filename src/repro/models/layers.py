"""Shared neural-net layers (pure-JAX, parameter pytrees; no flax).

Conventions:
* parameters are nested dicts of ``jnp.ndarray``; repeated layers are
  *stacked* along a leading axis and consumed with ``jax.lax.scan`` so the
  traced HLO contains each distinct layer body exactly once (compile time
  at 512 devices depends on it);
* matmuls are ``jnp.einsum`` with stable letter conventions so the sharding
  rules in ``repro.sharding.partition`` can reason about dimension roles;
* activations/softmax accumulate in f32, parameters/activations are stored
  in the config dtype (bf16 for the full-scale configs).
"""

from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "dense_init",
    "embed_init",
    "rmsnorm_init",
    "rmsnorm",
    "mlp_init",
    "mlp",
    "rope_freqs",
    "apply_rope",
    "mrope_rotate",
    "sinusoidal_positions",
]


def dense_init(key, shape, dtype, scale: float | None = None):
    """Truncated-normal fan-in init (matmul weights)."""
    fan_in = shape[0] if len(shape) >= 2 else 1
    scale = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab, d_model, dtype):
    return (jax.random.truncated_normal(key, -2.0, 2.0, (vocab, d_model), jnp.float32) * 0.02).astype(
        dtype
    )


def rmsnorm_init(d_model, dtype, offset: float = 0.0):
    # stored weight; effective scale is (offset + w) so gemma stores zeros
    return jnp.ones((d_model,), dtype) if offset == 0.0 else jnp.zeros((d_model,), dtype)


def rmsnorm(w, x, offset: float = 0.0, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    rms = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return ((offset + w.astype(jnp.float32)) * xf * rms).astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs: silu-gated (llama), geglu (gemma), squared-relu (nemotron/minitron)
# ---------------------------------------------------------------------------
def mlp_init(key, d_model, d_ff, act: str, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"down": dense_init(k2, (d_ff, d_model), dtype)}
    if act in ("silu", "geglu"):
        p["gate"] = dense_init(k1, (d_model, d_ff), dtype)
        p["up"] = dense_init(k3, (d_model, d_ff), dtype)
    else:  # relu2: single up-projection
        p["up"] = dense_init(k1, (d_model, d_ff), dtype)
    return p


def mlp(params, x, act: str):
    up = jnp.einsum("...d,df->...f", x, params["up"])
    if act == "silu":
        gate = jnp.einsum("...d,df->...f", x, params["gate"])
        h = jax.nn.silu(gate) * up
    elif act == "geglu":
        gate = jnp.einsum("...d,df->...f", x, params["gate"])
        h = jax.nn.gelu(gate, approximate=True) * up
    elif act == "relu2":
        h = jnp.square(jax.nn.relu(up))
    else:
        raise ValueError(f"unknown act {act}")
    return jnp.einsum("...f,fd->...d", h, params["down"])


# ---------------------------------------------------------------------------
# Rotary embeddings (standard + M-RoPE) and absolute positions
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    """Inverse frequencies for the pairwise rotation, shape (head_dim//2,)."""
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def _rotate(x, angles):
    """Rotate pairs. x: (..., S, H, D); angles: (..., S, 1|H, D/2)."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(
        x.dtype
    )


def apply_rope(x, positions, theta: float):
    """Standard RoPE. x: (B, S, H, D); positions: (B, S) int."""
    freqs = rope_freqs(x.shape[-1], theta)  # (D/2,)
    angles = positions[..., None, None].astype(jnp.float32) * freqs  # (B,S,1,D/2)
    return _rotate(x, angles)


def mrope_rotate(x, positions3, sections: Tuple[int, ...], theta: float):
    """Qwen2-VL M-RoPE: positions3 (B, 3, S) = (t, h, w) ids; the D/2 rotary
    pairs are split into ``sections`` (sum = D/2), each driven by one id."""
    d_half = x.shape[-1] // 2
    assert sum(sections) == d_half, (sections, d_half)
    freqs = rope_freqs(x.shape[-1], theta)  # (D/2,)
    # select which of the 3 position streams drives each pair
    sel = jnp.repeat(
        jnp.arange(3), jnp.array(sections), total_repeat_length=d_half
    )  # (D/2,)
    pos = jnp.take_along_axis(
        positions3.astype(jnp.float32),  # (B, 3, S)
        jnp.broadcast_to(sel[None, :, None], (x.shape[0], d_half, x.shape[1])),
        axis=1,
    )  # (B, D/2, S)
    angles = jnp.moveaxis(pos, 1, -1)[..., None, :] * freqs  # (B,S,1,D/2)
    return _rotate(x, angles)


def sinusoidal_positions(n: int, d_model: int) -> jnp.ndarray:
    """Whisper-style sinusoidal embedding table (n, d_model), f32."""
    half = d_model // 2
    scale = jnp.exp(-jnp.arange(half, dtype=jnp.float32) * math.log(10000.0) / (half - 1))
    args = jnp.arange(n, dtype=jnp.float32)[:, None] * scale[None, :]
    return jnp.concatenate([jnp.sin(args), jnp.cos(args)], axis=-1)
