"""Process-level health gauges: is the *gateway itself* healthy?

Registered into the default registry so ``GET /v1/metrics`` can answer
"how big is this process" without anyone shelling into the box:

* ``repro_process_rss_bytes`` -- resident set size, read from
  ``/proc/self/statm`` (resident pages x page size). On non-Linux hosts
  the sampler falls back to ``resource.getrusage`` peak RSS, and on
  platforms with neither it degrades to not updating the gauge at all --
  never raising from a metrics scrape.
* ``repro_gateway_connections`` -- currently open gateway HTTP
  connections (inc/dec'd by the handler lifecycle).
* ``repro_gateway_pool_servers`` -- resident artifact servers in the
  gateway's LRU pool.

RSS is sampled lazily at scrape time (:func:`sample_process`) rather
than on a timer: metrics that nobody reads cost nothing.
"""

from __future__ import annotations

import os
from typing import Optional

from .metrics import get_registry

__all__ = [
    "M_CONNECTIONS",
    "M_POOL_SERVERS",
    "M_RSS",
    "rss_bytes",
    "sample_process",
]

M_RSS = get_registry().gauge(
    "repro_process_rss_bytes",
    "resident set size of the serving process (sampled at scrape)",
)
M_CONNECTIONS = get_registry().gauge(
    "repro_gateway_connections",
    "currently open gateway HTTP connections",
)
M_POOL_SERVERS = get_registry().gauge(
    "repro_gateway_pool_servers",
    "resident artifact servers in the gateway LRU pool",
)

_PAGE_SIZE = os.sysconf("SC_PAGE_SIZE") if hasattr(os, "sysconf") else 4096


def rss_bytes() -> Optional[int]:
    """Current RSS in bytes, or None when the platform offers no cheap
    way to ask. Linux: /proc/self/statm. Elsewhere: getrusage peak RSS
    (a monotone over-estimate, but an honest upper bound)."""
    try:
        with open("/proc/self/statm", "rb") as f:
            fields = f.read().split()
        return int(fields[1]) * int(_PAGE_SIZE)
    except (OSError, IndexError, ValueError):
        pass
    try:
        import resource

        peak = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
        # ru_maxrss is KiB on Linux, bytes on macOS
        return int(peak) * (1 if peak > 1 << 32 else 1024)
    except Exception:
        return None


def sample_process() -> None:
    """Refresh the lazily-sampled process gauges (called on each
    ``/v1/metrics`` render). Never raises."""
    rss = rss_bytes()
    if rss is not None:
        M_RSS.set(rss)
