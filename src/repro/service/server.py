"""Thread-safe in-process codesign query servers.

Decouples the expensive eq.-18 sweep (producer) from cheap workload
queries (consumers):

* **warm path**: the configured sweep's artifact is on disk -- queries are
  answered by :class:`repro.service.query.QueryEngine` re-reductions and
  NEVER invoke a sweep engine;
* **miss path**: first touch runs the family's sweep once (under a build
  lock, so a thundering herd compiles/solves exactly once) and writes the
  artifact through the store for every later process;
* **microbatching**: concurrent ``query()`` callers rendezvous for a short
  window; the leader stacks every pending frequency vector into one
  ``(B, cells) @ (cells, hw)`` matmul and distributes the rows. Amortizes
  memory traffic over the big matrix exactly like batched inference.

One server serves one configured sweep. There is one server class per cell
family -- :class:`CodesignServer` (stencils) and :class:`LMServer` (LM
op-graph cells) -- sharing the serving machinery of :class:`_BaseServer`;
:func:`server_from_artifact` dispatches a discovered artifact to the right
class by its manifest family. The fleet front-end over *many* stored
sweeps is :class:`repro.service.gateway.Gateway`, which constructs its
pooled servers via that dispatcher (warm-only; the miss path is
unreachable).
"""

from __future__ import annotations

import threading
import time
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.area import LinearAreaModel, MAXWELL
from repro.core.codesign import (
    CodesignResult,
    HardwareSpace,
    codesign,
    enumerate_hw_space,
)
from repro.core.lmcells import (
    LM_GPU_NAME,
    LMCodesignResult,
    LMHardwareSpace,
    enumerate_lm_hw_space,
    lm_codesign,
)
from repro.core.solver import LATTICE_2D, LATTICE_3D, TileLattice
from repro.core.timemodel import GPUSpec, MAXWELL_GPU
from repro.core.workload import Workload, paper_workload

from repro.obs import get_logger
from repro.obs.metrics import SIZE_BUCKETS, get_registry as _obs_registry
from repro.obs.trace import span

from . import faults
from .query import QueryEngine, QueryRequest, QueryResponse
from .resilience import check_deadline, remaining_s
from .store import Artifact, ArtifactStore

__all__ = ["CodesignServer", "LMServer", "server_from_artifact"]

# ---- observability (repro.obs; no-ops under REPRO_OBS_DISABLED=1) --------
_LOG = get_logger("repro.server")
_REG = _obs_registry()
_M_BATCH_SIZE = _REG.histogram(
    "repro_server_batch_size",
    "microbatch flush sizes (requests per leader-stacked matmul)",
    buckets=SIZE_BUCKETS,
)
_M_FOLLOWER_WAIT = _REG.histogram(
    "repro_server_follower_wait_seconds",
    "wall time a follower spends parked on its rendezvous slot "
    "(leader's own window/answer time excluded)",
)
_M_ART_BUILDS = _REG.counter(
    "repro_server_artifact_builds_total",
    "miss-path sweeps run by a server (cold artifact built + persisted)",
)
_M_ART_LOADS = _REG.counter(
    "repro_server_artifact_loads_total",
    "warm artifact loads (stored sweep opened, no engine invoked)",
)
_M_BATCH_POISON = _REG.counter(
    "repro_server_batch_poison_total",
    "microbatch flushes that failed whole and fell back to per-request "
    "solo retries (one poison-pill request degrading its batchmates "
    "from one stacked matmul to N solo answers)",
)


class _Slot:
    __slots__ = ("request", "event", "response", "error")

    def __init__(self, request: QueryRequest):
        self.request = request
        self.event = threading.Event()
        self.response: Optional[QueryResponse] = None
        self.error: Optional[BaseException] = None


class _BaseServer:
    """Family-agnostic serving machinery: artifact lifecycle (get-or-build
    under the cross-process lock) and leader/follower query microbatching.

    Subclasses set ``self.key`` (the content address, known BEFORE any
    sweep -- that is what makes the warm path engine-free) in their
    ``__init__`` after calling :meth:`_init_serving`, and implement
    :meth:`_solve` (run the family's sweep, persist it, return the
    artifact)."""

    def _init_serving(
        self, store: ArtifactStore, batch_window: float, lru_size: int
    ) -> None:
        self.store = store
        self.batch_window = float(batch_window)
        self.lru_size = lru_size
        self._engine: Optional[QueryEngine] = None
        self._build_mu = threading.Lock()
        self._batch_mu = threading.Lock()
        self._pending: List[_Slot] = []
        self._leader_active = False
        self.stats: Dict[str, int] = {
            "queries": 0,
            "batches": 0,
            "max_batch": 0,
            "artifact_builds": 0,
            "artifact_loads": 0,
        }

    def _solve(self) -> Artifact:
        raise NotImplementedError

    # ---- artifact lifecycle ----------------------------------------------
    def ensure_artifact(self) -> QueryEngine:
        """Get-or-build the configured sweep's artifact (thread-safe)."""
        eng = self._engine
        if eng is not None:
            return eng
        with self._build_mu:
            if self._engine is None:
                art = self.store.get(self.key)
                if art is None:
                    # cross-process dedup: a second process racing to the
                    # same key blocks here (bounded by the lock timeout
                    # and any in-flight request deadline), then finds the
                    # winner's artifact on the re-check instead of
                    # re-sweeping (build_lock is reentrant, so store.put
                    # inside _solve can re-acquire it around the staged
                    # write).
                    with self.store.build_lock(self.key):
                        art = self.store.get(self.key)
                        if art is None:
                            # a request whose budget is already spent must
                            # not kick off a minutes-long sweep
                            check_deadline("server.build")
                            with span("artifact.build", key=self.key[:12]):
                                art = self._solve()
                            assert art.key == self.key, (
                                "store key drifted from server key"
                            )
                            self.stats["artifact_builds"] += 1
                            _M_ART_BUILDS.inc()
                        else:
                            self.stats["artifact_loads"] += 1
                            _M_ART_LOADS.inc()
                else:
                    self.stats["artifact_loads"] += 1
                    _M_ART_LOADS.inc()
                self._engine = QueryEngine(art, lru_size=self.lru_size)
            return self._engine

    @property
    def warm(self) -> bool:
        """True when queries can be served without any sweep engine."""
        return self._engine is not None or self.store.has(self.key)

    # ---- queries ----------------------------------------------------------
    def query(self, request: QueryRequest) -> QueryResponse:
        """Answer one request; concurrent callers microbatch automatically."""
        check_deadline("server.query")
        engine = self.ensure_artifact()
        if self.batch_window <= 0:
            with self._batch_mu:
                self.stats["queries"] += 1
                self.stats["batches"] += 1
                self.stats["max_batch"] = max(self.stats["max_batch"], 1)
            _M_BATCH_SIZE.observe(1)
            with span("server.answer", key=self.key[:12], batched=0):
                return engine.query(request)
        slot = _Slot(request)
        with self._batch_mu:
            self._pending.append(slot)
            am_leader = not self._leader_active
            if am_leader:
                self._leader_active = True
        if am_leader:
            try:
                # rendezvous: followers pile in. A leader carrying a
                # deadline never sleeps past its own remaining budget.
                time.sleep(
                    min(self.batch_window,
                        remaining_s(default=self.batch_window))
                )
            finally:
                # even if the sleep is interrupted (KeyboardInterrupt), the
                # leadership MUST be handed back and every collected
                # follower answered or failed -- never left waiting forever
                with self._batch_mu:
                    batch, self._pending = self._pending, []
                    self._leader_active = False
                    self.stats["queries"] += len(batch)
                    self.stats["batches"] += 1
                    self.stats["max_batch"] = max(self.stats["max_batch"], len(batch))
                _M_BATCH_SIZE.observe(len(batch))
                try:
                    # NB: follower requests are answered HERE, on the
                    # leader's thread -- span trees of traced followers
                    # show their rendezvous wait, not this matmul
                    faults.fire("server.batch")
                    with span("batch.answer", size=len(batch), key=self.key[:12]):
                        responses = engine.answer_many([s.request for s in batch])
                    for s, r in zip(batch, responses):
                        s.response = r
                except BaseException as flush_err:  # noqa: BLE001 -- isolate
                    # the poison pill: retry each request solo so one bad
                    # request can't take down its batchmates. Counted and
                    # logged (this path used to be silent -- a fleet
                    # quietly degrading from stacked matmuls to N solo
                    # answers looked identical to a healthy one).
                    _M_BATCH_POISON.inc()
                    _LOG.warning(
                        "batch_poisoned", size=len(batch),
                        error=f"{type(flush_err).__name__}: {flush_err}",
                    )
                    for idx, s in enumerate(batch):
                        try:
                            s.response = engine.query(s.request)
                        except BaseException as e:  # noqa: BLE001
                            s.error = e
                            _LOG.warning(
                                "batch_poison_request", request_id=idx,
                                request=repr(s.request)[:200],
                                error=f"{type(e).__name__}: {e}",
                            )
                finally:
                    for s in batch:
                        s.event.set()
        if am_leader:
            slot.event.wait()  # already set by the flush above
        else:
            t0 = time.perf_counter()
            with span("batch.wait"):
                slot.event.wait()
            _M_FOLLOWER_WAIT.observe(time.perf_counter() - t0)
        if slot.error is not None:
            raise slot.error
        assert slot.response is not None
        return slot.response

    def query_many(self, requests: Sequence[QueryRequest]) -> List[QueryResponse]:
        """Batch entry point for a caller that already has its requests in
        hand (no rendezvous window needed)."""
        check_deadline("server.query")
        engine = self.ensure_artifact()
        faults.fire("server.batch")
        with self._batch_mu:
            self.stats["queries"] += len(requests)
            self.stats["batches"] += 1
            self.stats["max_batch"] = max(self.stats["max_batch"], len(requests))
        _M_BATCH_SIZE.observe(len(requests))
        with span("server.answer_many", size=len(requests), key=self.key[:12]):
            return engine.answer_many(list(requests))


class CodesignServer(_BaseServer):
    """Serve codesign queries for one configured stencil sweep.

    ``batch_window`` is the rendezvous time (seconds) the microbatch leader
    waits for followers; 0 disables batching (every query answers solo,
    still thread-safe). The default workload is the paper's Fig.-3
    six-stencil uniform mix; ``downsample`` thins the hardware space for
    demos/CI. ``engine``/``devices`` pick the sweep engine for the miss
    path (``"sharded"`` partitions the hardware axis over a device mesh);
    the content address canonicalizes bit-identical engines, so an
    artifact built sharded on an 8-device host warms a single-device
    ``engine="jax"`` server and vice versa.
    """

    def __init__(
        self,
        store: ArtifactStore,
        workload: Optional[Workload] = None,
        gpu: GPUSpec = MAXWELL_GPU,
        area_model: LinearAreaModel = MAXWELL,
        max_area: float = 650.0,
        hw: Optional[HardwareSpace] = None,
        downsample: int = 1,
        engine: str = "auto",
        chunk: Optional[int] = None,
        devices=None,
        lattice_2d: TileLattice = LATTICE_2D,
        lattice_3d: TileLattice = LATTICE_3D,
        batch_window: float = 0.002,
        lru_size: int = 256,
    ):
        self._init_serving(store, batch_window, lru_size)
        self.workload = workload or paper_workload()
        self.gpu = gpu
        self.chunk = chunk
        self.devices = devices
        self.lattice_2d = lattice_2d
        self.lattice_3d = lattice_3d
        if hw is None:
            hw = enumerate_hw_space(area_model, max_area=max_area)
            if downsample > 1:
                hw = hw.downsample(downsample)
        self.hw = hw
        # apply the devices= promotion ONCE (auto -> sharded, non-mesh
        # engines rejected), so the key below, the miss-path build, and
        # the persisted artifact can never disagree about which matrix
        # family they name. Full auto resolution stays lazy: it needs
        # device_count(), which would initialize the jax backend on warm
        # paths that never sweep (the digest resolves the remaining
        # "auto" to its matrix family without touching a backend).
        from repro.core.codesign import _devices_engine

        engine = _devices_engine(engine, devices)
        self.engine = engine
        self.key = store.key_for(
            self.workload, gpu, self.hw, engine, lattice_2d, lattice_3d
        )

    def _solve(self) -> Artifact:
        result = codesign(
            self.workload,
            gpu=self.gpu,
            hw=self.hw,
            lattice_2d=self.lattice_2d,
            lattice_3d=self.lattice_3d,
            chunk=self.chunk,
            engine=self.engine,
            devices=self.devices,
        )
        return self.store.put(
            result,
            engine=self.engine,
            lattice_2d=self.lattice_2d,
            lattice_3d=self.lattice_3d,
        )

    @classmethod
    def from_artifact(
        cls,
        store: ArtifactStore,
        artifact: Artifact,
        batch_window: float = 0.002,
        lru_size: int = 256,
    ) -> "CodesignServer":
        """Wrap an already-stored artifact as a warm server (never sweeps).

        This is the gateway's constructor: a discovered artifact's manifest
        is parsed back into the server's configuration (workload, GPU,
        hardware space, lattices, resolved engine family), the content
        address is recomputed and checked against the artifact's own key --
        a mismatch means the manifest does not describe the matrix and the
        artifact must not be served -- and the query engine is pre-seeded,
        so the miss path is unreachable. Only the small npz hardware
        columns are materialized here; the ``(C, H)`` matrix stays an
        untouched mmap until the first query needs a row.
        """
        m = artifact.manifest
        workload, gpu, lattices = CodesignResult.parse_manifest(m)
        # the spec records the exact (2d, 3d) lattice pair the key was
        # digested over -- including a lattice for a dimensionality the
        # workload never used, which the per-cell tables cannot recover
        spec_lat = m.get("spec", {}).get("lattices")
        if spec_lat:
            lat2, lat3 = (
                TileLattice(**{k: tuple(int(x) for x in v) for k, v in spec_lat[d].items()})
                for d in ("2d", "3d")
            )
        else:  # pre-spec manifests: per-cell tables + defaults
            lat2 = next((lat for lat in lattices if len(lat.t_s3) == 1), LATTICE_2D)
            lat3 = next((lat for lat in lattices if len(lat.t_s3) > 1), LATTICE_3D)
        hw = HardwareSpace(
            n_sm=np.asarray(artifact.hw_n_sm, np.float64),
            n_v=np.asarray(artifact.hw_n_v, np.float64),
            m_sm=np.asarray(artifact.hw_m_sm, np.float64),
            area=np.asarray(artifact.hw_area, np.float64),
        )
        # the spec's engine is already the resolved matrix *family*
        # ("jax"/"numpy"), so the recomputed key cannot drift with the
        # loading host's device count or jax availability.
        engine = m.get("spec", {}).get("engine") or m.get("engine", "auto")
        srv = cls(
            store,
            workload=workload,
            gpu=gpu,
            hw=hw,
            engine=engine,
            lattice_2d=lat2,
            lattice_3d=lat3,
            batch_window=batch_window,
            lru_size=lru_size,
        )
        if srv.key != artifact.key:
            raise ValueError(
                f"artifact {artifact.key} does not reproduce its own content "
                f"address (got {srv.key}); refusing to serve it"
            )
        srv._engine = QueryEngine(artifact, lru_size=lru_size)
        srv.stats["artifact_loads"] += 1
        _M_ART_LOADS.inc()
        return srv


class LMServer(_BaseServer):
    """Serve codesign queries for one configured LM-family sweep.

    Same serving machinery and guarantees as :class:`CodesignServer`; the
    configured sweep is :func:`repro.core.lmcells.lm_codesign` over mesh
    factorizations of ``max_chips`` (area IS the chip count, so area
    budgets in requests are chip budgets). The default workload
    (:func:`repro.core.lmcells.lm_workload`) covers Llama-3-8B and
    Mixtral-8x22B -- built lazily only when no ``workload`` is given,
    since it touches model code via ``jax.eval_shape``.
    """

    def __init__(
        self,
        store: ArtifactStore,
        workload: Optional[Workload] = None,
        hw: Optional[LMHardwareSpace] = None,
        max_chips: int = 512,
        downsample: int = 1,
        engine: str = "auto",
        gpu_name: str = LM_GPU_NAME,
        batch_window: float = 0.002,
        lru_size: int = 256,
    ):
        self._init_serving(store, batch_window, lru_size)
        if workload is None:
            from repro.core.lmcells import lm_workload

            workload = lm_workload()
        if getattr(workload, "family", "stencil") != "lm":
            raise ValueError(
                f"LMServer wants an LM workload, got family {workload.family!r}"
            )
        self.workload = workload
        self.gpu_name = gpu_name
        if hw is None:
            hw = enumerate_lm_hw_space(max_chips=max_chips)
            if downsample > 1:
                hw = hw.downsample(downsample)
        self.hw = hw
        self.engine = engine
        self.key = store.key_for_lm(self.workload, self.hw, engine, gpu_name)

    def _solve(self) -> Artifact:
        result = lm_codesign(
            self.workload, hw=self.hw, engine=self.engine, gpu_name=self.gpu_name
        )
        return self.store.put(result, engine=self.engine)

    @classmethod
    def from_artifact(
        cls,
        store: ArtifactStore,
        artifact: Artifact,
        batch_window: float = 0.002,
        lru_size: int = 256,
    ) -> "LMServer":
        """Wrap a stored LM sweep as a warm server (never sweeps); same
        recomputed-key check as :meth:`CodesignServer.from_artifact`."""
        m = artifact.manifest
        workload, gpu_name, _lattices = LMCodesignResult.parse_manifest(m)
        hw = LMHardwareSpace(
            pod=np.asarray(artifact.hw_column("pod"), np.float64),
            data=np.asarray(artifact.hw_column("data"), np.float64),
            model=np.asarray(artifact.hw_column("model"), np.float64),
            area=np.asarray(artifact.hw_area, np.float64),
        )
        engine = m.get("spec", {}).get("engine") or m.get("engine", "auto")
        srv = cls(
            store,
            workload=workload,
            hw=hw,
            engine=engine,
            gpu_name=gpu_name,
            batch_window=batch_window,
            lru_size=lru_size,
        )
        if srv.key != artifact.key:
            raise ValueError(
                f"artifact {artifact.key} does not reproduce its own content "
                f"address (got {srv.key}); refusing to serve it"
            )
        srv._engine = QueryEngine(artifact, lru_size=lru_size)
        srv.stats["artifact_loads"] += 1
        _M_ART_LOADS.inc()
        return srv


def server_from_artifact(
    store: ArtifactStore,
    artifact: Artifact,
    batch_window: float = 0.002,
    lru_size: int = 256,
):
    """Warm server for a discovered sweep artifact, dispatched on its
    manifest's cell family -- the gateway's single construction point."""
    if artifact.family == "lm":
        return LMServer.from_artifact(store, artifact, batch_window, lru_size)
    return CodesignServer.from_artifact(store, artifact, batch_window, lru_size)
