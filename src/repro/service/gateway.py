"""Fleet gateway: one front door over many stored sweep artifacts.

:class:`repro.service.server.CodesignServer` serves exactly one sweep; a
fleet store holds one artifact per (GPU target, hardware space, lattice,
stencil set) and a cache only pays off if all of them are reachable
through a single long-lived endpoint. The gateway closes that gap:

* **discovery / index** -- every artifact under one or more
  :class:`~repro.service.store.ArtifactStore` roots is indexed at startup
  (and re-indexed on demand) by its manifest-only routing attributes
  (:meth:`repro.service.store.Artifact.routing`): content key, GPU name,
  workload name, stencil set, hardware-space digest, engine family.
  Indexing reads only the small JSON manifests -- no matrix is paged in;
* **routing** -- a request names its artifact either exactly (the content
  key) or by a *routing selector* (``{"gpu": "titanx"}``,
  ``{"stencils": ["heat2d"]}``); :meth:`Gateway.resolve` maps selector ->
  key, answering ``unknown_artifact`` / ``ambiguous_route`` as structured
  errors rather than guessing. A key that misses triggers one re-scan
  before failing, so artifacts dropped into the store after startup are
  served without a restart;
* **LRU server pool** -- each routed key gets a lazily-instantiated
  per-artifact server for its cell family
  (:func:`~repro.service.server.server_from_artifact`: a
  :class:`CodesignServer` for stencil sweeps, an
  :class:`~repro.service.server.LMServer` for LM sweeps), kept in an
  LRU bounded by ``pool_size``: hundreds of stored artifacts never mean
  hundreds of resident mmaps/LRUs. Evicted servers finish their in-flight
  queries (the query path holds a reference) and are garbage-collected;
* **HTTP transport** -- :class:`GatewayHTTPServer` (stdlib
  ``ThreadingHTTPServer``; one thread per connection) exposes
  ``POST /v1/query``, ``GET /v1/artifacts``, ``GET /v1/healthz``,
  ``GET /v1/metrics`` and ``POST /v1/refresh`` over the
  :mod:`repro.service.wire` codec. Concurrent HTTP requests for the same
  artifact rendezvous in that artifact's ``CodesignServer.query``, so the
  leader/follower microbatching survives the process boundary unchanged;
* **observability** -- every request lands in the :mod:`repro.obs`
  metrics registry (per-route and per-artifact counters + latency
  histograms, served back at ``/v1/metrics``), query routes carry an
  ``X-Repro-Trace`` id, a ``"trace": true`` envelope opts into span
  recording, and ``telemetry_interval`` periodically persists per-artifact
  hit/latency stats as ``kind: "telemetry"`` manifest-only artifacts.

Wire format, error codes and a curl-able quickstart are documented in
``docs/serving.md``; the observability surface in
``docs/observability.md``; the request flow diagram lives in
``docs/architecture.md``.
"""

from __future__ import annotations

import contextlib
import contextvars
import json
import math
import os
import re
import threading
import time
from collections import OrderedDict
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple, Union
from urllib.parse import parse_qs, urlsplit

from repro.obs import get_logger
from repro.obs.exemplar import ExemplarStore
from repro.obs.metrics import get_registry as _obs_registry
from repro.obs.process import M_CONNECTIONS, M_POOL_SERVERS, sample_process
from repro.obs.slo import DEFAULT_OBJECTIVES, SLOObjective, SLOTracker
from repro.obs.trace import TRACE_HEADER, new_trace_id, span, trace

from . import faults, wire
from .errors import GatewayError
from .usage import UsageLedger
from .portfolio import PortfolioServer, RouteRequest, RouteResponse
from .query import QueryRequest, QueryResponse
from .resilience import (
    CLIENT_HEADER,
    DEADLINE_HEADER,
    Deadline,
    GatewayResilience,
    check_deadline,
    deadline_scope,
)
from .server import CodesignServer, _M_BATCH_POISON, server_from_artifact
from .store import ArtifactStore

__all__ = [
    "Gateway",
    "GatewayError",
    "UnknownArtifactError",
    "UnknownRouteError",
    "AmbiguousRouteError",
    "AmbiguousWorkloadError",
    "WrongArtifactKindError",
    "GatewayHTTPServer",
    "serve_http",
]

#: selector names :meth:`Gateway.resolve` understands. ``stencils``,
#: ``models`` and ``ops`` are subset matches (the artifact must serve at
#: least those stencils / LM models / LM ops); the rest are exact equality
#: against the routing row. ``workload`` matches the workload name (LM
#: sweeps are built as workload ``"lm"`` by default, so ``{"workload":
#: "lm"}`` is the LM disambiguator); ``family`` matches the cell family
#: ("stencil" | "lm"). ``kind`` widens the search beyond sweep artifacts
#: (measurement/calibration manifests); ``calibration`` selects the sweep
#: built from a given calibration key.
ROUTE_SELECTORS = (
    "key", "gpu", "workload", "family", "stencils", "models", "ops",
    "engine", "hw_digest", "kind", "calibration",
)

#: selectors matched as subsets rather than exact equality.
_SUBSET_SELECTORS = ("stencils", "models", "ops")

# ---- observability (repro.obs; no-ops under REPRO_OBS_DISABLED=1) --------
_LOG = get_logger("repro.gateway")
_REG = _obs_registry()
_M_REQUESTS = _REG.counter(
    "repro_gateway_requests_total", "HTTP requests handled, by route",
    labels=("route",),
)
_M_REQUEST_SECONDS = _REG.histogram(
    "repro_gateway_request_seconds",
    "end-to-end HTTP request wall time (decode -> encode), by route",
    labels=("route",),
)
_M_ERRORS = _REG.counter(
    "repro_gateway_errors_total", "error responses, by route and wire code",
    labels=("route", "code"),
)
_M_ENCODE_SECONDS = _REG.histogram(
    "repro_gateway_encode_seconds", "wire-encoding wall time of /v1/query answers",
)
_M_ART_REQUESTS = _REG.counter(
    "repro_gateway_artifact_requests_total",
    "queries routed to each artifact (the per-artifact hit stats behind "
    "/v1/artifacts and the persisted telemetry snapshots)",
    labels=("artifact",),
)
_M_ART_LAST = _REG.gauge(
    "repro_gateway_artifact_last_access_seconds",
    "unix time of each artifact's most recent routed query",
    labels=("artifact",),
)
_M_ART_SECONDS = _REG.histogram(
    "repro_gateway_artifact_query_seconds",
    "server dispatch wall time per routed artifact",
    labels=("artifact",),
)

#: the bounded set of HTTP route labels (unknown paths all fold into
#: "other" so a path-scanning client can't explode label cardinality).
_ROUTES = (
    "/v1/query", "/v1/query_many", "/v1/route", "/v1/artifacts",
    "/v1/healthz", "/v1/metrics", "/v1/slo", "/v1/debug/exemplars",
    "/v1/refresh",
)

#: the routes whose finished requests are offered as tail exemplars
#: (slowest-N span trees + error ring; docs/observability.md).
_EXEMPLAR_ROUTES = ("/v1/query", "/v1/query_many", "/v1/route")

#: per-request client bucket (X-Repro-Client header or peer address),
#: set by the HTTP handler so the usage ledger can attribute hits
#: without threading a parameter through every query signature.
_CLIENT_BUCKET: contextvars.ContextVar[Optional[str]] = contextvars.ContextVar(
    "repro_gateway_client_bucket", default=None
)


# GatewayError itself now lives in the dependency-leaf
# :mod:`repro.service.errors` (so the store and resilience layers can
# raise structured failures without importing this module); it is
# re-exported here -- ``repro.service.gateway.GatewayError`` stays the
# public spelling. Every subclass pins the wire error ``code``, and the
# HTTP status comes from the shared :data:`wire.ERROR_HTTP_STATUS`
# registry (one table serves the server side here and the batched
# client-side decoder, so the two can never disagree about how a code
# classifies).


class UnknownArtifactError(GatewayError):
    """No stored artifact matches the requested key/selector (HTTP 404)."""

    code = "unknown_artifact"
    http_status = wire.ERROR_HTTP_STATUS["unknown_artifact"]


class UnknownRouteError(GatewayError):
    """A ``/v1/debug/exemplars?route=`` filter named a route this gateway
    does not serve -- a caller typo, not a retryable condition (HTTP 404)."""

    code = "unknown_route"
    http_status = wire.ERROR_HTTP_STATUS["unknown_route"]


class AmbiguousRouteError(GatewayError):
    """A routing selector matched more than one artifact; the message
    carries the candidate keys so the caller can pin one (HTTP 409)."""

    code = "ambiguous_route"
    http_status = wire.ERROR_HTTP_STATUS["ambiguous_route"]


class AmbiguousWorkloadError(GatewayError):
    """A routing selector matched artifacts of more than one *cell family*
    (e.g. a stencil sweep and an LM sweep stored for the same GPU name).
    Unlike a same-family :class:`AmbiguousRouteError` (HTTP 409, "pin a
    key"), the request is underspecified about what kind of question it is
    asking -- add a ``workload`` or ``family`` selector -- so it classifies
    as the caller's error (HTTP 400), mirroring ``wrong_artifact_kind``."""

    code = "ambiguous_workload"
    http_status = wire.ERROR_HTTP_STATUS["ambiguous_workload"]


class WrongArtifactKindError(GatewayError):
    """The resolved artifact exists but is not a queryable sweep (e.g. a
    measurement run or calibration manifest was pinned for /v1/query).
    The request named the wrong thing, hence HTTP 400."""

    code = "wrong_artifact_kind"
    http_status = wire.ERROR_HTTP_STATUS["wrong_artifact_kind"]


class Gateway:
    """Route :class:`QueryRequest` s across every artifact in one or more
    store roots (see the module docstring for the moving parts).

    Parameters
    ----------
    roots:
        One path or a sequence of paths to artifact store directories.
        Roots must exist (:class:`UnknownArtifactError` is *not* the right
        failure for a typo'd path): a missing root raises
        ``FileNotFoundError`` immediately.
    pool_size:
        Max resident per-artifact servers (LRU-evicted beyond this).
    batch_window / lru_size:
        Forwarded to each pooled :class:`CodesignServer` /
        :class:`~repro.service.query.QueryEngine`.
    telemetry_interval:
        Seconds between persisted per-artifact telemetry snapshots
        (:meth:`persist_telemetry`); ``0`` (the default) disables
        persistence entirely -- stored artifact counts then never drift
        under test/smoke query load.
    resilience:
        The :class:`~repro.service.resilience.GatewayResilience` bundle
        (admission control + per-artifact circuit breakers). The default
        sentinel ``"default"`` builds one with permissive settings (no
        rate limits, inflight cap 128, breaker threshold 5); pass
        ``None`` to disable resilience entirely (deadlines still
        propagate -- they are a per-request contract, not a knob).
    slo_objectives:
        Per-route :class:`~repro.obs.slo.SLOObjective` declarations
        tracked by the gateway's :class:`~repro.obs.slo.SLOTracker`
        (served at ``GET /v1/slo``; folds into ``/v1/healthz``). Pass
        ``()`` to declare none (the tracker then reports no routes).
    exemplar_slow_n / exemplar_errors:
        Per-route tail-exemplar retention: span trees of the slowest
        ``exemplar_slow_n`` requests plus the last ``exemplar_errors``
        error responses (``GET /v1/debug/exemplars``).
        ``exemplar_slow_n=0`` disables capture entirely.
    usage_flush_interval:
        Seconds between persistent usage-ledger flushes (the
        ``.usage-ledger.json`` beside each store root;
        :mod:`repro.service.usage`). The ledger replaces the old
        process-local hit counters behind ``/v1/artifacts``.
    telemetry_cap:
        Max ``kind: "telemetry"`` snapshots retained per store root;
        :meth:`persist_telemetry` prunes the oldest beyond it (the cap
        also folds into the ``gc`` CLI's retention plan).
    """

    def __init__(
        self,
        roots: Union[str, Sequence[str]],
        pool_size: int = 8,
        batch_window: float = 0.002,
        lru_size: int = 256,
        telemetry_interval: float = 0.0,
        resilience: Union[GatewayResilience, None, str] = "default",
        slo_objectives: Sequence[SLOObjective] = DEFAULT_OBJECTIVES,
        exemplar_slow_n: int = 8,
        exemplar_errors: int = 32,
        usage_flush_interval: float = 60.0,
        telemetry_cap: int = 32,
    ):
        if isinstance(roots, (str, os.PathLike)):
            roots = [roots]
        if not roots:
            raise ValueError("gateway needs at least one store root")
        self.stores = [ArtifactStore(r, create=False) for r in roots]
        self.pool_size = int(pool_size)
        if self.pool_size < 1:
            raise ValueError("pool_size must be >= 1")
        self.batch_window = float(batch_window)
        self.lru_size = int(lru_size)
        self.telemetry_interval = float(telemetry_interval)
        if resilience == "default":
            resilience = GatewayResilience()
        self.resilience: Optional[GatewayResilience] = resilience
        if telemetry_cap < 0:
            raise ValueError("telemetry_cap must be >= 0")
        self.telemetry_cap = int(telemetry_cap)
        self.slo = SLOTracker(slo_objectives)
        self.exemplars: Optional[ExemplarStore] = (
            ExemplarStore(exemplar_slow_n, exemplar_errors)
            if exemplar_slow_n > 0 else None
        )
        #: per-store-root persistent usage ledgers (the durable hit/byte
        #: accounting behind /v1/artifacts and the gc retention plan)
        self.usage: Dict[str, UsageLedger] = {
            s.root: UsageLedger(s.root, flush_interval_s=usage_flush_interval)
            for s in self.stores
        }
        self._t0_mono = time.monotonic()  # uptime basis (NTP-step immune)
        self._telemetry_mu = threading.Lock()
        self._telemetry_last = time.monotonic()
        self._mu = threading.Lock()  # guards _index and both pools
        self._index: "OrderedDict[str, Dict[str, Any]]" = OrderedDict()
        self._pool: "OrderedDict[str, CodesignServer]" = OrderedDict()
        self._portfolio_pool: "OrderedDict[str, PortfolioServer]" = OrderedDict()
        self.stats: Dict[str, int] = {
            "requests": 0,
            "routed_by_key": 0,
            "routed_by_selector": 0,
            "unknown": 0,
            "pool_hits": 0,
            "pool_instantiations": 0,
            "pool_evictions": 0,
            "rescans": 0,
            "batched_requests": 0,
        }
        self.refresh()

    # ---- discovery --------------------------------------------------------
    def refresh(self) -> int:
        """Re-scan every root and rebuild the routing index from manifests
        (cheap: JSON only). Returns the number of indexed artifacts.
        Already-pooled servers for keys that disappeared are dropped."""
        index: "OrderedDict[str, Dict[str, Any]]" = OrderedDict()
        for store in self.stores:
            for row in store.entries():
                # first root wins on (content-addressed) key collisions --
                # identical keys name identical bytes, so either copy serves
                index.setdefault(row["key"], {**row, "store": store})
        with self._mu:
            self._index = index
            self.stats["rescans"] += 1
            for key in [k for k in self._pool if k not in index]:
                del self._pool[key]
            for key in [k for k in self._portfolio_pool if k not in index]:
                del self._portfolio_pool[key]
            M_POOL_SERVERS.set(len(self._pool) + len(self._portfolio_pool))
        return len(index)

    def keys(self) -> List[str]:
        with self._mu:
            return list(self._index)

    def entries(self) -> List[Dict[str, Any]]:
        """Routing rows (sans store handles) -- the ``/v1/artifacts``
        payload. Each row carries ``hits`` / ``bytes`` / ``last_access``
        sourced from the persistent usage ledger beside its store root
        (:mod:`repro.service.usage`): buffered deltas merged over what
        the last flush persisted, so the counts survive restarts. The
        fields stay advisory in the wire sense -- deliberately excluded
        from the canonical byte-identity surface (only ``/v1/query``
        answers carry that guarantee)."""
        with self._mu:
            rows = [
                {k: v for k, v in row.items() if k != "store"}
                for row in self._index.values()
            ]
            roots = {k: row["store"].root for k, row in self._index.items()}
        snaps = {root: ledger.snapshot() for root, ledger in self.usage.items()}
        for row in rows:
            rec = snaps.get(roots.get(row["key"], ""), {}).get(row["key"])
            row["hits"] = int(rec["hits"]) if rec else 0
            row["bytes"] = int(rec["bytes"]) if rec else 0
            row["last_access"] = rec["last_access"] if rec else None
        return rows

    def __len__(self) -> int:
        with self._mu:
            return len(self._index)

    # ---- routing ----------------------------------------------------------
    def _match(
        self, route: Mapping[str, Any], kinds: Optional[Sequence[str]]
    ) -> List[str]:
        unknown = set(route) - set(ROUTE_SELECTORS)
        if unknown:
            raise ValueError(
                f"unknown route selector(s) {sorted(unknown)} "
                f"(want one of {list(ROUTE_SELECTORS)})"
            )
        if "kind" in route:
            kinds = None  # an explicit kind selector overrides the default
        with self._mu:
            rows = list(self._index.values())
        out = []
        for row in rows:
            ok = kinds is None or row.get("kind", "sweep") in kinds
            if ok:
                for name, want in route.items():
                    if name in _SUBSET_SELECTORS:
                        want_set = {want} if isinstance(want, str) else set(want)
                        ok = want_set <= set(row.get(name) or ())
                    elif name == "family":
                        ok = row.get("family", "stencil") == want
                    else:
                        ok = row.get(name) == want
                    if not ok:
                        break
            if ok:
                out.append(row["key"])
        return out

    def resolve(
        self,
        artifact: Optional[str] = None,
        route: Optional[Mapping[str, Any]] = None,
        kinds: Optional[Sequence[str]] = ("sweep",),
        rescan: bool = True,
    ) -> str:
        """Map (key | selector | nothing) -> one content key.

        An exact ``artifact`` key wins over ``route``. A miss triggers one
        on-demand :meth:`refresh` (new artifacts appear without a restart)
        before raising :class:`UnknownArtifactError`; a selector matching
        several artifacts raises :class:`AmbiguousRouteError` listing the
        candidates. With neither argument, a single-artifact gateway
        serves its only artifact and a multi-artifact one refuses to
        guess.

        ``kinds`` restricts which manifest kinds compete: the query paths
        keep the default ``("sweep",)`` so measurement/calibration
        manifests in the same store can never make a ``{"gpu": ...}``
        selector ambiguous (an explicit ``{"kind": ...}`` selector in
        ``route`` overrides it). A pinned ``artifact`` key of the wrong
        kind raises :class:`WrongArtifactKindError` rather than a
        misleading 404.

        ``rescan=False`` skips the on-demand refresh on a miss --
        :meth:`query_many` uses it to bound a whole batch to ONE store
        re-scan instead of one per unresolvable query."""
        for attempt in range(2 if rescan else 1):
            if artifact is not None:
                with self._mu:
                    row = self._index.get(artifact)
                    if row is not None:
                        kind = row.get("kind", "sweep")
                        if kinds is not None and kind not in kinds:
                            pass  # raise outside the lock
                        else:
                            self.stats["routed_by_key"] += 1
                            return artifact
                if row is not None:
                    want = (
                        "a queryable sweep" if kinds == ("sweep",)
                        else f"a routable {'/'.join(kinds)} manifest"
                    )
                    raise WrongArtifactKindError(
                        f"artifact {artifact!r} is a {row.get('kind')!r} manifest, "
                        f"not {want}"
                    )
            elif route:
                matches = self._match(route, kinds)
                if len(matches) == 1:
                    with self._mu:
                        self.stats["routed_by_selector"] += 1
                    return matches[0]
                if len(matches) > 1:
                    with self._mu:
                        families = {
                            self._index[k].get("family", "stencil")
                            for k in matches
                            if k in self._index
                        }
                    if len(families) > 1:
                        raise AmbiguousWorkloadError(
                            f"route {dict(route)} matches artifacts of "
                            f"{len(families)} cell families "
                            f"({', '.join(sorted(families))}); add a "
                            f"'workload' or 'family' selector to say which "
                            f"kind of question this is"
                        )
                    raise AmbiguousRouteError(
                        f"route {dict(route)} matches {len(matches)} artifacts "
                        f"({', '.join(sorted(matches))}); pin one with 'artifact'"
                    )
            else:
                with self._mu:
                    candidates = [
                        k for k, row in self._index.items()
                        if kinds is None or row.get("kind", "sweep") in kinds
                    ]
                if len(candidates) == 1:
                    with self._mu:
                        self.stats["routed_by_key"] += 1
                    return candidates[0]
                if len(candidates) > 1:
                    raise AmbiguousRouteError(
                        f"gateway serves {len(candidates)} artifacts; name one "
                        "via 'artifact' or a 'route' selector"
                    )
            if rescan and attempt == 0:
                self.refresh()  # on-demand discovery before giving up
        with self._mu:
            self.stats["unknown"] += 1
        if artifact is not None:
            what = f"artifact {artifact!r}"
        elif route:
            what = f"route {dict(route)}"
        elif kinds is not None:
            # the store may be non-empty but hold only non-sweep kinds
            # (e.g. after `measure.cli run` + `fit`, before `build`) --
            # "empty store" would contradict the indexed count printed next
            what = f"an unselected query (no {'/'.join(kinds)}-kind artifact stored)"
        else:
            what = "empty store"
        raise UnknownArtifactError(
            f"no stored artifact matches {what} "
            f"({len(self)} artifacts indexed; GET /v1/artifacts lists them)"
        )

    # ---- server pool ------------------------------------------------------
    def server_for(self, key: str) -> CodesignServer:
        """The pooled per-artifact server for an (already resolved) key,
        instantiating (and LRU-evicting) as needed."""
        with self._mu:
            srv = self._pool.get(key)
            if srv is not None:
                self._pool.move_to_end(key)
                self.stats["pool_hits"] += 1
                return srv
            row = self._index.get(key)
        if row is None:
            raise UnknownArtifactError(f"artifact {key!r} is not indexed")
        if row.get("kind", "sweep") != "sweep":
            raise WrongArtifactKindError(
                f"artifact {key!r} is a {row.get('kind')!r} manifest; only "
                "sweep artifacts serve queries"
            )
        store: ArtifactStore = row["store"]
        # the expensive, failure-prone part of a pool miss (store I/O +
        # server build: mmap, JSON, integrity check) runs under this
        # artifact's circuit breaker: after `threshold` consecutive raw
        # failures (corrupt file, flaky filesystem) the breaker opens and
        # callers fail fast with `circuit_open` instead of re-paying the
        # broken build until a half-open probe succeeds. GatewayError
        # outcomes (unknown/kind/deadline) pass through untouched and do
        # NOT count as breaker failures -- a client's tiny deadline must
        # never open the circuit for everyone else.
        res = self.resilience
        breaker = res.breaker(key) if res is not None else None
        ctx = breaker.call() if breaker is not None else contextlib.nullcontext()
        with ctx:
            art = store.get(key)
            if art is None:  # deleted between index and query
                self.refresh()
                raise UnknownArtifactError(
                    f"artifact {key!r} vanished from {store.root}"
                )
            srv = server_from_artifact(
                store, art, batch_window=self.batch_window, lru_size=self.lru_size
            )
        with self._mu:
            # a racing thread may have built it meanwhile; keep the first
            winner = self._pool.setdefault(key, srv)
            if winner is srv:
                self.stats["pool_instantiations"] += 1
            srv = winner
            self._pool.move_to_end(key)
            while len(self._pool) > self.pool_size:
                self._pool.popitem(last=False)  # in-flight queries hold refs
                self.stats["pool_evictions"] += 1
            M_POOL_SERVERS.set(len(self._pool) + len(self._portfolio_pool))
        return srv

    def portfolio_server_for(self, key: str) -> PortfolioServer:
        """The pooled :class:`~repro.service.portfolio.PortfolioServer`
        for an (already resolved) portfolio key. Shares the gateway's
        resilience bundle, so route-time member reads run under the
        per-member circuit breakers; the build itself (two manifest
        loads) runs under the portfolio's own breaker like any pool
        miss."""
        with self._mu:
            srv = self._portfolio_pool.get(key)
            if srv is not None:
                self._portfolio_pool.move_to_end(key)
                self.stats["pool_hits"] += 1
                return srv
            row = self._index.get(key)
        if row is None:
            raise UnknownArtifactError(f"artifact {key!r} is not indexed")
        if row.get("kind", "sweep") != "portfolio":
            raise WrongArtifactKindError(
                f"artifact {key!r} is a {row.get('kind')!r} manifest; only "
                "portfolio artifacts serve /v1/route"
            )
        store: ArtifactStore = row["store"]
        res = self.resilience
        breaker = res.breaker(key) if res is not None else None
        ctx = breaker.call() if breaker is not None else contextlib.nullcontext()
        with ctx:
            art = store.get(key)
            if art is None:
                self.refresh()
                raise UnknownArtifactError(
                    f"artifact {key!r} vanished from {store.root}"
                )
            sweep_key = art.payload.get("sweep_key")
            sweep = None
            for s in [store] + [s for s in self.stores if s is not store]:
                sweep = s.get(sweep_key)
                if sweep is not None:
                    break
            if sweep is None:
                raise UnknownArtifactError(
                    f"portfolio {key!r} references sweep {sweep_key!r}, which "
                    "no store root holds (was the member sweep deleted?)"
                )
            srv = PortfolioServer(art, sweep, resilience=res)
        with self._mu:
            winner = self._portfolio_pool.setdefault(key, srv)
            if winner is srv:
                self.stats["pool_instantiations"] += 1
            srv = winner
            self._portfolio_pool.move_to_end(key)
            while len(self._portfolio_pool) > self.pool_size:
                self._portfolio_pool.popitem(last=False)
                self.stats["pool_evictions"] += 1
            M_POOL_SERVERS.set(len(self._pool) + len(self._portfolio_pool))
        return srv

    # ---- queries ----------------------------------------------------------
    def _note_artifact(self, key: str, dispatch_s: float, n: int = 1) -> None:
        """Per-artifact hit accounting: the live metrics registry (the
        telemetry snapshots) plus the persistent usage ledger (the
        ``/v1/artifacts`` rows and the ``gc`` retention plan). The single
        choke point for routed-query hits, so the two can never double
        count. No-ops under the ``REPRO_OBS_DISABLED`` kill switch."""
        _M_ART_REQUESTS.labels(artifact=key).inc(n)
        _M_ART_LAST.labels(artifact=key).set(time.time())
        _M_ART_SECONDS.labels(artifact=key).observe(dispatch_s)
        if _REG.disabled:
            return
        with self._mu:
            row = self._index.get(key)
            root = row["store"].root if row is not None else None
        ledger = self.usage.get(root) if root is not None else None
        if ledger is not None:
            ledger.record(key, n=n, client=_CLIENT_BUCKET.get())
            ledger.maybe_flush()

    def _note_bytes(self, key: str, nbytes: int) -> None:
        """Response-byte accounting for the single-answer routes (the
        batched route's shared envelope is not attributed per artifact)."""
        if _REG.disabled:
            return
        with self._mu:
            row = self._index.get(key)
            root = row["store"].root if row is not None else None
        ledger = self.usage.get(root) if root is not None else None
        if ledger is not None:
            ledger.record(key, n=0, nbytes=nbytes)

    def flush_usage(self) -> None:
        """Flush every store root's usage ledger now (shutdown path; the
        request path flushes on its own interval). Never raises."""
        for ledger in self.usage.values():
            try:
                ledger.flush()
            except Exception as e:  # noqa: BLE001 - accounting, never fatal
                _LOG.warning("usage_flush_failed",
                             error=f"{type(e).__name__}: {e}")

    def query(
        self,
        request: QueryRequest,
        artifact: Optional[str] = None,
        route: Optional[Mapping[str, Any]] = None,
    ) -> QueryResponse:
        """Route one request to its artifact's server (microbatching with
        any concurrent caller of the same artifact) and answer it."""
        with self._mu:
            self.stats["requests"] += 1
        check_deadline("gateway.resolve")
        with span("resolve"):
            key = self.resolve(artifact, route)
        check_deadline("gateway.pool")
        with span("pool", artifact=key[:12]):
            srv = self.server_for(key)
        t0 = time.perf_counter()
        with span("dispatch", artifact=key[:12]):
            response = srv.query(request)
        self._note_artifact(key, time.perf_counter() - t0)
        self._maybe_persist_telemetry()
        return response

    def route(
        self,
        request: RouteRequest,
        artifact: Optional[str] = None,
        route: Optional[Mapping[str, Any]] = None,
    ) -> RouteResponse:
        """Resolve a portfolio (key or selector, among ``kind:
        "portfolio"`` manifests only) and route one workload cell to its
        assigned member design (``POST /v1/route``)."""
        with self._mu:
            self.stats["requests"] += 1
        check_deadline("gateway.resolve")
        with span("resolve"):
            key = self.resolve(artifact, route, kinds=("portfolio",))
        check_deadline("gateway.pool")
        with span("pool", artifact=key[:12]):
            srv = self.portfolio_server_for(key)
        t0 = time.perf_counter()
        with span("dispatch", artifact=key[:12]):
            response = srv.route(request)
        self._note_artifact(key, time.perf_counter() - t0)
        self._maybe_persist_telemetry()
        return response

    def query_many(
        self,
        queries: Sequence[
            Tuple[QueryRequest, Optional[str], Optional[Mapping[str, Any]]]
        ],
    ) -> List[Any]:
        """Answer N routed queries in one call (the ``/v1/query_many``
        body). Queries are resolved individually, grouped by artifact, and
        each group rides that artifact's ``CodesignServer.query_many``
        stacked matmul -- per-artifact microbatching without waiting on a
        rendezvous window. Returns, per query *in order*, either a
        :class:`QueryResponse` or a ``(code, message)`` error pair: one
        unroutable or poisonous query never fails its batchmates."""
        results: List[Any] = [None] * len(queries)
        groups: Dict[str, List[int]] = {}
        with self._mu:
            self.stats["requests"] += len(queries)
            self.stats["batched_requests"] += len(queries)
        # at most ONE on-demand store re-scan per batch: the first
        # unresolvable query pays it, the rest fail fast (a batch of
        # unknown keys must not trigger MAX_BATCH full-store scans)
        rescanned = False
        for i, (request, artifact, route) in enumerate(queries):
            try:
                # the deadline classifies per element (the batch contract:
                # errors are pairs, never a blanket failure) -- a spent
                # budget fails each remaining element fast, right here
                check_deadline("gateway.resolve")
                key = self.resolve(artifact, route, rescan=not rescanned)
            except UnknownArtifactError as e:
                rescanned = True
                results[i] = (e.code, str(e))
                continue
            except GatewayError as e:
                results[i] = (e.code, str(e))
                continue
            except (KeyError, ValueError) as e:
                results[i] = ("bad_request", str(e.args[0] if e.args else e))
                continue
            groups.setdefault(key, []).append(i)
        def answer_group(key: str, idxs: List[int]) -> None:
            try:
                _answer_group(key, idxs)
            except Exception as e:  # noqa: BLE001 - NOTHING may escape: an
                # unfilled slot would crash the whole batch's encoding
                # (and the pool path would swallow the exception silently)
                for i in idxs:
                    if results[i] is None:
                        results[i] = ("internal", f"{type(e).__name__}: {e}")

        def _answer_group(key: str, idxs: List[int]) -> None:
            try:
                # server_for can also raise outside the GatewayError
                # family (e.g. a corrupt artifact failing its content-key
                # check with ValueError) -- the outer boundary catches it
                srv = self.server_for(key)
            except GatewayError as e:
                for i in idxs:
                    results[i] = (e.code, str(e))
                return
            t0 = time.perf_counter()
            try:
                for i, resp in zip(idxs, srv.query_many([queries[i][0] for i in idxs])):
                    results[i] = resp
                self._note_artifact(key, time.perf_counter() - t0, n=len(idxs))
            except GatewayError as e:
                # a classified outcome for the whole stacked call (e.g.
                # deadline_exceeded): every element gets the code -- solo
                # retries would just re-pay a budget that is already spent
                for i in idxs:
                    results[i] = (e.code, str(e))
            except Exception as flush_err:  # noqa: BLE001 - isolate the poison pill
                _M_BATCH_POISON.inc()
                _LOG.warning("batch_poisoned", artifact=key[:12], size=len(idxs),
                             error=f"{type(flush_err).__name__}: {flush_err}")
                for i in idxs:
                    try:
                        results[i] = srv.query(queries[i][0])
                    except GatewayError as e:
                        results[i] = (e.code, str(e))
                    except (KeyError, ValueError) as e:
                        results[i] = (
                            "bad_request", str(e.args[0] if e.args else e)
                        )
                    except Exception as e:  # noqa: BLE001 - boundary
                        results[i] = ("internal", f"{type(e).__name__}: {e}")
                self._note_artifact(key, time.perf_counter() - t0, n=len(idxs))

        if len(groups) <= 1:
            for key, idxs in groups.items():
                answer_group(key, idxs)
        else:
            # overlap the per-artifact stacked matmuls: groups answer
            # concurrently (each writes disjoint result indices), matching
            # what concurrent single-endpoint requests would get from the
            # threaded HTTP server -- but on a pool BOUNDED by the server
            # pool size: a batch pinning 1024 distinct artifacts must not
            # spawn 1024 threads thrashing an 8-server LRU.
            from concurrent.futures import ThreadPoolExecutor

            workers = min(len(groups), self.pool_size)
            with ThreadPoolExecutor(max_workers=workers) as pool:
                for key, idxs in groups.items():
                    # contextvars (the request deadline) do not cross into
                    # executor threads by themselves; each submission gets
                    # its own Context copy (one Context cannot run
                    # concurrently in two threads)
                    pool.submit(
                        contextvars.copy_context().run, answer_group, key, idxs
                    )
        self._maybe_persist_telemetry()
        return results

    def health(self) -> Dict[str, Any]:
        slo_status = self.slo.status()  # own lock; computed outside _mu
        with self._mu:
            return {
                "ok": True,
                "slo": slo_status,
                "uptime_s": round(time.monotonic() - self._t0_mono, 3),
                "artifacts": len(self._index),
                "pooled_servers": len(self._pool),
                "pool_size": self.pool_size,
                "telemetry_interval": self.telemetry_interval,
                "roots": [s.root for s in self.stores],
                "stats": dict(self.stats),
            }

    # ---- telemetry persistence --------------------------------------------
    def artifact_stats(self) -> Dict[str, Dict[str, Any]]:
        """Per-artifact hit/latency stats for every *indexed* artifact,
        read from the live metrics registry (never minting zero samples
        for untouched keys). The payload of :meth:`persist_telemetry`."""
        out: Dict[str, Dict[str, Any]] = {}
        for key in self.keys():
            hits = _M_ART_REQUESTS.get(artifact=key)
            last = _M_ART_LAST.get(artifact=key)
            lat = _M_ART_SECONDS.get(artifact=key)
            out[key] = {
                "hits": int(hits.value) if hits is not None else 0,
                "last_access": last.value if last is not None else None,
                "query_seconds_count": lat.count if lat is not None else 0,
                "query_seconds_sum": lat.sum if lat is not None else 0.0,
            }
        return out

    def persist_telemetry(self, store: Optional[ArtifactStore] = None) -> str:
        """Write the current per-artifact hit/latency stats as a
        ``kind: "telemetry"`` manifest-only artifact (first store root by
        default) and return its content key.

        Each snapshot carries its collection time, so successive snapshots
        get distinct keys -- a retention policy reads the *series*. The
        ``("sweep",)`` default kind filter in :meth:`resolve` keeps these
        manifests out of query routing automatically."""
        store = store if store is not None else self.stores[0]
        with self._mu:
            stats = dict(self.stats)
        payload = {
            "collected_at": time.time(),
            "uptime_s": round(time.monotonic() - self._t0_mono, 3),
            "gateway": stats,
            "artifacts": self.artifact_stats(),
        }
        art = store.put_json(
            "telemetry", payload, routing={"workload": "gateway-telemetry"}
        )
        _LOG.info("telemetry_persisted", key=art.key,
                  artifacts=len(payload["artifacts"]))
        self._prune_telemetry(store)
        return art.key

    def _prune_telemetry(self, store: ArtifactStore) -> None:
        """Enforce ``telemetry_cap``: drop the oldest ``kind:
        "telemetry"`` snapshots (by their own ``collected_at``) beyond
        the cap, so a long-lived gateway's snapshot *series* stays a
        series instead of an unbounded accretion."""
        snaps: List[Tuple[float, str]] = []
        for key in store.keys():
            art = store.get(key)
            if art is not None and art.kind == "telemetry":
                snaps.append((float(art.payload.get("collected_at") or 0.0), key))
        excess = len(snaps) - self.telemetry_cap
        if excess <= 0:
            return
        snaps.sort()
        for _, key in snaps[:excess]:
            store.delete(key)
        _LOG.info("telemetry_pruned", dropped=excess, cap=self.telemetry_cap)
        self.refresh()

    def _maybe_persist_telemetry(self) -> None:
        """Interval-gated :meth:`persist_telemetry` on the request path
        (no background thread: a gateway that stops serving stops
        snapshotting, and tests stay deterministic). Never lets a
        telemetry failure fail the query that triggered it."""
        iv = self.telemetry_interval
        if iv <= 0:
            return
        now = time.monotonic()
        with self._telemetry_mu:
            if now - self._telemetry_last < iv:
                return
            self._telemetry_last = now
        try:
            self.persist_telemetry()
        except Exception as e:  # noqa: BLE001 - advisory path, never fatal
            _LOG.warning("telemetry_persist_failed",
                         error=f"{type(e).__name__}: {e}")


# ---------------------------------------------------------------------------
# HTTP transport
# ---------------------------------------------------------------------------
_TRACE_ID_RE = re.compile(r"[^A-Za-z0-9_-]")


def _clean_trace_id(raw: Optional[str]) -> str:
    """A usable trace id from a client-supplied header value: echo it
    (sanitized to a bounded identifier charset) or mint a fresh one."""
    if raw:
        tid = _TRACE_ID_RE.sub("", raw)[:64]
        if tid:
            return tid
    return new_trace_id()


class _Handler(BaseHTTPRequestHandler):
    """Maps the wire codec onto HTTP. All bodies are JSON; failures are
    :func:`repro.service.wire.encode_error` payloads (never tracebacks).

    Every request increments per-route counters and a latency histogram
    in the :mod:`repro.obs` registry (served right back at
    ``GET /v1/metrics``); query routes echo/mint an ``X-Repro-Trace``
    header, and a ``"trace": true`` request envelope opts into span
    recording (the tree rides back in the response envelope)."""

    server_version = "repro-gateway/1"
    protocol_version = "HTTP/1.1"  # keep-alive: clients reuse connections

    def setup(self) -> None:
        super().setup()
        M_CONNECTIONS.inc()

    def finish(self) -> None:
        try:
            super().finish()
        finally:
            M_CONNECTIONS.dec()

    def log_message(self, fmt, *args):  # noqa: ARG002
        # the stdlib's per-request stderr line, rerouted through the
        # structured logger at DEBUG: silent by default (NullHandler /
        # level), JSON lines under `serve --log-level debug`
        _LOG.debug("http_access", client=self.client_address[0],
                   line=fmt % args)

    @property
    def gateway(self) -> Gateway:
        return self.server.gateway  # type: ignore[attr-defined]

    def _route(self) -> str:
        """Metrics label for this request's path: the known endpoint, or
        "other" (bounded label cardinality under path scans)."""
        path = self.path.split("?", 1)[0]
        return path if path in _ROUTES else "other"

    def _send(
        self,
        status: int,
        body: bytes,
        content_type="application/json",
        headers: Optional[Mapping[str, str]] = None,
    ) -> None:
        self._last_status = status  # the SLO recorder reads it in finally
        self.send_response(status)
        self.send_header("Content-Type", content_type)
        self.send_header("Content-Length", str(len(body)))
        for name, value in (headers or {}).items():
            self.send_header(name, value)
        if self.close_connection:
            self.send_header("Connection", "close")
        self.end_headers()
        self.wfile.write(body)

    def _send_error(
        self,
        status: int,
        code: str,
        message: str,
        headers: Optional[Mapping[str, str]] = None,
    ) -> None:
        # one request per connection on failures: simpler client recovery
        # than reasoning about keep-alive state after an error
        self.close_connection = True
        self._ex_code = code  # the error-exemplar offer reads it in finally
        _M_ERRORS.labels(route=self._route(), code=code).inc()
        _LOG.debug("request_error", route=self._route(), code=code,
                   status=status, message=message)
        self._send(status, wire.encode_error(code, message), headers=headers)

    def _send_gateway_error(self, e: GatewayError) -> None:
        """A structured GatewayError onto the wire, carrying Retry-After
        when the failure advertises a backoff hint (429/503 family)."""
        headers = None
        retry_after = getattr(e, "retry_after_s", None)
        if retry_after is not None:
            headers = {"Retry-After": str(max(1, math.ceil(retry_after)))}
        self._send_error(e.http_status, e.code, str(e), headers=headers)

    def _request_deadline(self, env_ms: Optional[float]) -> Optional[Deadline]:
        """The effective request deadline: the ``X-Repro-Deadline-Ms``
        header, the envelope ``deadline_ms``, or (when both are present)
        the tighter of the two. None when the request carries neither."""
        raw = self.headers.get(DEADLINE_HEADER)
        ms: Optional[float] = None
        if raw is not None:
            try:
                ms = float(raw)
            except ValueError:
                raise wire.WireError(
                    f"invalid {DEADLINE_HEADER} header {raw!r} "
                    "(want a positive number of milliseconds)"
                ) from None
            ms = wire._check_deadline_ms(ms)
        if env_ms is not None:
            ms = env_ms if ms is None else min(ms, env_ms)
        return None if ms is None else Deadline(ms)

    def _metrics_body(self, query: str) -> Tuple[bytes, str]:
        """The ``/v1/metrics`` payload: Prometheus text by default,
        canonical JSON via ``?format=json`` or ``Accept:
        application/json`` (explicit ``?format=`` wins)."""
        fmt = self._scrape_format(query)
        sample_process()  # lazy process gauges: refreshed per scrape
        reg = _REG
        if fmt == "json":
            return reg.render_json(), "application/json"
        if fmt in ("prometheus", "text"):
            return (reg.render_prometheus(),
                    "text/plain; version=0.0.4; charset=utf-8")
        raise wire.WireError(
            f"unknown metrics format {fmt!r} (want 'prometheus' or 'json')"
        )

    def _scrape_format(self, query: str) -> str:
        """Shared format negotiation of the scrape endpoints
        (``/v1/metrics``, ``/v1/slo``): explicit ``?format=`` wins over
        the Accept header; Prometheus text is the default."""
        fmt = (parse_qs(query).get("format") or [""])[0]
        if not fmt:
            accept = self.headers.get("Accept", "")
            fmt = "json" if "application/json" in accept else "prometheus"
        return fmt

    def _slo_body(self, query: str) -> Tuple[bytes, str]:
        """The ``/v1/slo`` payload: the burn-rate gauges as Prometheus
        text by default, the full wire-enveloped report via
        ``?format=json`` (the canonical rendering the golden corpus
        pins)."""
        fmt = self._scrape_format(query)
        slo = self.gateway.slo
        if fmt == "json":
            return wire.encode_slo_response(slo.report()), "application/json"
        if fmt in ("prometheus", "text"):
            return (slo.render_prometheus(),
                    "text/plain; version=0.0.4; charset=utf-8")
        raise wire.WireError(
            f"unknown slo format {fmt!r} (want 'prometheus' or 'json')"
        )

    def do_GET(self) -> None:  # noqa: N802 - BaseHTTPRequestHandler API
        split = urlsplit(self.path)
        t0 = time.perf_counter()
        self._last_status: Optional[int] = None
        try:
            if split.path == "/v1/healthz":
                body = json.dumps(self.gateway.health(), sort_keys=True).encode()
                self._send(200, body)
            elif split.path == "/v1/artifacts":
                body = json.dumps(
                    {"v": wire.WIRE_VERSION, "artifacts": self.gateway.entries()},
                    sort_keys=True,
                ).encode()
                self._send(200, body)
            elif split.path == "/v1/metrics":
                body, content_type = self._metrics_body(split.query)
                self._send(200, body, content_type=content_type)
            elif split.path == "/v1/slo":
                body, content_type = self._slo_body(split.query)
                self._send(200, body, content_type=content_type)
            elif split.path == "/v1/debug/exemplars":
                self._send_exemplars(split.query)
            else:
                self._send_error(wire.ERROR_HTTP_STATUS["not_found"], "not_found",
                                 f"no such endpoint {split.path!r}")
        except wire.WireError as e:
            self._send_error(wire.ERROR_HTTP_STATUS.get(e.code, 400), e.code, str(e))
        except GatewayError as e:
            self._send_gateway_error(e)
        except BrokenPipeError:
            pass
        except Exception as e:  # noqa: BLE001 - boundary: never leak a traceback
            self._send_error(500, "internal", f"{type(e).__name__}: {e}")
        finally:
            route = self._route()
            dt = time.perf_counter() - t0
            _M_REQUESTS.labels(route=route).inc()
            _M_REQUEST_SECONDS.labels(route=route).observe(dt)
            status = getattr(self, "_last_status", None)
            if status is not None and not _REG.disabled:
                self.gateway.slo.record(route, dt, ok=status < 500)

    def _send_exemplars(self, query: str) -> None:
        """GET /v1/debug/exemplars[?route=/v1/query]: retained span trees
        of the slowest/error requests, cross-referenced by trace id."""
        route = (parse_qs(query).get("route") or [None])[0]
        if route is not None and route not in _ROUTES:
            raise UnknownRouteError(
                f"unknown route {route!r} (this gateway serves "
                f"{', '.join(_ROUTES)})"
            )
        ex = self.gateway.exemplars
        snap = (ex.snapshot(route) if ex is not None
                else {"slow_n": 0, "max_errors": 0, "routes": {}})
        self._send(200, wire.encode_exemplars_response(snap))

    def _capture(self) -> bool:
        """Whether this request should record an internal span tree for
        the tail-exemplar ring even though the client didn't ask for one
        (never perturbs response bytes; disabled with the kill switch so
        the obs-overhead A/B measures the whole capture path)."""
        return self.gateway.exemplars is not None and not _REG.disabled

    def _answer_query(self, data: bytes) -> None:
        """POST /v1/query: the one route with opt-in tracing. Untraced
        requests encode with ``trace=None`` -- the exact pre-tracing
        bytes (byte-identity) -- even when exemplar capture forces an
        *internal* span tree; traced requests return the tree in the
        (additive) ``trace`` envelope field, under the echoed/minted
        trace id."""
        request, artifact, route_sel, traced, env_ms = \
            wire.decode_request_full(data)
        deadline = self._request_deadline(env_ms)
        tid = _clean_trace_id(self.headers.get(TRACE_HEADER))
        self._ex_tid = tid
        tree = None
        with deadline_scope(deadline):
            if traced or self._capture():
                with trace("gateway.request", trace_id=tid,
                           route="/v1/query") as root:
                    response = self.gateway.query(
                        request, artifact=artifact, route=route_sel
                    )
                tree = root.root_tree()  # complete only after the root closes
            else:
                response = self.gateway.query(
                    request, artifact=artifact, route=route_sel
                )
        self._ex_tree = tree
        with _M_ENCODE_SECONDS.time():
            body = wire.encode_response(response, trace=tree if traced else None)
        self._send(200, body, headers={TRACE_HEADER: tid})
        self.gateway._note_bytes(response.artifact_key, len(body))

    def _answer_route(self, data: bytes) -> None:
        """POST /v1/route: canonical-byte answers like /v1/query (the
        portfolio byte-identity surface); degraded fallback answers are
        still HTTP 200 -- ``degraded: true`` rides in the payload."""
        request, artifact, route_sel, env_ms = wire.decode_route_request_full(data)
        deadline = self._request_deadline(env_ms)
        tid = _clean_trace_id(self.headers.get(TRACE_HEADER))
        self._ex_tid = tid
        with deadline_scope(deadline):
            if self._capture():
                with trace("gateway.request", trace_id=tid,
                           route="/v1/route") as root:
                    response = self.gateway.route(
                        request, artifact=artifact, route=route_sel
                    )
                self._ex_tree = root.root_tree()
            else:
                response = self.gateway.route(
                    request, artifact=artifact, route=route_sel
                )
        with _M_ENCODE_SECONDS.time():
            body = wire.encode_route_response(response)
        self._send(200, body, headers={TRACE_HEADER: tid})
        self.gateway._note_bytes(response.portfolio_key, len(body))

    def _answer_query_many(self, data: bytes) -> None:
        """POST /v1/query_many: an envelope-level deadline bounds the
        whole batch (elements past the budget classify as
        ``deadline_exceeded`` pairs; the batch itself still answers 200)."""
        queries, env_ms = wire.decode_request_many_full(data)
        deadline = self._request_deadline(env_ms)
        tid = _clean_trace_id(self.headers.get(TRACE_HEADER))
        self._ex_tid = tid
        with deadline_scope(deadline):
            if self._capture():
                with trace("gateway.request", trace_id=tid,
                           route="/v1/query_many") as root:
                    results = self.gateway.query_many(queries)
                self._ex_tree = root.root_tree()
            else:
                results = self.gateway.query_many(queries)
        self._send(200, wire.encode_response_many(results),
                   headers={TRACE_HEADER: tid})

    def do_POST(self) -> None:  # noqa: N802
        t0 = time.perf_counter()
        self._last_status: Optional[int] = None
        self._ex_tid: Optional[str] = None
        self._ex_tree: Optional[Dict[str, Any]] = None
        self._ex_code: Optional[str] = None
        client_token = _CLIENT_BUCKET.set(
            self.headers.get(CLIENT_HEADER) or self.client_address[0]
        )
        try:
            # always drain the body first: with keep-alive, unread body
            # bytes would be misparsed as the connection's next request line
            length = int(self.headers.get("Content-Length", 0))
            data = self.rfile.read(length)
            if faults.should_drop("gateway.drop_socket"):
                # chaos hook: abandon the connection without a response --
                # the client sees a reset/EOF (the retryable failure its
                # RetryPolicy is built for). Armed only via fault injection.
                self.close_connection = True
                return
            if self.path == "/v1/refresh":
                n = self.gateway.refresh()
                self._send(200, json.dumps({"ok": True, "artifacts": n}).encode())
                return
            if self.path not in ("/v1/query", "/v1/query_many", "/v1/route"):
                self._send_error(wire.ERROR_HTTP_STATUS["not_found"], "not_found",
                             f"no such endpoint {self.path!r}")
                return
            # admission control guards only the query routes (health,
            # metrics and refresh must stay reachable under overload --
            # they are how an operator sees the overload)
            res = self.gateway.resilience
            if res is not None:
                client = self.headers.get(CLIENT_HEADER) or self.client_address[0]
                admit = res.admission.admit(client)
            else:
                admit = contextlib.nullcontext()
            with admit:
                if self.path == "/v1/query_many":
                    self._answer_query_many(data)
                elif self.path == "/v1/route":
                    self._answer_route(data)
                else:
                    self._answer_query(data)
        except wire.WireError as e:
            self._send_error(
                wire.ERROR_HTTP_STATUS.get(e.code, 400), e.code, str(e)
            )
        except GatewayError as e:
            self._send_gateway_error(e)
        except (KeyError, ValueError) as e:
            # engine-level rejections (unknown stencil, bad shapes, bad
            # selector names): the request is at fault, not the server
            msg = e.args[0] if e.args else str(e)
            self._send_error(400, "bad_request", str(msg))
        except BrokenPipeError:  # client went away mid-answer
            pass
        except Exception as e:  # noqa: BLE001 - boundary: never leak a traceback
            self._send_error(500, "internal", f"{type(e).__name__}: {e}")
        finally:
            _CLIENT_BUCKET.reset(client_token)
            route = self._route()
            dt = time.perf_counter() - t0
            _M_REQUESTS.labels(route=route).inc()
            _M_REQUEST_SECONDS.labels(route=route).observe(dt)
            status = getattr(self, "_last_status", None)
            if status is not None and not _REG.disabled:
                gw = self.gateway
                gw.slo.record(route, dt, ok=status < 500)
                if gw.exemplars is not None and (
                    route in _EXEMPLAR_ROUTES or status >= 400
                ):
                    tid = self._ex_tid or _clean_trace_id(
                        self.headers.get(TRACE_HEADER)
                    )
                    gw.exemplars.offer(
                        route, tid, dt, status,
                        code=self._ex_code, trace=self._ex_tree,
                    )


class GatewayHTTPServer(ThreadingHTTPServer):
    """Threaded HTTP front end over one :class:`Gateway` (stdlib only).

    One thread per connection; threads answering the same artifact
    rendezvous inside that artifact's ``CodesignServer`` microbatch.
    ``daemon_threads`` keeps shutdown prompt."""

    daemon_threads = True

    def __init__(self, address, gateway: Gateway):
        super().__init__(address, _Handler)
        self.gateway = gateway


def serve_http(
    gateway: Gateway, host: str = "127.0.0.1", port: int = 0
) -> GatewayHTTPServer:
    """Bind (``port=0`` picks a free one -- see ``server_address``) and
    return the server; the caller drives ``serve_forever()``, typically on
    a daemon thread (tests, benchmarks) or the main thread (the CLI)."""
    return GatewayHTTPServer((host, port), gateway)
