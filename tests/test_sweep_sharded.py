"""Sharded (shard_map) sweep engine vs the single-device JAX engine.

The sharded engine runs the *same* fused time-model body per shard, so the
bar is **bit-identity** with :func:`repro.core.sweep.sweep_cells` -- not a
tolerance -- for every padding regime (H not divisible by devices x chunk,
H smaller than the device count) and every `devices=` selection. The CI
sharded lane runs this file under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` so the mesh is a
real 8-way partition; on a plain host the same tests exercise the 1-device
mesh (the degenerate but still shard_map-compiled path), and a subprocess
test forces the 8-device view regardless.
"""

import subprocess
import sys

import numpy as np
import pytest

from repro.core import MAXWELL, MAXWELL_GPU, STENCILS, codesign, enumerate_hw_space
from repro.core import sweep
from repro.core.codesign import _resolve_engine
from repro.core.solver import LATTICE_2D
from repro.core.workload import paper_workload

pytestmark = pytest.mark.skipif(not sweep.HAVE_JAX, reason="jax not installed")


def small_hw(step=16):
    return enumerate_hw_space(MAXWELL, max_area=650.0).downsample(step)


def hw_cols(hw):
    return hw.n_sm, hw.n_v, hw.m_sm


SIZES_2D = np.array([[4096, 4096, 1, 1024], [2048, 2048, 1, 512]], np.float64)


def test_sharded_bit_identical_paper_sweep():
    """Full six-stencil paper workload: the sharded driver path must equal
    the single-device engine bit-for-bit (times AND argmin indices)."""
    wl = paper_workload()
    hw = small_hw(step=24)
    res_jax = codesign(wl, hw=hw, engine="jax")
    res_sh = codesign(wl, hw=hw, engine="sharded")
    np.testing.assert_array_equal(res_sh.cell_time, res_jax.cell_time)
    np.testing.assert_array_equal(res_sh.cell_tile_idx, res_jax.cell_tile_idx)


@pytest.mark.parametrize("chunk", [None, 0, 7, 64])
def test_sharded_padding_is_invisible(chunk):
    """H deliberately not divisible by devices x chunk: the pad rows must
    never leak into results, for chunked and unchunked shard programs."""
    st = STENCILS["jacobi2d"]
    hw = small_hw(step=13)  # 394 points: not a multiple of 8 x any chunk
    t_ref, i_ref = sweep.sweep_cells(
        st, MAXWELL_GPU, SIZES_2D, *hw_cols(hw), LATTICE_2D, chunk
    )
    t, i = sweep.sweep_cells_sharded(
        st, MAXWELL_GPU, SIZES_2D, *hw_cols(hw), LATTICE_2D, chunk
    )
    np.testing.assert_array_equal(t, t_ref)
    np.testing.assert_array_equal(i, i_ref)


@pytest.mark.parametrize("n_hw", [1, 3, 7])
def test_sharded_tiny_hardware_spaces(n_hw):
    """H < devices (under the CI 8-device lane) and H < chunk: every
    device still gets a full-shaped shard via padding; results drop it."""
    st = STENCILS["jacobi2d"]
    hw = small_hw(step=16)
    cols = tuple(c[:n_hw] for c in hw_cols(hw))
    t_ref, i_ref = sweep.sweep_cells(
        st, MAXWELL_GPU, SIZES_2D, *cols, LATTICE_2D, 5
    )
    t, i = sweep.sweep_cells_sharded(
        st, MAXWELL_GPU, SIZES_2D, *cols, LATTICE_2D, 5
    )
    assert t.shape == (SIZES_2D.shape[0], n_hw)
    np.testing.assert_array_equal(t, t_ref)
    np.testing.assert_array_equal(i, i_ref)


def test_sharded_empty_hardware_space():
    st = STENCILS["jacobi2d"]
    empty = np.empty(0)
    t, i = sweep.sweep_cells_sharded(
        st, MAXWELL_GPU, SIZES_2D, empty, empty, empty, LATTICE_2D
    )
    assert t.shape == (2, 0) and i.shape == (2, 0)


def test_sharded_devices_knob():
    """devices= as an int prefix and as an explicit device list must agree
    with the all-devices default; out-of-range counts are rejected."""
    import jax

    st = STENCILS["jacobi2d"]
    hw = small_hw(step=16)
    t_ref, i_ref = sweep.sweep_cells_sharded(
        st, MAXWELL_GPU, SIZES_2D, *hw_cols(hw), LATTICE_2D
    )
    for devices in (1, len(jax.devices()), list(jax.devices())):
        t, i = sweep.sweep_cells_sharded(
            st, MAXWELL_GPU, SIZES_2D, *hw_cols(hw), LATTICE_2D, devices=devices
        )
        np.testing.assert_array_equal(t, t_ref)
        np.testing.assert_array_equal(i, i_ref)
    with pytest.raises(ValueError, match="out of range"):
        sweep.sweep_cells_sharded(
            st, MAXWELL_GPU, SIZES_2D, *hw_cols(hw), LATTICE_2D,
            devices=len(jax.devices()) + 1,
        )


def test_engine_auto_promotes_on_multi_device(monkeypatch):
    """auto -> sharded iff >1 device; -> jax on one device; -> numpy below
    the compile-amortization floor or without jax."""
    monkeypatch.setattr(sweep, "device_count", lambda: 8)
    assert _resolve_engine("auto", 1000) == "sharded"
    monkeypatch.setattr(sweep, "device_count", lambda: 1)
    assert _resolve_engine("auto", 1000) == "jax"
    assert _resolve_engine("auto", 3) == "numpy"  # tiny space: no compile
    monkeypatch.setattr(sweep, "HAVE_JAX", False)
    assert _resolve_engine("auto", 1000) == "numpy"


def test_devices_knob_implies_mesh_engine():
    """devices= promotes auto to sharded (even below the numpy floor --
    an explicit mesh request wins) and is rejected, not silently ignored,
    by non-mesh engines."""
    assert _resolve_engine("auto", 1000, devices=4) == "sharded"
    assert _resolve_engine("auto", 3, devices=1) == "sharded"
    assert _resolve_engine("sharded", 1000, devices=4) == "sharded"
    for eng in ("jax", "numpy"):
        with pytest.raises(ValueError, match="devices"):
            _resolve_engine(eng, 1000, devices=2)
    wl = paper_workload(["jacobi2d"])
    with pytest.raises(ValueError, match="devices"):
        codesign(wl, hw=small_hw(step=64), engine="numpy", devices=1)
    res_auto = codesign(wl, hw=small_hw(step=64), engine="auto", devices=1)
    res_jax = codesign(wl, hw=small_hw(step=64), engine="jax")
    np.testing.assert_array_equal(res_auto.cell_time, res_jax.cell_time)


def test_engine_sharded_explicit_requires_jax(monkeypatch):
    monkeypatch.setattr(sweep, "HAVE_JAX", False)
    wl = paper_workload(["jacobi2d"])
    with pytest.raises(ModuleNotFoundError, match="sharded"):
        codesign(wl, hw=small_hw(step=64), engine="sharded")


def test_sharded_matches_numpy_oracle_reductions():
    """Workload-level reductions through the full driver stack agree with
    the float64 oracle within the cross-engine noise bound."""
    wl = paper_workload(["heat2d", "heat3d"], name="sharded-parity")
    hw = small_hw(step=48)
    res_np = codesign(wl, hw=hw, engine="numpy")
    res_sh = codesign(wl, hw=hw, engine="sharded")
    np.testing.assert_allclose(
        res_sh.weighted_time(), res_np.weighted_time(), rtol=1e-5
    )
    np.testing.assert_allclose(res_sh.gflops(), res_np.gflops(), rtol=1e-5)


_FORCED_8DEV_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax
assert jax.device_count() == 8, jax.device_count()
from repro.core import MAXWELL, codesign, enumerate_hw_space
from repro.core.codesign import _resolve_engine
from repro.core.workload import paper_workload

assert _resolve_engine("auto", 1000) == "sharded"
wl = paper_workload(["jacobi2d", "heat3d"], name="forced8")
hw = enumerate_hw_space(MAXWELL, max_area=650.0).downsample(32)
res_jax = codesign(wl, hw=hw, engine="jax")
res_sh = codesign(wl, hw=hw, engine="sharded")
assert np.array_equal(res_sh.cell_time, res_jax.cell_time)
assert np.array_equal(res_sh.cell_tile_idx, res_jax.cell_tile_idx)
print("FORCED8_OK")
"""


@pytest.mark.slow
def test_sharded_bit_identical_under_forced_8_devices(subprocess_env):
    """End-to-end 8-way mesh regardless of the host: a subprocess forces
    the host-device count before jax initializes (XLA locks devices at
    import, so this cannot be tested in-process once jax is loaded)."""
    env = subprocess_env
    env.pop("XLA_FLAGS", None)  # the script sets its own device count
    out = subprocess.run(
        [sys.executable, "-c", _FORCED_8DEV_SCRIPT],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, out.stderr
    assert "FORCED8_OK" in out.stdout
