"""Gradient-2D: central-difference gradient magnitude.
out = sqrt(((e-w)/2)^2 + ((s-n)/2)^2)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .stencil_common import stencil2d_call

NAME = "gradient2d"
DIMS = 2
HALO = 1
FLOPS_PER_POINT = 9.0


def update(ext: jax.Array, h: int) -> jax.Array:
    n = ext[: -2 * h, h:-h]
    s = ext[2 * h :, h:-h]
    w = ext[h:-h, : -2 * h]
    e = ext[h:-h, 2 * h :]
    gx = 0.5 * (e - w)
    gy = 0.5 * (s - n)
    return jnp.sqrt(gx * gx + gy * gy)


def step(x, block_rows=None, interpret=None):
    return stencil2d_call(x, update, HALO, block_rows, interpret)
