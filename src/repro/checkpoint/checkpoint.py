"""Checkpointing substrate.

Design goals (the fault-tolerance contract of the trainer):

* **atomic**: a checkpoint directory is staged as ``step_N.tmp`` and
  ``os.rename``d into place -- a crash mid-write can never produce a
  half-readable "latest" checkpoint;
* **mesh-shape-agnostic**: leaves are saved as full logical arrays (npy)
  plus a json manifest of the tree structure; restore `device_put`s into
  *whatever sharding the new mesh prescribes* -- this is what makes elastic
  restarts (resume on a different chip count) work;
* **async**: `AsyncCheckpointer` snapshots to host memory synchronously
  (cheap) and does the disk I/O on a background thread, so the train loop
  stalls for milliseconds, not seconds;
* **self-pruning**: keeps the last ``keep`` checkpoints.

On a real multi-host fleet the np.save calls would write per-host shards to
a distributed store; the manifest/atomicity/resharding logic is unchanged.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

__all__ = [
    "save_checkpoint",
    "restore_checkpoint",
    "latest_step",
    "AsyncCheckpointer",
]

_MANIFEST = "manifest.json"


def _flatten(tree: Any):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save_checkpoint(directory: str, step: int, tree: Any, extra: Optional[Dict] = None) -> str:
    """Write atomically; returns the final path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves, treedef = _flatten(tree)
    for i, leaf in enumerate(leaves):
        np.save(os.path.join(tmp, f"leaf_{i:05d}.npy"), np.asarray(leaf))
    manifest = {
        "step": step,
        "n_leaves": len(leaves),
        "treedef": str(treedef),
        "extra": extra or {},
        "dtypes": [str(np.asarray(l).dtype) for l in leaves],
        "shapes": [list(np.asarray(l).shape) for l in leaves],
    }
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(name.split("_")[1])
        for name in os.listdir(directory)
        if name.startswith("step_") and not name.endswith(".tmp")
    ]
    return max(steps) if steps else None


def restore_checkpoint(
    directory: str,
    target: Any,
    step: Optional[int] = None,
    shardings: Any = None,
) -> Tuple[Any, int, Dict]:
    """Restore into the structure of ``target``; reshard onto ``shardings``
    (a pytree of jax.sharding.Sharding or None -> host arrays)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, _MANIFEST)) as f:
        manifest = json.load(f)
    leaves, treedef = _flatten(target)
    if manifest["n_leaves"] != len(leaves):
        raise ValueError(
            f"checkpoint has {manifest['n_leaves']} leaves, target has {len(leaves)}"
        )
    loaded = [
        np.load(os.path.join(path, f"leaf_{i:05d}.npy")) for i in range(len(leaves))
    ]
    restored = jax.tree_util.tree_unflatten(treedef, loaded)
    if shardings is not None:
        flat_sh = treedef.flatten_up_to(shardings)
        loaded = [
            jax.device_put(leaf, sh) if sh is not None else leaf
            for leaf, sh in zip(loaded, flat_sh)
        ]
        restored = jax.tree_util.tree_unflatten(treedef, loaded)
    return restored, step, manifest.get("extra", {})


def _prune(directory: str, keep: int) -> None:
    steps = sorted(
        int(n.split("_")[1])
        for n in os.listdir(directory)
        if n.startswith("step_") and not n.endswith(".tmp")
    )
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"), ignore_errors=True)


class AsyncCheckpointer:
    """Snapshot-to-host synchronously, write-to-disk on a worker thread."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def save(self, step: int, tree: Any, extra: Optional[Dict] = None) -> None:
        self.wait()  # one in-flight write at a time
        # synchronous host snapshot (device -> host copy, then we're free)
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)

        def work():
            try:
                save_checkpoint(self.directory, step, host_tree, extra)
                _prune(self.directory, self.keep)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()
