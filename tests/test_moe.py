"""MoE routing: ample-capacity output == naive per-expert reference;
capacity bounds; aux loss behaviour."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.models.layers import mlp
from repro.models.moe import moe_apply, moe_init


def _cfg(capacity_factor=None, top_k=None):
    cfg = get_arch("mixtral-8x22b").reduced()
    moe = cfg.moe
    if capacity_factor is not None:
        moe = dataclasses.replace(moe, capacity_factor=capacity_factor)
    if top_k is not None:
        moe = dataclasses.replace(moe, top_k=top_k)
    return dataclasses.replace(cfg, moe=moe)


def _naive_moe(params, cfg, x):
    """Reference: loop over experts, dense masks, no capacity limit."""
    m = cfg.moe
    b, s, d = x.shape
    xf = x.reshape(-1, d)
    logits = (xf @ params["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    gates, idx = jax.lax.top_k(probs, m.top_k)
    gates = gates / gates.sum(-1, keepdims=True)
    y = jnp.zeros_like(xf)
    for e in range(m.n_experts):
        pe = jax.tree.map(lambda w: w[e], params["experts"])
        fe = mlp(pe, xf, cfg.act)
        w_e = jnp.where(idx == e, gates, 0.0).sum(-1)[:, None]
        y = y + fe * w_e.astype(xf.dtype)
    if m.n_shared:
        y = y + mlp(params["shared"], xf, cfg.act)
    return y.reshape(b, s, d)


def test_moe_matches_naive_with_ample_capacity():
    cfg = _cfg(capacity_factor=64.0)  # capacity >= group size: dropless
    params = moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 16, cfg.d_model), jnp.float32)
    y, aux = moe_apply(params, cfg, x)
    y_ref = _naive_moe(params, cfg, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=2e-4, atol=2e-4)
    assert float(aux) > 0.0


def test_moe_shared_expert_path():
    cfg = get_arch("deepseek-v3-671b").reduced()
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=64.0)
    )
    params = moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model), jnp.float32)
    y, _ = moe_apply(params, cfg, x)
    y_ref = _naive_moe(params, cfg, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=2e-4, atol=2e-4)


def test_capacity_drops_tokens_not_nans():
    cfg = _cfg(capacity_factor=0.25)  # aggressive: forces drops
    params = moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model), jnp.float32)
    y, aux = moe_apply(params, cfg, x)
    assert np.all(np.isfinite(np.asarray(y)))
    # dropped tokens contribute zero; output norm below dropless output norm
    cfg2 = _cfg(capacity_factor=64.0)
    y2, _ = moe_apply(params, cfg2, x)
    assert float(jnp.linalg.norm(y)) <= float(jnp.linalg.norm(y2)) + 1e-3


def test_decode_single_token_group():
    cfg = _cfg(capacity_factor=8.0)
    params = moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(3), (8, 1, cfg.d_model), jnp.float32)
    y, _ = moe_apply(params, cfg, x)  # S==1: batch routed as one group
    y_ref = _naive_moe(params, cfg, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=2e-4, atol=2e-4)


def test_aux_loss_uniform_router_is_minimal():
    """A perfectly uniform router should give aux ~= weight (its minimum)."""
    cfg = _cfg()
    params = moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    params = dict(params, router=jnp.zeros_like(params["router"]))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model), jnp.float32)
    _, aux = moe_apply(params, cfg, x)
    # uniform probs: E * sum(f_e * 1/E) * w = w (f sums to 1)
    assert abs(float(aux) - cfg.moe.router_aux_weight) < 2e-3
