"""Serving: prefill+decode vs full-forward references across cache kinds."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models import forward, init_model
from repro.serve import generate, init_caches, make_decode_step, make_prefill
from repro.serve.kvcache import cache_bytes

# multi-second jit compiles: the fast CI lane deselects these (-m "not slow");
# the weekly scheduled lane (and a bare local `pytest`) still runs them
pytestmark = pytest.mark.slow


def _greedy_reference(params, cfg, tokens, steps):
    """Teacher-forced rollout with full recompute each step (no cache)."""
    toks = tokens
    out = []
    for _ in range(steps):
        logits, _, _ = forward(params, cfg, {"tokens": toks})
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        out.append(nxt)
        toks = jnp.concatenate([toks, nxt[:, None]], axis=1)
    return jnp.stack(out, axis=1)


@pytest.mark.parametrize("arch", ["llama3-8b", "mamba2-780m", "mixtral-8x22b"])
def test_generate_matches_cacheless_reference(arch):
    cfg = get_arch(arch).reduced()
    if cfg.moe:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=64.0)
        )
    params = init_model(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab)
    steps = 5
    want = _greedy_reference(params, cfg, toks, steps)
    got = generate(params, cfg, {"tokens": toks}, steps)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_whisper_prefill_decode():
    cfg = get_arch("whisper-medium").reduced()
    params = init_model(cfg, jax.random.PRNGKey(0))
    b = 2
    frames = jax.random.normal(
        jax.random.PRNGKey(2), (b, cfg.n_frontend_tokens, cfg.d_model)
    ) * 0.05
    toks = jax.random.randint(jax.random.PRNGKey(3), (b, 6), 0, cfg.vocab)
    batch = {"tokens": toks, "frontend": frames}

    full, _, _ = forward(params, cfg, batch)
    prefill = make_prefill(cfg, max_len=16)
    last, caches = prefill(params, batch)
    np.testing.assert_allclose(
        np.asarray(last), np.asarray(full[:, -1]), rtol=2e-4, atol=2e-4
    )
    # decode continues with cross-attention served from the cache
    decode = make_decode_step(cfg)
    nxt = jnp.argmax(last, -1).astype(jnp.int32)[:, None]
    logits, caches = decode(params, nxt, caches, jnp.int32(6))
    assert logits.shape == (b, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_vlm_generate_runs():
    cfg = get_arch("qwen2-vl-2b").reduced()
    params = init_model(cfg, jax.random.PRNGKey(0))
    b = 2
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (b, 8), 0, cfg.vocab),
        "frontend": jax.random.normal(
            jax.random.PRNGKey(2), (b, cfg.n_frontend_tokens, cfg.d_model)
        )
        * 0.05,
    }
    out = generate(params, cfg, batch, steps=3)
    assert out.shape == (b, 3)
    assert np.all((np.asarray(out) >= 0) & (np.asarray(out) < cfg.vocab))


def test_mla_cache_is_compressed():
    """DeepSeek's latent cache must be far smaller than a dense KV cache."""
    cfg = get_arch("deepseek-v3-671b")
    mla_bytes = cache_bytes(cfg, batch=1, max_len=1024)
    dense_kv = (
        cfg.n_layers * 2 * 1024 * cfg.n_kv_heads * cfg.head_dim_ * 2  # bf16
    )
    assert mla_bytes < dense_kv / 20  # ~28x structural shrink

def test_swa_cache_is_bounded():
    cfg = get_arch("mixtral-8x22b")
    short = cache_bytes(cfg, batch=1, max_len=4096)
    long = cache_bytes(cfg, batch=1, max_len=524288)
    assert long == short  # ring buffer: length never exceeds the window
