"""Shared Pallas machinery for the six stencil kernels (the paper's
workload, §IV.A), adapted to the TPU memory hierarchy.

GPU -> TPU adaptation (DESIGN.md, "Hardware adaptation"): the paper's
hybrid-hexagonal GPU tiling streams a (t_S1 x t_S2) tile + halo through
*shared memory* with one thread per S2 column. The TPU-native equivalent
keeps the same software-managed-memory insight but re-blocks for VMEM and
the VPU lane layout:

* the array is blocked along the *leading* spatial dimension into bands of
  ``block_rows`` rows; the trailing dimension stays whole (TPU lanes want
  the last dim contiguous and 128-aligned);
* the halo is realized with *neighbor-band BlockSpecs*: each grid step is
  given three aliased views of the input -- the previous, current and next
  band -- so the kernel never performs unaligned HBM reads; the up/down
  halo rows are the last/first rows of the neighbor bands;
* boundary cells (Dirichlet: borders are copied through) are handled by a
  global-row/column mask computed from the grid position, which also makes
  partially-padded trailing bands safe;
* ``block_rows`` is the software parameter of the codesign problem (the
  analogue of the paper's tile sizes): :func:`plan_block_rows` solves the
  same footprint-feasibility constraint as eqs. (9)/(11) -- resident
  buffers must fit the VMEM budget -- and is what `repro.core` codesign
  selects when it tunes the kernels.

All kernels come in (pallas, reference) pairs; `tests/test_kernels.py`
sweeps shapes/dtypes and asserts allclose in interpret mode (this container
has no TPU; interpret=True executes the same kernel body on CPU).
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = [
    "stencil2d_call",
    "stencil3d_call",
    "plan_block_rows",
    "time_loop",
    "on_tpu",
]

#: TPU v5e has ~16 MiB of VMEM per core; leave headroom for double buffering.
VMEM_BUDGET_BYTES = 8 * 1024 * 1024


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def plan_block_rows(
    shape, dtype, vmem_bytes: int = VMEM_BUDGET_BYTES, min_rows: int = 8
) -> int:
    """Choose the band height: the eq.-(9)/(11) feasibility solve for TPU.

    Resident working set = 3 input bands + 1 output band (+ halo rows), all
    of width ``prod(shape[1:])``; pick the largest power-of-two row count
    whose working set fits the VMEM budget.
    """
    row_bytes = int(jnp.dtype(dtype).itemsize)
    for d in shape[1:]:
        row_bytes *= int(d)
    rows = shape[0]
    # 3 in-bands + 1 out-band, +2 halo rows of slack
    while rows > min_rows and (3 * rows + rows + 2) * row_bytes > vmem_bytes:
        rows //= 2
    return max(1, min(rows, shape[0]))


def _row_mask(i, block_rows: int, n_rows: int, width: int, halo: int):
    """Boolean (block_rows, width) mask of *boundary* cells for this band."""
    gstart = i * block_rows
    rows = gstart + jax.lax.broadcasted_iota(jnp.int32, (block_rows, width), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (block_rows, width), 1)
    return (
        (rows < halo)
        | (rows >= n_rows - halo)
        | (cols < halo)
        | (cols >= width - halo)
    )


def _stencil2d_kernel(
    prev_ref, cur_ref, nxt_ref, out_ref, *, update: Callable, block_rows: int,
    n_rows: int, halo: int
):
    cur = cur_ref[...]
    width = cur.shape[1]
    # halo-extended band: last rows of prev band + cur + first rows of next.
    # Accumulate in f32 (standard TPU practice for bf16 data), store narrow.
    ext = jnp.concatenate(
        [prev_ref[...][-halo:, :], cur, nxt_ref[...][:halo, :]], axis=0
    ).astype(jnp.float32)
    # column halo via edge replication (border cells are masked anyway)
    ext = jnp.pad(ext, ((0, 0), (halo, halo)), mode="edge")
    new = update(ext, halo)  # (block_rows, width)
    i = pl.program_id(0)
    boundary = _row_mask(i, block_rows, n_rows, width, halo)
    out_ref[...] = jnp.where(boundary, cur, new).astype(out_ref.dtype)


def stencil2d_call(
    x: jax.Array,
    update: Callable,
    halo: int = 1,
    block_rows: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """One stencil step on a 2D array via `pl.pallas_call`.

    ``update(ext, halo)`` receives the halo-extended band (rows+2h, cols+2h)
    and must return the updated interior (rows, cols).
    """
    n_rows, width = x.shape
    if block_rows is None:
        block_rows = plan_block_rows(x.shape, x.dtype)
    block_rows = min(block_rows, n_rows)
    grid = (pl.cdiv(n_rows, block_rows),)
    nblk = grid[0]
    if interpret is None:
        interpret = not on_tpu()
    spec = functools.partial(pl.BlockSpec, (block_rows, width))
    kernel = functools.partial(
        _stencil2d_kernel,
        update=update,
        block_rows=block_rows,
        n_rows=n_rows,
        halo=halo,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            spec(lambda i: (jnp.maximum(i - 1, 0), 0)),  # prev band
            spec(lambda i: (i, 0)),  # current band
            spec(lambda i: (jnp.minimum(i + 1, nblk - 1), 0)),  # next band
        ],
        out_specs=spec(lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x, x, x)


def _stencil3d_kernel(
    prev_ref, cur_ref, nxt_ref, out_ref, *, update: Callable, block_rows: int,
    n_rows: int, halo: int
):
    cur = cur_ref[...]
    _, h, w = cur.shape
    ext = jnp.concatenate(
        [prev_ref[...][-halo:], cur, nxt_ref[...][:halo]], axis=0
    ).astype(jnp.float32)
    ext = jnp.pad(ext, ((0, 0), (halo, halo), (halo, halo)), mode="edge")
    new = update(ext, halo)  # (block_rows, h, w)
    i = pl.program_id(0)
    gstart = i * block_rows
    d_ids = gstart + jax.lax.broadcasted_iota(jnp.int32, (block_rows, h, w), 0)
    h_ids = jax.lax.broadcasted_iota(jnp.int32, (block_rows, h, w), 1)
    w_ids = jax.lax.broadcasted_iota(jnp.int32, (block_rows, h, w), 2)
    boundary = (
        (d_ids < halo)
        | (d_ids >= n_rows - halo)
        | (h_ids < halo)
        | (h_ids >= h - halo)
        | (w_ids < halo)
        | (w_ids >= w - halo)
    )
    out_ref[...] = jnp.where(boundary, cur, new).astype(out_ref.dtype)


def stencil3d_call(
    x: jax.Array,
    update: Callable,
    halo: int = 1,
    block_rows: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """One stencil step on a 3D array, blocked along the leading dim."""
    n_rows, h, w = x.shape
    if block_rows is None:
        block_rows = plan_block_rows(x.shape, x.dtype)
    block_rows = min(block_rows, n_rows)
    grid = (pl.cdiv(n_rows, block_rows),)
    nblk = grid[0]
    if interpret is None:
        interpret = not on_tpu()
    spec = functools.partial(pl.BlockSpec, (block_rows, h, w))
    kernel = functools.partial(
        _stencil3d_kernel,
        update=update,
        block_rows=block_rows,
        n_rows=n_rows,
        halo=halo,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            spec(lambda i: (jnp.maximum(i - 1, 0), 0, 0)),
            spec(lambda i: (i, 0, 0)),
            spec(lambda i: (jnp.minimum(i + 1, nblk - 1), 0, 0)),
        ],
        out_specs=spec(lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x, x, x)


def time_loop(step: Callable, x: jax.Array, steps: int) -> jax.Array:
    """Apply ``step`` ``steps`` times (the stencil time dimension T)."""
    if steps == 1:
        return step(x)
    return jax.lax.fori_loop(0, steps, lambda _, v: step(v), x)
