"""Integer non-linear solver for the per-cell tile-size problems.

The paper solves each per-(stencil, size) sub-problem (10 integer variables,
non-convex rational objective with floor/ceil) with bonmin, averaging 19 s
per instance (§IV.B) -- 7 to 24 hours per sweep. We replace bonmin with an
*exact* vectorized lattice sweep + local integer refinement:

* the feasible tile lattice is small once the paper's alignment constraints
  (t_S2 mult. 32, t_T even, k <= 32, footprint <= M_SM/k) are applied;
* `numpy` evaluates the full (hardware x lattice) cross product in chunked
  broadcasts -- thousands of hardware points x ~2k tile candidates per cell
  in milliseconds, so the whole Fig.-3 sweep takes minutes, not hours;
* a coordinate-descent refinement then polishes the best lattice point over
  unit integer steps, so reported optima are locally exact, not just
  lattice-exact.

This is the same eq.-(18) decomposition the paper uses; only the inner
solver is stronger (global-on-lattice instead of a local NLP solve).

This module is the **NumPy reference oracle**: the compiled JAX engine in
:mod:`repro.core.sweep` must match its argmins cell-by-cell (see
``tests/test_sweep.py``), and ``benchmarks/bench_sweep.py`` tracks the
wall-time gap between the two. Keep it simple and exact rather than fast.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, Tuple

import numpy as np

from .timemodel import GPUSpec, ProblemSize, StencilSpec, stencil_time

__all__ = [
    "TileLattice",
    "LATTICE_2D",
    "LATTICE_3D",
    "solve_cell",
    "refine_point",
]


@dataclasses.dataclass(frozen=True)
class TileLattice:
    """Candidate tile-size values per software parameter."""

    t_s1: Tuple[int, ...]
    t_s2: Tuple[int, ...]
    t_t: Tuple[int, ...]
    k: Tuple[int, ...]
    t_s3: Tuple[int, ...] = (1,)

    def grid(self) -> Dict[str, np.ndarray]:
        """Flattened meshgrid, one (L,) array per parameter."""
        combos = np.array(
            list(
                itertools.product(self.t_s1, self.t_s2, self.t_t, self.k, self.t_s3)
            ),
            dtype=np.float64,
        )
        return {
            "t_s1": combos[:, 0],
            "t_s2": combos[:, 1],
            "t_t": combos[:, 2],
            "k": combos[:, 3],
            "t_s3": combos[:, 4],
        }

    @property
    def size(self) -> int:
        return (
            len(self.t_s1) * len(self.t_s2) * len(self.t_t) * len(self.k) * len(self.t_s3)
        )


LATTICE_2D = TileLattice(
    t_s1=(1, 2, 4, 8, 16, 32, 64),
    t_s2=(32, 64, 128, 256, 512, 1024),
    t_t=(2, 4, 8, 16, 32, 64, 128),
    k=(1, 2, 4, 8, 16, 32),
)

LATTICE_3D = TileLattice(
    t_s1=(1, 2, 4, 8, 16, 32),
    t_s2=(32, 64, 128, 256),
    t_t=(2, 4, 8, 16, 32, 64),
    k=(1, 2, 4, 8, 16),
    t_s3=(1, 2, 4, 8),
)


def solve_cell(
    st: StencilSpec,
    gpu: GPUSpec,
    size: ProblemSize,
    n_sm: np.ndarray,
    n_v: np.ndarray,
    m_sm: np.ndarray,
    lattice: TileLattice | None = None,
    chunk: int = 512,
) -> Tuple[np.ndarray, np.ndarray]:
    """min over tile sizes of T_alg, for every hardware point.

    Returns ``(best_time (H,), best_lattice_index (H,))``; infeasible
    hardware points (no feasible tile) get +inf / -1.
    """
    if lattice is None:
        lattice = LATTICE_3D if st.dims == 3 else LATTICE_2D
    g = lattice.grid()
    n_sm = np.asarray(n_sm, np.float64).ravel()
    n_v = np.asarray(n_v, np.float64).ravel()
    m_sm = np.asarray(m_sm, np.float64).ravel()
    H = n_sm.shape[0]
    if chunk <= 0:  # same contract as the jax engine: no chunking
        chunk = max(1, H)
    best_t = np.full(H, np.inf)
    best_i = np.full(H, -1, dtype=np.int64)
    for lo in range(0, H, chunk):
        hi = min(lo + chunk, H)
        t = stencil_time(
            st,
            gpu,
            size,
            n_sm[lo:hi, None],
            n_v[lo:hi, None],
            m_sm[lo:hi, None],
            g["t_s1"][None, :],
            g["t_s2"][None, :],
            g["t_t"][None, :],
            g["k"][None, :],
            g["t_s3"][None, :],
        )
        idx = np.argmin(t, axis=1)
        tt = t[np.arange(hi - lo), idx]
        best_t[lo:hi] = tt
        best_i[lo:hi] = np.where(np.isfinite(tt), idx, -1)
    return best_t, best_i


def decode_index(lattice: TileLattice, index: int) -> Dict[str, int]:
    """Lattice index -> tile-size dict."""
    g = lattice.grid()
    return {kk: int(g[kk][index]) for kk in ("t_s1", "t_s2", "t_t", "k", "t_s3")}


_STEPS = {
    "t_s1": 1,
    "t_s2": 32,  # eq. (13): warps
    "t_t": 2,  # eq. (15): even (hybrid-hexagonal requirement)
    "k": 1,
    "t_s3": 1,
}


def refine_point(
    st: StencilSpec,
    gpu: GPUSpec,
    size: ProblemSize,
    hw: Tuple[float, float, float],
    sw0: Dict[str, int],
    max_rounds: int = 64,
) -> Tuple[float, Dict[str, int]]:
    """Coordinate descent over unit integer steps from a lattice optimum.

    Guarantees a locally-exact integer optimum (no neighbor within one
    aligned step improves). Used for the *reported* design points.
    """
    n_sm, n_v, m_sm = hw
    sw = dict(sw0)
    names = ["t_s1", "t_s2", "t_t", "k"] + (["t_s3"] if st.dims == 3 else [])

    def ev(s):
        return float(
            stencil_time(
                st, gpu, size, n_sm, n_v, m_sm,
                s["t_s1"], s["t_s2"], s["t_t"], s["k"], s["t_s3"],
            )
        )

    cur = ev(sw)
    for _ in range(max_rounds):
        improved = False
        for name in names:
            step = _STEPS[name]
            for delta in (step, -step):
                cand = dict(sw)
                cand[name] = max(step if name != "t_s1" else 1, cand[name] + delta)
                if cand[name] == sw[name]:
                    continue
                t = ev(cand)
                if t < cur:
                    cur, sw, improved = t, cand, True
        if not improved:
            break
    return cur, sw
