"""KV-cache construction, mirroring the stack's segment/slot structure.

Cache kinds per block:
* attention: k/v rings (full length, or ``window`` slots for SWA);
* MLA: the compressed latent ``ckv`` + shared rope key ``krope`` -- the
  per-token cache is r_kv + d_rope floats instead of 2*H*Dh (DeepSeek's
  ~28x cache shrink is structural here);
* SSD: constant-size conv window + state (this is why ssm/hybrid archs run
  long_500k: the "cache" does not grow with context);
* enc-dec decoders additionally get per-layer cross K/V (written once at
  prefill) -- ``enc_out`` itself is carried so prefill can compute them.

Leaves are stacked over segment repeats to match ``lax.scan``'s xs layout.
"""

from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..models.ssm import ssm_state_shapes
from ..models.transformer import segments

__all__ = ["init_caches", "cache_bytes"]


def _attn_cache(cfg: ArchConfig, batch: int, max_len: int, dtype):
    a = cfg.attn
    if a.kind == "mla":
        return {
            "ckv": jnp.zeros((batch, max_len, a.kv_lora_rank), dtype),
            "krope": jnp.zeros((batch, max_len, a.rope_head_dim), dtype),
            "idx": jnp.zeros((), jnp.int32),
        }
    length = min(max_len, a.window) if a.kind == "swa" and a.window else max_len
    kh, dh = cfg.n_kv_heads, cfg.head_dim_
    return {
        "k": jnp.zeros((batch, length, kh, dh), dtype),
        "v": jnp.zeros((batch, length, kh, dh), dtype),
        "idx": jnp.zeros((), jnp.int32),
    }


def _cross_cache(cfg: ArchConfig, batch: int, dtype):
    kh, dh = cfg.n_kv_heads, cfg.head_dim_
    n = cfg.n_frontend_tokens
    return {
        "k": jnp.zeros((batch, n, kh, dh), dtype),
        "v": jnp.zeros((batch, n, kh, dh), dtype),
        "idx": jnp.zeros((), jnp.int32),
    }


def _ssm_cache(cfg: ArchConfig, batch: int, dtype):
    return {k: jnp.zeros(v, dtype) for k, v in ssm_state_shapes(cfg, batch).items()}


def _stack_leaf(cache, reps: int):
    return jax.tree.map(lambda x: jnp.broadcast_to(x[None], (reps, *x.shape)), cache)


def init_caches(
    cfg: ArchConfig,
    batch: int,
    max_len: int,
    dtype=None,
    include_enc: bool = False,
) -> Dict:
    """Build the full cache pytree for ``forward``, zero-initialized.

    The tree mirrors the model's segment/slot structure: one ``seg{i}``
    entry per ``segments(cfg)`` group, each a tuple of per-slot dicts
    stacked over the segment's repeat count (``lax.scan`` xs layout).
    ``max_len`` bounds the ring buffers in *tokens* (SWA blocks clamp it
    to their window). ``include_enc=False`` (prefill): the enc-dec
    encoder output is not yet known; forward computes it and adds
    'enc_out' + cross K/V.
    """
    dtype = dtype or jnp.dtype(cfg.dtype)
    stack: Dict = {}
    for si, (pattern, reps) in enumerate(segments(cfg)):
        slots = []
        for mixer, _ffn in pattern:
            c: Dict = {}
            if mixer == "attn":
                c["mixer"] = _attn_cache(cfg, batch, max_len, dtype)
            else:
                c["mixer"] = _ssm_cache(cfg, batch, dtype)
            if cfg.enc_dec:
                c["cross"] = _cross_cache(cfg, batch, dtype)
            slots.append(_stack_leaf(c, reps))
        stack[f"seg{si}"] = tuple(slots)
    caches: Dict = {"stack": stack}
    if include_enc:
        caches["enc_out"] = jnp.zeros(
            (batch, cfg.n_frontend_tokens, cfg.d_model), dtype
        )
    return caches


def cache_bytes(cfg: ArchConfig, batch: int, max_len: int) -> int:
    """Analytic cache footprint in bytes, without allocating anything.

    Builds the exact cache pytree under ``jax.eval_shape`` (abstract
    values only) and sums ``prod(shape) * itemsize`` over the leaves, so
    it is always consistent with what :func:`init_caches` would really
    allocate -- MLA latents, SWA windows, SSD constant state and enc-dec
    cross K/V included. Consumers: the serving planner, the analytic
    roofline (``repro.core.lmtime.lm_roofline``'s decode KV traffic), and
    the LM codesign decode cells (``repro.core.lmcells``), which bake
    this number into their per-cell constants."""
    import math

    caches = jax.eval_shape(
        lambda: init_caches(cfg, batch, max_len, include_enc=cfg.enc_dec)
    )
    return sum(
        int(math.prod(l.shape)) * l.dtype.itemsize for l in jax.tree.leaves(caches)
    )
