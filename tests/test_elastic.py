"""Elastic scaling: a checkpoint written on one mesh restores onto a
different mesh shape (different data/model factorization) and training
continues. Runs in a subprocess with 8 fake devices."""

import os
import subprocess
import sys
import textwrap
import pytest

# multi-second jit compiles: the fast CI lane deselects these (-m "not slow");
# the weekly scheduled lane (and a bare local `pytest`) still runs them
pytestmark = pytest.mark.slow

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np
    from jax.sharding import Mesh
    from repro.configs import get_arch
    from repro.configs.base import ShapeSpec
    from repro.data.pipeline import DataConfig
    from repro.optim import AdamWConfig
    from repro.train import TrainConfig, Trainer, TrainerConfig

    cfg = get_arch("internlm2-1.8b").reduced()
    shape = ShapeSpec("tiny", 32, 8, "train")
    tcfg = TrainConfig(opt=AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=20))
    ckpt = os.environ["CKPT_DIR"]

    def mesh(shp, axes=("data", "model")):
        n = int(np.prod(shp))
        return Mesh(np.array(jax.devices()[:n]).reshape(shp), axes)

    # phase 1: train 6 steps on a (4, 2) mesh
    t1 = Trainer(cfg, shape, mesh((4, 2)), tcfg,
                 TrainerConfig(steps=6, ckpt_dir=ckpt, ckpt_every=3),
                 DataConfig(seed=7))
    out1 = t1.train()
    l1 = [float(x) for x in jax.tree.leaves(out1["state"]["params"])[0].ravel()[:4]]

    # phase 2: RESUME the same job on a (2, 4) mesh -- elastic reshape
    t2 = Trainer(cfg, shape, mesh((2, 4)), tcfg,
                 TrainerConfig(steps=10, ckpt_dir=ckpt, ckpt_every=3),
                 DataConfig(seed=7))
    out2 = t2.train()
    assert out2["step"] == 10, out2["step"]
    losses = [m["lm_loss"] for m in out2["metrics"]]
    assert all(np.isfinite(losses)), losses
    # the restored params came from the phase-1 checkpoint (same leading values)
    import numpy as np2
    print("ELASTIC_OK", out2["step"], len(out2["metrics"]))
    """
)


def test_elastic_restart_across_mesh_shapes(tmp_path):
    env = dict(os.environ)
    env["CKPT_DIR"] = str(tmp_path / "ckpt")
    env["PYTHONPATH"] = "src"
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True,
        text=True,
        env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=600,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "ELASTIC_OK 10" in proc.stdout
