#!/usr/bin/env python
"""Compare the newest benchmark entry against the previous one and fail
on large throughput regressions.

For each trajectory file (``BENCH_sweep.json``, ``BENCH_portfolio.json``
by default) the newest entry is matched against the most recent *earlier*
entry with the same ``(suite, smoke)`` signature, and every shared
``*_qps`` field is compared.  A field that dropped below
``old * (1 - threshold)`` (default threshold 25%) is a regression and the
script exits 1; everything else — missing files, empty trajectories, a
suite with no prior entry, non-numeric or absent fields — is reported and
tolerated, because a fresh clone or a brand-new suite is not a
regression.

Usage:
    python scripts/bench_regress.py [--threshold 0.25] [FILE ...]

The CI bench lane runs this non-blocking (continue-on-error): it is a
tripwire for eyeballs on the PR, not a merge gate — smoke-sized runs on
shared runners are too noisy to block on.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

DEFAULT_FILES = ["BENCH_sweep.json", "BENCH_portfolio.json"]
DEFAULT_THRESHOLD = 0.25


def _signature(entry):
    return (entry.get("suite"), bool(entry.get("smoke")))


def _qps_fields(entry):
    return {
        k: v
        for k, v in entry.items()
        if k.endswith("_qps") and isinstance(v, (int, float)) and v > 0
    }


def check_file(path, threshold):
    """Return a list of regression strings for one trajectory file."""
    if not os.path.exists(path):
        print(f"skip {path}: not found")
        return []
    try:
        with open(path) as f:
            entries = json.load(f)
    except (OSError, ValueError) as exc:
        print(f"skip {path}: unreadable ({exc})")
        return []
    if not isinstance(entries, list) or len(entries) < 2:
        print(f"skip {path}: fewer than 2 entries")
        return []

    newest = entries[-1]
    sig = _signature(newest)
    prev = next(
        (e for e in reversed(entries[:-1]) if _signature(e) == sig), None
    )
    if prev is None:
        print(f"skip {path}: no earlier entry for suite={sig[0]} smoke={sig[1]}")
        return []

    new_qps = _qps_fields(newest)
    old_qps = _qps_fields(prev)
    shared = sorted(set(new_qps) & set(old_qps))
    if not shared:
        print(f"skip {path}: no shared *_qps fields between newest entries")
        return []

    regressions = []
    for field in shared:
        old, new = old_qps[field], new_qps[field]
        delta_pct = 100.0 * (new - old) / old
        verdict = "ok"
        if new < old * (1.0 - threshold):
            verdict = "REGRESSION"
            regressions.append(
                f"{path}: {field} {old:.1f} -> {new:.1f} qps "
                f"({delta_pct:+.1f}%, limit -{threshold * 100:.0f}%)"
            )
        print(
            f"{verdict:>10}  {path} {field}: "
            f"{old:.1f} -> {new:.1f} qps ({delta_pct:+.1f}%)"
        )
    return regressions


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("files", nargs="*", default=None,
                    help="trajectory files (default: %s)" % " ".join(DEFAULT_FILES))
    ap.add_argument("--threshold", type=float, default=DEFAULT_THRESHOLD,
                    help="fractional qps drop that fails (default 0.25)")
    args = ap.parse_args(argv)
    if not 0.0 < args.threshold < 1.0:
        ap.error("--threshold must be in (0, 1)")

    files = args.files or DEFAULT_FILES
    regressions = []
    for path in files:
        regressions.extend(check_file(path, args.threshold))

    if regressions:
        print("\n%d regression(s):" % len(regressions))
        for r in regressions:
            print("  " + r)
        return 1
    print("\nno benchmark regressions")
    return 0


if __name__ == "__main__":
    sys.exit(main())
