"""Shared test fixtures."""

import os

import pytest


@pytest.fixture
def subprocess_env():
    """os.environ copy with src/ prepended to PYTHONPATH.

    Subprocess-spawning tests need this: pytest's ``pythonpath = ["src"]``
    config applies only in-process, so a bare-pytest run (no
    ``pip install -e``) would leave children unable to import ``repro``.
    """
    env = dict(os.environ)
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), os.pardir, "src"))
    prev = env.get("PYTHONPATH")
    env["PYTHONPATH"] = src + (os.pathsep + prev if prev else "")
    return env
