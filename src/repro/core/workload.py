"""Workload characterization (paper §II, §IV.A).

A workload is a set of (stencil, problem-size) cells with occurrence
frequencies. The paper's experiments use the six-stencil suite over

    SZ_S = {4096, 8192, 12288, 16384},  SZ_T = {1024, ..., 16384},
    SZ   = {(S, T) | S in SZ_S, T in SZ_T, T <= S}      (|SZ| = 16)

with uniform frequencies ("we assumed all six stencils equally likely, and
that each size combination also equally likely", §IV.B).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

from .timemodel import STENCILS, ProblemSize, StencilSpec

__all__ = [
    "WorkloadCell",
    "Workload",
    "paper_sizes",
    "paper_workload",
]

SZ_S = (4096, 8192, 12288, 16384)
SZ_T = (1024, 2048, 4096, 8192, 16384)


@dataclasses.dataclass(frozen=True)
class WorkloadCell:
    stencil: StencilSpec
    size: ProblemSize
    freq: float  # fr(c) * fr(c, Sz), already combined


@dataclasses.dataclass(frozen=True)
class Workload:
    """A frequency-weighted set of cells; eq. (17)'s objective is
    ``sum_cell freq * min_tiles T_alg(cell)`` (separability, eq. (18))."""

    name: str
    cells: Tuple[WorkloadCell, ...]

    def __post_init__(self):
        total = sum(c.freq for c in self.cells)
        if not 0.999 <= total <= 1.001:
            raise ValueError(f"cell frequencies sum to {total}, expected 1")

    @property
    def stencils(self) -> List[StencilSpec]:
        seen: Dict[str, StencilSpec] = {}
        for c in self.cells:
            seen.setdefault(c.stencil.name, c.stencil)
        return list(seen.values())


def paper_sizes(dims: int) -> List[ProblemSize]:
    """The 16-element SZ grid; for 3D stencils the three spatial extents are
    all S (the paper reuses the same SZ set for both classes)."""
    sizes = []
    for s in SZ_S:
        for t in SZ_T:
            if t <= s:
                sizes.append(
                    ProblemSize(s1=s, s2=s, t=t, s3=s if dims == 3 else 1)
                )
    assert len(sizes) == 16
    return sizes


def paper_workload(
    stencil_names: Sequence[str] | None = None, name: str = "paper-uniform"
) -> Workload:
    """Uniform-frequency workload over the chosen stencils (default: all six,
    as in Fig. 3 / §IV.B). Single-stencil workloads (Table II) are built by
    passing one name -- the §V.B 'workload sensitivity for free' trick."""
    names = list(stencil_names or STENCILS.keys())
    cells: List[WorkloadCell] = []
    for n in names:
        st = STENCILS[n]
        sizes = paper_sizes(st.dims)
        for sz in sizes:
            cells.append(WorkloadCell(st, sz, 1.0 / (len(names) * len(sizes))))
    return Workload(name=name, cells=tuple(cells))
