#!/usr/bin/env python
"""CI smoke lane for the predict -> measure -> refit -> serve loop.

End-to-end, through the actual CLI entry points (no test fixtures):

1. ``repro.measure.cli run``: execute the tile-parameterized Pallas
   stencils (interpret mode on CPU) over the smoke measurement grid and
   persist the timings as a ``kind: "measurement"`` artifact;
2. ``repro.measure.cli fit --synthetic``: fit model-generated timings and
   assert the fit **recovers the generating machine parameters** (the
   calibration acceptance property) by reloading the stored calibration;
3. ``repro.measure.cli fit``: refit from the real harness run and assert
   the reported per-stencil error improved;
4. ``repro.measure.cli build``: solve a tiny sweep on the calibrated
   hardware and store it;
5. serve the store through the HTTP gateway and assert the calibrated
   artifact's answers are **byte-identical** to the in-process oracle,
   routed both by ``{"calibration": <key>}`` and by the calibrated GPU
   name -- and that measurement/calibration manifests in the same store
   neither route queries nor make sweep selectors ambiguous.

Exit 0 and print PASS only if every check holds.

Usage: python scripts/measure_smoke.py [--store DIR] [--downsample N]
"""

from __future__ import annotations

import argparse
import os
import re
import subprocess
import sys
import tempfile

# runnable with or without `pip install -e .` (CI installs; dev may not)
_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
if os.path.isdir(_SRC) and _SRC not in sys.path:
    sys.path.insert(0, _SRC)

from repro.core.timemodel import GPUSpec, StencilSpec  # noqa: E402
from repro.measure.calibrate import RECOVERY_RTOL, CalibrationResult  # noqa: E402
from repro.service import (  # noqa: E402
    ArtifactStore,
    CodesignServer,
    GatewayClient,
    wire,
)
from repro.service.query import QueryRequest  # noqa: E402

MEASURE_CLI = [sys.executable, "-m", "repro.measure.cli"]
SERVICE_CLI = [sys.executable, "-m", "repro.service.cli"]


def _env() -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    env["PYTHONUNBUFFERED"] = "1"
    return env


def check(ok: bool, what: str) -> None:
    print(f"  {'ok' if ok else 'FAIL'}: {what}")
    if not ok:
        raise SystemExit(f"measure smoke failed at: {what}")


def _run(cmd, **kw):
    return subprocess.run(
        cmd, check=True, env=_env(), timeout=600, capture_output=True,
        text=True, **kw,
    )


def _key(stdout: str, kind: str) -> str:
    m = re.search(rf"{kind} ([0-9a-f]{{20}})", stdout)
    assert m, f"no {kind} key in output:\n{stdout}"
    return m.group(1)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--store", default=None, help="store dir (default: temp)")
    ap.add_argument("--downsample", type=int, default=48,
                    help="hw-space thinning for the calibrated build")
    args = ap.parse_args()
    root = args.store or tempfile.mkdtemp(prefix="measure-smoke-")

    print(f"[1/5] measurement run (Pallas interpret grid) under {root}")
    out = _run(MEASURE_CLI + ["run", "--store", root, "--repeats", "2"]).stdout
    print(out, end="")
    meas_key = _key(out, "measurement")

    print("[2/5] synthetic fit recovers the generating machine")
    out = _run(
        MEASURE_CLI + ["fit", "--store", root, "--synthetic", "--perturb", "0.5"]
    ).stdout
    syn_key = _key(out, "calibration")
    store = ArtifactStore(root)
    syn_art = store.get(syn_key)
    syn = CalibrationResult.from_payload(syn_art.payload)
    # --synthetic generated timings from a machine 50% off the datasheet
    # start; the fit must travel back to it (the stored truth)
    truth = syn_art.manifest["extra"]["synthetic_truth"]
    truth_gpu = GPUSpec(**truth["gpu"])
    truth_st = {n: StencilSpec(**d) for n, d in truth["stencils"].items()}
    err = syn.param_rel_error(truth_gpu, truth_st)
    check(err < RECOVERY_RTOL,
          f"synthetic recovery rel err {err:.2e} < {RECOVERY_RTOL}")
    check(syn.loss_after < 1e-6, f"synthetic fit loss {syn.loss_after:.2e} ~ 0")

    print("[3/5] refit from the real harness timings improves the model")
    out = _run(
        MEASURE_CLI + ["fit", "--store", root, "--measurement", meas_key]
    ).stdout
    print(out, end="")
    cal_key = _key(out, "calibration")
    cal = CalibrationResult.from_payload(store.get(cal_key).payload)
    check(cal.loss_after < cal.loss_before, "refit reduced the fit loss")
    improved = sum(
        cal.errors_after[n] < cal.errors_before[n] for n in cal.errors_after
    )
    # per-stencil C_iter is a free parameter, so nearly every stencil must
    # improve; allow one holdout for shared-parameter (bw/launch) coupling
    # on a noisy runner
    check(improved >= len(cal.errors_after) - 1,
          f"per-stencil |rel err| improved for {improved}/{len(cal.errors_after)}")

    print("[4/5] calibrated sweep build")
    out = _run(
        MEASURE_CLI + ["build", "--store", root, "--calibration", cal_key,
                       "--downsample", str(args.downsample),
                       "--engine", "numpy"]
    ).stdout
    print(out, end="")
    sweep_key = _key(out, "calibrated sweep")
    oracle = CodesignServer.from_artifact(
        store, store.get(sweep_key), batch_window=0.0
    )

    print("[5/5] gateway serves the calibrated artifact byte-identically")
    proc = subprocess.Popen(
        SERVICE_CLI + ["serve", "--store", root, "--port", "0"],
        stdout=subprocess.PIPE, text=True, env=_env(),
    )
    try:
        url = None
        for line in proc.stdout:
            m = re.search(r"serving on (http://\S+)", line)
            if m:
                url = m.group(1)
                break
        check(url is not None, "serve printed its bound address")
        client = GatewayClient(url)
        rows = {r["key"]: r for r in client.artifacts()}
        check(rows[meas_key]["kind"] == "measurement"
              and rows[cal_key]["kind"] == "calibration"
              and rows[sweep_key]["kind"] == "sweep",
              "all three artifact kinds indexed")
        gpu_name = oracle.gpu.name
        requests = [
            QueryRequest(freqs={"heat2d": 2.0, "jacobi2d": 1.0},
                         max_area=450.0, top_k=3, use_cache=False),
            QueryRequest(pareto=True, fix={"n_sm": 16.0}, use_cache=False),
        ]
        for req in requests:
            want = wire.encode_response(oracle.query(req))
            by_cal = client.query_bytes(req, route={"calibration": cal_key})
            by_gpu = client.query_bytes(req, route={"gpu": gpu_name})
            check(by_cal == want,
                  f"byte-identical via calibration key (gpu={gpu_name})")
            check(by_gpu == want, f"byte-identical via gpu={gpu_name}")
        # batched endpoint: same two queries, one round trip, same bytes
        many = client.query_many(requests, route={"calibration": cal_key})
        check(
            all(r.artifact_key == sweep_key for r in many)
            and [r.best_index for r in many]
            == [wire.decode_response(
                    wire.encode_response(oracle.query(q))).best_index
                for q in requests],
            "query_many answers match per-query oracles",
        )
        # a calibration manifest must answer 400, not serve a query
        try:
            client.query(requests[0], artifact=cal_key)
            check(False, "querying a calibration manifest must fail")
        except wire.RemoteError as e:
            check(e.code == "wrong_artifact_kind" and e.http_status == 400,
                  "calibration manifest -> 400 wrong_artifact_kind")
    finally:
        proc.terminate()
        proc.wait(timeout=30)

    print("PASS: measure smoke (kernels + calibration + calibrated serving)")


if __name__ == "__main__":
    main()
