"""Versioned on-disk artifact store for eq.-18 sweep results.

The separability decomposition makes the ``(cells x hardware)`` optima
matrix the unit of reuse: every §V.B analysis (re-weighted mixes, top-k
under an area budget, Pareto fronts, what-if subspaces) is a cheap
re-reduction over it. This module persists :class:`repro.core.codesign
.CodesignResult` so that reuse survives the process:

* one directory per artifact: ``manifest.json`` (workload cells with full
  stencil specs, GPU constants, lattices, shapes, spec) + ``cell_time.npy``
  (the big (C, H) float64 matrix, written raw so it can be **memory-mapped**
  on load) + ``arrays.npz`` (compressed: tile argmins and the hardware-space
  columns);
* **content-addressed keys**: sha256 over a canonical-JSON spec of
  (stencil set incl. numeric model constants, size grid, hardware-space
  digest, GPU constants, lattices, engine, format version). Same question
  -> same key; any change to the inputs that could change the matrix ->
  a different key (see ``tests/test_service.py``);
* lazy loading: :class:`Artifact` reads the manifest eagerly (small JSON)
  and materializes arrays on first attribute access -- ``cell_time`` as an
  ``mmap_mode="r"`` view, the npz members on demand;
* atomic writes: artifacts are staged in a temp directory and renamed into
  place, so readers never observe a half-written artifact; an exclusive
  per-key ``flock`` (:meth:`ArtifactStore.build_lock`) serializes
  concurrent builders across processes -- the loser reuses the winner's
  artifact instead of re-solving/re-staging.
"""

from __future__ import annotations

import contextlib
import dataclasses
import hashlib
import json
import os
import shutil
import tempfile
import threading
import time
from typing import Dict, List, Optional

import numpy as np

from repro.obs.metrics import get_registry as _obs_registry

try:  # POSIX file locks for the cross-process build path
    import fcntl
except ImportError:  # pragma: no cover - non-POSIX: fall back to lock-free
    fcntl = None

#: process-wide registry of held build locks: lock-file path -> [fd, depth].
#: flock is per open-file-description, so re-opening the same lock file in
#: one process (server wraps the whole build, put wraps the staged write)
#: would self-deadlock; the registry makes :meth:`ArtifactStore.build_lock`
#: reentrant *within* a process while staying exclusive *across* processes.
_HELD_LOCKS: Dict[str, list] = {}
_HELD_LOCKS_MU = threading.Lock()

from repro.core.codesign import CodesignResult, HardwareSpace
from repro.core.solver import LATTICE_2D, LATTICE_3D, TileLattice
from repro.core.timemodel import GPUSpec
from repro.core.workload import Workload

from . import faults
from .errors import ERROR_HTTP_STATUS, GatewayError
from .resilience import check_deadline, remaining_s

__all__ = [
    "FORMAT_VERSION",
    "KINDS",
    "Artifact",
    "ArtifactStore",
    "BuildLockTimeoutError",
    "artifact_spec",
    "lm_artifact_spec",
    "spec_key",
]

#: default bound on how long :meth:`ArtifactStore.build_lock` waits for
#: another process's flock before failing structured (seconds). Generous
#: on purpose -- a full-space sweep legitimately takes minutes -- and
#: overridable per store (``lock_timeout_s=``), per acquisition
#: (``timeout_s=``), or process-wide via ``REPRO_LOCK_TIMEOUT_S``.
DEFAULT_LOCK_TIMEOUT_S = 600.0

#: bump when the on-disk layout or the solver semantics change; old
#: artifacts then read as misses (the store rebuilds, never mis-serves).
FORMAT_VERSION = 1

#: manifest kinds one store can hold. "sweep" is the original (C, H)
#: optima matrix (manifest + cell_time.npy + arrays.npz); "measurement"
#: and "calibration" are manifest-only JSON artifacts written by
#: :mod:`repro.measure` (timing runs / refitted machine parameters);
#: "telemetry" is a manifest-only per-artifact hit/latency snapshot
#: persisted by a serving gateway (:meth:`repro.service.gateway.Gateway
#: .persist_telemetry`) so a future retention policy has data to act on;
#: "portfolio" is a manifest-only fleet decision (K member designs of a
#: sweep + the traffic assignment, :mod:`repro.service.portfolio`) that
#: the gateway routes ``POST /v1/route`` requests through.
#: Manifests written before kinds existed read as "sweep".
KINDS = ("sweep", "measurement", "calibration", "telemetry", "portfolio")

#: engines whose optima matrices are bit-identical share one content
#: address: "sharded" is the same compiled program as "jax", merely
#: partitioned over a device mesh, so an artifact built on an 8-device
#: host warms a single-device host (and vice versa). "numpy" keeps its own
#: key -- the float64 oracle differs from the float32 engines in the last
#: ulps, and the digest must never claim two different matrices are one.
#: "auto" is resolved to the concrete engine it would pick *before*
#: digesting (see :func:`artifact_spec`): keying the unresolved alias
#: would let a jax host's float32 matrix and a jax-less host's float64
#: matrix share one key.
_DIGEST_ENGINE = {"sharded": "jax"}

# ---- observability (repro.obs; no-ops under REPRO_OBS_DISABLED=1) --------
_REG = _obs_registry()
_M_BUILDS = _REG.counter(
    "repro_store_builds_total",
    "artifacts committed by a staged write, by manifest kind",
    labels=("kind",),
)
_M_OPENS = _REG.counter(
    "repro_store_opens_total",
    "successful artifact opens via ArtifactStore.get",
)
_M_LOCK_WAIT = _REG.histogram(
    "repro_store_lock_wait_seconds",
    "wall time blocked acquiring a per-key build flock (cross-process "
    "build contention)",
)
_M_LOCK_TIMEOUTS = _REG.counter(
    "repro_store_build_lock_timeouts_total",
    "build-lock acquisitions abandoned at their wait bound "
    "(structured build_lock_timeout errors instead of hung threads)",
)


class BuildLockTimeoutError(GatewayError):
    """Another process held a key's build flock past the caller's wait
    bound (HTTP 503, wire code ``build_lock_timeout``). Retryable: the
    holder is usually a legitimate builder that will finish."""

    code = "build_lock_timeout"
    http_status = ERROR_HTTP_STATUS["build_lock_timeout"]

    def __init__(self, message: str, retry_after_s: float = 5.0):
        super().__init__(message)
        self.retry_after_s = float(retry_after_s)


def _digest_engine(engine: str, n_hw: int) -> str:
    if engine == "auto":
        # resolve only the matrix *family* (float64 oracle vs float32
        # compiled) -- deliberately NOT via _resolve_engine, whose
        # device_count() call would initialize the jax backend (on GPU
        # hosts: ~75% memory preallocation) on warm paths that never
        # sweep. Device count cannot matter here: multi-device auto picks
        # "sharded", which canonicalizes to "jax" anyway.
        from repro.core.codesign import _AUTO_MIN_HW

        if n_hw < _AUTO_MIN_HW:
            engine = "numpy"
        else:
            from repro.core import sweep  # module import only, no backend

            engine = "jax" if sweep.HAVE_JAX else "numpy"
    return _DIGEST_ENGINE.get(engine, engine)


def _canonical_json(obj) -> str:
    return json.dumps(obj, sort_keys=True, separators=(",", ":"))


def _array_digest(*arrays: np.ndarray) -> str:
    h = hashlib.sha256()
    for a in arrays:
        a = np.ascontiguousarray(np.asarray(a, np.float64))
        h.update(str(a.shape).encode())
        h.update(a.tobytes())
    return h.hexdigest()


def artifact_spec(
    workload: Workload,
    gpu: GPUSpec,
    hw: HardwareSpace,
    engine: str,
    lattice_2d: TileLattice = LATTICE_2D,
    lattice_3d: TileLattice = LATTICE_3D,
) -> dict:
    """The content-address identity of a sweep, computable WITHOUT running
    it. Frequencies are deliberately excluded: the stored matrix serves
    every mix, so re-weighting must not change the key."""
    lat_d = lambda lat: {k: list(getattr(lat, k)) for k in ("t_s1", "t_s2", "t_t", "k", "t_s3")}
    return {
        "format_version": FORMAT_VERSION,
        "stencils": sorted(
            {c.stencil.name: dataclasses.asdict(c.stencil) for c in workload.cells}.values(),
            key=lambda d: d["name"],
        ),
        "cells": [
            [c.stencil.name, int(c.size.s1), int(c.size.s2), int(c.size.s3), int(c.size.t)]
            for c in workload.cells
        ],
        "gpu": dataclasses.asdict(gpu),
        "hw_digest": _array_digest(hw.n_sm, hw.n_v, hw.m_sm, hw.area),
        "n_hw": len(hw),
        "lattices": {"2d": lat_d(lattice_2d), "3d": lat_d(lattice_3d)},
        "engine": _digest_engine(engine, len(hw)),
    }


def lm_artifact_spec(workload: Workload, hw, engine: str, gpu_name: str) -> dict:
    """Content-address identity of an LM-family sweep (family ``"lm"``).

    Same contract as :func:`artifact_spec`: computable without running the
    sweep, frequencies excluded (the matrix serves every mix), engine
    resolved to its matrix family (float64 oracle vs float32 compiled) so
    bit-identical engines share one key. Cells are keyed by their full
    numeric identity -- model/op/shape plus the precomputed constants that
    enter the time model -- so any change that could move the matrix moves
    the key."""
    from repro.core.lmcells import resolve_lm_engine, lm_sw_lattice

    return {
        "format_version": FORMAT_VERSION,
        "family": "lm",
        "cells": [
            [
                c.model, c.op, c.shape.name, int(c.shape.seq_len),
                int(c.shape.global_batch), c.shape.kind, c.consts(),
            ]
            for c in workload.cells
        ],
        "gpu": gpu_name,
        "hw_digest": _array_digest(hw.pod, hw.data, hw.model, hw.area),
        "n_hw": len(hw),
        "sw_lattices": sorted(
            {
                _canonical_json(lm_sw_lattice(c.op).as_dict())
                for c in workload.cells
            }
        ),
        "engine": resolve_lm_engine(engine),
    }


def spec_key(spec: dict) -> str:
    return hashlib.sha256(_canonical_json(spec).encode()).hexdigest()[:20]


class Artifact:
    """Lazy read handle over one stored sweep.

    The manifest is loaded eagerly; ``cell_time`` is an mmap-backed view
    materialized on first access (queries that never touch a row never page
    it in), and the smaller arrays decompress from the npz on demand.
    """

    def __init__(self, path: str):
        self.path = path
        with open(os.path.join(path, "manifest.json")) as f:
            self.manifest = json.load(f)
        self.key: str = self.manifest["key"]
        self._cell_time: Optional[np.ndarray] = None
        self._npz = None
        self._cache: Dict[str, np.ndarray] = {}

    # ---- shapes / metadata ------------------------------------------------
    @property
    def kind(self) -> str:
        """Manifest kind; pre-kind manifests are sweep artifacts."""
        return self.manifest.get("kind", "sweep")

    @property
    def payload(self) -> dict:
        """The JSON body of a manifest-only artifact (measurement run /
        calibration); empty for sweep artifacts."""
        return self.manifest.get("payload", {})

    @property
    def n_cells(self) -> int:
        return int(self.manifest["shapes"]["cells"])

    @property
    def n_hw(self) -> int:
        return int(self.manifest["shapes"]["hw"])

    @property
    def family(self) -> str:
        """Cell family of a sweep artifact ("stencil" | "lm"); manifests
        written before families existed are stencil sweeps."""
        return self.manifest.get("workload", {}).get("family", "stencil")

    @property
    def stencil_names(self) -> List[str]:
        seen: Dict[str, None] = {}
        for c in self.manifest["workload"]["cells"]:
            seen.setdefault(c["stencil"]["name"])
        return list(seen)

    @property
    def cell_labels(self) -> List[str]:
        """Distinct cell group labels: stencil names, or ``model:op`` for
        the LM family."""
        if self.family == "lm":
            seen: Dict[str, None] = {}
            for c in self.manifest["workload"]["cells"]:
                seen.setdefault(f"{c['model']}:{c['op']}")
            return list(seen)
        return self.stencil_names

    def routing(self) -> Dict[str, object]:
        """The manifest-only attribute row a gateway indexes this artifact
        under: content key, GPU target, workload name, stencil set,
        hardware-space digest, resolved engine family, and shapes.

        Derivable from the (small) JSON manifest alone -- listing a fleet
        store never mmaps a matrix. Falls back to recomputing the fields
        for artifacts written before the manifest grew a ``"routing"``
        block (same format version, older writer). Non-sweep kinds
        (measurement / calibration manifests) carry whatever their writer
        put in the routing block, plus key/kind/format_version."""
        m = self.manifest
        spec = m.get("spec", {})
        r = dict(m.get("routing") or {})
        if self.kind != "sweep":
            r.update(
                key=self.key,
                kind=self.kind,
                format_version=m.get("format_version"),
            )
            return r
        r.setdefault("gpu", m["gpu"]["name"])
        r.setdefault("workload", m["workload"]["name"])
        r.setdefault("family", self.family)
        if self.family == "lm":
            cells = m["workload"]["cells"]
            r.setdefault("models", sorted({c["model"] for c in cells}))
            r.setdefault("ops", sorted({c["op"] for c in cells}))
        else:
            r.setdefault("stencils", sorted(self.stencil_names))
        r.update(
            key=self.key,
            kind=self.kind,
            hw_digest=spec.get("hw_digest"),
            engine=spec.get("engine", m.get("engine")),
            cells=self.n_cells,
            hw=self.n_hw,
            format_version=m.get("format_version"),
        )
        return r

    def cell_freqs(self) -> np.ndarray:
        """(C,) stored workload frequencies (the artifact's own mix)."""
        return np.array(
            [c["freq"] for c in self.manifest["workload"]["cells"]], np.float64
        )

    def cell_flops(self) -> np.ndarray:
        """(C,) useful flops per cell -- the GFLOP/s numerator. Stencil
        cells derive it from the model (flops/point x points); LM cells
        store it precomputed in their constants."""
        cells = self.manifest["workload"]["cells"]
        if self.family == "lm":
            return np.array([c["consts"]["flops"] for c in cells], np.float64)
        out = np.empty(self.n_cells, np.float64)
        for i, c in enumerate(cells):
            sz = c["size"]
            points = float(sz["s1"]) * sz["s2"] * sz["s3"] * sz["t"]
            out[i] = c["stencil"]["flops_per_point"] * points
        return out

    # ---- arrays -----------------------------------------------------------
    @property
    def cell_time(self) -> np.ndarray:
        if self._cell_time is None:
            self._cell_time = np.load(
                os.path.join(self.path, "cell_time.npy"), mmap_mode="r"
            )
        return self._cell_time

    def _arr(self, name: str) -> np.ndarray:
        if name not in self._cache:
            if self._npz is None:
                self._npz = np.load(os.path.join(self.path, "arrays.npz"))
            self._cache[name] = self._npz[name]
        return self._cache[name]

    @property
    def cell_tile_idx(self) -> np.ndarray:
        return self._arr("cell_tile_idx")

    @property
    def hw_n_sm(self) -> np.ndarray:
        return self._arr("hw_n_sm")

    @property
    def hw_n_v(self) -> np.ndarray:
        return self._arr("hw_n_v")

    @property
    def hw_m_sm(self) -> np.ndarray:
        return self._arr("hw_m_sm")

    @property
    def hw_area(self) -> np.ndarray:
        return self._arr("hw_area")

    def hw_column(self, name: str) -> np.ndarray:
        """Hardware-space column by design-parameter name (what-if filters).
        Column names are family-specific: ``n_sm/n_v/m_sm/area`` for
        stencil sweeps, ``pod/data/model/chips/area`` for LM sweeps (where
        area IS the chip count)."""
        if self.family == "lm":
            cols = {"pod": "hw_pod", "data": "hw_data", "model": "hw_model",
                    "chips": "hw_area", "area": "hw_area"}
        else:
            cols = {"n_sm": "hw_n_sm", "n_v": "hw_n_v", "m_sm": "hw_m_sm",
                    "area": "hw_area"}
        if name not in cols:
            raise KeyError(f"unknown hardware parameter {name!r} (want one of {sorted(cols)})")
        return self._arr(cols[name])

    def point(self, i: int) -> Dict[str, float]:
        """Design parameters of hardware point ``i`` as a plain dict."""
        if self.family == "lm":
            return {
                "pod": int(self._arr("hw_pod")[i]),
                "data": int(self._arr("hw_data")[i]),
                "model": int(self._arr("hw_model")[i]),
                "chips": int(self.hw_area[i]),
            }
        return {
            "n_sm": int(self.hw_n_sm[i]),
            "n_v": int(self.hw_n_v[i]),
            "m_sm": float(self.hw_m_sm[i]),
            "area": float(self.hw_area[i]),
        }

    def to_result(self):
        """Materialize the full in-process result object (round-trip
        inverse of :meth:`ArtifactStore.put`), dispatching on family."""
        if self.family == "lm":
            from repro.core.lmcells import LMCodesignResult

            arrays = {
                "cell_time": self.cell_time,
                "cell_plan_idx": self._arr("cell_plan_idx"),
                "hw_pod": self._arr("hw_pod"),
                "hw_data": self._arr("hw_data"),
                "hw_model": self._arr("hw_model"),
                "hw_area": self.hw_area,
            }
            return LMCodesignResult.from_artifact_payload(self.manifest, arrays)
        arrays = {
            "cell_time": self.cell_time,
            "cell_tile_idx": self.cell_tile_idx,
            "hw_n_sm": self.hw_n_sm,
            "hw_n_v": self.hw_n_v,
            "hw_m_sm": self.hw_m_sm,
            "hw_area": self.hw_area,
        }
        return CodesignResult.from_artifact_payload(self.manifest, arrays)


class ArtifactStore:
    """Directory of content-addressed sweep artifacts.

    ``create=False`` opens an existing root without creating it (a serving
    front-end must not silently conjure empty stores out of typo'd paths);
    the default keeps the build-path ergonomics of ``put`` into a fresh
    directory."""

    def __init__(self, root: str, create: bool = True,
                 lock_timeout_s: Optional[float] = None):
        self.root = os.path.abspath(root)
        if create:
            os.makedirs(self.root, exist_ok=True)
        elif not os.path.isdir(self.root):
            raise FileNotFoundError(f"artifact store root {self.root!r} does not exist")
        if lock_timeout_s is None:
            lock_timeout_s = float(
                os.environ.get("REPRO_LOCK_TIMEOUT_S", DEFAULT_LOCK_TIMEOUT_S)
            )
        if lock_timeout_s <= 0:
            raise ValueError("lock_timeout_s must be > 0")
        self.lock_timeout_s = lock_timeout_s

    # ---- keys -------------------------------------------------------------
    def key_for(
        self,
        workload: Workload,
        gpu: GPUSpec,
        hw: HardwareSpace,
        engine: str = "auto",
        lattice_2d: TileLattice = LATTICE_2D,
        lattice_3d: TileLattice = LATTICE_3D,
    ) -> str:
        return spec_key(
            artifact_spec(workload, gpu, hw, engine, lattice_2d, lattice_3d)
        )

    def key_for_lm(
        self, workload: Workload, hw, engine: str = "auto", gpu_name: str = "tpu_v5e"
    ) -> str:
        """Content key of an LM-family sweep, computable before running it."""
        return spec_key(lm_artifact_spec(workload, hw, engine, gpu_name))

    def _path(self, key: str) -> str:
        return os.path.join(self.root, key)

    @contextlib.contextmanager
    def build_lock(self, key: str, timeout_s: Optional[float] = None):
        """Exclusive **cross-process** lock for one key's build/staged-write.

        Two processes building the same artifact key serialize here: the
        loser re-checks the store after acquiring and finds the winner's
        artifact instead of re-staging (and, for callers that wrap the
        whole sweep -- :meth:`CodesignServer.ensure_artifact` -- instead of
        re-solving). Reentrant within a process via a refcount registry;
        it is NOT a cross-thread mutex (in-process threads serialize with
        their own locks, as the server does). Lock files are dot-prefixed
        so :meth:`keys` never lists them, and are left in place --
        unlinking a locked path would hand a third process a fresh inode
        and break the mutual exclusion. No-op where ``fcntl`` is
        unavailable (non-POSIX), which degrades to the previous
        benign-rename behavior.

        The wait is **bounded** (a wedged or merely slow holder must not
        park a request thread forever): ``timeout_s`` (default the
        store's ``lock_timeout_s``; generous, because a legitimate
        builder takes minutes) -- capped further by the in-flight
        request's remaining deadline budget when one is active
        (``docs/resilience.md``). Exhausting the bound raises a
        structured :class:`BuildLockTimeoutError` (wire code
        ``build_lock_timeout``) instead of hanging."""
        if fcntl is None:
            yield
            return
        path = os.path.join(self.root, f".lock-{key}")
        with _HELD_LOCKS_MU:
            held = _HELD_LOCKS.get(path)
            if held is not None:
                held[1] += 1
        if held is None:
            budget = self.lock_timeout_s if timeout_s is None else float(timeout_s)
            cap = remaining_s()  # in-flight request deadline, if any
            deadline_capped = cap is not None and cap < budget
            if deadline_capped:
                budget = cap
            fd = os.open(path, os.O_CREAT | os.O_RDWR, 0o644)
            t0 = time.perf_counter()
            try:
                faults.fire("store.lock")
                while True:
                    try:
                        # non-blocking + poll, never LOCK_EX: an
                        # uninterruptible blocking flock is exactly the
                        # unbounded wait this method exists to prevent
                        fcntl.flock(fd, fcntl.LOCK_EX | fcntl.LOCK_NB)
                        break
                    except (BlockingIOError, InterruptedError):
                        waited = time.perf_counter() - t0
                        if waited >= budget:
                            _M_LOCK_TIMEOUTS.inc()
                            why = ("request deadline budget"
                                   if deadline_capped else "wait bound")
                            raise BuildLockTimeoutError(
                                f"build lock for key {key[:12]}... still "
                                f"held by another process after "
                                f"{waited:.1f}s ({why} {budget:.1f}s); "
                                f"the holder is likely building this "
                                f"artifact -- retry later"
                            )
                        time.sleep(min(0.01, max(budget - waited, 0.001)))
            except BaseException:
                os.close(fd)
                raise
            _M_LOCK_WAIT.observe(time.perf_counter() - t0)
            with _HELD_LOCKS_MU:
                _HELD_LOCKS[path] = [fd, 1]
        try:
            yield
        finally:
            with _HELD_LOCKS_MU:
                ent = _HELD_LOCKS[path]
                ent[1] -= 1
                if ent[1] == 0:
                    del _HELD_LOCKS[path]
                    fcntl.flock(ent[0], fcntl.LOCK_UN)
                    os.close(ent[0])

    def _staged_write(self, key: str, write_files) -> Artifact:
        """The shared commit discipline of :meth:`put` / :meth:`put_json`:
        under the cross-process build lock, re-check for a racing winner,
        stage via ``write_files(tmp_dir)`` in a temp dir, and
        ``os.replace`` into place -- tolerating the rename failing only
        when a concurrent same-key builder's artifact is already there
        (content addressing guarantees the bytes match). Lives in ONE
        place because the lost-race tolerance is subtle enough that two
        copies would drift."""
        with self.build_lock(key):
            existing = self.get(key)
            if existing is not None:  # a racing builder finished first
                return existing
            tmp = tempfile.mkdtemp(prefix=f".stage-{key}-", dir=self.root)
            try:
                write_files(tmp)
                try:
                    os.replace(tmp, self._path(key))
                except OSError:
                    if not os.path.exists(
                        os.path.join(self._path(key), "manifest.json")
                    ):
                        raise  # real failure, not a lost same-key race
            finally:
                if os.path.exists(tmp):
                    shutil.rmtree(tmp, ignore_errors=True)
        art = self.get(key)
        assert art is not None
        _M_BUILDS.labels(kind=art.kind).inc()  # this process staged it
        return art

    def has(self, key: str) -> bool:
        """True iff ``key`` is stored AND readable at this format version."""
        return self.get(key) is not None

    def get(self, key: str) -> Optional[Artifact]:
        """None on miss OR format-version mismatch (stale artifacts are
        invisible, never mis-served)."""
        # resilience hooks: the chaos harness injects open latency /
        # load exceptions here, and a request whose deadline budget is
        # already spent fails fast instead of paying the open
        faults.fire("store.open")
        check_deadline("store.open")
        path = self._path(key)
        if not os.path.exists(os.path.join(path, "manifest.json")):
            return None
        art = Artifact(path)
        if art.manifest.get("format_version") != FORMAT_VERSION:
            return None
        _M_OPENS.inc()
        return art

    def put(
        self,
        result: CodesignResult,
        engine: str = "auto",
        extra: Optional[dict] = None,
        lattice_2d: Optional[TileLattice] = None,
        lattice_3d: Optional[TileLattice] = None,
        routing_extra: Optional[dict] = None,
    ) -> Artifact:
        """Persist a sweep result; returns the (re)loaded lazy handle.

        The staged write runs under :meth:`build_lock`, so two processes
        persisting the same key serialize and the loser returns the
        winner's artifact without re-staging (content addressing guarantees
        the bytes match). Writes are still staged in a temp dir and renamed
        into place, so a reader that ignores the lock sees either nothing
        or the whole artifact. ``lattice_2d``/``lattice_3d`` pin the key's
        lattice tables when the workload exercises only one dimensionality
        (otherwise inferred from the result's per-cell lattices, falling
        back to the defaults). ``routing_extra`` merges additional
        attributes into the manifest's routing block (e.g. the
        ``calibration`` key of the fit a calibrated sweep derives from) --
        routing is not part of the content address, so this never moves
        the key. Dispatches on the result's cell family: LM results
        (:class:`repro.core.lmcells.LMCodesignResult`) key via
        :func:`lm_artifact_spec` (the tile-lattice pins do not apply)."""
        if getattr(result, "family", "stencil") == "lm":
            spec = lm_artifact_spec(
                result.workload, result.hw, engine, result.gpu_name
            )
        else:
            lat2 = lattice_2d or next(
                (lat for lat in result.lattices if len(lat.t_s3) == 1), LATTICE_2D
            )
            lat3 = lattice_3d or next(
                (lat for lat in result.lattices if len(lat.t_s3) > 1), LATTICE_3D
            )
            spec = artifact_spec(
                result.workload, result.gpu, result.hw, engine, lat2, lat3
            )
        key = spec_key(spec)
        manifest, arrays = result.artifact_payload()
        manifest.update(
            format_version=FORMAT_VERSION,
            kind="sweep",
            key=key,
            spec=spec,
            engine=engine,
            shapes={"cells": int(arrays["cell_time"].shape[0]),
                    "hw": int(arrays["cell_time"].shape[1])},
            extra=extra or {},
        )
        if routing_extra:
            manifest["routing"] = {**manifest.get("routing", {}), **routing_extra}
        def write_files(tmp: str) -> None:
            np.save(os.path.join(tmp, "cell_time.npy"), arrays["cell_time"])
            np.savez_compressed(
                os.path.join(tmp, "arrays.npz"),
                **{k: v for k, v in arrays.items() if k != "cell_time"},
            )
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f, indent=1)

        return self._staged_write(key, write_files)

    def put_json(
        self,
        kind: str,
        payload: dict,
        routing: Optional[dict] = None,
        extra: Optional[dict] = None,
    ) -> Artifact:
        """Persist a manifest-only JSON artifact (measurement run,
        calibration) content-addressed over its canonical payload.

        Same staging/locking discipline as :meth:`put`; the key is a
        sha256 over ``(format_version, kind, payload)``, so identical runs
        dedupe and any payload change gets a fresh key. ``routing`` is the
        attribute row a gateway indexes the artifact under (not hashed);
        ``extra`` is free-form annotation (not hashed either).
        """
        if kind not in KINDS or kind == "sweep":
            raise ValueError(
                f"put_json stores manifest-only kinds {[k for k in KINDS if k != 'sweep']}, got {kind!r}"
            )
        spec = {
            "format_version": FORMAT_VERSION,
            "kind": kind,
            "payload_digest": hashlib.sha256(
                _canonical_json(payload).encode()
            ).hexdigest(),
        }
        key = spec_key(spec)
        manifest = {
            "format_version": FORMAT_VERSION,
            "kind": kind,
            "key": key,
            "spec": spec,
            "routing": dict(routing or {}),
            "payload": payload,
            "extra": extra or {},
        }
        def write_files(tmp: str) -> None:
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f, indent=1)

        return self._staged_write(key, write_files)

    def upgrade_manifests(self) -> List[str]:
        """Backfill manifests written by older writers in place.

        Legacy sweep manifests (pre-gateway) lack the ``"routing"`` block
        and the ``"kind"`` tag; a gateway can still index them through
        :meth:`Artifact.routing`'s derivation fallback, but every scan
        re-derives and the rows stay partial (no hw_digest-independent
        attrs a future writer might add). This rewrites each such manifest
        with its derived routing block and ``kind: "sweep"``. The content
        key hashes the *spec*, never the manifest bytes, so upgraded
        artifacts keep their key (asserted) -- readers racing the rewrite
        see either the old or the new manifest, both valid for the same
        matrix. Returns the upgraded keys."""
        upgraded: List[str] = []
        for key in self.keys():
            path = os.path.join(self._path(key), "manifest.json")
            with open(path) as f:
                manifest = json.load(f)
            if "routing" in manifest and "kind" in manifest:
                continue
            with self.build_lock(key):
                art = Artifact(self._path(key))
                row = art.routing()  # derivation fallback fills the gaps
                manifest = art.manifest
                manifest["kind"] = art.kind
                manifest["routing"] = {
                    k: row[k]
                    for k in ("gpu", "workload", "stencils")
                    if k in row
                }
                assert manifest.get("key", key) == key, "manifest key drifted"
                fd, tmp = tempfile.mkstemp(
                    prefix=".manifest-", dir=self._path(key)
                )
                try:
                    with os.fdopen(fd, "w") as f:
                        json.dump(manifest, f, indent=1)
                    os.replace(tmp, path)
                except BaseException:
                    if os.path.exists(tmp):
                        os.unlink(tmp)
                    raise
            upgraded.append(key)
        return upgraded

    def delete(self, key: str) -> bool:
        """Remove one stored artifact (the GC apply path). Runs under the
        key's build lock so a concurrent builder either finishes before
        the removal or re-stages afterward -- never loses half its files.
        Returns True when an artifact directory was removed. Open mmap
        handles on the old files stay valid on POSIX (the inode lives
        until the last reader closes)."""
        with self.build_lock(key):
            path = self._path(key)
            if not os.path.exists(os.path.join(path, "manifest.json")):
                return False
            shutil.rmtree(path)
        return True

    def keys(self) -> List[str]:
        """Sorted content keys of every (complete) stored artifact."""
        return sorted(
            d for d in os.listdir(self.root)
            if os.path.exists(os.path.join(self.root, d, "manifest.json"))
            and not d.startswith(".")
        )

    def entries(self) -> List[Dict]:
        """One routing-attribute row per stored artifact (the CLI's ``ls``
        and the raw material of the gateway's index); manifest-only, so
        listing a large store never touches a matrix."""
        return [Artifact(self._path(k)).routing() for k in self.keys()]
