"""gemma-7b [dense]: GeGLU, head_dim 256, MHA (kv=16), RMSNorm(1+w),
scaled embeddings. [arXiv:2403.08295; hf]"""

from .base import ArchConfig, register

CONFIG = register(
    ArchConfig(
        name="gemma-7b",
        family="dense",
        n_layers=28,
        d_model=3072,
        n_heads=16,
        n_kv_heads=16,
        head_dim=256,
        d_ff=24576,
        vocab=256000,
        act="geglu",
        rms_offset=1.0,
        emb_scale=True,
        tie_embeddings=True,
        source="arXiv:2403.08295; hf",
    )
)
