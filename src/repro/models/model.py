"""Model assembly: embeddings/frontends -> stack(s) -> head (+MTP), loss.

``init_model``/``forward`` are the only entry points the train/serve steps
use. Modality frontends are STUBS per the assignment: ``input_specs``
provides precomputed frame/patch embeddings, and the model consumes them
as leading sequence positions (vlm) or as the encoder input (audio).
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .layers import embed_init, rmsnorm, rmsnorm_init, sinusoidal_positions, dense_init
from .transformer import block_apply, block_init, segments, stack_apply, stack_init

__all__ = [
    "init_model",
    "forward",
    "lm_loss",
    "count_params",
    "active_params",
    "mrope_positions",
    "LEARNED_POS_MAX",
]

LEARNED_POS_MAX = 32768  # whisper decode_32k needs absolute slots up to 32k


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------
def init_model(cfg: ArchConfig, key) -> Dict:
    dtype = jnp.dtype(cfg.dtype)
    k_emb, k_stack, k_enc, k_head, k_mtp = jax.random.split(key, 5)
    params: Dict = {"embed": embed_init(k_emb, cfg.vocab, cfg.d_model, dtype)}
    if cfg.rope == "learned":
        params["pos_embed"] = (
            jax.random.normal(jax.random.fold_in(k_emb, 1), (LEARNED_POS_MAX, cfg.d_model), jnp.float32)
            * 0.01
        ).astype(dtype)
    if cfg.enc_dec:
        enc_segs = [((("attn", "mlp"),), cfg.n_enc_layers)]
        params["encoder"] = stack_init(k_enc, cfg, dtype, cross=False, segs=enc_segs)
        params["enc_norm"] = rmsnorm_init(cfg.d_model, dtype, cfg.rms_offset)
        params["decoder"] = stack_init(k_stack, cfg, dtype, cross=True)
    else:
        params["stack"] = stack_init(k_stack, cfg, dtype, cross=False)
    params["final_norm"] = rmsnorm_init(cfg.d_model, dtype, cfg.rms_offset)
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(k_head, (cfg.d_model, cfg.vocab), dtype)
    if cfg.mtp:
        km1, km2 = jax.random.split(k_mtp)
        params["mtp"] = {
            "norm_h": rmsnorm_init(cfg.d_model, dtype, cfg.rms_offset),
            "norm_e": rmsnorm_init(cfg.d_model, dtype, cfg.rms_offset),
            "proj": dense_init(km1, (2 * cfg.d_model, cfg.d_model), dtype),
            "block": block_init(km2, cfg, "attn", "mlp", dtype),
            "final_norm": rmsnorm_init(cfg.d_model, dtype, cfg.rms_offset),
        }
    return params


# ---------------------------------------------------------------------------
# Positions
# ---------------------------------------------------------------------------
def mrope_positions(cfg: ArchConfig, batch: int, n_vision: int, n_text: int, offset=0):
    """Qwen2-VL M-RoPE ids (B, 3, S): vision patches get (t=0, h, w) grid
    ids; text gets synchronized ids continuing after the grid extent."""
    g = max(1, int(math.ceil(math.sqrt(max(n_vision, 1)))))
    vis_i = jnp.arange(n_vision)
    vis = jnp.stack([jnp.zeros_like(vis_i), vis_i // g, vis_i % g])  # (3, Nv)
    start = g  # text ids start after the spatial extent
    txt_i = start + jnp.arange(n_text) + offset
    txt = jnp.stack([txt_i, txt_i, txt_i])  # (3, Nt)
    pos = jnp.concatenate([vis, txt], axis=1)  # (3, S)
    return jnp.broadcast_to(pos[None], (batch, 3, pos.shape[1]))


def _text_positions(batch: int, seq: int, offset, like=None) -> jnp.ndarray:
    """Position ids. ``like`` (the token array) donates its sharding: ids
    built from bare iota are unsharded, and an unsharded (B, S[, S]) mask
    bias makes GSPMD replicate the attention path across the data axis
    (measured 4.5x FLOP inflation on deepseek -- EXPERIMENTS.md §Perf)."""
    pos = jnp.arange(seq)[None, :] + jnp.asarray(offset).reshape(-1, 1)
    pos = jnp.broadcast_to(pos, (batch, seq))
    if like is not None:
        pos = pos + jnp.zeros_like(like, dtype=pos.dtype)
    return pos


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------
def _embed(cfg, params, tokens):
    x = params["embed"][tokens]
    if cfg.emb_scale:
        x = (x.astype(jnp.float32) * math.sqrt(cfg.d_model)).astype(x.dtype)
    return x


def _head(cfg, params, x):
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return jnp.einsum("bsd,dv->bsv", x, w)


def forward_hidden(
    params: Dict,
    cfg: ArchConfig,
    batch: Dict,
    *,
    caches: Optional[Dict] = None,
    impl: str = "auto",
    remat: str = "none",
    want_mtp: bool = False,
) -> Tuple[jnp.ndarray, Optional[Dict], Dict]:
    """Backbone only: returns (normed hidden (B,S,d), new_caches, extras
    {'aux', 'mtp_hidden'?}). The head is applied by the caller -- training
    uses :func:`chunked_ce` so full (tokens x vocab) logits never
    materialize; serving applies the head to the positions it needs.

    batch keys: 'tokens' (B,S); optional 'frontend' (B,F,d) patch/frame
    embeddings (vlm: prepended; audio: encoder input); optional
    'cache_index' () int for decode; optional 'positions' override.
    """
    tokens = batch["tokens"]
    b, s = tokens.shape
    offset = batch.get("cache_index", 0)
    x = _embed(cfg, params, tokens)

    enc_out = None  # only non-None when cross K/V must be (re)computed
    new_caches = dict(caches) if caches is not None else None
    if cfg.enc_dec:
        if caches is not None and "enc_out" in caches:
            # decode: cross K/V already live in the per-layer caches; the
            # stack must NOT see enc_out again (it would re-append K/V)
            new_caches["enc_out"] = caches["enc_out"]
        else:
            enc_in = batch["frontend"].astype(x.dtype)
            ns = enc_in.shape[1]
            enc_in = enc_in + sinusoidal_positions(ns, cfg.d_model)[None].astype(x.dtype)
            enc_pos = _text_positions(b, ns, 0)
            enc_out, _, _ = stack_apply(
                params["encoder"], cfg, enc_in, positions=enc_pos, mode="bidir",
                impl=impl, remat=remat, segs=[((("attn", "mlp"),), cfg.n_enc_layers)],
            )
            enc_out = rmsnorm(params["enc_norm"], enc_out, cfg.rms_offset)
            if new_caches is not None:
                new_caches["enc_out"] = enc_out

    if cfg.frontend == "vision" and batch.get("frontend") is not None:
        vis = batch["frontend"].astype(x.dtype)
        x = jnp.concatenate([vis, x], axis=1)
        positions = mrope_positions(cfg, b, vis.shape[1], s, offset=offset)
        positions = positions + jnp.zeros(
            (b, 1, 1), positions.dtype
        ) * 0  # keep shape; batch sharding follows the concat below
    elif cfg.rope == "mrope":
        # text-only step (e.g. decode): all three ids follow the text id
        nv = cfg.n_frontend_tokens
        g = max(1, int(math.ceil(math.sqrt(max(nv, 1)))))
        txt = _text_positions(b, s, offset, like=tokens) + g
        positions = jnp.broadcast_to(txt[:, None, :], (b, 3, s))
    else:
        positions = batch.get("positions")
        if positions is None:
            positions = _text_positions(b, s, offset, like=tokens)

    if cfg.rope == "learned":
        pos_tab = params["pos_embed"]
        x = x + pos_tab[jnp.clip(positions, 0, LEARNED_POS_MAX - 1)].astype(x.dtype)

    stack_name = "decoder" if cfg.enc_dec else "stack"
    stack_caches = caches.get("stack") if caches is not None else None
    h, stack_caches_out, aux = stack_apply(
        params[stack_name], cfg, x, positions=positions, mode="causal",
        caches=stack_caches, enc_out=enc_out, impl=impl, remat=remat,
        cross=cfg.enc_dec,
    )
    if new_caches is not None:
        new_caches["stack"] = stack_caches_out

    hn = rmsnorm(params["final_norm"], h, cfg.rms_offset)
    extras = {"aux": aux}

    if cfg.mtp and want_mtp and caches is None:
        # DeepSeek-V3 MTP: fuse h_t with emb(tok_{t+1}), one extra block,
        # shared head -> predicts tok_{t+2}. (Sequence shortened by 1.)
        mp = params["mtp"]
        h_in = rmsnorm(mp["norm_h"], h[:, :-1], cfg.rms_offset)
        e_in = rmsnorm(mp["norm_e"], _embed(cfg, params, tokens[:, 1:]), cfg.rms_offset)
        fused = jnp.einsum(
            "bsd,de->bse", jnp.concatenate([h_in, e_in], -1), mp["proj"]
        )
        fused, _, _ = block_apply(
            mp["block"], cfg, "attn", "mlp", fused,
            positions=positions[:, :-1] if positions.ndim == 2 else positions,
            mode="causal", cache=None, enc_out=None, impl=impl,
        )
        extras["mtp_hidden"] = rmsnorm(mp["final_norm"], fused, cfg.rms_offset)

    return hn, new_caches, extras


def forward(
    params: Dict,
    cfg: ArchConfig,
    batch: Dict,
    *,
    caches: Optional[Dict] = None,
    impl: str = "auto",
    remat: str = "none",
    want_mtp: bool = False,
) -> Tuple[jnp.ndarray, Optional[Dict], Dict]:
    """Full-logits forward (tests/small models/serving). Training uses
    forward_hidden + chunked_ce instead."""
    hn, new_caches, extras = forward_hidden(
        params, cfg, batch, caches=caches, impl=impl, remat=remat, want_mtp=want_mtp
    )
    logits = _head(cfg, params, hn)
    if "mtp_hidden" in extras:
        extras["mtp_logits"] = _head(cfg, params, extras.pop("mtp_hidden"))
    return logits, new_caches, extras


def chunked_ce(
    cfg: ArchConfig,
    params: Dict,
    hidden: jnp.ndarray,
    labels: jnp.ndarray,
    n_chunks: int = 1,
) -> jnp.ndarray:
    """Masked CE without materializing (B, S, V) logits: the sequence is
    split into n_chunks, each chunk's logits are computed, reduced, and
    *rematerialized* in the backward pass (jax.checkpoint), so live logits
    are (B, S/n, V) -- the standard streamed-softmax-CE memory fix.
    """
    b, s, d = hidden.shape
    while s % n_chunks:
        n_chunks -= 1  # largest divisor <= requested
    if n_chunks <= 1:
        return lm_loss(_head(cfg, params, hidden), labels)
    hc = hidden.reshape(b, n_chunks, s // n_chunks, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, n_chunks, s // n_chunks).transpose(1, 0, 2)

    @jax.checkpoint
    def chunk_stats(h_chunk, l_chunk):
        logits = _head(cfg, params, h_chunk).astype(jnp.float32)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(
            logits, jnp.maximum(l_chunk, 0)[..., None], axis=-1
        )[..., 0]
        mask = (l_chunk >= 0).astype(jnp.float32)
        return jnp.sum((lse - tgt) * mask), jnp.sum(mask)

    def body(carry, xs):
        tot, cnt = carry
        t, c = chunk_stats(*xs)
        return (tot + t, cnt + c), None

    (tot, cnt), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)), (hc, lc)
    )
    return tot / jnp.maximum(cnt, 1.0)


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------
def lm_loss(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Masked CE in f32; labels < 0 are ignored (vision slots, padding)."""
    v = logits.shape[-1]
    lf = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    tgt = jnp.take_along_axis(lf, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum((lse - tgt) * mask) / jnp.maximum(mask.sum(), 1.0)


# ---------------------------------------------------------------------------
# Parameter accounting (for MODEL_FLOPS / roofline)
# ---------------------------------------------------------------------------
def count_params(cfg: ArchConfig) -> int:
    """Exact parameter count via eval_shape over the real init (no alloc)."""
    shapes = jax.eval_shape(lambda: init_model(cfg, jax.random.PRNGKey(0)))
    return sum(int(math.prod(x.shape)) for x in jax.tree.leaves(shapes))


def active_params(cfg: ArchConfig) -> int:
    """Active-per-token parameters (MoE: routed top-k + shared only)."""
    total = count_params(cfg)
    if cfg.moe is None:
        return total
    m = cfg.moe
    mats = 3 if cfg.act in ("silu", "geglu") else 2
    per_expert = mats * cfg.d_model * m.d_ff
    n_moe_layers = sum(1 for _, f in cfg.layer_kinds() if f == "moe")
    inactive = per_expert * (m.n_experts - m.top_k) * n_moe_layers
    return total - inactive
