"""The error-code contract: every GatewayError subclass maps to exactly
one documented wire code + HTTP status (the ERROR_HTTP_STATUS registry),
and the gateway actually answers those statuses over live HTTP -- one
trigger per code, including the resilience family (429/503/504)."""

import json
import math
import tempfile
import threading
import urllib.error
import urllib.request

import pytest

from repro.core import MAXWELL, enumerate_hw_space
from repro.core.timemodel import MAXWELL_GPU, TITANX_GPU
from repro.core.workload import paper_workload
from repro.service import (
    ArtifactStore,
    CodesignServer,
    Gateway,
    GatewayError,
    QueryRequest,
    serve_http,
    wire,
)
from repro.service.errors import ERROR_HTTP_STATUS
from repro.service.resilience import GatewayResilience

STRIDE = 64
STENCILS = ["heat2d", "jacobi2d"]


def _all_gateway_error_classes():
    """Every concrete GatewayError subclass reachable from the package
    (importing repro.service pulls in gateway, store and resilience, so
    the recursive walk sees them all)."""
    out, stack = [], [GatewayError]
    while stack:
        cls = stack.pop()
        out.append(cls)
        stack.extend(cls.__subclasses__())
    return out


# ---------------------------------------------------------------------------
# the registry itself
# ---------------------------------------------------------------------------
def test_registry_statuses_are_sane():
    for code, status in ERROR_HTTP_STATUS.items():
        assert 400 <= status < 600, (code, status)
    # the codes the resilience layer added, pinned (docs/serving.md table)
    assert ERROR_HTTP_STATUS["rate_limited"] == 429
    assert ERROR_HTTP_STATUS["shed"] == 503
    assert ERROR_HTTP_STATUS["circuit_open"] == 503
    assert ERROR_HTTP_STATUS["build_lock_timeout"] == 503
    assert ERROR_HTTP_STATUS["deadline_exceeded"] == 504
    # wire re-exports THE registry (one table, never two)
    assert wire.ERROR_HTTP_STATUS is ERROR_HTTP_STATUS


@pytest.mark.parametrize(
    "cls", _all_gateway_error_classes(), ids=lambda c: c.__name__
)
def test_every_gateway_error_is_documented(cls):
    """Each subclass pins a code present in the registry and an
    http_status that agrees with it -- the property that keeps the server,
    the client decoder and docs/serving.md telling one story."""
    assert cls.code in ERROR_HTTP_STATUS, (
        f"{cls.__name__}.code = {cls.code!r} missing from ERROR_HTTP_STATUS"
    )
    assert cls.http_status == ERROR_HTTP_STATUS[cls.code]


@pytest.mark.parametrize("code", sorted(ERROR_HTTP_STATUS))
def test_every_code_round_trips_through_the_codec(code):
    status = ERROR_HTTP_STATUS[code]
    body = wire.encode_error(code, "why it failed")
    with pytest.raises(wire.RemoteError) as ei:
        wire.decode_response(body, http_status=status)
    assert ei.value.code == code
    assert ei.value.http_status == status
    assert "why it failed" in ei.value.message


# ---------------------------------------------------------------------------
# live-HTTP trigger table
# ---------------------------------------------------------------------------
def small_hw():
    return enumerate_hw_space(MAXWELL, max_area=650.0).downsample(STRIDE)


@pytest.fixture(scope="module")
def fleet():
    """Two sweep artifacts + one non-sweep manifest behind a live gateway
    whose resilience bundle the tests can reach (and swap)."""
    root = tempfile.mkdtemp(prefix="errfleet-")
    store = ArtifactStore(root)
    wl = paper_workload(STENCILS)
    hw = small_hw()
    keys = {}
    for gpu in (MAXWELL_GPU, TITANX_GPU):
        srv = CodesignServer(
            store, workload=wl, gpu=gpu, hw=hw, engine="numpy",
            batch_window=0.0,
        )
        srv.ensure_artifact()
        keys[gpu.name] = srv.key
    telemetry_key = store.put_json(
        "telemetry", {"collected_at": 0.0}, routing={"workload": "t"}
    ).key
    gw = Gateway(root, pool_size=2, batch_window=0.0,
                 resilience=GatewayResilience())
    httpd = serve_http(gw)
    threading.Thread(target=httpd.serve_forever, daemon=True).start()
    url = "http://%s:%d" % httpd.server_address[:2]
    yield gw, url, keys, telemetry_key
    httpd.shutdown()
    httpd.server_close()


def _post(url, body, path="/v1/query", headers=None):
    req = urllib.request.Request(
        url + path, data=body, method="POST",
        headers={"Content-Type": "application/json", **(headers or {})},
    )
    try:
        with urllib.request.urlopen(req) as r:
            return r.status, dict(r.headers), r.read()
    except urllib.error.HTTPError as e:
        return e.code, dict(e.headers), e.read()


def _assert_error(status, body, code):
    assert status == ERROR_HTTP_STATUS[code], (status, body)
    payload = json.loads(body)
    assert payload["ok"] is False
    assert payload["error"]["code"] == code
    assert payload["error"]["message"]


def _q(**kw):
    return wire.encode_request(QueryRequest(use_cache=False), **kw)


def test_http_bad_request(fleet):
    _, url, keys, _ = fleet
    status, _, body = _post(
        url, b'{"v": 1, "request": {"max_area": "plenty"}}'
    )
    _assert_error(status, body, "bad_request")


def test_http_unsupported_version(fleet):
    _, url, _, _ = fleet
    status, _, body = _post(url, b'{"v": 99, "request": {}}')
    _assert_error(status, body, "unsupported_version")


def test_http_unknown_artifact(fleet):
    _, url, _, _ = fleet
    status, _, body = _post(url, _q(artifact="0" * 64))
    _assert_error(status, body, "unknown_artifact")


def test_http_ambiguous_route(fleet):
    _, url, _, _ = fleet
    status, _, body = _post(url, _q())  # two artifacts, no selector
    _assert_error(status, body, "ambiguous_route")


def test_http_wrong_artifact_kind(fleet):
    _, url, _, telemetry_key = fleet
    status, _, body = _post(url, _q(artifact=telemetry_key))
    _assert_error(status, body, "wrong_artifact_kind")


def test_http_not_found(fleet):
    _, url, _, _ = fleet
    status, _, body = _post(url, b"{}", path="/v1/nope")
    _assert_error(status, body, "not_found")


def test_http_deadline_exceeded_envelope_and_header(fleet):
    _, url, keys, _ = fleet
    key = keys[MAXWELL_GPU.name]
    # a microscopic envelope budget is spent before the resolve stage
    status, _, body = _post(
        url, _q(artifact=key, deadline_ms=1e-6)
    )
    _assert_error(status, body, "deadline_exceeded")
    # header spelling, same contract
    status, _, body = _post(
        url, _q(artifact=key),
        headers={"X-Repro-Deadline-Ms": "0.000001"},
    )
    _assert_error(status, body, "deadline_exceeded")
    # a generous budget answers normally (and the envelope field is
    # accepted, not rejected as an unknown key)
    status, _, body = _post(url, _q(artifact=key, deadline_ms=60000))
    assert status == 200 and json.loads(body)["ok"] is True


def test_http_deadline_header_garbage_is_bad_request(fleet):
    _, url, keys, _ = fleet
    status, _, body = _post(
        url, _q(artifact=keys[MAXWELL_GPU.name]),
        headers={"X-Repro-Deadline-Ms": "soon"},
    )
    _assert_error(status, body, "bad_request")


def test_http_rate_limited_with_retry_after(fleet):
    gw, url, keys, _ = fleet
    saved = gw.resilience
    gw.resilience = GatewayResilience(global_rate=0.001, global_burst=1.0)
    try:
        body = _q(artifact=keys[MAXWELL_GPU.name])
        status, _, _ = _post(url, body)
        assert status == 200  # the one burst token
        status, headers, raw = _post(url, body)
        _assert_error(status, raw, "rate_limited")
        assert int(headers["Retry-After"]) >= 1
    finally:
        gw.resilience = saved


def test_http_shed_with_retry_after(fleet):
    gw, url, keys, _ = fleet
    saved = gw.resilience
    gw.resilience = GatewayResilience(max_inflight=1)
    try:
        # occupy the single in-flight slot from in-process, then knock
        holder = gw.resilience.admission.admit("holder")
        holder.__enter__()
        try:
            status, headers, raw = _post(
                url, _q(artifact=keys[MAXWELL_GPU.name])
            )
            _assert_error(status, raw, "shed")
            assert "Retry-After" in headers
        finally:
            holder.__exit__(None, None, None)
        status, _, _ = _post(url, _q(artifact=keys[MAXWELL_GPU.name]))
        assert status == 200
    finally:
        gw.resilience = saved


def test_http_circuit_open_with_retry_after(fleet):
    gw, url, keys, _ = fleet
    key = keys[TITANX_GPU.name]
    with gw._mu:
        gw._pool.pop(key, None)  # force the next query through the breaker
    breaker = gw.resilience.breaker(key)
    for _ in range(breaker.threshold):
        with pytest.raises(OSError):
            with breaker.call():
                raise OSError("simulated store failure")
    try:
        status, headers, raw = _post(url, _q(artifact=key))
        _assert_error(status, raw, "circuit_open")
        assert int(headers["Retry-After"]) >= 1
    finally:
        gw.resilience._breakers.pop(key, None)
    status, _, _ = _post(url, _q(artifact=key))
    assert status == 200


def test_http_query_many_deadline_classifies_elements(fleet):
    """An envelope deadline on /v1/query_many answers 200 with per-element
    deadline_exceeded pairs -- batch semantics, not a blanket 504."""
    _, url, keys, _ = fleet
    key = keys[MAXWELL_GPU.name]
    body = wire.encode_request_many(
        [(QueryRequest(use_cache=False), key, None)] * 3, deadline_ms=1e-6
    )
    status, _, raw = _post(url, body, path="/v1/query_many")
    assert status == 200
    payload = json.loads(raw)
    assert payload["ok"] is True
    for row in payload["results"]:
        assert row["ok"] is False
        assert row["error"]["code"] == "deadline_exceeded"


def test_in_process_matches_http_statuses(fleet):
    """The in-process exception carries the same status the wire answers:
    no drift between `except GatewayError` callers and HTTP clients."""
    gw, url, _, _ = fleet
    with pytest.raises(GatewayError) as ei:
        gw.query(QueryRequest(use_cache=False), artifact="0" * 64)
    status, _, _ = _post(url, _q(artifact="0" * 64))
    assert ei.value.http_status == status == 404
