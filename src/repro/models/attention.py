"""Attention variants: GQA/MHA, sliding-window (SWA), MLA (DeepSeek), and
cross-attention -- with a unified KV-cache contract for serving.

Cache contract (built by ``repro.serve.kvcache``):
* GQA/SWA/cross: ``{"k": (B, L, KH, Dk), "v": (B, L, KH, Dv), "idx": ()}``
  -- ``idx`` is the number of tokens already written; keys are stored
  *post-RoPE*. SWA caches are ring buffers of length ``window``.
* MLA: ``{"ckv": (B, L, r_kv), "krope": (B, L, Dr), "idx": ()}`` -- the
  compressed latent is cached (MLA's raison d'etre) and decode uses the
  absorbed-matmul path, so per-token memory is O(r_kv + Dr), not O(H*Dh).

Long sequences (prefill_32k and up) use a chunked online-softmax
implementation (lax.scan over KV blocks inside lax.map over Q blocks) so
activation memory is O(S * block), not O(S^2).
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import ArchConfig
from .layers import apply_rope, dense_init, mrope_rotate, rmsnorm, rmsnorm_init

__all__ = ["attn_init", "attention", "NEG_INF"]

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)

# Sequences at or above this length take the chunked path under impl="auto".
CHUNKED_THRESHOLD = 8192
Q_CHUNK = 1024
K_CHUNK = 1024


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------
def attn_init(key, cfg: ArchConfig, dtype, cross: bool = False) -> Dict:
    d, h, kh, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    a = cfg.attn
    if a.kind == "mla" and not cross:
        r_q, r_kv, dr, dv = a.q_lora_rank, a.kv_lora_rank, a.rope_head_dim, a.v_head_dim
        k1, k2, k3, k4, k5 = jax.random.split(key, 5)
        return {
            "wq_a": dense_init(k1, (d, r_q), dtype),
            "q_norm": rmsnorm_init(r_q, dtype),
            "wq_b": dense_init(k2, (r_q, h * (dh + dr)), dtype),
            "wkv_a": dense_init(k3, (d, r_kv + dr), dtype),
            "kv_norm": rmsnorm_init(r_kv, dtype),
            "wkv_b": dense_init(k4, (r_kv, h * (dh + dv)), dtype),
            "wo": dense_init(k5, (h * dv, d), dtype),
        }
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": dense_init(k1, (d, h * dh), dtype),
        "wk": dense_init(k2, (d, kh * dh), dtype),
        "wv": dense_init(k3, (d, kh * dh), dtype),
        "wo": dense_init(k4, (h * dh, d), dtype),
    }


# ---------------------------------------------------------------------------
# Masked softmax-attention over explicit K/V (grouped heads)
# ---------------------------------------------------------------------------
def _mask_bias(q_pos, k_pos, mode: str, window: int):
    """(B, Sq, Lk) additive f32 bias. k_pos < 0 marks invalid cache slots."""
    q = q_pos[:, :, None].astype(jnp.int32)
    k = k_pos[:, None, :].astype(jnp.int32)
    ok = k >= 0
    if mode == "causal":
        ok &= k <= q
        if window:
            ok &= (q - k) < window
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def _constrain_batch_heads(x):
    """Best-effort wsc pinning (batch -> data, kv-heads -> model) on the
    attention score/prob tensors (B, KH, G, Sq, L). Without it, GSPMD's
    propagation can resolve the softmax+bias chain by replicating the whole
    quadratic attention path across the data axis (measured 4x FLOP
    inflation on deepseek MLA -- EXPERIMENTS.md §Perf). No-op outside a
    mesh context or when dims do not divide."""
    for spec in (
        P(("pod", "data"), "model", None, None, None),
        P("data", "model", None, None, None),
        P("data", None, None, None, None),
    ):
        try:
            return jax.lax.with_sharding_constraint(x, spec)
        except Exception:  # noqa: BLE001 -- axis absent / indivisible
            continue
    return x


def _sdpa(q, k, v, bias, scale):
    """q: (B,Sq,H,Dk) k: (B,Lk,KH,Dk) v: (B,Lk,KH,Dv) bias: (B,Sq,Lk)."""
    b, sq, h, dk = q.shape
    kh = k.shape[2]
    g = h // kh
    qg = q.reshape(b, sq, kh, g, dk)
    scores = jnp.einsum("bqkgd,blkd->bkgql", qg, k).astype(jnp.float32) * scale
    scores = scores + bias[:, None, None, :, :]
    scores = _constrain_batch_heads(scores)
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgql,blke->bqkge", w, v)
    return out.reshape(b, sq, h, v.shape[-1])


def _sdpa_chunked(q, k, v, q_pos, k_pos, mode, window, scale):
    """Online-softmax attention; O(S*block) activation memory."""
    b, sq, h, dk = q.shape
    lk = k.shape[1]
    kh = k.shape[2]
    g = h // kh
    dv = v.shape[-1]
    q_chunks = max(1, sq // Q_CHUNK) if sq % Q_CHUNK == 0 else -(-sq // Q_CHUNK)
    k_chunks = max(1, lk // K_CHUNK) if lk % K_CHUNK == 0 else -(-lk // K_CHUNK)
    # pad to chunk multiples
    sq_p, lk_p = q_chunks * Q_CHUNK, k_chunks * K_CHUNK
    qp = jnp.pad(q, ((0, 0), (0, sq_p - sq), (0, 0), (0, 0)))
    qpos = jnp.pad(q_pos, ((0, 0), (0, sq_p - sq)), constant_values=0)
    kp = jnp.pad(k, ((0, 0), (0, lk_p - lk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, lk_p - lk), (0, 0), (0, 0)))
    kpos = jnp.pad(k_pos, ((0, 0), (0, lk_p - lk)), constant_values=-1)

    qp = qp.reshape(b, q_chunks, Q_CHUNK, kh, g, dk)
    kp = kp.reshape(b, k_chunks, K_CHUNK, kh, dk)
    vp = vp.reshape(b, k_chunks, K_CHUNK, kh, dv)
    qpos_c = qpos.reshape(b, q_chunks, Q_CHUNK)
    kpos_c = kpos.reshape(b, k_chunks, K_CHUNK)

    def q_block(args):
        qc, qpc = args  # (B, Qc, KH, G, Dk), (B, Qc)

        def kv_step(carry, inp):
            m, l, acc = carry
            kc, vc, kpc = inp  # (B, Kc, KH, Dk), (B, Kc, KH, Dv), (B, Kc)
            s = jnp.einsum("bqkgd,blkd->bkgql", qc, kc).astype(jnp.float32) * scale
            bias = _mask_bias(qpc, kpc, mode, window)
            s = s + bias[:, None, None, :, :]
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgql,blke->bkgqe", p.astype(vc.dtype), vc
            ).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, kh, g, Q_CHUNK), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kh, g, Q_CHUNK), jnp.float32)
        a0 = jnp.zeros((b, kh, g, Q_CHUNK, dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step,
            (m0, l0, a0),
            (
                jnp.moveaxis(kp, 1, 0),
                jnp.moveaxis(vp, 1, 0),
                jnp.moveaxis(kpos_c, 1, 0),
            ),
        )
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return jnp.moveaxis(out, 3, 1)  # (B, Qc, KH, G, Dv)

    outs = jax.lax.map(q_block, (jnp.moveaxis(qp, 1, 0), jnp.moveaxis(qpos_c, 1, 0)))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, sq_p, h, dv)[:, :sq]
    return out.astype(v.dtype)


def _attend(q, k, v, q_pos, k_pos, mode, window, impl):
    scale = 1.0 / math.sqrt(q.shape[-1])
    long_seq = max(q.shape[1], k.shape[1]) >= CHUNKED_THRESHOLD
    if impl == "chunked" or (impl == "auto" and long_seq and q.shape[1] > 1):
        return _sdpa_chunked(q, k, v, q_pos, k_pos, mode, window, scale)
    bias = _mask_bias(q_pos, k_pos, mode, window)
    return _sdpa(q, k, v, bias, scale)


# ---------------------------------------------------------------------------
# Cache write helpers
# ---------------------------------------------------------------------------
def _write_cache(cache: Dict, updates: Dict, positions, ring: int = 0) -> Dict:
    """Write S new entries into the cache at ``idx`` (ring-buffered if SWA).

    ``positions`` are the absolute token positions (B, S) of the updates;
    slot bookkeeping uses idx (same for all batch rows).
    """
    idx = cache["idx"]
    s = positions.shape[1]
    new = dict(cache)
    for name, val in updates.items():
        buf = cache[name]
        cap = buf.shape[1]
        if ring and s >= cap:
            # keep only the last `cap` entries, ring-placed
            tail = val[:, -cap:]
            tail_pos = (idx + jnp.arange(s - cap, s)) % cap
            new[name] = buf.at[:, tail_pos].set(tail.astype(buf.dtype))
        elif ring:
            slots = (idx + jnp.arange(s)) % cap
            new[name] = buf.at[:, slots].set(val.astype(buf.dtype))
        else:
            new[name] = jax.lax.dynamic_update_slice_in_dim(
                buf, val.astype(buf.dtype), idx, axis=1
            )
    new["idx"] = idx + s
    return new


def _cache_positions(cache: Dict, ring: int = 0) -> jnp.ndarray:
    """Absolute position per cache slot, -1 for unwritten slots. (B, L)."""
    idx = cache["idx"]
    first = next(k for k in cache if k != "idx")
    b, cap = cache[first].shape[:2]
    slots = jnp.arange(cap)
    if ring:
        # slot s holds position p where p % cap == s, for the last `cap` p's
        newest = idx - 1
        pos = newest - ((newest - slots) % cap)
        pos = jnp.where((pos >= 0) & (pos < idx), pos, -1)
    else:
        pos = jnp.where(slots < idx, slots, -1)
    return jnp.broadcast_to(pos[None, :], (b, cap))


# ---------------------------------------------------------------------------
# Public entry
# ---------------------------------------------------------------------------
def attention(
    params: Dict,
    cfg: ArchConfig,
    x: jnp.ndarray,
    *,
    positions: jnp.ndarray,
    mode: str = "causal",  # causal | bidir | cross
    cache: Optional[Dict] = None,
    kv_source: Optional[jnp.ndarray] = None,  # encoder states for cross-attn
    impl: str = "auto",
) -> Tuple[jnp.ndarray, Optional[Dict]]:
    """Returns (output (B,S,d), updated cache or None).

    * training/encoder: ``cache=None`` -- K/V computed inline.
    * prefill: pass a fresh cache; S tokens are written, attention runs
      against the inline K/V (cheaper than reading back).
    * decode: pass the live cache; S == 1 (or a small chunk) is appended and
      attention runs against the cache contents.
    """
    a = cfg.attn
    if a.kind == "mla" and mode != "cross":
        return _mla_attention(params, cfg, x, positions, cache, impl)

    h, kh, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    b, s, _ = x.shape
    ring = a.window if a.kind == "swa" else 0
    is_mrope = cfg.rope == "mrope"
    pos_ids = positions[:, 0] if is_mrope else positions  # (B,S) temporal ids

    q = jnp.einsum("bsd,de->bse", x, params["wq"]).reshape(b, s, h, dh)

    if mode == "cross":
        if cache is not None and kv_source is None:
            k, v = cache["k"], cache["v"]  # precomputed at prefill
            k_pos = _cache_positions(cache)
            out = _attend(q, k, v, pos_ids, k_pos, "bidir", 0, impl)
            return _po(params, out, b, s), cache
        assert kv_source is not None
        lk = kv_source.shape[1]
        k = jnp.einsum("bld,de->ble", kv_source, params["wk"]).reshape(b, lk, kh, dh)
        v = jnp.einsum("bld,de->ble", kv_source, params["wv"]).reshape(b, lk, kh, dh)
        k_pos = jnp.broadcast_to(jnp.arange(lk)[None], (b, lk))
        out = _attend(q, k, v, pos_ids, k_pos, "bidir", 0, impl)
        if cache is not None:
            cache = _write_cache(cache, {"k": k, "v": v}, k_pos)
        return _po(params, out, b, s), cache

    k = jnp.einsum("bsd,de->bse", x, params["wk"]).reshape(b, s, kh, dh)
    v = jnp.einsum("bsd,de->bse", x, params["wv"]).reshape(b, s, kh, dh)
    if cfg.rope in ("standard",):
        q = apply_rope(q, pos_ids, cfg.rope_theta)
        k = apply_rope(k, pos_ids, cfg.rope_theta)
    elif is_mrope:
        q = mrope_rotate(q, positions, cfg.mrope_sections, cfg.rope_theta)
        k = mrope_rotate(k, positions, cfg.mrope_sections, cfg.rope_theta)
    # learned/sinusoidal positions are added at the embedding level

    window = a.window if a.kind == "swa" else 0
    if cache is None:
        out = _attend(q, k, v, pos_ids, pos_ids, mode, window, impl)
        return _po(params, out, b, s), None

    prefill = s > 1
    cache = _write_cache(cache, {"k": k, "v": v}, pos_ids, ring=ring)
    if prefill:
        # inline K/V already cover every valid key (ring keeps last window)
        out = _attend(q, k, v, pos_ids, pos_ids, mode, window, impl)
    else:
        k_pos = _cache_positions(cache, ring=ring)
        out = _attend(q, cache["k"], cache["v"], pos_ids, k_pos, mode, window, impl)
    return _po(params, out, b, s), cache


def _po(params, out, b, s):
    """Output projection over flattened heads."""
    return jnp.einsum("bsf,fd->bsd", out.reshape(b, s, -1), params["wo"])


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V3): low-rank latent KV + decoupled RoPE
# ---------------------------------------------------------------------------
def _mla_project_q(params, cfg, x, pos_ids):
    a = cfg.attn
    b, s, _ = x.shape
    h, dh, dr = cfg.n_heads, cfg.head_dim_, a.rope_head_dim
    q_lat = jnp.einsum("bsd,dr->bsr", x, params["wq_a"])
    q_lat = rmsnorm(params["q_norm"], q_lat)
    q = jnp.einsum("bsr,re->bse", q_lat, params["wq_b"]).reshape(b, s, h, dh + dr)
    q_nope, q_rope = q[..., :dh], q[..., dh:]
    q_rope = apply_rope(q_rope, pos_ids, cfg.rope_theta)
    return q_nope, q_rope


def _mla_latents(params, cfg, x, pos_ids):
    a = cfg.attn
    kv = jnp.einsum("bsd,dr->bsr", x, params["wkv_a"])
    ckv, k_rope = kv[..., : a.kv_lora_rank], kv[..., a.kv_lora_rank :]
    ckv = rmsnorm(params["kv_norm"], ckv)
    k_rope = apply_rope(k_rope[:, :, None, :], pos_ids, cfg.rope_theta)[:, :, 0]
    return ckv, k_rope  # (B,S,r_kv), (B,S,Dr)


def _mla_attention(params, cfg, x, positions, cache, impl):
    a = cfg.attn
    b, s, _ = x.shape
    h, dh, dr, dv = cfg.n_heads, cfg.head_dim_, a.rope_head_dim, a.v_head_dim
    r_kv = a.kv_lora_rank
    pos_ids = positions
    q_nope, q_rope = _mla_project_q(params, cfg, x, pos_ids)
    ckv, k_rope = _mla_latents(params, cfg, x, pos_ids)
    scale = 1.0 / math.sqrt(dh + dr)

    wkv_b = params["wkv_b"].reshape(r_kv, h, dh + dv)
    wk_b, wv_b = wkv_b[..., :dh], wkv_b[..., dh:]

    decode = cache is not None and s == 1
    if cache is not None:
        cache = _write_cache(cache, {"ckv": ckv, "krope": k_rope}, pos_ids)

    if not decode:
        # train/prefill: expand per-position K/V (activation-sized, fine)
        k_nope = jnp.einsum("blr,rhe->blhe", ckv, wk_b)
        v = jnp.einsum("blr,rhe->blhe", ckv, wv_b)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (b, s, h, dr))], axis=-1
        )
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        out = _attend(q, k, v, pos_ids, pos_ids, "causal", 0, impl)
    else:
        # absorbed decode: score/context in latent space, O(L * r_kv)
        l = cache["ckv"].shape[1]
        k_pos = _cache_positions(cache)
        q_lat = jnp.einsum("bshe,rhe->bshr", q_nope, wk_b)  # absorb W^UK
        s_lat = jnp.einsum("bshr,blr->bhsl", q_lat, cache["ckv"]).astype(jnp.float32)
        s_rope = jnp.einsum("bshe,ble->bhsl", q_rope, cache["krope"]).astype(
            jnp.float32
        )
        scores = (s_lat + s_rope) * scale
        bias = _mask_bias(pos_ids, k_pos, "causal", 0)
        scores = scores + bias[:, None, :, :]
        w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
        ctx_lat = jnp.einsum("bhsl,blr->bshr", w, cache["ckv"])
        out = jnp.einsum("bshr,rhe->bshe", ctx_lat, wv_b)  # expand W^UV
    return (
        jnp.einsum("bsf,fd->bsd", out.reshape(b, s, h * dv), params["wo"]),
        cache,
    )
