"""Config registry (repro.configs): deterministic auto-discovery of every
config module, the ``get``/``list_archs`` lookup API, duplicate-name
rejection, and re-import idempotence."""

import importlib
import pkgutil

import pytest

import repro.configs as configs_pkg
from repro.configs import ARCHS, ArchConfig, get, get_arch, list_archs, register

#: every named architecture the repo carries; a config module whose
#: register() call went missing fails this list, not just its own tests.
EXPECTED = (
    "deepseek-v3-671b",
    "gemma-7b",
    "internlm2-1.8b",
    "jamba-v0.1-52b",
    "llama3-8b",
    "mamba2-780m",
    "minitron-4b",
    "mixtral-8x22b",
    "qwen2-vl-2b",
    "whisper-medium",
)


def test_listing_is_sorted_deterministic_and_complete():
    names = list_archs()
    assert names == tuple(sorted(names))
    assert names == EXPECTED
    assert list_archs() == names  # stable across calls


def test_get_resolves_every_listed_arch():
    for name in list_archs():
        cfg = get(name)
        assert isinstance(cfg, ArchConfig)
        assert cfg.name == name
        assert get_arch(name) is cfg  # `get` is the alias, same object


def test_get_unknown_name_is_a_keyerror_listing_known():
    with pytest.raises(KeyError, match="unknown arch"):
        get("llama3-8b-typo")


def test_every_config_module_registers_exactly_its_archs():
    """Auto-discovery imports every non-underscore module; each registered
    arch must be attributable to exactly one import (no module registers
    under another's name, no unregistered stragglers)."""
    modules = [
        m.name
        for m in pkgutil.iter_modules(configs_pkg.__path__)
        if not m.name.startswith("_") and m.name != "base"
    ]
    for name in modules:
        importlib.import_module(f"repro.configs.{name}")
    assert set(ARCHS) == set(EXPECTED)


def test_duplicate_registration_rejected():
    cfg = get("llama3-8b")
    with pytest.raises(ValueError, match="duplicate"):
        register(cfg)
    assert get("llama3-8b") is cfg  # failed re-register leaves it intact


def test_reimport_is_idempotent():
    """Re-running the discovery module must not re-execute config modules
    (sys.modules guards them), so no duplicate-registration blowups."""
    importlib.reload(importlib.import_module("repro.configs._register_all"))
    assert set(ARCHS) == set(EXPECTED)
