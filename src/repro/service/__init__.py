"""High-throughput codesign query service over precomputed sweep artifacts.

The eq.-18 separability decomposition caches per-cell/per-hardware optima
as a ``(cells x hardware)`` matrix; once persisted, every workload
question is a cheap vectorized re-reduction ("sensitivity for free",
paper §V.B). This package turns that observation into a serving system:

* :mod:`repro.service.store`   -- versioned, content-addressed on-disk
  artifacts (compressed npz + JSON manifest, mmap-backed lazy loads);
* :mod:`repro.service.query`   -- ``QueryRequest -> QueryResponse``
  re-reductions (mixes, top-k, Pareto, what-ifs) with an LRU;
* :mod:`repro.service.server`  -- thread-safe in-process server that
  microbatches concurrent queries into one ``(B, C) @ (C, H)`` matmul and
  falls back to the sweep engine exactly once on artifact miss;
* :mod:`repro.service.gateway` -- the fleet front door: discovers every
  artifact across store roots, routes each request by content key or
  selector (GPU / stencil set / workload), keeps an LRU-bounded pool of
  per-artifact servers, and serves it all over stdlib HTTP;
* :mod:`repro.service.portfolio` -- K-design fleet portfolios persisted as
  ``kind: "portfolio"`` manifests and the heterogeneity-aware
  ``/v1/route`` server over them -- see ``docs/portfolio.md``;
* :mod:`repro.service.wire`    -- the versioned HTTP/JSON codec (requests,
  responses, structured errors) -- see ``docs/serving.md``;
* :mod:`repro.service.client`  -- thin ``urllib`` client for a gateway;
* :mod:`repro.service.resilience` -- deadlines, admission control (token
  buckets + load shedding), circuit breakers and the client retry policy
  -- see ``docs/resilience.md``;
* :mod:`repro.service.faults`  -- deterministic fault injection behind the
  chaos harness (``scripts/chaos_smoke.py``);
* :mod:`repro.service.cli`     -- ``python -m repro.service.cli
  query|build|ls|serve`` (``query --url`` goes over HTTP).
"""

from . import faults  # noqa: F401
from .client import GatewayClient  # noqa: F401
from .errors import ERROR_HTTP_STATUS  # noqa: F401
from .resilience import (  # noqa: F401
    CircuitOpenError,
    Deadline,
    DeadlineExceededError,
    GatewayResilience,
    RateLimitedError,
    RetryPolicy,
    ShedError,
)
from .gateway import (  # noqa: F401
    AmbiguousRouteError,
    AmbiguousWorkloadError,
    Gateway,
    GatewayError,
    GatewayHTTPServer,
    UnknownArtifactError,
    WrongArtifactKindError,
    serve_http,
)
from .portfolio import (  # noqa: F401
    PortfolioExhaustedError,
    PortfolioServer,
    RouteRequest,
    RouteResponse,
    UnknownCellError,
    build_portfolio,
)
from .query import QueryEngine, QueryRequest, QueryResponse  # noqa: F401
from .server import CodesignServer, LMServer, server_from_artifact  # noqa: F401
from .store import (  # noqa: F401
    KINDS,
    Artifact,
    ArtifactStore,
    BuildLockTimeoutError,
    artifact_spec,
    spec_key,
)
from .wire import RemoteError, WireError  # noqa: F401
