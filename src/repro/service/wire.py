"""Versioned HTTP/JSON wire codec for the codesign query service.

This module is the single source of truth for how a
:class:`repro.service.query.QueryRequest` and its
:class:`~repro.service.query.QueryResponse` cross a process boundary.
Everything else (the gateway's HTTP handler, the thin client, the CLI's
``--url`` mode, the CI smoke lane) encodes and decodes through these four
functions, so the in-process objects and the wire can never drift apart:

* :func:`encode_request` / :func:`decode_request` -- request envelope
  (``{"v", "artifact", "route", "request"}`` plus two optional fields:
  a ``"trace": true`` observability opt-in and a ``"deadline_ms"`` time
  budget, surfaced by :func:`decode_request_traced` /
  :func:`decode_request_full`);
* :func:`encode_response` / :func:`decode_response` -- response envelope
  (``{"v", "ok", "response"}`` on success, ``{"v", "ok", "error"}`` on
  failure; a traced request's answer additionally carries ``"trace"``,
  read back by :func:`decode_response_traced`);
* :func:`encode_error` -- structured error payloads (``code`` +
  ``message``), never tracebacks.

Design rules (documented for clients in ``docs/serving.md``):

* **Canonical bytes.** Encoders emit ``sort_keys=True`` +
  ``separators=(",", ":")`` JSON, and Python's ``repr``-based float
  serialization round-trips every float64 exactly. Encoding is therefore
  deterministic: the same ``QueryResponse`` always produces the same
  bytes, which is what lets tests (and the CI smoke lane) assert that an
  HTTP answer is *byte-identical* to the in-process answer.
* **Non-finite floats.** Strict JSON has no ``inf``/``nan``, but the
  service's contract does (``best_gflops = -inf`` means "no feasible
  design"). Non-finite floats are encoded as a tagged object
  ``{"$f": "inf" | "-inf" | "nan"}`` and decoded back to the exact float.
* **Versioning.** Every envelope carries ``"v": WIRE_VERSION``. A server
  rejects requests whose major version it does not speak
  (``unsupported_version``); a *client* decoding a response tolerates
  unknown **response** fields (servers may add fields within a version),
  while a *server* rejects unknown **request** fields (a typo'd field
  silently ignored would answer the wrong question).
"""

from __future__ import annotations

import dataclasses
import json
import math
from typing import Any, Dict, Mapping, Optional, Sequence, Tuple, Union

import numpy as np

from .errors import ERROR_HTTP_STATUS  # noqa: F401  (re-export: THE registry)
from .portfolio import RouteRequest, RouteResponse
from .query import QueryRequest, QueryResponse

__all__ = [
    "WIRE_VERSION",
    "MAX_BATCH",
    "ERROR_HTTP_STATUS",
    "WireError",
    "RemoteError",
    "encode_request",
    "decode_request",
    "decode_request_traced",
    "decode_request_full",
    "encode_request_many",
    "decode_request_many",
    "decode_request_many_full",
    "encode_response",
    "decode_response",
    "decode_response_traced",
    "encode_response_many",
    "decode_response_many",
    "encode_route_request",
    "decode_route_request",
    "decode_route_request_full",
    "encode_route_response",
    "decode_route_response",
    "encode_slo_response",
    "decode_slo_response",
    "encode_exemplars_response",
    "decode_exemplars_response",
    "encode_error",
]

#: Wire (envelope) version. Bump only for incompatible envelope changes;
#: additive response fields do NOT bump it (clients ignore unknowns).
#: Adding the /v1/query_many envelope was additive (new endpoint, same
#: per-query objects), so it did not bump the version.
WIRE_VERSION = 1

#: upper bound on queries per /v1/query_many envelope: a fat-finger guard
#: (a million-query body would be decoded before any answer could say no),
#: not a throughput ceiling -- clients chunk above it.
MAX_BATCH = 1024

# ERROR_HTTP_STATUS -- THE code -> HTTP status registry -- is defined in
# the dependency-leaf :mod:`repro.service.errors` (the store needs it too
# and cannot import this module) and re-exported here unchanged: clients
# keep reading ``wire.ERROR_HTTP_STATUS``. One table, both directions:
# adding an error code means adding it THERE.

#: request fields a v1 server accepts, mirroring QueryRequest exactly.
_REQUEST_FIELDS = frozenset(f.name for f in dataclasses.fields(QueryRequest))

#: route-request fields, mirroring RouteRequest exactly (same strictness).
_ROUTE_REQUEST_FIELDS = frozenset(f.name for f in dataclasses.fields(RouteRequest))


class WireError(ValueError):
    """A request that cannot be decoded (malformed JSON, wrong types,
    unknown fields, unsupported version). Maps to HTTP 400."""

    def __init__(self, message: str, code: str = "bad_request"):
        super().__init__(message)
        self.code = code


class RemoteError(RuntimeError):
    """A structured error answer from a gateway (the client-side mirror of
    :func:`encode_error`); carries the server's ``code`` and HTTP status."""

    def __init__(self, code: str, message: str, http_status: int = 0):
        super().__init__(f"[{code}] {message}")
        self.code = code
        self.message = message
        self.http_status = http_status


# ---------------------------------------------------------------------------
# float / array tagging
# ---------------------------------------------------------------------------
_NONFINITE = {"inf": math.inf, "-inf": -math.inf}


def _jsonify(obj: Any) -> Any:
    """Recursively convert to strict-JSON-safe values: numpy scalars/arrays
    to native, non-finite floats to ``{"$f": ...}`` tags."""
    if isinstance(obj, (np.floating, np.integer)):
        obj = obj.item()
    if isinstance(obj, float):
        if math.isfinite(obj):
            return obj
        if math.isnan(obj):
            return {"$f": "nan"}
        return {"$f": "inf" if obj > 0 else "-inf"}
    if isinstance(obj, np.ndarray):
        return [_jsonify(x) for x in obj.tolist()]
    if isinstance(obj, dict):
        return {str(k): _jsonify(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonify(x) for x in obj]
    return obj


def _unjsonify(obj: Any) -> Any:
    """Invert :func:`_jsonify` (tags back to floats)."""
    if isinstance(obj, dict):
        if set(obj) == {"$f"}:
            tag = obj["$f"]
            if tag == "nan":
                return math.nan
            if tag in _NONFINITE:
                return _NONFINITE[tag]
            raise WireError(f"unknown non-finite float tag {tag!r}")
        return {k: _unjsonify(v) for k, v in obj.items()}
    if isinstance(obj, list):
        return [_unjsonify(x) for x in obj]
    return obj


def _dumps(obj: Any) -> bytes:
    return json.dumps(
        _jsonify(obj), sort_keys=True, separators=(",", ":"), allow_nan=False
    ).encode()


def _loads(data: bytes) -> Any:
    try:
        return json.loads(data.decode("utf-8"))
    except (UnicodeDecodeError, json.JSONDecodeError) as e:
        raise WireError(f"malformed JSON: {e}") from e


def _check_version(obj: Any, what: str) -> None:
    if not isinstance(obj, dict):
        raise WireError(f"{what} must be a JSON object, got {type(obj).__name__}")
    v = obj.get("v")
    if v != WIRE_VERSION:
        raise WireError(
            f"unsupported wire version {v!r} (this endpoint speaks v{WIRE_VERSION})",
            code="unsupported_version",
        )


# ---------------------------------------------------------------------------
# requests
# ---------------------------------------------------------------------------
def encode_request(
    request: QueryRequest,
    artifact: Optional[str] = None,
    route: Optional[Mapping[str, Any]] = None,
    trace: bool = False,
    deadline_ms: Optional[float] = None,
) -> bytes:
    """Serialize one query. ``artifact`` pins a content-address key;
    ``route`` is a routing selector the gateway resolves (e.g.
    ``{"gpu": "titanx"}``); both ``None`` is valid on a one-artifact
    gateway. ``trace=True`` asks the gateway to record spans for this
    request and return the span tree in the response envelope (see
    ``docs/observability.md``); ``deadline_ms`` is the caller's total
    time budget -- the gateway fails stages past it with a structured
    ``deadline_exceeded`` instead of piling on (``docs/resilience.md``).
    Both fields are omitted entirely when unset so capable clients emit
    byte-identical plain requests (and old servers, which reject unknown
    envelope fields, only ever see the fields the caller actually
    used)."""
    body: Dict[str, Any] = {
        "v": WIRE_VERSION,
        "request": dataclasses.asdict(request),
    }
    if artifact is not None:
        body["artifact"] = str(artifact)
    if route:
        body["route"] = dict(route)
    if trace:
        body["trace"] = True
    if deadline_ms is not None:
        body["deadline_ms"] = _check_deadline_ms(deadline_ms)
    return _dumps(body)


def _check_deadline_ms(value: Any) -> float:
    """Validate a ``deadline_ms`` budget (either side of the wire):
    a positive finite number, or WireError."""
    if isinstance(value, bool) or not isinstance(value, (int, float)):
        raise WireError(
            f"'deadline_ms' must be a positive number of milliseconds, "
            f"got {type(value).__name__}"
        )
    value = float(value)
    if not math.isfinite(value) or value <= 0:
        raise WireError(
            f"'deadline_ms' must be a positive finite number, got {value!r}"
        )
    return value


def decode_request(data: bytes) -> Tuple[QueryRequest, Optional[str], Optional[dict]]:
    """Bytes -> ``(QueryRequest, artifact_key, route)``.

    Raises :class:`WireError` on malformed JSON, a version this codec does
    not speak, non-object envelopes, or unknown request fields (strict on
    purpose: a silently dropped field would answer a different question
    than the client asked).
    """
    request, artifact, route, _ = decode_request_traced(data)
    return request, artifact, route


def decode_request_traced(
    data: bytes,
) -> Tuple[QueryRequest, Optional[str], Optional[dict], bool]:
    """Like :func:`decode_request` but also surfaces the envelope's
    optional ``trace`` flag as a fourth element (False when absent).
    In-process callers that don't care keep the 3-tuple
    :func:`decode_request`."""
    return decode_request_full(data)[:4]


def decode_request_full(
    data: bytes,
) -> Tuple[QueryRequest, Optional[str], Optional[dict], bool, Optional[float]]:
    """The whole v1 request envelope: ``(request, artifact, route,
    traced, deadline_ms)``. The HTTP handler decodes through this;
    ``deadline_ms`` is None when the caller set no budget."""
    obj = _loads(data)
    _check_version(obj, "request envelope")
    unknown = set(obj) - {"v", "artifact", "route", "request", "trace",
                          "deadline_ms"}
    if unknown:
        raise WireError(f"unknown envelope fields {sorted(unknown)}")
    traced = obj.get("trace", False)
    if not isinstance(traced, bool):
        raise WireError("'trace' must be a boolean")
    deadline_ms = obj.get("deadline_ms")
    if deadline_ms is not None:
        deadline_ms = _check_deadline_ms(deadline_ms)
    return (*_decode_query(obj), traced, deadline_ms)


def _decode_query(obj: dict) -> Tuple[QueryRequest, Optional[str], Optional[dict]]:
    """Shared body of the single and batched request decoders: one
    ``{artifact?, route?, request}`` object -> the routed-query triple."""
    artifact = obj.get("artifact")
    if artifact is not None and not isinstance(artifact, str):
        raise WireError("'artifact' must be a string key")
    route = obj.get("route")
    if route is not None and not isinstance(route, dict):
        raise WireError("'route' must be an object of selector: value pairs")
    req = obj.get("request")
    if not isinstance(req, dict):
        raise WireError("'request' must be an object (the QueryRequest fields)")
    req = _unjsonify(req)
    unknown = set(req) - _REQUEST_FIELDS
    if unknown:
        raise WireError(
            f"unknown request fields {sorted(unknown)} "
            f"(v{WIRE_VERSION} accepts {sorted(_REQUEST_FIELDS)})"
        )
    try:
        # coerce scalars so garbage fails HERE (bad_request) rather than
        # deep inside the engine -- and so a JSON "450" behaves like 450
        # instead of poisoning later comparisons with a str
        for name, conv in (("max_area", float), ("min_area", float),
                           ("top_k", int)):
            if name in req:
                req[name] = conv(req[name])
        for name in ("pareto", "use_cache"):
            if name in req and not isinstance(req[name], bool):
                raise WireError(f"{name!r} must be a boolean")
        request = QueryRequest(**req)
        if request.freqs is not None and not isinstance(request.freqs, dict):
            raise WireError("'freqs' must be an object of stencil: weight")
        if request.fix is not None and not isinstance(request.fix, dict):
            raise WireError("'fix' must be an object of param: value")
    except WireError:
        raise
    except (TypeError, ValueError) as e:
        raise WireError(f"bad request field: {e}") from e
    return request, artifact, route


def encode_request_many(
    queries: Sequence[
        Tuple[QueryRequest, Optional[str], Optional[Mapping[str, Any]]]
    ],
    deadline_ms: Optional[float] = None,
) -> bytes:
    """Serialize a ``POST /v1/query_many`` envelope: each element is a
    ``(request, artifact, route)`` triple exactly as :func:`encode_request`
    takes them, carried in one body so N queries cost one round trip.
    ``deadline_ms`` (optional, omitted when unset) budgets the whole
    batch, not each element."""
    items = []
    for request, artifact, route in queries:
        body: Dict[str, Any] = {"request": dataclasses.asdict(request)}
        if artifact is not None:
            body["artifact"] = str(artifact)
        if route:
            body["route"] = dict(route)
        items.append(body)
    envelope: Dict[str, Any] = {"v": WIRE_VERSION, "queries": items}
    if deadline_ms is not None:
        envelope["deadline_ms"] = _check_deadline_ms(deadline_ms)
    return _dumps(envelope)


def decode_request_many(
    data: bytes,
) -> list:
    """Bytes -> list of ``(QueryRequest, artifact_key, route)`` triples.

    Strict like :func:`decode_request`: one malformed query fails the
    whole envelope with the offending index in the message (a server must
    not answer a batch it only partially understood -- per-query *routing
    and engine* failures, by contrast, are reported per query)."""
    return decode_request_many_full(data)[0]


def decode_request_many_full(
    data: bytes,
) -> Tuple[list, Optional[float]]:
    """Like :func:`decode_request_many` but also surfaces the envelope's
    optional ``deadline_ms`` (the whole batch's budget; None when
    unset)."""
    obj = _loads(data)
    _check_version(obj, "request envelope")
    unknown = set(obj) - {"v", "queries", "deadline_ms"}
    if unknown:
        raise WireError(f"unknown envelope fields {sorted(unknown)}")
    deadline_ms = obj.get("deadline_ms")
    if deadline_ms is not None:
        deadline_ms = _check_deadline_ms(deadline_ms)
    queries = obj.get("queries")
    if not isinstance(queries, list) or not queries:
        raise WireError("'queries' must be a non-empty array of query objects")
    if len(queries) > MAX_BATCH:
        raise WireError(
            f"batch of {len(queries)} exceeds the {MAX_BATCH}-query cap; "
            "chunk the request"
        )
    out = []
    for i, q in enumerate(queries):
        if not isinstance(q, dict):
            raise WireError(f"queries[{i}] must be an object")
        unknown = set(q) - {"artifact", "route", "request"}
        if unknown:
            raise WireError(f"queries[{i}]: unknown fields {sorted(unknown)}")
        try:
            out.append(_decode_query(q))
        except WireError as e:
            raise WireError(f"queries[{i}]: {e}", code=e.code) from e
    return out, deadline_ms


# ---------------------------------------------------------------------------
# routing (POST /v1/route -- portfolio heterogeneity-aware routing)
# ---------------------------------------------------------------------------
def encode_route_request(
    request: RouteRequest,
    artifact: Optional[str] = None,
    route: Optional[Mapping[str, Any]] = None,
    deadline_ms: Optional[float] = None,
) -> bytes:
    """Serialize one ``POST /v1/route`` request. Same envelope shape as
    :func:`encode_request` (``artifact`` pins a portfolio's content key,
    ``route`` is a selector resolved among ``kind: "portfolio"``
    manifests, ``deadline_ms`` budgets the request); the ``request`` body
    carries the :class:`~repro.service.portfolio.RouteRequest` fields."""
    body: Dict[str, Any] = {
        "v": WIRE_VERSION,
        "request": dataclasses.asdict(request),
    }
    if artifact is not None:
        body["artifact"] = str(artifact)
    if route:
        body["route"] = dict(route)
    if deadline_ms is not None:
        body["deadline_ms"] = _check_deadline_ms(deadline_ms)
    return _dumps(body)


def decode_route_request(
    data: bytes,
) -> Tuple[RouteRequest, Optional[str], Optional[dict]]:
    """Bytes -> ``(RouteRequest, artifact_key, route)`` (strict, like
    :func:`decode_request`)."""
    return decode_route_request_full(data)[:3]


def decode_route_request_full(
    data: bytes,
) -> Tuple[RouteRequest, Optional[str], Optional[dict], Optional[float]]:
    """The whole v1 route envelope: ``(request, artifact, route,
    deadline_ms)``; the HTTP handler decodes through this."""
    obj = _loads(data)
    _check_version(obj, "request envelope")
    unknown = set(obj) - {"v", "artifact", "route", "request", "deadline_ms"}
    if unknown:
        raise WireError(f"unknown envelope fields {sorted(unknown)}")
    deadline_ms = obj.get("deadline_ms")
    if deadline_ms is not None:
        deadline_ms = _check_deadline_ms(deadline_ms)
    artifact = obj.get("artifact")
    if artifact is not None and not isinstance(artifact, str):
        raise WireError("'artifact' must be a string key")
    route = obj.get("route")
    if route is not None and not isinstance(route, dict):
        raise WireError("'route' must be an object of selector: value pairs")
    req = obj.get("request")
    if not isinstance(req, dict):
        raise WireError("'request' must be an object (the RouteRequest fields)")
    unknown = set(req) - _ROUTE_REQUEST_FIELDS
    if unknown:
        raise WireError(
            f"unknown request fields {sorted(unknown)} "
            f"(v{WIRE_VERSION} route accepts {sorted(_ROUTE_REQUEST_FIELDS)})"
        )
    cell = req.get("cell")
    if not isinstance(cell, str) or not cell:
        raise WireError("'cell' must be a non-empty string cell label")
    return RouteRequest(cell=cell), artifact, route, deadline_ms


def _route_response_payload(response: RouteResponse) -> Dict[str, Any]:
    """Canonical JSON-able body of one routing decision. ``degraded`` and
    ``fallback_from`` are always present (not elided when falsy): a
    client must be able to distinguish "healthy answer" from "old server
    that predates degradation marking" without guessing."""
    return {
        "portfolio_key": response.portfolio_key,
        "sweep_key": response.sweep_key,
        "cell": response.cell,
        "cell_indices": [int(i) for i in response.cell_indices],
        "hw_index": int(response.hw_index),
        "member_slot": int(response.member_slot),
        "point": dict(response.point),
        "time_s": float(response.time_s),
        "gflops": float(response.gflops),
        "degraded": bool(response.degraded),
        "fallback_from": [int(i) for i in response.fallback_from],
    }


def encode_route_response(response: RouteResponse) -> bytes:
    """Serialize a routing answer (canonical bytes, same determinism
    contract as :func:`encode_response` -- the gateway's ``/v1/route``
    byte-identity test encodes the in-process answer through this)."""
    return _dumps(
        {"v": WIRE_VERSION, "ok": True, "response": _route_response_payload(response)}
    )


def decode_route_response(data: bytes, http_status: int = 0) -> RouteResponse:
    """Bytes -> :class:`~repro.service.portfolio.RouteResponse`; a
    structured error envelope raises :class:`RemoteError`."""
    obj = _loads(data)
    _check_version(obj, "response envelope")
    if not obj.get("ok"):
        err = obj.get("error") or {}
        raise RemoteError(
            str(err.get("code", "unknown")),
            str(err.get("message", "(no message)")),
            http_status,
        )
    r = obj.get("response")
    if not isinstance(r, dict):
        raise WireError("'response' must be an object")
    r = _unjsonify(r)
    try:
        return RouteResponse(
            portfolio_key=str(r["portfolio_key"]),
            sweep_key=str(r["sweep_key"]),
            cell=str(r["cell"]),
            cell_indices=tuple(int(i) for i in r["cell_indices"]),
            hw_index=int(r["hw_index"]),
            member_slot=int(r["member_slot"]),
            point=dict(r["point"]),
            time_s=float(r["time_s"]),
            gflops=float(r["gflops"]),
            degraded=bool(r["degraded"]),
            fallback_from=tuple(int(i) for i in r["fallback_from"]),
        )
    except (KeyError, TypeError, ValueError) as e:
        raise WireError(f"bad route response field: {e}") from e


# ---------------------------------------------------------------------------
# responses / errors
# ---------------------------------------------------------------------------
def _response_payload(response: QueryResponse) -> Dict[str, Any]:
    """The canonical JSON-able body of one answer -- shared by the single
    and batched encoders so a query_many element is field-for-field the
    single-query payload (byte-identity composes)."""
    r: Dict[str, Any] = {
        "artifact_key": response.artifact_key,
        "best_index": int(response.best_index),
        "best_gflops": float(response.best_gflops),
        "best_weighted_time": float(response.best_weighted_time),
        "best_point": dict(response.best_point),
        "top_k": [dict(t) for t in response.top_k],
        "cached": bool(response.cached),
        "batch_size": int(response.batch_size),
    }
    if response.pareto_indices is not None:
        r["pareto_indices"] = [int(i) for i in np.asarray(response.pareto_indices)]
    if response.baseline_best_index is not None:
        r["baseline_best_index"] = int(response.baseline_best_index)
        r["baseline_best_gflops"] = float(response.baseline_best_gflops)
    return r


def encode_response(
    response: QueryResponse, trace: Optional[Mapping[str, Any]] = None
) -> bytes:
    """Serialize a success answer. Deterministic (canonical JSON), so two
    equal responses always encode to identical bytes -- the property the
    gateway's byte-identity acceptance test leans on. ``trace`` (a span
    tree from :meth:`repro.obs.trace.Span.root_tree`) is attached as an
    additive envelope field only when the request opted in; with
    ``trace=None`` the bytes are exactly the pre-tracing encoding, which
    is what preserves byte-identity for untraced requests."""
    body: Dict[str, Any] = {
        "v": WIRE_VERSION, "ok": True, "response": _response_payload(response)
    }
    if trace is not None:
        body["trace"] = dict(trace)
    return _dumps(body)


def decode_response(data: bytes, http_status: int = 0) -> QueryResponse:
    """Bytes -> :class:`QueryResponse`. A structured error envelope raises
    :class:`RemoteError`; unknown *response* fields are ignored (additive
    server evolution within a wire version)."""
    return decode_response_traced(data, http_status)[0]


def decode_response_traced(
    data: bytes, http_status: int = 0
) -> Tuple[QueryResponse, Optional[dict]]:
    """Like :func:`decode_response` but also returns the envelope's
    ``trace`` span tree (None when the request didn't opt in -- or the
    server predates tracing; the field is additive either way)."""
    obj = _loads(data)
    _check_version(obj, "response envelope")
    if not obj.get("ok"):
        err = obj.get("error") or {}
        raise RemoteError(
            str(err.get("code", "unknown")),
            str(err.get("message", "(no message)")),
            http_status,
        )
    trace = obj.get("trace")
    if trace is not None and not isinstance(trace, dict):
        trace = None
    return _parse_response_payload(obj.get("response")), trace


def _parse_response_payload(r: Any) -> QueryResponse:
    """One decoded-JSON response object -> :class:`QueryResponse` (the
    inverse of :func:`_response_payload`); shared by the single and
    batched decoders."""
    if not isinstance(r, dict):
        raise WireError("'response' must be an object")
    r = _unjsonify(r)
    pareto = r.get("pareto_indices")
    return QueryResponse(
        artifact_key=r["artifact_key"],
        best_index=int(r["best_index"]),
        best_gflops=float(r["best_gflops"]),
        best_weighted_time=float(r["best_weighted_time"]),
        best_point=r["best_point"],
        top_k=list(r["top_k"]),
        pareto_indices=None if pareto is None else np.asarray(pareto, np.int64),
        baseline_best_index=r.get("baseline_best_index"),
        baseline_best_gflops=r.get("baseline_best_gflops"),
        cached=bool(r.get("cached", False)),
        batch_size=int(r.get("batch_size", 1)),
    )


def encode_response_many(
    results: Sequence[Union[QueryResponse, Tuple[str, str]]],
) -> bytes:
    """Serialize a ``/v1/query_many`` answer. Each element is either a
    :class:`QueryResponse` (``{"ok": true, "response": ...}`` with the
    exact single-query payload) or a ``(code, message)`` pair for a query
    that failed routing/decoding/reduction (``{"ok": false, "error":
    ...}``) -- one bad query never fails its batchmates. The envelope
    itself is HTTP 200: per-query status lives per element."""
    items = []
    for r in results:
        if isinstance(r, QueryResponse):
            items.append({"ok": True, "response": _response_payload(r)})
        else:
            code, message = r
            items.append(
                {"ok": False, "error": {"code": str(code), "message": str(message)}}
            )
    return _dumps({"v": WIRE_VERSION, "ok": True, "results": items})


def decode_response_many(
    data: bytes, http_status: int = 0
) -> list:
    """Bytes -> list of :class:`QueryResponse` | :class:`RemoteError`
    (per-query failures are *returned*, not raised -- the caller decides
    what a partial batch means). A whole-envelope error (malformed batch,
    unsupported version) still raises. Per-element errors carry the HTTP
    status their *code* maps to on the single-query endpoint (the
    envelope itself is 200), so ``RemoteError.http_status`` means the
    same thing whichever endpoint produced it."""
    obj = _loads(data)
    _check_version(obj, "response envelope")
    if not obj.get("ok"):
        err = obj.get("error") or {}
        raise RemoteError(
            str(err.get("code", "unknown")),
            str(err.get("message", "(no message)")),
            http_status,
        )
    results = obj.get("results")
    if not isinstance(results, list):
        raise WireError("'results' must be an array")
    out = []
    for item in results:
        if not isinstance(item, dict):
            raise WireError("each query_many result must be an object")
        if item.get("ok"):
            out.append(_parse_response_payload(item.get("response")))
        else:
            err = item.get("error") or {}
            code = str(err.get("code", "unknown"))
            out.append(
                RemoteError(
                    code,
                    str(err.get("message", "(no message)")),
                    ERROR_HTTP_STATUS.get(code, 0),
                )
            )
    return out


# ---------------------------------------------------------------------------
# observability envelopes (GET /v1/slo, GET /v1/debug/exemplars)
# ---------------------------------------------------------------------------
def encode_slo_response(report: Mapping[str, Any]) -> bytes:
    """Serialize an SLO report (:meth:`repro.obs.slo.SLOTracker.report`)
    as the ``GET /v1/slo?format=json`` body. Canonical bytes, same
    determinism contract as every other envelope -- the golden corpus
    pins this encoding."""
    return _dumps({"v": WIRE_VERSION, "ok": True, "slo": dict(report)})


def decode_slo_response(data: bytes, http_status: int = 0) -> Dict[str, Any]:
    """Bytes -> the SLO report dict; a structured error envelope raises
    :class:`RemoteError`."""
    obj = _loads(data)
    _check_version(obj, "response envelope")
    if not obj.get("ok"):
        err = obj.get("error") or {}
        raise RemoteError(
            str(err.get("code", "unknown")),
            str(err.get("message", "(no message)")),
            http_status,
        )
    slo = obj.get("slo")
    if not isinstance(slo, dict):
        raise WireError("'slo' must be an object (the SLO report)")
    return _unjsonify(slo)


def encode_exemplars_response(payload: Mapping[str, Any]) -> bytes:
    """Serialize a tail-exemplar snapshot
    (:meth:`repro.obs.exemplar.ExemplarStore.snapshot`) as the
    ``GET /v1/debug/exemplars`` body."""
    return _dumps({"v": WIRE_VERSION, "ok": True, "exemplars": dict(payload)})


def decode_exemplars_response(data: bytes, http_status: int = 0) -> Dict[str, Any]:
    """Bytes -> the exemplar snapshot dict; a structured error envelope
    raises :class:`RemoteError`."""
    obj = _loads(data)
    _check_version(obj, "response envelope")
    if not obj.get("ok"):
        err = obj.get("error") or {}
        raise RemoteError(
            str(err.get("code", "unknown")),
            str(err.get("message", "(no message)")),
            http_status,
        )
    ex = obj.get("exemplars")
    if not isinstance(ex, dict):
        raise WireError("'exemplars' must be an object (the exemplar snapshot)")
    return _unjsonify(ex)


def encode_error(code: str, message: str) -> bytes:
    """Structured failure payload (the only thing a gateway ever sends on
    error -- clients never parse tracebacks)."""
    return _dumps(
        {"v": WIRE_VERSION, "ok": False,
         "error": {"code": str(code), "message": str(message)}}
    )
