"""deepseek-v3-671b [moe]: MLA, 1 shared + 256 routed top-8, first 3 layers
dense, MTP. [arXiv:2412.19437; hf]"""

from .base import ArchConfig, AttnConfig, MoEConfig, register

CONFIG = register(
    ArchConfig(
        name="deepseek-v3-671b",
        family="moe",
        n_layers=61,
        d_model=7168,
        n_heads=128,
        n_kv_heads=128,  # MLA: per-head K/V reconstructed from the latent
        head_dim=128,  # nope head dim; +64 rope dims (attn config)
        d_ff=18432,  # dense-layer MLP hidden (first_dense layers)
        vocab=129280,
        moe=MoEConfig(
            n_experts=256,
            top_k=8,
            d_ff=2048,
            n_shared=1,
            first_dense=3,
        ),
        attn=AttnConfig(
            kind="mla",
            q_lora_rank=1536,
            kv_lora_rank=512,
            rope_head_dim=64,
            v_head_dim=128,
        ),
        mtp=True,
        source="arXiv:2412.19437; hf",
    )
)
