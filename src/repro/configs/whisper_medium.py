"""whisper-medium [audio]: encoder-decoder, conv frontend STUB (input_specs
provides precomputed frame embeddings). [arXiv:2212.04356; unverified]"""

from .base import ArchConfig, register

CONFIG = register(
    ArchConfig(
        name="whisper-medium",
        family="audio",
        n_layers=24,  # decoder layers
        n_enc_layers=24,
        enc_dec=True,
        d_model=1024,
        n_heads=16,
        n_kv_heads=16,
        d_ff=4096,
        vocab=51865,
        rope="learned",  # whisper uses absolute positions
        frontend="audio",
        n_frontend_tokens=1500,  # 30 s of mel frames after conv subsampling
        source="arXiv:2212.04356; unverified",
    )
)
