"""Portfolio codesign end-to-end (docs/portfolio.md).

Covers the tentpole acceptance grid: the jitted JAX subset scorer is
tie-equivalent to the NumPy oracle over K in {1,2,3} on both paper GPUs
*and* on LM op-graph cells; K=1 under the throughput objective reproduces
``codesign().best()`` bit-for-bit; portfolio manifests persist with
deterministic canonical bytes; and the gateway's ``/v1/route`` answers --
in-process and over HTTP -- are byte-identical to the in-process
:class:`PortfolioServer` oracle.
"""

import json
import threading

import numpy as np
import pytest

from repro.core.codesign import codesign, enumerate_hw_space
from repro.core.lmcells import lm_codesign, lm_workload
from repro.core.portfolio import (
    OBJECTIVES,
    optimize_portfolio,
    optimize_portfolio_arrays,
)
from repro.core.timemodel import GPUS_BY_NAME
from repro.core.workload import paper_workload
from repro.service import wire
from repro.service.client import GatewayClient
from repro.service.gateway import Gateway, WrongArtifactKindError, serve_http
from repro.service.portfolio import (
    PortfolioServer,
    RouteRequest,
    UnknownCellError,
    build_portfolio,
)
from repro.service.server import CodesignServer
from repro.service.store import ArtifactStore

# ---------------------------------------------------------------------------
# sweeps under test: both paper GPUs (stencil cells) + an LM op-graph sweep
# ---------------------------------------------------------------------------

_RESULTS = {}


def sweep_result(name):
    """Module-cached downsampled sweeps (numpy engine: the oracle)."""
    if name not in _RESULTS:
        if name == "lm":
            _RESULTS[name] = lm_codesign(
                lm_workload(archs=("llama3-8b",)), max_chips=64, engine="numpy"
            )
        else:
            _RESULTS[name] = codesign(
                paper_workload(),
                gpu=GPUS_BY_NAME[name],
                hw=enumerate_hw_space().downsample(64),
                engine="numpy",
            )
    return _RESULTS[name]


def budgets_for(res):
    """Two feasible fleet budgets spanning single-member to multi-member."""
    area = np.asarray(res.hw.area, np.float64)
    return [float(np.quantile(area, 0.5)), float(area.sum())]


FAMILIES = ("gtx980", "titanx", "lm")


# ---------------------------------------------------------------------------
# engines: NumPy oracle vs jitted JAX scorer (the acceptance grid)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("family", FAMILIES)
@pytest.mark.parametrize("k", [1, 2, 3])
def test_numpy_jax_engines_equivalent(family, k):
    """Across the seeded grid, both engines report the same fleet
    objective; when they name the same subset, every reported number is
    bit-identical (both finalize in float64). A float32 scorer may pick a
    different member set only on a near-tie, so an index mismatch must be
    backed by objective agreement."""
    res = sweep_result(family)
    for objective in OBJECTIVES:
        for budget in budgets_for(res):
            r_np = optimize_portfolio(
                res, k, budget, objective=objective, engine="numpy"
            )
            r_jx = optimize_portfolio(
                res, k, budget, objective=objective, engine="jax"
            )
            obj_np = getattr(r_np, "fleet_density" if objective == "density"
                             else "fleet_gflops")
            obj_jx = getattr(r_jx, "fleet_density" if objective == "density"
                             else "fleet_gflops")
            if r_np.members == r_jx.members:
                assert r_np.fleet_gflops == r_jx.fleet_gflops
                assert r_np.weighted_time == r_jx.weighted_time
                assert r_np.total_area == r_jx.total_area
                np.testing.assert_array_equal(r_np.assignment, r_jx.assignment)
                np.testing.assert_array_equal(r_np.preference, r_jx.preference)
            else:  # near-tie resolved differently by the f32 scorer
                assert obj_jx == pytest.approx(obj_np, rel=1e-5), (
                    f"{family} k={k} {objective} budget={budget}: engines "
                    f"disagree beyond tie tolerance "
                    f"({r_np.members} vs {r_jx.members})"
                )


@pytest.mark.parametrize("family", FAMILIES)
def test_k1_throughput_is_exactly_best(family):
    """The K=1 degeneracy: same argmax index, bit-equal GFLOP/s."""
    res = sweep_result(family)
    area = np.asarray(res.hw.area, np.float64)
    for budget in [float(area.min()), *budgets_for(res)]:
        best_i, best_g = res.best(max_area=budget)
        r = optimize_portfolio(res, 1, budget, objective="throughput")
        assert r.members == (best_i,)
        assert r.fleet_gflops == best_g
        assert r.total_area == float(area[best_i])


def test_fleet_never_worse_than_single_design():
    res = sweep_result("gtx980")
    for budget in budgets_for(res):
        _, best_g = res.best(max_area=budget)
        r = optimize_portfolio(res, 3, budget, objective="throughput")
        assert r.fleet_gflops >= best_g * (1 - 1e-12)


def test_infeasible_budget_raises():
    res = sweep_result("gtx980")
    tiny = float(np.asarray(res.hw.area).min()) / 2
    with pytest.raises(ValueError, match="no feasible portfolio"):
        optimize_portfolio(res, 2, tiny)


def test_max_subsets_guard():
    res = sweep_result("gtx980")
    with pytest.raises(ValueError, match="max_subsets"):
        optimize_portfolio(res, 3, 1e9, max_subsets=10)


def test_bad_args_rejected():
    res = sweep_result("gtx980")
    with pytest.raises(ValueError, match="objective"):
        optimize_portfolio(res, 1, 100.0, objective="latency")
    with pytest.raises(ValueError, match="engine"):
        optimize_portfolio(res, 1, 100.0, engine="fortran")
    with pytest.raises(ValueError, match="k must be"):
        optimize_portfolio(res, 0, 100.0)
    with pytest.raises(ValueError, match="freqs"):
        optimize_portfolio_arrays(
            np.ones(2), np.ones((1, 2)), np.ones(1), -np.ones(1), 1, 10.0
        )


# ---------------------------------------------------------------------------
# persistence: deterministic manifests, store round trip
# ---------------------------------------------------------------------------


def _stencil_store(tmp_path, gpu="gtx980"):
    store = ArtifactStore(str(tmp_path))
    srv = CodesignServer(
        store, gpu=GPUS_BY_NAME[gpu], downsample=64, engine="numpy",
        batch_window=0.0,
    )
    srv.ensure_artifact()
    return store, srv.key


def test_build_portfolio_persists_deterministically(tmp_path):
    store, sweep_key = _stencil_store(tmp_path)
    art1, res1 = build_portfolio(store, sweep_key, 2, 900.0)
    art2, res2 = build_portfolio(store, sweep_key, 2, 900.0)
    assert art1.key == art2.key
    assert res1.members == res2.members

    # canonical manifest bytes are stable across processes/instances
    raw1 = json.dumps(art1.manifest, sort_keys=True, separators=(",", ":"))
    reopened = ArtifactStore(str(tmp_path))
    raw2 = json.dumps(
        reopened.get(art1.key).manifest, sort_keys=True, separators=(",", ":")
    )
    assert raw1 == raw2

    # payload carries the optimization decision + provenance
    p = art1.payload
    assert p["sweep_key"] == sweep_key
    assert p["members"] == list(res1.members)
    assert {g["label"] for g in p["groups"]} >= {"heat2d", "jacobi2d"}
    for g in p["groups"]:
        assert g["slot"] in range(len(res1.members))
        assert sorted(g["preference"]) == list(range(len(res1.members)))

    # a different budget is a different decision -> a different key
    art3, _ = build_portfolio(store, sweep_key, 2, 450.0)
    assert art3.key != art1.key

    # the store indexes it with routing inherited from the sweep
    row = [e for e in store.entries() if e["key"] == art1.key]
    assert row and row[0]["kind"] == "portfolio" and row[0]["gpu"] == "gtx980"


def test_build_portfolio_rejects_non_sweep(tmp_path):
    store, sweep_key = _stencil_store(tmp_path)
    art, _ = build_portfolio(store, sweep_key, 1, 900.0)
    with pytest.raises(ValueError, match="kind"):
        build_portfolio(store, art.key, 1, 900.0)
    with pytest.raises(KeyError, match="no stored sweep"):
        build_portfolio(store, "deadbeef", 1, 900.0)


# ---------------------------------------------------------------------------
# routing: gateway (in-process and HTTP) vs the PortfolioServer oracle
# ---------------------------------------------------------------------------


def test_route_byte_identity_and_errors(tmp_path):
    store, sweep_key = _stencil_store(tmp_path)
    art, _ = build_portfolio(store, sweep_key, 2, 900.0)
    oracle = PortfolioServer(store.get(art.key), store.get(sweep_key))
    gw = Gateway([str(tmp_path)], batch_window=0.0)

    for cell in oracle.cell_labels():
        req = RouteRequest(cell=cell)
        want = wire.encode_route_response(oracle.route(req))
        got = wire.encode_route_response(gw.route(req, route={"gpu": "gtx980"}))
        assert got == want, f"gateway route for {cell!r} diverged"
        # explicit artifact pinning takes the same path
        got_pinned = wire.encode_route_response(gw.route(req, artifact=art.key))
        assert got_pinned == want

    with pytest.raises(UnknownCellError):
        gw.route(RouteRequest(cell="not-a-cell"), artifact=art.key)
    with pytest.raises(WrongArtifactKindError):
        gw.route(RouteRequest(cell="heat2d"), artifact=sweep_key)

    httpd = serve_http(gw)
    threading.Thread(target=httpd.serve_forever, daemon=True).start()
    try:
        host, port = httpd.server_address[:2]
        client = GatewayClient(f"http://{host}:{port}")
        for cell in oracle.cell_labels():
            req = RouteRequest(cell=cell)
            body = client.route_bytes(req, route={"gpu": "gtx980"})
            assert body == wire.encode_route_response(oracle.route(req))
        resp = client.route("heat2d", artifact=art.key)
        assert resp == oracle.route(RouteRequest(cell="heat2d"))
        assert not resp.degraded and resp.fallback_from == ()
        with pytest.raises(wire.RemoteError) as exc:
            client.route("not-a-cell", artifact=art.key)
        assert exc.value.code == "unknown_cell"
    finally:
        httpd.shutdown()
        httpd.server_close()


def test_route_wire_codec_round_trip():
    req = RouteRequest(cell="llama3-8b:decode")
    data = wire.encode_route_request(
        req, artifact="abc123", route={"gpu": "tpu_v5e"}, deadline_ms=250.0
    )
    got, artifact, route, deadline = wire.decode_route_request_full(data)
    assert got == req and artifact == "abc123"
    assert route == {"gpu": "tpu_v5e"} and deadline == 250.0

    with pytest.raises(wire.WireError):
        wire.decode_route_request_full(
            json.dumps({"v": 1, "request": {"cell": "x", "bogus": 1}}).encode()
        )
    with pytest.raises(wire.WireError):
        wire.decode_route_request_full(
            json.dumps({"v": 1, "request": {"cell": ""}}).encode()
        )
