"""Soft dependency shim for ``hypothesis``.

The seed suite hard-imported hypothesis at module scope, so a machine
without it could not even *collect* the tests (6 modules errored out).
This shim keeps every module collectable and every non-property test
runnable; only the ``@given`` property tests themselves skip (via
``pytest.importorskip`` semantics) when hypothesis is missing. CI installs
the real thing through the ``repro[test]`` extra.

Usage (drop-in for the seed's imports)::

    from _hypothesis_compat import given, settings, st
"""

from __future__ import annotations

import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    class _AnyStub:
        """Absorbs the module-scope strategy expressions (``st.floats(...)``)
        that are evaluated at decoration time."""

        def __getattr__(self, _name):
            return self

        def __call__(self, *_a, **_k):
            return self

    st = _AnyStub()

    def given(*_a, **_k):
        def deco(fn):
            def skipper(*_args, **_kwargs):
                pytest.importorskip("hypothesis")

            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper

        return deco

    def settings(*_a, **_k):
        def deco(fn):
            return fn

        return deco


__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]
