"""End-to-end training driver: a ~100M-param llama-family model for a few
hundred steps with the production Trainer (checkpointing, fault tolerance,
deterministic data).

Default runs a fast reduced config so the example finishes in minutes on
CPU; pass --full-100m for the real ~100M variant (slow on CPU, sized for a
single TPU host).

Run: PYTHONPATH=src python examples/train_tiny_lm.py [--steps 200]
"""

import argparse
import dataclasses

import jax
import numpy as np
from jax.sharding import Mesh

from repro.configs.base import ArchConfig, ShapeSpec
from repro.data.pipeline import DataConfig
from repro.optim import AdamWConfig
from repro.train import TrainConfig, Trainer, TrainerConfig

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--full-100m", action="store_true")
ap.add_argument("--ckpt", default="/tmp/repro_tiny_lm")
args = ap.parse_args()

if args.full_100m:
    cfg = ArchConfig(
        name="llama-100m", family="dense", n_layers=12, d_model=768,
        n_heads=12, n_kv_heads=4, d_ff=2048, vocab=32768, dtype="float32",
    )
    shape = ShapeSpec("train", seq_len=512, global_batch=8, kind="train")
else:
    cfg = ArchConfig(
        name="llama-8m", family="dense", n_layers=4, d_model=256,
        n_heads=8, n_kv_heads=4, d_ff=688, vocab=4096, dtype="float32",
    )
    shape = ShapeSpec("train", seq_len=128, global_batch=8, kind="train")

mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
tcfg = TrainConfig(
    microbatches=2,
    remat="dots",
    opt=AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps),
)
trainer = Trainer(
    cfg, shape, mesh, tcfg,
    TrainerConfig(steps=args.steps, ckpt_dir=args.ckpt, ckpt_every=50),
    DataConfig(seed=0),
)
out = trainer.train()
losses = [m["lm_loss"] for m in out["metrics"]]
print(f"\nparams ~= {sum(x.size for x in jax.tree.leaves(out['state']['params']))/1e6:.1f}M")
print(f"step {out['step']}: loss {losses[0]:.3f} -> {losses[-1]:.3f}")
print("first/last-10 mean:", np.mean(losses[:10]).round(3), np.mean(losses[-10:]).round(3))
assert np.mean(losses[-10:]) < np.mean(losses[:10]), "training must make progress"
print("OK")
