"""qwen2-vl-2b [vlm]: M-RoPE, dynamic-resolution vision frontend STUB
(input_specs provides patch embeddings). [arXiv:2409.12191; hf]"""

from .base import ArchConfig, register

CONFIG = register(
    ArchConfig(
        name="qwen2-vl-2b",
        family="vlm",
        n_layers=28,
        d_model=1536,
        n_heads=12,
        n_kv_heads=2,
        d_ff=8960,
        vocab=151936,
        rope="mrope",
        mrope_sections=(16, 24, 24),  # temporal/height/width rope sections
        rope_theta=1000000.0,
        frontend="vision",
        n_frontend_tokens=256,  # stub patch-embedding count
        tie_embeddings=True,
        source="arXiv:2409.12191; hf",
    )
)
