"""Architecture configuration system.

Every assigned architecture is a frozen :class:`ArchConfig`; the registry
maps ``--arch <id>`` names to configs. ``cfg.reduced()`` produces the
small-but-same-family variant used by CPU smoke tests (the FULL configs are
exercised only through the dry-run's ShapeDtypeStruct lowering).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

__all__ = [
    "AttnConfig",
    "MoEConfig",
    "SSMConfig",
    "ArchConfig",
    "ARCHS",
    "register",
    "get_arch",
    "get",
    "list_archs",
    "SHAPES",
    "ShapeSpec",
]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts sublayer parameters.

    ``n_experts`` routed experts, of which ``top_k`` are active per token;
    each expert is an MLP with hidden width ``d_ff`` (units: model
    dimensions, not bytes). ``capacity_factor`` scales per-expert token
    buffers relative to a perfectly balanced router (dimensionless ratio);
    ``router_aux_weight`` is the load-balancing auxiliary-loss coefficient.
    """

    n_experts: int
    top_k: int
    d_ff: int  # per-expert hidden size
    n_shared: int = 0  # always-on shared experts (DeepSeek)
    every: int = 1  # MoE replaces the MLP every N layers (Jamba: 2)
    first_dense: int = 0  # leading dense layers (DeepSeek-V3: 3)
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """State-space (Mamba-2 / SSD) mixer parameters.

    ``d_state`` is the per-head recurrent state width, ``d_conv`` the depth
    of the causal conv preceding the SSM, ``expand`` the inner-width
    multiplier over ``d_model``, and ``chunk`` the SSD scan chunk length in
    tokens.
    """

    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 128  # SSD chunk length


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    """Attention variant. ``kind`` selects full softmax attention, sliding
    window (``swa``, window size in tokens), or DeepSeek's multi-head latent
    attention (``mla``) whose low-rank dims are per-head widths."""

    kind: str = "full"  # full | swa | mla
    window: int = 0  # SWA window
    # MLA (DeepSeek): low-rank Q/KV compression + decoupled RoPE dims
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    rope_head_dim: int = 0
    v_head_dim: int = 0


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """One published model architecture, frozen.

    Field units: ``n_layers``/``n_enc_layers`` count transformer (or SSM)
    blocks; ``d_model``/``d_ff``/``head_dim`` are activation widths in model
    dimensions (elements, not bytes — multiply by the ``dtype`` width for
    bytes); ``n_heads``/``n_kv_heads`` count query/KV heads (GQA when
    ``n_kv_heads < n_heads``); ``vocab`` is the embedding-table row count;
    ``rope_theta`` is the rotary base frequency (dimensionless). ``dtype``
    names the parameter/activation storage dtype and is what converts
    element counts into HBM bytes in the roofline model.
    """

    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    act: str = "silu"  # silu | geglu | relu2
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    attn: AttnConfig = AttnConfig()
    rope: str = "standard"  # standard | mrope | learned | sinusoidal
    rope_theta: float = 10000.0
    mrope_sections: Tuple[int, ...] = ()
    enc_dec: bool = False
    n_enc_layers: int = 0
    frontend: Optional[str] = None  # audio | vision (STUB: embeddings given)
    n_frontend_tokens: int = 0  # stub frame/patch count fed by input_specs
    layer_pattern: str = "uniform"  # uniform | jamba
    attn_every: int = 0  # jamba: attention layer each N (offset period//2)
    tie_embeddings: bool = False
    mtp: bool = False  # DeepSeek multi-token-prediction head
    rms_offset: float = 0.0  # gemma: rmsnorm scale = (1 + w)
    emb_scale: bool = False  # gemma: embeddings * sqrt(d_model)
    dtype: str = "bfloat16"
    source: str = ""  # provenance note [arXiv id; verification tier]

    # ------------------------------------------------------------------
    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def layer_kinds(self) -> Tuple[Tuple[str, str], ...]:
        """(mixer, ffn) kind per layer index.

        mixer: 'attn' | 'ssm' | (encoder handled separately)
        ffn:   'mlp' | 'moe'
        """
        kinds = []
        for i in range(self.n_layers):
            if self.layer_pattern == "jamba":
                mixer = "attn" if (i % self.attn_every) == self.attn_every // 2 else "ssm"
            elif self.family == "ssm":
                mixer = "ssm"
            else:
                mixer = "attn"
            if self.moe is None:
                ffn = "mlp" if self.d_ff else "none"  # pure-SSM blocks
            elif i < self.moe.first_dense:
                ffn = "mlp"
            elif (i % self.moe.every) == (self.moe.every - 1):
                ffn = "moe"
            else:
                ffn = "mlp"
            kinds.append((mixer, ffn))
        return tuple(kinds)

    def reduced(self) -> "ArchConfig":
        """Same-family tiny variant for CPU smoke tests."""
        moe = (
            dataclasses.replace(
                self.moe,
                n_experts=min(self.moe.n_experts, 4),
                top_k=min(self.moe.top_k, 2),
                d_ff=64,
                first_dense=min(self.moe.first_dense, 1),
                capacity_factor=4.0,
            )
            if self.moe
            else None
        )
        ssm = (
            dataclasses.replace(self.ssm, d_state=16, head_dim=8, chunk=16)
            if self.ssm
            else None
        )
        attn = self.attn
        if attn.kind == "mla":
            attn = dataclasses.replace(
                attn, q_lora_rank=32, kv_lora_rank=16, rope_head_dim=8, v_head_dim=16
            )
        if attn.kind == "swa":
            attn = dataclasses.replace(attn, window=16)
        n_layers = {
            "uniform": 4 if self.moe is None else 5,
            "jamba": 2 * self.attn_every if self.attn_every else 4,
        }[self.layer_pattern]
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            n_layers=n_layers,
            n_enc_layers=min(self.n_enc_layers, 2),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            head_dim=16,
            d_ff=128 if self.d_ff else 0,
            vocab=256,
            moe=moe,
            ssm=ssm,
            attn=attn,
            n_frontend_tokens=8 if self.frontend else 0,
            mrope_sections=(4, 2, 2) if self.rope == "mrope" else (),
            dtype="float32",
        )


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------
ARCHS: Dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    """Add ``cfg`` to the registry; raises ``ValueError`` on a duplicate name."""
    if cfg.name in ARCHS:
        raise ValueError(f"duplicate arch {cfg.name}")
    ARCHS[cfg.name] = cfg
    return cfg


def get_arch(name: str) -> ArchConfig:
    """Look up a registered architecture by ``--arch`` name.

    Triggers discovery of every config module on first use, so callers never
    see a partially populated registry.
    """
    from . import _register_all  # noqa: F401  (side-effect registration)

    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def list_archs() -> Tuple[str, ...]:
    """All registered architecture names, sorted (deterministic across runs)."""
    from . import _register_all  # noqa: F401  (side-effect registration)

    return tuple(sorted(ARCHS))


#: Short alias — ``repro.configs.get(name)``.
get = get_arch


# ---------------------------------------------------------------------------
# Assigned input shapes (harness table). decode_*/long_* lower serve_step.
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One workload shape: ``seq_len`` tokens of context per sequence and
    ``global_batch`` concurrent sequences across the whole mesh. ``kind``
    selects the cost model — ``train`` (fwd+bwd over all tokens),
    ``prefill`` (fwd over all tokens), or ``decode`` (one new token per
    sequence per step against a ``seq_len``-deep KV cache)."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def tokens(self) -> int:
        """Tokens processed per step (for decode this is tokens *resident*,
        not tokens generated — decode emits ``global_batch`` per step)."""
        return self.seq_len * self.global_batch


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}
