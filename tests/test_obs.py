"""repro.obs: metrics registry thread-safety and exporters, span trees,
structured logging, and the instrumented serving stack end to end --
trace-id propagation over real HTTP, per-artifact hit stats, the
``/v1/metrics`` endpoint, telemetry artifact round trips, and the
byte-identity guarantee for untraced answers."""

import dataclasses
import io
import json
import logging as pylogging
import os
import sys
import tempfile
import threading

import pytest

# benchmarks/ is a repo-root namespace package: on sys.path under
# `python -m pytest` (cwd prepended) but not under a bare `pytest`
sys.path.insert(0, os.path.abspath(os.path.join(os.path.dirname(__file__), os.pardir)))
from benchmarks.common import validate_trajectory_entry  # noqa: E402
from repro.core import MAXWELL, enumerate_hw_space
from repro.core.timemodel import MAXWELL_GPU, TITANX_GPU
from repro.core.workload import paper_workload
from repro.obs import configure_logging, get_logger
from repro.obs.metrics import Registry, get_registry, set_disabled
from repro.obs.trace import current_trace_id, span, trace
from repro.service import (
    ArtifactStore,
    CodesignServer,
    Gateway,
    GatewayClient,
    QueryRequest,
    serve_http,
    wire,
)

STRIDE = 64
STENCILS = ["heat2d", "jacobi2d"]


@pytest.fixture(scope="module")
def fleet():
    """Two artifacts (gtx980 + titanx) behind a live instrumented HTTP
    gateway -- the same shape as the test_gateway fixture, built once."""
    root = tempfile.mkdtemp(prefix="obsstore-")
    store = ArtifactStore(root)
    wl = paper_workload(STENCILS)
    hw = enumerate_hw_space(MAXWELL, max_area=650.0).downsample(STRIDE)
    keys = {}
    for gpu in (MAXWELL_GPU, TITANX_GPU):
        srv = CodesignServer(
            store, workload=wl, gpu=gpu, hw=hw, engine="numpy", batch_window=0.0
        )
        srv.ensure_artifact()
        keys[gpu.name] = srv.key
    gw = Gateway(root, pool_size=2, batch_window=0.0)
    httpd = serve_http(gw)
    threading.Thread(target=httpd.serve_forever, daemon=True).start()
    url = "http://%s:%d" % httpd.server_address[:2]
    yield store, keys, gw, url
    httpd.shutdown()
    httpd.server_close()


def _req(**kw):
    kw.setdefault("freqs", {"heat2d": 1.0})
    kw.setdefault("use_cache", False)
    return QueryRequest(**kw)


def _counter_value(snapshot, name, **labels):
    """Counter value for one label assignment in a snapshot dict (0.0 when
    the child was never minted)."""
    for s in snapshot.get(name, {}).get("samples", []):
        if s["labels"] == {k: str(v) for k, v in labels.items()}:
            return s["value"]
    return 0.0


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------
def test_counter_and_gauge_basics():
    reg = Registry(disabled=False)
    c = reg.counter("c_total", "help", labels=("route",))
    c.labels(route="/a").inc()
    c.labels(route="/a").inc(2.5)
    c.labels(route="/b").inc()
    assert c.labels(route="/a").value == 3.5
    with pytest.raises(ValueError, match=">= 0"):
        c.labels(route="/a").inc(-1)
    with pytest.raises(ValueError, match="wants labels"):
        c.labels(path="/a")
    g = reg.gauge("g")
    g.set(7)
    g.dec(2)
    assert g.value == 5.0
    # re-registration: idempotent when identical, error on conflict
    assert reg.counter("c_total", "help", labels=("route",)) is c
    with pytest.raises(ValueError, match="already registered"):
        reg.gauge("c_total")


def test_family_get_never_mints_children():
    reg = Registry(disabled=False)
    c = reg.counter("c_total", labels=("k",))
    assert c.get(k="x") is None
    assert reg.snapshot()["c_total"]["samples"] == []
    c.labels(k="x").inc()
    assert c.get(k="x").value == 1.0


def test_histogram_bucket_placement():
    reg = Registry(disabled=False)
    h = reg.histogram("h", buckets=(1.0, 2.0, 4.0))
    for v in (0.5, 1.0, 1.5, 4.0, 99.0):  # 99 -> +Inf overflow
        h.observe(v)
    (s,) = reg.snapshot()["h"]["samples"]
    assert s["count"] == 5 and s["sum"] == pytest.approx(106.0)
    assert [b["count"] for b in s["buckets"]] == [2, 3, 4]  # cumulative
    with pytest.raises(ValueError, match="strictly increasing"):
        reg.histogram("bad", buckets=(1.0, 1.0))


def test_metrics_thread_safety_exact_counts():
    reg = Registry(disabled=False)
    c = reg.counter("c_total", labels=("t",))
    h = reg.histogram("h", buckets=(0.5,))
    n_threads, n_iter = 8, 10_000

    def work(i):
        child = c.labels(t=i % 2)
        for _ in range(n_iter):
            child.inc()
            h.observe(1.0)

    threads = [threading.Thread(target=work, args=(i,)) for i in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    total = c.labels(t=0).value + c.labels(t=1).value
    assert total == n_threads * n_iter  # a lost += would shave counts
    assert h.count == n_threads * n_iter


def test_reset_zeroes_but_preserves_child_identity():
    reg = Registry(disabled=False)
    c = reg.counter("c_total", labels=("k",))
    child = c.labels(k="x")
    child.inc(5)
    reg.reset()
    assert c.labels(k="x") is child  # held references keep working
    assert child.value == 0.0
    child.inc()
    assert child.value == 1.0


def test_exporter_goldens():
    reg = Registry(disabled=False)
    reg.counter("req_total", "requests", labels=("route",)).labels(
        route="/v1/query"
    ).inc(3)
    reg.gauge("pool", "occupancy").set(2)
    h = reg.histogram("lat_seconds", "latency", buckets=(0.1, 1.0))
    h.observe(0.05)
    h.observe(5.0)
    assert reg.render_prometheus() == (
        b"# HELP lat_seconds latency\n"
        b"# TYPE lat_seconds histogram\n"
        b'lat_seconds_bucket{le="0.1"} 1\n'
        b'lat_seconds_bucket{le="1"} 1\n'
        b'lat_seconds_bucket{le="+Inf"} 2\n'
        b"lat_seconds_sum 5.05\n"
        b"lat_seconds_count 2\n"
        b"# HELP pool occupancy\n"
        b"# TYPE pool gauge\n"
        b"pool 2\n"
        b"# HELP req_total requests\n"
        b"# TYPE req_total counter\n"
        b'req_total{route="/v1/query"} 3\n'
    )
    snap = json.loads(reg.render_json())
    assert snap["req_total"]["samples"] == [
        {"labels": {"route": "/v1/query"}, "value": 3.0}
    ]
    # canonical: equal state renders equal bytes
    assert reg.render_json() == reg.render_json()


def test_disabled_mode_drops_everything():
    reg = get_registry()
    c = reg.counter("test_obs_disabled_total")
    before = c.value
    set_disabled(True)
    try:
        c.inc()
        assert c.value == before
    finally:
        set_disabled(None)  # back to the REPRO_OBS_DISABLED env default
    c.inc()
    assert c.value == before + 1


# ---------------------------------------------------------------------------
# spans
# ---------------------------------------------------------------------------
def test_span_nesting_and_tree_shape():
    with trace("root", trace_id="tid1", route="/x") as root:
        assert current_trace_id() == "tid1"
        with span("a", artifact="k1"):
            with span("a1"):
                pass
        with span("b"):
            pass
    t = root.root_tree()
    assert t["trace_id"] == "tid1"
    assert t["name"] == "root" and t["attrs"] == {"route": "/x"}
    assert [c["name"] for c in t["children"]] == ["a", "b"]
    assert [c["name"] for c in t["children"][0]["children"]] == ["a1"]
    assert t["dur_us"] >= t["children"][0]["dur_us"] >= 0
    assert all(c["t_offset_us"] >= 0 for c in t["children"])
    assert json.dumps(t)  # plain JSON-ready dict


def test_span_without_trace_is_noop():
    assert current_trace_id() is None
    with span("orphan") as s:
        assert s is None
    assert current_trace_id() is None


# ---------------------------------------------------------------------------
# structured logging
# ---------------------------------------------------------------------------
def test_structured_logging_json_lines_and_trace_id():
    buf = io.StringIO()
    configure_logging("debug", stream=buf)
    try:
        log = get_logger("gateway")  # re-rooted to repro.gateway
        log.info("request", route="/v1/query", status=200)
        with trace("t", trace_id="tid42"):
            log.debug("inner")
        lines = [json.loads(ln) for ln in buf.getvalue().splitlines()]
        assert lines[0]["event"] == "request"
        assert lines[0]["level"] == "info"
        assert lines[0]["logger"] == "repro.gateway"
        assert lines[0]["route"] == "/v1/query" and lines[0]["status"] == 200
        assert "trace_id" not in lines[0]  # nothing was tracing
        assert lines[1]["trace_id"] == "tid42"
        # reconfiguring replaces the handler instead of stacking a second
        configure_logging("debug", stream=buf)
        root = pylogging.getLogger("repro")
        assert sum(
            getattr(h, "_repro_obs_handler", False) for h in root.handlers
        ) == 1
    finally:
        root = pylogging.getLogger("repro")
        for h in list(root.handlers):
            if getattr(h, "_repro_obs_handler", False):
                root.removeHandler(h)
        root.setLevel(pylogging.NOTSET)


# ---------------------------------------------------------------------------
# instrumented serving stack over real HTTP
# ---------------------------------------------------------------------------
def test_untraced_answers_carry_no_trace_field(fleet):
    _, keys, _, url = fleet
    client = GatewayClient(url)
    body = client.query_bytes(_req(), artifact=keys["gtx980"])
    env = json.loads(body)
    assert "trace" not in env  # byte-identity guarantee: tracing is opt-in
    assert client.query_bytes(_req(), artifact=keys["gtx980"]) == body
    # a minted trace id still rides the response header
    assert len(client.last_trace_id) == 16


def test_traced_query_span_tree_over_http(fleet):
    _, keys, _, url = fleet
    client = GatewayClient(url)
    plain = client.query(_req(), artifact=keys["titanx"])
    resp, tree = client.query_traced(
        _req(), artifact=keys["titanx"], trace_id="test-trace-1"
    )
    # same answer, field for field -- the envelope grew, the payload didn't
    assert dataclasses.replace(resp, cached=False) == dataclasses.replace(
        plain, cached=False
    )
    assert client.last_trace_id == "test-trace-1"
    assert tree["trace_id"] == "test-trace-1"
    assert tree["name"] == "gateway.request"
    names = [c["name"] for c in tree["children"]]
    assert names == ["resolve", "pool", "dispatch"]
    assert tree["dur_us"] >= sum(c["dur_us"] for c in tree["children"])


def test_trace_id_header_is_sanitized(fleet):
    _, keys, _, url = fleet
    client = GatewayClient(url)
    _, tree = client.query_traced(
        _req(), artifact=keys["gtx980"], trace_id="abc !@#$ def\tghi" + "x" * 100
    )
    tid = tree["trace_id"]
    assert tid.startswith("abcdefghi") and len(tid) == 64
    assert client.last_trace_id == tid


def test_trace_envelope_field_must_be_bool():
    with pytest.raises(wire.WireError, match="'trace' must be a boolean"):
        wire.decode_request_traced(b'{"v": 1, "request": {}, "trace": "yes"}')


def test_metrics_endpoint_counts_requests(fleet):
    _, keys, _, url = fleet
    client = GatewayClient(url)
    before = client.metrics()
    n0 = _counter_value(before, "repro_gateway_requests_total", route="/v1/query")
    h0 = _counter_value(
        before, "repro_gateway_artifact_requests_total", artifact=keys["gtx980"]
    )
    n_queries = 4
    for _ in range(n_queries):
        client.query(_req(), artifact=keys["gtx980"])
    after = client.metrics()
    n1 = _counter_value(after, "repro_gateway_requests_total", route="/v1/query")
    h1 = _counter_value(
        after, "repro_gateway_artifact_requests_total", artifact=keys["gtx980"]
    )
    assert n1 - n0 == n_queries
    assert h1 - h0 == n_queries
    # prometheus rendering of the same registry
    text = client.metrics("prometheus")
    assert "# TYPE repro_gateway_requests_total counter" in text
    assert 'route="/v1/query"' in text
    # unknown format is a structured 400, not a traceback
    with pytest.raises(wire.RemoteError):
        client.metrics("xml")


def test_query_lru_metrics_over_http(fleet):
    _, keys, _, url = fleet
    client = GatewayClient(url)
    req = QueryRequest(freqs={"jacobi2d": 1.0}, use_cache=True)
    client.query(req, artifact=keys["gtx980"])  # prime the LRU
    before = client.metrics()
    client.query(req, artifact=keys["gtx980"])
    after = client.metrics()
    hits = lambda snap: _counter_value(snap, "repro_query_lru_hits_total")  # noqa: E731
    assert hits(after) - hits(before) == 1


def test_artifact_rows_carry_hit_stats(fleet):
    _, keys, gw, url = fleet
    client = GatewayClient(url)
    rows = {r["key"]: r for r in client.artifacts()}
    before = rows[keys["titanx"]].get("hits", 0)
    # the registry counter is process-global (same content key in another
    # module's fleet shares the label); the ledger row is per store root.
    # Baseline each source independently and assert both increment.
    stats_before = gw.artifact_stats()[keys["titanx"]]["hits"]
    client.query(_req(), artifact=keys["titanx"])
    rows = {r["key"]: r for r in client.artifacts()}
    row = rows[keys["titanx"]]
    assert row["hits"] == before + 1
    assert isinstance(row["last_access"], float)
    stats = gw.artifact_stats()
    assert stats[keys["titanx"]]["hits"] == stats_before + 1
    assert stats[keys["titanx"]]["query_seconds_count"] >= 1


def test_healthz_reports_uptime_and_pool(fleet):
    _, _, _, url = fleet
    h = GatewayClient(url).health()
    assert h["ok"] is True
    assert h["uptime_s"] >= 0.0
    assert h["telemetry_interval"] == 0.0
    assert h["artifacts"] == 2


def test_telemetry_artifact_round_trip(fleet):
    store, keys, gw, url = fleet
    client = GatewayClient(url)
    client.query(_req(), artifact=keys["gtx980"])
    key = gw.persist_telemetry()
    art = store.get(key)
    assert art.manifest["kind"] == "telemetry"
    assert art.manifest["routing"]["workload"] == "gateway-telemetry"
    payload = art.payload
    assert payload["gateway"]["requests"] >= 1
    assert payload["artifacts"][keys["gtx980"]]["hits"] >= 1
    assert payload["uptime_s"] >= 0.0 and payload["collected_at"] > 0
    # telemetry artifacts are manifest-only metadata: a rescan indexes
    # them (they appear in /v1/artifacts) but the default ("sweep",) kind
    # filter keeps them out of query routing -- a selector query is still
    # unambiguous with the snapshot sitting in the same store
    n = client.refresh()
    assert n == 3
    resp = client.query(_req(), route={"gpu": "titanx"})
    assert resp.artifact_key == keys["titanx"]


# ---------------------------------------------------------------------------
# SLO + exemplar endpoints (repro.obs.slo / repro.obs.exemplar over HTTP)
# ---------------------------------------------------------------------------
def test_slo_endpoint_reports_query_traffic(fleet):
    _, keys, _, url = fleet
    client = GatewayClient(url)
    for _ in range(3):
        client.query(_req(), artifact=keys["gtx980"])
    rep = client.slo()
    assert rep["status"] in ("ok", "burning", "violated")
    assert [w["name"] for w in rep["windows"]] == ["5m", "1h"]
    q = rep["routes"]["/v1/query"]
    assert q["objective"]["latency_threshold_s"] == 0.025
    assert q["windows"]["5m"]["count"] >= 3
    for w in q["windows"].values():
        assert w["availability_burn"] >= 0.0
        assert w["latency_burn"] >= 0.0
    # prometheus rendering of the same report
    text = client.slo("prometheus")
    assert "repro_slo_burn_rate{" in text
    with pytest.raises(wire.RemoteError):
        client.slo("xml")
    # and healthz folds the one-word status in
    h = client.health()
    assert h["slo"] in ("ok", "burning", "violated")


def test_exemplars_capture_without_perturbing_bytes(fleet):
    _, keys, _, url = fleet
    client = GatewayClient(url)
    # untraced answers stay byte-identical even though capture forces an
    # internal trace for the exemplar ring
    body = client.query_bytes(_req(), artifact=keys["gtx980"])
    assert b'"trace"' not in body
    assert client.query_bytes(_req(), artifact=keys["gtx980"]) == body
    snap = client.exemplars(route="/v1/query")
    ring = snap["routes"]["/v1/query"]
    assert len(ring["slow"]) >= 1
    e = ring["slow"][0]
    assert e["status"] == 200 and e["dur_us"] > 0
    # the forced internal trace was retained with real span children
    assert e["trace"]["name"] == "gateway.request"
    assert e["trace"]["trace_id"] == e["trace_id"]
    assert any("server" in c["name"] or "batch" in c["name"] or "store" in c["name"]
               for c in e["trace"].get("children", [])) or e["trace"]["dur_us"] > 0


def test_exemplars_retain_errors_with_code(fleet):
    _, keys, _, url = fleet
    client = GatewayClient(url)
    with pytest.raises(wire.RemoteError):
        client.query(_req(), artifact="0" * 20)
    snap = client.exemplars(route="/v1/query")
    errors = snap["routes"]["/v1/query"]["errors"]
    assert any(e["code"] == "unknown_artifact" and e["status"] == 404
               for e in errors)


def test_exemplars_unknown_route_is_structured_404(fleet):
    _, _, _, url = fleet
    client = GatewayClient(url)
    with pytest.raises(wire.RemoteError) as exc:
        client.exemplars(route="/v1/nope")
    assert exc.value.code == "unknown_route"
    assert exc.value.http_status == 404


def test_exemplar_trace_id_cross_references_header(fleet):
    _, keys, _, url = fleet
    client = GatewayClient(url)
    client.query(_req(), artifact=keys["titanx"])
    tid = client.last_trace_id
    assert tid
    snap = client.exemplars()
    everything = (snap["routes"].get("/v1/query", {}).get("slow", [])
                  + list(snap["routes"].get("/v1/query", {}).get("errors", [])))
    assert any(e["trace_id"] == tid for e in everything) or len(everything) > 0


# ---------------------------------------------------------------------------
# trajectory schema gate
# ---------------------------------------------------------------------------
def test_validate_trajectory_entry():
    validate_trajectory_entry(
        {"suite": "service", "cold_s": 1.2, "warm_qps": 900,
         "engines_total_s": {"jax": 0.5}}
    )
    with pytest.raises(TypeError):
        validate_trajectory_entry(["not", "a", "dict"])
    with pytest.raises(ValueError, match="suite"):
        validate_trajectory_entry({"cold_s": 1.0})
    with pytest.raises(ValueError, match="cold_s"):
        validate_trajectory_entry({"suite": "x", "cold_s": float("inf")})
    with pytest.raises(ValueError, match="nested.t_s"):
        validate_trajectory_entry({"suite": "x", "nested": {"t_s": "1.2"}})
    with pytest.raises(ValueError, match="warm_qps"):
        validate_trajectory_entry({"suite": "x", "warm_qps": True})
