"""repro.measure: harness record discipline, payload round trips, the
synthetic-recovery acceptance property (fitting model-generated timings
from perturbed starting parameters recovers the generating machine), and
the measurement/calibration artifact kinds in the store."""

import numpy as np
import pytest

from repro.core.timemodel import (
    MAXWELL_GPU,
    STENCILS,
    with_c_iter,
    with_machine_params,
)
from repro.measure import (
    CalibrationResult,
    MeasurementRecord,
    MeasurementRun,
    fit_machine_params,
    measure_one,
    predicted_times,
    synthetic_records,
)
from repro.measure.harness import STOCK_HW, feasible_tiles


def _truth():
    """A 'real machine' deliberately off the datasheet on every parameter."""
    gpu = with_machine_params(MAXWELL_GPU, bw_gmem=150.0e9, launch_overhead=8.0e-6)
    sts = {
        n: with_c_iter(st, st.c_iter * (1.0 + 0.25 * (i + 1)))
        for i, (n, st) in enumerate(STENCILS.items())
    }
    return gpu, sts


# ---------------------------------------------------------------------------
# harness
# ---------------------------------------------------------------------------
def test_measure_one_record_contract():
    rec = measure_one(
        "heat2d", (24, 40), steps=4, tiles={"t_s1": 8, "t_s2": 32, "t_t": 2},
        warmup=1, repeats=2, interpret=True,
    )
    assert rec.stencil == "heat2d"
    assert rec.size == (24, 40, 1, 4)
    # 2D records are framed at t_s3=1 (the kernel never reads t_s3 in 2D,
    # and the model's compute term multiplies by it)
    assert rec.tiles == (8, 32, 2, 1, 1)
    assert rec.time_s > 0
    assert rec.hw == (STOCK_HW["n_sm"], STOCK_HW["n_v"], STOCK_HW["m_sm"])
    # JSON round trip is lossless
    assert MeasurementRecord.from_json(rec.to_json()) == rec


def test_measurement_run_payload_round_trip():
    rec = MeasurementRecord(
        stencil="jacobi2d", size=(64, 64, 1, 4), tiles=(8, 32, 2, 1, 1),
        time_s=1.25e-3, hw=(16.0, 128.0, 96.0),
    )
    run = MeasurementRun(
        records=[rec], gpu_name="gtx980", backend="cpu", interpret=True, note="x"
    )
    back = MeasurementRun.from_payload(run.to_payload())
    assert back.records == run.records
    assert (back.gpu_name, back.backend, back.interpret, back.note) == (
        "gtx980", "cpu", True, "x",
    )
    assert back.stencil_names() == ["jacobi2d"]


def test_feasible_tiles_filters_model_infeasible():
    cands = [
        {"t_s1": 8, "t_s2": 32, "t_t": 2, "k": 1},  # fine
        {"t_s1": 8, "t_s2": 33, "t_t": 2, "k": 1},  # violates warp multiple
        {"t_s1": 8, "t_s2": 32, "t_t": 3, "k": 1},  # violates even t_T
        {"t_s1": 512, "t_s2": 1024, "t_t": 64, "k": 32},  # footprint blowout
    ]
    kept = feasible_tiles("heat2d", cands)
    assert kept == [{"t_s1": 8, "t_s2": 32, "t_t": 2, "k": 1, "t_s3": 1}]
    # 2D candidates differing only in t_s3 collapse to one framed config
    dup = feasible_tiles(
        "heat2d",
        [{"t_s1": 8, "t_s2": 32, "t_t": 2, "k": 1, "t_s3": 8},
         {"t_s1": 8, "t_s2": 32, "t_t": 2, "k": 1, "t_s3": 4}],
    )
    assert len(dup) == 1
    # 3D keeps distinct t_s3 values distinct
    dup3 = feasible_tiles(
        "heat3d",
        [{"t_s1": 4, "t_s2": 32, "t_t": 2, "k": 1, "t_s3": 8},
         {"t_s1": 4, "t_s2": 32, "t_t": 2, "k": 1, "t_s3": 4}],
    )
    assert len(dup3) == 2


def test_stock_hw_follows_gpu_family():
    """A titanx-framed run must be stamped (and feasibility-filtered) at
    the Titan X's stock hardware point, not the GTX-980's."""
    from repro.core.timemodel import TITANX_GPU
    from repro.measure.harness import measure_grid, stock_hw

    assert stock_hw(TITANX_GPU)["n_sm"] == 24.0
    assert stock_hw(MAXWELL_GPU)["n_sm"] == 16.0
    run = measure_grid(
        {"heat2d": [{"shape": (32, 48), "steps": 2,
                     "tiles": {"t_s1": 8, "t_s2": 32, "t_t": 2, "t_s3": 1}}]},
        warmup=0, repeats=1, interpret=True, gpu=TITANX_GPU,
    )
    assert run.records[0].hw == (24.0, 128.0, 96.0)
    assert run.gpu_name == "titanx"


# ---------------------------------------------------------------------------
# calibration
# ---------------------------------------------------------------------------
def test_predicted_times_match_model_and_flag_infeasible():
    recs = synthetic_records(MAXWELL_GPU)
    pred = predicted_times(recs, MAXWELL_GPU)
    np.testing.assert_allclose(pred, [r.time_s for r in recs], rtol=1e-12)
    bad = MeasurementRecord(
        stencil="heat2d", size=(64, 64, 1, 4), tiles=(8, 33, 2, 1, 1),
        time_s=1.0, hw=(16.0, 128.0, 96.0),
    )
    assert not np.isfinite(predicted_times([bad], MAXWELL_GPU)[0])


def test_synthetic_fit_recovers_generating_parameters():
    """The CI acceptance property: exact model-generated timings, fit
    started from the (wrong) datasheet parameters, must land back on the
    generating machine to sub-percent relative error."""
    gpu_t, st_t = _truth()
    recs = synthetic_records(gpu_t, st_t)
    cal = fit_machine_params(recs, gpu0=MAXWELL_GPU, stencils0=STENCILS)
    assert cal.n_dropped == 0
    assert cal.loss_after < 1e-6 < cal.loss_before
    assert cal.param_rel_error(gpu_t, st_t) < 1e-2
    # error report: every stencil's predicted-vs-measured error collapses
    for name in cal.stencils:
        assert cal.errors_after[name] < 1e-2
        assert cal.errors_after[name] < cal.errors_before[name]


def test_noisy_fit_still_converges_near_truth():
    gpu_t, st_t = _truth()
    recs = synthetic_records(gpu_t, st_t, noise=0.05, seed=7)
    cal = fit_machine_params(recs, gpu0=MAXWELL_GPU, stencils0=STENCILS)
    assert cal.loss_after < cal.loss_before
    assert cal.param_rel_error(gpu_t, st_t) < 0.15


def test_fit_drops_infeasible_records_and_requires_some():
    recs = synthetic_records(MAXWELL_GPU)
    bad = MeasurementRecord(
        stencil="heat2d", size=(64, 64, 1, 4), tiles=(8, 33, 2, 1, 1),
        time_s=1.0, hw=(16.0, 128.0, 96.0),
    )
    cal = fit_machine_params(recs + [bad], gpu0=MAXWELL_GPU)
    assert cal.n_dropped == 1 and cal.n_records == len(recs)
    with pytest.raises(ValueError, match="no measurement records"):
        fit_machine_params([])
    with pytest.raises(ValueError, match="infeasible"):
        fit_machine_params([bad])


def test_calibration_result_payload_round_trip_and_apply():
    gpu_t, st_t = _truth()
    cal = fit_machine_params(
        synthetic_records(gpu_t, st_t), gpu0=MAXWELL_GPU, iters=50
    )
    back = CalibrationResult.from_payload(cal.to_payload())
    assert back.gpu == cal.gpu
    assert back.stencils == cal.stencils
    assert back.errors_after == cal.errors_after
    # calibrated identities are routable as distinct targets
    assert back.calibrated_gpu().name == "gtx980-cal"
    wl = back.calibrated_workload()
    assert wl.name == "paper-uniform-cal"
    assert {c.stencil.name for c in wl.cells} == set(STENCILS)
    assert all(
        c.stencil.c_iter == back.stencils[c.stencil.name].c_iter for c in wl.cells
    )
    with pytest.raises(KeyError, match="not calibrated"):
        back.calibrated_workload(["nosuch"])


def test_fit_on_real_harness_records_improves_prediction():
    """A tiny real measurement run (interpret mode) will not match a GPU
    model closely, but the refit must still cut the log-space loss --
    the predict -> measure -> refit loop improves, end to end."""
    from repro.measure.harness import measure_grid

    grid = {
        "heat2d": [
            {"shape": (48, 64), "steps": 4,
             "tiles": {"t_s1": 8, "t_s2": 32, "t_t": 2, "k": 1, "t_s3": 1}},
            {"shape": (96, 128), "steps": 4,
             "tiles": {"t_s1": 16, "t_s2": 64, "t_t": 2, "k": 2, "t_s3": 1}},
        ],
    }
    run = measure_grid(grid, warmup=1, repeats=2, interpret=True)
    cal = fit_machine_params(run, iters=300)
    assert cal.loss_after < cal.loss_before
    assert set(cal.stencils) == {"heat2d"}


# ---------------------------------------------------------------------------
# store integration (kind="measurement"/"calibration" artifacts)
# ---------------------------------------------------------------------------
def test_store_json_artifacts_round_trip_and_dedupe(tmp_path):
    from repro.service import ArtifactStore

    store = ArtifactStore(str(tmp_path))
    run = MeasurementRun(
        records=[
            MeasurementRecord(
                stencil="heat2d", size=(64, 64, 1, 4), tiles=(8, 32, 2, 1, 1),
                time_s=2e-3, hw=(16.0, 128.0, 96.0),
            )
        ],
        gpu_name="gtx980", backend="cpu", interpret=True,
    )
    art = store.put_json(
        "measurement", run.to_payload(), routing={"gpu": "gtx980"}
    )
    assert art.kind == "measurement"
    assert MeasurementRun.from_payload(art.payload).records == run.records
    # content addressing: same payload -> same key; any change -> new key
    assert store.put_json("measurement", run.to_payload()).key == art.key
    other = run.to_payload()
    other["note"] = "different"
    assert store.put_json("measurement", other).key != art.key
    # routing rows carry the kind and never pretend to be sweeps
    rows = {r["key"]: r for r in store.entries()}
    assert rows[art.key]["kind"] == "measurement"
    assert rows[art.key]["gpu"] == "gtx980"
    with pytest.raises(ValueError, match="manifest-only"):
        store.put_json("sweep", {})
    with pytest.raises(ValueError, match="manifest-only"):
        store.put_json("nosuch", {})
