"""Production meshes.

Single pod  : (16, 16)    -> axes ("data", "model")          = 256 chips
Multi-pod   : (2, 16, 16) -> axes ("pod", "data", "model")   = 512 chips

``make_production_mesh`` is a FUNCTION (not module state) so importing this
module never touches jax device state; the dry-run entrypoint sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import, everything else sees the host's real device count.
"""

from __future__ import annotations

from typing import Optional, Sequence, Tuple

import numpy as np

__all__ = ["make_production_mesh", "make_mesh", "SINGLE_POD", "MULTI_POD"]

SINGLE_POD: Tuple[int, ...] = (16, 16)
MULTI_POD: Tuple[int, ...] = (2, 16, 16)


def make_production_mesh(*, multi_pod: bool = False):
    """The assignment's production mesh over (a prefix of) jax.devices()."""
    shape = MULTI_POD if multi_pod else SINGLE_POD
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_mesh(shape: Sequence[int], axes: Sequence[str]):
    """Build a Mesh of the requested shape from the first prod(shape)
    devices (jax.make_mesh when counts line up, manual reshape otherwise --
    the dry-run runs with 512 fake devices and also builds 256-chip
    single-pod meshes)."""
    import jax
    from jax.sharding import Mesh

    need = int(np.prod(shape))
    devs = jax.devices()
    if len(devs) < need:
        raise RuntimeError(
            f"mesh {tuple(shape)} needs {need} devices, have {len(devs)} "
            "(dry-run sets XLA_FLAGS=--xla_force_host_platform_device_count=512)"
        )
    if len(devs) == need:
        return jax.make_mesh(tuple(shape), tuple(axes))
    return Mesh(np.array(devs[:need]).reshape(tuple(shape)), tuple(axes))
