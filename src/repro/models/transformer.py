"""Block + stack assembly.

A *block* = pre-norm mixer (attention or SSD) + pre-norm FFN (MLP or MoE),
with an optional cross-attention sub-layer (enc-dec decoders).

A *stack* is a list of **segments**: (pattern, repeats) where pattern is a
short tuple of (mixer, ffn) block kinds and the segment executes
``pattern * repeats`` layers. Parameters of the r repeats are stacked on a
leading axis and consumed with ``jax.lax.scan`` so each distinct block body
is traced exactly once -- jamba's 8-layer period, deepseek's 3 dense + 58
MoE split, and uniform stacks all reduce to this representation, and
compile time at 512 fake devices stays sane.

Remat: the per-block function is wrapped in ``jax.checkpoint`` with a
selectable policy ('none' | 'dots' | 'full') -- a §Perf hillclimb lever.
"""

from __future__ import annotations

import functools
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name

from ..configs.base import ArchConfig
from .attention import attn_init, attention
from .layers import mlp, mlp_init, rmsnorm, rmsnorm_init
from .moe import moe_apply, moe_init
from .ssm import ssm_apply, ssm_init

__all__ = [
    "segments",
    "stack_init",
    "stack_apply",
    "block_init",
    "block_apply",
    "REMAT_POLICIES",
]

REMAT_POLICIES = {
    "none": None,
    "dots": jax.checkpoint_policies.checkpoint_dots,
    "dots_no_batch": jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
    "full": jax.checkpoint_policies.nothing_saveable,
    # save exactly the post-collective sub-layer outputs: the backward pass
    # then re-runs elementwise work but NOT the forward TP all-reduces --
    # the collective-term lever of the SPerf hillclimb
    "save_block_io": jax.checkpoint_policies.save_only_these_names(
        "mixer_out", "ffn_out"
    ),
}


def segments(cfg: ArchConfig) -> List[Tuple[Tuple[Tuple[str, str], ...], int]]:
    """Decompose layer kinds into (pattern, repeats) segments."""
    kinds = list(cfg.layer_kinds())
    segs: List[Tuple[Tuple[Tuple[str, str], ...], int]] = []
    first_dense = cfg.moe.first_dense if cfg.moe else 0
    if first_dense:
        segs.append((tuple(kinds[:first_dense]), 1))
        kinds = kinds[first_dense:]
    n = len(kinds)
    for p in range(1, n + 1):
        if n % p:
            continue
        unit = kinds[:p]
        if kinds == unit * (n // p):
            segs.append((tuple(unit), n // p))
            break
    return segs


# ---------------------------------------------------------------------------
# Single block
# ---------------------------------------------------------------------------
def block_init(key, cfg: ArchConfig, mixer: str, ffn: str, dtype, cross: bool = False):
    keys = jax.random.split(key, 6)
    p: Dict = {"norm1": rmsnorm_init(cfg.d_model, dtype, cfg.rms_offset)}
    if mixer == "attn":
        p["mixer"] = attn_init(keys[0], cfg, dtype)
    else:
        p["mixer"] = ssm_init(keys[0], cfg, dtype)
    if cross:
        p["norm_cross"] = rmsnorm_init(cfg.d_model, dtype, cfg.rms_offset)
        p["cross"] = attn_init(keys[1], cfg, dtype, cross=True)
    if ffn != "none":
        p["norm2"] = rmsnorm_init(cfg.d_model, dtype, cfg.rms_offset)
        p["ffn"] = (
            moe_init(keys[2], cfg, dtype) if ffn == "moe" else mlp_init(keys[2], cfg.d_model, cfg.d_ff, cfg.act, dtype)
        )
    return p


def block_apply(
    params,
    cfg: ArchConfig,
    mixer: str,
    ffn: str,
    x,
    *,
    positions,
    mode: str,
    cache: Optional[Dict],
    enc_out: Optional[jnp.ndarray],
    impl: str,
    cross: bool = False,
):
    """Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    new_cache: Dict = {}
    h = rmsnorm(params["norm1"], x, cfg.rms_offset)
    if mixer == "attn":
        mixer_cache = cache.get("mixer") if cache else None
        h, c = attention(
            params["mixer"], cfg, h, positions=positions, mode=mode,
            cache=mixer_cache, impl=impl,
        )
        if c is not None:
            new_cache["mixer"] = c
    else:
        mixer_cache = cache.get("mixer") if cache else None
        h, c = ssm_apply(params["mixer"], cfg, h, cache=mixer_cache)
        if c is not None:
            new_cache["mixer"] = c
    h = checkpoint_name(h, "mixer_out")
    x = x + h
    if cross:
        h = rmsnorm(params["norm_cross"], x, cfg.rms_offset)
        cross_cache = cache.get("cross") if cache else None
        h, c = attention(
            params["cross"], cfg, h, positions=positions, mode="cross",
            cache=cross_cache, kv_source=enc_out, impl=impl,
        )
        if c is not None:
            new_cache["cross"] = c
        x = x + h
    if ffn != "none":
        h = rmsnorm(params["norm2"], x, cfg.rms_offset)
        if ffn == "moe":
            h, aux = moe_apply(params["ffn"], cfg, h)
        else:
            h = mlp(params["ffn"], h, cfg.act)
        h = checkpoint_name(h, "ffn_out")
        x = x + h
    return x, (new_cache or None), aux


# ---------------------------------------------------------------------------
# Stacks
# ---------------------------------------------------------------------------
def stack_init(key, cfg: ArchConfig, dtype, *, cross: bool = False, segs=None):
    """Parameters: {'seg0': (slot params stacked over repeats), ...}."""
    segs = segs if segs is not None else segments(cfg)
    out = {}
    for si, (pattern, reps) in enumerate(segs):
        slot_params = []
        for j, (mixer, ffn) in enumerate(pattern):
            keys = jax.random.split(jax.random.fold_in(key, si * 131 + j), reps)
            stacked = jax.vmap(
                lambda kk: block_init(kk, cfg, mixer, ffn, dtype, cross=cross)
            )(keys)
            slot_params.append(stacked)
        out[f"seg{si}"] = tuple(slot_params)
    return out


def stack_apply(
    params,
    cfg: ArchConfig,
    x,
    *,
    positions,
    mode: str = "causal",
    caches=None,
    enc_out=None,
    impl: str = "auto",
    remat: str = "none",
    cross: bool = False,
    segs=None,
):
    """Run the full stack. Returns (x, new_caches, aux_sum).

    ``caches`` mirrors the parameter structure: {'seg0': (slot caches with
    leaves stacked over repeats, ...)} or None for training.
    """
    segs = segs if segs is not None else segments(cfg)
    aux_total = jnp.zeros((), jnp.float32)
    new_caches = {} if caches is not None else None

    for si, (pattern, reps) in enumerate(segs):
        seg_p = params[f"seg{si}"]
        seg_c = caches.get(f"seg{si}") if caches is not None else None

        def one_layer(x, slot_params, slot_caches, pattern=pattern):
            new_slot_caches = []
            aux = jnp.zeros((), jnp.float32)
            for j, (mixer, ffn) in enumerate(pattern):
                c_in = slot_caches[j] if slot_caches is not None else None
                x, c_out, a = block_apply(
                    slot_params[j], cfg, mixer, ffn, x,
                    positions=positions, mode=mode, cache=c_in,
                    enc_out=enc_out, impl=impl, cross=cross,
                )
                new_slot_caches.append(c_out)
                aux = aux + a
            return x, tuple(new_slot_caches), aux

        policy = REMAT_POLICIES.get(remat, None)
        if remat != "none":
            one_layer = jax.checkpoint(
                one_layer, policy=policy, static_argnums=()
            )

        if reps == 1:
            sp = jax.tree.map(lambda a: a[0], seg_p)
            sc = jax.tree.map(lambda a: a[0], seg_c) if seg_c is not None else None
            x, c_out, aux = one_layer(x, sp, sc)
            aux_total = aux_total + aux
            if new_caches is not None:
                new_caches[f"seg{si}"] = (
                    jax.tree.map(lambda a: a[None], c_out) if c_out is not None else None
                )
        else:

            def body(carry, xs):
                x, aux_acc = carry
                if seg_c is not None:
                    sp, sc = xs
                else:
                    sp, sc = xs, None
                x, c_out, aux = one_layer(x, sp, sc)
                return (x, aux_acc + aux), c_out

            xs = (seg_p, seg_c) if seg_c is not None else seg_p
            (x, aux_total), seg_c_out = jax.lax.scan(body, (x, aux_total), xs)
            if new_caches is not None:
                new_caches[f"seg{si}"] = seg_c_out

    return x, new_caches, aux_total
