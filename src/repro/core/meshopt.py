"""Mesh/sharding codesign -- the paper's eq. (18) on the TPU fleet.

Exhaustive search over the hardware factorization (pod, data, model) of the
chip budget x an independent small integer search over the software knobs
(microbatches, remat, fsdp, compression) per (arch, shape) cell -- exactly
the separability decomposition the paper uses for (n_SM, n_V, M_SM) x tile
sizes. The analytic `lm_roofline` plays T_alg; HBM capacity plays the chip
area budget.

Output is a ranked list of feasible plans per cell; the §Perf hillclimb
takes the top proposals, re-lowers them through the real dry-run, and
accepts/rejects on measured compiled terms (hypothesis -> change ->
measure -> validate).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from ..configs.base import ArchConfig, ShapeSpec
from .lmtime import HW, MeshPlan, lm_roofline

__all__ = ["enumerate_plans", "optimize", "pareto_plans"]


def _factorizations(chips: int, multi_pod: bool) -> List[Tuple[int, int, int]]:
    pods = [2] if multi_pod else [1]
    out = []
    for pod in pods:
        rest = chips // pod
        model = 1
        while model <= rest:
            if rest % model == 0:
                out.append((pod, rest // model, model))
            model *= 2
    return out


def enumerate_plans(
    chips: int = 256,
    multi_pod: bool = False,
    microbatches=(1, 2, 4, 8, 16, 32),
    remats=("none", "full"),
    fsdps=(False, True),
    compress=(False, True),
    train: bool = True,
) -> List[MeshPlan]:
    plans = []
    for pod, data, model in _factorizations(chips, multi_pod):
        for mb in microbatches if train else (1,):
            for remat in remats if train else ("none",):
                for fsdp in fsdps:
                    for comp in compress if (train and pod > 1) else (False,):
                        plans.append(
                            MeshPlan(pod, data, model, mb, remat, fsdp, comp)
                        )
    return plans


def optimize(
    cfg: ArchConfig,
    shape: ShapeSpec,
    n_params: int,
    n_active: int,
    chips: int = 256,
    multi_pod: bool = False,
    top_k: int = 5,
    constraints: Optional[Dict] = None,
) -> List[Dict]:
    """Ranked feasible plans (lowest bound_s first) for one cell."""
    train = shape.kind == "train"
    results = []
    for plan in enumerate_plans(chips, multi_pod, train=train):
        if shape.global_batch % plan.data_shards and shape.global_batch >= plan.data_shards:
            continue
        if train and shape.global_batch % (plan.data_shards * plan.microbatches):
            continue
        r = lm_roofline(cfg, shape, plan, n_params, n_active)
        if constraints:
            if not all(r.get(k) == v for k, v in constraints.items()):
                continue
        if not r["fits"]:
            continue
        results.append({"plan": dataclasses.asdict(plan), **r})
    results.sort(key=lambda r: r["bound_s"])
    return results[:top_k]


def pareto_plans(results: List[Dict]) -> List[Dict]:
    """Pareto set over (chips used, bound_s) -- the Fig. 3 analogue."""
    out = []
    best = float("inf")
    for r in sorted(results, key=lambda r: r["plan"]["pod"] * r["plan"]["data"] * r["plan"]["model"]):
        if r["bound_s"] < best:
            best = r["bound_s"]
            out.append(r)
    return out
