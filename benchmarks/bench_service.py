"""Codesign query service: queries/sec cold (artifact miss -> full eq.-18
sweep) vs warm (stored artifact -> vectorized re-reductions), then the
fleet gateway's tax on top of warm (routing + LRU server pool, locally
and over the HTTP wire), and the observability tax (repro.obs metrics +
spans on vs disabled, asserted under 5%).

Cold is measured against a throwaway store so the number is honest even
when CI restored the persistent artifact cache; warm is measured against
the persistent store with a fresh server (artifact mmap-loaded from disk,
LRU cold), then with the LRU primed, then through the stacked
``query_many`` matmul. The warm/cold ratio is asserted >= 100x -- the
entire point of persisting the separability matrix.

The gateway stages build a second GPU target (titanx) into the same store
and alternate requests across both artifacts -- real fleet traffic, every
query routed -- first through :meth:`Gateway.query` in-process, then
through the stdlib HTTP server + client. Gateway QPS (warm local vs
over-HTTP) is appended to the repo-root ``BENCH_sweep.json`` trajectory
(schema: ``benchmarks/README.md``)."""

from __future__ import annotations

import os
import shutil
import tempfile
import threading
import time

import numpy as np

from repro.core.timemodel import TITANX_GPU
from repro.obs.metrics import set_disabled
from repro.service import (
    ArtifactStore,
    CodesignServer,
    Gateway,
    GatewayClient,
    QueryRequest,
    serve_http,
)

from .common import (
    ARTIFACTS,
    SMOKE_HW_STRIDE,
    append_trajectory,
    emit,
    skey,
    smoke,
)

#: distinct frequency mixes per warm pass (all LRU misses on the first lap)
N_MIXES = 64

STENCIL_NAMES = (
    "jacobi2d", "heat2d", "laplacian2d", "gradient2d", "heat3d", "laplacian3d",
)


def _mixes(rng: np.random.Generator, n: int, use_cache: bool = True):
    return [
        QueryRequest(
            freqs=dict(zip(STENCIL_NAMES, rng.uniform(0.05, 1.0, size=6))),
            max_area=650.0,
            top_k=3,
            use_cache=use_cache,
        )
        for _ in range(n)
    ]


def run() -> None:
    downsample = SMOKE_HW_STRIDE if smoke() else 1
    rng = np.random.default_rng(2017)

    # --- cold: throwaway store, one query pays sweep + persist + reduce ----
    tmp = tempfile.mkdtemp(prefix="bench-service-cold-")
    try:
        cold_srv = CodesignServer(
            ArtifactStore(tmp), downsample=downsample, batch_window=0.0
        )
        assert not cold_srv.warm
        t0 = time.perf_counter()
        cold_resp = cold_srv.query(_mixes(rng, 1)[0])
        t_cold = time.perf_counter() - t0
        assert cold_srv.stats["artifact_builds"] == 1
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
    emit(
        "service_cold", t_cold * 1e6,
        f"miss path: sweep + persist + query = {t_cold:.2f}s "
        f"({1.0/t_cold:.3f} q/s), best {cold_resp.best_gflops:.0f} GFLOP/s",
    )

    # --- warm: persistent store (CI caches it between steps/runs) ---------
    root = os.path.join(ARTIFACTS, skey("service"))
    store = ArtifactStore(root)
    CodesignServer(store, downsample=downsample, batch_window=0.0).ensure_artifact()

    srv = CodesignServer(store, downsample=downsample, batch_window=0.0)
    assert srv.warm, "persistent artifact should be on disk by now"
    reqs = _mixes(rng, N_MIXES)
    t0 = time.perf_counter()
    for r in reqs:
        srv.query(r)
    t_warm = time.perf_counter() - t0
    assert srv.stats["artifact_builds"] == 0
    qps_warm = len(reqs) / t_warm
    emit(
        "service_warm", t_warm / len(reqs) * 1e6,
        f"{len(reqs)} distinct mixes (LRU cold): {qps_warm:.0f} q/s",
    )

    t0 = time.perf_counter()
    for r in reqs:
        srv.query(r)
    t_lru = time.perf_counter() - t0
    emit(
        "service_warm_lru", t_lru / len(reqs) * 1e6,
        f"same mixes again (LRU hot): {len(reqs)/t_lru:.0f} q/s",
    )

    batch = _mixes(rng, N_MIXES)
    t0 = time.perf_counter()
    srv.query_many(batch)
    t_batch = time.perf_counter() - t0
    emit(
        "service_batched", t_batch / len(batch) * 1e6,
        f"one stacked (B={len(batch)}) matmul: {len(batch)/t_batch:.0f} q/s",
    )

    ratio = qps_warm / (1.0 / t_cold)
    emit(
        "service_speedup", t_cold * 1e6,
        f"warm/cold queries-per-sec ratio {ratio:.0f}x "
        f"(acceptance floor 100x)",
    )
    assert ratio >= 100.0, f"warm path only {ratio:.1f}x cold"

    # --- gateway: routed fleet traffic, local then over HTTP ---------------
    # a second GPU target in the same store makes the routing honest: every
    # request below is resolved (key -> routing index -> pooled per-artifact
    # server) before it is answered. Requests pin content keys: a persistent
    # fleet store legitimately accumulates extra artifacts across code
    # versions, so a bare {"gpu": ...} selector may be (correctly) ambiguous.
    srv_tx = CodesignServer(
        store, gpu=TITANX_GPU, downsample=downsample, batch_window=0.0
    )
    srv_tx.ensure_artifact()
    gw = Gateway(store.root, pool_size=4, batch_window=0.0)
    targets = [srv.key, srv_tx.key]

    reqs = _mixes(rng, N_MIXES)
    t0 = time.perf_counter()
    for i, r in enumerate(reqs):
        gw.query(r, artifact=targets[i % 2])
    t_gw = time.perf_counter() - t0
    qps_gw_local = len(reqs) / t_gw
    emit(
        "service_gateway_local", t_gw / len(reqs) * 1e6,
        f"routed across {len(gw)} artifacts in-process: "
        f"{qps_gw_local:.0f} q/s",
    )

    httpd = serve_http(gw)
    threading.Thread(target=httpd.serve_forever, daemon=True).start()
    url = "http://%s:%d" % httpd.server_address[:2]
    try:
        # one request set, LRU bypassed (use_cache=False), for all three
        # HTTP stages: transport is the ONLY variable in the A/B -- fresh
        # mixes per stage would confound it with reduction-cost variance,
        # shared mixes WITH the LRU would hand later stages cache hits.
        reqs = _mixes(rng, N_MIXES, use_cache=False)

        # (a) BEFORE: one TCP connection per request (the pre-PR5 client
        # behavior, kept behind keepalive=False for exactly this A/B) --
        # ROADMAP attributes most of the wire tax to connection setup.
        client = GatewayClient(url, keepalive=False)
        t0 = time.perf_counter()
        for i, r in enumerate(reqs):
            client.query(r, artifact=targets[i % 2])
        t_http_cpr = time.perf_counter() - t0

        # (b) AFTER: one persistent keep-alive connection, same mixes.
        client = GatewayClient(url)
        t0 = time.perf_counter()
        for i, r in enumerate(reqs):
            client.query(r, artifact=targets[i % 2])
        t_http = time.perf_counter() - t0

        # (c) batched wire: the same N routed queries in ONE
        # /v1/query_many round trip (per-artifact stacked matmuls).
        batch_http = [(r, targets[i % 2], None) for i, r in enumerate(reqs)]
        t0 = time.perf_counter()
        results = client.query_many(batch_http)
        t_http_many = time.perf_counter() - t0
        assert all(not isinstance(x, Exception) for x in results)

        # (d) observability tax: the same batched round trip with the
        # repro.obs registry live vs disabled (the in-process switch behind
        # REPRO_OBS_DISABLED=1; the server runs in THIS process, so the
        # toggle covers both sides of the wire). Alternating best-of-4 laps
        # de-noise the A/B before the <5% acceptance gate below.
        t_obs = {False: float("inf"), True: float("inf")}
        try:
            for _ in range(4):
                for disabled in (False, True):
                    set_disabled(disabled)
                    t0 = time.perf_counter()
                    obs_results = client.query_many(batch_http)
                    t_obs[disabled] = min(
                        t_obs[disabled], time.perf_counter() - t0
                    )
                    assert all(
                        not isinstance(x, Exception) for x in obs_results
                    )
        finally:
            set_disabled(None)  # back to whatever the env says

        # (e) resilience tax: the same batched round trip with the
        # admission-control + breaker + deadline layer live (the default
        # permissive GatewayResilience bundle) vs resilience=None. The
        # happy path through the layer is a handful of no-op checks
        # (inflight counter, disabled buckets, one contextvar read), so
        # this A/B holds it to the same <5% ceiling as observability.
        res_bundle = gw.resilience
        t_res = {True: float("inf"), False: float("inf")}
        try:
            for _ in range(4):
                for on in (True, False):
                    gw.resilience = res_bundle if on else None
                    t0 = time.perf_counter()
                    res_results = client.query_many(batch_http)
                    t_res[on] = min(t_res[on], time.perf_counter() - t0)
                    assert all(
                        not isinstance(x, Exception) for x in res_results
                    )
        finally:
            gw.resilience = res_bundle
    finally:
        httpd.shutdown()
        httpd.server_close()
    qps_http_cpr = len(reqs) / t_http_cpr
    qps_gw_http = len(reqs) / t_http
    qps_http_many = len(batch_http) / t_http_many
    emit(
        "service_gateway_http_conn_per_req", t_http_cpr / len(reqs) * 1e6,
        f"HTTP, new connection per request: {qps_http_cpr:.0f} q/s "
        f"({qps_gw_local / qps_http_cpr:.1f}x wire tax)",
    )
    emit(
        "service_gateway_http", t_http / len(reqs) * 1e6,
        f"HTTP, persistent connection: {qps_gw_http:.0f} q/s "
        f"({qps_gw_local / qps_gw_http:.1f}x wire tax, "
        f"{qps_gw_http / qps_http_cpr:.1f}x vs per-request connections)",
    )
    emit(
        "service_gateway_http_batched", t_http_many / len(batch_http) * 1e6,
        f"one /v1/query_many round trip (B={len(batch_http)}): "
        f"{qps_http_many:.0f} q/s",
    )

    qps_obs_on = len(batch_http) / t_obs[False]
    qps_obs_off = len(batch_http) / t_obs[True]
    overhead = 1.0 - qps_obs_on / qps_obs_off
    emit(
        "service_obs_overhead", t_obs[False] / len(batch_http) * 1e6,
        f"metrics+spans on {qps_obs_on:.0f} q/s vs off {qps_obs_off:.0f} q/s "
        f"({overhead * 100:+.1f}% tax; acceptance ceiling 5%)",
    )
    assert overhead < 0.05, (
        f"observability tax {overhead * 100:.1f}% >= 5% "
        f"(on {qps_obs_on:.0f} q/s, off {qps_obs_off:.0f} q/s)"
    )

    qps_res_on = len(batch_http) / t_res[True]
    qps_res_off = len(batch_http) / t_res[False]
    res_overhead = 1.0 - qps_res_on / qps_res_off
    emit(
        "service_resilience_overhead", t_res[True] / len(batch_http) * 1e6,
        f"admission+deadline+breaker on {qps_res_on:.0f} q/s vs off "
        f"{qps_res_off:.0f} q/s ({res_overhead * 100:+.1f}% tax; "
        f"acceptance ceiling 5%)",
    )
    assert res_overhead < 0.05, (
        f"resilience tax {res_overhead * 100:.1f}% >= 5% "
        f"(on {qps_res_on:.0f} q/s, off {qps_res_off:.0f} q/s)"
    )

    append_trajectory(
        "sweep",
        {
            "suite": "service",
            "smoke": smoke(),
            "artifacts": len(gw),
            "hw_points": len(srv.hw),
            "cold_s": round(t_cold, 4),
            "warm_qps": round(qps_warm, 1),
            "warm_lru_qps": round(len(reqs) / t_lru, 1),
            "batched_qps": round(len(batch) / t_batch, 1),
            "gateway_local_qps": round(qps_gw_local, 1),
            "gateway_http_conn_per_req_qps": round(qps_http_cpr, 1),
            "gateway_http_qps": round(qps_gw_http, 1),
            "gateway_http_batched_qps": round(qps_http_many, 1),
            "obs_on_qps": round(qps_obs_on, 1),
            "obs_off_qps": round(qps_obs_off, 1),
            "obs_overhead_pct": round(overhead * 100, 2),
            "resilience_on_qps": round(qps_res_on, 1),
            "resilience_off_qps": round(qps_res_off, 1),
            "resilience_overhead_pct": round(res_overhead * 100, 2),
        },
    )
