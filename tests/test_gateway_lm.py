"""Gateway routing across cell families: the ``workload``/``family``/
``models``/``ops`` selectors, cross-family ambiguity as a structured 400
(``ambiguous_workload``, mirroring ``wrong_artifact_kind``'s
classification), HTTP byte-identity vs the in-process LM server, and the
CLI's ``--workload lm`` end-to-end path (the acceptance query: Llama-3-8B
decode at batch 64 under a chip budget)."""

import json
import subprocess
import sys
import tempfile
import threading

import pytest

from repro.configs import get_arch
from repro.core import MAXWELL, enumerate_hw_space
from repro.core.lmcells import enumerate_lm_hw_space, lm_workload
from repro.core.timemodel import MAXWELL_GPU
from repro.core.workload import paper_workload
from repro.service import (
    ArtifactStore,
    CodesignServer,
    Gateway,
    GatewayClient,
    QueryRequest,
    RemoteError,
    serve_http,
    wire,
)
from repro.service.gateway import AmbiguousWorkloadError
from repro.service.server import LMServer

#: the stencil artifact's GPU name, reused for the LM sweep to force the
#: cross-family collision the workload selector exists to resolve.
GPU = MAXWELL_GPU.name
MODEL = "llama3-8b-reduced"


@pytest.fixture(scope="module")
def fleet():
    """One store holding a stencil sweep and an LM sweep for the SAME gpu
    name, their oracle servers, a gateway, and a live HTTP endpoint."""
    root = tempfile.mkdtemp(prefix="lmgw-")
    store = ArtifactStore(root)
    ssrv = CodesignServer(
        store,
        workload=paper_workload(["heat2d", "jacobi2d"]),
        gpu=MAXWELL_GPU,
        hw=enumerate_hw_space(MAXWELL, max_area=650.0).downsample(64),
        engine="numpy",
        batch_window=0.0,
    )
    ssrv.ensure_artifact()
    lsrv = LMServer(
        store,
        workload=lm_workload(archs=[get_arch("llama3-8b").reduced()], name="lm"),
        hw=enumerate_lm_hw_space(max_chips=32),
        engine="numpy",
        gpu_name=GPU,
        batch_window=0.0,
    )
    lsrv.ensure_artifact()
    gw = Gateway(root, batch_window=0.0)
    httpd = serve_http(gw)
    threading.Thread(target=httpd.serve_forever, daemon=True).start()
    url = "http://%s:%d" % httpd.server_address[:2]
    yield ssrv, lsrv, gw, url
    httpd.shutdown()
    httpd.server_close()


def _req(**kw):
    kw.setdefault("freqs", {f"{MODEL}:decode": 1.0})
    kw.setdefault("use_cache", False)
    return QueryRequest(**kw)


def test_cross_family_ambiguity_is_structured_400(fleet):
    _, _, gw, url = fleet
    with pytest.raises(AmbiguousWorkloadError) as ei:
        gw.resolve(route={"gpu": GPU})
    assert ei.value.code == "ambiguous_workload"
    assert ei.value.http_status == 400
    assert "workload" in str(ei.value)  # tells the caller the fix
    # same classification as wrong_artifact_kind: the request is at fault
    assert (wire.ERROR_HTTP_STATUS["ambiguous_workload"]
            == wire.ERROR_HTTP_STATUS["wrong_artifact_kind"] == 400)
    # and the same failure crosses the wire structurally, never a 500
    with pytest.raises(RemoteError) as ei:
        GatewayClient(url).query(_req(), route={"gpu": GPU})
    assert ei.value.code == "ambiguous_workload"
    assert ei.value.http_status == 400


def test_workload_and_family_selectors_resolve(fleet):
    ssrv, lsrv, gw, _ = fleet
    assert gw.resolve(route={"gpu": GPU, "workload": "lm"}) == lsrv.key
    assert gw.resolve(route={"gpu": GPU, "family": "lm"}) == lsrv.key
    assert gw.resolve(route={"gpu": GPU, "family": "stencil"}) == ssrv.key
    assert gw.resolve(route={"workload": "paper-uniform"}) == ssrv.key
    with pytest.raises(Exception, match="no stored artifact"):
        gw.resolve(route={"workload": "nope"})


def test_models_and_ops_subset_selectors(fleet):
    _, lsrv, gw, _ = fleet
    assert gw.resolve(route={"models": [MODEL]}) == lsrv.key
    assert gw.resolve(route={"ops": ["decode", "train"]}) == lsrv.key
    with pytest.raises(Exception, match="no stored artifact"):
        gw.resolve(route={"ops": ["decode", "backprop"]})
    # stencil subset selection is unaffected by the LM artifact
    ssrv = fleet[0]
    assert gw.resolve(route={"stencils": ["heat2d"]}) == ssrv.key


def test_http_lm_answers_are_byte_identical_to_in_process(fleet):
    _, lsrv, _, url = fleet
    client = GatewayClient(url)
    route = {"gpu": GPU, "workload": "lm"}
    for req in (
        _req(max_area=16.0, top_k=3, pareto=True),
        _req(freqs={MODEL: 1.0}, top_k=5),              # model-level group
        _req(freqs={"train": 1.0}, fix={"model": 2.0}),  # op group + what-if
        _req(max_area=0.5),                             # infeasible budget
    ):
        raw = client.query_bytes(req, route=route)
        assert raw == wire.encode_response(lsrv.query(req))
    # the decoded answer is a mesh design point under the chip budget
    resp = client.query(_req(max_area=16.0, top_k=3), route=route)
    assert resp.best_index >= 0
    assert set(resp.best_point) == {"pod", "data", "model", "chips"}
    assert resp.best_point["chips"] <= 16


def test_unknown_group_is_bad_request(fleet):
    _, _, _, url = fleet
    with pytest.raises(RemoteError) as ei:
        GatewayClient(url).query(
            _req(freqs={"not-a-group": 1.0}), route={"gpu": GPU, "workload": "lm"}
        )
    assert ei.value.code == "bad_request"
    assert ei.value.http_status == 400


def test_artifact_listing_carries_lm_routing(fleet):
    _, lsrv, gw, _ = fleet
    rows = {r["key"]: r for r in gw.entries()}
    row = rows[lsrv.key]
    assert row["family"] == "lm"
    assert row["models"] == [MODEL]
    assert row["ops"] == ["decode", "prefill", "train"]
    stencil_rows = [r for r in rows.values() if r.get("family", "stencil") == "stencil"]
    assert stencil_rows and all("models" not in r for r in stencil_rows)


def test_cli_workload_lm_end_to_end(subprocess_env, tmp_path):
    """The acceptance query: chip config for Llama-3-8B decode at batch 64
    under a chip budget, via ``query --workload lm`` (cold build + warm)."""
    cmd = [
        sys.executable, "-m", "repro.service.cli", "query",
        "--store", str(tmp_path), "--workload", "lm",
        "--arch", "llama3-8b", "--chips", "64", "--engine", "numpy",
        "--freq", "llama3-8b:decode=1", "--max-area", "64",
        "--top-k", "3", "--json",
    ]
    out = subprocess.run(cmd, env=subprocess_env, capture_output=True, text=True)
    assert out.returncode == 0, out.stderr
    data = json.loads(out.stdout)
    assert data["feasible"]
    assert data["best"]["chips"] <= 64
    assert {"pod", "data", "model"} <= set(data["best"])
    assert len(data["top_k"]) <= 3
    # second run answers warm from the stored artifact, byte-identical
    again = subprocess.run(cmd, env=subprocess_env, capture_output=True, text=True)
    assert again.returncode == 0, again.stderr
    d2 = json.loads(again.stdout)
    assert d2["origin"] == "warm" and d2["best"] == data["best"]


def test_cli_rejects_lm_flags_without_lm_workload(subprocess_env, tmp_path):
    out = subprocess.run(
        [sys.executable, "-m", "repro.service.cli", "query",
         "--store", str(tmp_path), "--arch", "llama3-8b"],
        env=subprocess_env, capture_output=True, text=True,
    )
    assert out.returncode == 2
    assert "--workload lm" in out.stderr and "Traceback" not in out.stderr
