"""Codesign query engine: cheap re-reductions over a stored sweep artifact.

Everything here is "sensitivity for free" (paper §V.B): the expensive
eq.-18 matrix is already on disk, so a query -- an arbitrary stencil
frequency mix, a top-k under an area budget, a Pareto front, a what-if
subspace ("fix n_SM=16") -- is one vectorized pass over ``(C, H)`` data:

    weighted_time = F @ cell_time          # (B, C) @ (C, H)
    gflops        = (F @ cell_flops) / weighted_time / 1e9

A small LRU memoizes recent reduction rows, so repeated mixes (dashboards,
retry storms) skip even the matmul. :meth:`QueryEngine.answer_many` is the
microbatch entry point the in-process server feeds: requests sharing a
what-if signature stack their frequency vectors into ONE matmul.
"""

from __future__ import annotations

import dataclasses
import math
import threading
import time
from collections import OrderedDict
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.core.pareto import pareto_mask_batched
from repro.obs.metrics import get_registry as _obs_registry
from repro.obs.trace import span

from .store import Artifact

__all__ = ["QueryRequest", "QueryResponse", "QueryEngine"]

# ---- observability (repro.obs; no-ops under REPRO_OBS_DISABLED=1) --------
_REG = _obs_registry()
_M_LRU_HITS = _REG.counter(
    "repro_query_lru_hits_total",
    "reduction rows served from the QueryEngine LRU (matmul skipped)",
)
_M_LRU_MISSES = _REG.counter(
    "repro_query_lru_misses_total",
    "reduction rows that had to ride the (B', C) @ (C, H) matmul",
)
_M_REDUCE_SECONDS = _REG.histogram(
    "repro_query_reduce_seconds",
    "wall time of one stacked reduction matmul over the optima matrix",
)


@dataclasses.dataclass(frozen=True)
class QueryRequest:
    """One codesign question against a stored artifact.

    ``freqs`` weights whole cell groups (unnormalized; redistributed over
    each group's stored cells proportionally to the artifact's cell
    frequencies). Group names are stencil names for stencil artifacts; LM
    artifacts accept a model name, an op name, or an exact ``model:op``
    label. ``cell_freqs`` overrides with an explicit per-cell vector.
    Leaving both None asks about the artifact's own workload mix.
    ``fix`` is the what-if subspace: only hardware points whose named
    design parameters equal the given values compete (e.g.
    ``{"n_sm": 16}``); the response also carries the unrestricted
    baseline's best so the delta is one subtraction away.

    Requests cross process boundaries via :mod:`repro.service.wire`; every
    field here is a wire field (``docs/serving.md`` documents each one).
    """

    freqs: Optional[Mapping[str, float]] = None
    cell_freqs: Optional[Sequence[float]] = None
    max_area: float = math.inf
    min_area: float = 0.0
    top_k: int = 1
    pareto: bool = False
    fix: Optional[Mapping[str, float]] = None
    use_cache: bool = True


@dataclasses.dataclass
class QueryResponse:
    """``best_index == -1`` (empty ``best_point``/``top_k``,
    ``best_gflops == -inf``) means NO design satisfies the request's
    budget/fix constraints -- never an arbitrary fallback design.

    Crosses process boundaries via :mod:`repro.service.wire`
    (``encode_response``/``decode_response``); the encoding is canonical,
    so equal responses always serialize to identical bytes (field
    reference: ``docs/serving.md``)."""

    artifact_key: str
    best_index: int
    best_gflops: float
    best_weighted_time: float
    best_point: Dict[str, float]
    top_k: List[Dict[str, float]]
    pareto_indices: Optional[np.ndarray] = None
    baseline_best_index: Optional[int] = None  # set iff the query had a what-if
    baseline_best_gflops: Optional[float] = None
    cached: bool = False  # reduction row came from the LRU
    batch_size: int = 1  # how many requests shared this reduction matmul


class _LRU:
    """Tiny thread-safe LRU of reduction rows, with stats."""

    def __init__(self, maxsize: int):
        self.maxsize = maxsize
        self._d: OrderedDict[bytes, Tuple[np.ndarray, np.ndarray]] = OrderedDict()
        self._mu = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def get(self, key: bytes):
        with self._mu:
            row = self._d.get(key)
            if row is None:
                self.misses += 1
                return None
            self._d.move_to_end(key)
            self.hits += 1
            return row

    def put(self, key: bytes, value) -> None:
        if self.maxsize <= 0:
            return
        with self._mu:
            self._d[key] = value
            self._d.move_to_end(key)
            while len(self._d) > self.maxsize:
                self._d.popitem(last=False)
                self.evictions += 1

    def __len__(self) -> int:
        with self._mu:
            return len(self._d)


def _fix_signature(fix: Optional[Mapping[str, float]]) -> Tuple:
    if not fix:
        return ()
    return tuple(sorted((str(k), float(v)) for k, v in fix.items()))


class QueryEngine:
    """Vectorized re-reductions over one artifact, with an LRU of recent
    reduction rows."""

    def __init__(self, artifact: Artifact, lru_size: int = 256):
        self.artifact = artifact
        self._flops = artifact.cell_flops()
        self._default_freqs = artifact.cell_freqs()
        # per-group cell index lists, in artifact cell order. Stencil
        # artifacts group by stencil name; LM artifacts register three
        # overlapping aliases per cell -- model ("llama3-8b"), op
        # ("decode"), and the exact "model:op" label -- so mixes can be
        # stated at whichever granularity the caller thinks in.
        self._group_cells: Dict[str, List[int]] = {}
        for i, c in enumerate(artifact.manifest["workload"]["cells"]):
            if artifact.family == "lm":
                for alias in (c["model"], c["op"], f"{c['model']}:{c['op']}"):
                    self._group_cells.setdefault(alias, []).append(i)
            else:
                self._group_cells.setdefault(c["stencil"]["name"], []).append(i)
        self.lru = _LRU(lru_size)

    # ---- frequency resolution --------------------------------------------
    def freq_vector(self, req: QueryRequest) -> np.ndarray:
        """(C,) normalized cell frequencies for a request."""
        c = self.artifact.n_cells
        if req.cell_freqs is not None:
            f = np.asarray(req.cell_freqs, np.float64)
            if f.shape != (c,):
                raise ValueError(f"cell_freqs must have shape ({c},); got {f.shape}")
        elif req.freqs is not None:
            f = np.zeros(c, np.float64)
            for name, w in req.freqs.items():
                cells = self._group_cells.get(name)
                if cells is None:
                    raise KeyError(
                        f"cell group {name!r} not in artifact "
                        f"(has {sorted(self._group_cells)})"
                    )
                base = self._default_freqs[cells]
                f[cells] = float(w) * base / base.sum()
        else:
            f = self._default_freqs.copy()
        total = f.sum()
        if not (np.isfinite(total) and total > 0):
            raise ValueError("frequency mix must have a positive finite sum")
        return f / total

    # ---- reductions -------------------------------------------------------
    def _feasible_mask(self, fix_sig: Tuple) -> Optional[np.ndarray]:
        if not fix_sig:
            return None
        mask = np.ones(self.artifact.n_hw, dtype=bool)
        for name, value in fix_sig:
            mask &= self.artifact.hw_column(name) == value
        return mask

    def _reduce_rows(
        self, fmat: np.ndarray, use_cache: Sequence[bool]
    ) -> Tuple[np.ndarray, np.ndarray, List[bool]]:
        """(B, C) frequency rows -> (wt (B, H), gflops (B, H), lru_hit flags).

        Rows found in the LRU skip the matmul; the rest stack into one
        ``(B', C) @ (C, H)`` product. A single uncached row intentionally
        uses the exact vector-matrix expression of
        ``CodesignResult.weighted_time`` so a warm service answer is
        bit-identical to a fresh in-process reduction.
        """
        b, _ = fmat.shape
        h = self.artifact.n_hw
        wt = np.empty((b, h))
        gf = np.empty((b, h))
        hit = [False] * b
        todo: List[int] = []
        keys: List[Optional[bytes]] = [None] * b
        for i in range(b):
            if use_cache[i]:
                keys[i] = fmat[i].tobytes()
                row = self.lru.get(keys[i])
                if row is not None:
                    wt[i], gf[i] = row
                    hit[i] = True
                    continue
            todo.append(i)
        _M_LRU_HITS.inc(b - len(todo))
        _M_LRU_MISSES.inc(len(todo))
        if todo:
            t0 = time.perf_counter()
            with span("reduce.matmul", rows=len(todo)):
                sub = fmat[todo]
                if len(todo) == 1:
                    wt_new = (sub[0] @ self.artifact.cell_time)[None, :]
                else:
                    wt_new = sub @ self.artifact.cell_time
                num = sub @ self._flops  # (B',)
                gf_new = num[:, None] / wt_new / 1.0e9
            _M_REDUCE_SECONDS.observe(time.perf_counter() - t0)
            for j, i in enumerate(todo):
                wt[i], gf[i] = wt_new[j], gf_new[j]
                if keys[i] is not None:
                    # copy: a row VIEW would pin the whole (B', H) batch
                    # product alive for as long as the entry stays cached
                    self.lru.put(keys[i], (wt_new[j].copy(), gf_new[j].copy()))
        return wt, gf, hit

    # ---- request finalization --------------------------------------------
    def _finalize(
        self,
        req: QueryRequest,
        wt_row: np.ndarray,
        gf_row: np.ndarray,
        cached: bool,
        batch_size: int,
    ) -> QueryResponse:
        art = self.artifact
        area = art.hw_area
        in_budget = (area <= req.max_area) & (area >= req.min_area)
        mask = self._feasible_mask(_fix_signature(req.fix))
        sel = in_budget if mask is None else (in_budget & mask)
        # a one-hot mix times an infeasible unused cell yields 0*inf = nan in
        # the (seed-exact) matmul; such designs are infeasible for the asked
        # mix, never winners
        g = np.where(sel & np.isfinite(gf_row), gf_row, -np.inf)
        best = int(np.argmax(g))
        feasible = bool(np.isfinite(g[best]))
        if not feasible:
            best = -1
        k = max(1, int(req.top_k))
        if k >= g.shape[0]:
            order = np.argsort(-g, kind="stable")
        else:
            part = np.argpartition(-g, k)[:k]
            order = part[np.argsort(-g[part], kind="stable")]
        top = [
            {**art.point(int(i)), "index": int(i), "gflops": float(g[i]),
             "weighted_time": float(wt_row[i])}
            for i in order[:k]
            if np.isfinite(g[i])
        ]
        resp = QueryResponse(
            artifact_key=art.key,
            best_index=best,
            best_gflops=float(g[best]) if feasible else -np.inf,
            best_weighted_time=float(wt_row[best]) if feasible else np.inf,
            best_point=art.point(best) if feasible else {},
            top_k=top,
            cached=cached,
            batch_size=batch_size,
        )
        if req.pareto:
            perf = np.where(sel, gf_row, -np.inf)  # -inf -> excluded (non-finite)
            resp.pareto_indices = np.nonzero(pareto_mask_batched(area, perf)[0])[0]
        if mask is not None:
            # what-if delta: unrestricted baseline under the same mix/budget
            # (left None when even the unrestricted budget is infeasible)
            g0 = np.where(in_budget & np.isfinite(gf_row), gf_row, -np.inf)
            b0 = int(np.argmax(g0))
            if np.isfinite(g0[b0]):
                resp.baseline_best_index = b0
                resp.baseline_best_gflops = float(g0[b0])
        return resp

    def query(self, req: QueryRequest) -> QueryResponse:
        return self.answer_many([req])[0]

    def answer_many(self, reqs: Sequence[QueryRequest]) -> List[QueryResponse]:
        """Answer a microbatch: one stacked reduction matmul for all
        LRU-missing frequency rows, then per-request finalization."""
        fmat = np.stack([self.freq_vector(r) for r in reqs])
        wt, gf, hit = self._reduce_rows(fmat, [r.use_cache for r in reqs])
        return [
            self._finalize(r, wt[i], gf[i], hit[i], len(reqs))
            for i, r in enumerate(reqs)
        ]
