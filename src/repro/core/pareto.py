"""Pareto-front extraction over (cost, performance) design points (Fig. 3).

A design is Pareto-optimal iff no other design has both lower-or-equal cost
(area) and strictly higher performance. The paper observes only ~1% of the
thousands of feasible designs are Pareto-optimal -- "a nearly 100-fold
savings in design cost".
"""

from __future__ import annotations

import numpy as np

__all__ = ["pareto_mask", "pareto_front"]


def pareto_mask(cost: np.ndarray, perf: np.ndarray) -> np.ndarray:
    """Boolean mask of Pareto-optimal points (minimize cost, maximize perf).

    O(n log n): sweep by ascending cost, keep the running best performance.
    Ties on cost keep only the best-performing point.
    """
    cost = np.asarray(cost, np.float64).ravel()
    perf = np.asarray(perf, np.float64).ravel()
    if cost.shape != perf.shape:
        raise ValueError("cost/perf shape mismatch")
    n = cost.shape[0]
    mask = np.zeros(n, dtype=bool)
    finite = np.isfinite(cost) & np.isfinite(perf)
    idx = np.nonzero(finite)[0]
    if idx.size == 0:
        return mask
    # sort by (cost asc, perf desc) so equal-cost groups see their best first
    order = idx[np.lexsort((-perf[idx], cost[idx]))]
    best = -np.inf
    for i in order:
        if perf[i] > best:
            mask[i] = True
            best = perf[i]
    return mask


def pareto_front(cost: np.ndarray, perf: np.ndarray):
    """(sorted_cost, sorted_perf, indices) of the Pareto-optimal points."""
    mask = pareto_mask(cost, perf)
    idx = np.nonzero(mask)[0]
    order = np.argsort(np.asarray(cost)[idx])
    idx = idx[order]
    return np.asarray(cost)[idx], np.asarray(perf)[idx], idx
