#!/usr/bin/env python
"""Executable-docs checker: run tagged fenced blocks, verify relative links.

Two guarantees over ``docs/*.md`` + ``README.md``:

1. **Runnable blocks run.** A fenced block whose info string carries the
   ``runnable`` tag (` ```bash runnable ` or ` ```python runnable `) is
   executed against a throwaway store (``$REPRO_STORE`` points into a temp
   dir; ``src/`` is prepended to ``PYTHONPATH``) with ``bash -euo
   pipefail`` / the current interpreter. Blocks in one file share the
   store and accumulate into one script per language *per file*, so a
   walkthrough can build an artifact in one block and query it in the
   next. Untagged blocks are prose -- never executed.

2. **Relative links resolve.** Every ``[text](target)`` whose target is
   not an absolute URL/anchor must exist on disk relative to the doc.

Exit 0 iff both hold everywhere; failures print per-file with the
offending block/link. CI runs this in the docs lane; locally:

    python scripts/check_docs.py            # all docs
    python scripts/check_docs.py docs/lm_codesign.md
"""

from __future__ import annotations

import argparse
import os
import re
import subprocess
import sys
import tempfile
from typing import Dict, List, Tuple

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

FENCE_RE = re.compile(
    r"^```(?P<info>[^\n`]*)\n(?P<body>.*?)^```\s*$", re.M | re.S
)
# [text](target) -- skipping images is fine (none in the tree), but the
# pattern tolerates them; inline code spans are cheaply excluded by the
# negative char class on the text.
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def default_docs() -> List[str]:
    docs = sorted(
        os.path.join("docs", n)
        for n in os.listdir(os.path.join(REPO, "docs"))
        if n.endswith(".md")
    )
    return ["README.md"] + docs


def runnable_blocks(text: str) -> List[Tuple[str, str]]:
    """(language, body) for every ``runnable``-tagged fence, in order."""
    out = []
    for m in FENCE_RE.finditer(text):
        info = m.group("info").split()
        if len(info) >= 2 and info[1] == "runnable":
            lang = info[0]
            if lang not in ("bash", "sh", "python"):
                raise ValueError(f"runnable tag on unsupported language {lang!r}")
            out.append(("bash" if lang == "sh" else lang, m.group("body")))
    return out


def check_links(path: str, text: str) -> List[str]:
    """Relative link targets that do not exist on disk."""
    # links inside fenced code are illustrative, not navigation
    prose = FENCE_RE.sub("", text)
    base = os.path.dirname(os.path.join(REPO, path))
    bad = []
    for target in LINK_RE.findall(prose):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        rel = target.split("#", 1)[0]
        if not rel:
            continue
        resolved = os.path.normpath(os.path.join(base, rel))
        if not resolved.startswith(REPO + os.sep):
            continue  # GitHub-relative idioms (../../actions/...) -- not disk paths
        if not os.path.exists(resolved):
            bad.append(target)
    return bad


def run_blocks(path: str, blocks: List[Tuple[str, str]]) -> Tuple[bool, str]:
    """Execute a file's runnable blocks, concatenated per language in doc
    order, inside one throwaway store. Returns (ok, combined output)."""
    with tempfile.TemporaryDirectory(prefix="docscheck-") as tmp:
        env = dict(os.environ)
        src = os.path.join(REPO, "src")
        prev = env.get("PYTHONPATH")
        env["PYTHONPATH"] = src + (os.pathsep + prev if prev else "")
        env["REPRO_STORE"] = os.path.join(tmp, "store")
        scripts: Dict[str, List[str]] = {}
        for lang, body in blocks:
            scripts.setdefault(lang, []).append(body)
        for lang, bodies in scripts.items():
            joined = "\n".join(bodies)
            if lang == "bash":
                cmd = ["bash", "-euo", "pipefail", "-c", joined]
            else:
                cmd = [sys.executable, "-c", joined]
            proc = subprocess.run(
                cmd, env=env, cwd=tmp, capture_output=True, text=True,
                timeout=600,
            )
            if proc.returncode != 0:
                return False, (
                    f"[{path}] {lang} blocks exited {proc.returncode}\n"
                    f"--- script ---\n{joined}\n"
                    f"--- stdout ---\n{proc.stdout}\n"
                    f"--- stderr ---\n{proc.stderr}"
                )
    return True, ""


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("docs", nargs="*", help="doc files (default: README + docs/*.md)")
    ap.add_argument("--links-only", action="store_true",
                    help="skip block execution (fast local pass)")
    args = ap.parse_args(argv)
    failures = 0
    ran = 0
    for path in args.docs or default_docs():
        full = os.path.join(REPO, path)
        with open(full) as f:
            text = f.read()
        bad = check_links(path, text)
        for target in bad:
            print(f"FAIL {path}: dead relative link ({target})")
            failures += 1
        try:
            blocks = runnable_blocks(text)
        except ValueError as e:
            print(f"FAIL {path}: {e}")
            failures += 1
            continue
        if blocks and not args.links_only:
            ok, output = run_blocks(path, blocks)
            ran += len(blocks)
            if ok:
                print(f"ok   {path}: {len(blocks)} runnable block(s), "
                      f"{len(bad)} dead link(s)")
            else:
                print(f"FAIL {path}:\n{output}")
                failures += 1
        else:
            print(f"ok   {path}: links checked ({len(blocks)} runnable "
                  f"block(s) {'skipped' if args.links_only else 'found'})")
    print(f"{failures} failure(s), {ran} block(s) executed")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
