"""Analytical execution-time model for tiled stencils (reconstruction of
Prajapati et al., PPoPP 2017 [27] -- see DESIGN.md §3).

The codesign paper treats ``T_alg(p, h, s)`` as an imported black box; only
its interface (parameters + feasibility constraints, eqs. 9-15) is given.
This module re-derives a documented hybrid-hexagonal-tiling time model with
the same interface:

problem parameters  p = (S1, S2[, S3], T)        -- iteration-space extents
hardware parameters h = (n_SM, n_V, M_SM)        -- + GPU family constants
software parameters s = (t_S1, t_S2[, t_S3], t_T, k)

Model (all floor/ceil kept -- the paper's non-smoothness is intentional):

* hexagonal tiles on the (T, S1) plane: average width ``W = t_S1 + s*t_T``
  (sigma = stencil radius), max width ``W_max = t_S1 + 2*s*t_T``;
* a tile is one threadblock of ``t_S2`` threads (mult. of 32 = warps);
  for 3D stencils each thread additionally walks ``t_S3`` points;
* compute time per co-resident *group* (the k blocks hyperthreaded on one
  SM): ``C_iter * t_T * W * t_S3 * ceil(k*t_S2/n_V)`` -- the k*t_S2 resident
  threads time-share the n_V lanes; the group completes k tiles in that
  time, so throughput saturates at ``n_V/C_iter`` points/s/SM exactly when
  ``k*t_S2`` is a multiple of ``n_V`` (latency hiding = rounding efficiency);
* shared-memory footprint / tile (bytes):
  ``n_arr * (W_max+2s) * (t_S2+2s) * (t_S3+2s | 1) * 4``; feasibility is
  eq. (11): ``k * footprint <= M_SM`` (eq. (9) is this divided by k);
* per wavefront *phase* (hexagonal schedules alternate 2 phases per time
  band): ``tiles_phase = ceil(ceil(S1/W)/2) * ceil(S2/t_S2) * ceil(S3/t_S3)``
  tiles issue in batches of ``k*n_SM``; a batch overlaps compute with the
  global-memory traffic of its tiles through the shared bandwidth:
  ``T_batch = max(T_compute_tile, n_active*footprint/BW)``;
* ``T_alg = 2*ceil(T/t_T) * (batches*T_batch + launch_overhead)``.

Everything is vectorized over numpy arrays so the solver can sweep the
(hardware x tile) lattice in bulk.
"""

from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np

__all__ = [
    "StencilSpec",
    "GPUSpec",
    "ProblemSize",
    "STENCILS",
    "MAXWELL_GPU",
    "TITANX_GPU",
    "stencil_time",
    "stencil_gflops",
    "feasible",
]


@dataclasses.dataclass(frozen=True)
class StencilSpec:
    """Workload characterization of one stencil benchmark."""

    name: str
    dims: int  # spatial dimensions (2 or 3)
    radius: int  # sigma: halo width per time step
    flops_per_point: float
    n_arrays: int  # arrays resident in the tile footprint (in + out)
    c_iter: float  # seconds per iteration per thread (measured, §IV.B)


@dataclasses.dataclass(frozen=True)
class GPUSpec:
    """Family constants that are *not* design variables (paper §IV.A)."""

    name: str
    bw_gmem: float  # global-memory bandwidth, bytes/s
    max_threads_per_block: int = 1024
    max_threads_per_sm: int = 2048
    max_threadblocks_per_sm: int = 32  # MTB_SM, eq. (10)
    launch_overhead: float = 5.0e-6  # per-phase sync/launch, seconds
    bytes_per_word: int = 4  # fp32 stencils


@dataclasses.dataclass(frozen=True)
class ProblemSize:
    """Problem parameters p. ``s3 = 1`` for 2D stencils."""

    s1: int
    s2: int
    t: int
    s3: int = 1

    @property
    def points(self) -> float:
        return float(self.s1) * self.s2 * self.s3 * self.t


# ---------------------------------------------------------------------------
# The paper's six-benchmark suite (§IV.A). flops/point follow the loop bodies
# of the standard PolyBench/HHC kernels; C_iter is the measured per-iteration
# per-thread cost on the GTX-980 (paper §IV.B: "we measured this parameter
# for the different stencils ... we used the former [GTX-980] value"). The
# published values are not in the paper; these are calibrated so the stock
# GTX-980 / Titan X land in Table II's GFLOP/s magnitude range.
# ---------------------------------------------------------------------------
STENCILS: Dict[str, StencilSpec] = {
    "jacobi2d": StencilSpec("jacobi2d", 2, 1, 5.0, 2, 4.0e-9),
    "heat2d": StencilSpec("heat2d", 2, 1, 10.0, 2, 5.5e-9),
    "laplacian2d": StencilSpec("laplacian2d", 2, 1, 6.0, 2, 4.0e-9),
    "gradient2d": StencilSpec("gradient2d", 2, 1, 9.0, 2, 4.5e-9),
    "heat3d": StencilSpec("heat3d", 3, 1, 15.0, 2, 7.0e-9),
    "laplacian3d": StencilSpec("laplacian3d", 3, 1, 8.0, 2, 6.0e-9),
}

MAXWELL_GPU = GPUSpec(name="gtx980", bw_gmem=224.0e9)
TITANX_GPU = GPUSpec(name="titanx", bw_gmem=336.0e9)


def _ceil_div(a, b):
    return np.ceil(np.asarray(a, np.float64) / np.asarray(b, np.float64))


def footprint_bytes(st: StencilSpec, gpu: GPUSpec, t_s1, t_s2, t_t, t_s3=1):
    """Shared-memory bytes needed by one tile (halo-expanded, all arrays)."""
    s = st.radius
    w_max = np.asarray(t_s1, np.float64) + 2.0 * s * np.asarray(t_t, np.float64)
    depth = (
        np.asarray(t_s3, np.float64) + 2.0 * s
        if st.dims == 3
        else np.ones_like(np.asarray(t_s3, np.float64))
    )
    return (
        st.n_arrays
        * (w_max + 2.0 * s)
        * (np.asarray(t_s2, np.float64) + 2.0 * s)
        * depth
        * gpu.bytes_per_word
    )


def feasible(
    st: StencilSpec,
    gpu: GPUSpec,
    n_sm,
    n_v,
    m_sm,
    t_s1,
    t_s2,
    t_t,
    k,
    t_s3=1,
):
    """Feasibility mask, eqs. (9)-(15). Broadcasts over array inputs."""
    t_s2 = np.asarray(t_s2, np.float64)
    k = np.asarray(k, np.float64)
    fp = footprint_bytes(st, gpu, t_s1, t_s2, t_t, t_s3)
    ok = k * fp <= np.asarray(m_sm, np.float64) * 1024.0  # eq. (11) [& (9)]
    ok &= k <= gpu.max_threadblocks_per_sm  # eq. (10)
    ok &= t_s2 <= gpu.max_threads_per_block
    ok &= k * t_s2 <= gpu.max_threads_per_sm
    ok &= np.asarray(t_t, np.float64) % 2 == 0  # eq. (15): t_T even (HHC)
    ok &= t_s2 % 32 == 0  # eq. (13): full warps
    return ok


def stencil_time(
    st: StencilSpec,
    gpu: GPUSpec,
    size: ProblemSize,
    n_sm,
    n_v,
    m_sm,
    t_s1,
    t_s2,
    t_t,
    k,
    t_s3=1,
):
    """T_alg in seconds. Infeasible points get +inf. Fully vectorized."""
    n_sm = np.asarray(n_sm, np.float64)
    n_v = np.asarray(n_v, np.float64)
    t_s1 = np.asarray(t_s1, np.float64)
    t_s2 = np.asarray(t_s2, np.float64)
    t_t = np.asarray(t_t, np.float64)
    k = np.asarray(k, np.float64)
    t_s3 = np.asarray(t_s3, np.float64)
    s = st.radius

    w_avg = t_s1 + s * t_t
    fp = footprint_bytes(st, gpu, t_s1, t_s2, t_t, t_s3)

    # --- compute time of one co-resident group (k blocks -> k tiles done).
    serial = np.ceil(k * t_s2 / n_v)
    t_compute = st.c_iter * t_t * w_avg * t_s3 * serial

    # --- phase structure.
    tiles_phase = (
        np.ceil(_ceil_div(size.s1, w_avg) / 2.0)
        * _ceil_div(size.s2, t_s2)
        * (_ceil_div(size.s3, t_s3) if st.dims == 3 else 1.0)
    )
    tiles_phase = np.maximum(tiles_phase, 1.0)
    concurrent = np.minimum(k * n_sm, tiles_phase)
    batches = _ceil_div(tiles_phase, k * n_sm)

    # --- per-batch: all concurrent tiles' global traffic shares BW.
    t_mem = concurrent * fp / gpu.bw_gmem
    t_batch = np.maximum(t_compute, t_mem)

    phases = 2.0 * _ceil_div(size.t, t_t)
    t_alg = phases * (batches * t_batch + gpu.launch_overhead)

    ok = feasible(st, gpu, n_sm, n_v, m_sm, t_s1, t_s2, t_t, k, t_s3)
    return np.where(ok, t_alg, np.inf)


def stencil_gflops(st: StencilSpec, size: ProblemSize, t_alg_seconds):
    """Achieved GFLOP/s given a T_alg (broadcasts)."""
    total = st.flops_per_point * size.points
    return total / np.asarray(t_alg_seconds, np.float64) / 1.0e9
