"""repro.service: artifact store round-trip, content-addressed keys,
engine-free warm queries (the acceptance property), microbatching vs the
sequential oracle, what-ifs, and LRU eviction."""

import threading

import numpy as np
import pytest

from repro.core import MAXWELL, MAXWELL_GPU, codesign, enumerate_hw_space
from repro.core.pareto import pareto_mask, pareto_mask_batched
from repro.core.workload import paper_workload
from repro.service import (
    ArtifactStore,
    CodesignServer,
    QueryEngine,
    QueryRequest,
    artifact_spec,
    spec_key,
)
from repro.service import store as store_mod

#: small spaces keep the sweeps in test time; stride 32 ~ 160 points.
STRIDE = 32


def small_hw(step=STRIDE):
    return enumerate_hw_space(MAXWELL, max_area=650.0).downsample(step)


@pytest.fixture(scope="module")
def built():
    """One shared (store, server, fresh result) build for the module --
    the expensive part happens once."""
    import tempfile

    root = tempfile.mkdtemp(prefix="svcstore-")
    store = ArtifactStore(root)
    hw = small_hw()
    srv = CodesignServer(store, hw=hw, engine="auto", batch_window=0.0)
    srv.ensure_artifact()
    fresh = codesign(paper_workload(), hw=hw, engine="auto")
    return store, srv, fresh


# ---------------------------------------------------------------------------
# store: round-trip + keys
# ---------------------------------------------------------------------------
def test_artifact_round_trip_bit_identical(built):
    store, srv, fresh = built
    art = store.get(srv.key)
    assert art is not None
    res = art.to_result()
    np.testing.assert_array_equal(res.weighted_time(), fresh.weighted_time())
    np.testing.assert_array_equal(res.gflops(), fresh.gflops())
    np.testing.assert_array_equal(res.pareto(), fresh.pareto())
    np.testing.assert_array_equal(np.asarray(res.cell_time), fresh.cell_time)
    np.testing.assert_array_equal(
        np.asarray(res.cell_tile_idx), fresh.cell_tile_idx
    )
    # reconstructed workload/lattices decode tiles like the original
    ci, hi = 0, int(np.nonzero(fresh.cell_tile_idx[0] >= 0)[0][0])
    assert res.tiles_for(ci, hi) == fresh.tiles_for(ci, hi)


def test_store_key_tracks_hardware_spec(built):
    store, srv, _ = built
    wl = paper_workload()
    base = store.key_for(wl, MAXWELL_GPU, small_hw(), "auto")
    assert base == srv.key
    # same spec -> same key (deterministic content address)
    assert store.key_for(wl, MAXWELL_GPU, small_hw(), "auto") == base
    # a changed hardware space MUST move the key (collision would serve a
    # matrix computed for different hardware points)
    assert store.key_for(wl, MAXWELL_GPU, small_hw(step=16), "auto") != base
    hw2 = enumerate_hw_space(MAXWELL, max_area=500.0).downsample(STRIDE)
    assert store.key_for(wl, MAXWELL_GPU, hw2, "auto") != base
    # so do workload, engine, and format-version changes
    assert store.key_for(paper_workload(["heat2d"]), MAXWELL_GPU, small_hw(), "auto") != base
    assert store.key_for(wl, MAXWELL_GPU, small_hw(), "numpy") != base
    spec = artifact_spec(wl, MAXWELL_GPU, small_hw(), "auto")
    spec["format_version"] += 1
    assert spec_key(spec) != base
    # frequencies are deliberately NOT in the key: re-weighting is free
    reweighted = paper_workload(name="paper-uniform")
    assert store.key_for(reweighted, MAXWELL_GPU, small_hw(), "auto") == base


def test_stale_format_version_reads_as_miss(built, monkeypatch):
    store, srv, _ = built
    assert store.get(srv.key) is not None
    monkeypatch.setattr(store_mod, "FORMAT_VERSION", store_mod.FORMAT_VERSION + 1)
    assert store.get(srv.key) is None  # rebuilt, never mis-served


def test_key_is_engine_invariant_for_bit_identical_engines(built):
    """'sharded' is the same compiled program as 'jax' over a mesh, so the
    two must share one content address (a warm artifact built on an
    8-device host serves a 1-device host); the float64 'numpy' oracle must
    keep a distinct key."""
    store, _, _ = built
    wl = paper_workload()
    k_jax = store.key_for(wl, MAXWELL_GPU, small_hw(), "jax")
    assert store.key_for(wl, MAXWELL_GPU, small_hw(), "sharded") == k_jax
    assert store.key_for(wl, MAXWELL_GPU, small_hw(), "numpy") != k_jax
    # "auto" digests as the engine it would resolve to on this host --
    # never as the raw alias (which would let a float32 and a float64
    # matrix share one key depending on where the build happened)
    from repro.core import sweep

    k_auto = store.key_for(wl, MAXWELL_GPU, small_hw(), "auto")
    assert k_auto == (k_jax if sweep.HAVE_JAX else
                      store.key_for(wl, MAXWELL_GPU, small_hw(), "numpy"))


def test_put_same_key_reuses_winner_without_restaging(built):
    """The build lock's re-check: a second put of an already-stored key
    returns the existing artifact and leaves its files untouched."""
    import os

    store, srv, fresh = built
    art = store.get(srv.key)
    manifest_path = os.path.join(art.path, "manifest.json")
    mtime = os.stat(manifest_path).st_mtime_ns
    again = store.put(fresh, engine="auto")
    assert again.key == srv.key
    assert os.stat(manifest_path).st_mtime_ns == mtime  # no re-stage
    assert os.path.exists(os.path.join(store.root, f".lock-{srv.key}"))


@pytest.mark.skipif(
    store_mod.fcntl is None, reason="no fcntl: build_lock degrades to a no-op"
)
def test_build_lock_excludes_across_processes(built, subprocess_env):
    """Cross-process exclusion: while this process holds the build lock, a
    child process must block on it (and proceed after release)."""
    import subprocess
    import sys
    import time as _time

    store, _, _ = built
    child = """
import sys
from repro.service.store import ArtifactStore
store = ArtifactStore(sys.argv[1])
print("WAITING", flush=True)
with store.build_lock(sys.argv[2]):
    print("ACQUIRED", flush=True)
"""
    key = "lock-contention-test"
    with store.build_lock(key):
        with store.build_lock(key):  # reentrant within the process
            pass
        proc = subprocess.Popen(
            [sys.executable, "-c", child, store.root, key],
            stdout=subprocess.PIPE, text=True, env=subprocess_env,
        )
        assert proc.stdout.readline().strip() == "WAITING"
        _time.sleep(0.3)  # give the child time to (wrongly) acquire
        assert proc.poll() is None, "child acquired a held exclusive lock"
    out, _ = proc.communicate(timeout=30)
    assert "ACQUIRED" in out  # released lock handed over cleanly


# ---------------------------------------------------------------------------
# acceptance: warm queries never touch a sweep engine
# ---------------------------------------------------------------------------
def test_warm_query_is_engine_free_and_exact(built, monkeypatch):
    store, _, fresh = built

    def boom(*a, **k):  # noqa: ARG001
        raise AssertionError("sweep engine invoked on the warm path")

    import importlib

    # repro.core re-exports the codesign *function* under the submodule's
    # name, so `import repro.core.codesign` would bind the function
    codesign_mod = importlib.import_module("repro.core.codesign")
    solver_mod = importlib.import_module("repro.core.solver")
    server_mod = importlib.import_module("repro.service.server")

    monkeypatch.setattr(solver_mod, "solve_cell", boom)
    monkeypatch.setattr(codesign_mod, "solve_cell", boom)
    monkeypatch.setattr(codesign_mod, "codesign", boom)
    monkeypatch.setattr(server_mod, "codesign", boom)
    sweep_mod = importlib.import_module("repro.core.sweep")
    if sweep_mod.HAVE_JAX:
        monkeypatch.setattr(sweep_mod, "sweep_cell", boom)
        monkeypatch.setattr(sweep_mod, "sweep_cells", boom)

    # a NEW server over the same store: key computed from the spec alone
    srv = CodesignServer(store, hw=small_hw(), engine="auto", batch_window=0.0)
    assert srv.warm

    rng = np.random.default_rng(7)
    names = [st.name for st in fresh.workload.stencils]
    assert len(names) == 6
    for _ in range(3):
        w = rng.uniform(0.1, 1.0, size=6)
        freqs = dict(zip(names, w))
        resp = srv.query(QueryRequest(freqs=freqs, max_area=500.0))
        # oracle: the same mix through the in-process result, resolved to a
        # cell vector with the engine's exact arithmetic (bit-equality is
        # part of the contract, so the oracle must not re-order the math)
        vec = np.zeros(len(fresh.workload.cells))
        for name, wt in freqs.items():
            cells = [i for i, c in enumerate(fresh.workload.cells)
                     if c.stencil.name == name]
            base = np.array([fresh.workload.cells[i].freq for i in cells])
            vec[cells] = float(wt) * base / base.sum()
        vec /= vec.sum()
        i_ref, g_ref = fresh.best(max_area=500.0, freqs=vec)
        assert resp.best_index == i_ref
        assert resp.best_gflops == pytest.approx(g_ref, rel=0, abs=0)
        # the unbudgeted front must equal CodesignResult.pareto exactly (a
        # budgeted request fronts only the subspace it may buy from, which
        # the fresh API has no analogue for)
        resp_p = srv.query(QueryRequest(freqs=freqs, pareto=True))
        pareto_ref = np.nonzero(fresh.pareto(vec))[0]
        np.testing.assert_array_equal(resp_p.pareto_indices, pareto_ref)
    assert srv.stats["artifact_builds"] == 0


# ---------------------------------------------------------------------------
# queries: top-k, what-if, batched pareto
# ---------------------------------------------------------------------------
def test_top_k_is_sorted_and_within_budget(built):
    _, srv, fresh = built
    resp = srv.query(QueryRequest(max_area=450.0, top_k=5))
    assert 1 <= len(resp.top_k) <= 5
    gs = [r["gflops"] for r in resp.top_k]
    assert gs == sorted(gs, reverse=True)
    assert all(r["area"] <= 450.0 for r in resp.top_k)
    assert resp.top_k[0]["index"] == resp.best_index
    i_ref, g_ref = fresh.best(max_area=450.0)
    assert resp.best_index == i_ref


def test_what_if_fix_restricts_subspace(built):
    _, srv, _ = built
    resp = srv.query(QueryRequest(fix={"n_sm": 16.0}))
    assert resp.best_point["n_sm"] == 16
    assert resp.baseline_best_index is not None
    # the restricted best can never beat the unrestricted best
    assert resp.best_gflops <= resp.baseline_best_gflops + 1e-12


def test_infeasible_constraints_signal_not_fallback(built):
    """An empty budget/fix subspace must answer best_index=-1 with empty
    top_k -- never an arbitrary design that violates the constraints."""
    _, srv, _ = built
    for req in (
        QueryRequest(fix={"n_sm": 17.0}),  # odd n_SM: not in the grid
        QueryRequest(max_area=1.0),  # below every design's area
    ):
        resp = srv.query(req)
        assert resp.best_index == -1
        assert resp.best_point == {}
        assert resp.top_k == []
        assert resp.best_gflops == -np.inf


def test_unknown_stencil_is_rejected_without_poisoning(built):
    _, srv, _ = built
    with pytest.raises(KeyError, match="not in artifact"):
        srv.query(QueryRequest(freqs={"nosuch": 1.0}))
    # server still serves afterwards
    assert np.isfinite(srv.query(QueryRequest()).best_gflops)


def test_pareto_mask_batched_matches_sequential():
    rng = np.random.default_rng(3)
    cost = rng.uniform(100, 650, size=200)
    cost[::17] = cost[0]  # exercise equal-cost ties
    perf = rng.uniform(10, 1e4, size=(5, 200))
    perf[2, ::13] = np.inf
    perf[3, ::11] = np.nan
    got = pareto_mask_batched(cost, perf)
    for b in range(5):
        np.testing.assert_array_equal(got[b], pareto_mask(cost, perf[b]))


# ---------------------------------------------------------------------------
# microbatching: concurrent queries vs the sequential oracle
# ---------------------------------------------------------------------------
def test_concurrent_microbatched_queries_match_sequential(built):
    store, _, fresh = built
    # two servers over the same artifact: separate LRUs, so the batched
    # server really exercises the stacked (B, C) @ (C, H) matmul instead of
    # replaying rows the sequential pass cached
    srv_seq = CodesignServer(store, hw=small_hw(), engine="auto", batch_window=0.0)
    srv = CodesignServer(store, hw=small_hw(), engine="auto", batch_window=0.05)
    srv.ensure_artifact()
    names = [st.name for st in fresh.workload.stencils]
    rng = np.random.default_rng(11)
    reqs = [
        QueryRequest(
            freqs=dict(zip(names, rng.uniform(0.1, 1.0, size=6))),
            max_area=float(rng.uniform(350, 650)),
            top_k=3,
            pareto=(i % 2 == 0),
        )
        for i in range(8)
    ]
    sequential = [srv_seq.query(r) for r in reqs]

    out = [None] * len(reqs)
    barrier = threading.Barrier(len(reqs))

    def worker(i):
        barrier.wait()
        out[i] = srv.query(reqs[i])

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(len(reqs))]
    for t in threads:
        t.start()
    for t in threads:
        t.join()

    for got, want in zip(out, sequential):
        assert got.best_index == want.best_index
        assert got.best_gflops == pytest.approx(want.best_gflops, rel=1e-12)
        assert [r["index"] for r in got.top_k] == [r["index"] for r in want.top_k]
        if want.pareto_indices is not None:
            np.testing.assert_array_equal(got.pareto_indices, want.pareto_indices)
    # the rendezvous actually batched (8 threads released together, 50 ms
    # window): at least one batch carried more than one request
    assert srv.stats["max_batch"] > 1
    assert srv.stats["queries"] >= len(reqs)


def test_one_bad_request_does_not_poison_the_batch(built):
    store, _, _ = built
    srv = CodesignServer(store, hw=small_hw(), engine="auto", batch_window=0.05)
    srv.ensure_artifact()
    results = {}
    barrier = threading.Barrier(2)

    def good():
        barrier.wait()
        results["good"] = srv.query(QueryRequest(max_area=500.0))

    def bad():
        barrier.wait()
        try:
            srv.query(QueryRequest(freqs={"nosuch": 1.0}))
        except KeyError as e:
            results["bad"] = e

    ts = [threading.Thread(target=good), threading.Thread(target=bad)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert isinstance(results["bad"], KeyError)
    assert np.isfinite(results["good"].best_gflops)


# ---------------------------------------------------------------------------
# LRU
# ---------------------------------------------------------------------------
def test_lru_hit_and_eviction(built):
    store, srv, _ = built
    art = store.get(srv.key)
    eng = QueryEngine(art, lru_size=2)
    names = art.stencil_names
    reqs = [QueryRequest(freqs={names[i]: 1.0}) for i in range(4)]
    base = [eng.query(r) for r in reqs]
    assert eng.lru.hits == 0 and eng.lru.misses == 4
    assert len(eng.lru) == 2  # capacity bound held
    assert eng.lru.evictions == 2
    # the two most recent mixes are hits; results identical to first pass
    for r, want in zip(reqs[2:], base[2:]):
        got = eng.query(r)
        assert got.cached
        assert got.best_index == want.best_index
        assert got.best_gflops == want.best_gflops
    assert eng.lru.hits == 2
    # evicted mixes recompute to the same answer
    again = eng.query(reqs[0])
    assert not again.cached
    assert again.best_index == base[0].best_index
    assert again.best_gflops == base[0].best_gflops


def test_use_cache_false_bypasses_lru(built):
    store, srv, _ = built
    eng = QueryEngine(store.get(srv.key), lru_size=8)
    r = QueryRequest(use_cache=False)
    a, b = eng.query(r), eng.query(r)
    assert not a.cached and not b.cached
    assert len(eng.lru) == 0
    assert a.best_index == b.best_index
