"""NumPy chunked sweep vs compiled JAX sweep engine on the Fig.-3 workload.

Times the full eq.-(18) solve (every workload cell x every feasible
hardware point) once per engine and reports the wall-time ratio, plus a
cell-by-cell argmin equivalence check so the speedup is never bought with
a wrong answer. The JAX number includes compilation (cold start); a warm
second pass is reported separately to show the steady-state gap.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import MAXWELL, codesign, enumerate_hw_space
from repro.core import sweep
from repro.core.workload import paper_workload

from .common import (
    SMOKE_HW_STRIDE,
    STENCIL_CLASSES as CLASSES,
    cache_json,
    emit,
    refine_enabled,
    skey,
    smoke,
)


def _equivalent(res_np, res_jax) -> float:
    """Max relative gap between the engines' per-cell optima (the argmins
    may differ on exact ties; the achieved times must agree)."""
    finite = np.isfinite(res_np.cell_time)
    if not np.array_equal(finite, np.isfinite(res_jax.cell_time)):
        return float("inf")
    gap = np.abs(res_jax.cell_time[finite] - res_np.cell_time[finite])
    return float(np.max(gap / res_np.cell_time[finite]))


def _refine_stage(cls: str, res) -> None:
    """Polish the reported best design with the batched coordinate descent
    (CodesignResult.refine) and land the speedup/quality delta in the
    artifact JSON -- the refine trajectory is now part of the tracked
    benchmark surface, not just a test fixture."""
    i, g0 = res.best(max_area=650.0)
    wt0 = float(res.weighted_time()[i])
    t0 = time.perf_counter()
    times, _ = res.refine(i)
    dt = time.perf_counter() - t0
    freqs = res.cell_freqs()
    wt1 = float(freqs @ times)
    flops = float(freqs @ res.cell_flops())
    g1 = flops / wt1 / 1.0e9
    improved = int(np.sum(times < res.cell_time[:, i]))
    rec = {
        "class": cls,
        "best_index": int(i),
        "refine_s": round(dt, 4),
        "cells_improved": improved,
        "cells": int(len(times)),
        "weighted_time_lattice_s": wt0,
        "weighted_time_refined_s": wt1,
        "gflops_lattice": g0,
        "gflops_refined": g1,
        "quality_delta_pct": 100.0 * (g1 / g0 - 1.0) if g0 else 0.0,
    }
    cache_json(skey(f"sweep_refine_{cls}"), lambda: rec, force=True)
    emit(
        f"sweep_refine_{cls}", dt * 1e6,
        f"best design {i}: {improved}/{len(times)} cells improved, "
        f"{g0:.1f} -> {g1:.1f} GFLOP/s ({rec['quality_delta_pct']:+.2f}%) "
        f"in {dt:.2f}s",
    )
    # wt0 is the jax engine's float32 sweep; wt1 is refine's float64
    # re-evaluation -- allow the cross-engine noise bound (same RTOL as the
    # equivalence tests), not a bitwise comparison
    assert wt1 <= wt0 * (1 + 1e-5), "refine regressed the lattice optimum"


def run() -> None:
    if not sweep.HAVE_JAX:
        emit("sweep_engine", 0.0, "skipped (jax not installed)")
        return
    hw = enumerate_hw_space(MAXWELL, max_area=650.0)
    if smoke():
        hw = hw.downsample(SMOKE_HW_STRIDE)
    total_np = total_jax = 0.0
    for cls, names in CLASSES.items():
        wl = paper_workload(names, name=f"sweep-{cls}")
        sweep.clear_caches()  # honest cold start: compile time is charged

        t0 = time.perf_counter()
        res_jax = codesign(wl, hw=hw, engine="jax")
        t_cold = time.perf_counter() - t0

        t0 = time.perf_counter()
        codesign(wl, hw=hw, engine="jax")
        t_warm = time.perf_counter() - t0

        t0 = time.perf_counter()
        res_np = codesign(wl, hw=hw, engine="numpy")
        t_np = time.perf_counter() - t0

        gap = _equivalent(res_np, res_jax)
        total_np += t_np
        total_jax += t_cold
        emit(
            f"sweep_{cls}", t_cold * 1e6,
            f"{len(wl.cells)} cells x {len(hw)} hw: numpy {t_np:.1f}s, "
            f"jax cold {t_cold:.1f}s ({t_np/t_cold:.1f}x) / warm {t_warm:.1f}s "
            f"({t_np/t_warm:.1f}x); max argmin gap {gap:.1e}",
        )
        assert gap < 1e-5, f"engines diverged on {cls}: {gap}"
        if refine_enabled():
            _refine_stage(cls, res_jax)
    emit(
        "sweep_total", total_jax * 1e6,
        f"numpy {total_np:.1f}s vs jax {total_jax:.1f}s cold incl. compile "
        f"-> {total_np/total_jax:.1f}x",
    )
