"""Benchmark suite driver: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines (harness contract).

  bench_area                -- SIII.B-C  area calibration + validation
  bench_pareto              -- Fig. 3    design space + Pareto fronts
  bench_sweep               -- engine    NumPy vs compiled JAX sweep
  bench_sensitivity         -- Table II  per-stencil optimal architectures
  bench_cache_removal       -- SV.A      cache-less comparison
  bench_resource_allocation -- Fig. 4    area-fraction clustering
  bench_kernels             -- workload  Pallas stencil kernels vs oracle
  bench_measure             -- predict->measure->refit: tile-kernel grid +
                               machine-parameter calibration fit
  bench_meshopt             -- beyond-paper: TPU mesh codesign (eq. 18)
  bench_roofline            -- SRoofline summary from dry-run artifacts
  bench_service             -- query service: cold sweep vs warm artifact
  bench_portfolio           -- fleet codesign: K-design portfolio search,
                               NumPy oracle vs jitted JAX scorer

``--smoke`` runs every suite on tiny problem sizes / downsampled hardware
spaces (separate artifact cache), sized for a CI lane: the point is that
every code path executes, not that the numbers are publication-grade.
"""

from __future__ import annotations

import argparse
import os
import sys
import traceback


SUITE_NAMES = [
    "area", "pareto", "sweep", "sensitivity", "cache_removal",
    "resource_allocation", "kernels", "measure", "meshopt", "roofline",
    "service", "portfolio",
]


def main() -> None:
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    ap.add_argument(
        "only", nargs="?", default=None, choices=SUITE_NAMES,
        help="run a single suite",
    )
    ap.add_argument(
        "--smoke",
        action="store_true",
        help="tiny CI-runnable sizes (downsampled hw space, small kernels)",
    )
    ap.add_argument(
        "--refine",
        action="store_true",
        help="sweep suite: add the batched coordinate-descent refine stage "
        "(speedup/quality delta lands in the artifact JSON)",
    )
    ap.add_argument(
        "--lm",
        action="store_true",
        help="sweep suite: also time the LM cell family (mesh-factorization "
        "sweep over the repo's model configs; docs/lm_codesign.md)",
    )
    args = ap.parse_args()
    if args.smoke:
        # env (not a global) so suite modules can check common.smoke()
        # regardless of import order
        os.environ["REPRO_BENCH_SMOKE"] = "1"
    if args.refine:
        os.environ["REPRO_BENCH_REFINE"] = "1"
    if args.lm:
        os.environ["REPRO_BENCH_LM"] = "1"

    from . import (
        bench_area,
        bench_cache_removal,
        bench_kernels,
        bench_measure,
        bench_meshopt,
        bench_pareto,
        bench_portfolio,
        bench_resource_allocation,
        bench_roofline,
        bench_sensitivity,
        bench_service,
        bench_sweep,
    )

    suites = list(
        zip(
            SUITE_NAMES,
            [
                bench_area,
                bench_pareto,
                bench_sweep,
                bench_sensitivity,
                bench_cache_removal,
                bench_resource_allocation,
                bench_kernels,
                bench_measure,
                bench_meshopt,
                bench_roofline,
                bench_service,
                bench_portfolio,
            ],
            strict=True,  # a skewed registry must be a hard error
        )
    )
    failed = []
    print("name,us_per_call,derived")
    for name, mod in suites:
        if args.only and args.only != name:
            continue
        try:
            rec = mod.run()
        except Exception:  # noqa: BLE001
            failed.append(name)
            traceback.print_exc()
            continue
        if isinstance(rec, dict) and "suite" in rec:
            # repo-root perf trajectory: any suite returning a record dict
            # (currently sweep: per-engine wall times + device count) gets
            # a timestamped BENCH_<suite>.json entry, committed so
            # regressions are diffable across PRs.
            from .common import append_trajectory

            path = append_trajectory(rec["suite"], rec)
            print(f"# trajectory entry appended to {path}", file=sys.stderr)
    if failed:
        print(f"FAILED suites: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
