"""Laplacian-2D: 5-point discrete Laplace operator. out = n+s+e+w-4c."""

from __future__ import annotations

import jax

from .stencil_common import stencil2d_call

NAME = "laplacian2d"
DIMS = 2
HALO = 1
FLOPS_PER_POINT = 6.0


def update(ext: jax.Array, h: int) -> jax.Array:
    c = ext[h:-h, h:-h]
    n = ext[: -2 * h, h:-h]
    s = ext[2 * h :, h:-h]
    w = ext[h:-h, : -2 * h]
    e = ext[h:-h, 2 * h :]
    return n + s + e + w - 4.0 * c


def step(x, block_rows=None, interpret=None):
    return stencil2d_call(x, update, HALO, block_rows, interpret)
