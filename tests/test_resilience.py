"""Resilience layer: token buckets, admission control, circuit breakers,
deadlines, the retry policy, fault injection, the bounded build lock and
the poison-batch solo-retry path -- all with injectable clocks/rngs/sleeps
so nothing here actually waits."""

import os
import random
import tempfile
import threading
import urllib.error
from contextlib import ExitStack

import numpy as np
import pytest

from repro.core import MAXWELL, enumerate_hw_space
from repro.core.timemodel import MAXWELL_GPU
from repro.core.workload import paper_workload
from repro.service import (
    ArtifactStore,
    BuildLockTimeoutError,
    CircuitOpenError,
    CodesignServer,
    Deadline,
    DeadlineExceededError,
    GatewayClient,
    GatewayError,
    QueryRequest,
    RateLimitedError,
    RetryPolicy,
    ShedError,
    faults,
)
from repro.service.errors import ERROR_HTTP_STATUS
from repro.service.resilience import (
    AdmissionController,
    CircuitBreaker,
    TokenBucket,
    check_deadline,
    current_deadline,
    deadline_scope,
    remaining_s,
)

try:
    import fcntl
except ImportError:  # pragma: no cover - non-POSIX
    fcntl = None


class FakeClock:
    def __init__(self, t: float = 1000.0):
        self.t = t

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


@pytest.fixture(autouse=True)
def _no_leftover_faults():
    faults.reset()
    yield
    faults.reset()


# ---------------------------------------------------------------------------
# token bucket
# ---------------------------------------------------------------------------
def test_token_bucket_disabled_always_admits():
    clk = FakeClock()
    for rate in (0.0, float("inf")):
        b = TokenBucket(rate, clock=clk)
        assert all(b.try_acquire() == 0.0 for _ in range(1000))


def test_token_bucket_burst_drain_and_refill():
    clk = FakeClock()
    b = TokenBucket(rate=2.0, burst=3.0, clock=clk)
    assert [b.try_acquire() for _ in range(3)] == [0.0, 0.0, 0.0]
    wait = b.try_acquire()
    assert wait == pytest.approx(0.5)  # 1 token at 2/s
    clk.advance(0.5)
    assert b.try_acquire() == 0.0
    # refill caps at burst: a long idle never banks more than `burst`
    clk.advance(1e6)
    assert [b.try_acquire() for _ in range(3)] == [0.0, 0.0, 0.0]
    assert b.try_acquire() > 0


def test_token_bucket_rejects_bad_params():
    with pytest.raises(ValueError):
        TokenBucket(rate=-1.0)
    with pytest.raises(ValueError):
        TokenBucket(rate=5.0, burst=0.0)


# ---------------------------------------------------------------------------
# admission control
# ---------------------------------------------------------------------------
def test_admission_sheds_over_inflight_watermark():
    adm = AdmissionController(max_inflight=2, clock=FakeClock())
    with ExitStack() as stack:
        stack.enter_context(adm.admit("a"))
        stack.enter_context(adm.admit("b"))
        assert adm.inflight == 2
        with pytest.raises(ShedError) as ei:
            stack.enter_context(adm.admit("c"))
        assert ei.value.code == "shed"
        assert ei.value.http_status == 503
        assert ei.value.retry_after_s > 0
    # contexts released: admits again
    assert adm.inflight == 0
    with adm.admit("c"):
        pass


def test_admission_global_rate_limit():
    clk = FakeClock()
    adm = AdmissionController(global_rate=1.0, global_burst=1.0, clock=clk)
    with adm.admit("x"):
        pass
    with pytest.raises(RateLimitedError) as ei:
        with adm.admit("x"):
            pass
    assert ei.value.code == "rate_limited"
    assert ei.value.http_status == 429
    assert ei.value.retry_after_s == pytest.approx(1.0)
    clk.advance(1.0)
    with adm.admit("x"):
        pass
    # a rejected request must not leak in-flight accounting
    assert adm.inflight == 0


def test_admission_per_client_buckets_are_isolated():
    clk = FakeClock()
    adm = AdmissionController(client_rate=1.0, client_burst=1.0, clock=clk)
    with adm.admit("alice"):
        pass
    with pytest.raises(RateLimitedError, match="alice"):
        with adm.admit("alice"):
            pass
    # bob has his own bucket
    with adm.admit("bob"):
        pass


def test_admission_client_bucket_lru_is_bounded():
    clk = FakeClock()
    adm = AdmissionController(client_rate=100.0, max_clients=2, clock=clk)
    for name in ("a", "b", "c", "d"):
        with adm.admit(name):
            pass
    assert len(adm._clients) <= 2


# ---------------------------------------------------------------------------
# deadlines
# ---------------------------------------------------------------------------
def test_deadline_expiry_and_stage_label():
    clk = FakeClock()
    d = Deadline(100.0, clock=clk)
    assert not d.expired
    assert d.remaining_s() == pytest.approx(0.1)
    d.check("gateway.resolve")  # free while budget remains
    clk.advance(0.2)
    assert d.expired
    assert d.remaining_s() == 0.0
    with pytest.raises(DeadlineExceededError, match="store.open"):
        d.check("store.open")
    err = pytest.raises(DeadlineExceededError, d.check, "x").value
    assert err.code == "deadline_exceeded"
    assert err.http_status == 504


def test_deadline_rejects_bad_budget():
    for bad in (0.0, -5.0, float("inf"), float("nan")):
        with pytest.raises(ValueError):
            Deadline(bad)


def test_deadline_scope_binds_and_clears():
    assert current_deadline() is None
    check_deadline("anywhere")  # no deadline in flight: free no-op
    assert remaining_s() is None
    assert remaining_s(default=7.0) == 7.0
    clk = FakeClock()
    d = Deadline(50.0, clock=clk)
    with deadline_scope(d):
        assert current_deadline() is d
        assert remaining_s(default=99.0) == pytest.approx(0.05)
        clk.advance(1.0)
        with pytest.raises(DeadlineExceededError):
            check_deadline("server.query")
        # an inner scope can explicitly clear the inherited deadline
        with deadline_scope(None):
            check_deadline("inner")
    assert current_deadline() is None


def test_deadline_does_not_leak_across_threads():
    seen = {}

    def worker():
        seen["deadline"] = current_deadline()

    with deadline_scope(Deadline(1000.0)):
        t = threading.Thread(target=worker)
        t.start()
        t.join()
    assert seen["deadline"] is None


# ---------------------------------------------------------------------------
# circuit breaker
# ---------------------------------------------------------------------------
def _fail(breaker, exc=OSError("boom")):
    with pytest.raises(type(exc)):
        with breaker.call():
            raise exc


def test_breaker_opens_after_threshold_then_fails_fast():
    clk = FakeClock()
    b = CircuitBreaker("k1", threshold=3, cooldown_s=10.0, clock=clk)
    _fail(b)
    _fail(b)
    assert b.state == CircuitBreaker.CLOSED  # 2 < threshold
    _fail(b)
    assert b.state == CircuitBreaker.OPEN
    with pytest.raises(CircuitOpenError) as ei:
        with b.call():
            raise AssertionError("must not run while open")
    assert ei.value.code == "circuit_open"
    assert ei.value.http_status == 503
    assert 0 < ei.value.retry_after_s <= 10.0


def test_breaker_success_resets_failure_streak():
    b = CircuitBreaker("k2", threshold=2, clock=FakeClock())
    _fail(b)
    with b.call():
        pass  # success wipes the streak
    _fail(b)
    assert b.state == CircuitBreaker.CLOSED


def test_breaker_half_open_probe_recovers():
    clk = FakeClock()
    b = CircuitBreaker("k3", threshold=1, cooldown_s=5.0, clock=clk)
    _fail(b)
    assert b.state == CircuitBreaker.OPEN
    clk.advance(5.1)
    with b.call():  # the half-open probe, succeeding
        assert b.state == CircuitBreaker.HALF_OPEN
    assert b.state == CircuitBreaker.CLOSED
    with b.call():
        pass


def test_breaker_half_open_probe_failure_reopens():
    clk = FakeClock()
    b = CircuitBreaker("k4", threshold=1, cooldown_s=5.0, clock=clk)
    _fail(b)
    clk.advance(5.1)
    _fail(b, RuntimeError("still broken"))
    assert b.state == CircuitBreaker.OPEN
    with pytest.raises(CircuitOpenError):
        with b.call():
            pass


def test_breaker_admits_one_probe_at_a_time():
    clk = FakeClock()
    b = CircuitBreaker("k5", threshold=1, cooldown_s=1.0, clock=clk)
    _fail(b)
    clk.advance(1.5)
    probe = b.call()
    probe.__enter__()  # probe in flight
    try:
        with pytest.raises(CircuitOpenError, match="probe in flight"):
            with b.call():
                pass
    finally:
        probe.__exit__(None, None, None)
    assert b.state == CircuitBreaker.CLOSED


def test_breaker_ignores_gateway_errors():
    """Classified outcomes (a caller's bad key, a spent deadline) must
    neither trip nor reset the breaker -- else one impatient client opens
    the circuit for everyone."""
    clk = FakeClock()
    b = CircuitBreaker("k6", threshold=2, clock=clk)
    _fail(b)  # one real failure banked
    for _ in range(10):
        with pytest.raises(DeadlineExceededError):
            with b.call():
                raise DeadlineExceededError("budget spent")
    assert b.state == CircuitBreaker.CLOSED
    _fail(b)  # second REAL failure: streak was preserved, not reset
    assert b.state == CircuitBreaker.OPEN


# ---------------------------------------------------------------------------
# retry policy
# ---------------------------------------------------------------------------
class _FixedRng:
    def __init__(self, r: float):
        self.r = r

    def random(self) -> float:
        return self.r


def test_retry_policy_exponential_ramp_and_cap():
    p = RetryPolicy(max_retries=5, base_s=0.1, max_s=1.0, jitter=0.0)
    rng = _FixedRng(0.0)
    assert [p.delay(a, rng) for a in (1, 2, 3, 4, 5)] == [
        pytest.approx(0.1), pytest.approx(0.2), pytest.approx(0.4),
        pytest.approx(0.8), pytest.approx(1.0),  # capped
    ]


def test_retry_policy_full_jitter_range():
    p = RetryPolicy(base_s=0.4, max_s=10.0, jitter=0.5)
    assert p.delay(1, _FixedRng(0.0)) == pytest.approx(0.4)  # no jitter drawn
    assert p.delay(1, _FixedRng(1.0)) == pytest.approx(0.2)  # full jitter
    rng = random.Random(7)
    for _ in range(100):
        d = p.delay(2, rng)
        assert 0.4 <= d <= 0.8


def test_retry_policy_honors_retry_after_capped():
    p = RetryPolicy(base_s=0.05, max_s=2.0)
    rng = _FixedRng(0.5)
    assert p.delay(1, rng, retry_after_s=0.7) == pytest.approx(0.7)
    assert p.delay(1, rng, retry_after_s=3600.0) == pytest.approx(2.0)
    assert p.delay(1, rng, retry_after_s=-4.0) == 0.0


def test_retry_policy_rejects_bad_params():
    with pytest.raises(ValueError):
        RetryPolicy(max_retries=-1)
    with pytest.raises(ValueError):
        RetryPolicy(jitter=1.5)


# ---------------------------------------------------------------------------
# client retry integration (scripted transport; no sockets, no sleeps)
# ---------------------------------------------------------------------------
def _scripted_client(script, **kw):
    """A GatewayClient whose transport replays `script`: each item is a
    ``(body, status, retry_after)`` tuple or an exception to raise."""
    sleeps = []
    kw.setdefault("retry", RetryPolicy(max_retries=3, base_s=0.1,
                                       max_s=2.0, jitter=0.0))
    c = GatewayClient("http://127.0.0.1:1", sleep=sleeps.append,
                      rng=_FixedRng(0.0), **kw)
    it = iter(script)

    def fake_exchange(method, path, body, hdrs):
        item = next(it)
        if isinstance(item, BaseException):
            raise item
        return item

    c._exchange = fake_exchange
    return c, sleeps


def test_client_retries_connection_reset_then_succeeds():
    reset = urllib.error.URLError(ConnectionResetError("peer reset"))
    c, sleeps = _scripted_client([reset, (b"ok", 200, None)])
    data, status = c._request("/v1/query", b"{}")
    assert (data, status) == (b"ok", 200)
    assert c.stats["retries"] == 1
    assert sleeps == [pytest.approx(0.1)]


def test_client_retries_429_honoring_retry_after():
    c, sleeps = _scripted_client([(b"no", 429, 0.7), (b"ok", 200, None)])
    data, status = c._request("/v1/query", b"{}")
    assert (data, status) == (b"ok", 200)
    assert sleeps == [pytest.approx(0.7)]


def test_client_retries_503_with_backoff_schedule():
    c, sleeps = _scripted_client(
        [(b"a", 503, None), (b"b", 503, None), (b"ok", 200, None)]
    )
    data, status = c._request("/v1/query", b"{}")
    assert status == 200
    assert sleeps == [pytest.approx(0.1), pytest.approx(0.2)]


def test_client_retry_budget_exhausts_to_last_answer():
    c, _ = _scripted_client([(b"x", 503, None)] * 4)  # 1 try + 3 retries
    data, status = c._request("/v1/query", b"{}")
    assert status == 503
    assert c.stats["retries"] == 3


def test_client_never_retries_timeouts():
    import socket

    c, sleeps = _scripted_client(
        [urllib.error.URLError(socket.timeout("timed out"))]
    )
    with pytest.raises(urllib.error.URLError):
        c._request("/v1/query", b"{}")
    assert sleeps == [] and c.stats["retries"] == 0


def test_client_never_retries_connection_refused():
    c, sleeps = _scripted_client(
        [urllib.error.URLError(ConnectionRefusedError("down"))]
    )
    with pytest.raises(urllib.error.URLError):
        c._request("/v1/query", b"{}")
    assert sleeps == []


def test_client_retry_none_disables():
    c, sleeps = _scripted_client([(b"x", 503, None)], retry=None)
    _, status = c._request("/v1/query", b"{}")
    assert status == 503 and sleeps == []


def test_client_does_not_retry_non_idempotent_statuses():
    for status in (400, 404, 409, 500, 504):
        c, sleeps = _scripted_client([(b"x", status, None)])
        _, got = c._request("/v1/query", b"{}")
        assert got == status and sleeps == []


# ---------------------------------------------------------------------------
# fault injection registry
# ---------------------------------------------------------------------------
def test_fault_fire_is_noop_when_disarmed():
    faults.fire("store.open")  # must not raise
    assert not faults.should_drop("gateway.drop_socket")


def test_fault_error_and_latency():
    slept = []
    faults.enable("store.open", latency_s=0.25, error=OSError("disk gone"))
    with pytest.raises(OSError, match="disk gone"):
        faults.fire("store.open", sleep=slept.append)
    assert slept == [0.25]


def test_fault_count_auto_clears_and_after_skips():
    faults.enable("server.batch", error=RuntimeError("x"), count=2, after=1)
    faults.fire("server.batch")  # hit 1: skipped by after=1
    for _ in range(2):
        with pytest.raises(RuntimeError):
            faults.fire("server.batch")
    faults.fire("server.batch")  # count exhausted: auto-cleared
    assert not faults.is_active("server.batch")


def test_fault_env_string_errors_whitelisted():
    faults.configure({"store.open": {"error": "TimeoutError:slow disk"}})
    with pytest.raises(TimeoutError, match="slow disk"):
        faults.fire("store.open")
    faults.configure({"store.open": {"error": "SystemExit:nope"}})
    with pytest.raises(RuntimeError):  # unknown names never eval
        faults.fire("store.open")


def test_fault_configure_rejects_unknown_fields():
    with pytest.raises(ValueError, match="unknown fields"):
        faults.configure({"store.open": {"latency": 1.0}})
    with pytest.raises(ValueError, match="must be an object"):
        faults.configure({"store.open": 5})


def test_should_drop_consumes_hits():
    faults.enable("gateway.drop_socket", count=1)
    assert faults.should_drop("gateway.drop_socket")
    assert not faults.should_drop("gateway.drop_socket")


# ---------------------------------------------------------------------------
# bounded build lock (satellite: build_lock_timeout)
# ---------------------------------------------------------------------------
@pytest.mark.skipif(fcntl is None, reason="flock requires POSIX")
def test_build_lock_timeout_is_structured():
    root = tempfile.mkdtemp(prefix="lockstore-")
    store = ArtifactStore(root)
    key = "f" * 64
    # hold the flock on a SEPARATE file descriptor: flock exclusion is per
    # open-file-description, so this conflicts even within one process
    path = os.path.join(root, f".lock-{key}")
    fd = os.open(path, os.O_CREAT | os.O_RDWR, 0o644)
    fcntl.flock(fd, fcntl.LOCK_EX)
    try:
        with pytest.raises(BuildLockTimeoutError, match="still held") as ei:
            with store.build_lock(key, timeout_s=0.05):
                raise AssertionError("lock must not be acquired")
        assert ei.value.code == "build_lock_timeout"
        assert ei.value.http_status == ERROR_HTTP_STATUS["build_lock_timeout"]
        assert isinstance(ei.value, GatewayError)
        assert ei.value.retry_after_s > 0
    finally:
        fcntl.flock(fd, fcntl.LOCK_UN)
        os.close(fd)
    # holder released: the same acquisition now succeeds
    with store.build_lock(key, timeout_s=1.0):
        pass


def test_store_lock_timeout_env_and_validation(monkeypatch):
    monkeypatch.setenv("REPRO_LOCK_TIMEOUT_S", "12.5")
    root = tempfile.mkdtemp(prefix="lockenv-")
    assert ArtifactStore(root).lock_timeout_s == 12.5
    with pytest.raises(ValueError):
        ArtifactStore(root, lock_timeout_s=0.0)


# ---------------------------------------------------------------------------
# server integration: deadlines + the poison-batch metric (needs a real
# artifact; everything below shares one tiny single-stencil sweep)
# ---------------------------------------------------------------------------
STRIDE = 64


def small_hw():
    return enumerate_hw_space(MAXWELL, max_area=650.0).downsample(STRIDE)


@pytest.fixture(scope="module")
def built():
    root = tempfile.mkdtemp(prefix="resil-")
    store = ArtifactStore(root)
    srv = CodesignServer(
        store, workload=paper_workload(["heat2d"]), gpu=MAXWELL_GPU,
        hw=small_hw(), engine="numpy", batch_window=0.0,
    )
    srv.ensure_artifact()
    return store, srv


def test_expired_deadline_fails_server_query(built):
    _, srv = built
    clk = FakeClock()
    d = Deadline(10.0, clock=clk)
    clk.advance(1.0)
    with deadline_scope(d):
        with pytest.raises(DeadlineExceededError, match="server.query"):
            srv.query(QueryRequest())
    # scope exited: the same server answers normally
    assert np.isfinite(srv.query(QueryRequest()).best_gflops)


def test_expired_deadline_fails_store_open(built):
    store, srv = built
    clk = FakeClock()
    d = Deadline(10.0, clock=clk)
    clk.advance(1.0)
    with deadline_scope(d):
        with pytest.raises(DeadlineExceededError, match="store.open"):
            store.get(srv.key)


def test_store_open_fault_reaches_caller(built):
    store, srv = built
    faults.enable("store.open", error=OSError("injected disk failure"))
    with pytest.raises(OSError, match="injected disk failure"):
        store.get(srv.key)
    faults.reset()
    assert store.get(srv.key) is not None


def test_poisoned_batch_counts_metric_and_solo_retries(built):
    """Satellite: a failing batch flush increments
    repro_server_batch_poison_total and every request is still answered
    via the solo-retry path."""
    from repro.service.server import _M_BATCH_POISON

    store, _ = built
    srv = CodesignServer(
        store, hw=small_hw(), engine="numpy", batch_window=0.01,
    )
    srv.ensure_artifact()
    before = _M_BATCH_POISON.value
    faults.enable("server.batch", error=RuntimeError("injected flush"), count=1)
    resp = srv.query(QueryRequest())  # leader flush fails -> solo retry
    assert np.isfinite(resp.best_gflops)
    assert _M_BATCH_POISON.value == before + 1
    # fault consumed: the next batched query takes the fast path again
    assert np.isfinite(srv.query(QueryRequest()).best_gflops)
    assert _M_BATCH_POISON.value == before + 1
