"""PR-5 service additions: the /v1/query_many batched wire endpoint,
the persistent-connection client, manifest-kind routing (measurement /
calibration artifacts sharing a store with sweeps), the legacy-manifest
upgrade path, and the acceptance property that calibrated-hardware sweep
artifacts round-trip store -> gateway -> HTTP with byte-identical wire
answers."""

import json
import os
import subprocess
import sys
import tempfile
import threading

import numpy as np
import pytest

from repro.core import MAXWELL, enumerate_hw_space
from repro.core.codesign import codesign
from repro.core.timemodel import (
    MAXWELL_GPU,
    STENCILS,
    with_c_iter,
    with_machine_params,
)
from repro.measure import fit_machine_params, synthetic_records
from repro.service import (
    ArtifactStore,
    CodesignServer,
    Gateway,
    GatewayClient,
    QueryRequest,
    RemoteError,
    WireError,
    WrongArtifactKindError,
    serve_http,
    wire,
)

STRIDE = 64
STENCIL_NAMES = ["heat2d", "jacobi2d"]


def small_hw():
    return enumerate_hw_space(MAXWELL, max_area=650.0).downsample(STRIDE)


@pytest.fixture(scope="module")
def fleet():
    """One store holding a datasheet sweep, a calibrated sweep (built from
    a stored calibration), a measurement manifest, a gateway, and a live
    HTTP server."""
    from repro.core.workload import paper_workload
    from repro.measure import MeasurementRecord, MeasurementRun

    root = tempfile.mkdtemp(prefix="gwbatch-")
    store = ArtifactStore(root)
    hw = small_hw()
    # datasheet sweep (the "before" target)
    srv = CodesignServer(
        store, workload=paper_workload(STENCIL_NAMES), gpu=MAXWELL_GPU,
        hw=hw, engine="numpy", batch_window=0.0,
    )
    srv.ensure_artifact()
    # a measurement manifest shares the store (must never route queries)
    meas = store.put_json(
        "measurement",
        MeasurementRun(
            records=[
                MeasurementRecord(
                    stencil="heat2d", size=(64, 64, 1, 4),
                    tiles=(8, 32, 2, 1, 1), time_s=1e-3,
                    hw=(16.0, 128.0, 96.0),
                )
            ],
            gpu_name="gtx980", backend="cpu", interpret=True,
        ).to_payload(),
        routing={"gpu": "gtx980"},
    )
    # calibration fitted from synthetic truth, persisted, then a sweep on
    # the calibrated hardware routed by its calibration key
    truth_gpu = with_machine_params(
        MAXWELL_GPU, bw_gmem=150.0e9, launch_overhead=8.0e-6
    )
    truth_st = {n: with_c_iter(STENCILS[n], STENCILS[n].c_iter * 1.5)
                for n in STENCIL_NAMES}
    cal = fit_machine_params(
        synthetic_records(truth_gpu, truth_st), gpu0=MAXWELL_GPU, iters=150
    )
    cal_art = store.put_json(
        "calibration", cal.to_payload(),
        routing={"gpu": "gtx980", "calibrated_gpu": cal.calibrated_gpu().name},
    )
    result = codesign(
        cal.calibrated_workload(STENCIL_NAMES), gpu=cal.calibrated_gpu(),
        hw=hw, engine="numpy",
    )
    cal_sweep = store.put(
        result, engine="numpy", routing_extra={"calibration": cal_art.key}
    )
    cal_srv = CodesignServer.from_artifact(store, cal_sweep, batch_window=0.0)
    gw = Gateway(root, batch_window=0.0)
    httpd = serve_http(gw)
    threading.Thread(target=httpd.serve_forever, daemon=True).start()
    url = "http://%s:%d" % httpd.server_address[:2]
    yield {
        "store": store, "srv": srv, "cal": cal, "cal_art": cal_art,
        "cal_srv": cal_srv, "meas": meas, "gw": gw, "url": url,
    }
    httpd.shutdown()
    httpd.server_close()


def _req(**kw):
    kw.setdefault("freqs", {"heat2d": 1.0})
    kw.setdefault("use_cache", False)
    return QueryRequest(**kw)


# ---------------------------------------------------------------------------
# wire: query_many codec
# ---------------------------------------------------------------------------
def test_wire_request_many_round_trip():
    triples = [
        (_req(top_k=3), "abc", None),
        (_req(freqs={"jacobi2d": 2.0}, max_area=450.0), None, {"gpu": "titanx"}),
    ]
    data = wire.encode_request_many(triples)
    back = wire.decode_request_many(data)
    assert back == triples
    assert wire.encode_request_many(triples) == data  # canonical


def test_wire_request_many_is_strict():
    with pytest.raises(WireError, match="non-empty array"):
        wire.decode_request_many(b'{"v": 1, "queries": []}')
    with pytest.raises(WireError, match="unknown envelope fields"):
        wire.decode_request_many(b'{"v": 1, "queries": [], "x": 1}')
    with pytest.raises(WireError, match=r"queries\[1\].*unknown fields"):
        wire.decode_request_many(
            b'{"v": 1, "queries": [{"request": {}}, {"request": {}, "bogus": 1}]}'
        )
    with pytest.raises(WireError, match=r"queries\[0\]"):
        wire.decode_request_many(
            b'{"v": 1, "queries": [{"request": {"max_aera": 1}}]}'
        )
    too_many = json.dumps(
        {"v": 1, "queries": [{"request": {}}] * (wire.MAX_BATCH + 1)}
    ).encode()
    with pytest.raises(WireError, match="cap"):
        wire.decode_request_many(too_many)


def test_wire_response_many_elements_are_single_payloads(fleet):
    """Each query_many element must carry byte-for-byte the single-query
    payload (the byte-identity property composes into batches)."""
    resp = fleet["srv"].query(_req(top_k=2))
    data = wire.encode_response_many([resp, ("unknown_artifact", "nope")])
    obj = json.loads(data)
    single = json.loads(wire.encode_response(resp))
    assert obj["results"][0] == {"ok": True, "response": single["response"]}
    assert obj["results"][1]["ok"] is False
    back = wire.decode_response_many(data, 200)
    assert isinstance(back[0], type(resp))
    assert wire.encode_response(back[0]) == wire.encode_response(resp)
    assert isinstance(back[1], RemoteError) and back[1].code == "unknown_artifact"


# ---------------------------------------------------------------------------
# gateway + HTTP: batched endpoint
# ---------------------------------------------------------------------------
def test_gateway_query_many_groups_and_orders(fleet):
    gw, srv, cal_srv = fleet["gw"], fleet["srv"], fleet["cal_srv"]
    reqs = [_req(max_area=float(a)) for a in (400, 500, 600, 450)]
    queries = [
        (reqs[0], srv.key, None),
        (reqs[1], cal_srv.key, None),
        (reqs[2], srv.key, None),
        (reqs[3], None, {"calibration": fleet["cal_art"].key}),
    ]
    results = gw.query_many(queries)
    # oracle: the same grouping by artifact (order preserved within and
    # across groups), answered by each artifact's own server batch
    want = {0: None, 1: None, 2: None, 3: None}
    want[0], want[2] = srv.query_many([reqs[0], reqs[2]])
    want[1], want[3] = cal_srv.query_many([reqs[1], reqs[3]])
    for i, got in enumerate(results):
        assert wire.encode_response(got) == wire.encode_response(want[i])
    assert gw.stats["batched_requests"] >= len(queries)


def test_gateway_query_many_rescans_at_most_once(fleet):
    """A batch of unresolvable queries must cost ONE on-demand store
    re-scan, not one per query (MAX_BATCH unknown keys must not mean
    MAX_BATCH full-store manifest scans)."""
    gw = fleet["gw"]
    before = gw.stats["rescans"]
    results = gw.query_many([(_req(), "a" * 20, None)] * 5)
    assert all(r == ("unknown_artifact", r[1]) for r in results)
    assert gw.stats["rescans"] == before + 1


def test_http_query_many_matches_singles_and_isolates_errors(fleet):
    client = GatewayClient(fleet["url"])
    srv = fleet["srv"]
    good = _req(top_k=3)
    bad_route = (_req(), "f" * 20, None)
    bad_request = (_req(freqs={"nosuch": 1.0}), srv.key, None)
    results = client.query_many(
        [(good, srv.key, None), bad_route, bad_request, (good, srv.key, None)]
    )
    want = wire.encode_response(srv.query(good))
    assert wire.encode_response(results[0]) == want
    assert wire.encode_response(results[3]) == want
    assert isinstance(results[1], RemoteError)
    # per-element errors classify exactly like their single-query twins,
    # even though the batch envelope itself is HTTP 200
    assert results[1].code == "unknown_artifact" and results[1].http_status == 404
    assert isinstance(results[2], RemoteError)
    assert results[2].code == "bad_request" and "nosuch" in results[2].message
    assert results[2].http_status == 400


def test_client_query_many_chunks_above_wire_cap(fleet, monkeypatch):
    """Batches above wire.MAX_BATCH split transparently into consecutive
    round trips, results concatenated in input order."""
    client = GatewayClient(fleet["url"])
    srv = fleet["srv"]
    monkeypatch.setattr(wire, "MAX_BATCH", 3)
    reqs = [_req(top_k=k + 1) for k in range(8)]  # 3 + 3 + 2 round trips
    results = client.query_many(reqs, artifact=srv.key)
    assert len(results) == len(reqs)
    for req, got in zip(reqs, results):
        assert len(got.top_k) == req.top_k
        assert got.artifact_key == srv.key
    assert max(r.batch_size for r in results) <= 3  # server saw the chunks


def test_http_query_many_batch_rides_one_matmul(fleet):
    """All same-artifact queries in one envelope share one reduction
    (batch_size > 1 on every response)."""
    client = GatewayClient(fleet["url"])
    srv = fleet["srv"]
    rng = np.random.default_rng(11)
    reqs = [
        _req(freqs=dict(zip(STENCIL_NAMES, rng.uniform(0.1, 1.0, size=2))))
        for _ in range(6)
    ]
    results = client.query_many(reqs, artifact=srv.key)
    assert all(r.batch_size == len(reqs) for r in results)


# ---------------------------------------------------------------------------
# client transport: persistent connection
# ---------------------------------------------------------------------------
def test_client_reuses_connection(fleet):
    client = GatewayClient(fleet["url"])
    assert client._conn is None
    client.health()
    conn1 = client._conn
    assert conn1 is not None  # kept alive
    client.artifacts()
    assert client._conn is conn1  # same socket reused
    client.query(_req(), artifact=fleet["srv"].key)
    assert client._conn is conn1
    client.close()
    assert client._conn is None
    # and still works after an explicit close (fresh connection)
    assert client.health()["ok"]


def test_client_keepalive_off_never_pools(fleet):
    client = GatewayClient(fleet["url"], keepalive=False)
    client.health()
    assert client._conn is None
    resp = client.query(_req(), artifact=fleet["srv"].key)
    assert wire.encode_response(resp) == wire.encode_response(
        fleet["srv"].query(_req())
    )


def test_client_survives_server_side_close(fleet):
    """Error responses close the connection server-side; the next request
    must transparently reconnect."""
    client = GatewayClient(fleet["url"])
    with pytest.raises(RemoteError):
        client.query(_req(), artifact="0" * 20)
    assert client.health()["ok"]
    with pytest.raises(ValueError, match="scheme"):
        GatewayClient("ftp://example.com")


# ---------------------------------------------------------------------------
# kind routing
# ---------------------------------------------------------------------------
def test_non_sweep_kinds_never_route_queries(fleet):
    gw = fleet["gw"]
    # the measurement + calibration manifests carry gpu=gtx980 too; the
    # sweep selector must not become ambiguous because of them
    key = gw.resolve(route={"gpu": "gtx980"})
    assert key == fleet["srv"].key
    with pytest.raises(WrongArtifactKindError, match="measurement"):
        gw.query(_req(), artifact=fleet["meas"].key)
    with pytest.raises(WrongArtifactKindError, match="calibration"):
        gw.query(_req(), artifact=fleet["cal_art"].key)
    # over HTTP: structured 400 wrong_artifact_kind
    client = GatewayClient(fleet["url"])
    with pytest.raises(RemoteError) as ei:
        client.query(_req(), artifact=fleet["meas"].key)
    assert ei.value.code == "wrong_artifact_kind" and ei.value.http_status == 400
    # explicit kind selector finds the manifest (e.g. for tooling), but
    # querying it is still a kind error
    assert gw.resolve(route={"kind": "measurement"}) == fleet["meas"].key
    with pytest.raises(WrongArtifactKindError):
        gw.query(_req(), route={"kind": "measurement"})


def test_artifacts_endpoint_lists_all_kinds(fleet):
    rows = {r["key"]: r for r in GatewayClient(fleet["url"]).artifacts()}
    assert rows[fleet["meas"].key]["kind"] == "measurement"
    assert rows[fleet["cal_art"].key]["kind"] == "calibration"
    assert rows[fleet["srv"].key]["kind"] == "sweep"


# ---------------------------------------------------------------------------
# acceptance: calibrated hardware round-trips byte-identically
# ---------------------------------------------------------------------------
def test_calibrated_sweep_serves_byte_identical_over_http(fleet):
    client = GatewayClient(fleet["url"])
    cal_srv = fleet["cal"]
    srv = fleet["cal_srv"]
    for req in (
        _req(top_k=3, pareto=True),
        _req(freqs={"jacobi2d": 1.0, "heat2d": 0.5}, max_area=500.0,
             fix={"n_sm": 16.0}),
    ):
        want = wire.encode_response(srv.query(req))
        by_cal = client.query_bytes(
            req, route={"calibration": fleet["cal_art"].key}
        )
        by_gpu = client.query_bytes(
            req, route={"gpu": cal_srv.calibrated_gpu().name}
        )
        assert by_cal == want
        assert by_gpu == want
    # and the calibrated sweep answers differently from the datasheet one
    a = fleet["srv"].query(_req())
    b = srv.query(_req())
    assert a.best_gflops != b.best_gflops


# ---------------------------------------------------------------------------
# legacy-manifest upgrade
# ---------------------------------------------------------------------------
def _strip_manifest(store: ArtifactStore, key: str) -> None:
    """Rewrite an artifact's manifest as a pre-PR4 writer would have left
    it (no routing block, no kind tag)."""
    path = os.path.join(store.root, key, "manifest.json")
    with open(path) as f:
        m = json.load(f)
    m.pop("routing", None)
    m.pop("kind", None)
    with open(path, "w") as f:
        json.dump(m, f, indent=1)


def test_upgrade_backfills_legacy_manifests(tmp_path, subprocess_env):
    from repro.core.timemodel import TITANX_GPU
    from repro.core.workload import paper_workload

    store = ArtifactStore(str(tmp_path))
    hw = small_hw()
    legacy = CodesignServer(
        store, workload=paper_workload(["heat2d"]), gpu=MAXWELL_GPU,
        hw=hw, engine="numpy", batch_window=0.0,
    )
    legacy.ensure_artifact()
    modern = CodesignServer(
        store, workload=paper_workload(["heat2d"]), gpu=TITANX_GPU,
        hw=hw, engine="numpy", batch_window=0.0,
    )
    modern.ensure_artifact()
    _strip_manifest(store, legacy.key)
    # mixed store: the gateway still serves the legacy artifact through
    # the derivation fallback...
    gw = Gateway(store.root, batch_window=0.0)
    req = _req()
    want_legacy = wire.encode_response(legacy.query(req))
    assert gw.resolve(route={"gpu": "gtx980"}) == legacy.key
    assert wire.encode_response(
        gw.query(req, route={"gpu": "gtx980"})
    ) == want_legacy
    # ...and the upgrade rewrites it in place, key unchanged
    proc = subprocess.run(
        [sys.executable, "-m", "repro.service.cli", "upgrade",
         "--store", str(tmp_path)],
        capture_output=True, text=True, timeout=120, env=subprocess_env,
    )
    assert proc.returncode == 0, proc.stderr
    assert legacy.key in proc.stdout and "1 manifest(s) upgraded" in proc.stdout
    with open(os.path.join(store.root, legacy.key, "manifest.json")) as f:
        m = json.load(f)
    assert m["kind"] == "sweep"
    assert m["routing"] == {
        "gpu": "gtx980", "workload": "paper-uniform", "stencils": ["heat2d"],
    }
    assert m["key"] == legacy.key
    # second run is a no-op; answers unchanged after re-index
    assert ArtifactStore(str(tmp_path)).upgrade_manifests() == []
    gw.refresh()
    assert wire.encode_response(
        gw.query(req, route={"gpu": "gtx980"})
    ) == want_legacy


# ---------------------------------------------------------------------------
# CLI --batch-file
# ---------------------------------------------------------------------------
def test_cli_query_batch_file(fleet, tmp_path, subprocess_env):
    batch = [
        {"artifact": fleet["srv"].key,
         "request": {"freqs": {"heat2d": 1.0}, "top_k": 2}},
        {"route": {"calibration": fleet["cal_art"].key},
         "request": {"freqs": {"jacobi2d": 1.0}}},
        {"artifact": "f" * 20, "request": {}},
    ]
    path = tmp_path / "batch.json"
    path.write_text(json.dumps(batch))
    proc = subprocess.run(
        [sys.executable, "-m", "repro.service.cli", "query",
         "--url", fleet["url"], "--batch-file", str(path)],
        capture_output=True, text=True, timeout=120, env=subprocess_env,
    )
    assert proc.returncode == 0, proc.stderr
    out = json.loads(proc.stdout)
    assert [r["ok"] for r in out["results"]] == [True, True, False]
    assert out["results"][0]["artifact_key"] == fleet["srv"].key
    assert out["results"][2]["error"]["code"] == "unknown_artifact"
    # --batch-file without --url is a clean one-line failure
    proc = subprocess.run(
        [sys.executable, "-m", "repro.service.cli", "query",
         "--batch-file", str(path)],
        capture_output=True, text=True, timeout=120, env=subprocess_env,
    )
    assert proc.returncode == 2
    assert "requires --url" in proc.stderr and "Traceback" not in proc.stderr
