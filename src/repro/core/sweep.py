"""JAX-native codesign sweep engine (the eq.-18 inner solves, compiled).

The seed solved each per-(stencil, size) cell with chunked NumPy broadcasts
(:func:`repro.core.solver.solve_cell`): serial, CPU-bound, float64, and a
fresh pile of temporaries per chunk. This module re-expresses the same
lattice sweep as a **jitted vmap over hardware points x tile-lattice
candidates**, so XLA fuses the whole time-model expression into one kernel
and runs it on whatever backend is attached (CPU, GPU, TPU):

* the time model itself is untouched -- :func:`repro.core.timemodel
  .stencil_time` is called with ``xp=jax.numpy``, so the NumPy path stays
  the bit-exact reference oracle (see ``tests/test_sweep.py``);
* problem sizes are *dynamic* jit arguments AND a batch (vmap) axis: all 16
  paper sizes of a stencil solve in one compiled dispatch
  (:func:`sweep_cells`), instead of recompiling -- or even re-dispatching --
  per cell;
* an optional ``lax.map`` chunking knob bounds peak memory at
  ``chunk x |lattice|`` floats, for hardware spaces far larger than the
  paper's ~13k points;
* :func:`sweep_cells_sharded` shards the hardware axis over a 1-D device
  ``Mesh`` with ``shard_map`` + ``NamedSharding`` -- each device streams
  its shard through the *same* fused body, so multi-device results are
  bit-identical to the single-device engine while wall time scales with
  the mesh (the fleet path; see README "Scaling the sweep");
* coordinate-descent refinement (:func:`refine_points`) is batched across
  all reported design points at once -- each descent round evaluates every
  (point, +/-step neighbor) pair in a single compiled call instead of the
  seed's one-at-a-time Python loops.

When jax is absent ``HAVE_JAX`` is False and every entry point raises
``ModuleNotFoundError`` -- asking for the compiled engine is an explicit
contract. Graceful degradation lives one layer up: the driver
(:mod:`repro.core.codesign`) defaults to ``engine="auto"``, which routes
to the NumPy reference solver instead of this module.
"""

from __future__ import annotations

import functools
import threading
import time
import warnings
from typing import Dict, Tuple

import numpy as np

from repro.obs.metrics import get_registry as _obs_registry

from .solver import TileLattice
from .solver import _STEPS as _SOLVER_STEPS
from .timemodel import GPUSpec, ProblemSize, StencilSpec, stencil_time

try:  # pragma: no cover - exercised implicitly on import
    import jax
    import jax.numpy as jnp
    from jax import lax
    from jax.sharding import Mesh, NamedSharding
    from jax.sharding import PartitionSpec as P

    HAVE_JAX = True
except ModuleNotFoundError:  # pragma: no cover
    jax = None
    jnp = None
    lax = None
    Mesh = NamedSharding = P = None
    HAVE_JAX = False

# shard_map gets its own guard: its home has moved (jax.experimental ->
# jax.shard_map), and its absence must only disable the *sharded* engine,
# never take HAVE_JAX -- and with it the single-device engine, the jax
# test suite, and the bench parity asserts -- down with it.
shard_map = getattr(jax, "shard_map", None) if HAVE_JAX else None
if HAVE_JAX and shard_map is None:  # pragma: no cover - version-dependent
    try:
        from jax.experimental.shard_map import shard_map
    except (ModuleNotFoundError, ImportError):
        shard_map = None
HAVE_SHARD_MAP = shard_map is not None

__all__ = [
    "HAVE_JAX",
    "HAVE_SHARD_MAP",
    "DEFAULT_CHUNK",
    "device_count",
    "sweep_cell",
    "sweep_cells",
    "sweep_cells_sharded",
    "refine_points",
    "clear_caches",
]

#: lax.map chunk: 2048 hw points x ~2.9k lattice candidates x 4 B ~ 24 MB
#: peak per intermediate -- measured fastest on small CPU hosts (fits L3
#: alongside the fused expression's live values) and tiny for devices.
DEFAULT_CHUNK = 2048

#: software-parameter column order used by the packed (P, 5) refine arrays.
SW_NAMES = ("t_s1", "t_s2", "t_t", "k", "t_s3")

#: aligned unit steps per parameter (eq. 13: warps; eq. 15: even t_T) and
#: the lower bounds the descent must not cross -- derived from the NumPy
#: oracle's table so the two refine paths can never drift apart.
SW_STEPS = tuple(float(_SOLVER_STEPS[k]) for k in SW_NAMES)
SW_MINS = tuple(1.0 if k == "t_s1" else float(_SOLVER_STEPS[k]) for k in SW_NAMES)

# ---- observability (repro.obs; no-ops under REPRO_OBS_DISABLED=1) --------
_REG = _obs_registry()
_M_DISPATCH_SECONDS = _REG.histogram(
    "repro_sweep_dispatch_seconds",
    "wall time of one compiled sweep dispatch (solve call through host "
    "materialization), split by engine and compile phase: 'first' is the "
    "initial dispatch of a (solver, shape) pair -- XLA tracing + "
    "compilation included -- 'steady' is every re-dispatch of the cached "
    "executable. An approximation of compile-vs-execute: jax keys its "
    "executable cache the same way",
    labels=("engine", "phase"),
)
_M_CELL_EVALS = _REG.counter(
    "repro_sweep_cell_evals_total",
    "optima-matrix entries produced (P sizes x H hardware points per "
    "dispatch) -- divide by dispatch seconds for cells/sec",
    labels=("engine",),
)

#: (solver id, shapes) pairs whose first (compiling) dispatch has been
#: seen; cleared alongside the solver caches in :func:`clear_caches`.
_DISPATCH_SEEN: set = set()
_DISPATCH_MU = threading.Lock()


def _note_dispatch(engine: str, cache_key: tuple, p: int, h: int, dt: float) -> None:
    """Record one dispatch, classified first/steady by whether this
    (solver, shape) pair has dispatched before (mirrors jax's retrace
    rule: a cached solver re-invoked on new shapes recompiles)."""
    with _DISPATCH_MU:
        first = cache_key not in _DISPATCH_SEEN
        if first:
            _DISPATCH_SEEN.add(cache_key)
    _M_DISPATCH_SECONDS.labels(
        engine=engine, phase="first" if first else "steady"
    ).observe(dt)
    _M_CELL_EVALS.labels(engine=engine).inc(p * h)


def _require_jax():
    if not HAVE_JAX:
        raise ModuleNotFoundError(
            "jax is required for the compiled sweep engine; "
            "use engine='numpy' (repro.core.solver.solve_cell) instead"
        )


def device_count() -> int:
    """Attached devices, 0 when jax is absent. The engine="auto" promotion
    test monkeypatches this, so route all auto decisions through here."""
    return jax.device_count() if HAVE_JAX else 0


def _require_shard_map():
    _require_jax()
    if not HAVE_SHARD_MAP:
        raise ModuleNotFoundError(
            "this jax installation exposes neither jax.shard_map nor "
            "jax.experimental.shard_map; the sharded engine is unavailable "
            "-- use engine='jax' (single device) or engine='auto'"
        )


def _resolve_devices(devices):
    """Normalize the ``devices=`` knob to a concrete device list.

    ``None`` -> every attached device; an int n -> the first n devices (so
    scaling-efficiency benchmarks can sweep 1..D on one host); an explicit
    sequence of jax devices is used as-is.
    """
    _require_jax()
    if devices is None:
        return tuple(jax.devices())
    if isinstance(devices, int):
        avail = jax.devices()
        if not 1 <= devices <= len(avail):
            raise ValueError(
                f"devices={devices} out of range (1..{len(avail)} attached)"
            )
        return tuple(avail[:devices])
    return tuple(devices)


def _lattice_arrays(lattice: TileLattice, gpu: GPUSpec):
    """Pruned (candidates, original-index) lattice columns.

    Candidates violating the *hardware-independent* feasibility constraints
    (eqs. 10/12-15 restricted to GPU-family constants) are +inf for every
    hardware point, so dropping them up front cannot change any argmin --
    it only shrinks the compiled (H x L) sweep (~28% of the seed's 2D
    lattice is dead weight). Original lattice indices are kept so callers
    still receive seed-compatible indices for ``decode_index``.
    """
    g = lattice.grid()
    keep = (
        (g["k"] * g["t_s2"] <= gpu.max_threads_per_sm)
        & (g["t_s2"] <= gpu.max_threads_per_block)
        & (g["k"] <= gpu.max_threadblocks_per_sm)
        & (g["t_t"] % 2 == 0)
        & (g["t_s2"] % 32 == 0)
    )
    keep_idx = np.nonzero(keep)[0]
    cols = tuple(jnp.asarray(g[k][keep_idx], jnp.float32) for k in SW_NAMES)
    return cols, jnp.asarray(keep_idx, jnp.int32)


def _traced_spec(dims: int, radius, c_iter, n_arrays) -> StencilSpec:
    """A StencilSpec carrying tracers for its numeric fields.

    Only ``dims`` shapes the traced program (a static Python branch in the
    time model); radius / C_iter / n_arrays are plain multiplicands, so
    passing them as jit arguments lets ALL stencils of a dimensionality
    share one compiled executable instead of recompiling per stencil.
    """
    return StencilSpec(
        name="<traced>", dims=dims, radius=radius, flops_per_point=0.0,
        n_arrays=n_arrays, c_iter=c_iter,
    )


def _best_of_factory(gpu: GPUSpec, lat, keep_idx):
    """The fused eq.-18 inner body shared by every compiled engine.

    Returns ``best_of(hw_chunk (n, 3), sizes (P, 4), st) -> (best_t (P, n),
    best_i (P, n))``. Both the single-device and the shard_map engines call
    exactly this function on their slabs, which is what makes the sharded
    results bit-identical: the per-point expression, reduction order, and
    dtype are byte-for-byte the same program.
    """

    def tile_times(hw_point, size_scalars, st):
        """(L,) candidate times for one hardware point -- the vmap body."""
        n_sm, n_v, m_sm = hw_point
        s1, s2, s3, t = size_scalars
        size = ProblemSize(s1=s1, s2=s2, t=t, s3=s3)
        return stencil_time(
            st, gpu, size, n_sm, n_v, m_sm, *lat, xp=jnp, dtype=jnp.float32
        )

    def best_of(hw_chunk, sizes, st):
        """(P, chunk) optima: vmap over sizes x vmap over hardware points."""
        times = jax.vmap(
            lambda sz: jax.vmap(
                lambda p: tile_times(p, (sz[0], sz[1], sz[2], sz[3]), st)
            )(hw_chunk)
        )(sizes)  # (P, chunk, L)
        best_i = jnp.argmin(times, axis=2)
        best_t = jnp.take_along_axis(times, best_i[..., None], axis=2)[..., 0]
        # map back to seed lattice indices; -1 where nothing was feasible
        best_i = jnp.where(jnp.isfinite(best_t), keep_idx[best_i], -1)
        return best_t, best_i

    return best_of


def _solve_empty(n_sm, n_v, m_sm, sizes, radius, c_iter, n_arrays):
    """Every-candidate-infeasible fast path (no lattice point survives the
    static constraints): +inf / -1 without touching the mesh or compiler."""
    p, h = sizes.shape[0], n_sm.shape[0]
    return jnp.full((p, h), jnp.inf), jnp.full((p, h), -1, jnp.int32)


@functools.lru_cache(maxsize=None)
def _cells_solver(dims: int, gpu: GPUSpec, lattice: TileLattice, chunk: int):
    """Compiled (sizes x hardware x lattice) argmin solver, shared per
    (dims, GPU, lattice, chunk).

    Returned callable:
    ``(n_sm, n_v, m_sm, sizes (P, 4), radius, c_iter, n_arrays)
    -> (best_t (P, H), best_i (P, H))`` over (H,) hardware arrays. Sizes
    and stencil scalars are dynamic jit arguments, and the size axis is an
    extra vmap dimension: all P problem sizes of a stencil family sweep in
    ONE dispatch (the seed looped Python-side, paying per-cell dispatch).
    The whole six-stencil paper sweep still compiles exactly twice
    (2D + 3D); only a new (P, H) shape pair retraces.
    """
    _require_jax()
    lat, keep_idx = _lattice_arrays(lattice, gpu)
    if keep_idx.shape[0] == 0:  # no candidate survives the static constraints
        return _solve_empty
    best_of = _best_of_factory(gpu, lat, keep_idx)

    @jax.jit
    def solve(n_sm, n_v, m_sm, sizes, radius, c_iter, n_arrays):
        st = _traced_spec(dims, radius, c_iter, n_arrays)
        hw = jnp.stack([n_sm, n_v, m_sm], axis=1)  # (H, 3)
        h = hw.shape[0]
        if chunk <= 0 or h <= chunk:
            return best_of(hw, sizes, st)
        # pad to a chunk multiple, lax.map over (B, chunk, 3) slabs so peak
        # memory is P x chunk x |lattice| regardless of |hardware space|.
        b = -(-h // chunk)
        pad = b * chunk - h
        hw = jnp.concatenate([hw, jnp.broadcast_to(hw[:1], (pad, 3))], axis=0)
        best_t, best_i = lax.map(
            lambda slab: best_of(slab, sizes, st),
            hw.reshape(b, chunk, 3),
        )  # (B, P, chunk)
        best_t = jnp.moveaxis(best_t, 0, 1).reshape(sizes.shape[0], -1)[:, :h]
        best_i = jnp.moveaxis(best_i, 0, 1).reshape(sizes.shape[0], -1)[:, :h]
        return best_t, best_i

    return solve


@functools.lru_cache(maxsize=None)
def _sharded_cells_solver(
    dims: int,
    gpu: GPUSpec,
    lattice: TileLattice,
    chunk: int,
    devices: tuple,
):
    """Multi-device solver: the (H,) hardware axis sharded over a 1-D mesh.

    Same contract as :func:`_cells_solver`, but the caller must pass the
    hardware columns already padded to ``len(devices) x max(chunk, 1)``
    (see :func:`sweep_cells_sharded`): each device receives whole chunks,
    so the per-shard program is shape-static and identical on every device.
    ``devices`` is a tuple of jax Device objects (hashable singletons, so
    they key the lru_cache directly -- never remapped through per-backend
    integer ids, which collide across backends).

    Inside each shard a ``lax.fori_loop`` streams chunk-sized slabs through
    the fused time-model body and writes the per-chunk argmins into a
    preallocated ``(P, H/D)`` output -- peak per-device memory is the
    ``P x chunk x |lattice|`` times tensor of ONE slab plus the output,
    regardless of how large the hardware space grows. The hw slab buffers
    are donated: at fleet scale they are dead weight after the stack.
    """
    _require_shard_map()
    mesh = Mesh(np.array(devices), ("hw",))
    lat, keep_idx = _lattice_arrays(lattice, gpu)
    if keep_idx.shape[0] == 0:
        return mesh, _solve_empty
    best_of = _best_of_factory(gpu, lat, keep_idx)

    def shard_body(n_sm, n_v, m_sm, sizes, radius, c_iter, n_arrays):
        """One device's shard: hw columns are the local (H/D,) slice."""
        st = _traced_spec(dims, radius, c_iter, n_arrays)
        hw = jnp.stack([n_sm, n_v, m_sm], axis=1)  # (H/D, 3)
        h, p = hw.shape[0], sizes.shape[0]
        if chunk <= 0 or h <= chunk:
            return best_of(hw, sizes, st)
        out_t = jnp.full((p, h), jnp.inf, jnp.float32)
        out_i = jnp.full((p, h), -1, jnp.int32)

        def one_chunk(c, carry):
            out_t, out_i = carry
            slab = lax.dynamic_slice_in_dim(hw, c * chunk, chunk, axis=0)
            t, i = best_of(slab, sizes, st)
            out_t = lax.dynamic_update_slice_in_dim(out_t, t, c * chunk, axis=1)
            out_i = lax.dynamic_update_slice_in_dim(out_i, i, c * chunk, axis=1)
            return out_t, out_i

        return lax.fori_loop(0, h // chunk, one_chunk, (out_t, out_i))

    sharded = shard_map(
        shard_body,
        mesh=mesh,
        in_specs=(P("hw"), P("hw"), P("hw"), P(), P(), P(), P()),
        out_specs=(P(None, "hw"), P(None, "hw")),
    )
    return mesh, jax.jit(sharded, donate_argnums=(0, 1, 2))


def _prep_cells(st, sizes, lattice, chunk):
    """Shared argument normalization for the compiled engines: default
    lattice by dimensionality, (P, 4) size validation, P-scaled chunk."""
    if lattice is None:
        from .solver import LATTICE_2D, LATTICE_3D

        lattice = LATTICE_3D if st.dims == 3 else LATTICE_2D
    sizes = np.atleast_2d(np.asarray(sizes, np.float64))
    if sizes.shape[1] != 4:
        raise ValueError(f"sizes must be (P, 4) (s1, s2, s3, t); got {sizes.shape}")
    if chunk is None:
        chunk = max(1, DEFAULT_CHUNK // sizes.shape[0])
    return lattice, sizes, int(chunk)


def sweep_cells(
    st: StencilSpec,
    gpu: GPUSpec,
    sizes: np.ndarray,
    n_sm: np.ndarray,
    n_v: np.ndarray,
    m_sm: np.ndarray,
    lattice: TileLattice | None = None,
    chunk: int | None = None,
) -> Tuple[np.ndarray, np.ndarray]:
    """All P problem sizes of one stencil in a single compiled dispatch.

    ``sizes`` is a ``(P, 4)`` float array of ``(s1, s2, s3, t)`` rows (the
    :data:`repro.core.workload.paper_sizes` grid packs 16 of them). Returns
    ``(best_time (P, H), best_lattice_index (P, H))`` as float64/int64;
    infeasible points get ``+inf`` / ``-1``. ``chunk=None`` scales the
    hardware slab down by P so peak memory matches the single-size sweep.
    """
    _require_jax()
    lattice, sizes, chunk = _prep_cells(st, sizes, lattice, chunk)
    solve = _cells_solver(st.dims, gpu, lattice, chunk)
    f32 = functools.partial(jnp.asarray, dtype=jnp.float32)
    h = np.asarray(n_sm).size
    t0 = time.perf_counter()
    best_t, best_i = solve(
        f32(np.asarray(n_sm).ravel()),
        f32(np.asarray(n_v).ravel()),
        f32(np.asarray(m_sm).ravel()),
        f32(sizes),
        f32(st.radius),
        f32(st.c_iter),
        f32(st.n_arrays),
    )
    out = (
        np.asarray(best_t, np.float64),  # blocks until the dispatch is done
        np.asarray(best_i, np.int64),
    )
    _note_dispatch(
        "jax", (id(solve), sizes.shape, h), sizes.shape[0], h,
        time.perf_counter() - t0,
    )
    return out


def sweep_cells_sharded(
    st: StencilSpec,
    gpu: GPUSpec,
    sizes: np.ndarray,
    n_sm: np.ndarray,
    n_v: np.ndarray,
    m_sm: np.ndarray,
    lattice: TileLattice | None = None,
    chunk: int | None = None,
    devices=None,
) -> Tuple[np.ndarray, np.ndarray]:
    """:func:`sweep_cells` with the hardware axis sharded across a device
    mesh -- the fleet-scale eq.-18 path.

    The (H,) hardware arrays are padded to a multiple of
    ``len(devices) x chunk`` (repeating the first point, whose padded
    results are discarded), partitioned over a 1-D ``Mesh(("hw",))`` with
    ``NamedSharding``, and each device streams its shard through the same
    fused time-model body as the single-device engine -- the gathered
    ``(best_t, best_i)`` are **bit-identical** to :func:`sweep_cells`
    (tested in ``tests/test_sweep_sharded.py``).

    ``devices`` is ``None`` (all attached), an int (first n devices), or an
    explicit device sequence. On CPU hosts, force a multi-device view with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` *before* jax
    initializes to exercise the real sharded path.
    """
    _require_shard_map()
    lattice, sizes, chunk = _prep_cells(st, sizes, lattice, chunk)
    devs = _resolve_devices(devices)
    n_dev = len(devs)
    cols = [
        np.asarray(np.asarray(a).ravel(), np.float32) for a in (n_sm, n_v, m_sm)
    ]
    h = cols[0].shape[0]
    if h == 0:
        p = sizes.shape[0]
        return np.full((p, 0), np.inf), np.full((p, 0), -1, np.int64)
    # cap the per-device chunk at the actual shard size: the default 2048
    # against a small H would otherwise pad every device to a full chunk
    # of discarded time-model evaluations (8 dev x 2048 for H=64).
    if chunk > 0:
        chunk = min(chunk, -(-h // n_dev))
    # pad H so every device gets the same whole number of chunks: the shard
    # program is shape-static, and a ragged tail cannot skew one device.
    quantum = n_dev * max(chunk, 1)
    h_pad = -(-h // quantum) * quantum
    if h_pad != h:
        cols = [np.concatenate([a, np.full(h_pad - h, a[0], a.dtype)]) for a in cols]
    mesh, solve = _sharded_cells_solver(st.dims, gpu, lattice, chunk, devs)
    shard = NamedSharding(mesh, P("hw"))
    repl = NamedSharding(mesh, P())
    f32 = functools.partial(jnp.asarray, dtype=jnp.float32)
    t0 = time.perf_counter()
    with warnings.catch_warnings():
        # the hw slabs are donated for accelerator meshes (dead after the
        # stack); on hosts where no output can alias them XLA drops the
        # donation and warns -- expected, not actionable.
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable"
        )
        best_t, best_i = solve(
            *(jax.device_put(a, shard) for a in cols),
            jax.device_put(f32(sizes), repl),
            f32(st.radius),
            f32(st.c_iter),
            f32(st.n_arrays),
        )
    out = (
        np.asarray(best_t, np.float64)[:, :h],  # blocks on the dispatch
        np.asarray(best_i, np.int64)[:, :h],
    )
    _note_dispatch(
        "sharded", (id(solve), sizes.shape, h_pad), sizes.shape[0], h,
        time.perf_counter() - t0,
    )
    return out


def sweep_cell(
    st: StencilSpec,
    gpu: GPUSpec,
    size: ProblemSize,
    n_sm: np.ndarray,
    n_v: np.ndarray,
    m_sm: np.ndarray,
    lattice: TileLattice | None = None,
    chunk: int = DEFAULT_CHUNK,
) -> Tuple[np.ndarray, np.ndarray]:
    """Drop-in replacement for :func:`repro.core.solver.solve_cell` -- the
    P=1 case of :func:`sweep_cells`.

    Returns ``(best_time (H,), best_lattice_index (H,))`` as float64/int64
    NumPy arrays; infeasible hardware points get ``+inf`` / ``-1``.
    Raises ``ModuleNotFoundError`` when jax is unavailable (use
    ``codesign(engine="auto")`` or the NumPy solver for soft fallback).
    """
    sizes = np.array([[size.s1, size.s2, size.s3, size.t]], np.float64)
    best_t, best_i = sweep_cells(
        st, gpu, sizes, n_sm, n_v, m_sm, lattice, int(chunk)
    )
    return best_t[0], best_i[0]


# ---------------------------------------------------------------------------
# Batched coordinate-descent refinement
# ---------------------------------------------------------------------------
@functools.lru_cache(maxsize=None)
def _refine_descent(dims: int, gpu: GPUSpec):
    """Compiled whole-descent best-neighbor refinement over (P,) points.

    Candidates per point per round: current + (+step, -step) for each of
    the 5 software parameters, clamped to the aligned lower bounds; every
    point moves to its best single-parameter neighbor simultaneously
    (Jacobi-style). The rounds live in a ``lax.while_loop`` that stops on
    convergence (a no-movement round) or after ``max_rounds`` -- the whole
    descent is ONE dispatch and ONE device->host sync, where the previous
    engine forced a blocking ``bool(jnp.all(...))`` transfer every round.
    ``max_rounds`` is a dynamic operand, so changing the budget never
    retraces.
    """
    _require_jax()
    steps = jnp.asarray(SW_STEPS, jnp.float32)
    mins = jnp.asarray(SW_MINS, jnp.float32)
    n_par = len(SW_NAMES)

    def candidates(sw):
        """(2*n_par + 1, 5): current point first, then +/- steps."""
        deltas = jnp.concatenate(
            [jnp.zeros((1, n_par)), jnp.diag(steps), -jnp.diag(steps)], axis=0
        )
        return jnp.maximum(sw[None, :] + deltas, mins[None, :])

    def eval_point(st, hw, size_scalars, sw_cands):
        n_sm, n_v, m_sm = hw
        s1, s2, s3, t = size_scalars
        size = ProblemSize(s1=s1, s2=s2, t=t, s3=s3)
        return stencil_time(
            st, gpu, size, n_sm, n_v, m_sm,
            sw_cands[:, 0], sw_cands[:, 1], sw_cands[:, 2], sw_cands[:, 3],
            sw_cands[:, 4], xp=jnp, dtype=jnp.float32,
        )

    @jax.jit
    def descend(hw, sizes, sw0, radius, c_iter, n_arrays, max_rounds):
        """hw (P,3), sizes (P,4), sw0 (P,5) ->
        (times (P,), sw (P,5), rounds executed)."""
        st = _traced_spec(dims, radius, c_iter, n_arrays)

        def one_round(sw):
            cands = jax.vmap(candidates)(sw)  # (P, 2n+1, 5)
            times = jax.vmap(
                lambda h, s, c: eval_point(st, h, (s[0], s[1], s[2], s[3]), c)
            )(hw, sizes, cands)  # (P, 2n+1)
            best = jnp.argmin(times, axis=1)
            best_t = jnp.take_along_axis(times, best[:, None], axis=1)[:, 0]
            best_sw = jnp.take_along_axis(cands, best[:, None, None], axis=1)[:, 0]
            return best_t, best_sw

        def cond(carry):
            _, _, rounds, moved = carry
            return moved & (rounds < max_rounds)

        def body(carry):
            sw, _, rounds, _ = carry
            best_t, best_sw = one_round(sw)
            # a no-movement round means every point sat still (argmin ties
            # break to the current point), so best_t is exact: stop.
            moved = jnp.any(best_sw != sw)
            return best_sw, best_t, rounds + 1, moved

        t0 = jnp.full((sw0.shape[0],), jnp.inf, jnp.float32)
        sw, t, rounds, _ = lax.while_loop(
            cond, body, (sw0, t0, jnp.int32(0), jnp.bool_(True))
        )
        return t, sw, rounds

    return descend


def refine_points(
    st: StencilSpec,
    gpu: GPUSpec,
    sizes: np.ndarray,
    hw: np.ndarray,
    sw0: np.ndarray,
    max_rounds: int = 64,
) -> Tuple[np.ndarray, np.ndarray]:
    """Coordinate descent over aligned integer steps, batched over P points.

    Parameters
    ----------
    sizes: (P, 4) float array of (s1, s2, s3, t) per design point.
    hw:    (P, 3) float array of (n_sm, n_v, m_sm).
    sw0:   (P, 5) float array of starting tile sizes in :data:`SW_NAMES`
           order (e.g. lattice optima from :func:`sweep_cell`).

    Returns ``(times (P,), sw (P, 5))`` where no point's single aligned-step
    neighbor improves on its returned tile sizes (the same local-exactness
    guarantee as the seed's :func:`repro.core.solver.refine_point`, reached
    by best-neighbor rounds instead of first-improvement scans). As with
    the seed, the guarantee holds only when the descent converges within
    ``max_rounds``; lattice-optimum starts (the intended use) converge in a
    handful of rounds, but arbitrary far-from-optimal ``sw0`` may exhaust
    the budget and return the best point reached so far. The whole descent
    -- every round, every ``P x 11`` candidate -- is one compiled
    ``lax.while_loop`` dispatch with a single device->host sync at the end
    (the previous per-round ``bool(jnp.all(...))`` convergence check forced
    a blocking transfer every round).
    """
    _require_jax()
    hw64 = np.asarray(hw, np.float64)
    sizes64 = np.asarray(sizes, np.float64)
    sw = np.asarray(sw0, np.float64)
    if max_rounds <= 0:  # return the start points untouched, like the oracle
        size = ProblemSize(
            s1=sizes64[:, 0], s2=sizes64[:, 1], t=sizes64[:, 3], s3=sizes64[:, 2]
        )
        cur = stencil_time(
            st, gpu, size, hw64[:, 0], hw64[:, 1], hw64[:, 2],
            sw[:, 0], sw[:, 1], sw[:, 2], sw[:, 3], sw[:, 4],
        )
        return np.asarray(cur, np.float64), sw
    descend = _refine_descent(st.dims, gpu)
    t, sw_out, _ = descend(
        jnp.asarray(hw64, jnp.float32),
        jnp.asarray(sizes64, jnp.float32),
        jnp.asarray(sw, jnp.float32),
        jnp.asarray(st.radius, jnp.float32),
        jnp.asarray(st.c_iter, jnp.float32),
        jnp.asarray(st.n_arrays, jnp.float32),
        jnp.asarray(max_rounds, jnp.int32),
    )
    return np.asarray(t, np.float64), np.asarray(sw_out, np.float64)


def decode_sw(sw_row: np.ndarray) -> Dict[str, int]:
    """(5,) packed software-parameter row -> tile-size dict."""
    return {name: int(v) for name, v in zip(SW_NAMES, sw_row)}


def clear_caches() -> None:
    """Drop compiled solvers (mainly for tests/benchmarks timing cold starts)."""
    _cells_solver.cache_clear()
    _sharded_cells_solver.cache_clear()
    _refine_descent.cache_clear()
    with _DISPATCH_MU:
        # cleared solvers recompile, so their next dispatch is 'first'
        # again (and a recycled id() must not classify it 'steady')
        _DISPATCH_SEEN.clear()
