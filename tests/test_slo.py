"""SLO engine: bucket quantile estimator vs the NumPy percentile oracle
on adversarial distributions, rolling-window frame arithmetic under a
fake clock, burn-rate math, status transitions (ok -> burning ->
violated), and the dual renderings of ``SLOTracker.report``."""

import json

import numpy as np
import pytest

from repro.obs.metrics import LATENCY_BUCKETS
from repro.obs.slo import (
    DEFAULT_OBJECTIVES,
    SLOObjective,
    SLOTracker,
    bucket_quantile,
)


def _bucketize(bounds, samples):
    """Counts in the same layout bucket_quantile wants: one count per
    bound (cumulative-style bins: sample <= bound) plus overflow."""
    counts = [0] * (len(bounds) + 1)
    for s in samples:
        for i, b in enumerate(bounds):
            if s <= b:
                counts[i] += 1
                break
        else:
            counts[-1] += 1
    return counts


# ---------------------------------------------------------------------------
# bucket_quantile vs numpy oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("q", [0.5, 0.9, 0.99, 0.999])
@pytest.mark.parametrize(
    "name,samples",
    [
        ("uniform", np.linspace(1e-4, 5.0, 4001)),
        ("lognormal", np.exp(np.random.RandomState(0).normal(-6, 2, 5000))),
        # adversarial: bimodal mass hugging two bucket boundaries
        ("bimodal_edges", np.concatenate([
            np.full(900, 0.00101), np.full(100, 0.9999),
        ])),
        # everything in ONE bucket: interpolation must stay inside it
        ("single_bucket", np.full(1000, 0.003)),
        # heavy overflow tail beyond the last bound
        ("overflow_tail", np.concatenate([
            np.full(500, 0.001), np.full(500, 50.0),
        ])),
    ],
)
def test_bucket_quantile_vs_numpy(name, samples, q):
    bounds = LATENCY_BUCKETS
    counts = _bucketize(bounds, samples)
    est = bucket_quantile(bounds, counts, q)
    assert est is not None
    # the estimator is correct up to bucket resolution: it must land
    # within the bucket span covered by the order-statistic oracles
    # (nearest sample at or below / above the rank -- at an exact rank
    # boundary the linear-interpolation oracle jumps buckets, the
    # histogram cannot). Overflow clamps to the last finite bound.
    o_lo = float(np.percentile(samples, q * 100, method="lower"))
    o_hi = float(np.percentile(samples, q * 100, method="higher"))

    def bucket_edges(x):
        if x > bounds[-1]:
            return bounds[-1], bounds[-1]
        i = next(i for i, b in enumerate(bounds) if x <= b)
        return (0.0 if i == 0 else bounds[i - 1]), bounds[i]

    lo_edge = bucket_edges(o_lo)[0]
    hi_edge = bucket_edges(o_hi)[1]
    assert lo_edge - 1e-12 <= est <= hi_edge + 1e-12, (
        f"{name}: q={q} est={est} outside oracle band [{lo_edge}, {hi_edge}]"
    )


def test_bucket_quantile_edge_cases():
    bounds = (1.0, 2.0, 4.0)
    assert bucket_quantile(bounds, [0, 0, 0, 0], 0.5) is None  # no mass
    # all mass in overflow -> clamp to last bound
    assert bucket_quantile(bounds, [0, 0, 0, 7], 0.99) == 4.0
    # exact midpoint of a uniform bucket
    assert bucket_quantile(bounds, [0, 10, 0, 0], 0.5) == pytest.approx(1.5)
    with pytest.raises(ValueError):
        bucket_quantile(bounds, [1, 2], 0.5)  # wrong count arity
    with pytest.raises(ValueError):
        bucket_quantile(bounds, [0, 0, 0, 1], 1.5)  # q out of range


# ---------------------------------------------------------------------------
# objectives
# ---------------------------------------------------------------------------


def test_objective_validation():
    with pytest.raises(ValueError):
        SLOObjective(route="/v1/query", availability=1.5)
    with pytest.raises(ValueError):
        SLOObjective(route="/v1/query", latency_p=0.0)
    with pytest.raises(ValueError):
        SLOObjective(route="", latency_threshold_s=0.01)
    with pytest.raises(ValueError):
        SLOObjective(route="/v1/query", latency_threshold_s=-1.0)
    d = SLOObjective(route="/v1/query").to_dict()
    assert d["availability"] == 0.999 and d["latency_p"] == 0.99


def test_default_objectives_cover_query_routes():
    routes = {o.route for o in DEFAULT_OBJECTIVES}
    assert routes == {"/v1/query", "/v1/query_many", "/v1/route"}


# ---------------------------------------------------------------------------
# tracker: windows, burn rates, status transitions
# ---------------------------------------------------------------------------


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_tracker_ignores_unknown_routes():
    clk = FakeClock()
    tr = SLOTracker(clock=clk)
    tr.record("/v1/metrics", 0.001, ok=True)
    rep = tr.report()
    assert all(
        w["count"] == 0
        for r in rep["routes"].values()
        for w in r["windows"].values()
    )


def test_tracker_healthy_traffic_is_ok():
    clk = FakeClock()
    tr = SLOTracker(clock=clk)
    for i in range(1000):
        clk.t = i * 0.1
        tr.record("/v1/query", 0.005, ok=True)
    rep = tr.report()
    q = rep["routes"]["/v1/query"]
    assert q["status"] == "ok"
    assert rep["status"] == "ok"
    w5 = q["windows"]["5m"]
    assert w5["errors"] == 0 and w5["availability_burn"] == 0.0
    assert w5["p_estimate_s"] is not None and w5["p_estimate_s"] < 0.025


def test_tracker_burn_math_exact():
    """1% 5xx against a 99.9% objective = burn rate 10x, both windows."""
    clk = FakeClock()
    tr = SLOTracker(clock=clk)
    for i in range(1000):
        clk.t = float(i) * 0.05
        tr.record("/v1/query", 0.001, ok=(i % 100 != 0))
    rep = tr.report()
    q = rep["routes"]["/v1/query"]
    for w in ("5m", "1h"):
        assert q["windows"][w]["availability_burn"] == pytest.approx(10.0)
    # burning in BOTH windows -> violated, and the top status folds worst-of
    assert q["status"] == "violated"
    assert rep["status"] == "violated"


def test_tracker_recovery_transitions_to_burning_then_ok():
    """A recent error blip burns the short window while staying inside
    the hour's budget -> ``burning``; once it ages out of both windows
    the route is ok again."""
    clk = FakeClock()
    tr = SLOTracker(clock=clk)
    # an hour of clean traffic at 1 qps
    for i in range(3600):
        clk.t = float(i)
        tr.record("/v1/query", 0.001, ok=True)
    # then a 2-error blip: over the 5m budget (2/~300 >> 0.001), under
    # the 1h budget (2/~3600 < 0.001 is false -- 2/3602 = 0.00056 < 0.001)
    for i in (3600, 3601):
        clk.t = float(i)
        tr.record("/v1/query", 0.001, ok=False)
    rep = tr.report()
    q = rep["routes"]["/v1/query"]
    assert q["windows"]["5m"]["errors"] == 2
    assert q["windows"]["5m"]["availability_burn"] >= 1.0
    assert q["windows"]["1h"]["availability_burn"] < 1.0
    assert q["status"] == "burning"
    assert rep["status"] == "burning"
    # two hours later every error aged out of both windows
    clk.t = 10800.0
    tr.record("/v1/query", 0.001, ok=True)
    rep = tr.report()
    assert rep["routes"]["/v1/query"]["status"] == "ok"
    assert tr.status() == "ok"


def test_tracker_latency_burn_without_errors():
    """Slow-but-successful answers burn the latency budget only."""
    clk = FakeClock()
    tr = SLOTracker(clock=clk)
    for i in range(1000):
        clk.t = float(i) * 0.01
        # 5% of answers over the 25ms threshold, all HTTP 200
        tr.record("/v1/query", 0.5 if i % 20 == 0 else 0.001, ok=True)
    q = tr.report()["routes"]["/v1/query"]
    w5 = q["windows"]["5m"]
    assert w5["availability_burn"] == 0.0
    assert w5["latency_burn"] == pytest.approx(0.05 / 0.01)  # 5x
    assert q["status"] == "violated"


def test_report_shape_and_canonical_encoding():
    clk = FakeClock()
    tr = SLOTracker(clock=clk)
    tr.record("/v1/query", 0.004, ok=True)
    rep = tr.report()
    assert [w["name"] for w in rep["windows"]] == ["5m", "1h"]
    assert [w["seconds"] for w in rep["windows"]] == [300.0, 3600.0]
    assert list(rep["routes"]) == sorted(rep["routes"])
    # JSON-serializable all the way down (wire.encode_slo_response relies
    # on this)
    json.dumps(rep)


def test_render_prometheus_exposition():
    clk = FakeClock()
    tr = SLOTracker(clock=clk)
    for _ in range(10):
        tr.record("/v1/query", 0.004, ok=True)
    text = tr.render_prometheus().decode("utf-8")
    assert "repro_slo_burn_rate{" in text
    assert 'route="/v1/query"' in text
    assert "repro_slo_status{" in text
    assert "repro_slo_latency_estimate_seconds{" in text
    # status gauge encodes ok=0
    line = next(l for l in text.splitlines()
                if l.startswith('repro_slo_status{route="/v1/query"}'))
    assert float(line.split()[-1]) == 0.0


def test_frame_ring_is_bounded():
    """Days of traffic cannot grow the ring past its computed cap."""
    clk = FakeClock()
    tr = SLOTracker(clock=clk, frame_interval_s=5.0)
    for i in range(100_000):
        clk.t = float(i)
        tr.record("/v1/query", 0.001, ok=True)
    assert len(tr._frames) <= tr._max_frames
