"""Import every architecture module for registry side effects."""

from . import (  # noqa: F401
    deepseek_v3_671b,
    gemma_7b,
    internlm2_1_8b,
    jamba_v0_1_52b,
    llama3_8b,
    mamba2_780m,
    minitron_4b,
    mixtral_8x22b,
    qwen2_vl_2b,
    whisper_medium,
)
