"""End-to-end driver: codesign -> configure kernels -> execute -> report.

The full loop the paper envisions, on this machine:
1. solve the codesign problem for a Jacobi-2D workload (analytic),
2. take the winning *software* parameters (the tile sizes),
3. map them onto the TPU Pallas kernel's block plan (DESIGN.md: the VMEM
   feasibility constraint is the eq. 9/11 analogue),
4. execute the Pallas kernel (interpret mode on CPU) against the jnp
   oracle and report correctness + achieved useful FLOP/s.

Run: PYTHONPATH=src python examples/stencil_codesign_e2e.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import MAXWELL_GPU, STENCILS, ProblemSize, solve_cell
from repro.core.solver import LATTICE_2D, decode_index
from repro.kernels.ops import kernel_flops, stencil_run, tuned_block_rows
from repro.kernels.ref import run_ref

# --- 1. codesign: optimal tiles for a 2048^2 x 64 Jacobi-2D cell ----------
spec = STENCILS["jacobi2d"]
size = ProblemSize(2048, 2048, 64)
hw = (np.array([16.0]), np.array([128.0]), np.array([96.0]))  # GTX-980 point
t, idx = solve_cell(spec, MAXWELL_GPU, size, *hw, LATTICE_2D)
tiles = decode_index(LATTICE_2D, int(idx[0]))
print(f"analytic optimum: T_alg={t[0]*1e3:.1f} ms, tiles={tiles}")

# --- 2-3. map the software solve onto the TPU kernel's block plan ---------
shape = (512, 512)
steps = 8
x = jax.random.normal(jax.random.PRNGKey(0), shape, jnp.float32)
block_rows = tuned_block_rows("jacobi2d", shape, jnp.float32)
print(f"TPU block plan: band of {block_rows} rows (VMEM-fit solve)")

# --- 4. execute + validate -------------------------------------------------
t0 = time.perf_counter()
got = stencil_run("jacobi2d", x, steps=steps, block_rows=block_rows)
got.block_until_ready()
dt = time.perf_counter() - t0
want = run_ref("jacobi2d", x, steps=steps)
err = float(jnp.abs(got - want).max())
flops = kernel_flops("jacobi2d", shape, steps)
print(
    f"ran {steps} steps of {shape} in {dt*1e3:.0f} ms "
    f"(interpret mode): max|err| = {err:.2e}, useful {flops/dt/1e6:.1f} MFLOP/s"
)
assert err < 1e-5
print("OK: Pallas kernel matches the oracle with codesigned blocks")
