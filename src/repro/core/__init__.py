"""Core library: the paper's contribution -- analytical area/time models and
the non-linear codesign optimizer (plus the TPU re-instantiation used by the
LM framework's mesh/sharding autotuner)."""

from .area import (  # noqa: F401
    GTX980,
    MAXWELL,
    TITAN_X,
    HardwarePoint,
    LinearAreaModel,
    cacheless,
)
from .codesign import (  # noqa: F401
    CodesignResult,
    HardwareSpace,
    codesign,
    enumerate_hw_space,
    evaluate_fixed_hw,
)
from .pareto import pareto_front, pareto_mask  # noqa: F401
from .solver import LATTICE_2D, LATTICE_3D, TileLattice, refine_point, solve_cell  # noqa: F401
from .timemodel import (  # noqa: F401
    MAXWELL_GPU,
    STENCILS,
    TITANX_GPU,
    GPUSpec,
    ProblemSize,
    StencilSpec,
    stencil_gflops,
    stencil_time,
)
from .workload import Workload, WorkloadCell, paper_sizes, paper_workload  # noqa: F401
