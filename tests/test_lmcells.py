"""LM op-graph cells (repro.core.lmcells): the vectorized sweep engine vs
the plain-scalar oracle (bit-exact in float64), the oracle vs
``lm_roofline`` (term-level equality for the standard ops), jax engine
agreement, family dispatch through ``codesign()``, and artifact
round-trip bit-identity + content-key stability through the store."""

import dataclasses

import numpy as np
import pytest

from repro.configs import get_arch
from repro.core import sweep
from repro.core.codesign import codesign
from repro.core.lmcells import (
    LM_GPU_NAME,
    enumerate_lm_hw_space,
    lm_cell_roofline,
    lm_codesign,
    lm_sw_lattice,
    lm_workload,
    resolve_lm_engine,
)
from repro.core.lmtime import MeshPlan, lm_roofline
from repro.core.workload import Workload, paper_workload
from repro.service.store import ArtifactStore

#: float32 evaluation noise bound for the jax engine (numpy is exact).
RTOL = 1e-5


@pytest.fixture(scope="module")
def cfgs():
    """Reduced same-family variants keep cell constants small and fast;
    mixtral brings the MoE dispatch op into the workload."""
    return [get_arch("llama3-8b").reduced(), get_arch("mixtral-8x22b").reduced()]


@pytest.fixture(scope="module")
def wl(cfgs):
    return lm_workload(archs=cfgs, name="lm-test")


@pytest.fixture(scope="module")
def hw():
    return enumerate_lm_hw_space(max_chips=32)


@pytest.fixture(scope="module")
def oracle(wl, hw):
    return lm_codesign(wl, hw=hw, engine="numpy")


def _brute_force(cell, lat, point):
    """min over the software lattice, feasibility-masked, via the scalar
    oracle -- the reference the vectorized engines must reproduce."""
    times = []
    for j in range(len(lat)):
        plan = lat.plan(point["pod"], point["data"], point["model"], j)
        r = lm_cell_roofline(cell, plan)
        times.append(r["bound_s"] if r["feasible"] else np.inf)
    return times


def test_workload_shape(wl):
    assert wl.family == "lm"
    ops = {c.op for c in wl.cells}
    assert ops == {"prefill", "decode", "train", "moe_dispatch"}
    assert len(wl.cells) == 7  # 3 dense + 4 MoE
    np.testing.assert_allclose(sum(c.freq for c in wl.cells), 1.0)
    # decode cells carry a real KV-cache footprint; others none
    for c in wl.cells:
        assert (c.kv_bytes > 0) == (c.op == "decode")


def test_numpy_engine_is_bit_exact_vs_scalar_oracle(wl, hw, oracle):
    """Exhaustive (cell x hw x sw) check: identical expression order makes
    the vectorized float64 grid *bit*-equal to the scalar oracle."""
    for ci, cell in enumerate(wl.cells):
        lat = lm_sw_lattice(cell.op)
        for hi in range(len(hw)):
            times = _brute_force(cell, lat, hw.point(hi))
            t = min(times)
            if np.isfinite(t):
                assert oracle.cell_time[ci, hi] == t, (cell.label, hi)
                # the recorded plan achieves the optimum
                j = int(oracle.cell_plan_idx[ci, hi])
                assert times[j] == t
            else:
                assert oracle.cell_time[ci, hi] == np.inf
                assert oracle.cell_plan_idx[ci, hi] == -1


def test_scalar_oracle_mirrors_lm_roofline(cfgs, wl):
    """For prefill/decode/train the cell oracle must reproduce
    ``lm_roofline`` term for term (moe_dispatch is defined in lmcells and
    has no lmtime twin)."""
    by_model = {c.name: c for c in cfgs}
    plans = [
        MeshPlan(1, 2, 2),
        MeshPlan(1, 1, 8, microbatches=2, remat="none"),
        MeshPlan(2, 4, 2, microbatches=4, remat="full", fsdp=True,
                 compress_grads=True),
    ]
    checked = 0
    for cell in wl.cells:
        if cell.op == "moe_dispatch":
            continue
        cfg = by_model[cell.model]
        for plan in plans:
            a = lm_cell_roofline(cell, plan)
            b = lm_roofline(cfg, cell.shape, plan, cell.n_params, cell.n_active)
            for key in ("compute_s", "memory_s", "collective_s", "bound_s",
                        "hbm_bytes"):
                assert a[key] == b[key], (cell.label, plan, key)
            assert a["dominant"] == b["dominant"]
            assert a["fits"] == b["fits"]
            checked += 1
    assert checked == 6 * len(plans)


@pytest.mark.skipif(not sweep.HAVE_JAX, reason="jax not installed")
def test_jax_engine_matches_numpy(wl, hw, oracle):
    jres = lm_codesign(wl, hw=hw, engine="jax")
    feas = np.isfinite(oracle.cell_time)
    assert np.array_equal(feas, np.isfinite(jres.cell_time))
    assert np.allclose(jres.cell_time[feas], oracle.cell_time[feas], rtol=RTOL)
    # where the f32 argmin differs it must be a tie in the f64 model
    for ci, cell in enumerate(wl.cells):
        lat = lm_sw_lattice(cell.op)
        diff = np.nonzero(feas[ci] & (jres.cell_plan_idx[ci] != oracle.cell_plan_idx[ci]))[0]
        for hi in diff:
            times = _brute_force(cell, lat, hw.point(int(hi)))
            j = int(jres.cell_plan_idx[ci, hi])
            assert times[j] == pytest.approx(oracle.cell_time[ci, hi], rel=RTOL)


def test_engine_resolution():
    assert resolve_lm_engine("numpy") == "numpy"
    assert resolve_lm_engine("auto") in ("numpy", "jax")
    with pytest.raises(ValueError):
        resolve_lm_engine("cuda")


def test_codesign_dispatches_on_family(wl, hw, oracle):
    res = codesign(wl, hw=hw, engine="numpy")
    assert type(res).__name__ == "LMCodesignResult"
    assert np.array_equal(res.cell_time, oracle.cell_time)
    assert np.array_equal(res.cell_plan_idx, oracle.cell_plan_idx)


def test_mixed_family_workload_rejected(wl):
    halved = [
        dataclasses.replace(c, freq=c.freq / 2)
        for c in (*paper_workload().cells, *wl.cells)
    ]
    with pytest.raises(ValueError, match="famil"):
        Workload(name="mixed", cells=tuple(halved))


def test_plan_for_round_trips(wl, hw, oracle):
    ci = next(i for i, c in enumerate(wl.cells) if c.op == "train")
    hi = int(np.nonzero(np.isfinite(oracle.cell_time[ci]))[0][-1])
    plan = oracle.plan_for(ci, hi)
    r = lm_cell_roofline(wl.cells[ci], plan)
    assert r["feasible"]
    assert r["bound_s"] == oracle.cell_time[ci, hi]


def test_artifact_round_trip_bit_identity(tmp_path, wl, hw, oracle):
    store = ArtifactStore(str(tmp_path))
    art = store.put(oracle, engine="numpy")
    # the content key is computable BEFORE any sweep, and stable
    assert art.key == store.key_for_lm(wl, hw, engine="numpy")
    assert art.family == "lm"
    assert store.put(oracle, engine="numpy").key == art.key

    back = art.to_result()
    assert type(back).__name__ == "LMCodesignResult"
    assert np.array_equal(back.cell_time, oracle.cell_time)
    assert np.array_equal(back.cell_plan_idx, oracle.cell_plan_idx)
    assert back.gpu_name == oracle.gpu_name == LM_GPU_NAME
    assert [c.label for c in back.workload.cells] == [c.label for c in wl.cells]
    np.testing.assert_array_equal(back.cell_freqs(), oracle.cell_freqs())
    np.testing.assert_array_equal(back.cell_flops(), oracle.cell_flops())
    # the reconstructed cells re-solve to the same plans
    for ci in range(len(wl.cells)):
        hi = int(np.nonzero(np.isfinite(oracle.cell_time[ci]))[0][0])
        assert back.plan_for(ci, hi) == oracle.plan_for(ci, hi)

    md = art.routing()
    assert md["workload"] == "lm-test" and md["family"] == "lm"
    assert md["models"] == sorted({c.model for c in wl.cells})
    assert md["ops"] == ["decode", "moe_dispatch", "prefill", "train"]
    # area IS the chip count for LM sweeps
    np.testing.assert_array_equal(art.hw_area, art.hw_column("chips"))


def test_key_tracks_the_question(tmp_path, wl, cfgs, hw):
    store = ArtifactStore(str(tmp_path))
    base = store.key_for_lm(wl, hw, engine="numpy")
    assert store.key_for_lm(wl, hw, engine="numpy") == base
    smaller = enumerate_lm_hw_space(max_chips=16)
    assert store.key_for_lm(wl, smaller, engine="numpy") != base
    one = lm_workload(archs=cfgs[:1], name="lm-test")
    assert store.key_for_lm(one, hw, engine="numpy") != base
    assert store.key_for_lm(wl, hw, engine="numpy", gpu_name="other") != base


def test_divisibility_infeasibility(cfgs, hw):
    """A global batch that cannot shard over the data axis must surface as
    +inf / plan -1, mirroring meshopt's constraint -- not as a silently
    wrong time."""
    from repro.configs.base import ShapeSpec

    shape = ShapeSpec("decode_b3", 1024, 3, "decode")  # 3 never splits
    wl3 = lm_workload(archs=cfgs[:1], name="gb3",
                      shapes={"decode": shape})
    res = lm_codesign(wl3, hw=hw, engine="numpy")
    ci = next(i for i, c in enumerate(wl3.cells) if c.op == "decode")
    ds = (hw.pod * hw.data).astype(int)
    bad = (3 % ds != 0) & (3 >= ds)
    assert np.all(~np.isfinite(res.cell_time[ci][bad]))
    assert np.all(res.cell_plan_idx[ci][bad] == -1)
