"""Gradient compression for the slow (cross-pod) axis: int8 quantization
with error feedback.

At 1000+-node scale the pod-to-pod reduction is the scarce bandwidth; int8
cuts those bytes 4x vs f32 (2x vs bf16). Error feedback keeps the *long-run*
bias at zero: the residual e_t = g_t - deq(quant(g_t + e_{t-1})) is added to
the next step's gradient, so quantization noise is a zero-mean perturbation
instead of a systematic truncation (Seide et al.; Karimireddy et al.).

Two integration points:
* :func:`compress_grads` -- drop-in transform inside the train step (works
  under pjit; the quant/dequant pair also *shrinks the all-reduce* when the
  reduction is expressed via :func:`compressed_psum` under shard_map);
* :func:`compressed_psum` -- explicit shard_map collective for the 'pod'
  axis: quantize -> psum int32 -> dequantize.
"""

from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "quantize_int8",
    "dequantize_int8",
    "CompressionState",
    "compress_grads",
    "compressed_psum",
]


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-tensor int8. Returns (q int8, scale f32 scalar)."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


class CompressionState(NamedTuple):
    error: Any  # pytree of f32 residuals, same structure as grads


def compression_init(grads_like: Any) -> CompressionState:
    return CompressionState(
        error=jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)
    )


def compress_grads(
    grads: Any, state: Optional[CompressionState]
) -> Tuple[Any, CompressionState]:
    """Quantize-dequantize each gradient leaf with error feedback."""
    if state is None:
        state = compression_init(grads)

    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, s = quantize_int8(corrected)
        deq = dequantize_int8(q, s)
        return deq.astype(g.dtype), corrected - deq

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(state.error)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (
        tdef.unflatten([o[0] for o in out]),
        CompressionState(error=tdef.unflatten([o[1] for o in out])),
    )


def compressed_psum(x: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """int8-over-the-wire psum for use inside shard_map.

    A shared quantization scale is agreed with a scalar ``pmax`` (negligible
    bytes), then the int8 payloads are summed exactly in int32 -- each
    participant ships ~1/4 the bytes of an f32 all-reduce, and the result is
    exactly the sum of the per-shard quantized values (error feedback at the
    caller absorbs the quantization residual)."""
    xf = x.astype(jnp.float32)
    local_max = jnp.max(jnp.abs(xf))
    scale = jnp.maximum(jax.lax.pmax(local_max, axis_name), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return total.astype(jnp.float32) * scale
