"""Stencil kernel microbenchmarks: Pallas (interpret) vs jnp oracle, with
useful-FLOP throughput. Wall-times are CPU-interpret numbers -- the TPU is
the target; correctness + blocking behaviour is what is exercised here."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ops import KERNELS, kernel_flops, stencil_run, tuned_block_rows
from repro.kernels.ref import run_ref

from .common import emit, smoke, timed

SHAPES = {2: (256, 256), 3: (32, 64, 64)}
SMOKE_SHAPES = {2: (64, 64), 3: (16, 32, 32)}
STEPS = 2


def run() -> None:
    shapes = SMOKE_SHAPES if smoke() else SHAPES
    for name, mod in KERNELS.items():
        shape = shapes[mod.DIMS]
        x = jax.random.normal(jax.random.PRNGKey(0), shape, jnp.float32)
        br = tuned_block_rows(name, shape, jnp.float32)

        run_k = lambda: stencil_run(name, x, steps=STEPS, block_rows=br).block_until_ready()
        run_k()  # compile
        _, us_k = timed(run_k)

        run_r = lambda: jax.block_until_ready(run_ref(name, x, steps=STEPS))
        run_r()
        _, us_r = timed(run_r)

        got = stencil_run(name, x, steps=STEPS, block_rows=br)
        want = run_ref(name, x, steps=STEPS)
        err = float(jnp.abs(got - want).max())
        fl = kernel_flops(name, shape, STEPS)
        emit(
            f"kernel_{name}", us_k,
            f"blocks={br} rows, max|err|={err:.1e}, useful "
            f"{fl/us_k:.2f} MFLOP/s interp (jnp oracle {us_r:.0f} us)",
        )
        assert err < 1e-4
