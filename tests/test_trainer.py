"""Trainer loop: convergence, fault-tolerance (crash -> restore -> replay),
preemption, straggler accounting, restart determinism."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.configs import get_arch
from repro.configs.base import ShapeSpec
from repro.data import DataConfig
from repro.optim import AdamWConfig
from repro.train import TrainConfig, Trainer, TrainerConfig

# multi-second jit compiles: the fast CI lane deselects these (-m "not slow");
# the weekly scheduled lane (and a bare local `pytest`) still runs them
pytestmark = pytest.mark.slow

SHAPE = ShapeSpec("tiny", 32, 4, "train")


def _mesh():
    return Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))


def _tcfg():
    return TrainConfig(
        microbatches=1,
        remat="none",
        opt=AdamWConfig(lr=6e-3, warmup_steps=5, total_steps=80, weight_decay=0.0),
    )


def _trainer(tmp_path, steps=30, fault_hook=None, **kw):
    cfg = get_arch("internlm2-1.8b").reduced()
    run = TrainerConfig(
        steps=steps, ckpt_dir=str(tmp_path / "ckpt"), ckpt_every=5, log_every=100, **kw
    )
    return Trainer(
        cfg, SHAPE, _mesh(), _tcfg(), run, DataConfig(seed=1), fault_hook=fault_hook
    )


def test_loss_decreases(tmp_path):
    out = _trainer(tmp_path, steps=40).train()
    losses = [m["lm_loss"] for m in out["metrics"]]
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1
    assert out["step"] == 40 and out["failures"] == 0


def test_fault_recovery_resumes_and_is_deterministic(tmp_path):
    # clean run
    clean = _trainer(tmp_path / "clean", steps=20).train()

    # faulty run: crash once at step 13 (after the step-10 checkpoint)
    state = {"fired": False}

    def hook(step):
        if step == 13 and not state["fired"]:
            state["fired"] = True
            raise RuntimeError("injected node failure")

    faulty = _trainer(tmp_path / "faulty", steps=20, fault_hook=hook).train()
    assert faulty["failures"] == 1
    assert faulty["step"] == 20

    # deterministic pipeline + checkpoint/replay => identical final params
    for a, b in zip(
        jax.tree.leaves(clean["state"]["params"]),
        jax.tree.leaves(faulty["state"]["params"]),
    ):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)


def test_failure_budget_exhaustion(tmp_path):
    def hook(step):
        raise RuntimeError("permafail")

    t = _trainer(tmp_path, steps=10, fault_hook=hook, max_failures=2)
    with pytest.raises(RuntimeError, match="failure budget"):
        t.train()


def test_preemption_checkpoint_and_exit(tmp_path):
    flag = tmp_path / "preempt"

    def hook(step):
        if step == 7:
            flag.write_text("now")

    out = _trainer(
        tmp_path, steps=50, fault_hook=hook, preempt_file=str(flag)
    ).train()
    assert out["preempted"] is True
    assert out["step"] <= 9
    # a final checkpoint exists at the preemption step
    from repro.checkpoint import latest_step

    assert latest_step(str(tmp_path / "ckpt")) == out["step"]


def test_straggler_detection(tmp_path):
    import time

    def hook(step):
        if step == 20:
            time.sleep(1.0)  # synthetic slow step

    out = _trainer(tmp_path, steps=25, fault_hook=hook).train()
    assert 20 in out["stragglers"]
