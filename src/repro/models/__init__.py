"""Model zoo: layers, attention variants, MoE, SSD, stacks, assembly."""

from .model import (  # noqa: F401
    active_params,
    chunked_ce,
    count_params,
    forward,
    forward_hidden,
    init_model,
    lm_loss,
)
