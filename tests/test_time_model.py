"""Execution-time-model tests: physical bounds, feasibility, monotonicities."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # soft dep: skips, not errors

from repro.core.timemodel import (
    MAXWELL_GPU,
    STENCILS,
    ProblemSize,
    feasible,
    stencil_gflops,
    stencil_time,
)

SIZE2D = ProblemSize(s1=4096, s2=4096, t=1024)
SIZE3D = ProblemSize(s1=512, s2=512, s3=512, t=256)


def _t(st_name, size, n_sm, n_v, m_sm, **sw):
    spec = STENCILS[st_name]
    return float(
        stencil_time(
            spec, MAXWELL_GPU, size, n_sm, n_v, m_sm,
            sw.get("t_s1", 4), sw.get("t_s2", 64), sw.get("t_t", 16),
            sw.get("k", 2), sw.get("t_s3", 1),
        )
    )


def test_infeasible_is_inf():
    # footprint of a 2-array (4+2*64+2)x(1024+2) fp32 tile >> 12 kB
    assert _t("jacobi2d", SIZE2D, 16, 128, 12, t_s2=1024, t_t=64) == np.inf
    # odd t_T violates the hybrid-hexagonal evenness constraint (eq. 15)
    assert _t("jacobi2d", SIZE2D, 16, 128, 96, t_t=15) == np.inf
    # t_S2 not a warp multiple (eq. 13)
    assert _t("jacobi2d", SIZE2D, 16, 128, 96, t_s2=48) == np.inf
    # k beyond MTB_SM (eq. 10)
    assert _t("jacobi2d", SIZE2D, 16, 128, 480, k=64) == np.inf


def test_compute_roofline_never_exceeded():
    """GFLOP/s can never exceed flops_pt * n_SM * n_V / C_iter (lane bound)."""
    spec = STENCILS["jacobi2d"]
    rng = np.random.default_rng(0)
    for _ in range(200):
        n_sm = int(rng.integers(2, 33))
        n_v = int(rng.integers(1, 65)) * 32
        m_sm = float(rng.choice([48, 96, 192, 480]))
        sw = dict(
            t_s1=int(rng.integers(1, 33)),
            t_s2=int(rng.integers(1, 17)) * 32,
            t_t=int(rng.integers(1, 33)) * 2,
            k=int(rng.integers(1, 17)),
        )
        t = _t("jacobi2d", SIZE2D, n_sm, n_v, m_sm, **sw)
        if not np.isfinite(t):
            continue
        g = stencil_gflops(spec, SIZE2D, t)
        bound = spec.flops_per_point * n_sm * n_v / spec.c_iter / 1e9
        assert g <= bound * (1 + 1e-9)


def test_memory_roofline_never_exceeded():
    """Effective DRAM traffic (one footprint per tile) can't beat BW."""
    spec = STENCILS["jacobi2d"]
    # huge compute power so memory is binding
    t = _t("jacobi2d", SIZE2D, 32, 2048, 480, t_s1=8, t_s2=128, t_t=32, k=2)
    assert np.isfinite(t)
    # traffic >= points / (t_T * W * t_S2) tiles * footprint
    from repro.core.timemodel import footprint_bytes

    fp = float(footprint_bytes(spec, MAXWELL_GPU, 8, 128, 32, 1))
    w = 8 + 32
    n_tiles = (SIZE2D.points / (32 * w * 128))
    assert t >= 0.5 * n_tiles * fp / MAXWELL_GPU.bw_gmem  # phase rounding slack


def test_more_sms_never_hurts_much():
    """Scaling coarse parallelism with fixed tiles should not slow down."""
    t8 = _t("jacobi2d", SIZE2D, 8, 128, 96)
    t16 = _t("jacobi2d", SIZE2D, 16, 128, 96)
    t32 = _t("jacobi2d", SIZE2D, 32, 128, 96)
    assert t16 <= t8 * 1.01
    assert t32 <= t16 * 1.01


def test_3d_stencil_runs_and_is_finite():
    t = _t("heat3d", SIZE3D, 16, 128, 192, t_s1=2, t_s2=32, t_t=8, k=1, t_s3=4)
    assert np.isfinite(t) and t > 0
    g = float(stencil_gflops(STENCILS["heat3d"], SIZE3D, t))
    assert 1.0 < g < 1e5


@settings(max_examples=150, deadline=None)
@given(
    n_sm=st.sampled_from([2, 8, 16, 32]),
    n_v=st.sampled_from([32, 128, 512, 2048]),
    m_sm=st.sampled_from([12, 48, 96, 480]),
    t_s1=st.integers(1, 64),
    t_s2=st.sampled_from([32, 64, 128, 256, 512, 1024]),
    t_t=st.sampled_from([2, 4, 8, 16, 32, 64]),
    k=st.integers(1, 32),
)
def test_time_positive_iff_feasible(n_sm, n_v, m_sm, t_s1, t_s2, t_t, k):
    spec = STENCILS["heat2d"]
    ok = bool(
        feasible(spec, MAXWELL_GPU, n_sm, n_v, m_sm, t_s1, t_s2, t_t, k)
    )
    t = float(
        stencil_time(spec, MAXWELL_GPU, SIZE2D, n_sm, n_v, m_sm, t_s1, t_s2, t_t, k)
    )
    if ok:
        assert np.isfinite(t) and t > 0
    else:
        assert t == np.inf


@settings(max_examples=100, deadline=None)
@given(
    t_t=st.sampled_from([2, 4, 8, 16, 32]),
    scale=st.sampled_from([2, 4]),
)
def test_work_scaling(t_t, scale):
    """Property: scaling the time extent scales T_alg ~linearly (same tiles)."""
    small = ProblemSize(s1=2048, s2=2048, t=512)
    big = ProblemSize(s1=2048, s2=2048, t=512 * scale)
    t1 = _t("jacobi2d", small, 16, 128, 96, t_t=t_t)
    t2 = _t("jacobi2d", big, 16, 128, 96, t_t=t_t)
    assert t2 == pytest.approx(t1 * scale, rel=0.02)
