"""Analytic TPU execution-time model for the LM cells -- the `T(p, h, s)`
of the paper's codesign problem, re-grounded on the v5e fleet (DESIGN.md,
"The TPU bridge").

Problem parameters  p: ArchConfig + ShapeSpec (the 40 assigned cells)
Hardware parameters h: mesh factorization (pod, data, model) of the chip
                       budget -- the paper's (n_SM, n_V, M_SM) analogue
Software parameters s: microbatches, remat policy, fsdp on/off,
                       gradient compression -- the paper's tile sizes

The model returns the three roofline terms (seconds/step, per chip) plus
an HBM-fit feasibility flag (the eq. 9/11 analogue: the working set must
fit the per-chip memory budget). Constants are validated against the
dry-run artifacts: `meshopt.optimize` only *proposes*; §Perf re-lowers the
winning plans and measures the real compiled terms.
"""

from __future__ import annotations

import dataclasses
from typing import Dict

from ..configs.base import ArchConfig, ShapeSpec

__all__ = ["MeshPlan", "lm_roofline", "HW"]

#: TPU v5e per-chip constants. Units: ``peak_flops_bf16`` FLOP/s,
#: ``hbm_bw``/``ici_link_bw``/``dci_link_bw`` bytes/s, ``ici_links`` count
#: (the torus gives each chip 4 usable links), ``hbm_bytes`` bytes.
HW = {
    "peak_flops_bf16": 197e12,
    "hbm_bw": 819e9,
    "ici_link_bw": 50e9,
    "ici_links": 4,
    "dci_link_bw": 12.5e9,  # cross-pod (data-center network) per chip
    "hbm_bytes": 16e9,
}


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    """One point in the hardware x software design space.

    Hardware axes (chip-count factorization, ``chips = pod*data*model``):
    ``pod`` pods bridged by DCN, ``data``-way data parallelism within a
    pod, ``model``-way tensor parallelism. Software knobs (the paper's
    tile-size analogue): ``microbatches`` splits the global batch into
    sequential pipeline passes; ``remat`` trades +50% forward FLOPs for a
    4x smaller activation working set when "full"; ``fsdp`` additionally
    shards weights over the data axis (all-gathering them per pass);
    ``compress_grads`` sends int8 (1-byte) instead of f32 gradients in the
    data-parallel all-reduce.
    """

    pod: int
    data: int
    model: int
    microbatches: int = 1
    remat: str = "full"  # none | full
    fsdp: bool = False
    compress_grads: bool = False

    @property
    def chips(self) -> int:
        return self.pod * self.data * self.model

    @property
    def data_shards(self) -> int:
        return self.pod * self.data


def _param_bytes(n_params: int) -> float:
    return 2.0 * n_params  # bf16 storage


def lm_roofline(
    cfg: ArchConfig,
    shape: ShapeSpec,
    plan: MeshPlan,
    n_params: int,
    n_active: int,
) -> Dict:
    """Three analytic roofline terms + feasibility for one design point.

    Args:
        cfg: architecture (only ``d_model``/``n_layers`` enter directly;
            expert sparsity is already folded into ``n_active``).
        shape: workload shape; ``kind`` picks the cost model. For decode,
            "one step" means one token generated per sequence, so the
            compute term scales with ``global_batch`` tokens while the
            memory term streams the full ``seq_len``-deep KV cache.
        plan: mesh factorization + software knobs (see :class:`MeshPlan`).
        n_params: total parameter count (elements, bf16-stored).
        n_active: parameters touched per token (``< n_params`` for MoE).

    Returns a dict of per-step wall-clock seconds — ``compute_s``,
    ``memory_s``, ``collective_s``, their max ``bound_s`` with the
    ``dominant`` term's name — plus the per-chip working set ``hbm_bytes``
    and ``fits`` (True iff it is under 90% of HBM, the eq. 9/11 analogue).
    All terms are smooth in the plan parameters, so a vectorized twin
    (:mod:`repro.core.lmcells`) can evaluate the whole lattice under
    ``jax.vmap``/``jit``.
    """
    chips = plan.chips
    tokens = shape.tokens if shape.kind != "decode" else shape.global_batch
    train = shape.kind == "train"

    # ---- compute ----------------------------------------------------------
    mult = 6.0 if train else 2.0
    flops_total = mult * n_active * tokens
    recompute = 1.0 + (0.5 if (train and plan.remat == "full") else 0.0)
    t_compute = flops_total * recompute / (chips * HW["peak_flops_bf16"])

    # ---- memory -----------------------------------------------------------
    # weights stream per microbatch pass (fwd [+bwd]), sharded over
    # model (x data when fsdp); optimizer state traffic once per step
    passes = (2.0 if train else 1.0) * plan.microbatches
    w_shards = plan.model * (plan.data_shards if plan.fsdp else 1)
    weight_traffic = _param_bytes(n_params) / w_shards * passes
    tokens_local = tokens / plan.data_shards
    act_traffic = 12.0 * tokens_local * cfg.d_model * 2.0 * max(cfg.n_layers, 1)
    opt_traffic = (12.0 * n_params / chips) if train else 0.0
    kv_traffic = 0.0
    if shape.kind == "decode":
        # decode reads the whole cache once per token
        from ..serve.kvcache import cache_bytes

        kv_traffic = cache_bytes(cfg, shape.global_batch, shape.seq_len) / chips
    t_memory = (weight_traffic + act_traffic / 1.0 + opt_traffic + kv_traffic) / HW[
        "hbm_bw"
    ]

    # ---- collectives ------------------------------------------------------
    # TP: 2 all-reduces of the token activations per layer per pass (4 with
    # full-remat backward recompute); ICI bandwidth
    tp_factor = 0.0 if plan.model == 1 else 2.0 * (plan.model - 1) / plan.model
    ar_per_layer = (4.0 if train and plan.remat == "full" else 2.0) * (
        2.0 if train else 1.0
    ) / 2.0
    tp_bytes = (
        ar_per_layer * max(cfg.n_layers, 1) * tokens_local * cfg.d_model * 2.0 * tp_factor
    ) * plan.microbatches
    # DP gradient reduction: once per step over (pod x data); f32 grads
    dp_size = plan.data_shards
    dp_factor = 0.0 if dp_size == 1 or not train else 2.0 * (dp_size - 1) / dp_size
    grad_bytes_unit = 1.0 if plan.compress_grads else 4.0
    dp_bytes = grad_bytes_unit * n_params / plan.model * dp_factor
    # FSDP weight all-gather per microbatch pass
    fsdp_bytes = (
        _param_bytes(n_params) / plan.model * passes if plan.fsdp else 0.0
    )
    ici_bw = HW["ici_links"] * HW["ici_link_bw"]
    # the pod axis rides the slower cross-pod fabric
    pod_fraction = 0.0 if plan.pod == 1 else (plan.pod - 1) / plan.pod
    dci_bytes = dp_bytes * pod_fraction
    ici_bytes = tp_bytes + fsdp_bytes + dp_bytes * (1 - pod_fraction)
    t_coll = ici_bytes / ici_bw + dci_bytes / HW["dci_link_bw"]

    # ---- feasibility (the eq. 9/11 analogue) ------------------------------
    hbm = _param_bytes(n_params) / w_shards
    if train:
        hbm += 12.0 * n_params / chips  # f32 grads+moments, ZeRO over chips
        hbm += 3.0 * (tokens_local / plan.microbatches) * cfg.d_model * 2.0 * max(
            cfg.n_layers, 1
        ) * (1.0 if plan.remat == "full" else 4.0)
    if shape.kind == "decode":
        from ..serve.kvcache import cache_bytes

        hbm += cache_bytes(cfg, shape.global_batch, shape.seq_len) / chips

    terms = {
        "compute_s": t_compute,
        "memory_s": t_memory,
        "collective_s": t_coll,
    }
    dominant = max(terms, key=terms.get)
    return {
        **terms,
        "dominant": dominant.replace("_s", ""),
        "bound_s": terms[dominant],
        "hbm_bytes": hbm,
        "fits": hbm <= HW["hbm_bytes"] * 0.9,
    }
