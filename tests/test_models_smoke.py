"""Per-architecture smoke tests (assignment requirement): a REDUCED config
of the same family runs one forward + one train step on CPU; output shapes
and finiteness asserted. The FULL configs are exercised via the dry-run."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.configs import ARCHS, get_arch
from repro.configs.base import ShapeSpec
from repro.data import DataConfig, make_batch
from repro.models import count_params, forward, init_model
from repro.optim import AdamWConfig
from repro.train import TrainConfig, init_train_state, make_train_step

# multi-second jit compiles: the fast CI lane deselects these (-m "not slow");
# the weekly scheduled lane (and a bare local `pytest`) still runs them
pytestmark = pytest.mark.slow

get_arch("llama3-8b")  # trigger registry
ALL = sorted(ARCHS)
SHAPE = ShapeSpec("tiny", 32, 4, "train")


def _mesh():
    return Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))


@pytest.mark.parametrize("name", ALL)
def test_forward_shapes_and_finite(name):
    cfg = ARCHS[name].reduced()
    params = init_model(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg, SHAPE, DataConfig(), step=0)
    logits, _, ex = forward(params, cfg, batch, want_mtp=cfg.mtp)
    s_out = SHAPE.seq_len + (cfg.n_frontend_tokens if cfg.frontend == "vision" else 0)
    assert logits.shape == (SHAPE.global_batch, s_out, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    if cfg.mtp:
        assert ex["mtp_logits"].shape[1] == s_out - 1


@pytest.mark.parametrize("name", ALL)
def test_one_train_step(name):
    cfg = ARCHS[name].reduced()
    mesh = _mesh()
    tcfg = TrainConfig(
        microbatches=1, remat="dots", opt=AdamWConfig(warmup_steps=2, total_steps=10)
    )
    state = init_train_state(cfg, tcfg, mesh)
    step = make_train_step(cfg, tcfg, mesh)
    batch = make_batch(cfg, SHAPE, DataConfig(), 0, mesh)
    state, metrics = step(state, batch)
    assert np.isfinite(metrics["loss"])
    assert np.isfinite(metrics["grad_norm"]) and float(metrics["grad_norm"]) > 0
    assert int(state["opt"]["step"]) == 1
    # params actually moved
    l0 = jax.tree.leaves(state["params"])[0]
    assert bool(jnp.all(jnp.isfinite(l0)))


@pytest.mark.parametrize("name", ALL)
def test_full_config_param_count_sane(name):
    """eval_shape the FULL config (no allocation) and check param counts
    land in the architecture's nominal class."""
    cfg = ARCHS[name]
    n = count_params(cfg)
    expected = {
        "jamba-v0.1-52b": (45e9, 60e9),
        # whisper: the spec dims with this repo's conventions (gated MLP,
        # 32k learned-pos table for decode_32k, untied head) land ~1.05B
        # vs the original 769M (2-matrix GELU MLP, 448 positions, tied)
        "whisper-medium": (0.25e9, 1.2e9),
        "mamba2-780m": (0.6e9, 1.0e9),
        "minitron-4b": (3.5e9, 6e9),
        "llama3-8b": (7e9, 9e9),
        "internlm2-1.8b": (1.5e9, 2.2e9),
        "gemma-7b": (7.5e9, 10e9),
        "qwen2-vl-2b": (1.2e9, 2.5e9),
        "mixtral-8x22b": (120e9, 160e9),
        "deepseek-v3-671b": (600e9, 720e9),
    }[name]
    assert expected[0] < n < expected[1], f"{name}: {n/1e9:.2f}B"


def test_microbatch_accumulation_matches_single():
    """Grad accumulation is exact: M=2 microbatches == one big batch."""
    cfg = get_arch("internlm2-1.8b").reduced()
    mesh = _mesh()
    opt = AdamWConfig(warmup_steps=0, lr=1e-2)
    batch = make_batch(cfg, SHAPE, DataConfig(), 0, mesh)

    s1 = init_train_state(cfg, TrainConfig(microbatches=1, opt=opt), mesh)
    f1 = make_train_step(cfg, TrainConfig(microbatches=1, opt=opt), mesh)
    s1, m1 = f1(s1, batch)

    s2 = init_train_state(cfg, TrainConfig(microbatches=2, opt=opt), mesh)
    f2 = make_train_step(cfg, TrainConfig(microbatches=2, opt=opt), mesh)
    s2, m2 = f2(s2, batch)

    p1 = jax.tree.leaves(s1["params"])
    p2 = jax.tree.leaves(s2["params"])
    for a, b in zip(p1, p2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-5)
