"""Benchmark suite driver: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines (harness contract).

  bench_area                -- SIII.B-C  area calibration + validation
  bench_pareto              -- Fig. 3    design space + Pareto fronts
  bench_sensitivity         -- Table II  per-stencil optimal architectures
  bench_cache_removal       -- SV.A      cache-less comparison
  bench_resource_allocation -- Fig. 4    area-fraction clustering
  bench_kernels             -- workload  Pallas stencil kernels vs oracle
  bench_meshopt             -- beyond-paper: TPU mesh codesign (eq. 18)
  bench_roofline            -- SRoofline summary from dry-run artifacts
"""

from __future__ import annotations

import sys
import traceback


def main() -> None:
    from . import (
        bench_area,
        bench_cache_removal,
        bench_kernels,
        bench_meshopt,
        bench_pareto,
        bench_resource_allocation,
        bench_roofline,
        bench_sensitivity,
    )

    suites = [
        ("area", bench_area),
        ("pareto", bench_pareto),
        ("sensitivity", bench_sensitivity),
        ("cache_removal", bench_cache_removal),
        ("resource_allocation", bench_resource_allocation),
        ("kernels", bench_kernels),
        ("meshopt", bench_meshopt),
        ("roofline", bench_roofline),
    ]
    only = sys.argv[1] if len(sys.argv) > 1 else None
    failed = []
    print("name,us_per_call,derived")
    for name, mod in suites:
        if only and only != name:
            continue
        try:
            mod.run()
        except Exception:  # noqa: BLE001
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"FAILED suites: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
