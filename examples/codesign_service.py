"""Serving codesign queries from a persisted sweep artifact.

The first run sweeps the paper's Fig.-3 workload once (eq. 18) and writes
the (cells x hardware) optima matrix through the artifact store; every
later run -- and every query in between -- is a warm, engine-free matrix
re-reduction ("sensitivity for free", §V.B).

Run: PYTHONPATH=src python examples/codesign_service.py [--fast]
     (--fast downsamples the hardware space ~8x; store under ./artifacts)
"""

import argparse
import concurrent.futures
import time

from repro.service import ArtifactStore, CodesignServer, QueryRequest

ap = argparse.ArgumentParser()
ap.add_argument("--fast", action="store_true")
ap.add_argument("--store", default="benchmarks/artifacts/service_example")
args = ap.parse_args()

srv = CodesignServer(
    ArtifactStore(args.store), downsample=8 if args.fast else 1
)
print(f"store: {srv.store.root}\nartifact key: {srv.key} "
      f"({'warm' if srv.warm else 'cold: sweeping once'})")

t0 = time.perf_counter()
resp = srv.query(QueryRequest(max_area=450.0, top_k=3))
print(f"\nuniform mix, <=450 mm^2  ({time.perf_counter()-t0:.3f}s):")
for r in resp.top_k:
    print(f"  n_SM={r['n_sm']:3d} n_V={r['n_v']:4d} M_SM={r['m_sm']:4.0f}kB "
          f"area={r['area']:6.1f}  {r['gflops']:8.1f} GFLOP/s")

# 1) arbitrary mixes are one matmul row each
t0 = time.perf_counter()
heavy3d = srv.query(QueryRequest(freqs={"heat3d": 3.0, "laplacian3d": 1.0}))
print(f"\n3D-heavy mix ({(time.perf_counter()-t0)*1e3:.1f} ms): "
      f"best {heavy3d.best_point} @ {heavy3d.best_gflops:.1f} GFLOP/s")

# 2) what-if: freeze a design parameter, read the delta off the response
fixed = srv.query(QueryRequest(fix={"n_sm": 16.0}))
print(f"fix n_SM=16: {fixed.best_gflops:.1f} GFLOP/s "
      f"({fixed.best_gflops - fixed.baseline_best_gflops:+.1f} vs unrestricted)")

# 3) Pareto front of the current mix
front = srv.query(QueryRequest(pareto=True))
print(f"Pareto-optimal designs: {front.pareto_indices.size} of {len(srv.hw)}")

# 4) concurrent callers microbatch into one (B, C) @ (C, H) matmul
mixes = [QueryRequest(freqs={"heat2d": 1.0 + 0.1 * i, "jacobi2d": 1.0})
         for i in range(16)]
t0 = time.perf_counter()
with concurrent.futures.ThreadPoolExecutor(16) as pool:
    list(pool.map(srv.query, mixes))
dt = time.perf_counter() - t0
print(f"16 concurrent queries: {dt*1e3:.1f} ms total, "
      f"max microbatch {srv.stats['max_batch']}")
