#!/usr/bin/env python
"""CI smoke lane for portfolio codesign + routing: real processes/sockets.

End-to-end, through the actual CLI entry points (no test fixtures):

1. build two tiny sweep artifacts (gtx980 + titanx) into one store, then
   a K=2 throughput portfolio over each via ``cli portfolio``;
2. assert each portfolio's persisted fleet objective is >= the best
   single design the same sweep offers under the same budget (the
   "a fleet never loses to one chip" acceptance bound), and that
   rebuilding is a no-op landing on the identical content key;
3. start ``python -m repro.service.cli serve`` as a child process and,
   for every cell group of every portfolio, assert the raw ``/v1/route``
   response bytes over HTTP are **byte-identical** to the in-process
   ``PortfolioServer`` oracle (the acceptance criterion);
4. assert the structured route error paths answer as documented
   (unknown cell -> 404 ``unknown_cell``, a sweep key pinned on
   ``/v1/route`` -> ``wrong_artifact_kind``) without downing the server.

Exit 0 and print PASS only if every check holds.

Usage: python scripts/portfolio_smoke.py [--store DIR] [--downsample N]
"""

from __future__ import annotations

import argparse
import os
import re
import subprocess
import sys
import tempfile

# runnable with or without `pip install -e .` (CI installs; dev may not)
_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
if os.path.isdir(_SRC) and _SRC not in sys.path:
    sys.path.insert(0, _SRC)

import numpy as np  # noqa: E402

from repro.service import ArtifactStore, GatewayClient, wire  # noqa: E402
from repro.service.portfolio import PortfolioServer, RouteRequest  # noqa: E402

CLI = [sys.executable, "-m", "repro.service.cli"]
GPUS = ("gtx980", "titanx")
BUDGET = 900.0


def _env() -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    env["PYTHONUNBUFFERED"] = "1"
    return env


def check(ok: bool, what: str) -> None:
    print(f"  {'ok' if ok else 'FAIL'}: {what}")
    if not ok:
        raise SystemExit(f"portfolio smoke failed at: {what}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--store", default=None, help="store dir (default: temp)")
    ap.add_argument("--downsample", type=int, default=48,
                    help="hw-space thinning for the tiny builds")
    args = ap.parse_args()
    store_root = args.store or tempfile.mkdtemp(prefix="portfolio-smoke-")

    print(f"[1/4] building {len(GPUS)} sweeps + portfolios under {store_root}")
    for gpu in GPUS:
        base = ["--store", store_root, "--gpu", gpu, "--engine", "numpy",
                "--downsample", str(args.downsample)]
        subprocess.run(CLI + ["build"] + base, check=True, env=_env(), timeout=600)
        r = subprocess.run(
            CLI + ["portfolio"] + base
            + ["--k", "2", "--budget", str(BUDGET), "--objective", "throughput"],
            check=True, env=_env(), timeout=600, capture_output=True, text=True,
        )
        check(re.search(r"^portfolio [0-9a-f]{20}: built", r.stdout, re.M)
              is not None, f"cli portfolio built one manifest (gpu={gpu})")
        # deterministic: the second build must land on the same key, stored
        r2 = subprocess.run(
            CLI + ["portfolio"] + base
            + ["--k", "2", "--budget", str(BUDGET), "--objective", "throughput"],
            check=True, env=_env(), timeout=600, capture_output=True, text=True,
        )
        key = re.search(r"^portfolio ([0-9a-f]{20}):", r.stdout, re.M).group(1)
        check(f"portfolio {key}: already stored" in r2.stdout,
              f"rebuild is a stored no-op on the same content key (gpu={gpu})")

    print("[2/4] fleet objective >= best single design, per portfolio")
    store = ArtifactStore(store_root)
    oracles = {}  # gpu -> (PortfolioServer, portfolio key)
    for row in store.entries():
        if row.get("kind") != "portfolio":
            continue
        art = store.get(row["key"])
        sweep = store.get(art.payload["sweep_key"])
        gpu = row["gpu"]
        oracles[gpu] = PortfolioServer(art, sweep)
        # the eq.-18 single-design reduction, straight off the sweep arrays
        freqs = sweep.cell_freqs()
        wt = freqs @ np.asarray(sweep.cell_time, np.float64)
        g = (freqs @ sweep.cell_flops()) / wt / 1.0e9
        best_single = float(np.max(np.where(sweep.hw_area <= BUDGET, g, -np.inf)))
        fleet = float(art.payload["fleet_gflops"])
        check(fleet >= best_single * (1 - 1e-12),
              f"fleet {fleet:.1f} >= single {best_single:.1f} GFLOP/s (gpu={gpu})")
    check(set(oracles) == set(GPUS), f"store holds one portfolio per GPU {GPUS}")

    print("[3/4] starting the gateway; HTTP /v1/route vs in-process oracle")
    proc = subprocess.Popen(
        CLI + ["serve", "--store", store_root, "--port", "0"],
        stdout=subprocess.PIPE, text=True, env=_env(),
    )
    try:
        url = None
        for line in proc.stdout:  # the bound port is printed last
            m = re.search(r"serving on (http://\S+)", line)
            if m:
                url = m.group(1)
                break
        check(url is not None, "serve printed its bound address")
        client = GatewayClient(url)
        n = 0
        for gpu, oracle in oracles.items():
            for cell in oracle.cell_labels():
                req = RouteRequest(cell=cell)
                raw = client.route_bytes(req, route={"gpu": gpu})
                want = wire.encode_route_response(oracle.route(req))
                check(raw == want, f"byte-identical route (gpu={gpu} cell={cell})")
                resp = wire.decode_route_response(raw)
                check(not resp.degraded and resp.hw_index in oracle.members,
                      f"healthy answer from a member design ({gpu}/{cell})")
                n += 1
        check(n >= 2 * len(GPUS), f"routed {n} cell groups over HTTP")

        print("[4/4] structured route error paths")
        try:
            client.route("not-a-cell", route={"gpu": GPUS[0]})
            check(False, "unknown cell must raise")
        except wire.RemoteError as e:
            check(e.code == "unknown_cell" and e.http_status == 404,
                  "unknown cell -> 404 unknown_cell")
        sweep_key = oracles[GPUS[0]].sweep.key
        try:
            client.route("heat2d", artifact=sweep_key)
            check(False, "routing through a sweep key must raise")
        except wire.RemoteError as e:
            check(e.code == "wrong_artifact_kind",
                  "sweep key on /v1/route -> wrong_artifact_kind")
        check(client.health()["ok"], "gateway still healthy after errors")
    finally:
        proc.terminate()
        proc.wait(timeout=30)

    print("PASS: portfolio smoke (build + fleet bound + route byte-identity)")


if __name__ == "__main__":
    main()
