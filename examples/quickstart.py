"""Quickstart: the paper's codesign loop in ~40 lines.

1. characterize a workload (2 stencils x the paper's size grid),
2. enumerate the hardware space under an area budget (eq. 8),
3. solve the per-cell tile-size problems (eq. 18 separability),
4. extract the Pareto front and compare against the stock GTX-980.

Run: PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import MAXWELL, GTX980, codesign, enumerate_hw_space, pareto_front
from repro.core.codesign import evaluate_fixed_hw
from repro.core.workload import paper_workload

wl = paper_workload(["jacobi2d", "heat2d"], name="quickstart")
hw = enumerate_hw_space(MAXWELL, max_area=500.0)
print(f"hardware design space: {len(hw)} feasible points <= 500 mm^2")

res = codesign(wl, hw=hw)
gflops = res.gflops()
area = hw.area

front_a, front_p, idx = pareto_front(area, gflops)
print(f"Pareto-optimal designs: {len(idx)} ({100*len(idx)/len(hw):.1f}% of the space)")

_, stock = evaluate_fixed_hw(wl, GTX980)
best_i, best = res.best(max_area=MAXWELL.area_point(GTX980))
pt = res.hw.point(best_i)
print(f"stock GTX-980 (394.7 mm^2): {stock:8.1f} GFLOP/s")
print(
    f"best codesigned @ <= same area: {best:8.1f} GFLOP/s "
    f"(+{100*(best/stock-1):.0f}%)  n_SM={pt.n_sm} n_V={pt.n_v} M_SM={pt.m_sm:.0f}kB"
)
print("\nPareto front (area mm^2 -> GFLOP/s):")
for a, p in zip(front_a[::max(1, len(front_a)//10)], front_p[::max(1, len(front_p)//10)]):
    print(f"  {a:7.1f} -> {p:8.1f}")
