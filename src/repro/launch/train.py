"""Training launcher: ``python -m repro.launch.train --arch llama3-8b ...``

Runs the fault-tolerant Trainer on the requested mesh. On this CPU
container you will want --mesh 1x1 and a reduced config (--reduced); on a
real fleet the same flags select the production meshes.
"""

from __future__ import annotations

import argparse

import jax
import numpy as np
from jax.sharding import Mesh

from repro.configs import SHAPES
from repro.configs.base import get_arch
from repro.data.pipeline import DataConfig
from repro.optim.adamw import AdamWConfig
from repro.train import TrainConfig, Trainer, TrainerConfig


def parse_mesh(spec: str) -> Mesh:
    dims = tuple(int(x) for x in spec.split("x"))
    axes = {1: ("model",), 2: ("data", "model"), 3: ("pod", "data", "model")}[len(dims)]
    n = int(np.prod(dims))
    return Mesh(np.array(jax.devices()[:n]).reshape(dims), axes)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k", choices=list(SHAPES))
    ap.add_argument("--mesh", default="1x1", help="e.g. 16x16 or 2x16x16")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=0, help="override global batch")
    ap.add_argument("--seq", type=int, default=0, help="override seq len")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default="none")
    ap.add_argument("--fsdp", action="store_true")
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    shape = SHAPES[args.shape]
    mesh = parse_mesh(args.mesh)
    tcfg = TrainConfig(
        microbatches=args.microbatches,
        remat=args.remat,
        fsdp=args.fsdp,
        compress_grads=args.compress_grads,
        opt=AdamWConfig(lr=args.lr, warmup_steps=max(2, args.steps // 20),
                        total_steps=args.steps),
    )
    run = TrainerConfig(
        steps=args.steps, ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
        batch_override=args.batch or None, seq_override=args.seq or None,
    )
    trainer = Trainer(cfg, shape, mesh, tcfg, run, DataConfig(seed=args.seed))
    out = trainer.train()
    last = out["metrics"][-1] if out["metrics"] else {}
    print(
        f"finished step={out['step']} failures={out['failures']} "
        f"stragglers={len(out['stragglers'])} "
        f"loss={last.get('lm_loss', float('nan')):.4f}"
    )


if __name__ == "__main__":
    main()
