"""Pareto-front extraction over (cost, performance) design points (Fig. 3).

A design is Pareto-optimal iff no other design has both lower-or-equal cost
(area) and strictly higher performance. The paper observes only ~1% of the
thousands of feasible designs are Pareto-optimal -- "a nearly 100-fold
savings in design cost".

Tie contract: when several points tie on *every* axis (exact duplicates),
the survivor is the one with the LOWEST original index, and the mask is
invariant under permutation/duplication of the input (the surviving point
set is the same set of (cost, perf) values). Downstream consumers --
portfolio subset enumeration in particular -- rely on this: an unstable
tie-break would make candidate sets, and therefore chosen fleets, depend
on iteration order. Every sort below is explicitly stable to keep the
contract independent of numpy's default (introsort) tie behavior.
"""

from __future__ import annotations

import numpy as np

__all__ = ["pareto_mask", "pareto_mask_batched", "pareto_front"]


def pareto_mask(cost: np.ndarray, perf: np.ndarray) -> np.ndarray:
    """Boolean mask of Pareto-optimal points (minimize cost, maximize perf).

    O(n log n): sweep by ascending cost, keep the running best performance.
    Ties on cost keep only the best-performing point; full duplicates keep
    the lowest-index copy (``np.lexsort`` is stable, so equal keys preserve
    original order and the scan admits only the first).
    """
    cost = np.asarray(cost, np.float64).ravel()
    perf = np.asarray(perf, np.float64).ravel()
    if cost.shape != perf.shape:
        raise ValueError("cost/perf shape mismatch")
    n = cost.shape[0]
    mask = np.zeros(n, dtype=bool)
    finite = np.isfinite(cost) & np.isfinite(perf)
    idx = np.nonzero(finite)[0]
    if idx.size == 0:
        return mask
    # sort by (cost asc, perf desc) so equal-cost groups see their best first
    order = idx[np.lexsort((-perf[idx], cost[idx]))]
    best = -np.inf
    for i in order:
        if perf[i] > best:
            mask[i] = True
            best = perf[i]
    return mask


def pareto_mask_batched(cost: np.ndarray, perf: np.ndarray) -> np.ndarray:
    """Row-wise :func:`pareto_mask` for B perf vectors sharing one cost axis.

    ``cost`` is ``(H,)``, ``perf`` is ``(B, H)``; returns a ``(B, H)`` bool
    mask identical row-by-row to ``pareto_mask(cost, perf[b])``. The shared
    cost axis is the codesign-service case (one hardware space, many
    frequency mixes), and it is what makes the batch vectorizable: cost is
    sorted once and the per-row scan collapses to a running-max over
    equal-cost segments (``maximum.reduceat`` + ``maximum.accumulate``),
    with no Python loop over B or H.
    """
    cost = np.asarray(cost, np.float64).ravel()
    perf = np.atleast_2d(np.asarray(perf, np.float64))
    if perf.shape[1] != cost.shape[0]:
        raise ValueError("cost/perf shape mismatch")
    b, n = perf.shape
    mask = np.zeros((b, n), dtype=bool)
    usable_cost = np.isfinite(cost)
    idx = np.nonzero(usable_cost)[0]
    if idx.size == 0:
        return mask
    order = idx[np.argsort(cost[idx], kind="stable")]  # cost asc, stable
    cs = cost[order]
    ps = perf[:, order]  # (B, K)
    ps = np.where(np.isfinite(ps), ps, -np.inf)  # per-row non-finite perf
    # equal-cost segments: within a segment only the best perf can win
    seg_start = np.nonzero(np.r_[True, cs[1:] != cs[:-1]])[0]
    seg_id = np.cumsum(np.r_[False, cs[1:] != cs[:-1]])
    seg_max = np.maximum.reduceat(ps, seg_start, axis=1)  # (B, S)
    # running best over *previous* segments (exclusive cumulative max)
    run = np.maximum.accumulate(seg_max, axis=1)
    prev = np.concatenate(
        [np.full((b, 1), -np.inf), run[:, :-1]], axis=1
    )  # (B, S)
    seg_wins = seg_max > prev[:, : seg_max.shape[1]]
    # the winner inside a segment is the FIRST position achieving seg_max
    # (stable cost sort keeps original index order, matching pareto_mask's
    # lexsort tie-breaking); np.maximum.reduceat has no arg variant, so
    # find it with a segment-local == scan.
    is_max = ps == seg_max[:, seg_id]
    first_hit = np.zeros_like(is_max)
    # positions where is_max first becomes True within each segment:
    csum = np.cumsum(is_max, axis=1)
    seg_base = np.concatenate(
        [np.zeros((b, 1), csum.dtype), csum[:, seg_start[1:] - 1]], axis=1
    )  # cumulative hits before each segment
    first_hit = is_max & (csum - seg_base[:, seg_id] == 1)
    winners = first_hit & seg_wins[:, seg_id] & np.isfinite(ps)
    rows, cols = np.nonzero(winners)
    mask[rows, order[cols]] = True
    return mask


def pareto_front(cost: np.ndarray, perf: np.ndarray):
    """(sorted_cost, sorted_perf, indices) of the Pareto-optimal points.

    Survivor costs are strictly increasing (equal-cost groups keep one
    point), but the sort is stable anyway so the lowest-index tie contract
    cannot silently regress if that invariant ever loosens.
    """
    mask = pareto_mask(cost, perf)
    idx = np.nonzero(mask)[0]
    order = np.argsort(np.asarray(cost)[idx], kind="stable")
    idx = idx[order]
    return np.asarray(cost)[idx], np.asarray(perf)[idx], idx
