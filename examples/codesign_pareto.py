"""Reproduce Fig. 3 (design-space exploration + Pareto fronts) and the
§V.B workload-sensitivity analysis -- full 6-stencil workload.

Run: PYTHONPATH=src python examples/codesign_pareto.py [--fast] [--engine E]
(--fast subsamples the hardware space ~4x for a quicker demo; --engine
picks the eq.-18 inner solver: auto (default), jax, or numpy.)
"""

import argparse
import time

import numpy as np

from repro.core import GTX980, MAXWELL, TITAN_X, codesign, enumerate_hw_space
from repro.core.codesign import evaluate_fixed_hw
from repro.core.pareto import pareto_mask
from repro.core.workload import paper_workload

ap = argparse.ArgumentParser()
ap.add_argument("--fast", action="store_true")
ap.add_argument(
    "--engine", choices=("auto", "jax", "sharded", "numpy"), default="auto"
)
args = ap.parse_args()

for cls, names in (
    ("2D", ["jacobi2d", "heat2d", "laplacian2d", "gradient2d"]),
    ("3D", ["heat3d", "laplacian3d"]),
):
    wl = paper_workload(names, name=f"paper-{cls}")
    hw = enumerate_hw_space(MAXWELL, max_area=650.0)
    if args.fast:
        hw = hw.downsample(4)
    t0 = time.perf_counter()
    res = codesign(wl, hw=hw, engine=args.engine)
    print(f"[{cls}] eq.-18 sweep ({args.engine}): {time.perf_counter()-t0:.1f}s")
    g = res.gflops()
    mask = pareto_mask(hw.area, g)
    print(f"\n=== {cls} stencils: {len(hw)} feasible designs ===")
    print(f"Pareto-optimal: {mask.sum()} ({100*mask.sum()/len(hw):.1f}%)")

    for name, point in (("GTX-980", GTX980), ("Titan X", TITAN_X)):
        _, stock = evaluate_fixed_hw(wl, point)
        a = MAXWELL.area_point(point)
        i, best = res.best(max_area=a)
        print(
            f"{name:8s} stock {stock:7.1f} GFLOP/s @ {a:.0f} mm^2 | "
            f"codesigned {best:7.1f} (+{100*(best/stock-1):.0f}%) "
            f"-> {res.hw.point(i)}"
        )

    # §V.B: per-stencil optima for free (re-weighting cached cell times)
    print("workload sensitivity (Table II analogue, 425-450 mm^2):")
    cells = list(wl.cells)
    for name in names:
        freqs = np.array(
            [1.0 / 16 if c.stencil.name == name else 0.0 for c in cells]
        )
        gs = res.gflops(freqs)
        gs = np.where((hw.area >= 425) & (hw.area <= 450), gs, -np.inf)
        i = int(np.argmax(gs))
        p = res.hw.point(i)
        print(
            f"  {name:12s} n_SM={p.n_sm:3d} n_V={p.n_v:4d} M_SM={p.m_sm:4.0f}kB "
            f"area={hw.area[i]:5.1f} {gs[i]:8.1f} GFLOP/s"
        )
