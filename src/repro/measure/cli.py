"""Command-line front end for the measure -> fit -> serve loop.

    # 1. run the Pallas tile kernels over a measurement grid, persist the
    #    timings as a content-addressed `kind: "measurement"` artifact
    python -m repro.measure.cli run --store /tmp/fleet --smoke

    # 2. refit the time model's machine parameters from a measurement run
    #    (or --synthetic: model-generated timings, the CI recovery check),
    #    persist as `kind: "calibration"`
    python -m repro.measure.cli fit --store /tmp/fleet --measurement <KEY>

    # 3. solve the eq.-18 sweep on the CALIBRATED hardware description and
    #    store it; the fleet gateway then routes queries against it via
    #    route={"calibration": <KEY>} or {"gpu": "gtx980-cal"}
    python -m repro.measure.cli build --store /tmp/fleet --calibration <KEY>

Full walkthrough: ``docs/calibration.md``. The store layout/locking is
the same :class:`repro.service.store.ArtifactStore` the query service
uses, so `python -m repro.service.cli ls|serve` see measurement and
calibration artifacts alongside sweeps.
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from typing import Optional

from repro.service.cli import DEFAULT_STORE, _die, _gpu, _gpu_names
from repro.service.store import Artifact, ArtifactStore


def _latest(store: ArtifactStore, kind: str) -> Optional[Artifact]:
    """Most recently written artifact of a kind: stat mtimes first, then
    parse manifests newest-first and stop at the first match (a fleet
    store holds hundreds of sweeps whose manifests we must not parse just
    to pick the newest measurement)."""
    import os

    def mtime(key: str) -> float:
        try:
            return os.path.getmtime(os.path.join(store.root, key, "manifest.json"))
        except OSError:
            return -1.0

    for key in sorted(store.keys(), key=mtime, reverse=True):
        art = store.get(key)
        if art is not None and art.kind == kind:
            return art
    return None


def _resolve(store: ArtifactStore, key: Optional[str], kind: str) -> Artifact:
    if key:
        art = store.get(key)
        if art is None:
            raise _die(f"no artifact {key!r} under {store.root}")
        if art.kind != kind:
            raise _die(f"artifact {key} is kind={art.kind!r}, expected {kind!r}")
        return art
    art = _latest(store, kind)
    if art is None:
        raise _die(
            f"no {kind} artifact under {store.root}; run "
            f"`python -m repro.measure.cli "
            f"{'run' if kind == 'measurement' else 'fit'}` first"
        )
    return art


def cmd_run(args) -> None:
    from .harness import default_grid, measure_grid

    store = ArtifactStore(args.store)
    gpu = _gpu(args.gpu)
    grid = default_grid(smoke=not args.full, gpu=gpu)
    t0 = time.perf_counter()
    run = measure_grid(
        grid, warmup=args.warmup, repeats=args.repeats, gpu=gpu, note=args.note
    )
    dt = time.perf_counter() - t0
    art = store.put_json(
        "measurement",
        run.to_payload(),
        routing={
            "gpu": gpu.name,
            "stencils": sorted(run.stencil_names()),
            "backend": run.backend,
            "interpret": run.interpret,
            "records": len(run.records),
        },
    )
    print(
        f"measurement {art.key}: {len(run.records)} records "
        f"({dt:.1f}s, backend={run.backend}, interpret={run.interpret}, "
        f"gpu frame={gpu.name})"
    )


def cmd_fit(args) -> None:
    import dataclasses

    from repro.core.timemodel import STENCILS, with_c_iter, with_machine_params

    from .calibrate import CalibrationResult, fit_machine_params, synthetic_records
    from .harness import MeasurementRun

    store = ArtifactStore(args.store)
    extra = {}
    if args.synthetic:
        gpu0 = _gpu(args.gpu or "gtx980")
        # generate from a machine --perturb away from the datasheet start:
        # the fit must travel back to it (recovery, not mere stability).
        # Bandwidth is perturbed DOWN: a slower-than-datasheet memory
        # system binds (t_mem wins the max) on part of the grid, keeping
        # bw identifiable -- a faster one can stop binding anywhere, and
        # an unidentifiable parameter has no recovery to assert.
        p = float(args.perturb)
        truth_gpu = with_machine_params(
            gpu0,
            bw_gmem=gpu0.bw_gmem / (1.0 + p),
            launch_overhead=gpu0.launch_overhead * (1.0 + 0.5 * p),
        )
        truth_st = {
            n: with_c_iter(st, st.c_iter * (1.0 + p * (i + 1) / len(STENCILS)))
            for i, (n, st) in enumerate(STENCILS.items())
        }
        run = synthetic_records(truth_gpu, truth_st, seed=args.seed)
        source = "synthetic"
        extra["synthetic_truth"] = {
            "gpu": dataclasses.asdict(truth_gpu),
            "stencils": {n: dataclasses.asdict(st) for n, st in truth_st.items()},
        }
    else:
        meas = _resolve(store, args.measurement, "measurement")
        run = MeasurementRun.from_payload(meas.payload)
        source = meas.key
        # default to the GPU family the measurement itself was framed
        # against -- fitting a titanx run from the gtx980 datasheet (and
        # routing the calibration as gtx980) must require an explicit ask
        gpu0 = _gpu(args.gpu or run.gpu_name)
    t0 = time.perf_counter()
    cal: CalibrationResult = fit_machine_params(
        run, gpu0=gpu0, iters=args.iters, learning_rate=args.lr
    )
    dt = time.perf_counter() - t0
    art = store.put_json(
        "calibration",
        cal.to_payload(),
        routing={
            "gpu": gpu0.name,
            "calibrated_gpu": cal.calibrated_gpu().name,
            "measurement": source,
            "stencils": sorted(cal.stencils),
        },
        extra={"fit_seconds": round(dt, 3), **extra},
    )
    print(f"calibration {art.key} (fit {dt:.1f}s on {cal.n_records} records, "
          f"{cal.n_dropped} dropped as model-infeasible; source={source})")
    print(f"  mean sq log residual: {cal.loss_before:.4g} -> {cal.loss_after:.4g}")
    print(f"  bw_gmem: {cal.gpu0.bw_gmem:.3e} -> {cal.gpu.bw_gmem:.3e} B/s")
    print(f"  launch:  {cal.gpu0.launch_overhead:.2e} -> "
          f"{cal.gpu.launch_overhead:.2e} s")
    for name in sorted(cal.stencils):
        print(
            f"  {name:12s} C_iter {cal.stencils[name].c_iter:.3e}  "
            f"|rel err| {cal.errors_before.get(name, float('nan')):7.2%}"
            f" -> {cal.errors_after.get(name, float('nan')):7.2%}"
        )
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(cal.to_payload(), f, indent=1)
        print(f"  report written to {args.json_out}")


def cmd_build(args) -> None:
    from repro.core.codesign import codesign, enumerate_hw_space

    from .calibrate import CalibrationResult

    store = ArtifactStore(args.store)
    cal_art = _resolve(store, args.calibration, "calibration")
    cal = CalibrationResult.from_payload(cal_art.payload)
    workload = cal.calibrated_workload()
    gpu = cal.calibrated_gpu()
    hw = enumerate_hw_space(max_area=args.max_hw_area)
    if args.downsample > 1:
        hw = hw.downsample(args.downsample)
    t0 = time.perf_counter()
    result = codesign(workload, gpu=gpu, hw=hw, engine=args.engine)
    art = store.put(
        result,
        engine=args.engine,
        routing_extra={"calibration": cal_art.key},
        extra={"calibration": cal_art.key},
    )
    print(
        f"calibrated sweep {art.key}: {len(workload.cells)} cells x "
        f"{len(hw)} hw points on gpu={gpu.name} "
        f"({time.perf_counter()-t0:.1f}s); route with "
        f'{{"calibration": "{cal_art.key}"}} or {{"gpu": "{gpu.name}"}}'
    )


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        prog="repro.measure.cli", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    sub = ap.add_subparsers(dest="cmd", required=True)

    r = sub.add_parser("run", help="time the Pallas tile kernels over a grid")
    r.add_argument("--store", default=DEFAULT_STORE)
    r.add_argument("--gpu", choices=_gpu_names(), default="gtx980",
                   help="GPU family whose constants frame the fit")
    r.add_argument("--full", action="store_true",
                   help="full grid (default: smoke grid sized for CI)")
    r.add_argument("--warmup", type=int, default=1)
    r.add_argument("--repeats", type=int, default=3)
    r.add_argument("--note", default="")
    r.set_defaults(fn=cmd_run)

    f = sub.add_parser("fit", help="refit machine parameters from a run")
    f.add_argument("--store", default=DEFAULT_STORE)
    f.add_argument("--gpu", choices=_gpu_names(), default=None,
                   help="datasheet family to start the fit from (default: "
                        "the measurement run's own GPU frame)")
    f.add_argument("--measurement", default=None, metavar="KEY",
                   help="measurement artifact (default: most recent)")
    f.add_argument("--synthetic", action="store_true",
                   help="fit model-generated timings instead (recovery check)")
    f.add_argument("--perturb", type=float, default=0.5,
                   help="with --synthetic: relative distance of the "
                        "generating machine from the datasheet start")
    f.add_argument("--seed", type=int, default=0)
    f.add_argument("--iters", type=int, default=1500)
    f.add_argument("--lr", type=float, default=0.05)
    f.add_argument("--json-out", default=None, metavar="FILE",
                   help="also write the calibration payload to FILE")
    f.set_defaults(fn=cmd_fit)

    b = sub.add_parser(
        "build", help="sweep on the calibrated hardware and store the artifact"
    )
    b.add_argument("--store", default=DEFAULT_STORE)
    b.add_argument("--calibration", default=None, metavar="KEY",
                   help="calibration artifact (default: most recent)")
    b.add_argument("--max-hw-area", type=float, default=650.0)
    b.add_argument("--downsample", type=int, default=1)
    b.add_argument(
        "--engine", choices=("auto", "jax", "sharded", "numpy"), default="auto"
    )
    b.set_defaults(fn=cmd_build)

    args = ap.parse_args(argv)
    args.fn(args)
    sys.stdout.flush()


if __name__ == "__main__":
    main()
