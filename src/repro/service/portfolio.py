"""Portfolio artifacts + heterogeneity-aware routing (the serving half of
:mod:`repro.core.portfolio`).

* :func:`build_portfolio` optimizes a fleet over a stored sweep artifact
  and persists the decision as a ``kind: "portfolio"`` manifest-only
  artifact: members (hw indices into the sweep), the one-hot traffic
  assignment matrix, per-cell-group routing tables, and the content key
  of the underlying sweep -- all canonical JSON, so the same
  optimization always produces the same bytes and content key.
* :class:`PortfolioServer` answers :class:`RouteRequest` s: "which
  design serves cell X?" resolves through the persisted assignment to a
  member design, and the answer's numbers (per-unit-traffic time,
  GFLOP/s) are recomputed from the *sweep artifact's matrix at serve
  time* -- live store reads, so member health is a real runtime
  property, not a build-time constant.
* Degraded routing: each member read runs under that member's circuit
  breaker (key ``{portfolio_key}:{hw_index}``) and a deterministic
  fault-injection point ``route.member.{hw_index}``. A failing/broken
  member falls back to the cell's next-preferred member with a
  structured ``degraded: true`` marker (the skipped members ride along
  in ``fallback_from``); only when EVERY member of a cell's preference
  list is down does the route fail -- structured 503
  ``portfolio_exhausted``, never a 500.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Mapping, Optional, Tuple, Union

import numpy as np

from repro.core.portfolio import PortfolioResult, optimize_portfolio_arrays

from . import faults
from .errors import ERROR_HTTP_STATUS, GatewayError
from .resilience import CircuitOpenError, GatewayResilience, check_deadline
from .store import Artifact, ArtifactStore

__all__ = [
    "PortfolioServer",
    "RouteRequest",
    "RouteResponse",
    "UnknownCellError",
    "PortfolioExhaustedError",
    "build_portfolio",
]


class UnknownCellError(GatewayError):
    """The route request named a workload cell the portfolio's sweep does
    not carry (HTTP 404; the message lists the known labels)."""

    code = "unknown_cell"
    http_status = ERROR_HTTP_STATUS["unknown_cell"]


class PortfolioExhaustedError(GatewayError):
    """Every member design in the cell's preference order is failing (all
    breakers open / all reads raising). The fleet is degraded beyond
    this portfolio's redundancy -- retry later (HTTP 503)."""

    code = "portfolio_exhausted"
    http_status = ERROR_HTTP_STATUS["portfolio_exhausted"]

    retry_after_s: float = 1.0


@dataclass(frozen=True)
class RouteRequest:
    """``POST /v1/route`` body: which design serves this workload cell?

    ``cell`` is a cell-group label exactly as sweep artifacts expose
    them: a stencil name (``"heat2d"``) or ``"model:op"`` for LM sweeps
    (``"llama3_8b:decode"``).
    """

    cell: str


@dataclass(frozen=True)
class RouteResponse:
    """The routing decision for one cell, plus serve-time numbers read
    from the member's reduction row of the underlying sweep."""

    portfolio_key: str
    sweep_key: str
    cell: str
    cell_indices: Tuple[int, ...]  # sweep cell rows in this group
    hw_index: int  # the member design actually serving the cell
    member_slot: int  # its slot in the portfolio's member list
    point: Dict[str, float]  # design parameters of hw_index
    time_s: float  # per-unit-traffic weighted time on that design
    gflops: float
    degraded: bool  # True iff preferred member(s) were skipped
    fallback_from: Tuple[int, ...] = field(default_factory=tuple)


def _group_cells(sweep: Artifact) -> "Dict[str, List[int]]":
    """Cell-group label -> sweep cell rows, in stored cell order (the
    same labels :attr:`Artifact.cell_labels` reports)."""
    cells = sweep.manifest["workload"]["cells"]
    groups: Dict[str, List[int]] = {}
    for i, c in enumerate(cells):
        if sweep.family == "lm":
            label = f"{c['model']}:{c['op']}"
        else:
            label = c["stencil"]["name"]
        groups.setdefault(label, []).append(i)
    return groups


def build_portfolio(
    store: ArtifactStore,
    sweep: Union[Artifact, str],
    k: int,
    budget: float,
    freqs: Optional[np.ndarray] = None,
    *,
    objective: str = "density",
    engine: str = "numpy",
) -> Tuple[Artifact, PortfolioResult]:
    """Optimize a K-design fleet over a stored sweep and persist it.

    Returns ``(portfolio_artifact, PortfolioResult)``. The payload is
    pure canonical JSON over the optimization *decision* (members,
    assignment, per-group routing) plus the sweep's content key; the
    matrix itself stays in the sweep artifact, which routing re-reads at
    serve time. Identical inputs dedupe to the same content key.
    """
    if isinstance(sweep, str):
        art = store.get(sweep)
        if art is None:
            raise KeyError(f"no stored sweep artifact {sweep!r} in {store.root}")
        sweep = art
    if sweep.kind != "sweep":
        raise ValueError(
            f"portfolios are built over sweep artifacts, got kind {sweep.kind!r}"
        )
    f = sweep.cell_freqs() if freqs is None else np.asarray(freqs, np.float64)
    result = optimize_portfolio_arrays(
        sweep.hw_area,
        sweep.cell_time,
        sweep.cell_flops(),
        f,
        k,
        budget,
        objective=objective,
        engine=engine,
    )
    times = np.asarray(sweep.cell_time, np.float64)
    groups = []
    for label, cells in _group_cells(sweep).items():
        # the group's routed member: the member slot serving the largest
        # share of the group's traffic (freq-weighted vote over the
        # per-cell one-hot assignment; np.argmax ties -> lowest slot)
        shares = result.assignment[cells].T @ result.freqs[cells]
        slot = int(np.argmax(shares))
        # fallback order: member slots by the group's weighted time,
        # fastest first (stable sort -> lowest slot on exact ties)
        member_time = times[np.ix_(cells, list(result.members))].T @ result.freqs[cells]
        preference = [int(s) for s in np.argsort(member_time, kind="stable")]
        groups.append(
            {
                "label": label,
                "cells": [int(c) for c in cells],
                "slot": slot,
                "preference": preference,
            }
        )
    payload = {
        **result.payload(),
        "sweep_key": sweep.key,
        "groups": groups,
    }
    sweep_routing = sweep.routing()
    routing = {
        k_: sweep_routing[k_]
        for k_ in ("gpu", "workload", "family", "stencils", "models", "ops")
        if k_ in sweep_routing
    }
    routing.update(sweep_key=sweep.key, members=[int(m) for m in result.members])
    artifact = store.put_json("portfolio", payload, routing=routing)
    return artifact, result


class PortfolioServer:
    """In-process route oracle over one portfolio artifact.

    The gateway pools these exactly like :class:`CodesignServer` s; tests
    use them directly as the byte-identity reference. ``resilience``
    supplies the per-member circuit breakers (None disables breakers --
    faults then surface as immediate fallback, still never a 500).
    """

    def __init__(
        self,
        artifact: Artifact,
        sweep: Artifact,
        resilience: Optional[GatewayResilience] = None,
    ):
        if artifact.kind != "portfolio":
            raise ValueError(
                f"PortfolioServer wants a portfolio manifest, got {artifact.kind!r}"
            )
        p = artifact.payload
        if sweep.key != p["sweep_key"]:
            raise ValueError(
                f"sweep artifact {sweep.key!r} is not this portfolio's member "
                f"sweep {p['sweep_key']!r}"
            )
        self.artifact = artifact
        self.sweep = sweep
        self.key: str = artifact.key
        self.resilience = resilience
        self.members: List[int] = [int(m) for m in p["members"]]
        self.freqs = np.asarray(p["freqs"], np.float64)
        self._groups: Dict[str, Dict[str, Any]] = {
            g["label"]: g for g in p["groups"]
        }

    def cell_labels(self) -> List[str]:
        return list(self._groups)

    def _member_read(self, cells: List[int], hw: int) -> np.ndarray:
        """The member's reduction rows for a cell group, read from the
        sweep artifact's (mmap-backed) matrix -- the serve-time store
        access that breakers and fault injection guard."""
        faults.fire(f"route.member.{hw}")
        check_deadline("route.member")
        return np.asarray(self.sweep.cell_time[cells, hw], np.float64)

    def route(self, request: RouteRequest) -> RouteResponse:
        group = self._groups.get(request.cell)
        if group is None:
            known = ", ".join(sorted(self._groups))
            raise UnknownCellError(
                f"portfolio {self.key!r} serves no cell {request.cell!r} "
                f"(known cells: {known})"
            )
        cells: List[int] = list(group["cells"])
        f = self.freqs[cells]
        fsum = float(f.sum())
        weights = f / fsum if fsum > 0 else np.full(len(cells), 1.0 / len(cells))
        numer = float(weights @ np.asarray(self.sweep.cell_flops())[cells])
        # the assigned member first, then the group's fallback preference
        order = [int(group["slot"])] + [
            int(s) for s in group["preference"] if int(s) != int(group["slot"])
        ]
        fallback_from: List[int] = []
        res = self.resilience
        for slot in order:
            hw = self.members[slot]
            breaker = res.breaker(f"{self.key}:{hw}") if res is not None else None
            try:
                if breaker is not None:
                    with breaker.call():
                        rows = self._member_read(cells, hw)
                else:
                    rows = self._member_read(cells, hw)
            except CircuitOpenError:
                fallback_from.append(hw)
                continue
            except GatewayError:
                raise  # deadlines etc. classify for the whole request
            except Exception:  # noqa: BLE001 - a failing member is routed
                # around, not surfaced: degraded beats unavailable
                fallback_from.append(hw)
                continue
            time_s = float(weights @ rows)
            return RouteResponse(
                portfolio_key=self.key,
                sweep_key=self.sweep.key,
                cell=request.cell,
                cell_indices=tuple(cells),
                hw_index=int(hw),
                member_slot=int(slot),
                point=self.sweep.point(hw),
                time_s=time_s,
                gflops=float(numer / time_s / 1.0e9),
                degraded=bool(fallback_from),
                fallback_from=tuple(fallback_from),
            )
        raise PortfolioExhaustedError(
            f"every member design of portfolio {self.key!r} failed for cell "
            f"{request.cell!r} (tried hw indices {fallback_from})"
        )
