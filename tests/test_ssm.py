"""Mamba2 SSD: chunked scan == naive recurrence; decode streaming == batch."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # soft dep: skips, not errors

from repro.configs import get_arch
from repro.models.ssm import (
    _ssd_chunked,
    ssd_reference,
    ssm_apply,
    ssm_init,
    ssm_state_shapes,
)


def _rand_ssd(b=2, l=48, h=4, p=8, n=16, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    xdt = jax.random.normal(ks[0], (b, l, h, p), jnp.float32) * 0.5
    dta = -jax.nn.softplus(jax.random.normal(ks[1], (b, l, h), jnp.float32))
    bm = jax.random.normal(ks[2], (b, l, h, n), jnp.float32) * 0.3
    cm = jax.random.normal(ks[3], (b, l, h, n), jnp.float32) * 0.3
    return xdt, dta, bm, cm


@pytest.mark.parametrize("chunk", [4, 8, 16, 48, 64])
def test_chunked_matches_reference(chunk):
    xdt, dta, bm, cm = _rand_ssd()
    y_ref, s_ref = ssd_reference(xdt, dta, bm, cm)
    y, s = _ssd_chunked(xdt, dta, bm, cm, chunk, None)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref), rtol=2e-4, atol=2e-4)


def test_chunked_with_initial_state():
    xdt, dta, bm, cm = _rand_ssd(seed=1)
    s0 = jax.random.normal(jax.random.PRNGKey(9), (2, 4, 8, 16), jnp.float32) * 0.2
    y_ref, s_ref = ssd_reference(xdt, dta, bm, cm, s0)
    y, s = _ssd_chunked(xdt, dta, bm, cm, 16, s0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref), rtol=2e-4, atol=2e-4)


def test_block_prefill_then_decode_matches_full():
    """Streaming the block one token at a time == one full-sequence pass."""
    cfg = get_arch("mamba2-780m").reduced()
    params = ssm_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    b, s = 2, 24
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, cfg.d_model), jnp.float32) * 0.3

    y_full, _ = ssm_apply(params, cfg, x, cache=None)

    shapes = ssm_state_shapes(cfg, b)
    cache = {k: jnp.zeros(v, jnp.float32) for k, v in shapes.items()}
    split = 11
    y_pre, cache = ssm_apply(params, cfg, x[:, :split], cache=cache)
    ys = [y_pre]
    for t in range(split, s):
        yt, cache = ssm_apply(params, cfg, x[:, t : t + 1], cache=cache)
        ys.append(yt)
    y_stream = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(
        np.asarray(y_stream), np.asarray(y_full), rtol=5e-4, atol=5e-4
    )


def test_seq_not_multiple_of_chunk():
    cfg = dataclasses.replace(get_arch("mamba2-780m").reduced())
    params = ssm_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 19, cfg.d_model), jnp.float32)
    y, _ = ssm_apply(params, cfg, x)
    assert y.shape == x.shape
    assert np.all(np.isfinite(np.asarray(y)))


@settings(max_examples=20, deadline=None)
@given(
    l=st.integers(2, 40),
    chunk=st.sampled_from([2, 4, 8, 16]),
    seed=st.integers(0, 100),
)
def test_property_chunk_invariance(l, chunk, seed):
    """The chunk size is a pure performance knob -- results must not move."""
    xdt, dta, bm, cm = _rand_ssd(b=1, l=l, h=2, p=4, n=8, seed=seed)
    y_ref, s_ref = ssd_reference(xdt, dta, bm, cm)
    y, s = _ssd_chunked(xdt, dta, bm, cm, chunk, None)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref), rtol=5e-4, atol=5e-4)
