"""Per-kernel allclose vs the pure-jnp oracle: shape/dtype sweeps +
hypothesis property tests. Kernels run in interpret mode on CPU (TPU is the
compile target; interpret executes the same kernel body)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # soft dep: skips, not errors

from repro.kernels.ops import KERNELS, kernel_flops, stencil_run, stencil_step
from repro.kernels.ref import run_ref
from repro.kernels.stencil_common import plan_block_rows

NAMES_2D = ["jacobi2d", "heat2d", "laplacian2d", "gradient2d"]
NAMES_3D = ["heat3d", "laplacian3d"]

SHAPES_2D = [(8, 130), (16, 128), (33, 257), (64, 64), (128, 384), (5, 7)]
SHAPES_3D = [(8, 16, 130), (12, 12, 12), (17, 9, 33), (32, 16, 128)]


def _rand(shape, dtype, seed=0):
    x = jax.random.normal(jax.random.PRNGKey(seed), shape, dtype=jnp.float32)
    return x.astype(dtype)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("name", NAMES_2D)
@pytest.mark.parametrize("shape", SHAPES_2D)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_2d_kernels_match_oracle(name, shape, dtype):
    x = _rand(shape, dtype)
    got = stencil_step(name, x, interpret=True)
    want = run_ref(name, x)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **_tol(dtype)
    )


@pytest.mark.parametrize("name", NAMES_3D)
@pytest.mark.parametrize("shape", SHAPES_3D)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_3d_kernels_match_oracle(name, shape, dtype):
    x = _rand(shape, dtype)
    got = stencil_step(name, x, interpret=True)
    want = run_ref(name, x)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **_tol(dtype)
    )


@pytest.mark.parametrize("name", ["jacobi2d", "heat3d"])
@pytest.mark.parametrize("block_rows", [1, 2, 3, 5, 8, 64])
def test_block_size_invariance(name, block_rows):
    """Property: the tiling is semantics-preserving for any band height."""
    shape = (19, 33) if KERNELS[name].DIMS == 2 else (11, 9, 17)
    x = _rand(shape, jnp.float32, seed=3)
    got = stencil_step(name, x, block_rows=block_rows, interpret=True)
    want = run_ref(name, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("name", list(KERNELS))
def test_multi_step_run(name):
    shape = (24, 40) if KERNELS[name].DIMS == 2 else (10, 12, 14)
    x = _rand(shape, jnp.float32, seed=1)
    got = stencil_run(name, x, steps=4, interpret=True)
    want = run_ref(name, x, steps=4)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5)
    assert not np.any(np.isnan(np.asarray(got)))


def test_borders_are_dirichlet():
    x = _rand((16, 24), jnp.float32, seed=2)
    y = stencil_step("jacobi2d", x, interpret=True)
    np.testing.assert_array_equal(np.asarray(y[0]), np.asarray(x[0]))
    np.testing.assert_array_equal(np.asarray(y[-1]), np.asarray(x[-1]))
    np.testing.assert_array_equal(np.asarray(y[:, 0]), np.asarray(x[:, 0]))
    np.testing.assert_array_equal(np.asarray(y[:, -1]), np.asarray(x[:, -1]))


def test_plan_block_rows_fits_budget():
    rows = plan_block_rows((4096, 4096), jnp.float32, vmem_bytes=8 << 20)
    assert rows >= 1
    assert (4 * rows + 2) * 4096 * 4 <= (8 << 20)
    # small arrays: whole array in one band
    assert plan_block_rows((8, 16), jnp.float32) == 8


def test_kernel_flops_counts_interior():
    assert kernel_flops("jacobi2d", (10, 10), steps=2) == 5.0 * 8 * 8 * 2
    assert kernel_flops("heat3d", (4, 4, 4)) == 15.0 * 2 * 2 * 2


@settings(max_examples=25, deadline=None)
@given(
    name=st.sampled_from(NAMES_2D),
    rows=st.integers(3, 40),
    cols=st.integers(3, 70),
    block_rows=st.integers(1, 16),
    seed=st.integers(0, 10),
)
def test_property_2d_allclose(name, rows, cols, block_rows, seed):
    x = _rand((rows, cols), jnp.float32, seed=seed)
    got = stencil_step(name, x, block_rows=block_rows, interpret=True)
    want = run_ref(name, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(
    name=st.sampled_from(NAMES_3D),
    d=st.integers(3, 12),
    h=st.integers(3, 12),
    w=st.integers(3, 20),
    block_rows=st.integers(1, 6),
)
def test_property_3d_allclose(name, d, h, w, block_rows):
    x = _rand((d, h, w), jnp.float32, seed=d * h + w)
    got = stencil_step(name, x, block_rows=block_rows, interpret=True)
    want = run_ref(name, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)
