"""Fleet gateway: one front door over many stored sweep artifacts.

:class:`repro.service.server.CodesignServer` serves exactly one sweep; a
fleet store holds one artifact per (GPU target, hardware space, lattice,
stencil set) and a cache only pays off if all of them are reachable
through a single long-lived endpoint. The gateway closes that gap:

* **discovery / index** -- every artifact under one or more
  :class:`~repro.service.store.ArtifactStore` roots is indexed at startup
  (and re-indexed on demand) by its manifest-only routing attributes
  (:meth:`repro.service.store.Artifact.routing`): content key, GPU name,
  workload name, stencil set, hardware-space digest, engine family.
  Indexing reads only the small JSON manifests -- no matrix is paged in;
* **routing** -- a request names its artifact either exactly (the content
  key) or by a *routing selector* (``{"gpu": "titanx"}``,
  ``{"stencils": ["heat2d"]}``); :meth:`Gateway.resolve` maps selector ->
  key, answering ``unknown_artifact`` / ``ambiguous_route`` as structured
  errors rather than guessing. A key that misses triggers one re-scan
  before failing, so artifacts dropped into the store after startup are
  served without a restart;
* **LRU server pool** -- each routed key gets a lazily-instantiated
  per-artifact server for its cell family
  (:func:`~repro.service.server.server_from_artifact`: a
  :class:`CodesignServer` for stencil sweeps, an
  :class:`~repro.service.server.LMServer` for LM sweeps), kept in an
  LRU bounded by ``pool_size``: hundreds of stored artifacts never mean
  hundreds of resident mmaps/LRUs. Evicted servers finish their in-flight
  queries (the query path holds a reference) and are garbage-collected;
* **HTTP transport** -- :class:`GatewayHTTPServer` (stdlib
  ``ThreadingHTTPServer``; one thread per connection) exposes
  ``POST /v1/query``, ``GET /v1/artifacts``, ``GET /v1/healthz`` and
  ``POST /v1/refresh`` over the :mod:`repro.service.wire` codec.
  Concurrent HTTP requests for the same artifact rendezvous in that
  artifact's ``CodesignServer.query``, so the leader/follower
  microbatching survives the process boundary unchanged.

Wire format, error codes and a curl-able quickstart are documented in
``docs/serving.md``; the request flow diagram lives in
``docs/architecture.md``.
"""

from __future__ import annotations

import json
import os
import threading
from collections import OrderedDict
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple, Union

from . import wire
from .query import QueryRequest, QueryResponse
from .server import CodesignServer, server_from_artifact
from .store import ArtifactStore

__all__ = [
    "Gateway",
    "GatewayError",
    "UnknownArtifactError",
    "AmbiguousRouteError",
    "AmbiguousWorkloadError",
    "WrongArtifactKindError",
    "GatewayHTTPServer",
    "serve_http",
]

#: selector names :meth:`Gateway.resolve` understands. ``stencils``,
#: ``models`` and ``ops`` are subset matches (the artifact must serve at
#: least those stencils / LM models / LM ops); the rest are exact equality
#: against the routing row. ``workload`` matches the workload name (LM
#: sweeps are built as workload ``"lm"`` by default, so ``{"workload":
#: "lm"}`` is the LM disambiguator); ``family`` matches the cell family
#: ("stencil" | "lm"). ``kind`` widens the search beyond sweep artifacts
#: (measurement/calibration manifests); ``calibration`` selects the sweep
#: built from a given calibration key.
ROUTE_SELECTORS = (
    "key", "gpu", "workload", "family", "stencils", "models", "ops",
    "engine", "hw_digest", "kind", "calibration",
)

#: selectors matched as subsets rather than exact equality.
_SUBSET_SELECTORS = ("stencils", "models", "ops")


class GatewayError(Exception):
    """Base of the gateway's structured failures; every subclass pins the
    wire error ``code``, and the HTTP status comes from the shared
    :data:`wire.ERROR_HTTP_STATUS` registry (one table serves the server
    side here and the batched client-side decoder, so the two can never
    disagree about how a code classifies)."""

    code = "internal"
    http_status = wire.ERROR_HTTP_STATUS["internal"]


class UnknownArtifactError(GatewayError):
    """No stored artifact matches the requested key/selector (HTTP 404)."""

    code = "unknown_artifact"
    http_status = wire.ERROR_HTTP_STATUS["unknown_artifact"]


class AmbiguousRouteError(GatewayError):
    """A routing selector matched more than one artifact; the message
    carries the candidate keys so the caller can pin one (HTTP 409)."""

    code = "ambiguous_route"
    http_status = wire.ERROR_HTTP_STATUS["ambiguous_route"]


class AmbiguousWorkloadError(GatewayError):
    """A routing selector matched artifacts of more than one *cell family*
    (e.g. a stencil sweep and an LM sweep stored for the same GPU name).
    Unlike a same-family :class:`AmbiguousRouteError` (HTTP 409, "pin a
    key"), the request is underspecified about what kind of question it is
    asking -- add a ``workload`` or ``family`` selector -- so it classifies
    as the caller's error (HTTP 400), mirroring ``wrong_artifact_kind``."""

    code = "ambiguous_workload"
    http_status = wire.ERROR_HTTP_STATUS["ambiguous_workload"]


class WrongArtifactKindError(GatewayError):
    """The resolved artifact exists but is not a queryable sweep (e.g. a
    measurement run or calibration manifest was pinned for /v1/query).
    The request named the wrong thing, hence HTTP 400."""

    code = "wrong_artifact_kind"
    http_status = wire.ERROR_HTTP_STATUS["wrong_artifact_kind"]


class Gateway:
    """Route :class:`QueryRequest` s across every artifact in one or more
    store roots (see the module docstring for the moving parts).

    Parameters
    ----------
    roots:
        One path or a sequence of paths to artifact store directories.
        Roots must exist (:class:`UnknownArtifactError` is *not* the right
        failure for a typo'd path): a missing root raises
        ``FileNotFoundError`` immediately.
    pool_size:
        Max resident per-artifact servers (LRU-evicted beyond this).
    batch_window / lru_size:
        Forwarded to each pooled :class:`CodesignServer` /
        :class:`~repro.service.query.QueryEngine`.
    """

    def __init__(
        self,
        roots: Union[str, Sequence[str]],
        pool_size: int = 8,
        batch_window: float = 0.002,
        lru_size: int = 256,
    ):
        if isinstance(roots, (str, os.PathLike)):
            roots = [roots]
        if not roots:
            raise ValueError("gateway needs at least one store root")
        self.stores = [ArtifactStore(r, create=False) for r in roots]
        self.pool_size = int(pool_size)
        if self.pool_size < 1:
            raise ValueError("pool_size must be >= 1")
        self.batch_window = float(batch_window)
        self.lru_size = int(lru_size)
        self._mu = threading.Lock()  # guards _index and _pool
        self._index: "OrderedDict[str, Dict[str, Any]]" = OrderedDict()
        self._pool: "OrderedDict[str, CodesignServer]" = OrderedDict()
        self.stats: Dict[str, int] = {
            "requests": 0,
            "routed_by_key": 0,
            "routed_by_selector": 0,
            "unknown": 0,
            "pool_hits": 0,
            "pool_instantiations": 0,
            "pool_evictions": 0,
            "rescans": 0,
            "batched_requests": 0,
        }
        self.refresh()

    # ---- discovery --------------------------------------------------------
    def refresh(self) -> int:
        """Re-scan every root and rebuild the routing index from manifests
        (cheap: JSON only). Returns the number of indexed artifacts.
        Already-pooled servers for keys that disappeared are dropped."""
        index: "OrderedDict[str, Dict[str, Any]]" = OrderedDict()
        for store in self.stores:
            for row in store.entries():
                # first root wins on (content-addressed) key collisions --
                # identical keys name identical bytes, so either copy serves
                index.setdefault(row["key"], {**row, "store": store})
        with self._mu:
            self._index = index
            self.stats["rescans"] += 1
            for key in [k for k in self._pool if k not in index]:
                del self._pool[key]
        return len(index)

    def keys(self) -> List[str]:
        with self._mu:
            return list(self._index)

    def entries(self) -> List[Dict[str, Any]]:
        """Routing rows (sans store handles) -- the ``/v1/artifacts``
        payload."""
        with self._mu:
            return [
                {k: v for k, v in row.items() if k != "store"}
                for row in self._index.values()
            ]

    def __len__(self) -> int:
        with self._mu:
            return len(self._index)

    # ---- routing ----------------------------------------------------------
    def _match(
        self, route: Mapping[str, Any], kinds: Optional[Sequence[str]]
    ) -> List[str]:
        unknown = set(route) - set(ROUTE_SELECTORS)
        if unknown:
            raise ValueError(
                f"unknown route selector(s) {sorted(unknown)} "
                f"(want one of {list(ROUTE_SELECTORS)})"
            )
        if "kind" in route:
            kinds = None  # an explicit kind selector overrides the default
        with self._mu:
            rows = list(self._index.values())
        out = []
        for row in rows:
            ok = kinds is None or row.get("kind", "sweep") in kinds
            if ok:
                for name, want in route.items():
                    if name in _SUBSET_SELECTORS:
                        want_set = {want} if isinstance(want, str) else set(want)
                        ok = want_set <= set(row.get(name) or ())
                    elif name == "family":
                        ok = row.get("family", "stencil") == want
                    else:
                        ok = row.get(name) == want
                    if not ok:
                        break
            if ok:
                out.append(row["key"])
        return out

    def resolve(
        self,
        artifact: Optional[str] = None,
        route: Optional[Mapping[str, Any]] = None,
        kinds: Optional[Sequence[str]] = ("sweep",),
        rescan: bool = True,
    ) -> str:
        """Map (key | selector | nothing) -> one content key.

        An exact ``artifact`` key wins over ``route``. A miss triggers one
        on-demand :meth:`refresh` (new artifacts appear without a restart)
        before raising :class:`UnknownArtifactError`; a selector matching
        several artifacts raises :class:`AmbiguousRouteError` listing the
        candidates. With neither argument, a single-artifact gateway
        serves its only artifact and a multi-artifact one refuses to
        guess.

        ``kinds`` restricts which manifest kinds compete: the query paths
        keep the default ``("sweep",)`` so measurement/calibration
        manifests in the same store can never make a ``{"gpu": ...}``
        selector ambiguous (an explicit ``{"kind": ...}`` selector in
        ``route`` overrides it). A pinned ``artifact`` key of the wrong
        kind raises :class:`WrongArtifactKindError` rather than a
        misleading 404.

        ``rescan=False`` skips the on-demand refresh on a miss --
        :meth:`query_many` uses it to bound a whole batch to ONE store
        re-scan instead of one per unresolvable query."""
        for attempt in range(2 if rescan else 1):
            if artifact is not None:
                with self._mu:
                    row = self._index.get(artifact)
                    if row is not None:
                        kind = row.get("kind", "sweep")
                        if kinds is not None and kind not in kinds:
                            pass  # raise outside the lock
                        else:
                            self.stats["routed_by_key"] += 1
                            return artifact
                if row is not None:
                    raise WrongArtifactKindError(
                        f"artifact {artifact!r} is a {row.get('kind')!r} manifest, "
                        f"not a queryable sweep"
                    )
            elif route:
                matches = self._match(route, kinds)
                if len(matches) == 1:
                    with self._mu:
                        self.stats["routed_by_selector"] += 1
                    return matches[0]
                if len(matches) > 1:
                    with self._mu:
                        families = {
                            self._index[k].get("family", "stencil")
                            for k in matches
                            if k in self._index
                        }
                    if len(families) > 1:
                        raise AmbiguousWorkloadError(
                            f"route {dict(route)} matches artifacts of "
                            f"{len(families)} cell families "
                            f"({', '.join(sorted(families))}); add a "
                            f"'workload' or 'family' selector to say which "
                            f"kind of question this is"
                        )
                    raise AmbiguousRouteError(
                        f"route {dict(route)} matches {len(matches)} artifacts "
                        f"({', '.join(sorted(matches))}); pin one with 'artifact'"
                    )
            else:
                with self._mu:
                    candidates = [
                        k for k, row in self._index.items()
                        if kinds is None or row.get("kind", "sweep") in kinds
                    ]
                if len(candidates) == 1:
                    with self._mu:
                        self.stats["routed_by_key"] += 1
                    return candidates[0]
                if len(candidates) > 1:
                    raise AmbiguousRouteError(
                        f"gateway serves {len(candidates)} artifacts; name one "
                        "via 'artifact' or a 'route' selector"
                    )
            if rescan and attempt == 0:
                self.refresh()  # on-demand discovery before giving up
        with self._mu:
            self.stats["unknown"] += 1
        if artifact is not None:
            what = f"artifact {artifact!r}"
        elif route:
            what = f"route {dict(route)}"
        elif kinds is not None:
            # the store may be non-empty but hold only non-sweep kinds
            # (e.g. after `measure.cli run` + `fit`, before `build`) --
            # "empty store" would contradict the indexed count printed next
            what = f"an unselected query (no {'/'.join(kinds)}-kind artifact stored)"
        else:
            what = "empty store"
        raise UnknownArtifactError(
            f"no stored artifact matches {what} "
            f"({len(self)} artifacts indexed; GET /v1/artifacts lists them)"
        )

    # ---- server pool ------------------------------------------------------
    def server_for(self, key: str) -> CodesignServer:
        """The pooled per-artifact server for an (already resolved) key,
        instantiating (and LRU-evicting) as needed."""
        with self._mu:
            srv = self._pool.get(key)
            if srv is not None:
                self._pool.move_to_end(key)
                self.stats["pool_hits"] += 1
                return srv
            row = self._index.get(key)
        if row is None:
            raise UnknownArtifactError(f"artifact {key!r} is not indexed")
        if row.get("kind", "sweep") != "sweep":
            raise WrongArtifactKindError(
                f"artifact {key!r} is a {row.get('kind')!r} manifest; only "
                "sweep artifacts serve queries"
            )
        store: ArtifactStore = row["store"]
        art = store.get(key)
        if art is None:  # deleted between index and query
            self.refresh()
            raise UnknownArtifactError(f"artifact {key!r} vanished from {store.root}")
        srv = server_from_artifact(
            store, art, batch_window=self.batch_window, lru_size=self.lru_size
        )
        with self._mu:
            # a racing thread may have built it meanwhile; keep the first
            winner = self._pool.setdefault(key, srv)
            if winner is srv:
                self.stats["pool_instantiations"] += 1
            srv = winner
            self._pool.move_to_end(key)
            while len(self._pool) > self.pool_size:
                self._pool.popitem(last=False)  # in-flight queries hold refs
                self.stats["pool_evictions"] += 1
        return srv

    # ---- queries ----------------------------------------------------------
    def query(
        self,
        request: QueryRequest,
        artifact: Optional[str] = None,
        route: Optional[Mapping[str, Any]] = None,
    ) -> QueryResponse:
        """Route one request to its artifact's server (microbatching with
        any concurrent caller of the same artifact) and answer it."""
        with self._mu:
            self.stats["requests"] += 1
        key = self.resolve(artifact, route)
        return self.server_for(key).query(request)

    def query_many(
        self,
        queries: Sequence[
            Tuple[QueryRequest, Optional[str], Optional[Mapping[str, Any]]]
        ],
    ) -> List[Any]:
        """Answer N routed queries in one call (the ``/v1/query_many``
        body). Queries are resolved individually, grouped by artifact, and
        each group rides that artifact's ``CodesignServer.query_many``
        stacked matmul -- per-artifact microbatching without waiting on a
        rendezvous window. Returns, per query *in order*, either a
        :class:`QueryResponse` or a ``(code, message)`` error pair: one
        unroutable or poisonous query never fails its batchmates."""
        results: List[Any] = [None] * len(queries)
        groups: Dict[str, List[int]] = {}
        with self._mu:
            self.stats["requests"] += len(queries)
            self.stats["batched_requests"] += len(queries)
        # at most ONE on-demand store re-scan per batch: the first
        # unresolvable query pays it, the rest fail fast (a batch of
        # unknown keys must not trigger MAX_BATCH full-store scans)
        rescanned = False
        for i, (request, artifact, route) in enumerate(queries):
            try:
                key = self.resolve(artifact, route, rescan=not rescanned)
            except UnknownArtifactError as e:
                rescanned = True
                results[i] = (e.code, str(e))
                continue
            except GatewayError as e:
                results[i] = (e.code, str(e))
                continue
            except (KeyError, ValueError) as e:
                results[i] = ("bad_request", str(e.args[0] if e.args else e))
                continue
            groups.setdefault(key, []).append(i)
        def answer_group(key: str, idxs: List[int]) -> None:
            try:
                _answer_group(key, idxs)
            except Exception as e:  # noqa: BLE001 - NOTHING may escape: an
                # unfilled slot would crash the whole batch's encoding
                # (and the pool path would swallow the exception silently)
                for i in idxs:
                    if results[i] is None:
                        results[i] = ("internal", f"{type(e).__name__}: {e}")

        def _answer_group(key: str, idxs: List[int]) -> None:
            try:
                # server_for can also raise outside the GatewayError
                # family (e.g. a corrupt artifact failing its content-key
                # check with ValueError) -- the outer boundary catches it
                srv = self.server_for(key)
            except GatewayError as e:
                for i in idxs:
                    results[i] = (e.code, str(e))
                return
            try:
                for i, resp in zip(idxs, srv.query_many([queries[i][0] for i in idxs])):
                    results[i] = resp
            except Exception:  # noqa: BLE001 - isolate the poison pill
                for i in idxs:
                    try:
                        results[i] = srv.query(queries[i][0])
                    except GatewayError as e:
                        results[i] = (e.code, str(e))
                    except (KeyError, ValueError) as e:
                        results[i] = (
                            "bad_request", str(e.args[0] if e.args else e)
                        )
                    except Exception as e:  # noqa: BLE001 - boundary
                        results[i] = ("internal", f"{type(e).__name__}: {e}")

        if len(groups) <= 1:
            for key, idxs in groups.items():
                answer_group(key, idxs)
        else:
            # overlap the per-artifact stacked matmuls: groups answer
            # concurrently (each writes disjoint result indices), matching
            # what concurrent single-endpoint requests would get from the
            # threaded HTTP server -- but on a pool BOUNDED by the server
            # pool size: a batch pinning 1024 distinct artifacts must not
            # spawn 1024 threads thrashing an 8-server LRU.
            from concurrent.futures import ThreadPoolExecutor

            workers = min(len(groups), self.pool_size)
            with ThreadPoolExecutor(max_workers=workers) as pool:
                for key, idxs in groups.items():
                    pool.submit(answer_group, key, idxs)
        return results

    def health(self) -> Dict[str, Any]:
        with self._mu:
            return {
                "ok": True,
                "artifacts": len(self._index),
                "pooled_servers": len(self._pool),
                "pool_size": self.pool_size,
                "roots": [s.root for s in self.stores],
                "stats": dict(self.stats),
            }


# ---------------------------------------------------------------------------
# HTTP transport
# ---------------------------------------------------------------------------
class _Handler(BaseHTTPRequestHandler):
    """Maps the wire codec onto HTTP. All bodies are JSON; failures are
    :func:`repro.service.wire.encode_error` payloads (never tracebacks)."""

    server_version = "repro-gateway/1"
    protocol_version = "HTTP/1.1"  # keep-alive: clients reuse connections

    # silence the default per-request stderr line (benchmarks hammer this)
    def log_message(self, fmt, *args):  # noqa: ARG002
        pass

    @property
    def gateway(self) -> Gateway:
        return self.server.gateway  # type: ignore[attr-defined]

    def _send(self, status: int, body: bytes, content_type="application/json") -> None:
        self.send_response(status)
        self.send_header("Content-Type", content_type)
        self.send_header("Content-Length", str(len(body)))
        if self.close_connection:
            self.send_header("Connection", "close")
        self.end_headers()
        self.wfile.write(body)

    def _send_error(self, status: int, code: str, message: str) -> None:
        # one request per connection on failures: simpler client recovery
        # than reasoning about keep-alive state after an error
        self.close_connection = True
        self._send(status, wire.encode_error(code, message))

    def do_GET(self) -> None:  # noqa: N802 - BaseHTTPRequestHandler API
        if self.path == "/v1/healthz":
            body = json.dumps(self.gateway.health(), sort_keys=True).encode()
            self._send(200, body)
        elif self.path == "/v1/artifacts":
            body = json.dumps(
                {"v": wire.WIRE_VERSION, "artifacts": self.gateway.entries()},
                sort_keys=True,
            ).encode()
            self._send(200, body)
        else:
            self._send_error(wire.ERROR_HTTP_STATUS["not_found"], "not_found",
                             f"no such endpoint {self.path!r}")

    def do_POST(self) -> None:  # noqa: N802
        try:
            # always drain the body first: with keep-alive, unread body
            # bytes would be misparsed as the connection's next request line
            length = int(self.headers.get("Content-Length", 0))
            data = self.rfile.read(length)
            if self.path == "/v1/refresh":
                n = self.gateway.refresh()
                self._send(200, json.dumps({"ok": True, "artifacts": n}).encode())
                return
            if self.path == "/v1/query_many":
                queries = wire.decode_request_many(data)
                results = self.gateway.query_many(queries)
                self._send(200, wire.encode_response_many(results))
                return
            if self.path != "/v1/query":
                self._send_error(wire.ERROR_HTTP_STATUS["not_found"], "not_found",
                             f"no such endpoint {self.path!r}")
                return
            request, artifact, route = wire.decode_request(data)
            response = self.gateway.query(request, artifact=artifact, route=route)
            self._send(200, wire.encode_response(response))
        except wire.WireError as e:
            self._send_error(
                wire.ERROR_HTTP_STATUS.get(e.code, 400), e.code, str(e)
            )
        except GatewayError as e:
            self._send_error(e.http_status, e.code, str(e))
        except (KeyError, ValueError) as e:
            # engine-level rejections (unknown stencil, bad shapes, bad
            # selector names): the request is at fault, not the server
            msg = e.args[0] if e.args else str(e)
            self._send_error(400, "bad_request", str(msg))
        except BrokenPipeError:  # client went away mid-answer
            pass
        except Exception as e:  # noqa: BLE001 - boundary: never leak a traceback
            self._send_error(500, "internal", f"{type(e).__name__}: {e}")


class GatewayHTTPServer(ThreadingHTTPServer):
    """Threaded HTTP front end over one :class:`Gateway` (stdlib only).

    One thread per connection; threads answering the same artifact
    rendezvous inside that artifact's ``CodesignServer`` microbatch.
    ``daemon_threads`` keeps shutdown prompt."""

    daemon_threads = True

    def __init__(self, address, gateway: Gateway):
        super().__init__(address, _Handler)
        self.gateway = gateway


def serve_http(
    gateway: Gateway, host: str = "127.0.0.1", port: int = 0
) -> GatewayHTTPServer:
    """Bind (``port=0`` picks a free one -- see ``server_address``) and
    return the server; the caller drives ``serve_forever()``, typically on
    a daemon thread (tests, benchmarks) or the main thread (the CLI)."""
    return GatewayHTTPServer((host, port), gateway)
