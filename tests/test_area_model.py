"""Area-model tests: paper §III calibration/validation numbers + properties."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # soft dep: skips, not errors

from repro.core.area import (
    GTX980,
    GTX980_DIE_MM2,
    MAXWELL,
    TITAN_X,
    TITAN_X_DIE_MM2,
    HardwarePoint,
    cacheless,
)


def test_gtx980_calibration():
    """Eq. (6) at the GTX-980 stock point reproduces the published die area
    (398 mm^2) to < 2.5% (we land at 394.68, -0.83%)."""
    a = MAXWELL.area_point(GTX980)
    assert a == pytest.approx(394.6784, abs=1e-3)
    assert abs(a - GTX980_DIE_MM2) / GTX980_DIE_MM2 < 0.025


def test_titanx_validation():
    """Paper §III.C: the model predicts the Titan X within ~2% of the
    published 601 mm^2 (paper: 589.2, -1.96%; our eq.-6-exact: 592.0)."""
    a = MAXWELL.area_point(TITAN_X)
    assert a == pytest.approx(592.0176, abs=1e-3)
    assert abs(a - TITAN_X_DIE_MM2) / TITAN_X_DIE_MM2 < 0.025


def test_cacheless_transform():
    """§V.A: deleting caches removes exactly the L1/L2 terms."""
    a_with = MAXWELL.area_point(GTX980)
    a_without = MAXWELL.area_point(cacheless(GTX980))
    l1 = 0.08 * 48.0 * 16
    l2 = 0.041 * 2048.0
    assert a_with - a_without == pytest.approx(l1 + l2, rel=1e-9)


def test_breakdown_sums_to_total():
    b = MAXWELL.breakdown(TITAN_X)
    assert sum(b.values()) == pytest.approx(MAXWELL.area_point(TITAN_X), rel=1e-12)


def test_vectorized_matches_scalar():
    n_sm = np.array([2, 16, 32])
    n_v = np.array([32, 128, 2048])
    m_sm = np.array([12.0, 96.0, 480.0])
    vec = MAXWELL.area(n_sm, n_v, m_sm)
    for i in range(3):
        pt = HardwarePoint(int(n_sm[i]), int(n_v[i]), float(m_sm[i]))
        assert vec[i] == pytest.approx(MAXWELL.area_point(pt), rel=1e-12)


@settings(max_examples=200, deadline=None)
@given(
    n_sm=st.integers(2, 64),
    n_v=st.integers(32, 4096),
    m_sm=st.integers(12, 960),
    dn=st.integers(0, 8),
    dv=st.integers(0, 256),
    dm=st.integers(0, 96),
)
def test_area_monotone(n_sm, n_v, m_sm, dn, dv, dm):
    """Property: area is monotone non-decreasing in every resource."""
    a0 = float(MAXWELL.area(n_sm, n_v, m_sm))
    a1 = float(MAXWELL.area(n_sm + dn, n_v + dv, m_sm + dm))
    assert a1 >= a0 - 1e-9


@settings(max_examples=100, deadline=None)
@given(n_sm=st.integers(2, 64), n_v=st.integers(32, 4096), m_sm=st.integers(12, 960))
def test_area_positive_and_linear_in_l2(n_sm, n_v, m_sm):
    a = float(MAXWELL.area(n_sm, n_v, m_sm))
    assert a > 0
    a2 = float(MAXWELL.area(n_sm, n_v, m_sm, l2_kb=1024.0))
    assert a2 - a == pytest.approx(0.041 * 1024.0, rel=1e-9)
