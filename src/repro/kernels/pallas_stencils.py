"""Pallas stencil kernels parameterized by the eq.-18 tile lattice.

The sweep engine (:mod:`repro.core.sweep`) optimizes over software
parameters ``(t_s1, t_s2, t_t, k, t_s3)`` -- but until this module, no
executable kernel accepted those parameters: ``repro.kernels.ops`` exposes
only a VMEM band height (``block_rows``), so the time model's predictions
were never confronted with a kernel actually *running* the tile shapes the
optimizer enumerates. This module closes that gap for the measurement
subsystem (:mod:`repro.measure`):

* a tile is a ``(t_s1, t_s2[, t_s3])`` block of the iteration space; the
  grid covers the array in those blocks (the paper's "one threadblock of
  t_S2 threads per tile" becomes "one grid step per tile");
* ``t_t`` is the *time-tile depth*: one ``pallas_call`` advances up to
  ``t_t`` stencil steps before touching HBM again, reading a halo-extended
  block of ``radius * t_t`` extra cells per side (overlapped -- a.k.a.
  trapezoidal -- time tiling). The paper's hybrid-hexagonal schedule avoids
  the redundant halo compute by alternating phases; the overlapped schedule
  trades that redundancy for independence of tiles, but spans the *same*
  ``(t_s1, t_s2, t_t, t_s3)`` parameter space with the same footprint and
  bandwidth scaling, which is what the calibration fit needs;
* ``k`` (tiles co-resident per SM) is an occupancy/scheduling knob with no
  effect on values; it is accepted (so a full sweep-lattice point is a
  valid tile config) and ignored by the kernel body;
* Dirichlet borders and out-of-tile padding are handled by masking on
  *global* coordinates, so any tile shape -- aligned or not, larger than
  the array or not -- is value-identical to the reference
  (:mod:`repro.kernels.ref`); ``tests/test_pallas_stencils.py`` asserts
  allclose (f32 accumulation, atol/rtol 1e-5) across the tile grid in
  ``interpret=True`` mode on CPU.

Correctness of the time tile: after ``n`` in-kernel steps the outer
``radius*n`` ring of the halo-extended block is stale (it read replicated
edge values), but the core tile sits ``radius*t_t`` cells from the block
edge, so every core value equals the global evolution. Boundary cells are
pinned by the mask (Dirichlet), and padding cells are only ever read by
pinned cells, so they cannot leak in.

The input rides into the kernel as one unblocked ref and each grid step
slices its own halo-extended window with ``pl.ds`` -- overlapping reads
that blocked ``BlockSpec`` indexing cannot express. That keeps the whole
array resident per step, which is exactly right for the interpret-mode CI
lane and the measurement harness's problem sizes; a production TPU variant
would stream windows by DMA instead.
"""

from __future__ import annotations

import functools
from types import ModuleType
from typing import Dict, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import gradient2d, heat2d, heat3d, jacobi2d, laplacian2d, laplacian3d

__all__ = [
    "TILE_NAMES",
    "DEFAULT_TILES",
    "normalize_tiles",
    "tile_footprint_cells",
    "stencil_run_tiled",
    "run_tiled",
]

#: software-parameter order -- MUST stay aligned with
#: ``repro.core.sweep.SW_NAMES`` (asserted in tests): a packed (5,) row
#: from the sweep's refine path is a valid tile config here.
TILE_NAMES = ("t_s1", "t_s2", "t_t", "k", "t_s3")

#: a modest, always-feasible default (every stencil, every shape).
DEFAULT_TILES = {"t_s1": 8, "t_s2": 32, "t_t": 2, "k": 1, "t_s3": 8}

_MODULES: Dict[str, ModuleType] = {
    m.NAME: m
    for m in (jacobi2d, heat2d, laplacian2d, gradient2d, heat3d, laplacian3d)
}


def normalize_tiles(tiles: Optional[Mapping[str, int]]) -> Tuple[int, ...]:
    """Tile mapping -> hashable ``TILE_NAMES``-ordered int tuple (the jit
    static key). Unknown names and non-positive sizes are rejected here so
    a typo'd sweep row fails loudly, not as a silent default."""
    merged = dict(DEFAULT_TILES)
    if tiles:
        unknown = set(tiles) - set(TILE_NAMES)
        if unknown:
            raise ValueError(
                f"unknown tile parameter(s) {sorted(unknown)} "
                f"(want {list(TILE_NAMES)})"
            )
        merged.update({k: int(v) for k, v in tiles.items()})
    out = tuple(int(merged[k]) for k in TILE_NAMES)
    if any(v < 1 for v in out):
        raise ValueError(f"tile sizes must be >= 1, got {dict(zip(TILE_NAMES, out))}")
    return out


def tile_footprint_cells(dims: int, tiles: Mapping[str, int], radius: int = 1) -> int:
    """Cells resident per halo-extended time tile -- the empirical analogue
    of :func:`repro.core.timemodel.footprint_bytes` (divide by arrays x
    bytes/word to compare orders of magnitude, not exact constants)."""
    t = dict(zip(TILE_NAMES, normalize_tiles(tiles)))
    hh = radius * t["t_t"]
    cells = (t["t_s1"] + 2 * hh) * (t["t_s2"] + 2 * hh)
    if dims == 3:
        cells *= t["t_s3"] + 2 * hh
    return int(cells)


# ---------------------------------------------------------------------------
# kernel bodies
# ---------------------------------------------------------------------------
def _kernel_2d(x_ref, out_ref, *, update, radius, hh, t_s1, t_s2, n_steps, s1, s2):
    i, j = pl.program_id(0), pl.program_id(1)
    er, ec = t_s1 + 2 * hh, t_s2 + 2 * hh
    ext = x_ref[pl.ds(i * t_s1, er), pl.ds(j * t_s2, ec)].astype(jnp.float32)
    # global (unpadded) coordinates of every ext cell: the Dirichlet mask
    # and the padding guard in one predicate
    rows = i * t_s1 - hh + jax.lax.broadcasted_iota(jnp.int32, (er, ec), 0)
    cols = j * t_s2 - hh + jax.lax.broadcasted_iota(jnp.int32, (er, ec), 1)
    active = (
        (rows >= radius) & (rows < s1 - radius)
        & (cols >= radius) & (cols < s2 - radius)
    )

    def one_step(_, v):
        vp = jnp.pad(v, radius, mode="edge")
        return jnp.where(active, update(vp, radius), v)

    ext = jax.lax.fori_loop(0, n_steps, one_step, ext)
    out_ref[...] = ext[hh : hh + t_s1, hh : hh + t_s2].astype(out_ref.dtype)


def _kernel_3d(
    x_ref, out_ref, *, update, radius, hh, t_s1, t_s2, t_s3, n_steps, s1, s2, s3
):
    i, j, m = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    e1, e2, e3 = t_s1 + 2 * hh, t_s2 + 2 * hh, t_s3 + 2 * hh
    ext = x_ref[
        pl.ds(i * t_s1, e1), pl.ds(j * t_s2, e2), pl.ds(m * t_s3, e3)
    ].astype(jnp.float32)
    shape = (e1, e2, e3)
    d0 = i * t_s1 - hh + jax.lax.broadcasted_iota(jnp.int32, shape, 0)
    d1 = j * t_s2 - hh + jax.lax.broadcasted_iota(jnp.int32, shape, 1)
    d2 = m * t_s3 - hh + jax.lax.broadcasted_iota(jnp.int32, shape, 2)
    active = (
        (d0 >= radius) & (d0 < s1 - radius)
        & (d1 >= radius) & (d1 < s2 - radius)
        & (d2 >= radius) & (d2 < s3 - radius)
    )

    def one_step(_, v):
        vp = jnp.pad(v, radius, mode="edge")
        return jnp.where(active, update(vp, radius), v)

    ext = jax.lax.fori_loop(0, n_steps, one_step, ext)
    out_ref[...] = ext[
        hh : hh + t_s1, hh : hh + t_s2, hh : hh + t_s3
    ].astype(out_ref.dtype)


# ---------------------------------------------------------------------------
# pass drivers (one pallas_call = up to t_t time steps)
# ---------------------------------------------------------------------------
def _pass_2d(x, update, radius, t_s1, t_s2, n_steps, interpret):
    s1, s2 = x.shape
    hh = radius * n_steps
    g1, g2 = pl.cdiv(s1, t_s1), pl.cdiv(s2, t_s2)
    rows_p, cols_p = g1 * t_s1, g2 * t_s2
    xp = jnp.pad(x, ((hh, hh + rows_p - s1), (hh, hh + cols_p - s2)), mode="edge")
    kernel = functools.partial(
        _kernel_2d, update=update, radius=radius, hh=hh,
        t_s1=t_s1, t_s2=t_s2, n_steps=n_steps, s1=s1, s2=s2,
    )
    out = pl.pallas_call(
        kernel,
        grid=(g1, g2),
        in_specs=[pl.BlockSpec(xp.shape, lambda i, j: (0, 0))],
        out_specs=pl.BlockSpec((t_s1, t_s2), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((rows_p, cols_p), x.dtype),
        interpret=interpret,
    )(xp)
    return out[:s1, :s2]


def _pass_3d(x, update, radius, t_s1, t_s2, t_s3, n_steps, interpret):
    s1, s2, s3 = x.shape
    hh = radius * n_steps
    g1, g2, g3 = pl.cdiv(s1, t_s1), pl.cdiv(s2, t_s2), pl.cdiv(s3, t_s3)
    p1, p2, p3 = g1 * t_s1, g2 * t_s2, g3 * t_s3
    xp = jnp.pad(
        x,
        ((hh, hh + p1 - s1), (hh, hh + p2 - s2), (hh, hh + p3 - s3)),
        mode="edge",
    )
    kernel = functools.partial(
        _kernel_3d, update=update, radius=radius, hh=hh,
        t_s1=t_s1, t_s2=t_s2, t_s3=t_s3, n_steps=n_steps, s1=s1, s2=s2, s3=s3,
    )
    out = pl.pallas_call(
        kernel,
        grid=(g1, g2, g3),
        in_specs=[pl.BlockSpec(xp.shape, lambda i, j, m: (0, 0, 0))],
        out_specs=pl.BlockSpec((t_s1, t_s2, t_s3), lambda i, j, m: (i, j, m)),
        out_shape=jax.ShapeDtypeStruct((p1, p2, p3), x.dtype),
        interpret=interpret,
    )(xp)
    return out[:s1, :s2, :s3]


@functools.partial(
    jax.jit, static_argnames=("name", "steps", "tiles", "interpret")
)
def stencil_run_tiled(
    name: str,
    x: jax.Array,
    steps: int,
    tiles: Tuple[int, ...],
    interpret: bool = True,
) -> jax.Array:
    """Jitted T-step run at one (normalized) tile tuple -- the harness's
    hot entry point. ``tiles`` must come from :func:`normalize_tiles`."""
    mod = _MODULES[name]
    t_s1, t_s2, t_t, _k, t_s3 = tiles
    radius = mod.HALO
    done = 0
    while done < steps:
        n = min(t_t, steps - done)
        if mod.DIMS == 3:
            x = _pass_3d(x, mod.update, radius, t_s1, t_s2, t_s3, n, interpret)
        else:
            x = _pass_2d(x, mod.update, radius, t_s1, t_s2, n, interpret)
        done += n
    return x


def run_tiled(
    name: str,
    x: jax.Array,
    steps: int = 1,
    tiles: Optional[Mapping[str, int]] = None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """T time steps of the named stencil at an eq.-18 tile configuration.

    ``tiles`` maps any subset of :data:`TILE_NAMES` to ints (sweep rows,
    ``decode_index`` dicts, and ``decode_sw`` dicts all qualify); missing
    parameters take :data:`DEFAULT_TILES`. ``interpret=None`` resolves to
    interpret mode off-TPU (this container has no TPU; interpret executes
    the same kernel body on CPU).
    """
    if name not in _MODULES:
        raise KeyError(f"unknown stencil {name!r} (want one of {sorted(_MODULES)})")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if steps < 0:
        raise ValueError(f"steps must be >= 0, got {steps}")
    if steps == 0:
        return x
    return stencil_run_tiled(
        name, x, int(steps), normalize_tiles(tiles), bool(interpret)
    )
