"""Deterministic fault injection for the serving stack.

You cannot claim a gateway degrades gracefully without being able to
*make* it degrade on demand. This module is a process-wide registry of
named injection points that production code consults via cheap hooks:

* the hooks (:func:`fire`, :func:`should_drop`) cost **one module-global
  read** when nothing is armed -- the registry exists precisely so the
  production request path can carry its failure modes at zero cost;
* faults are **deterministic**: no randomness. A fault fires on every
  hit, optionally skipping the first ``after`` hits and auto-clearing
  after ``count`` firings -- which is what lets the chaos harness
  (``scripts/chaos_smoke.py``) assert not just the failure but the
  *recovery* after the fault clears;
* gating is explicit: programmatic (:func:`enable` / :func:`configure`,
  used by tests) or the ``REPRO_FAULTS`` environment variable (a JSON
  object, parsed once at import -- how the chaos harness arms a
  ``serve`` child process). An unset env and an empty registry mean
  every hook is a no-op.

Injection points wired into the stack (each documented where it is
called):

========================  ==================================================
``store.open``            :meth:`repro.service.store.ArtifactStore.get` --
                          artifact-open latency and load exceptions
``store.lock``            :meth:`~repro.service.store.ArtifactStore
                          .build_lock` -- extra hold time on the build flock
``server.batch``          the microbatch leader's flush in
                          :mod:`repro.service.server` -- slow/failing
                          batch answers (slow-follower symptom)
``gateway.drop_socket``   the HTTP handler -- close the connection without
                          answering (client sees a reset/EOF)
``route.member.<hw>``     :meth:`repro.service.portfolio.PortfolioServer
                          .route` -- fail one portfolio member (hardware
                          index ``<hw>``) so routing degrades onto the
                          next-preferred design instead of erroring
========================  ==================================================

Fault spec fields: ``latency_s`` (sleep before proceeding), ``error``
(raise; programmatically an exception instance, from the env a string
``"ExcName:message"`` resolved against a small builtin whitelist),
``count`` (fire at most N times, then auto-clear), ``after`` (skip the
first N hits). Example::

    REPRO_FAULTS='{"store.open": {"latency_s": 0.5, "count": 2}}'
"""

from __future__ import annotations

import json
import os
import threading
import time
from typing import Any, Dict, Mapping, Optional

from repro.obs import get_logger
from repro.obs.metrics import get_registry as _obs_registry

__all__ = [
    "enable",
    "disable",
    "reset",
    "configure",
    "active",
    "is_active",
    "fire",
    "should_drop",
]

_LOG = get_logger("repro.faults")
_REG = _obs_registry()
_M_FIRED = _REG.counter(
    "repro_faults_fired_total",
    "injected faults actually fired, by injection point (nonzero only "
    "when fault injection is armed -- never in production)",
    labels=("point",),
)

#: exception names the env-var string form may raise. A whitelist, not
#: arbitrary lookup: REPRO_FAULTS is a test harness knob, not an eval.
_ERROR_TYPES = {
    "OSError": OSError,
    "IOError": OSError,
    "RuntimeError": RuntimeError,
    "ValueError": ValueError,
    "TimeoutError": TimeoutError,
    "ConnectionResetError": ConnectionResetError,
}

_MU = threading.Lock()
_ACTIVE: Dict[str, Dict[str, Any]] = {}
#: the no-op fast path: hooks return immediately unless this is True.
#: Only ever written under _MU; read without it (a stale False merely
#: delays arming by one hit, a stale True costs one lock acquisition).
_ARMED = False


def _parse_error(err: Any) -> Optional[BaseException]:
    """An exception instance from a spec's ``error`` field: pass
    instances through; parse ``"ExcName:message"`` strings (whitelisted
    types only; unknown names become RuntimeError)."""
    if err is None:
        return None
    if isinstance(err, BaseException):
        return err
    name, _, message = str(err).partition(":")
    exc_type = _ERROR_TYPES.get(name.strip())
    if exc_type is None:
        return RuntimeError(str(err))
    return exc_type(message.strip() or name.strip())


def enable(point: str, *, latency_s: float = 0.0,
           error: Any = None, count: Optional[int] = None,
           after: int = 0) -> None:
    """Arm one injection point (replacing any existing spec for it)."""
    global _ARMED
    spec = {
        "latency_s": float(latency_s),
        "error": error,
        "count": None if count is None else int(count),
        "after": int(after),
        "hits": 0,
        "fired": 0,
    }
    with _MU:
        _ACTIVE[point] = spec
        _ARMED = True
    _LOG.info("fault_enabled", point=point, latency_s=latency_s,
              error=str(error) if error is not None else None,
              count=count, after=after)


def disable(point: str) -> None:
    """Disarm one injection point (idempotent)."""
    global _ARMED
    with _MU:
        _ACTIVE.pop(point, None)
        _ARMED = bool(_ACTIVE)


def reset() -> None:
    """Disarm everything (tests call this in teardown)."""
    global _ARMED
    with _MU:
        _ACTIVE.clear()
        _ARMED = False


def configure(spec: Mapping[str, Mapping[str, Any]]) -> None:
    """Replace the whole registry from a ``{point: spec}`` mapping (the
    parsed form of ``REPRO_FAULTS``)."""
    reset()
    for point, cfg in spec.items():
        if not isinstance(cfg, Mapping):
            raise ValueError(
                f"fault spec for {point!r} must be an object, got "
                f"{type(cfg).__name__}"
            )
        unknown = set(cfg) - {"latency_s", "error", "count", "after"}
        if unknown:
            raise ValueError(
                f"fault spec for {point!r} has unknown fields "
                f"{sorted(unknown)}"
            )
        enable(point, **cfg)


def active() -> Dict[str, Dict[str, Any]]:
    """Snapshot of the armed points (counters included) -- diagnostics
    and test assertions."""
    with _MU:
        return {k: dict(v) for k, v in _ACTIVE.items()}


def is_active(point: str) -> bool:
    if not _ARMED:
        return False
    with _MU:
        return point in _ACTIVE


def _take(point: str) -> Optional[Dict[str, Any]]:
    """Consume one hit of ``point``; returns the spec iff the fault fires
    this hit (honoring ``after``/``count``, auto-clearing at count)."""
    global _ARMED
    with _MU:
        spec = _ACTIVE.get(point)
        if spec is None:
            return None
        spec["hits"] += 1
        if spec["hits"] <= spec["after"]:
            return None
        if spec["count"] is not None and spec["fired"] >= spec["count"]:
            del _ACTIVE[point]
            _ARMED = bool(_ACTIVE)
            return None
        spec["fired"] += 1
        if spec["count"] is not None and spec["fired"] >= spec["count"]:
            # last firing: clear now so the very next hit is clean
            del _ACTIVE[point]
            _ARMED = bool(_ACTIVE)
        return spec


def fire(point: str, sleep=time.sleep) -> None:
    """Production hook: no-op unless ``point`` is armed; then apply its
    latency and/or raise its exception. The sleep happens outside the
    registry lock."""
    if not _ARMED:
        return
    spec = _take(point)
    if spec is None:
        return
    _M_FIRED.labels(point=point).inc()
    _LOG.warning("fault_fired", point=point, fired=spec["fired"])
    if spec["latency_s"] > 0:
        sleep(spec["latency_s"])
    exc = _parse_error(spec["error"])
    if exc is not None:
        raise exc


def should_drop(point: str) -> bool:
    """Production hook for faults that cannot be expressed as an
    exception (e.g. the HTTP handler abandoning a connection): True iff
    the armed fault fires this hit. Latency (if any) is applied here
    too; an ``error`` field is ignored for drop-style points."""
    if not _ARMED:
        return False
    spec = _take(point)
    if spec is None:
        return False
    _M_FIRED.labels(point=point).inc()
    _LOG.warning("fault_fired", point=point, fired=spec["fired"])
    if spec["latency_s"] > 0:
        time.sleep(spec["latency_s"])
    return True


# --- env gating: how a child process (the chaos harness's `serve`) is
# armed. Parsed once at import; malformed JSON is a hard error -- a chaos
# run silently testing nothing would be worse than crashing.
_env_spec = os.environ.get("REPRO_FAULTS")
if _env_spec:
    configure(json.loads(_env_spec))
