"""Pipeline parallelism: GPipe schedule == sequential semantics.
Runs in a subprocess with 4 fake devices (one per stage)."""

import os
import subprocess
import sys
import textwrap
import pytest

# multi-second jit compiles: the fast CI lane deselects these (-m "not slow");
# the weekly scheduled lane (and a bare local `pytest`) still runs them
pytestmark = pytest.mark.slow

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh
    from repro.train.pipeline import pipeline_apply, bubble_fraction

    S, M, B, D = 4, 8, 16, 32
    mesh = Mesh(np.array(jax.devices()).reshape(S), ("stage",))
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (S, D, D)) * 0.2
    bvec = jax.random.normal(jax.random.fold_in(key, 1), (S, D)) * 0.1
    params = {"w": w, "b": bvec}
    x = jax.random.normal(jax.random.fold_in(key, 2), (B, D))

    def stage_fn(p, h):
        return jnp.tanh(h @ p["w"] + p["b"])

    got = pipeline_apply(stage_fn, params, x, mesh, n_microbatches=M)

    ref = x
    for s in range(S):
        ref = stage_fn(jax.tree.map(lambda a: a[s], params), ref)

    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-5, atol=1e-5)
    assert abs(bubble_fraction(S, M) - 3/11) < 1e-9
    # also: microbatch count must not change semantics
    got2 = pipeline_apply(stage_fn, params, x, mesh, n_microbatches=4)
    np.testing.assert_allclose(np.asarray(got2), np.asarray(ref), rtol=1e-5, atol=1e-5)
    print("PIPELINE_OK")
    """
)


def test_gpipe_matches_sequential():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True, text=True, env=env, cwd=ROOT, timeout=600,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "PIPELINE_OK" in proc.stdout
