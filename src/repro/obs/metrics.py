"""Thread-safe metrics registry: counters, gauges, fixed-bucket histograms.

A deliberately tiny subset of the Prometheus client model, stdlib-only,
built for hot paths measured in microseconds:

* **families + labels** -- ``registry.counter(name, help, labels=("route",))``
  returns a :class:`Family`; ``family.labels(route="/v1/query")`` returns
  (and caches) one :class:`Counter` child per label-value tuple. A family
  with no label names acts as its own single child (``family.inc()``).
* **thread safety** -- every child guards its state with one uncontended
  lock (a bare ``+=`` on a Python float is a read-modify-write and CAN
  interleave across threads); child creation locks the family.
* **snapshot / reset** -- :meth:`Registry.snapshot` returns a plain,
  deterministic dict (sorted names, sorted label tuples) decoupled from
  live state; :meth:`Registry.reset` zeroes every child in place (tests,
  benchmarks) without dropping registrations.
* **exporters** -- :meth:`Registry.render_prometheus` (text exposition
  format, version 0.0.4) and :meth:`Registry.render_json` (canonical JSON:
  sorted keys, compact separators -- equal states always render to equal
  bytes). Both render from the same snapshot so they can never disagree.
* **kill switch** -- ``REPRO_OBS_DISABLED=1`` (or :func:`set_disabled`)
  turns ``inc``/``set``/``observe`` into early returns on every child of
  the default registry. Instrumented code never needs to branch.

Histograms use **fixed buckets** chosen at registration (defaults:
:data:`LATENCY_BUCKETS` seconds / :data:`SIZE_BUCKETS` counts); bucket
counts are cumulative, Prometheus-style, with ``+Inf`` implicit in
``count``.
"""

from __future__ import annotations

import bisect
import json
import math
import os
import threading
import time
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

__all__ = [
    "LATENCY_BUCKETS",
    "SIZE_BUCKETS",
    "Counter",
    "Gauge",
    "Histogram",
    "Family",
    "Registry",
    "get_registry",
    "set_disabled",
]

#: env var disabling the DEFAULT registry's instrumentation at import
#: (benchmarks A/B the overhead against exactly this knob).
DISABLED_ENV = "REPRO_OBS_DISABLED"

#: default histogram buckets for wall-time observations, in seconds:
#: 50 us (an LRU-hit query) up through 10 s (a cold sweep build).
LATENCY_BUCKETS: Tuple[float, ...] = (
    5e-5, 1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3, 1e-2, 2.5e-2, 5e-2,
    0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0,
)

#: default buckets for size-ish observations (batch sizes, row counts).
SIZE_BUCKETS: Tuple[float, ...] = (1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024)


class Counter:
    """Monotonically increasing float (negative increments rejected)."""

    __slots__ = ("_mu", "_value", "_family")

    def __init__(self, family: "Family"):
        self._mu = threading.Lock()
        self._value = 0.0
        self._family = family

    def inc(self, n: float = 1.0) -> None:
        if self._family._registry.disabled:
            return
        if n < 0:
            raise ValueError(f"counter increment must be >= 0, got {n}")
        with self._mu:
            self._value += n

    @property
    def value(self) -> float:
        with self._mu:
            return self._value

    def _sample(self) -> Dict[str, Any]:
        return {"value": self.value}

    def _reset(self) -> None:
        with self._mu:
            self._value = 0.0


class Gauge:
    """A value that goes up and down (pool occupancy, last-access stamp)."""

    __slots__ = ("_mu", "_value", "_family")

    def __init__(self, family: "Family"):
        self._mu = threading.Lock()
        self._value = 0.0
        self._family = family

    def set(self, v: float) -> None:
        if self._family._registry.disabled:
            return
        with self._mu:
            self._value = float(v)

    def inc(self, n: float = 1.0) -> None:
        if self._family._registry.disabled:
            return
        with self._mu:
            self._value += n

    def dec(self, n: float = 1.0) -> None:
        self.inc(-n)

    @property
    def value(self) -> float:
        with self._mu:
            return self._value

    def _sample(self) -> Dict[str, Any]:
        return {"value": self.value}

    def _reset(self) -> None:
        with self._mu:
            self._value = 0.0


class Histogram:
    """Fixed-bucket histogram (cumulative counts + sum + count).

    Buckets are upper bounds, strictly increasing, fixed at registration;
    an observation lands in the first bucket whose bound is >= the value
    (``bisect_left``), and ``+Inf`` is implicit: ``count`` minus the last
    bucket's cumulative count is the overflow.
    """

    __slots__ = ("_mu", "_buckets", "_counts", "_sum", "_count", "_family")

    def __init__(self, family: "Family", buckets: Sequence[float]):
        b = tuple(float(x) for x in buckets)
        if not b or any(b[i] >= b[i + 1] for i in range(len(b) - 1)):
            raise ValueError(f"buckets must be strictly increasing, got {b}")
        self._mu = threading.Lock()
        self._buckets = b
        self._counts = [0] * len(b)
        self._sum = 0.0
        self._count = 0
        self._family = family

    def observe(self, v: float) -> None:
        if self._family._registry.disabled:
            return
        v = float(v)
        i = bisect.bisect_left(self._buckets, v)
        with self._mu:
            if i < len(self._counts):
                self._counts[i] += 1
            self._sum += v
            self._count += 1

    def time(self) -> "_Timer":
        """``with hist.time(): ...`` observes the block's wall seconds."""
        return _Timer(self)

    @property
    def count(self) -> int:
        with self._mu:
            return self._count

    @property
    def sum(self) -> float:
        with self._mu:
            return self._sum

    def _sample(self) -> Dict[str, Any]:
        with self._mu:
            counts, total, n = list(self._counts), self._sum, self._count
        cum, cumulative = 0, []
        for bound, c in zip(self._buckets, counts):
            cum += c
            cumulative.append({"le": bound, "count": cum})
        return {"count": n, "sum": total, "buckets": cumulative}

    def _reset(self) -> None:
        with self._mu:
            self._counts = [0] * len(self._buckets)
            self._sum = 0.0
            self._count = 0


class _Timer:
    __slots__ = ("_hist", "_t0")

    def __init__(self, hist: Histogram):
        self._hist = hist

    def __enter__(self) -> "_Timer":
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> None:
        self._hist.observe(time.perf_counter() - self._t0)


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class Family:
    """One named metric family: fixed label names, one child per
    label-value tuple. With no label names the family proxies its single
    child, so unlabeled metrics read naturally (``family.inc()``)."""

    __slots__ = ("name", "help", "kind", "labelnames", "_buckets",
                 "_children", "_mu", "_registry")

    def __init__(
        self,
        registry: "Registry",
        name: str,
        help: str,
        kind: str,
        labelnames: Sequence[str],
        buckets: Optional[Sequence[float]] = None,
    ):
        self.name = name
        self.help = help
        self.kind = kind
        self.labelnames = tuple(labelnames)
        if buckets is not None:
            # validate at registration, not first observation -- a bad
            # bucket spec should fail the module import that wrote it
            b = tuple(float(x) for x in buckets)
            if not b or any(b[i] >= b[i + 1] for i in range(len(b) - 1)):
                raise ValueError(f"buckets must be strictly increasing, got {b}")
            self._buckets = b
        else:
            self._buckets = None
        self._children: Dict[Tuple[str, ...], Any] = {}
        self._mu = threading.Lock()
        self._registry = registry

    def _make_child(self):
        if self.kind == "histogram":
            return Histogram(self, self._buckets or LATENCY_BUCKETS)
        return _KINDS[self.kind](self)

    def labels(self, **kv: Any):
        """The child for one label-value assignment (cached). Values are
        stringified -- label values are identifiers, not data."""
        if set(kv) != set(self.labelnames):
            raise ValueError(
                f"{self.name} wants labels {self.labelnames}, got {sorted(kv)}"
            )
        key = tuple(str(kv[ln]) for ln in self.labelnames)
        child = self._children.get(key)
        if child is None:
            with self._mu:
                child = self._children.setdefault(key, self._make_child())
        return child

    def get(self, **kv: Any):
        """The child for one label assignment IF it exists, else None.
        Read-side queries (artifact listings, telemetry snapshots) go
        through this so looking at a metric never mints a zero sample."""
        if set(kv) != set(self.labelnames):
            raise ValueError(
                f"{self.name} wants labels {self.labelnames}, got {sorted(kv)}"
            )
        return self._children.get(tuple(str(kv[ln]) for ln in self.labelnames))

    # -- unlabeled convenience: the family IS its single child ------------
    def _solo(self):
        if self.labelnames:
            raise ValueError(
                f"{self.name} has labels {self.labelnames}; use .labels(...)"
            )
        return self.labels()

    def inc(self, n: float = 1.0) -> None:
        self._solo().inc(n)

    def dec(self, n: float = 1.0) -> None:
        self._solo().dec(n)

    def set(self, v: float) -> None:
        self._solo().set(v)

    def observe(self, v: float) -> None:
        self._solo().observe(v)

    def time(self) -> _Timer:
        return self._solo().time()

    @property
    def value(self) -> float:
        return self._solo().value

    @property
    def count(self) -> int:
        return self._solo().count

    @property
    def sum(self) -> float:
        return self._solo().sum

    def _snapshot(self) -> Dict[str, Any]:
        with self._mu:
            items = sorted(self._children.items())
        return {
            "type": self.kind,
            "help": self.help,
            "labelnames": list(self.labelnames),
            "samples": [
                {"labels": dict(zip(self.labelnames, key)), **child._sample()}
                for key, child in items
            ],
        }


class Registry:
    """Process-wide named metric families with snapshot/reset semantics.

    Registration is idempotent: asking for an already-registered name with
    the same (kind, labelnames) returns the existing family, so module
    init order never matters; a *conflicting* re-registration raises.
    """

    def __init__(self, disabled: Optional[bool] = None):
        self._mu = threading.Lock()
        self._families: Dict[str, Family] = {}
        if disabled is None:
            disabled = os.environ.get(DISABLED_ENV, "") == "1"
        self.disabled = bool(disabled)

    # ---- registration -----------------------------------------------------
    def _register(
        self,
        kind: str,
        name: str,
        help: str,
        labels: Sequence[str],
        buckets: Optional[Sequence[float]] = None,
    ) -> Family:
        with self._mu:
            fam = self._families.get(name)
            if fam is not None:
                if fam.kind != kind or fam.labelnames != tuple(labels):
                    raise ValueError(
                        f"metric {name!r} already registered as {fam.kind}"
                        f"{fam.labelnames}; cannot re-register as {kind}"
                        f"{tuple(labels)}"
                    )
                return fam
            fam = Family(self, name, help, kind, labels, buckets)
            self._families[name] = fam
            return fam

    def counter(self, name: str, help: str = "", labels: Sequence[str] = ()) -> Family:
        return self._register("counter", name, help, labels)

    def gauge(self, name: str, help: str = "", labels: Sequence[str] = ()) -> Family:
        return self._register("gauge", name, help, labels)

    def histogram(
        self,
        name: str,
        help: str = "",
        labels: Sequence[str] = (),
        buckets: Optional[Sequence[float]] = None,
    ) -> Family:
        return self._register("histogram", name, help, labels, buckets)

    # ---- snapshot / reset -------------------------------------------------
    def snapshot(self) -> Dict[str, Any]:
        """Deterministic plain-dict snapshot (sorted family names, sorted
        label tuples), fully decoupled from live children."""
        with self._mu:
            fams = sorted(self._families.items())
        return {name: fam._snapshot() for name, fam in fams}

    def reset(self) -> None:
        """Zero every child in place; registrations (and child identity --
        instrumented code holds direct references) survive."""
        with self._mu:
            fams = list(self._families.values())
        for fam in fams:
            with fam._mu:
                children = list(fam._children.values())
            for child in children:
                child._reset()

    # ---- exporters ---------------------------------------------------------
    def render_json(self, snapshot: Optional[Mapping[str, Any]] = None) -> bytes:
        """Canonical JSON (sorted keys, compact separators): equal
        snapshots always render to identical bytes."""
        snap = self.snapshot() if snapshot is None else snapshot
        return json.dumps(
            snap, sort_keys=True, separators=(",", ":"), allow_nan=False
        ).encode()

    def render_prometheus(
        self, snapshot: Optional[Mapping[str, Any]] = None
    ) -> bytes:
        """Prometheus text exposition format (version 0.0.4)."""
        snap = self.snapshot() if snapshot is None else snapshot
        lines: List[str] = []
        for name, fam in snap.items():
            if fam["help"]:
                lines.append(f"# HELP {name} {fam['help']}")
            lines.append(f"# TYPE {name} {fam['type']}")
            for s in fam["samples"]:
                labels = s["labels"]
                if fam["type"] == "histogram":
                    for b in s["buckets"]:
                        lines.append(
                            name + "_bucket"
                            + _labelstr({**labels, "le": _fmt(b["le"])})
                            + f" {b['count']}"
                        )
                    lines.append(
                        name + "_bucket" + _labelstr({**labels, "le": "+Inf"})
                        + f" {s['count']}"
                    )
                    lines.append(name + "_sum" + _labelstr(labels) + f" {_fmt(s['sum'])}")
                    lines.append(name + "_count" + _labelstr(labels) + f" {s['count']}")
                else:
                    lines.append(name + _labelstr(labels) + f" {_fmt(s['value'])}")
        return ("\n".join(lines) + "\n").encode()


def _fmt(v: float) -> str:
    """Prometheus number formatting: integers without the trailing .0."""
    if isinstance(v, float) and math.isfinite(v) and v == int(v) and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


def _escape(v: str) -> str:
    return v.replace("\\", r"\\").replace('"', r"\"").replace("\n", r"\n")


def _labelstr(labels: Mapping[str, Any]) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{_escape(str(v))}"' for k, v in sorted(labels.items()))
    return "{" + inner + "}"


#: THE default registry every instrumented subsystem registers into (and
#: the one ``GET /v1/metrics`` serves). Honors ``REPRO_OBS_DISABLED=1``.
_DEFAULT = Registry()


def get_registry() -> Registry:
    return _DEFAULT


def set_disabled(disabled: Optional[bool] = None) -> bool:
    """Flip the default registry's kill switch; ``None`` re-reads
    :data:`DISABLED_ENV` (how benchmarks A/B the instrumentation overhead
    in-process). Returns the new state."""
    if disabled is None:
        disabled = os.environ.get(DISABLED_ENV, "") == "1"
    _DEFAULT.disabled = bool(disabled)
    return _DEFAULT.disabled
