"""Core library: the paper's contribution -- analytical area/time models and
the non-linear codesign optimizer (plus the TPU re-instantiation used by the
LM framework's mesh/sharding autotuner)."""

from .area import (  # noqa: F401
    GTX980,
    MAXWELL,
    TITAN_X,
    HardwarePoint,
    LinearAreaModel,
    cacheless,
)
from .codesign import (  # noqa: F401
    CodesignResult,
    HardwareSpace,
    codesign,
    enumerate_hw_space,
    evaluate_fixed_hw,
)
from .pareto import pareto_front, pareto_mask  # noqa: F401
from .solver import LATTICE_2D, LATTICE_3D, TileLattice, refine_point, solve_cell  # noqa: F401

# .sweep imports jax at module scope (~1s); load it lazily (PEP 562) so the
# pure-NumPy oracle/area paths keep the seed's cheap `import repro.core`.
_SWEEP_EXPORTS = (
    "HAVE_JAX",
    "device_count",
    "refine_points",
    "sweep_cell",
    "sweep_cells",
    "sweep_cells_sharded",
)


def __getattr__(name):
    if name in _SWEEP_EXPORTS:
        from . import sweep

        return getattr(sweep, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
from .timemodel import (  # noqa: F401
    MAXWELL_GPU,
    STENCILS,
    TITANX_GPU,
    GPUSpec,
    ProblemSize,
    StencilSpec,
    stencil_gflops,
    stencil_time,
)
from .workload import Workload, WorkloadCell, paper_sizes, paper_workload  # noqa: F401
