"""Edge-case coverage for the NumPy reference solver + Pareto extraction
(single point, all-dominated, ties) -- pure-NumPy, runs everywhere."""

import numpy as np
import pytest

from repro.core import MAXWELL_GPU, STENCILS, ProblemSize
from repro.core.pareto import pareto_front, pareto_mask
from repro.core.solver import LATTICE_2D, TileLattice, decode_index, refine_point, solve_cell


# ---------------------------------------------------------------------------
# pareto_front / pareto_mask
# ---------------------------------------------------------------------------
def test_pareto_single_point():
    c, p, idx = pareto_front(np.array([10.0]), np.array([5.0]))
    assert idx.tolist() == [0]
    assert c.tolist() == [10.0] and p.tolist() == [5.0]


def test_pareto_all_dominated_by_one():
    """One point dominates everything: the front is exactly that point."""
    cost = np.array([5.0, 10.0, 20.0, 30.0])
    perf = np.array([100.0, 90.0, 50.0, 10.0])  # [0] dominates all
    mask = pareto_mask(cost, perf)
    assert mask.tolist() == [True, False, False, False]


def test_pareto_cost_ties_keep_best_performer_only():
    cost = np.array([10.0, 10.0, 10.0, 20.0])
    perf = np.array([50.0, 70.0, 60.0, 80.0])
    mask = pareto_mask(cost, perf)
    assert mask.tolist() == [False, True, False, True]


def test_pareto_perf_ties_at_same_cost():
    """Exact duplicates: exactly one representative survives."""
    cost = np.array([10.0, 10.0])
    perf = np.array([50.0, 50.0])
    assert pareto_mask(cost, perf).sum() == 1


def test_pareto_nonfinite_points_never_on_front():
    cost = np.array([1.0, 2.0, np.inf, 3.0])
    perf = np.array([1.0, np.nan, 5.0, 2.0])
    mask = pareto_mask(cost, perf)
    assert not mask[1] and not mask[2]
    assert mask[0] and mask[3]


def test_pareto_front_sorted_and_strictly_improving():
    rng = np.random.default_rng(7)
    cost = rng.uniform(1, 100, 200)
    perf = rng.uniform(1, 100, 200)
    fc, fp, idx = pareto_front(cost, perf)
    assert np.all(np.diff(fc) > 0)  # unique, ascending cost
    assert np.all(np.diff(fp) > 0)  # strictly better perf as cost grows
    np.testing.assert_array_equal(cost[idx], fc)


def test_pareto_shape_mismatch_raises():
    with pytest.raises(ValueError, match="shape mismatch"):
        pareto_mask(np.ones(3), np.ones(4))


# ---------------------------------------------------------------------------
# refine_point
# ---------------------------------------------------------------------------
HW = (16.0, 128.0, 96.0)


def _lattice_opt(st, size):
    t, i = solve_cell(
        st, MAXWELL_GPU, size,
        np.array([HW[0]]), np.array([HW[1]]), np.array([HW[2]]), LATTICE_2D,
    )
    return float(t[0]), decode_index(LATTICE_2D, int(i[0]))


def test_refine_from_lattice_optimum_is_locally_exact():
    st = STENCILS["jacobi2d"]
    size = ProblemSize(4096, 4096, 1024)
    t0, sw0 = _lattice_opt(st, size)
    t1, sw1 = refine_point(st, MAXWELL_GPU, size, HW, sw0)
    assert t1 <= t0 * (1 + 1e-12)
    # alignment survives the descent
    assert sw1["t_s2"] % 32 == 0 and sw1["t_t"] % 2 == 0
    assert sw1["t_s1"] >= 1 and sw1["k"] >= 1


def test_refine_single_round_when_already_optimal():
    """Refining a refined point is a fixed point (terminates round one)."""
    st = STENCILS["heat2d"]
    size = ProblemSize(8192, 8192, 2048)
    _, sw0 = _lattice_opt(st, size)
    t1, sw1 = refine_point(st, MAXWELL_GPU, size, HW, sw0)
    t2, sw2 = refine_point(st, MAXWELL_GPU, size, HW, sw1)
    assert sw2 == sw1
    assert t2 == t1


def test_refine_respects_max_rounds():
    """max_rounds=0 must return the starting point untouched."""
    st = STENCILS["jacobi2d"]
    size = ProblemSize(4096, 4096, 1024)
    _, sw0 = _lattice_opt(st, size)
    t, sw = refine_point(st, MAXWELL_GPU, size, HW, sw0, max_rounds=0)
    assert sw == sw0


def test_refine_from_infeasible_start_cannot_reach_finite_lie():
    """Starting from an infeasible tile, the descent either escapes to a
    feasible neighbor (finite time) or reports +inf -- never a finite time
    for an infeasible configuration."""
    st = STENCILS["jacobi2d"]
    size = ProblemSize(4096, 4096, 1024)
    sw0 = {"t_s1": 1, "t_s2": 2048, "t_t": 2, "k": 32, "t_s3": 1}  # violates eq. 12/14
    t, sw = refine_point(st, MAXWELL_GPU, size, HW, sw0)
    from repro.core.timemodel import feasible

    if np.isfinite(t):
        assert bool(
            feasible(
                st, MAXWELL_GPU, HW[0], HW[1], HW[2],
                sw["t_s1"], sw["t_s2"], sw["t_t"], sw["k"], sw["t_s3"],
            )
        )


def test_solve_cell_empty_hardware():
    """H=0 is a degenerate but legal sweep."""
    st = STENCILS["jacobi2d"]
    size = ProblemSize(4096, 4096, 1024)
    t, i = solve_cell(
        st, MAXWELL_GPU, size, np.array([]), np.array([]), np.array([]), LATTICE_2D
    )
    assert t.shape == (0,) and i.shape == (0,)


def test_solve_cell_chunk_zero_means_unchunked():
    """chunk<=0 is 'no chunking' -- same contract as the jax engine."""
    st = STENCILS["jacobi2d"]
    size = ProblemSize(4096, 4096, 1024)
    hw = (np.array([16.0, 8.0]), np.array([128.0, 64.0]), np.array([96.0, 48.0]))
    t_ref, i_ref = solve_cell(st, MAXWELL_GPU, size, *hw, LATTICE_2D)
    t0, i0 = solve_cell(st, MAXWELL_GPU, size, *hw, LATTICE_2D, chunk=0)
    np.testing.assert_array_equal(t0, t_ref)
    np.testing.assert_array_equal(i0, i_ref)


def test_single_candidate_lattice():
    """A one-point lattice degenerates to a plain feasibility check."""
    st = STENCILS["jacobi2d"]
    size = ProblemSize(4096, 4096, 1024)
    lat = TileLattice(t_s1=(8,), t_s2=(64,), t_t=(16,), k=(2,))
    t, i = solve_cell(
        st, MAXWELL_GPU, size,
        np.array([16.0]), np.array([128.0]), np.array([96.0]), lat,
    )
    assert i[0] == 0 and np.isfinite(t[0])
