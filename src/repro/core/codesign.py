"""The codesign optimization driver (paper §IV, eqs. 7-18).

Implements the separability decomposition of eq. (18): exhaustive
enumeration of the hardware space ``HP`` x an independent tile-size
minimization per (stencil, size) cell. Because the per-cell optima are
cached as a ``(cells x hardware)`` matrix, the §V.B "workload sensitivity
for free" analyses (re-weighting frequencies, single-stencil workloads)
are simple matrix re-reductions -- no re-solving.

The inner solves run on one of three engines:

* ``"jax"`` -- the compiled sweep of :mod:`repro.core.sweep` (jitted vmap
  over hardware x tile lattice; CPU/GPU/TPU); the default whenever jax is
  importable and the hardware space is big enough to amortize compilation;
* ``"sharded"`` -- the same fused body with the hardware axis partitioned
  over a 1-D device mesh (``shard_map`` + ``NamedSharding``); bit-identical
  to ``"jax"`` and the ``engine="auto"`` promotion whenever more than one
  device is attached (the ``devices=`` knob picks the mesh);
* ``"numpy"`` -- the seed's chunked-broadcast reference solver
  (:func:`repro.core.solver.solve_cell`), kept bit-exact as the oracle the
  jax engines are equivalence-tested against.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.obs.metrics import get_registry as _obs_registry
from repro.obs.trace import span

from .area import GTX980, TITAN_X, HardwarePoint, LinearAreaModel, MAXWELL
from .pareto import pareto_mask
from .solver import LATTICE_2D, LATTICE_3D, TileLattice, decode_index, solve_cell
from .timemodel import GPUSpec, MAXWELL_GPU, ProblemSize, stencil_time
from .workload import Workload, WorkloadCell

# ---- observability (repro.obs; no-ops under REPRO_OBS_DISABLED=1) --------
_REG = _obs_registry()
_M_CODESIGN_SECONDS = _REG.histogram(
    "repro_codesign_seconds",
    "wall time of one full codesign() sweep (all cells x hardware "
    "points), by resolved engine and cell family",
    labels=("engine", "family"),
)
_M_CODESIGN_CELLS = _REG.counter(
    "repro_codesign_cells_total",
    "workload cells swept by codesign(), by resolved engine",
    labels=("engine",),
)

__all__ = [
    "HardwareSpace",
    "CodesignResult",
    "enumerate_hw_space",
    "codesign",
    "evaluate_fixed_hw",
]

#: Paper §IV.B parameter ranges: n_SM in [2, 32] even; n_V in [32, 2048]
#: multiple of 32; M_SM multiples of 48 kB up to 480 kB, plus {12, 24, 36}.
N_SM_RANGE = tuple(range(2, 33, 2))
N_V_RANGE = tuple(range(32, 2049, 32))
M_SM_RANGE = (12, 24, 36) + tuple(48 * j for j in range(1, 11))


@dataclasses.dataclass
class HardwareSpace:
    """Flattened feasible hardware points + their (cache-less) areas."""

    n_sm: np.ndarray
    n_v: np.ndarray
    m_sm: np.ndarray
    area: np.ndarray

    def __len__(self) -> int:
        return self.n_sm.shape[0]

    def point(self, i: int) -> HardwarePoint:
        return HardwarePoint(
            n_sm=int(self.n_sm[i]), n_v=int(self.n_v[i]), m_sm=float(self.m_sm[i])
        )

    def downsample(self, step: int) -> "HardwareSpace":
        """Every ``step``-th point -- quick demos / smoke benchmarks."""
        keep = np.arange(len(self)) % step == 0
        return HardwareSpace(
            self.n_sm[keep], self.n_v[keep], self.m_sm[keep], self.area[keep]
        )


def enumerate_hw_space(
    area_model: LinearAreaModel = MAXWELL,
    max_area: float = 650.0,
    min_area: float = 0.0,
    n_sm_range: Sequence[int] = N_SM_RANGE,
    n_v_range: Sequence[int] = N_V_RANGE,
    m_sm_range: Sequence[int] = M_SM_RANGE,
) -> HardwareSpace:
    """All hardware points within the area budget. Proposed designs are
    cache-less (§V.A: the HHC compiler performs explicit data transfers and
    does not use caches), so L1 = L2 = 0 in the area term."""
    n_sm, n_v, m_sm = np.meshgrid(
        np.array(n_sm_range, np.float64),
        np.array(n_v_range, np.float64),
        np.array(m_sm_range, np.float64),
        indexing="ij",
    )
    n_sm, n_v, m_sm = n_sm.ravel(), n_v.ravel(), m_sm.ravel()
    area = area_model.area(n_sm, n_v, m_sm, r_vu=2.0, l1_smpair=0.0, l2_kb=0.0)
    keep = (area <= max_area) & (area >= min_area)
    return HardwareSpace(n_sm[keep], n_v[keep], m_sm[keep], area[keep])


def _stencil_groups(
    workload: Workload, indices: Optional[Sequence[int]] = None
) -> Dict[str, Tuple[object, List[int], np.ndarray]]:
    """Cells grouped per stencil family for batched dispatch: name ->
    (stencil spec, cell indices, (P, 4) sizes as (s1, s2, s3, t) rows).
    Shared by the sweep driver and ``CodesignResult.refine`` so the two
    batching paths cannot drift. Grouping is by stencil *name* -- cells of
    one family must share a spec (and, by dims, a lattice)."""
    groups: Dict[str, List[int]] = {}
    for ci in range(len(workload.cells)) if indices is None else indices:
        groups.setdefault(workload.cells[ci].stencil.name, []).append(ci)
    out: Dict[str, Tuple[object, List[int], np.ndarray]] = {}
    for name, cis in groups.items():
        sizes = np.array(
            [
                (c.size.s1, c.size.s2, c.size.s3, c.size.t)
                for c in (workload.cells[ci] for ci in cis)
            ],
            np.float64,
        )
        out[name] = (workload.cells[cis[0]].stencil, cis, sizes)
    return out


@dataclasses.dataclass
class CodesignResult:
    """Per-cell optimal times for every hardware point (eq. 18 inner solves)
    plus workload-level reductions."""

    workload: Workload
    gpu: GPUSpec
    hw: HardwareSpace
    cell_time: np.ndarray  # (C, H) optimal T_alg per cell per hw point
    cell_tile_idx: np.ndarray  # (C, H) winning lattice index (-1 infeasible)
    lattices: List[TileLattice]  # per cell

    # ---- reductions -------------------------------------------------------
    def cell_freqs(self) -> np.ndarray:
        """(C,) default workload frequencies."""
        return np.array([c.freq for c in self.workload.cells], np.float64)

    def cell_flops(self) -> np.ndarray:
        """(C,) useful flops per cell -- the gflops numerator, exposed so
        artifact consumers can re-reduce without Workload objects."""
        return np.array(
            [c.stencil.flops_per_point * c.size.points for c in self.workload.cells],
            np.float64,
        )

    def weighted_time(self, freqs: Optional[np.ndarray] = None) -> np.ndarray:
        """Eq. (17) objective per hardware point; default = workload freqs.
        Passing new ``freqs`` is the §V.B sensitivity-for-free path."""
        if freqs is None:
            freqs = self.cell_freqs()
        freqs = np.asarray(freqs, np.float64)
        return freqs @ self.cell_time

    def gflops(self, freqs: Optional[np.ndarray] = None) -> np.ndarray:
        """Workload performance: weighted useful flops / weighted time."""
        if freqs is None:
            freqs = self.cell_freqs()
        freqs = np.asarray(freqs, np.float64)
        return (freqs @ self.cell_flops()) / self.weighted_time(freqs) / 1.0e9

    def pareto(self, freqs: Optional[np.ndarray] = None) -> np.ndarray:
        """Pareto mask over (area, GFLOP/s)."""
        return pareto_mask(self.hw.area, self.gflops(freqs))

    def best(self, max_area: float = np.inf, freqs=None) -> Tuple[int, float]:
        """(index, GFLOP/s) of the best design within an area cap."""
        g = self.gflops(freqs)
        g = np.where(self.hw.area <= max_area, g, -np.inf)
        i = int(np.argmax(g))
        return i, float(g[i])

    def tiles_for(self, cell_index: int, hw_index: int) -> Dict[str, int]:
        idx = int(self.cell_tile_idx[cell_index, hw_index])
        if idx < 0:
            raise ValueError("infeasible cell/hw combination")
        return decode_index(self.lattices[cell_index], idx)

    def refine(
        self, hw_index: int
    ) -> Tuple[np.ndarray, List[Optional[Dict[str, int]]]]:
        """Polish every cell's lattice optimum at one reported hardware
        point with the batched coordinate descent of
        :func:`repro.core.sweep.refine_points` (all cells of a stencil
        descend together in one compiled call per round, instead of the
        seed's per-point Python loops).

        Returns ``(times (C,), tile dicts)``; a cell that is infeasible at
        this hardware point keeps its +inf time and gets ``None`` tiles
        (there is no valid configuration to report).
        """
        from . import sweep

        times = self.cell_time[:, hw_index].copy()
        tiles: List[Optional[Dict[str, int]]] = [None] * len(times)
        point = self.hw.point(hw_index)
        hw_row = (float(point.n_sm), float(point.n_v), float(point.m_sm))
        feasible = [
            ci
            for ci in range(len(self.workload.cells))
            if self.cell_tile_idx[ci, hw_index] >= 0
        ]
        for st, cis, sizes in _stencil_groups(self.workload, feasible).values():
            start = {ci: self.tiles_for(ci, hw_index) for ci in cis}
            sw0 = np.array(
                [[start[ci][k] for k in sweep.SW_NAMES] for ci in cis],
                np.float64,
            )
            if sweep.HAVE_JAX:
                _, sw_ref = sweep.refine_points(
                    st, self.gpu, sizes, np.tile(hw_row, (len(cis), 1)), sw0
                )
            else:  # seed fallback: sequential scans
                from .solver import refine_point

                sw_ref = np.empty_like(sw0)
                for j, ci in enumerate(cis):
                    _, swd = refine_point(
                        st, self.gpu, self.workload.cells[ci].size, hw_row,
                        dict(start[ci]),
                    )
                    sw_ref[j] = [swd[k] for k in sweep.SW_NAMES]
            # re-evaluate BOTH candidates in the float64 oracle model:
            # acceptance must never be decided by float32 evaluation noise,
            # and reported times must reproduce at the reported tiles
            # regardless of which engine produced the lattice optimum.
            size64 = ProblemSize(
                s1=sizes[:, 0], s2=sizes[:, 1], t=sizes[:, 3], s3=sizes[:, 2]
            )

            def t64(sw):
                return stencil_time(
                    st, self.gpu, size64, hw_row[0], hw_row[1], hw_row[2],
                    sw[:, 0], sw[:, 1], sw[:, 2], sw[:, 3], sw[:, 4],
                )

            t_ref, t_start = t64(sw_ref), t64(sw0)
            for j, ci in enumerate(cis):
                # keep the lattice optimum unless the descent improved it
                if t_ref[j] < t_start[j]:
                    times[ci] = t_ref[j]
                    tiles[ci] = sweep.decode_sw(sw_ref[j])
                else:
                    times[ci] = t_start[j]
                    tiles[ci] = start[ci]
        return times, tiles

    def routing_metadata(self) -> Dict[str, object]:
        """The attributes a multi-artifact front-end routes on, derivable
        without touching any array: GPU target, stencil set, workload name.
        Persisted verbatim as the manifest's ``"routing"`` block so a
        gateway can index hundreds of artifacts from their (small) JSON
        manifests alone -- no mmap, no npz decompression."""
        return {
            "gpu": self.gpu.name,
            "workload": self.workload.name,
            "stencils": sorted({c.stencil.name for c in self.workload.cells}),
        }

    # ---- artifact serialization (repro.service.store persistence hooks) ---
    def artifact_payload(self) -> Tuple[dict, Dict[str, np.ndarray]]:
        """(manifest, arrays) split for on-disk persistence.

        The manifest is pure JSON (workload cells with full stencil specs,
        GPU constants, the per-cell lattice tables, and the ``"routing"``
        block of :meth:`routing_metadata`); the arrays dict holds the big
        matrices. :meth:`from_artifact_payload` inverts it exactly: JSON
        round-trips float64 losslessly, so a reloaded result's
        ``weighted_time``/``pareto`` are bit-identical.
        """
        unique: List[TileLattice] = []
        lat_idx: List[int] = []
        for lat in self.lattices:
            if lat not in unique:
                unique.append(lat)
            lat_idx.append(unique.index(lat))
        manifest = {
            "workload": {
                "name": self.workload.name,
                "cells": [
                    {
                        "stencil": dataclasses.asdict(c.stencil),
                        "size": {
                            "s1": int(c.size.s1), "s2": int(c.size.s2),
                            "t": int(c.size.t), "s3": int(c.size.s3),
                        },
                        "freq": float(c.freq),
                        "lattice": lat_idx[i],
                    }
                    for i, c in enumerate(self.workload.cells)
                ],
            },
            "gpu": dataclasses.asdict(self.gpu),
            "lattices": [
                {k: list(getattr(lat, k)) for k in ("t_s1", "t_s2", "t_t", "k", "t_s3")}
                for lat in unique
            ],
            "routing": self.routing_metadata(),
        }
        arrays = {
            "cell_time": np.asarray(self.cell_time, np.float64),
            "cell_tile_idx": np.asarray(self.cell_tile_idx, np.int64),
            "hw_n_sm": np.asarray(self.hw.n_sm, np.float64),
            "hw_n_v": np.asarray(self.hw.n_v, np.float64),
            "hw_m_sm": np.asarray(self.hw.m_sm, np.float64),
            "hw_area": np.asarray(self.hw.area, np.float64),
        }
        return manifest, arrays

    @staticmethod
    def parse_manifest(
        manifest: dict,
    ) -> Tuple[Workload, GPUSpec, List[TileLattice]]:
        """The JSON-only half of :meth:`from_artifact_payload`:
        ``(workload, gpu, per-cell lattices)`` from a stored manifest,
        touching no arrays. A service front-end uses this to reconstruct a
        server's configuration from a discovered artifact without paging
        in its ``(C, H)`` matrix."""
        from .timemodel import StencilSpec  # local: avoid cycle at import

        lattices_tbl = [
            TileLattice(**{k: tuple(int(x) for x in v) for k, v in d.items()})
            for d in manifest["lattices"]
        ]
        cells = []
        lattices: List[TileLattice] = []
        for c in manifest["workload"]["cells"]:
            st = StencilSpec(**c["stencil"])
            sz = c["size"]
            size = ProblemSize(s1=sz["s1"], s2=sz["s2"], t=sz["t"], s3=sz["s3"])
            cells.append(WorkloadCell(st, size, c["freq"]))
            lattices.append(lattices_tbl[c["lattice"]])
        workload = Workload(manifest["workload"]["name"], tuple(cells))
        gpu = GPUSpec(**manifest["gpu"])
        return workload, gpu, lattices

    @classmethod
    def from_artifact_payload(
        cls, manifest: dict, arrays: Dict[str, np.ndarray]
    ) -> "CodesignResult":
        """Rebuild a result from :meth:`artifact_payload` output. Array
        values may be mmap-backed; they are used as-is (no copy)."""
        workload, gpu, lattices = cls.parse_manifest(manifest)
        hw = HardwareSpace(
            n_sm=np.asarray(arrays["hw_n_sm"], np.float64),
            n_v=np.asarray(arrays["hw_n_v"], np.float64),
            m_sm=np.asarray(arrays["hw_m_sm"], np.float64),
            area=np.asarray(arrays["hw_area"], np.float64),
        )
        return cls(
            workload=workload,
            gpu=gpu,
            hw=hw,
            cell_time=np.asarray(arrays["cell_time"]),
            cell_tile_idx=np.asarray(arrays["cell_tile_idx"]),
            lattices=lattices,
        )


#: below this many hardware points the jit compile cannot pay for itself;
#: ``engine="auto"`` falls back to the NumPy reference solver.
_AUTO_MIN_HW = 64


def _devices_engine(engine: str, devices) -> str:
    """An explicit device selection IS a request for the mesh engine:
    promote auto (even below the numpy floor -- the caller knows their
    mesh) and reject engines that would silently drop the knob. Cheap
    (never touches jax), so key-time callers can share the rule."""
    if devices is None or engine == "sharded":
        return engine
    if engine == "auto":
        return "sharded"
    raise ValueError(
        f"devices= only applies to engine='sharded' (or 'auto'); "
        f"engine={engine!r} would silently ignore it"
    )


def _resolve_engine(engine: str, n_hw: int, devices=None) -> str:
    if engine not in ("auto", "jax", "sharded", "numpy"):
        raise ValueError(
            f"unknown engine {engine!r} (want auto|jax|sharded|numpy)"
        )
    engine = _devices_engine(engine, devices)
    # decide every numpy-bound case before touching .sweep: importing it
    # loads jax (~1s), which the lazy PEP-562 loader exists to avoid
    if engine == "numpy" or (engine == "auto" and n_hw < _AUTO_MIN_HW):
        return "numpy"
    from . import sweep

    if engine == "auto":
        if not sweep.HAVE_JAX:
            return "numpy"
        # promote to the mesh engine whenever there is a mesh to feed;
        # on one device "sharded" degenerates to "jax" (same program),
        # so the single-device jit path stays the simpler choice.
        if sweep.device_count() > 1 and sweep.HAVE_SHARD_MAP:
            return "sharded"
        return "jax"
    if not sweep.HAVE_JAX:
        raise ModuleNotFoundError(
            f"engine={engine!r} requested but jax is not installed; "
            "use engine='auto' (soft fallback) or engine='numpy'"
        )
    return engine


def codesign(
    workload: Workload,
    gpu: GPUSpec = MAXWELL_GPU,
    area_model: LinearAreaModel = MAXWELL,
    max_area: float = 650.0,
    hw: Optional[HardwareSpace] = None,
    lattice_2d: TileLattice = LATTICE_2D,
    lattice_3d: TileLattice = LATTICE_3D,
    chunk: Optional[int] = None,
    engine: str = "auto",
    devices=None,
) -> CodesignResult:
    """Solve eq. (18): for every feasible hardware point, the optimal tile
    sizes (and time) of every workload cell.

    ``engine`` picks the inner solver: ``"jax"`` (compiled sweep),
    ``"sharded"`` (hardware axis over a device mesh), ``"numpy"`` (seed
    reference), or ``"auto"`` (sharded when >1 device is attached, else
    jax, else numpy). ``chunk`` bounds solver memory (hardware points per
    slab -- per device on the sharded engine); ``None`` uses each engine's
    default. ``devices`` is ``None`` for every attached device, an int for
    the first n, or an explicit device sequence; setting it implies the
    mesh engine (``"auto"`` promotes to ``"sharded"``, non-mesh engines
    reject it rather than silently ignore it).

    Dispatches on the workload's cell family: LM op-graph workloads
    (``workload.family == "lm"``) route to :func:`repro.core.lmcells
    .lm_codesign`, whose hardware axis is mesh factorizations of a chip
    budget (``hw`` must then be an :class:`~repro.core.lmcells
    .LMHardwareSpace` or None); the stencil-specific knobs (gpu, area
    model, tile lattices) do not apply there.
    """
    if getattr(workload, "family", "stencil") == "lm":
        from .lmcells import lm_codesign, resolve_lm_engine

        t0 = time.perf_counter()
        with span("codesign", family="lm"):
            result = lm_codesign(workload, hw=hw, engine=engine)
        eng = resolve_lm_engine(engine)
        _M_CODESIGN_SECONDS.labels(engine=eng, family="lm").observe(
            time.perf_counter() - t0
        )
        _M_CODESIGN_CELLS.labels(engine=eng).inc(len(workload.cells))
        return result
    if hw is None:
        hw = enumerate_hw_space(area_model, max_area=max_area)
    eng = _resolve_engine(engine, len(hw), devices)
    C, H = len(workload.cells), len(hw)
    cell_time = np.empty((C, H))
    cell_idx = np.empty((C, H), dtype=np.int64)
    lattices: List[TileLattice] = [
        lattice_3d if c.stencil.dims == 3 else lattice_2d for c in workload.cells
    ]
    t0 = time.perf_counter()
    with span("codesign", family="stencil", engine=eng, cells=C, hw=H):
        if eng in ("jax", "sharded"):
            # one compiled dispatch per stencil family: all of a stencil's
            # problem sizes ride the sweep's extra vmap axis (amortizes
            # dispatch/launch overhead on accelerators; same argmins).
            from . import sweep

            for st, cis, sizes in _stencil_groups(workload).values():
                if eng == "sharded":
                    t, i = sweep.sweep_cells_sharded(
                        st, gpu, sizes, hw.n_sm, hw.n_v, hw.m_sm,
                        lattices[cis[0]], chunk, devices=devices,
                    )
                else:
                    t, i = sweep.sweep_cells(
                        st, gpu, sizes, hw.n_sm, hw.n_v, hw.m_sm,
                        lattices[cis[0]], chunk,
                    )
                for j, ci in enumerate(cis):
                    cell_time[ci] = t[j]
                    cell_idx[ci] = i[j]
        else:
            np_chunk = 512 if chunk is None else chunk
            for ci, cell in enumerate(workload.cells):
                t, i = solve_cell(
                    cell.stencil, gpu, cell.size, hw.n_sm, hw.n_v, hw.m_sm,
                    lattices[ci], np_chunk,
                )
                cell_time[ci] = t
                cell_idx[ci] = i
            # the seed oracle has no per-dispatch hook of its own: account
            # its cell evaluations here so engine throughput is comparable
            from repro.core.sweep import _M_CELL_EVALS

            _M_CELL_EVALS.labels(engine="numpy").inc(C * H)
    _M_CODESIGN_SECONDS.labels(engine=eng, family="stencil").observe(
        time.perf_counter() - t0
    )
    _M_CODESIGN_CELLS.labels(engine=eng).inc(C)
    return CodesignResult(workload, gpu, hw, cell_time, cell_idx, lattices)


def evaluate_fixed_hw(
    workload: Workload,
    point: HardwarePoint,
    gpu: GPUSpec = MAXWELL_GPU,
    lattice_2d: TileLattice = LATTICE_2D,
    lattice_3d: TileLattice = LATTICE_3D,
    engine: str = "auto",
) -> Tuple[float, float]:
    """(weighted time, GFLOP/s) of a *fixed* hardware point (e.g. the stock
    GTX-980 / Titan X baselines in Fig. 3) with per-cell optimal tiles --
    i.e. the paper's eq. (2) tile-size-selection problem."""
    hw = HardwareSpace(
        n_sm=np.array([point.n_sm], np.float64),
        n_v=np.array([point.n_v], np.float64),
        m_sm=np.array([point.m_sm], np.float64),
        area=np.array([MAXWELL.area_point(point)]),
    )
    res = codesign(
        workload, gpu=gpu, hw=hw, lattice_2d=lattice_2d, lattice_3d=lattice_3d,
        engine=engine,
    )
    return float(res.weighted_time()[0]), float(res.gflops()[0])


#: Stock baseline points, re-exported for benchmarks.
STOCK = {"gtx980": GTX980, "titanx": TITAN_X}
