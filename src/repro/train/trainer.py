"""Fault-tolerant training loop.

Production posture on a single process:
* **checkpoint/restart**: async atomic checkpoints every N steps; any
  exception inside the step triggers restore-from-latest + replay (the data
  pipeline is stateless-deterministic, so the replayed batches are
  identical); a bounded failure budget prevents crash loops;
* **preemption**: a preemption file (what a real cluster delivers as
  SIGTERM) causes a final synchronous checkpoint + clean exit;
* **straggler mitigation**: a step-time watchdog tracks a robust moving
  median; steps slower than ``straggler_factor`` x median are recorded and
  surfaced (on a real fleet this feeds the scheduler's hot-swap; here it
  also exercises the accounting path);
* **elastic restarts**: checkpoints are mesh-agnostic (see
  ``repro.checkpoint``), so a Trainer constructed over a *different* mesh
  restores the same logical state -- tested in tests/test_elastic.py with a
  different fake-device count.
"""

from __future__ import annotations

import dataclasses
import os
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from ..checkpoint.checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint
from ..configs.base import ArchConfig, ShapeSpec
from ..data.pipeline import DataConfig, SyntheticPipeline
from ..models.model import init_model
from ..sharding.partition import opt_state_specs, param_specs
from .train_step import TrainConfig, init_train_state, make_train_step

__all__ = ["Trainer", "TrainerConfig"]


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 25
    keep: int = 3
    max_failures: int = 3
    straggler_factor: float = 2.0
    preempt_file: Optional[str] = None
    log_every: int = 10
    batch_override: Optional[int] = None
    seq_override: Optional[int] = None


class Trainer:
    def __init__(
        self,
        cfg: ArchConfig,
        shape: ShapeSpec,
        mesh: Mesh,
        tcfg: TrainConfig = TrainConfig(),
        run_cfg: TrainerConfig = TrainerConfig(),
        dcfg: DataConfig = DataConfig(),
        fault_hook: Optional[Callable[[int], None]] = None,
    ):
        self.cfg, self.shape, self.mesh = cfg, shape, mesh
        self.tcfg, self.run_cfg, self.dcfg = tcfg, run_cfg, dcfg
        self.fault_hook = fault_hook
        self.step_fn = make_train_step(cfg, tcfg, mesh)
        self.checkpointer = AsyncCheckpointer(run_cfg.ckpt_dir, keep=run_cfg.keep)
        self.step_times: List[float] = []
        self.stragglers: List[int] = []
        self.metrics_history: List[Dict[str, float]] = []
        self.failures = 0

    # ------------------------------------------------------------------
    def _state_shardings(self, state: Any):
        abstract = jax.eval_shape(lambda: init_model(self.cfg, jax.random.PRNGKey(0)))
        p = param_specs(self.cfg, abstract, self.mesh)
        o = opt_state_specs(self.cfg, abstract, self.mesh)
        specs = {"params": p, "opt": {"m": o, "v": o, "step": P()}}
        if "comp" in state:
            specs["comp"] = o
        return jax.tree.map(
            lambda s: NamedSharding(self.mesh, s), specs,
            is_leaf=lambda x: isinstance(x, P),
        )

    def _init_or_restore(self):
        state = init_train_state(self.cfg, self.tcfg, self.mesh)
        start = 0
        if latest_step(self.run_cfg.ckpt_dir) is not None:
            shardings = self._state_shardings(state)
            state, start, extra = restore_checkpoint(
                self.run_cfg.ckpt_dir, state, shardings=shardings
            )
            start = int(extra.get("next_step", start))
        return state, start

    def _is_straggler(self, dt: float) -> bool:
        if len(self.step_times) < 5:
            return False
        med = float(np.median(self.step_times[-50:]))
        return dt > self.run_cfg.straggler_factor * med

    def _preempted(self) -> bool:
        f = self.run_cfg.preempt_file
        return bool(f and os.path.exists(f))

    # ------------------------------------------------------------------
    def train(self) -> Dict[str, Any]:
        state, start = self._init_or_restore()
        step = start
        while step < self.run_cfg.steps:
            try:
                pipeline = SyntheticPipeline(
                    self.cfg, self.shape, self.dcfg, self.mesh, start_step=step,
                    batch_override=self.run_cfg.batch_override,
                    seq_override=self.run_cfg.seq_override,
                )
                for batch in pipeline:
                    if step >= self.run_cfg.steps:
                        break
                    if self._preempted():
                        self.checkpointer.wait()
                        self.checkpointer.save(step, state, {"next_step": step})
                        self.checkpointer.wait()
                        return self._summary(state, step, preempted=True)
                    t0 = time.perf_counter()
                    if self.fault_hook is not None:
                        self.fault_hook(step)
                    state, metrics = self.step_fn(state, batch)
                    metrics = {k: float(v) for k, v in metrics.items()}
                    dt = time.perf_counter() - t0
                    self.step_times.append(dt)
                    if self._is_straggler(dt):
                        self.stragglers.append(step)
                    self.metrics_history.append(dict(metrics, step=step, time=dt))
                    step += 1
                    if step % self.run_cfg.ckpt_every == 0:
                        self.checkpointer.save(step, state, {"next_step": step})
            except (KeyboardInterrupt,):
                raise
            except Exception as e:  # noqa: BLE001 -- restart-on-failure
                self.failures += 1
                if self.failures > self.run_cfg.max_failures:
                    raise RuntimeError(
                        f"exceeded failure budget ({self.failures})"
                    ) from e
                self.checkpointer.wait()
                if latest_step(self.run_cfg.ckpt_dir) is not None:
                    state, step = self._restore_after_failure(state)
                else:
                    state = init_train_state(self.cfg, self.tcfg, self.mesh)
                    step = 0
        self.checkpointer.wait()
        self.checkpointer.save(step, state, {"next_step": step})
        self.checkpointer.wait()
        return self._summary(state, step)

    def _restore_after_failure(self, state):
        shardings = self._state_shardings(state)
        state, ck_step, extra = restore_checkpoint(
            self.run_cfg.ckpt_dir, state, shardings=shardings
        )
        return state, int(extra.get("next_step", ck_step))

    def _summary(self, state, step, preempted: bool = False):
        return {
            "state": state,
            "step": step,
            "preempted": preempted,
            "failures": self.failures,
            "stragglers": self.stragglers,
            "metrics": self.metrics_history,
        }
