"""AdamW + schedule + gradient-compression tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # soft dep: skips, not errors

from repro.optim import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    global_norm,
    lr_at,
    quantize_int8,
    dequantize_int8,
    compress_grads,
)
from repro.optim.compression import compression_init


def test_adamw_matches_reference_math():
    """Single-tensor AdamW vs a hand-rolled numpy reference."""
    cfg = AdamWConfig(lr=1e-2, b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.01,
                      clip_norm=1e9, warmup_steps=0, total_steps=10**9)
    p = {"w": jnp.array([1.0, -2.0, 3.0], jnp.float32)}
    g = {"w": jnp.array([0.1, 0.2, -0.3], jnp.float32)}
    state = adamw_init(p)
    new_p, state, _ = adamw_update(p, g, state, cfg)

    m = 0.1 * np.array([0.1, 0.2, -0.3])
    v = 0.01 * np.array([0.1, 0.2, -0.3]) ** 2
    mhat, vhat = m / 0.1, v / 0.01
    lr = float(lr_at(cfg, 1))
    want = np.array([1.0, -2.0, 3.0]) - lr * (
        mhat / (np.sqrt(vhat) + 1e-8) + 0.01 * np.array([1.0, -2.0, 3.0])
    )
    np.testing.assert_allclose(np.asarray(new_p["w"]), want, rtol=1e-5)


def test_clipping_bounds_update():
    cfg = AdamWConfig(clip_norm=1.0, weight_decay=0.0, warmup_steps=0)
    p = {"w": jnp.zeros(4)}
    g = {"w": jnp.full(4, 100.0)}
    state = adamw_init(p)
    _, state, metrics = adamw_update(p, g, state, cfg)
    assert float(metrics["grad_norm"]) == pytest.approx(200.0, rel=1e-5)
    # post-clip first moment magnitude <= (1-b1) * clip_norm
    assert float(jnp.abs(state["m"]["w"]).max()) <= 0.1 * 1.0 + 1e-6


def test_schedule_warmup_and_decay():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=110, min_lr_ratio=0.1)
    assert float(lr_at(cfg, 0)) == 0.0
    assert float(lr_at(cfg, 5)) == pytest.approx(0.5)
    assert float(lr_at(cfg, 10)) == pytest.approx(1.0)
    assert float(lr_at(cfg, 110)) == pytest.approx(0.1, abs=1e-6)
    assert float(lr_at(cfg, 60)) == pytest.approx(0.55, abs=1e-6)


@settings(max_examples=50, deadline=None)
@given(st.lists(st.floats(-1e3, 1e3, allow_nan=False), min_size=1, max_size=64))
def test_quantize_roundtrip_error_bound(vals):
    x = jnp.asarray(vals, jnp.float32)
    q, s = quantize_int8(x)
    err = jnp.abs(dequantize_int8(q, s) - x).max()
    assert float(err) <= float(s) * 0.5 + 1e-9  # rounding: half a bin


def test_error_feedback_accumulates_residual():
    """With constant grads, error feedback makes the *average* dequantized
    gradient converge to the true gradient (unbiasedness over time)."""
    g = {"w": jnp.array([1e-3, 2.5e-3, -7e-4, 0.9], jnp.float32)}
    state = compression_init(g)
    total = jnp.zeros_like(g["w"])
    n = 64
    for _ in range(n):
        dq, state = compress_grads(g, state)
        total = total + dq["w"]
    # |avg - g| <= residual range / n = one int8 bin (~0.9/127) / 64 steps
    np.testing.assert_allclose(
        np.asarray(total / n), np.asarray(g["w"]), rtol=0.0, atol=1.5e-4
    )


def test_global_norm():
    t = {"a": jnp.ones(4), "b": jnp.full(9, 2.0)}
    assert float(global_norm(t)) == pytest.approx(np.sqrt(4 + 36), rel=1e-6)
