"""Service-level objectives: declared targets, measured burn rates.

The gateway's metrics (:mod:`repro.obs.metrics`) say *what happened*;
this module says *whether that is acceptable*. An :class:`SLOObjective`
declares, per route, an availability target (fraction of non-5xx
responses) and a latency target (a percentile that must stay under a
threshold). An :class:`SLOTracker` folds every response into rolling
multi-window frames (5 minutes and 1 hour by default) and reports, per
window:

* the observed request/error/slow counts,
* a streaming latency-percentile estimate -- linear interpolation over
  the same fixed ``LATENCY_BUCKETS`` the request histograms use, so the
  estimate is dependency-free and costs one bisect per record,
* **error-budget burn rates**: observed bad fraction divided by the
  budgeted bad fraction. Burn 1.0 means "spending the budget exactly as
  fast as allowed"; burn 10 on the short window is a page.

Status folds to one word the health endpoint can carry:
``violated`` when the long (1h) window is burning >= 1x on any
objective, ``burning`` when only the short (5m) window is, ``ok``
otherwise (including "no traffic yet" -- silence is not an outage).

Frames are advanced lazily on both :meth:`SLOTracker.record` and
:meth:`SLOTracker.report`, so an idle gateway's windows still roll
forward when scraped. The clock is injectable (monotonic seconds) which
keeps the golden wire fixture and the window tests deterministic.
"""

from __future__ import annotations

import bisect
import threading
import time
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple

from .metrics import LATENCY_BUCKETS, Registry

__all__ = [
    "DEFAULT_OBJECTIVES",
    "WINDOWS",
    "SLOObjective",
    "SLOTracker",
    "bucket_quantile",
]

#: rolling windows reported per objective: (label, seconds). The last
#: (longest) window drives the ``violated`` status; the short one drives
#: ``burning``.
WINDOWS: Tuple[Tuple[str, float], ...] = (("5m", 300.0), ("1h", 3600.0))


@dataclass(frozen=True)
class SLOObjective:
    """One route's declared service level.

    ``availability`` is the target fraction of non-5xx responses (0.999
    budgets one bad request per thousand). ``latency_p`` is the
    percentile (0.99 = p99) that must stay under
    ``latency_threshold_s`` seconds; requests over the threshold spend
    the latency budget ``1 - latency_p``.
    """

    route: str
    availability: float = 0.999
    latency_p: float = 0.99
    latency_threshold_s: float = 0.025

    def __post_init__(self) -> None:
        if not self.route:
            raise ValueError("route must be a non-empty path")
        if not 0.0 < self.availability < 1.0:
            raise ValueError(f"availability must be in (0, 1), got {self.availability}")
        if not 0.0 < self.latency_p < 1.0:
            raise ValueError(f"latency_p must be in (0, 1), got {self.latency_p}")
        if self.latency_threshold_s <= 0.0:
            raise ValueError(
                f"latency_threshold_s must be > 0, got {self.latency_threshold_s}"
            )

    def to_dict(self) -> Dict[str, Any]:
        return {
            "route": self.route,
            "availability": self.availability,
            "latency_p": self.latency_p,
            "latency_threshold_s": self.latency_threshold_s,
        }


#: the serving stack's declared objectives: answer routes are p99-bound
#: at interactive thresholds; the batch route gets 10x headroom.
DEFAULT_OBJECTIVES: Tuple[SLOObjective, ...] = (
    SLOObjective("/v1/query", availability=0.999, latency_p=0.99,
                 latency_threshold_s=0.025),
    SLOObjective("/v1/query_many", availability=0.999, latency_p=0.99,
                 latency_threshold_s=0.250),
    SLOObjective("/v1/route", availability=0.999, latency_p=0.99,
                 latency_threshold_s=0.025),
)


def bucket_quantile(
    bounds: Sequence[float], counts: Sequence[int], q: float
) -> Optional[float]:
    """Quantile ``q`` estimated from per-bucket counts by linear
    interpolation inside the containing bucket.

    ``bounds`` are the histogram's upper bounds (strictly increasing);
    ``counts`` are NON-cumulative per-bucket counts with one extra
    trailing entry for the ``+Inf`` overflow bucket (``len(bounds)+1``
    entries). Returns ``None`` when there are no observations. Overflow
    quantiles clamp to the last finite bound -- the estimator never
    invents a value above what the histogram can resolve.
    """
    if not 0.0 <= q <= 1.0:
        raise ValueError(f"q must be in [0, 1], got {q}")
    if len(counts) != len(bounds) + 1:
        raise ValueError(
            f"need {len(bounds) + 1} counts (incl. overflow), got {len(counts)}"
        )
    total = sum(counts)
    if total == 0:
        return None
    # rank of the target observation (1-based, ceil)
    rank = q * total
    cum = 0
    for i, c in enumerate(counts):
        cum += c
        if cum >= rank and c > 0:
            if i == len(bounds):  # overflow bucket: clamp to last bound
                return float(bounds[-1])
            lo = 0.0 if i == 0 else float(bounds[i - 1])
            hi = float(bounds[i])
            # fraction of the way through this bucket's mass
            frac = (rank - (cum - c)) / c
            return lo + (hi - lo) * min(max(frac, 0.0), 1.0)
    return float(bounds[-1])


class _Totals:
    """Cumulative per-route counters (monotone; windows are deltas)."""

    __slots__ = ("count", "errors", "slow", "sum_s", "buckets")

    def __init__(self, n_buckets: int):
        self.count = 0
        self.errors = 0
        self.slow = 0
        self.sum_s = 0.0
        self.buckets = [0] * (n_buckets + 1)  # + overflow

    def snapshot(self) -> "_Totals":
        s = _Totals(len(self.buckets) - 1)
        s.count, s.errors, s.slow = self.count, self.errors, self.slow
        s.sum_s = self.sum_s
        s.buckets = list(self.buckets)
        return s


class SLOTracker:
    """Rolling-window SLO accounting over an injectable monotonic clock.

    ``record(route, duration_s, ok)`` is the single write path (one lock,
    one bisect); ``report()`` is the read path serving ``GET /v1/slo``.
    Windows are computed as deltas between the live cumulative counters
    and periodic frame snapshots kept in a bounded ring -- memory is
    O(routes x frames), independent of traffic.
    """

    def __init__(
        self,
        objectives: Sequence[SLOObjective] = DEFAULT_OBJECTIVES,
        *,
        buckets: Sequence[float] = LATENCY_BUCKETS,
        clock=time.monotonic,
        frame_interval_s: float = 5.0,
        windows: Sequence[Tuple[str, float]] = WINDOWS,
    ):
        if frame_interval_s <= 0:
            raise ValueError("frame_interval_s must be > 0")
        self._objectives = {o.route: o for o in objectives}
        self._bounds = tuple(float(b) for b in buckets)
        self._clock = clock
        self._frame_interval = float(frame_interval_s)
        self._windows = tuple((str(n), float(w)) for n, w in windows)
        max_w = max(w for _, w in self._windows)
        # frames to cover the longest window, +2 so the delta baseline
        # (newest frame at or before now - w) is always retained
        self._max_frames = int(max_w / self._frame_interval) + 2
        self._mu = threading.Lock()
        self._t0 = float(clock())
        self._last_event = self._t0
        self._totals: Dict[str, _Totals] = {
            r: _Totals(len(self._bounds)) for r in self._objectives
        }
        # frame ring: list of (t, {route: _Totals snapshot}) oldest-first
        self._frames: List[Tuple[float, Dict[str, _Totals]]] = [
            (self._t0, {r: t.snapshot() for r, t in self._totals.items()})
        ]

    @property
    def objectives(self) -> Tuple[SLOObjective, ...]:
        return tuple(self._objectives[r] for r in sorted(self._objectives))

    def _advance_frames(self, now: float) -> None:
        # caller holds self._mu; totals must NOT yet include an event
        # being recorded at `now` (record() advances before folding)
        last_t = self._frames[-1][0]
        if now - last_t < self._frame_interval:
            return
        if self._last_event > last_t and now - self._last_event >= self._frame_interval:
            # idle gap: totals haven't changed since the last event, so
            # sealing them at that event's own time is exact -- without
            # this frame, a quiet stretch would keep old events inside
            # windows that have already rolled past them
            self._frames.append(
                (self._last_event,
                 {r: t.snapshot() for r, t in self._totals.items()})
            )
        self._frames.append(
            (now, {r: t.snapshot() for r, t in self._totals.items()})
        )
        if len(self._frames) > self._max_frames:
            del self._frames[: len(self._frames) - self._max_frames]

    # ---- write path --------------------------------------------------------
    def record(self, route: str, duration_s: float, ok: bool) -> None:
        """Fold one response in. Routes without a declared objective are
        ignored -- scrapes and debug endpoints don't spend budget."""
        tot = self._totals.get(route)
        if tot is None:
            return
        d = float(duration_s)
        obj = self._objectives[route]
        i = bisect.bisect_left(self._bounds, d)
        with self._mu:
            now = float(self._clock())
            # seal pre-event state first, so this event can never leak
            # into a window baseline older than itself
            self._advance_frames(now)
            tot.count += 1
            tot.sum_s += d
            tot.buckets[min(i, len(self._bounds))] += 1
            if not ok:
                tot.errors += 1
            if d > obj.latency_threshold_s:
                tot.slow += 1
            self._last_event = now

    # ---- read path ---------------------------------------------------------
    def _baseline(self, now: float, window_s: float) -> Dict[str, _Totals]:
        # newest frame at or before (now - window_s); the very first
        # frame (all zeros at t0) backstops trackers younger than the
        # window. Caller holds self._mu.
        cutoff = now - window_s
        base = self._frames[0][1]
        for t, snap in self._frames:
            if t <= cutoff:
                base = snap
            else:
                break
        return base

    def _window_report(
        self, obj: SLOObjective, cur: _Totals, base: _Totals
    ) -> Dict[str, Any]:
        count = cur.count - base.count
        errors = cur.errors - base.errors
        slow = cur.slow - base.slow
        dcounts = [c - b for c, b in zip(cur.buckets, base.buckets)]
        p_est = bucket_quantile(self._bounds, dcounts, obj.latency_p)
        if count > 0:
            avail_burn = (errors / count) / (1.0 - obj.availability)
            latency_burn = (slow / count) / (1.0 - obj.latency_p)
        else:
            avail_burn = 0.0
            latency_burn = 0.0
        return {
            "count": count,
            "errors": errors,
            "slow": slow,
            "availability_burn": avail_burn,
            "latency_burn": latency_burn,
            "p_estimate_s": p_est,
        }

    @staticmethod
    def _route_status(windows: Dict[str, Dict[str, Any]],
                      short: str, long: str) -> str:
        def burning(w: Dict[str, Any]) -> bool:
            return w["availability_burn"] >= 1.0 or w["latency_burn"] >= 1.0

        if burning(windows[long]):
            return "violated"
        if burning(windows[short]):
            return "burning"
        return "ok"

    def report(self, now: Optional[float] = None) -> Dict[str, Any]:
        """The full SLO report as a deterministic plain dict (the JSON
        rendering of ``GET /v1/slo`` wraps exactly this)."""
        with self._mu:
            t = float(self._clock()) if now is None else float(now)
            self._advance_frames(t)
            cur = {r: tot.snapshot() for r, tot in self._totals.items()}
            bases = {
                name: self._baseline(t, w) for name, w in self._windows
            }
        short_name = self._windows[0][0]
        long_name = self._windows[-1][0]
        routes: Dict[str, Any] = {}
        worst = "ok"
        rank = {"ok": 0, "burning": 1, "violated": 2}
        for route in sorted(self._objectives):
            obj = self._objectives[route]
            windows = {
                name: self._window_report(obj, cur[route], bases[name][route])
                for name, _ in self._windows
            }
            status = self._route_status(windows, short_name, long_name)
            if rank[status] > rank[worst]:
                worst = status
            routes[route] = {
                "objective": obj.to_dict(),
                "status": status,
                "windows": windows,
            }
        return {
            "status": worst,
            "windows": [
                {"name": n, "seconds": w} for n, w in self._windows
            ],
            "routes": routes,
        }

    def status(self) -> str:
        """Just the folded one-word status (what ``/v1/healthz`` carries)."""
        return self.report()["status"]

    def render_prometheus(self, report: Optional[Dict[str, Any]] = None) -> bytes:
        """The report as Prometheus text exposition, via a throwaway
        private registry so families/labels render in the exact same
        format as ``/v1/metrics``."""
        rep = self.report() if report is None else report
        reg = Registry(disabled=False)
        burn = reg.gauge(
            "repro_slo_burn_rate",
            "error-budget burn rate (1.0 = spending exactly the budget)",
            labels=("route", "window", "objective"),
        )
        pest = reg.gauge(
            "repro_slo_latency_estimate_seconds",
            "windowed latency percentile estimate",
            labels=("route", "window"),
        )
        stat = reg.gauge(
            "repro_slo_status",
            "folded route status (0 ok, 1 burning, 2 violated)",
            labels=("route",),
        )
        rank = {"ok": 0, "burning": 1, "violated": 2}
        for route, r in rep["routes"].items():
            stat.labels(route=route).set(rank[r["status"]])
            for wname, w in r["windows"].items():
                burn.labels(route=route, window=wname,
                            objective="availability").set(w["availability_burn"])
                burn.labels(route=route, window=wname,
                            objective="latency").set(w["latency_burn"])
                if w["p_estimate_s"] is not None:
                    pest.labels(route=route, window=wname).set(w["p_estimate_s"])
        return reg.render_prometheus()
