"""Analytical silicon-area model (paper §III).

The paper models total die area of a GPU-like programmable accelerator as a
linear composite of micro-architectural parameters (eqs. 3-6), calibrated
with Cacti 6.5 fits + die-photomicrograph measurements on the Maxwell
GTX-980 and validated on the Titan X.

Two layers are provided:

* :class:`LinearAreaModel` -- the generic linear-composite form of eq. (5):
  a sum of per-SM, per-vector-unit, per-kB and per-chip terms. Any
  accelerator family can be expressed by choosing coefficients.
* :data:`MAXWELL` -- the paper's calibrated Maxwell instantiation, using the
  folded coefficients of eq. (6) *exactly* (the operative model the paper
  validates against the Titan X). The raw §III.B Cacti-fit coefficients are
  kept in :data:`MAXWELL_RAW_FITS` for reference; the paper's folded
  constants do not precisely re-derive from them (see DESIGN.md,
  "Known internal inconsistencies").

All evaluation functions are vectorized over numpy arrays so the codesign
driver can sweep thousands of hardware points at once.
"""

from __future__ import annotations

import dataclasses
from typing import Mapping

import numpy as np

__all__ = [
    "HardwarePoint",
    "LinearAreaModel",
    "MAXWELL",
    "MAXWELL_RAW_FITS",
    "GTX980",
    "TITAN_X",
    "cacheless",
]


@dataclasses.dataclass(frozen=True)
class HardwarePoint:
    """One point in the hardware design space (paper Table I, group 2).

    Attributes
    ----------
    n_sm:        number of streaming multiprocessors (coarse parallelism).
    n_v:         vector units (cores) per SM (fine parallelism).
    m_sm:        kB of shared (scratchpad) memory per SM.
    r_vu:        kB of register file per vector unit (fixed at calibration
                 value by the paper -- "the register file size is a fixed
                 constant in the area model").
    l1_smpair:   kB of L1 cache per SM pair (0 for the paper's cache-less
                 proposed designs).
    l2_kb:       kB of L2 cache on the chip (0 for cache-less designs).
    """

    n_sm: int
    n_v: int
    m_sm: float
    r_vu: float = 2.0
    l1_smpair: float = 0.0
    l2_kb: float = 0.0

    def as_dict(self) -> Mapping[str, float]:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class LinearAreaModel:
    """Eq. (5)/(6): ``A_tot = c_vu*n_sm*n_v + c_r*R_vu*n_sm*n_v
    + c_m*M_sm*n_sm + c_l1*L1_smpair*n_sm + c_l2*L2_kb + c_sm*n_sm + c_0``.

    Coefficients are mm^2 (per kB where applicable). ``c_0`` is a per-chip
    constant (zero in the paper's folded eq. (6) -- the chip-level overheads
    are amortized per-SM via ``c_sm``, a documented design choice, §III.A
    footnote 2).
    """

    c_vu: float  # per vector unit (core logic + per-VU register overhead)
    c_r: float  # per kB of register file per vector unit
    c_m: float  # per kB of shared memory per SM
    c_l1: float  # per kB of L1 per SM-pair, already folded with the 1/2
    c_l2: float  # per kB of L2 (chip-wide)
    c_sm: float  # per-SM overhead (FDU, I-cache, LSU, chip overhead share)
    c_0: float = 0.0
    name: str = "linear-area"

    def area(
        self,
        n_sm,
        n_v,
        m_sm,
        r_vu=2.0,
        l1_smpair=0.0,
        l2_kb=0.0,
    ):
        """Total die area in mm^2; broadcasts over numpy array inputs."""
        n_sm = np.asarray(n_sm, dtype=np.float64)
        n_v = np.asarray(n_v, dtype=np.float64)
        m_sm = np.asarray(m_sm, dtype=np.float64)
        return (
            self.c_vu * n_sm * n_v
            + self.c_r * np.asarray(r_vu, np.float64) * n_sm * n_v
            + self.c_m * m_sm * n_sm
            + self.c_l1 * np.asarray(l1_smpair, np.float64) * n_sm
            + self.c_l2 * np.asarray(l2_kb, np.float64)
            + self.c_sm * n_sm
            + self.c_0
        )

    def area_point(self, hw: HardwarePoint) -> float:
        return float(
            self.area(
                hw.n_sm, hw.n_v, hw.m_sm, hw.r_vu, hw.l1_smpair, hw.l2_kb
            )
        )

    def breakdown(self, hw: HardwarePoint) -> Mapping[str, float]:
        """Per-component areas (mm^2) -- used by the Fig.-4 resource plot."""
        return {
            "vector_units": self.c_vu * hw.n_sm * hw.n_v,
            "register_files": self.c_r * hw.r_vu * hw.n_sm * hw.n_v,
            "shared_memory": self.c_m * hw.m_sm * hw.n_sm,
            "l1": self.c_l1 * hw.l1_smpair * hw.n_sm,
            "l2": self.c_l2 * hw.l2_kb,
            "overhead": self.c_sm * hw.n_sm + self.c_0,
        }


#: The paper's folded, calibrated Maxwell model -- eq. (6) verbatim.
MAXWELL = LinearAreaModel(
    c_vu=0.0447,
    c_r=0.0043,
    c_m=0.015,
    c_l1=0.08,
    c_l2=0.041,
    c_sm=7.317,
    name="maxwell-eq6",
)

#: Raw §III.B Cacti linear-fit coefficients (reference only; eq. (6) is the
#: operative model). beta = slope per kB, alpha = per-bank overhead, mm^2.
MAXWELL_RAW_FITS = {
    "beta_R": 0.004305,
    "alpha_R": 0.001947,
    "beta_M": 0.01565,
    "alpha_M": 0.09281,
    "beta_L1": 0.1604,
    "alpha_L1": 0.08204,
    "beta_L2": 0.04197,
    "alpha_L2": 0.7685,
    "beta_VU": 0.04282,  # measured from die photo, excludes register file
    "alpha_oh": 6.4156,  # per-SM share of I/O pads, controllers, etc.
}

#: Stock configurations (paper §III.B-C). R_VU = 512 regs x 32 b = 2 kB.
#: L1_SMpair = 48 kB is required for eq. (6) to reproduce the published die
#: areas (see DESIGN.md); L2 = 2 MB (GTX980) / 3 MB (Titan X).
GTX980 = HardwarePoint(n_sm=16, n_v=128, m_sm=96.0, r_vu=2.0, l1_smpair=48.0, l2_kb=2048.0)
TITAN_X = HardwarePoint(n_sm=24, n_v=128, m_sm=96.0, r_vu=2.0, l1_smpair=48.0, l2_kb=3072.0)

#: Published die areas (mm^2) used for calibration/validation.
GTX980_DIE_MM2 = 398.0
TITAN_X_DIE_MM2 = 601.0


def cacheless(hw: HardwarePoint) -> HardwarePoint:
    """The paper's §V.A *delete the caches* transform (HHC codes bypass
    caches, so proposed designs spend that area on cores instead)."""
    return dataclasses.replace(hw, l1_smpair=0.0, l2_kb=0.0)
