"""Import every architecture module for registry side effects.

Discovery is automatic and deterministic: every non-underscore module in
this package is imported in sorted name order, so adding a config file is
enough to make it appear in ``repro.configs.list_archs()`` -- no manual
import list to forget to update (the old hand-maintained list silently
dropped newly added modules). ``base.py`` is skipped (it *defines* the
registry and registers nothing). Importing this module twice is a no-op
(Python module caching), and :func:`repro.configs.base.register` still
rejects two *different* modules claiming the same name.
"""

import importlib
import pkgutil

import repro.configs as _pkg

_SKIP = {"base"}

for _info in sorted(pkgutil.iter_modules(_pkg.__path__), key=lambda m: m.name):
    if _info.name in _SKIP or _info.name.startswith("_"):
        continue
    importlib.import_module(f"repro.configs.{_info.name}")
