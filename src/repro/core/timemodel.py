"""Analytical execution-time model for tiled stencils (reconstruction of
Prajapati et al., PPoPP 2017 [27] -- see DESIGN.md §3).

The codesign paper treats ``T_alg(p, h, s)`` as an imported black box; only
its interface (parameters + feasibility constraints, eqs. 9-15) is given.
This module re-derives a documented hybrid-hexagonal-tiling time model with
the same interface:

problem parameters  p = (S1, S2[, S3], T)        -- iteration-space extents
hardware parameters h = (n_SM, n_V, M_SM)        -- + GPU family constants
software parameters s = (t_S1, t_S2[, t_S3], t_T, k)

Model (all floor/ceil kept -- the paper's non-smoothness is intentional):

* hexagonal tiles on the (T, S1) plane: average width ``W = t_S1 + s*t_T``
  (sigma = stencil radius), max width ``W_max = t_S1 + 2*s*t_T``;
* a tile is one threadblock of ``t_S2`` threads (mult. of 32 = warps);
  for 3D stencils each thread additionally walks ``t_S3`` points;
* compute time per co-resident *group* (the k blocks hyperthreaded on one
  SM): ``C_iter * t_T * W * t_S3 * ceil(k*t_S2/n_V)`` -- the k*t_S2 resident
  threads time-share the n_V lanes; the group completes k tiles in that
  time, so throughput saturates at ``n_V/C_iter`` points/s/SM exactly when
  ``k*t_S2`` is a multiple of ``n_V`` (latency hiding = rounding efficiency);
* shared-memory footprint / tile (bytes):
  ``n_arr * (W_max+2s) * (t_S2+2s) * (t_S3+2s | 1) * 4``; feasibility is
  eq. (11): ``k * footprint <= M_SM`` (eq. (9) is this divided by k);
* per wavefront *phase* (hexagonal schedules alternate 2 phases per time
  band): ``tiles_phase = ceil(ceil(S1/W)/2) * ceil(S2/t_S2) * ceil(S3/t_S3)``
  tiles issue in batches of ``k*n_SM``; a batch overlaps compute with the
  global-memory traffic of its tiles through the shared bandwidth:
  ``T_batch = max(T_compute_tile, n_active*footprint/BW)``;
* ``T_alg = 2*ceil(T/t_T) * (batches*T_batch + launch_overhead)``.

Every evaluation function is *backend-generic*: it takes an array namespace
``xp`` (``numpy`` by default, ``jax.numpy`` for the JIT-compiled sweep
engine in :mod:`repro.core.sweep`) and only uses ops both provide. The only
Python-level branches are on **static** stencil structure (``st.dims``),
never on array values, so the functions trace cleanly under ``jax.jit`` /
``jax.vmap`` while staying bit-compatible with the seed's NumPy float64
path when called with the defaults.
"""

from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np

__all__ = [
    "StencilSpec",
    "GPUSpec",
    "ProblemSize",
    "STENCILS",
    "MAXWELL_GPU",
    "TITANX_GPU",
    "GPUS_BY_NAME",
    "footprint_bytes",
    "stencil_time",
    "stencil_gflops",
    "feasible",
    "with_machine_params",
    "with_c_iter",
]


@dataclasses.dataclass(frozen=True)
class StencilSpec:
    """Workload characterization of one stencil benchmark."""

    name: str
    dims: int  # spatial dimensions (2 or 3)
    radius: int  # sigma: halo width per time step
    flops_per_point: float
    n_arrays: int  # arrays resident in the tile footprint (in + out)
    c_iter: float  # seconds per iteration per thread (measured, §IV.B)


@dataclasses.dataclass(frozen=True)
class GPUSpec:
    """Family constants that are *not* design variables (paper §IV.A)."""

    name: str
    bw_gmem: float  # global-memory bandwidth, bytes/s
    max_threads_per_block: int = 1024
    max_threads_per_sm: int = 2048
    max_threadblocks_per_sm: int = 32  # MTB_SM, eq. (10)
    launch_overhead: float = 5.0e-6  # per-phase sync/launch, seconds
    bytes_per_word: int = 4  # fp32 stencils


@dataclasses.dataclass(frozen=True)
class ProblemSize:
    """Problem parameters p. ``s3 = 1`` for 2D stencils.

    Fields are ints for concrete sizes, but the sweep engine may carry JAX
    tracers here (sizes are *dynamic* under jit so one compiled sweep serves
    every problem size) -- hence nothing below hashes or int()-casts them
    except the convenience :attr:`points` property.
    """

    s1: int
    s2: int
    t: int
    s3: int = 1

    @property
    def points(self) -> float:
        return float(self.s1) * self.s2 * self.s3 * self.t


# ---------------------------------------------------------------------------
# The paper's six-benchmark suite (§IV.A). flops/point follow the loop bodies
# of the standard PolyBench/HHC kernels; C_iter is the measured per-iteration
# per-thread cost on the GTX-980 (paper §IV.B: "we measured this parameter
# for the different stencils ... we used the former [GTX-980] value"). The
# published values are not in the paper; these are calibrated so the stock
# GTX-980 / Titan X land in Table II's GFLOP/s magnitude range.
# ---------------------------------------------------------------------------
STENCILS: Dict[str, StencilSpec] = {
    "jacobi2d": StencilSpec("jacobi2d", 2, 1, 5.0, 2, 4.0e-9),
    "heat2d": StencilSpec("heat2d", 2, 1, 10.0, 2, 5.5e-9),
    "laplacian2d": StencilSpec("laplacian2d", 2, 1, 6.0, 2, 4.0e-9),
    "gradient2d": StencilSpec("gradient2d", 2, 1, 9.0, 2, 4.5e-9),
    "heat3d": StencilSpec("heat3d", 3, 1, 15.0, 2, 7.0e-9),
    "laplacian3d": StencilSpec("laplacian3d", 3, 1, 8.0, 2, 6.0e-9),
}

MAXWELL_GPU = GPUSpec(name="gtx980", bw_gmem=224.0e9)
TITANX_GPU = GPUSpec(name="titanx", bw_gmem=336.0e9)

#: THE name -> datasheet-spec registry. Every layer that resolves a GPU
#: family by name (the service CLI's --gpu knob, the calibration fit's
#: measurement-frame lookup) consumes this one table; adding a target
#: means adding it here (plus a stock hardware point in
#: repro.measure.harness if it will frame measurements).
GPUS_BY_NAME: Dict[str, GPUSpec] = {g.name: g for g in (MAXWELL_GPU, TITANX_GPU)}


def with_machine_params(gpu: GPUSpec, bw_gmem=None, launch_overhead=None, name=None):
    """A copy of ``gpu`` with refitted *measured* machine parameters.

    This is the calibration seam (:mod:`repro.measure.calibrate`): the two
    continuous constants the empirical fit can move -- global-memory
    bandwidth and launch overhead -- swapped without touching the design
    variables or family limits. Values may be JAX tracers (the fit
    differentiates straight through :func:`stencil_time` on a spec built
    from traced parameters, exactly like the sweep engine's traced specs).
    """
    updates: Dict[str, object] = {}
    if bw_gmem is not None:
        updates["bw_gmem"] = bw_gmem
    if launch_overhead is not None:
        updates["launch_overhead"] = launch_overhead
    if name is not None:
        updates["name"] = name
    return dataclasses.replace(gpu, **updates)


def with_c_iter(st: StencilSpec, c_iter):
    """A copy of ``st`` with a refitted per-iteration compute cost (the
    per-stencil machine parameter the paper measures in §IV.B). ``c_iter``
    may be a JAX tracer during fitting."""
    return dataclasses.replace(st, c_iter=c_iter)


def _dtype_for(xp, dtype):
    """Default working dtype: float64 on NumPy (seed-exact), float32 on JAX
    backends (float64 would silently downcast unless x64 mode is on)."""
    if dtype is not None:
        return dtype
    return np.float64 if xp is np else np.float32


def _ceil_div(xp, a, b):
    return xp.ceil(a / b)


def footprint_bytes(st: StencilSpec, gpu: GPUSpec, t_s1, t_s2, t_t, t_s3=1, *, xp=np, dtype=None):
    """Shared-memory bytes needed by one tile (halo-expanded, all arrays)."""
    dtype = _dtype_for(xp, dtype)
    s = st.radius
    t_s1 = xp.asarray(t_s1, dtype)
    t_s2 = xp.asarray(t_s2, dtype)
    t_t = xp.asarray(t_t, dtype)
    t_s3 = xp.asarray(t_s3, dtype)
    w_max = t_s1 + 2.0 * s * t_t
    # static branch on stencil structure -- never on array values
    depth = t_s3 + 2.0 * s if st.dims == 3 else xp.ones_like(t_s3)
    return (
        st.n_arrays
        * (w_max + 2.0 * s)
        * (t_s2 + 2.0 * s)
        * depth
        * gpu.bytes_per_word
    )


def feasible(
    st: StencilSpec,
    gpu: GPUSpec,
    n_sm,
    n_v,
    m_sm,
    t_s1,
    t_s2,
    t_t,
    k,
    t_s3=1,
    *,
    xp=np,
    dtype=None,
):
    """Feasibility mask, eqs. (9)-(15). Broadcasts over array inputs."""
    dtype = _dtype_for(xp, dtype)
    t_s2 = xp.asarray(t_s2, dtype)
    t_t = xp.asarray(t_t, dtype)
    k = xp.asarray(k, dtype)
    fp = footprint_bytes(st, gpu, t_s1, t_s2, t_t, t_s3, xp=xp, dtype=dtype)
    ok = k * fp <= xp.asarray(m_sm, dtype) * 1024.0  # eq. (11) [& (9)]
    ok &= k <= gpu.max_threadblocks_per_sm  # eq. (10)
    ok &= t_s2 <= gpu.max_threads_per_block
    ok &= k * t_s2 <= gpu.max_threads_per_sm
    ok &= t_t % 2 == 0  # eq. (15): t_T even (HHC)
    ok &= t_s2 % 32 == 0  # eq. (13): full warps
    return ok


def stencil_time(
    st: StencilSpec,
    gpu: GPUSpec,
    size: ProblemSize,
    n_sm,
    n_v,
    m_sm,
    t_s1,
    t_s2,
    t_t,
    k,
    t_s3=1,
    *,
    xp=np,
    dtype=None,
):
    """T_alg in seconds. Infeasible points get +inf. Fully vectorized, and
    traceable under jit/vmap when called with ``xp=jax.numpy``."""
    dtype = _dtype_for(xp, dtype)
    n_sm = xp.asarray(n_sm, dtype)
    n_v = xp.asarray(n_v, dtype)
    t_s1 = xp.asarray(t_s1, dtype)
    t_s2 = xp.asarray(t_s2, dtype)
    t_t = xp.asarray(t_t, dtype)
    k = xp.asarray(k, dtype)
    t_s3 = xp.asarray(t_s3, dtype)
    s1 = xp.asarray(size.s1, dtype)
    s2 = xp.asarray(size.s2, dtype)
    s3 = xp.asarray(size.s3, dtype)
    t_total = xp.asarray(size.t, dtype)
    s = st.radius

    w_avg = t_s1 + s * t_t
    fp = footprint_bytes(st, gpu, t_s1, t_s2, t_t, t_s3, xp=xp, dtype=dtype)

    # --- compute time of one co-resident group (k blocks -> k tiles done).
    serial = xp.ceil(k * t_s2 / n_v)
    t_compute = st.c_iter * t_t * w_avg * t_s3 * serial

    # --- phase structure.
    tiles_phase = (
        xp.ceil(_ceil_div(xp, s1, w_avg) / 2.0)
        * _ceil_div(xp, s2, t_s2)
        * (_ceil_div(xp, s3, t_s3) if st.dims == 3 else 1.0)
    )
    tiles_phase = xp.maximum(tiles_phase, 1.0)
    concurrent = xp.minimum(k * n_sm, tiles_phase)
    batches = _ceil_div(xp, tiles_phase, k * n_sm)

    # --- per-batch: all concurrent tiles' global traffic shares BW.
    t_mem = concurrent * fp / gpu.bw_gmem
    t_batch = xp.maximum(t_compute, t_mem)

    phases = 2.0 * _ceil_div(xp, t_total, t_t)
    t_alg = phases * (batches * t_batch + gpu.launch_overhead)

    ok = feasible(
        st, gpu, n_sm, n_v, m_sm, t_s1, t_s2, t_t, k, t_s3, xp=xp, dtype=dtype
    )
    return xp.where(ok, t_alg, xp.inf)


def stencil_gflops(st: StencilSpec, size: ProblemSize, t_alg_seconds, *, xp=np):
    """Achieved GFLOP/s given a T_alg (broadcasts)."""
    total = st.flops_per_point * size.points
    return total / xp.asarray(t_alg_seconds) / 1.0e9
