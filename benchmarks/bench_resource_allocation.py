"""Paper Fig. 4: resource allocation -- fraction of die area spent on
memory vs vector units across the design space, and the clustering of the
Pareto-optimal points."""

from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.core import MAXWELL, HardwarePoint
from repro.core.pareto import pareto_mask

from .common import ARTIFACTS, STENCIL_CLASSES, emit, skey


def run() -> None:
    # reuse the Fig.-3 artifacts (bench_pareto must run first in the suite)
    for cls in STENCIL_CLASSES:
        path = os.path.join(ARTIFACTS, skey(f"pareto_{cls}") + ".json")
        if not os.path.exists(path):
            emit(f"resource_alloc_{cls}", 0.0, "skipped (run bench_pareto first)")
            continue
        t0 = time.perf_counter()
        with open(path) as f:
            r = json.load(f)
        fracs_mem, fracs_vu = [], []
        for hwdict in [r["gtx980"]["best_hw"], r["titanx"]["best_hw"]]:
            p = HardwarePoint(
                n_sm=hwdict["n_sm"], n_v=hwdict["n_v"], m_sm=hwdict["m_sm"]
            )
            b = MAXWELL.breakdown(p)
            total = sum(b.values())
            fracs_mem.append(100 * (b["shared_memory"] + b["register_files"]) / total)
            fracs_vu.append(100 * b["vector_units"] / total)
        us = (time.perf_counter() - t0) * 1e6
        emit(
            f"resource_alloc_{cls}", us,
            f"Pareto designs spend {np.mean(fracs_vu):.0f}% die on vector units / "
            f"{np.mean(fracs_mem):.0f}% on scratchpad+RF (paper Fig. 4: optima cluster)",
        )
