"""Context-manager spans over the monotonic clock.

A *trace* is one request's tree of timed spans. The API is built around
two costs-nothing-when-off invariants:

* With no active trace, :func:`span` yields ``None`` without allocating a
  node -- instrumented code pays one contextvar read.
* Span trees are plain dicts the moment the root closes, so encoding them
  is just JSON; nothing observability-shaped touches the answer path.

Usage (the gateway does exactly this per traced request)::

    with trace("gateway.request", trace_id=tid) as root:
        with span("resolve", artifact=key[:12]):
            ...
        with span("dispatch"):
            ...
    tree = root.tree()   # {"trace_id", "name", "t_offset_us", "dur_us", ...}

Nesting rides :mod:`contextvars`, so concurrent requests on a
``ThreadingHTTPServer`` (one thread each) never see each other's spans.
One documented blind spot: the microbatching ``CodesignServer`` executes
*followers'* reductions on the leader's thread, so engine-level spans
attach to the leader's trace only -- follower trees show the rendezvous
wait, not the matmul. Trace ids ride the HTTP wire as the
:data:`TRACE_HEADER` header (client-supplied or gateway-minted).
"""

from __future__ import annotations

import contextlib
import contextvars
import time
import uuid
from typing import Any, Dict, Iterator, List, Optional

__all__ = [
    "TRACE_HEADER",
    "Span",
    "current_span",
    "current_trace_id",
    "new_trace_id",
    "span",
    "trace",
]

#: HTTP header carrying the request's trace id in both directions: echoed
#: back when the client supplied one, minted by the gateway otherwise.
TRACE_HEADER = "X-Repro-Trace"

_ACTIVE: contextvars.ContextVar[Optional["Span"]] = contextvars.ContextVar(
    "repro_obs_active_span", default=None
)


def new_trace_id() -> str:
    """A fresh 16-hex-char trace id (no ordering or meaning implied)."""
    return uuid.uuid4().hex[:16]


class Span:
    """One timed node. Offsets/durations are whole microseconds relative
    to the trace root's start on the monotonic clock -- wall-clock never
    enters a span tree, so trees are insensitive to NTP steps."""

    __slots__ = ("name", "trace_id", "attrs", "children",
                 "_t0", "_root_t0", "_dur", "_token")

    def __init__(
        self,
        name: str,
        trace_id: str,
        root_t0: Optional[float] = None,
        attrs: Optional[Dict[str, Any]] = None,
    ):
        self.name = name
        self.trace_id = trace_id
        self.attrs = attrs or {}
        self.children: List[Span] = []
        self._t0 = time.perf_counter()
        self._root_t0 = self._t0 if root_t0 is None else root_t0
        self._dur: Optional[float] = None
        self._token: Optional[contextvars.Token] = None

    # -- lifecycle ---------------------------------------------------------
    def _enter(self) -> "Span":
        self._token = _ACTIVE.set(self)
        return self

    def _exit(self) -> None:
        self._dur = time.perf_counter() - self._t0
        if self._token is not None:
            _ACTIVE.reset(self._token)
            self._token = None

    @property
    def duration_s(self) -> float:
        """Closed span's duration in seconds (0.0 while still open)."""
        return self._dur if self._dur is not None else 0.0

    def tree(self) -> Dict[str, Any]:
        """The span subtree as a plain JSON-ready dict (children in
        start order). Safe to call once the span has closed."""
        node: Dict[str, Any] = {
            "name": self.name,
            "t_offset_us": int(round((self._t0 - self._root_t0) * 1e6)),
            "dur_us": int(round(self.duration_s * 1e6)),
        }
        if self.attrs:
            node["attrs"] = {k: self.attrs[k] for k in sorted(self.attrs)}
        if self.children:
            node["children"] = [c.tree() for c in self.children]
        return node

    def root_tree(self) -> Dict[str, Any]:
        """Like :meth:`tree` but stamped with the trace id -- the shape
        that goes into the response envelope's ``trace`` field."""
        return {"trace_id": self.trace_id, **self.tree()}


def current_span() -> Optional[Span]:
    """The innermost open span on this thread/context, or None."""
    return _ACTIVE.get()


def current_trace_id() -> Optional[str]:
    """Trace id of the active trace, or None when not tracing."""
    s = _ACTIVE.get()
    return s.trace_id if s is not None else None


@contextlib.contextmanager
def trace(
    name: str, trace_id: Optional[str] = None, **attrs: Any
) -> Iterator[Span]:
    """Open a ROOT span, starting a new trace on this context. Always
    yields a real :class:`Span` (unlike :func:`span`, which no-ops when
    nothing is tracing)."""
    root = Span(name, trace_id or new_trace_id(), attrs=attrs or None)
    root._enter()
    try:
        yield root
    finally:
        root._exit()


@contextlib.contextmanager
def span(name: str, **attrs: Any) -> Iterator[Optional[Span]]:
    """Open a child span under the active trace. With NO active trace
    this yields ``None`` without allocating -- instrumentation stays
    near-free on untraced requests."""
    parent = _ACTIVE.get()
    if parent is None:
        yield None
        return
    child = Span(name, parent.trace_id, root_t0=parent._root_t0,
                 attrs=attrs or None)
    parent.children.append(child)
    child._enter()
    try:
        yield child
    finally:
        child._exit()
