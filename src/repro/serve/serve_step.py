"""Serving steps: jitted prefill + decode, and a batched generation loop.

``serve_step`` (decode) is what the decode_32k / long_500k dry-run shapes
lower: one new token against a seq_len-deep cache. Cache shardings follow
``repro.sharding.cache_specs`` (batch over data axes, heads over model).
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from ..configs.base import ArchConfig
from ..models.model import _head, forward, forward_hidden, init_model
from ..sharding.partition import batch_specs, cache_specs, param_specs
from .kvcache import init_caches

__all__ = ["make_prefill", "make_decode_step", "generate"]


def make_prefill(
    cfg: ArchConfig,
    mesh: Optional[Mesh] = None,
    max_len: int = 0,
    impl: str = "auto",
    fsdp: bool = False,
):
    """(params, batch) -> (last-position logits, caches). ``max_len`` is the
    cache capacity (>= prompt + generation length)."""

    def prefill(params, batch):
        b, s = batch["tokens"].shape
        caches = init_caches(cfg, b, max_len or s, dtype=jnp.dtype(cfg.dtype))
        hidden, caches, _ = forward_hidden(params, cfg, batch, caches=caches, impl=impl)
        # head on the last position only: prefill never needs 32k x V logits
        logits = _head(cfg, params, hidden[:, -1:])
        return logits[:, 0], caches

    if mesh is None:
        return jax.jit(prefill)
    abstract_p = jax.eval_shape(lambda: init_model(cfg, jax.random.PRNGKey(0)))
    p_sh = jax.tree.map(
        lambda s: NamedSharding(mesh, s), param_specs(cfg, abstract_p, mesh, fsdp=fsdp),
        is_leaf=lambda x: isinstance(x, P),
    )
    return jax.jit(prefill, in_shardings=(p_sh, None))


def make_decode_step(cfg: ArchConfig, mesh: Optional[Mesh] = None, impl: str = "auto"):
    """(params, tokens (B,1), caches, cache_index) -> (logits (B,V), caches)."""

    def decode(params, tokens, caches, cache_index):
        batch = {"tokens": tokens, "cache_index": cache_index}
        logits, caches, _ = forward(params, cfg, batch, caches=caches, impl=impl)
        return logits[:, -1], caches

    donate = (2,)
    return jax.jit(decode, donate_argnums=donate)


def greedy(logits: jnp.ndarray) -> jnp.ndarray:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def generate(
    params,
    cfg: ArchConfig,
    batch: Dict,
    steps: int,
    mesh: Optional[Mesh] = None,
    impl: str = "auto",
) -> jnp.ndarray:
    """Prefill the prompt batch, then greedy-decode ``steps`` tokens.
    Returns (B, steps) generated ids. Batched serving in ~15 lines."""
    b, s = batch["tokens"].shape
    extra = cfg.n_frontend_tokens if cfg.frontend == "vision" else 0
    prefill = make_prefill(cfg, mesh, max_len=s + steps + extra, impl=impl)
    decode = make_decode_step(cfg, mesh, impl=impl)
    logits, caches = prefill(params, batch)
    tok = greedy(logits)
    out = [tok]
    pos = s
    for _ in range(steps - 1):
        logits, caches = decode(params, tok[:, None], caches, jnp.int32(pos))
        tok = greedy(logits)
        out.append(tok)
        pos += 1
    return jnp.stack(out, axis=1)
