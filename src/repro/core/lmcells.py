"""LM op-graph workload cells -- the second :class:`~repro.core.workload.Cell`
family (``family="lm"``), routing the repo's real model configs through the
same eq.-18 machinery as the stencils.

The mapping onto the paper's decomposition:

* **cell**: one ``(model, op, shape)`` triple -- ``prefill``, ``decode``
  (KV-cache streaming via :func:`repro.serve.kvcache.cache_bytes`),
  ``train`` step, or ``moe_dispatch`` (the all-to-all routing op of MoE
  models) -- with an occurrence frequency;
* **hardware axis** (the paper's ``(n_SM, n_V, M_SM)`` analogue): the
  chip-budget factorizations ``(pod, data, model)`` of
  :class:`LMHardwareSpace`, with **area := chips** so every existing area
  budget / Pareto / what-if reduction applies unchanged;
* **software axis** (the tile-size analogue): the
  ``(microbatches, remat, fsdp, compress_grads)`` lattice of
  :class:`MeshPlan` knobs, minimized out independently per (cell, hw).

Two engines, mirroring :mod:`repro.core.codesign`: ``"numpy"`` evaluates the
scalar oracle's exact float64 expressions vectorized over the whole
``(hw, sw)`` grid, and ``"jax"`` jits the identical traceable body in
float32 (one compile per op kind -- cell constants enter as traced
scalars). :func:`lm_cell_roofline` is the plain-scalar oracle both are
parity-tested against; for the three standard ops it reproduces
:func:`repro.core.lmtime.lm_roofline` term for term.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..configs.base import SHAPES, ArchConfig, ShapeSpec
from .lmtime import HW, MeshPlan
from .pareto import pareto_mask
from .workload import Workload

__all__ = [
    "LMCell",
    "LMHardwareSpace",
    "LMSwLattice",
    "LMCodesignResult",
    "LM_GPU_NAME",
    "enumerate_lm_hw_space",
    "lm_sw_lattice",
    "lm_cells_for",
    "lm_workload",
    "lm_cell_roofline",
    "lm_codesign",
    "resolve_lm_engine",
]

#: default "gpu" routing attribute of LM artifacts: the chip the roofline
#: constants describe. Overridable per sweep (routing is not the model).
LM_GPU_NAME = "tpu_v5e"

#: the acceptance-criteria serving shape: decode at global batch 64 over an
#: 8k context (ISSUE: "what chip config serves Llama-3-8B at batch 64").
DECODE_B64 = ShapeSpec("decode_b64", 8192, 64, "decode")

LM_OPS = ("prefill", "decode", "train", "moe_dispatch")


@dataclasses.dataclass(frozen=True)
class LMCell:
    """One LM workload cell: an op of one model at one shape.

    All numeric fields are plain Python scalars precomputed at build time
    (parameter counts via ``jax.eval_shape``, KV bytes via
    :func:`repro.serve.kvcache.cache_bytes`), so a cell round-trips through
    a JSON manifest and the sweep never re-touches model code.
    """

    model: str  # arch name, e.g. "llama3-8b"
    op: str  # prefill | decode | train | moe_dispatch
    shape: ShapeSpec
    freq: float
    n_params: int  # total parameters (elements)
    n_active: int  # parameters touched per token (< n_params for MoE)
    kv_bytes: int  # full KV-cache bytes at this shape (0 unless decode)
    d_model: int
    n_layers: int
    flops: float  # useful FLOPs per step -- the GFLOP/s numerator
    moe_top_k: int = 0
    moe_capacity: float = 0.0
    moe_n_experts: int = 0

    def __post_init__(self):
        if self.op not in LM_OPS:
            raise ValueError(f"unknown LM op {self.op!r} (want one of {LM_OPS})")

    @property
    def family(self) -> str:
        return "lm"

    @property
    def label(self) -> str:
        return f"{self.model}:{self.op}"

    @property
    def tokens(self) -> int:
        """Tokens processed per step (decode emits one per sequence)."""
        return (
            self.shape.tokens
            if self.shape.kind != "decode"
            else self.shape.global_batch
        )

    def consts(self) -> Dict[str, float]:
        """The serializable numeric identity of this cell."""
        return {
            "n_params": int(self.n_params),
            "n_active": int(self.n_active),
            "kv_bytes": int(self.kv_bytes),
            "d_model": int(self.d_model),
            "n_layers": int(self.n_layers),
            "flops": float(self.flops),
            "moe_top_k": int(self.moe_top_k),
            "moe_capacity": float(self.moe_capacity),
            "moe_n_experts": int(self.moe_n_experts),
        }


# ---------------------------------------------------------------------------
# Design-space enumeration
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class LMHardwareSpace:
    """Flattened chip-budget factorizations; ``area`` IS the chip count, so
    the store/query/gateway area-budget machinery applies verbatim."""

    pod: np.ndarray
    data: np.ndarray
    model: np.ndarray
    area: np.ndarray  # = pod * data * model (chips)

    def __len__(self) -> int:
        return self.pod.shape[0]

    def point(self, i: int) -> Dict[str, float]:
        return {
            "pod": int(self.pod[i]),
            "data": int(self.data[i]),
            "model": int(self.model[i]),
            "chips": int(self.area[i]),
        }

    def downsample(self, step: int) -> "LMHardwareSpace":
        keep = np.arange(len(self)) % step == 0
        return LMHardwareSpace(
            self.pod[keep], self.data[keep], self.model[keep], self.area[keep]
        )


def enumerate_lm_hw_space(
    max_chips: int = 512, multi_pod: bool = True
) -> LMHardwareSpace:
    """All mesh factorizations ``pod * data * model <= max_chips`` with
    power-of-two data/model axes (the shapes XLA meshes actually take),
    sorted by (chips, pod, model) for a deterministic content address.

    The 512 default is the smallest power of two at which EVERY default
    cell fits HBM somewhere -- Mixtral-8x22B's train step needs 512 v5e
    chips -- so the default pair artifact has a non-empty answer for its
    own uniform mix (a mix is infeasible at a mesh where *any* workload
    cell is infeasible, zero-weighted or not; see docs/lm_codesign.md)."""
    rows: List[Tuple[int, int, int]] = []
    pows = [1 << j for j in range(max_chips.bit_length()) if (1 << j) <= max_chips]
    for pod in (1, 2) if multi_pod else (1,):
        for data in pows:
            for model in pows:
                if pod * data * model <= max_chips:
                    rows.append((pod, data, model))
    rows.sort(key=lambda r: (r[0] * r[1] * r[2], r[0], r[2], r[1]))
    arr = np.array(rows, np.float64)
    return LMHardwareSpace(
        pod=arr[:, 0],
        data=arr[:, 1],
        model=arr[:, 2],
        area=arr[:, 0] * arr[:, 1] * arr[:, 2],
    )


@dataclasses.dataclass(frozen=True)
class LMSwLattice:
    """Software-knob candidate rows (aligned columns, not a cross product
    object -- row ``j`` is one :class:`MeshPlan` knob setting)."""

    microbatches: Tuple[int, ...]
    remat_full: Tuple[int, ...]  # 0 | 1
    fsdp: Tuple[int, ...]  # 0 | 1
    compress: Tuple[int, ...]  # 0 | 1

    def __len__(self) -> int:
        return len(self.microbatches)

    def plan(self, pod: int, data: int, model: int, j: int) -> MeshPlan:
        """Materialize row ``j`` at one hardware point."""
        return MeshPlan(
            pod=pod,
            data=data,
            model=model,
            microbatches=int(self.microbatches[j]),
            remat="full" if self.remat_full[j] else "none",
            fsdp=bool(self.fsdp[j]),
            compress_grads=bool(self.compress[j]),
        )

    def as_dict(self) -> Dict[str, List[int]]:
        return {
            k: [int(x) for x in getattr(self, k)]
            for k in ("microbatches", "remat_full", "fsdp", "compress")
        }


MICROBATCHES = (1, 2, 4, 8, 16, 32)


def lm_sw_lattice(op: str) -> LMSwLattice:
    """The software lattice an op minimizes over (the tile-size analogue).

    Train steps search the full ``microbatches x remat x fsdp x compress``
    product (48 rows, matching :func:`repro.core.meshopt.enumerate_plans`'s
    knob ranges); inference ops and MoE dispatch have no backward pass, so
    only the weight-sharding knob remains (2 rows).
    """
    if op == "train":
        rows = list(
            itertools.product(MICROBATCHES, (0, 1), (0, 1), (0, 1))
        )
    else:
        rows = [(1, 0, 0, 0), (1, 0, 1, 0)]
    cols = list(zip(*rows))
    return LMSwLattice(
        microbatches=tuple(cols[0]),
        remat_full=tuple(cols[1]),
        fsdp=tuple(cols[2]),
        compress=tuple(cols[3]),
    )


# ---------------------------------------------------------------------------
# Cell builders
# ---------------------------------------------------------------------------
def lm_cells_for(
    cfg: ArchConfig,
    shapes: Optional[Dict[str, ShapeSpec]] = None,
    freq: float = 1.0,
) -> List[LMCell]:
    """Unnormalized cells for one architecture: prefill + decode@batch-64 +
    train step, plus the MoE dispatch op when the config routes experts.

    ``shapes`` overrides the per-op shape table (keys: op names); parameter
    counts come from ``jax.eval_shape`` over the real model init, so they
    are exact without allocating anything.
    """
    from ..models.model import active_params, count_params
    from ..serve.kvcache import cache_bytes

    shapes = {
        "prefill": SHAPES["prefill_32k"],
        "decode": DECODE_B64,
        "train": SHAPES["train_4k"],
        **(shapes or {}),
    }
    n_params = int(count_params(cfg))
    n_active = int(active_params(cfg))
    cells: List[LMCell] = []
    for op in ("prefill", "decode", "train"):
        shape = shapes[op]
        if shape.kind != op:
            raise ValueError(f"shape {shape.name!r} is kind {shape.kind!r}, not {op!r}")
        tokens = shape.tokens if op != "decode" else shape.global_batch
        mult = 6.0 if op == "train" else 2.0
        kv = (
            int(cache_bytes(cfg, shape.global_batch, shape.seq_len))
            if op == "decode"
            else 0
        )
        cells.append(
            LMCell(
                model=cfg.name,
                op=op,
                shape=shape,
                freq=freq,
                n_params=n_params,
                n_active=n_active,
                kv_bytes=kv,
                d_model=cfg.d_model,
                n_layers=cfg.n_layers,
                flops=mult * n_active * tokens,
            )
        )
    if cfg.moe is not None:
        shape = shapes.get("moe_dispatch", shapes["decode"])
        tokens = shape.tokens if shape.kind != "decode" else shape.global_batch
        cells.append(
            LMCell(
                model=cfg.name,
                op="moe_dispatch",
                shape=shape,
                freq=freq,
                n_params=n_params,
                n_active=n_active,
                kv_bytes=0,
                d_model=cfg.d_model,
                n_layers=cfg.n_layers,
                flops=2.0 * cfg.d_model * cfg.moe.n_experts * tokens,
                moe_top_k=cfg.moe.top_k,
                moe_capacity=cfg.moe.capacity_factor,
                moe_n_experts=cfg.moe.n_experts,
            )
        )
    return cells


def lm_workload(
    archs: Sequence = ("llama3-8b", "mixtral-8x22b"),
    name: str = "lm",
    shapes: Optional[Dict[str, ShapeSpec]] = None,
) -> Workload:
    """Uniform-frequency LM workload over the given architectures (names
    resolved through the config registry, or :class:`ArchConfig` objects
    passed directly -- tests use ``cfg.reduced()``). The default pair is
    the docs walkthrough's: a dense 8B and a large MoE."""
    from ..configs import get_arch

    cfgs = [a if isinstance(a, ArchConfig) else get_arch(a) for a in archs]
    raw: List[LMCell] = []
    for cfg in cfgs:
        raw.extend(lm_cells_for(cfg, shapes=shapes))
    cells = tuple(dataclasses.replace(c, freq=1.0 / len(raw)) for c in raw)
    return Workload(name=name, cells=cells)


# ---------------------------------------------------------------------------
# Scalar oracle
# ---------------------------------------------------------------------------
def _div_ok(op: str, gb: int, data_shards: int, microbatches: int) -> bool:
    """The :func:`repro.core.meshopt.optimize` shardability constraints."""
    if gb % data_shards and gb >= data_shards:
        return False
    if op == "train" and gb % (data_shards * microbatches):
        return False
    return True


def lm_cell_roofline(cell: LMCell, plan: MeshPlan) -> Dict:
    """Plain-scalar reference model for one (cell, plan) point.

    For prefill/decode/train this mirrors
    :func:`repro.core.lmtime.lm_roofline` expression for expression (a
    test asserts term-level equality against it); ``moe_dispatch`` is
    defined here: the dispatch+combine all-to-all of ``capacity * top_k``
    routed tokens over the model axis as expert parallelism, plus the
    router matmul, with weight-fit feasibility. Adds the mesh
    shardability constraint (``div_ok``) on top of the HBM fit;
    ``feasible`` is their conjunction and is what the sweep masks on.
    """
    chips = plan.chips
    ds = plan.data_shards
    tokens = cell.tokens
    peak, hbm_bw = HW["peak_flops_bf16"], HW["hbm_bw"]
    ici_bw = HW["ici_links"] * HW["ici_link_bw"]
    if cell.op == "moe_dispatch":
        tokens_local = tokens / ds
        toks_chip = cell.moe_capacity * cell.moe_top_k * tokens / chips
        t_compute = 2.0 * cell.d_model * cell.moe_n_experts * tokens / chips / peak
        t_memory = 2.0 * toks_chip * cell.d_model * 2.0 / hbm_bw
        ep_factor = (plan.model - 1) / plan.model
        t_coll = 2.0 * toks_chip * cell.d_model * 2.0 * ep_factor / ici_bw
        w_shards = plan.model * (ds if plan.fsdp else 1)
        hbm = 2.0 * cell.n_params / w_shards
    else:
        train = cell.op == "train"
        n_layers_eff = max(cell.n_layers, 1)
        recompute = 1.0 + (0.5 if (train and plan.remat == "full") else 0.0)
        t_compute = cell.flops * recompute / (chips * peak)
        passes = (2.0 if train else 1.0) * plan.microbatches
        w_shards = plan.model * (ds if plan.fsdp else 1)
        weight_traffic = 2.0 * cell.n_params / w_shards * passes
        tokens_local = tokens / ds
        act_traffic = 12.0 * tokens_local * cell.d_model * 2.0 * n_layers_eff
        opt_traffic = (12.0 * cell.n_params / chips) if train else 0.0
        kv_traffic = cell.kv_bytes / chips if cell.op == "decode" else 0.0
        t_memory = (weight_traffic + act_traffic + opt_traffic + kv_traffic) / hbm_bw
        tp_factor = 0.0 if plan.model == 1 else 2.0 * (plan.model - 1) / plan.model
        ar_per_layer = (4.0 if train and plan.remat == "full" else 2.0) * (
            2.0 if train else 1.0
        ) / 2.0
        tp_bytes = (
            ar_per_layer * n_layers_eff * tokens_local * cell.d_model * 2.0 * tp_factor
        ) * plan.microbatches
        dp_factor = 0.0 if ds == 1 or not train else 2.0 * (ds - 1) / ds
        grad_bytes_unit = 1.0 if plan.compress_grads else 4.0
        dp_bytes = grad_bytes_unit * cell.n_params / plan.model * dp_factor
        fsdp_bytes = 2.0 * cell.n_params / plan.model * passes if plan.fsdp else 0.0
        pod_fraction = 0.0 if plan.pod == 1 else (plan.pod - 1) / plan.pod
        dci_bytes = dp_bytes * pod_fraction
        ici_bytes = tp_bytes + fsdp_bytes + dp_bytes * (1 - pod_fraction)
        t_coll = ici_bytes / ici_bw + dci_bytes / HW["dci_link_bw"]
        hbm = 2.0 * cell.n_params / w_shards
        if train:
            hbm += 12.0 * cell.n_params / chips
            hbm += 3.0 * (tokens_local / plan.microbatches) * cell.d_model * 2.0 * (
                n_layers_eff
            ) * (1.0 if plan.remat == "full" else 4.0)
        if cell.op == "decode":
            hbm += cell.kv_bytes / chips
    terms = {"compute_s": t_compute, "memory_s": t_memory, "collective_s": t_coll}
    dominant = max(terms, key=terms.get)
    fits = hbm <= HW["hbm_bytes"] * 0.9
    div_ok = _div_ok(cell.op, cell.shape.global_batch, ds, plan.microbatches)
    return {
        **terms,
        "dominant": dominant.replace("_s", ""),
        "bound_s": terms[dominant],
        "hbm_bytes": hbm,
        "fits": fits,
        "div_ok": div_ok,
        "feasible": fits and div_ok,
    }


# ---------------------------------------------------------------------------
# Vectorized twin (traceable)
# ---------------------------------------------------------------------------
def _grid_times(op, consts, pod, data, model, mb, remat, fsdp, compress, xp):
    """(H, L) bound-seconds grid; +inf where infeasible.

    ``op`` is the only static branch (cell *structure*); every numeric
    input is an ``xp`` array or scalar, so the body traces under
    ``jax.vmap``/``jit`` and evaluates bit-exactly against the scalar
    oracle under ``xp=numpy`` float64 (identical expression order).
    Hardware columns arrive shaped (H, 1), software columns (L,); all
    terms broadcast to (H, L).
    """
    (tokens, gb, n_params, kv_bytes, d_model, n_layers_eff, flops,
     top_k, capacity, n_experts) = consts
    chips = pod * data * model
    ds = pod * data
    peak, hbm_bw = HW["peak_flops_bf16"], HW["hbm_bw"]
    ici_bw = HW["ici_links"] * HW["ici_link_bw"]
    one = xp.ones_like(mb)  # broadcast helper: (L,)
    if op == "moe_dispatch":
        toks_chip = capacity * top_k * tokens / chips
        t_compute = (2.0 * d_model * n_experts * tokens / chips / peak) * one
        t_memory = (2.0 * toks_chip * d_model * 2.0 / hbm_bw) * one
        ep_factor = (model - 1) / model
        t_coll = (2.0 * toks_chip * d_model * 2.0 * ep_factor / ici_bw) * one
        w_shards = model * (1.0 + fsdp * (ds - 1.0))
        hbm = 2.0 * n_params / w_shards
    else:
        train = op == "train"
        recompute = 1.0 + 0.5 * remat if train else one
        t_compute = flops * recompute / (chips * peak)
        passes = (2.0 if train else 1.0) * mb
        w_shards = model * (1.0 + fsdp * (ds - 1.0))
        weight_traffic = 2.0 * n_params / w_shards * passes
        tokens_local = tokens / ds
        act_traffic = 12.0 * tokens_local * d_model * 2.0 * n_layers_eff
        opt_traffic = 12.0 * n_params / chips if train else 0.0
        kv_traffic = kv_bytes / chips if op == "decode" else 0.0
        t_memory = (weight_traffic + act_traffic + opt_traffic + kv_traffic) / hbm_bw
        tp_factor = 2.0 * (model - 1.0) / model
        ar_per_layer = (2.0 + 2.0 * remat) * 2.0 / 2.0 if train else one
        tp_bytes = (
            ar_per_layer * n_layers_eff * tokens_local * d_model * 2.0 * tp_factor
        ) * mb
        dp_factor = 2.0 * (ds - 1.0) / ds if train else 0.0
        grad_bytes_unit = 4.0 - 3.0 * compress
        dp_bytes = grad_bytes_unit * n_params / model * dp_factor
        fsdp_bytes = fsdp * (2.0 * n_params / model * passes)
        pod_fraction = (pod - 1.0) / pod
        dci_bytes = dp_bytes * pod_fraction
        ici_bytes = tp_bytes + fsdp_bytes + dp_bytes * (1 - pod_fraction)
        t_coll = ici_bytes / ici_bw + dci_bytes / HW["dci_link_bw"]
        hbm = 2.0 * n_params / w_shards
        if train:
            hbm = hbm + 12.0 * n_params / chips + 3.0 * (
                tokens_local / mb
            ) * d_model * 2.0 * n_layers_eff * (4.0 - 3.0 * remat)
        if op == "decode":
            hbm = hbm + kv_bytes / chips
    bound = xp.maximum(t_compute, xp.maximum(t_memory, t_coll))
    fits = hbm <= HW["hbm_bytes"] * 0.9
    div = (xp.mod(gb, ds) == 0) | (gb < ds)
    if op == "train":
        div = div & (xp.mod(gb, ds * mb) == 0)
    feasible = fits & div
    return xp.where(feasible, bound, xp.inf)


def _cell_consts(cell: LMCell) -> Tuple[float, ...]:
    """The numeric tuple :func:`_grid_times` consumes (order matters)."""
    return (
        float(cell.tokens),
        float(cell.shape.global_batch),
        float(cell.n_params),
        float(cell.kv_bytes),
        float(cell.d_model),
        float(max(cell.n_layers, 1)),
        float(cell.flops),
        float(cell.moe_top_k),
        float(cell.moe_capacity),
        float(cell.moe_n_experts),
    )


_JIT_CACHE: Dict[str, object] = {}


def _jax_grid_fn(op: str):
    """One compiled grid evaluator per op kind; constants are traced, so
    every cell of an op reuses the same executable."""
    if op not in _JIT_CACHE:
        import jax
        import jax.numpy as jnp

        _JIT_CACHE[op] = jax.jit(
            lambda consts, pod, data, model, mb, remat, fsdp, compress: _grid_times(
                op, consts, pod, data, model, mb, remat, fsdp, compress, jnp
            )
        )
    return _JIT_CACHE[op]


def resolve_lm_engine(engine: str) -> str:
    """Concrete engine for the LM sweep. The LM hardware axis is small
    (dozens of factorizations), so ``"sharded"`` degenerates to the
    single-program jit path rather than paying mesh setup."""
    if engine not in ("auto", "jax", "sharded", "numpy"):
        raise ValueError(f"unknown engine {engine!r} (want auto|jax|sharded|numpy)")
    if engine == "numpy":
        return "numpy"
    from . import sweep  # module import only; no backend init

    if engine == "auto":
        return "jax" if sweep.HAVE_JAX else "numpy"
    if not sweep.HAVE_JAX:
        raise ModuleNotFoundError(
            f"engine={engine!r} requested but jax is not installed; "
            "use engine='auto' (soft fallback) or engine='numpy'"
        )
    return "jax"


# ---------------------------------------------------------------------------
# Result + driver
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class LMCodesignResult:
    """Per-cell optimal step times for every mesh factorization -- the LM
    twin of :class:`repro.core.codesign.CodesignResult`, exposing the same
    reduction surface so the artifact store, query engine, and gateway
    treat both families uniformly. ``gflops`` here reads "model GFLOP/s":
    useful model FLOPs per step over the optimized step time."""

    workload: Workload
    hw: LMHardwareSpace
    cell_time: np.ndarray  # (C, H) optimal bound_s; +inf infeasible
    cell_plan_idx: np.ndarray  # (C, H) winning sw-lattice row (-1 infeasible)
    sw_lattices: List[LMSwLattice]  # per cell
    gpu_name: str = LM_GPU_NAME

    family = "lm"

    # ---- reductions (same contracts as CodesignResult) --------------------
    def cell_freqs(self) -> np.ndarray:
        return np.array([c.freq for c in self.workload.cells], np.float64)

    def cell_flops(self) -> np.ndarray:
        return np.array([c.flops for c in self.workload.cells], np.float64)

    def weighted_time(self, freqs: Optional[np.ndarray] = None) -> np.ndarray:
        if freqs is None:
            freqs = self.cell_freqs()
        freqs = np.asarray(freqs, np.float64)
        return freqs @ self.cell_time

    def gflops(self, freqs: Optional[np.ndarray] = None) -> np.ndarray:
        if freqs is None:
            freqs = self.cell_freqs()
        freqs = np.asarray(freqs, np.float64)
        return (freqs @ self.cell_flops()) / self.weighted_time(freqs) / 1.0e9

    def pareto(self, freqs: Optional[np.ndarray] = None) -> np.ndarray:
        return pareto_mask(self.hw.area, self.gflops(freqs))

    def best(self, max_area: float = np.inf, freqs=None) -> Tuple[int, float]:
        g = self.gflops(freqs)
        g = np.where(self.hw.area <= max_area, g, -np.inf)
        i = int(np.argmax(g))
        return i, float(g[i])

    def plan_for(self, cell_index: int, hw_index: int) -> MeshPlan:
        """The winning :class:`MeshPlan` of one (cell, hw) solve."""
        j = int(self.cell_plan_idx[cell_index, hw_index])
        if j < 0:
            raise ValueError("infeasible cell/hw combination")
        p = self.hw.point(hw_index)
        return self.sw_lattices[cell_index].plan(p["pod"], p["data"], p["model"], j)

    def routing_metadata(self) -> Dict[str, object]:
        """Manifest routing block: same keys a stencil sweep publishes
        (gpu, workload) plus the LM discriminators (family, models, ops) --
        ``workload: "lm"`` is what ``query --workload lm`` selects on."""
        return {
            "gpu": self.gpu_name,
            "workload": self.workload.name,
            "family": "lm",
            "models": sorted({c.model for c in self.workload.cells}),
            "ops": sorted({c.op for c in self.workload.cells}),
        }

    # ---- artifact serialization ------------------------------------------
    def artifact_payload(self) -> Tuple[dict, Dict[str, np.ndarray]]:
        """(manifest, arrays) split; exact inverse of
        :meth:`from_artifact_payload` (JSON round-trips float64 losslessly)."""
        unique: List[LMSwLattice] = []
        lat_idx: List[int] = []
        for lat in self.sw_lattices:
            if lat not in unique:
                unique.append(lat)
            lat_idx.append(unique.index(lat))
        manifest = {
            "workload": {
                "name": self.workload.name,
                "family": "lm",
                "cells": [
                    {
                        "model": c.model,
                        "op": c.op,
                        "shape": {
                            "name": c.shape.name,
                            "seq_len": int(c.shape.seq_len),
                            "global_batch": int(c.shape.global_batch),
                            "kind": c.shape.kind,
                        },
                        "freq": float(c.freq),
                        "consts": c.consts(),
                        "lattice": lat_idx[i],
                    }
                    for i, c in enumerate(self.workload.cells)
                ],
            },
            "gpu": {"name": self.gpu_name, "hw": dict(HW)},
            "sw_lattices": [lat.as_dict() for lat in unique],
            "routing": self.routing_metadata(),
        }
        arrays = {
            "cell_time": np.asarray(self.cell_time, np.float64),
            "cell_plan_idx": np.asarray(self.cell_plan_idx, np.int64),
            "hw_pod": np.asarray(self.hw.pod, np.float64),
            "hw_data": np.asarray(self.hw.data, np.float64),
            "hw_model": np.asarray(self.hw.model, np.float64),
            "hw_area": np.asarray(self.hw.area, np.float64),
        }
        return manifest, arrays

    @staticmethod
    def parse_manifest(
        manifest: dict,
    ) -> Tuple[Workload, str, List[LMSwLattice]]:
        """JSON-only half of :meth:`from_artifact_payload`: ``(workload,
        gpu_name, per-cell sw lattices)``, touching no arrays."""
        lattices_tbl = [
            LMSwLattice(**{k: tuple(int(x) for x in v) for k, v in d.items()})
            for d in manifest["sw_lattices"]
        ]
        cells: List[LMCell] = []
        lattices: List[LMSwLattice] = []
        for c in manifest["workload"]["cells"]:
            s = c["shape"]
            shape = ShapeSpec(s["name"], s["seq_len"], s["global_batch"], s["kind"])
            cells.append(
                LMCell(
                    model=c["model"], op=c["op"], shape=shape, freq=c["freq"],
                    **c["consts"],
                )
            )
            lattices.append(lattices_tbl[c["lattice"]])
        workload = Workload(manifest["workload"]["name"], tuple(cells))
        return workload, manifest["gpu"]["name"], lattices

    @classmethod
    def from_artifact_payload(
        cls, manifest: dict, arrays: Dict[str, np.ndarray]
    ) -> "LMCodesignResult":
        workload, gpu_name, lattices = cls.parse_manifest(manifest)
        hw = LMHardwareSpace(
            pod=np.asarray(arrays["hw_pod"], np.float64),
            data=np.asarray(arrays["hw_data"], np.float64),
            model=np.asarray(arrays["hw_model"], np.float64),
            area=np.asarray(arrays["hw_area"], np.float64),
        )
        return cls(
            workload=workload,
            hw=hw,
            cell_time=np.asarray(arrays["cell_time"]),
            cell_plan_idx=np.asarray(arrays["cell_plan_idx"]),
            sw_lattices=lattices,
            gpu_name=gpu_name,
        )


def lm_codesign(
    workload: Workload,
    hw: Optional[LMHardwareSpace] = None,
    max_chips: int = 512,
    engine: str = "auto",
    gpu_name: str = LM_GPU_NAME,
) -> LMCodesignResult:
    """Eq. (18) for the LM family: for every mesh factorization, the
    optimal software knobs (and step time) of every cell.

    ``engine="numpy"`` evaluates the oracle's float64 expressions
    vectorized (bit-exact vs :func:`lm_cell_roofline`); ``"jax"`` jits the
    same body in float32; ``"auto"`` picks jax when importable. Infeasible
    (cell, hw) combinations -- HBM overflow or unshardable batch at every
    software setting -- carry ``+inf`` time and plan index ``-1``, exactly
    the stencil sweep's convention.
    """
    if getattr(workload, "family", "stencil") != "lm":
        raise ValueError(f"lm_codesign wants an LM workload, got {workload.family!r}")
    if hw is None:
        hw = enumerate_lm_hw_space(max_chips=max_chips)
    eng = resolve_lm_engine(engine)
    C, H = len(workload.cells), len(hw)
    cell_time = np.empty((C, H))
    cell_idx = np.empty((C, H), dtype=np.int64)
    lattices = [lm_sw_lattice(c.op) for c in workload.cells]
    for ci, cell in enumerate(workload.cells):
        lat = lattices[ci]
        consts = _cell_consts(cell)
        if eng == "jax":
            import jax.numpy as jnp

            f32 = lambda a: jnp.asarray(np.asarray(a, np.float32))
            grid = _jax_grid_fn(cell.op)(
                consts,
                f32(hw.pod)[:, None], f32(hw.data)[:, None], f32(hw.model)[:, None],
                f32(lat.microbatches), f32(lat.remat_full),
                f32(lat.fsdp), f32(lat.compress),
            )
            grid = np.asarray(grid, np.float64)
        else:
            c64 = lambda a: np.asarray(a, np.float64)
            grid = _grid_times(
                cell.op, consts,
                c64(hw.pod)[:, None], c64(hw.data)[:, None], c64(hw.model)[:, None],
                c64(lat.microbatches), c64(lat.remat_full),
                c64(lat.fsdp), c64(lat.compress),
                np,
            )
        idx = np.argmin(grid, axis=1)
        t = grid[np.arange(H), idx]
        cell_time[ci] = t
        cell_idx[ci] = np.where(np.isfinite(t), idx, -1)
    return LMCodesignResult(workload, hw, cell_time, cell_idx, lattices, gpu_name)
