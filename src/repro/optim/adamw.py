"""AdamW with decoupled weight decay, global-norm clipping and a
warmup+cosine schedule -- pure JAX over parameter pytrees.

Moments are f32 regardless of parameter dtype; parameters stay in their
storage dtype and the update is computed in f32 then cast back (bf16-native
training, the standard large-scale recipe when a separate f32 master copy
is not kept).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "global_norm", "lr_at"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1
    moment_dtype: str = "float32"  # bf16 halves optimizer HBM (see §Perf)


def lr_at(cfg: AdamWConfig, step) -> jnp.ndarray:
    """Linear warmup then cosine decay to min_lr_ratio * lr."""
    step = jnp.asarray(step, jnp.float32)
    warm = step / jnp.maximum(1.0, cfg.warmup_steps)
    t = (step - cfg.warmup_steps) / jnp.maximum(
        1.0, cfg.total_steps - cfg.warmup_steps
    )
    t = jnp.clip(t, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def adamw_init(params: Any, cfg: "AdamWConfig | None" = None) -> Dict[str, Any]:
    dtype = jnp.dtype(cfg.moment_dtype) if cfg else jnp.float32
    zeros = lambda p: jnp.zeros(p.shape, dtype)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(
    params: Any, grads: Any, state: Dict[str, Any], cfg: AdamWConfig
) -> Tuple[Any, Dict[str, Any], Dict[str, jnp.ndarray]]:
    """One step. Returns (params, state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = lr_at(cfg, step)
    bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m_new = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v_new = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(g)
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32
        )
        p_new = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return p_new, m_new.astype(mdt), v_new.astype(mdt)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "step": step}, metrics
