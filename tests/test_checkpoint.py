"""Checkpoint: atomic write, roundtrip, pruning, async, crash-consistency."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (
    AsyncCheckpointer,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "a": jax.random.normal(k, (4, 8)),
        "nested": {"b": jnp.arange(10, dtype=jnp.int32), "c": jnp.float32(3.5)},
    }


def test_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 7, t, extra={"next_step": 7})
    restored, step, extra = restore_checkpoint(str(tmp_path), t)
    assert step == 7 and extra["next_step"] == 7
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_and_multiple(tmp_path):
    for s in (5, 10, 15):
        save_checkpoint(str(tmp_path), s, _tree(s))
    assert latest_step(str(tmp_path)) == 15
    _, step, _ = restore_checkpoint(str(tmp_path), _tree(), step=10)
    assert step == 10


def test_tmp_dirs_are_invisible(tmp_path):
    save_checkpoint(str(tmp_path), 1, _tree())
    os.makedirs(tmp_path / "step_00000099.tmp")  # simulated dead write
    assert latest_step(str(tmp_path)) == 1


def test_structure_mismatch_raises(tmp_path):
    save_checkpoint(str(tmp_path), 1, _tree())
    with pytest.raises(ValueError):
        restore_checkpoint(str(tmp_path), {"only": jnp.zeros(3)})


def test_async_checkpointer_and_prune(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, _tree(s))
    ck.wait()
    steps = sorted(
        int(n.split("_")[1]) for n in os.listdir(tmp_path) if n.startswith("step_")
    )
    assert steps == [3, 4]
    restored, step, _ = restore_checkpoint(str(tmp_path), _tree())
    assert step == 4
    for a, b in zip(jax.tree.leaves(_tree(4)), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restore_with_shardings(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 3, t)
    sh = jax.tree.map(
        lambda _: jax.sharding.SingleDeviceSharding(jax.devices()[0]), t
    )
    restored, _, _ = restore_checkpoint(str(tmp_path), t, shardings=sh)
    assert all(
        leaf.sharding == jax.sharding.SingleDeviceSharding(jax.devices()[0])
        for leaf in jax.tree.leaves(restored)
        if hasattr(leaf, "sharding")
    )
