"""Refit the time model's machine parameters from measurements.

The analytical model (:mod:`repro.core.timemodel`) separates *design
variables* (n_SM, n_V, M_SM, tile sizes) from *machine parameters* the
paper measures per target (§IV.B): per-stencil per-iteration compute cost
``C_iter``, global-memory bandwidth, and launch overhead. This module fits
those machine parameters to a :class:`~repro.measure.harness
.MeasurementRun` by nonlinear least squares **in log space**::

    theta = log([C_iter(st_1) ... C_iter(st_n), bw_gmem, launch_overhead])
    loss(theta) = mean_r (log T_model(r; theta) - log T_measured(r))^2

The model is evaluated with ``xp=jax.numpy`` on specs carrying traced
parameters (:func:`repro.core.timemodel.with_c_iter` /
:func:`~repro.core.timemodel.with_machine_params`), so ``jax.grad``
differentiates straight through every floor/ceil term: the non-smoothness
lives entirely in factors that do not depend on ``theta``, which makes the
log-residual surface piecewise-smooth in the fitted parameters. The whole
descent (Adam, fixed iteration budget) runs as one jitted
``lax.fori_loop``.

Feasibility (eqs. 9-15) does not depend on ``theta`` either, so records
the model rejects at the nominal hardware point are dropped up front (and
counted in the result) instead of poisoning the loss with infinities.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.timemodel import (
    MAXWELL_GPU,
    STENCILS,
    GPUSpec,
    ProblemSize,
    StencilSpec,
    stencil_time,
    with_c_iter,
    with_machine_params,
)
from repro.core.workload import Workload, WorkloadCell, paper_sizes
from repro.kernels.pallas_stencils import TILE_NAMES

from .harness import MeasurementRecord, MeasurementRun, feasible_tiles

__all__ = [
    "RECOVERY_RTOL",
    "CalibrationResult",
    "predicted_times",
    "fit_machine_params",
    "synthetic_records",
]

#: the synthetic-recovery acceptance property, in ONE place: fitting
#: model-generated timings from perturbed starting parameters must land
#: every parameter within this relative error of the generating machine.
#: Both the CI smoke lane (scripts/measure_smoke.py) and the benchmark
#: suite (benchmarks/bench_measure.py) assert against this constant.
RECOVERY_RTOL = 0.05


def _group_arrays(
    records: Sequence[MeasurementRecord],
) -> Dict[str, Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]]:
    """stencil -> (hw (P,3), sizes (P,4) as (s1,s2,s3,t), tiles (P,5),
    measured times (P,)), in first-appearance order."""
    groups: Dict[str, List[MeasurementRecord]] = {}
    for r in records:
        groups.setdefault(r.stencil, []).append(r)
    out = {}
    for name, rs in groups.items():
        out[name] = (
            np.array([r.hw for r in rs], np.float64),
            np.array([r.size for r in rs], np.float64),
            np.array([r.tiles for r in rs], np.float64),
            np.array([r.time_s for r in rs], np.float64),
        )
    return out


def _model_times(
    st: StencilSpec,
    gpu: GPUSpec,
    hw: np.ndarray,
    sizes: np.ndarray,
    tiles: np.ndarray,
    xp=np,
    dtype=None,
):
    """Vectorized T_alg for (P,) records of one stencil; spec fields (and
    therefore the machine parameters) may be tracers."""
    size = ProblemSize(s1=sizes[:, 0], s2=sizes[:, 1], t=sizes[:, 3], s3=sizes[:, 2])
    return stencil_time(
        st, gpu, size, hw[:, 0], hw[:, 1], hw[:, 2],
        tiles[:, 0], tiles[:, 1], tiles[:, 2], tiles[:, 3], tiles[:, 4],
        xp=xp, dtype=dtype,
    )


def predicted_times(
    records: Sequence[MeasurementRecord],
    gpu: GPUSpec,
    stencils: Optional[Mapping[str, StencilSpec]] = None,
) -> np.ndarray:
    """Model predictions (float64 NumPy path) for each record, in order;
    infeasible configurations get ``+inf``."""
    stencils = dict(STENCILS if stencils is None else stencils)
    out = np.empty(len(records), np.float64)
    index: Dict[str, List[int]] = {}
    for i, r in enumerate(records):
        index.setdefault(r.stencil, []).append(i)
    for name, (hw, sizes, tiles, _) in _group_arrays(records).items():
        out[index[name]] = _model_times(stencils[name], gpu, hw, sizes, tiles)
    return out


@dataclasses.dataclass
class CalibrationResult:
    """Fitted machine parameters plus the before/after error report."""

    gpu0: GPUSpec  # datasheet constants the fit started from
    gpu: GPUSpec  # refitted (bw_gmem, launch_overhead)
    stencils: Dict[str, StencilSpec]  # refitted c_iter per measured stencil
    errors_before: Dict[str, float]  # per-stencil mean |rel err|, datasheet
    errors_after: Dict[str, float]  # ... refitted
    loss_before: float  # mean squared log residual
    loss_after: float
    n_records: int
    n_dropped: int  # model-infeasible records excluded from the fit
    iters: int
    learning_rate: float

    def param_rel_error(self, target_gpu: GPUSpec,
                        target_stencils: Mapping[str, StencilSpec]) -> float:
        """Max relative error of the fitted parameters vs a known-truth
        model -- the synthetic-recovery acceptance metric."""
        errs = [
            abs(self.gpu.bw_gmem - target_gpu.bw_gmem) / target_gpu.bw_gmem,
            abs(self.gpu.launch_overhead - target_gpu.launch_overhead)
            / target_gpu.launch_overhead,
        ]
        for name, st in self.stencils.items():
            truth = target_stencils[name].c_iter
            errs.append(abs(st.c_iter - truth) / truth)
        return float(max(errs))

    def calibrated_gpu(self, name: Optional[str] = None) -> GPUSpec:
        """The refitted GPUSpec under a distinguishable name (a calibrated
        artifact must never alias the datasheet target in routing)."""
        return with_machine_params(
            self.gpu, name=name or f"{self.gpu0.name}-cal"
        )

    def calibrated_workload(
        self,
        stencil_names: Optional[Sequence[str]] = None,
        name: str = "paper-uniform-cal",
    ) -> Workload:
        """The paper's uniform workload rebuilt on the refitted stencil
        specs -- what a calibrated sweep artifact is solved over."""
        names = list(stencil_names or self.stencils)
        missing = [n for n in names if n not in self.stencils]
        if missing:
            raise KeyError(f"stencil(s) {missing} were not calibrated")
        cells: List[WorkloadCell] = []
        for n in names:
            st = self.stencils[n]
            sizes = paper_sizes(st.dims)
            for sz in sizes:
                cells.append(WorkloadCell(st, sz, 1.0 / (len(names) * len(sizes))))
        return Workload(name=name, cells=tuple(cells))

    # ---- plain-JSON persistence (artifact-store manifest body) -----------
    def to_payload(self) -> dict:
        return {
            "gpu0": dataclasses.asdict(self.gpu0),
            "gpu": dataclasses.asdict(self.gpu),
            "stencils": {
                n: dataclasses.asdict(st) for n, st in sorted(self.stencils.items())
            },
            "errors_before": {k: float(v) for k, v in sorted(self.errors_before.items())},
            "errors_after": {k: float(v) for k, v in sorted(self.errors_after.items())},
            "loss_before": float(self.loss_before),
            "loss_after": float(self.loss_after),
            "n_records": int(self.n_records),
            "n_dropped": int(self.n_dropped),
            "iters": int(self.iters),
            "learning_rate": float(self.learning_rate),
        }

    @classmethod
    def from_payload(cls, obj: Mapping) -> "CalibrationResult":
        return cls(
            gpu0=GPUSpec(**obj["gpu0"]),
            gpu=GPUSpec(**obj["gpu"]),
            stencils={n: StencilSpec(**d) for n, d in obj["stencils"].items()},
            errors_before=dict(obj["errors_before"]),
            errors_after=dict(obj["errors_after"]),
            loss_before=float(obj["loss_before"]),
            loss_after=float(obj["loss_after"]),
            n_records=int(obj["n_records"]),
            n_dropped=int(obj["n_dropped"]),
            iters=int(obj["iters"]),
            learning_rate=float(obj["learning_rate"]),
        )


def _rel_errors(
    records: Sequence[MeasurementRecord],
    gpu: GPUSpec,
    stencils: Mapping[str, StencilSpec],
) -> Dict[str, float]:
    pred = predicted_times(records, gpu, stencils)
    per: Dict[str, List[float]] = {}
    for r, p in zip(records, pred):
        if np.isfinite(p):
            per.setdefault(r.stencil, []).append(abs(p - r.time_s) / r.time_s)
    return {k: float(np.mean(v)) for k, v in sorted(per.items())}


def fit_machine_params(
    run: MeasurementRun | Sequence[MeasurementRecord],
    gpu0: Optional[GPUSpec] = None,
    stencils0: Optional[Mapping[str, StencilSpec]] = None,
    iters: int = 1500,
    learning_rate: float = 0.05,
) -> CalibrationResult:
    """Fit (per-stencil C_iter, bw_gmem, launch_overhead) to measurements.

    Adam in log-parameter space (positivity for free, scale-invariant
    steps across parameters nine orders of magnitude apart), fixed
    ``iters`` budget, the whole descent one compiled ``lax.fori_loop``.
    """
    if isinstance(run, MeasurementRun):
        records = list(run.records)
        if gpu0 is None:
            from repro.core.timemodel import GPUS_BY_NAME

            gpu0 = GPUS_BY_NAME.get(run.gpu_name)
            if gpu0 is None:
                # a silent gtx980 fallback would frame the fit on the
                # wrong family AND name/route the calibration as
                # gtx980-cal -- cross-family confusion must be explicit
                raise ValueError(
                    f"measurement run is framed against unknown GPU "
                    f"{run.gpu_name!r}; pass gpu0= explicitly "
                    f"(known families: {sorted(GPUS_BY_NAME)})"
                )
    else:
        records = list(run)
    gpu0 = gpu0 or MAXWELL_GPU
    stencils0 = dict(STENCILS if stencils0 is None else stencils0)
    if not records:
        raise ValueError("no measurement records to fit")

    # drop model-infeasible records (theta-independent mask) up front
    pred0 = predicted_times(records, gpu0, stencils0)
    keep = np.isfinite(pred0)
    n_dropped = int((~keep).sum())
    records = [r for r, k in zip(records, keep) if k]
    if not records:
        raise ValueError("every record is infeasible under the analytical model")

    groups = _group_arrays(records)
    names = list(groups)  # first-appearance order; theta layout
    dev_groups = {
        n: tuple(jnp.asarray(a, jnp.float32) for a in arrs)
        for n, arrs in groups.items()
    }
    theta0 = jnp.log(
        jnp.asarray(
            [stencils0[n].c_iter for n in names]
            + [gpu0.bw_gmem, gpu0.launch_overhead],
            jnp.float32,
        )
    )

    def loss_fn(theta):
        total, count = 0.0, 0
        for gi, n in enumerate(names):
            hw, sizes, tiles, t_meas = dev_groups[n]
            st = with_c_iter(stencils0[n], jnp.exp(theta[gi]))
            gpu = with_machine_params(
                gpu0, bw_gmem=jnp.exp(theta[-2]), launch_overhead=jnp.exp(theta[-1])
            )
            pred = _model_times(st, gpu, hw, sizes, tiles, xp=jnp, dtype=jnp.float32)
            r = jnp.log(pred) - jnp.log(t_meas)
            total = total + jnp.sum(r * r)
            count += t_meas.shape[0]
        return total / count

    grad_fn = jax.value_and_grad(loss_fn)

    @jax.jit
    def descend(theta):
        m0 = jnp.zeros_like(theta)
        v0 = jnp.zeros_like(theta)
        b1, b2, eps = 0.9, 0.999, 1e-8

        def step(i, carry):
            theta, m, v = carry
            _, g = grad_fn(theta)
            m = b1 * m + (1.0 - b1) * g
            v = b2 * v + (1.0 - b2) * g * g
            t = i + 1.0
            mhat = m / (1.0 - b1**t)
            vhat = v / (1.0 - b2**t)
            theta = theta - learning_rate * mhat / (jnp.sqrt(vhat) + eps)
            return theta, m, v

        theta, _, _ = lax.fori_loop(0.0, float(iters), step, (theta, m0, v0))
        return theta

    theta = np.asarray(descend(theta0), np.float64)
    fitted = np.exp(theta)
    stencils = {
        n: with_c_iter(stencils0[n], float(fitted[i])) for i, n in enumerate(names)
    }
    gpu = with_machine_params(
        gpu0, bw_gmem=float(fitted[-2]), launch_overhead=float(fitted[-1])
    )

    def _sq_log_loss(g, sts):
        pred = predicted_times(records, g, sts)
        r = np.log(pred) - np.log([rec.time_s for rec in records])
        return float(np.mean(r * r))

    return CalibrationResult(
        gpu0=gpu0,
        gpu=gpu,
        stencils=stencils,
        errors_before=_rel_errors(records, gpu0, stencils0),
        errors_after=_rel_errors(records, gpu, stencils),
        loss_before=_sq_log_loss(gpu0, stencils0),
        loss_after=_sq_log_loss(gpu, stencils),
        n_records=len(records),
        n_dropped=n_dropped,
        iters=int(iters),
        learning_rate=float(learning_rate),
    )


def synthetic_records(
    gpu: GPUSpec,
    stencils: Optional[Mapping[str, StencilSpec]] = None,
    noise: float = 0.0,
    seed: int = 0,
    hw_points: Optional[Sequence[Tuple[float, float, float]]] = None,
) -> List[MeasurementRecord]:
    """Model-generated "measurements" (the CI calibration check's input:
    fitting these from perturbed starting parameters must recover the
    generating model). Varies hardware point, problem size, and tile so
    every fitted parameter is identifiable; ``noise`` is multiplicative
    log-normal sigma."""
    stencils = dict(STENCILS if stencils is None else stencils)
    if hw_points is None:
        # the (2, 32) point matters: with few SMs the memory term
        # (concurrent * footprint / bw) stays small, so every stencil gets
        # compute-bound records and C_iter's gradient never plateaus under
        # the max(t_compute, t_mem) kink (memory-bound-only grids leave
        # C_iter unidentifiable).
        hw_points = [(16.0, 128.0, 96.0), (8.0, 64.0, 48.0), (2.0, 32.0, 96.0)]
    tile_cands = [
        {"t_s1": 8, "t_s2": 32, "t_t": 2, "k": 1},
        {"t_s1": 16, "t_s2": 64, "t_t": 4, "k": 2},
        {"t_s1": 32, "t_s2": 128, "t_t": 8, "k": 1},
    ]
    sizes_2d = [(512, 512, 1, 8), (2048, 2048, 1, 64), (128, 128, 1, 2)]
    sizes_3d = [(64, 64, 64, 8), (128, 128, 128, 16), (32, 32, 32, 2)]
    rng = np.random.default_rng(seed)
    candidates: List[MeasurementRecord] = []
    for name, st in stencils.items():
        sizes = sizes_3d if st.dims == 3 else sizes_2d
        for hw in hw_points:
            hw_map = dict(zip(("n_sm", "n_v", "m_sm"), hw))
            for tiles in feasible_tiles(name, tile_cands, gpu, hw_map):
                for size in sizes:
                    candidates.append(
                        MeasurementRecord(
                            stencil=name,
                            size=size,
                            tiles=tuple(int(tiles[k]) for k in TILE_NAMES),
                            time_s=1.0,  # placeholder, replaced below
                            hw=hw,
                        )
                    )
    # one vectorized model pass over the whole grid (per-stencil groups)
    times = predicted_times(candidates, gpu, stencils)
    if noise > 0:
        times = times * np.exp(rng.normal(0.0, noise, size=times.shape))
    out = [
        dataclasses.replace(rec, time_s=float(t))
        for rec, t in zip(candidates, times)
        if np.isfinite(t)
    ]
    if not out:
        raise RuntimeError("synthetic grid produced no feasible records")
    return out
