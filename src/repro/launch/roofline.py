"""Roofline analysis over the dry-run artifacts (deliverable g).

This container is CPU-only; TPU v5e is the *target*. The three roofline
terms are derived per (arch x shape x mesh) from the compiled artifact:

    compute term    = HLO_FLOPs / peak_FLOP/s            [per-chip]
    memory term     = HLO_bytes / HBM_bw                 [per-chip]
    collective term = collective_bytes / (links*link_bw) [per-chip]

where HLO_FLOPs is the *scan-expanded* dot-FLOP count (see hloanalysis.py --
cost_analysis visits while bodies once and would undercount by the layer
count), HLO_bytes is the loop-expanded *materialized* bytes (write+read of
every fusion-boundary tensor -- cost_analysis 'bytes accessed' has no
fusion awareness and overstates HBM traffic by orders of magnitude), and
collective_bytes is the loop-expanded sum of collective operand bytes
parsed from the optimized HLO.

The SPMD module after partitioning is per-chip, so every quantity here is
per-chip per-step; dividing by per-chip peaks gives seconds directly (the
"/ chips" in the assignment formulas is absorbed because cost_analysis is
already per-chip).

Also reported per cell: dominant term, MODEL_FLOPS = 6*N(_active)*D (2*N*D
for inference shapes), useful-compute ratio MODEL_FLOPS/HLO_FLOPs, and a
one-line lever for the dominant term.

This module reads *compiled* HLO counters; its analytic twin is
``repro.core.lmtime.lm_roofline``, which predicts the same three terms
from closed-form traffic formulas (and whose ``HW`` table extends the one
below with DCI constants for cross-pod meshes). The LM codesign sweep
(``repro.core.lmcells``) vectorizes those formulas over whole mesh-plan
lattices.
"""

from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List, Optional

__all__ = ["HW", "roofline_terms", "load_cells", "render_table", "main"]

#: TPU v5e per-chip hardware constants (assignment-provided).
HW = {
    "peak_flops_bf16": 197e12,  # FLOP/s
    "hbm_bw": 819e9,  # B/s
    "ici_link_bw": 50e9,  # B/s per link
    "ici_links": 4,  # torus links usable per chip (2D torus, 4 neighbours)
    "hbm_bytes": 16e9,
}


def model_flops_for(rec: Dict, seq_len: int, global_batch: int) -> float:
    """6*N_active*D for training, 2*N_active*D forward-only (prefill),
    2*N_active*B for one decoded token."""
    n = rec.get("active_params") or rec.get("params") or 0
    kind = rec.get("kind", "train")
    if kind == "train":
        return 6.0 * n * seq_len * global_batch
    if kind == "prefill":
        return 2.0 * n * seq_len * global_batch
    return 2.0 * n * global_batch  # decode: one token per sequence


def roofline_terms(rec: Dict, chips: Optional[int] = None) -> Dict:
    """Three terms in seconds (per chip = per step wall-clock bound)."""
    chips = chips or rec.get("chips", 256)
    raw_flops = rec.get("flops", 0.0) or 0.0
    exp_flops = rec.get("dot_flops_expanded", 0.0) or 0.0
    ratio = exp_flops / raw_flops if raw_flops > 0 and exp_flops > 0 else 1.0
    ratio = max(ratio, 1.0)
    bytes_accessed = rec.get("materialized_bytes", 0.0) or (
        (rec.get("bytes_accessed", 0.0) or 0.0) * ratio
    )
    coll = rec.get("collective_bytes", 0.0) or 0.0

    t_compute = exp_flops / HW["peak_flops_bf16"]
    t_memory = bytes_accessed / HW["hbm_bw"]
    t_coll = coll / (HW["ici_links"] * HW["ici_link_bw"])
    terms = {"compute_s": t_compute, "memory_s": t_memory, "collective_s": t_coll}
    dominant = max(terms, key=terms.get)
    bound = terms[dominant]
    out = dict(terms)
    out["dominant"] = dominant.replace("_s", "")
    out["bound_s"] = bound
    out["bytes_expansion_ratio"] = ratio
    return out


_LEVERS = {
    "compute": (
        "cut recompute (remat policy) or raise MXU utilization "
        "(pad matmul dims to 128, fuse small einsums)"
    ),
    "memory": (
        "raise arithmetic intensity: larger microbatch per chip, bf16 "
        "accumulators where safe, fuse normalization chains"
    ),
    "collective": (
        "re-shard to cut all-reduce bytes: sequence-parallel reduce-scatter, "
        "microbatch-amortized grad reduction, int8 cross-pod compression, "
        "or a different mesh factorization (meshopt)"
    ),
}


def load_cells(outdir: str, mesh_kind: str = "single") -> List[Dict]:
    d = os.path.join(outdir, mesh_kind)
    cells = []
    if not os.path.isdir(d):
        return cells
    for name in sorted(os.listdir(d)):
        if name.endswith(".json"):
            with open(os.path.join(d, name)) as f:
                cells.append(json.load(f))
    return cells


def analyze_cell(rec: Dict, shapes: Dict) -> Optional[Dict]:
    if rec.get("skipped") or "error" in rec:
        return None
    shape = shapes[rec["shape"]]
    terms = roofline_terms(rec)
    mf_total = model_flops_for(rec, shape.seq_len, shape.global_batch)
    mf_chip = mf_total / rec.get("chips", 256)
    hlo = rec.get("dot_flops_expanded", 0.0) or 1.0
    useful = mf_chip / hlo if hlo else 0.0
    step_s = terms["bound_s"]
    mfu = (mf_chip / HW["peak_flops_bf16"]) / step_s if step_s > 0 else 0.0
    return {
        "arch": rec["arch"],
        "shape": rec["shape"],
        "mesh": rec["mesh"],
        "plan": rec.get("plan", {}),
        **{k: terms[k] for k in ("compute_s", "memory_s", "collective_s")},
        "dominant": terms["dominant"],
        "model_flops_per_chip": mf_chip,
        "useful_ratio": useful,
        "roofline_fraction": mfu,
        "lever": _LEVERS[terms["dominant"]],
        "hbm_gb": (rec.get("memory", {}).get("temp_size_in_bytes", 0)
                   + rec.get("memory", {}).get("argument_size_in_bytes", 0)) / 1e9,
    }


def render_table(rows: List[Dict]) -> str:
    hdr = (
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "useful | roofline frac | HBM GB |\n"
        "|---|---|---|---|---|---|---|---|---|\n"
    )
    lines = []
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | "
            f"**{r['dominant']}** | {r['useful_ratio']:.2f} | "
            f"{r['roofline_fraction']:.3f} | {r['hbm_gb']:.1f} |"
        )
    return hdr + "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="benchmarks/artifacts/dryrun")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--json", default="")
    args = ap.parse_args()
    from repro.configs.base import SHAPES

    rows = []
    for rec in load_cells(args.out, args.mesh):
        row = analyze_cell(rec, SHAPES)
        if row:
            rows.append(row)
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    print(render_table(rows))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
