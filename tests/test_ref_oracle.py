"""Hardening for `kernels/ref.py` -- the oracle every Pallas kernel
(banded and tile-parameterized) is equivalence-tested against.

The cross-check here is a third, maximally-dumb implementation: explicit
Python loops over cells in NumPy float64, written from the stencils'
mathematical definitions (module docstrings), sharing no code with either
the jnp oracle or the kernels. Coverage: odd/degenerate shapes and both
float32/float64 inputs (the latter under JAX's x64 mode) -- the contract
being that ref computes in f32 regardless of input dtype and stores back
in the input dtype."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ops import stencil_step
from repro.kernels.ref import REF_STEPS, run_ref

NAMES_2D = ["jacobi2d", "heat2d", "laplacian2d", "gradient2d"]
NAMES_3D = ["heat3d", "laplacian3d"]

ODD_SHAPES_2D = [(3, 3), (5, 7), (9, 3), (4, 3), (7, 13), (2, 5)]
ODD_SHAPES_3D = [(3, 3, 3), (5, 3, 7), (7, 7, 5), (3, 4, 5)]


def _loop_step_2d(name: str, x: np.ndarray) -> np.ndarray:
    """One step, scalar loops, float64 -- independent of ref.py's slicing."""
    x = np.asarray(x, np.float64)
    y = x.copy()
    n_r, n_c = x.shape
    for i in range(1, n_r - 1):
        for j in range(1, n_c - 1):
            c = x[i, j]
            n = x[i - 1, j]
            s = x[i + 1, j]
            w = x[i, j - 1]
            e = x[i, j + 1]
            if name == "jacobi2d":
                y[i, j] = 0.2 * (c + n + s + e + w)
            elif name == "heat2d":
                y[i, j] = c + 0.125 * (n + s + e + w - 4.0 * c)
            elif name == "laplacian2d":
                y[i, j] = n + s + e + w - 4.0 * c
            elif name == "gradient2d":
                gx = 0.5 * (e - w)
                gy = 0.5 * (s - n)
                y[i, j] = np.sqrt(gx * gx + gy * gy)
            else:
                raise AssertionError(name)
    return y


def _loop_step_3d(name: str, x: np.ndarray) -> np.ndarray:
    x = np.asarray(x, np.float64)
    y = x.copy()
    d, h, w = x.shape
    for i in range(1, d - 1):
        for j in range(1, h - 1):
            for k in range(1, w - 1):
                c = x[i, j, k]
                neighbors = (
                    x[i - 1, j, k] + x[i + 1, j, k]
                    + x[i, j - 1, k] + x[i, j + 1, k]
                    + x[i, j, k - 1] + x[i, j, k + 1]
                )
                if name == "heat3d":
                    y[i, j, k] = c + 0.125 * (neighbors - 6.0 * c)
                elif name == "laplacian3d":
                    y[i, j, k] = neighbors - 6.0 * c
                else:
                    raise AssertionError(name)
    return y


def _loop_run(name: str, x: np.ndarray, steps: int) -> np.ndarray:
    step = _loop_step_3d if name in NAMES_3D else _loop_step_2d
    for _ in range(steps):
        x = step(name, x)
    return x


def _rand(shape, seed=0):
    rng = np.random.default_rng(seed)
    return rng.normal(size=shape)


TOL = dict(rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("name", NAMES_2D)
@pytest.mark.parametrize("shape", ODD_SHAPES_2D)
def test_ref_2d_matches_scalar_loops_float32(name, shape):
    x = _rand(shape, seed=sum(shape))
    got = run_ref(name, jnp.asarray(x, jnp.float32), steps=2)
    want = _loop_run(name, x, steps=2)
    assert got.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(got, np.float64), want, **TOL)


@pytest.mark.parametrize("name", NAMES_3D)
@pytest.mark.parametrize("shape", ODD_SHAPES_3D)
def test_ref_3d_matches_scalar_loops_float32(name, shape):
    x = _rand(shape, seed=sum(shape))
    got = run_ref(name, jnp.asarray(x, jnp.float32), steps=2)
    want = _loop_run(name, x, steps=2)
    np.testing.assert_allclose(np.asarray(got, np.float64), want, **TOL)


@pytest.mark.parametrize("name", list(REF_STEPS))
def test_ref_float64_inputs_keep_dtype_and_f32_accuracy(name):
    """Under x64, a float64 input must come back float64, with values at
    f32 accuracy (ref deliberately computes in f32 so the kernels and the
    oracle share an arithmetic contract across input dtypes)."""
    shape = (5, 7, 9) if name in NAMES_3D else (7, 9)
    x = _rand(shape, seed=42)
    with jax.experimental.enable_x64():
        xin = jnp.asarray(x, jnp.float64)
        assert xin.dtype == jnp.float64
        got = run_ref(name, xin, steps=1)
        assert got.dtype == jnp.float64
    np.testing.assert_allclose(
        np.asarray(got), _loop_run(name, x, steps=1), **TOL
    )


@pytest.mark.parametrize("name", list(REF_STEPS))
def test_ref_degenerate_interiors_are_identity(name):
    """Shapes with no interior (any extent <= 2) must pass through
    unchanged -- the Dirichlet border is the whole array."""
    shape = (2, 5, 2) if name in NAMES_3D else (2, 6)
    x = jnp.asarray(_rand(shape), jnp.float32)
    np.testing.assert_array_equal(np.asarray(run_ref(name, x)), np.asarray(x))


@pytest.mark.parametrize("name", ["jacobi2d", "gradient2d", "heat3d"])
def test_banded_pallas_kernels_close_the_triangle(name):
    """kernels -> ref -> scalar loops: the banded Pallas kernels must also
    match the scalar-loop truth directly (not only transitively), on odd
    shapes that stress their masking."""
    shape = (5, 3, 7) if name in NAMES_3D else (5, 7)
    x = _rand(shape, seed=9)
    got = stencil_step(name, jnp.asarray(x, jnp.float32), interpret=True)
    np.testing.assert_allclose(
        np.asarray(got, np.float64), _loop_run(name, x, steps=1), **TOL
    )
