"""PartitionSpec rules for every parameter / activation / cache tensor.

Strategy (Megatron-style TP x DP, EP for experts, ZeRO-1 for optimizer
state):

* batch-like dims -> the data axes (``('pod', 'data')`` on the multi-pod
  mesh, ``('data',)`` single-pod);
* attention head / ffn hidden / vocab dims -> the ``model`` axis;
* MoE experts -> the ``model`` axis (EP) when E divides the axis size,
  otherwise TP *within* experts (mixtral's 8 experts on a 16-wide axis);
* SSM d_inner-sized dims -> ``model``; the small B/C/dt streams replicate;
* optimizer moments -> the parameter spec plus the data axes on the largest
  still-unsharded dim (ZeRO-1);
* KV caches -> batch over data, kv-heads over model; MLA latents and SSM
  states shard their structurally analogous dims.

Rules are name/context-based over the parameter tree (tree_map_with_path),
so new layers that reuse the naming conventions are covered automatically.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from ..configs.base import ArchConfig

__all__ = [
    "data_axes",
    "param_specs",
    "opt_state_specs",
    "batch_specs",
    "cache_specs",
]


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def _axis_size(mesh: Mesh, name: str) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(name, 1)


# leaf-name buckets ---------------------------------------------------------
_SHARD_LAST = {"wq", "wk", "wv", "wq_b", "wkv_b", "up", "gate", "wz", "wx", "proj", "lm_head"}
_SHARD_PENULT_LAST = {"wo", "down", "out_proj"}  # (in=model-sharded, out)
_REPLICATE = {
    "router", "wq_a", "wkv_a", "wbc", "wdt", "conv_x_b", "conv_bc_w",
    "conv_bc_b", "dt_bias", "a_log", "d_skip", "norm_w", "q_norm", "kv_norm",
    "norm1", "norm2", "norm_cross", "final_norm", "enc_norm", "norm_h",
    "norm_e", "pos_embed", "conv_b",
}
_SHARD_LAST_1D = {"conv_x_w", "conv_x_b"}  # depthwise conv over d_inner


def _name_of(path) -> str:
    names = [p.key for p in path if isinstance(p, jax.tree_util.DictKey)]
    return names[-1] if names else ""


def _add_dp(spec: P, shape, dp: Tuple[str, ...], mesh: Mesh, min_elems: int = 1 << 16) -> P:
    """Additionally shard the largest evenly-divisible free dim over the
    (not already used) data axes (FSDP / ZeRO-style; GSPMD inserts the
    per-layer gather)."""
    if not dp or len(shape) == 0 or int(np.prod(shape)) < min_elems:
        return spec
    entries = list(spec) + [None] * (len(shape) - len(spec))
    used = set()
    for e in entries:
        if e is None:
            continue
        used.update(e if isinstance(e, tuple) else (e,))
    dp = tuple(a for a in dp if a not in used)
    if not dp:
        return spec
    dp_size = int(np.prod([_axis_size(mesh, a) for a in dp]))
    free = [
        i for i, s in enumerate(entries)
        if s is None and shape[i] % max(dp_size, 1) == 0
    ]
    if not free:
        return spec
    i_best = max(free, key=lambda i: shape[i])
    entries[i_best] = dp if len(dp) > 1 else dp[0]
    return P(*entries)


def _in_experts(path) -> bool:
    return any(
        isinstance(p, jax.tree_util.DictKey) and p.key == "experts" for p in path
    )


def _divisible(shape, entries, mesh) -> bool:
    """Every sharded dim must divide evenly (jit argument requirement)."""
    for size, e in zip(shape, entries):
        if e is None:
            continue
        axes = e if isinstance(e, tuple) else (e,)
        total = int(np.prod([_axis_size(mesh, a) for a in axes]))
        if total and size % total:
            return False
    return True


def _spec_for(path, leaf, cfg: ArchConfig, mesh: Mesh, fsdp: bool = False) -> P:
    name = _name_of(path)
    shape = tuple(getattr(leaf, "shape", ()))
    rank = len(shape)
    model_size = _axis_size(mesh, "model")
    dp = data_axes(mesh) if fsdp else ()

    def pad(*candidates):
        """First candidate whose sharded dims divide evenly; candidates are
        right-aligned tails, left-padded with None for stacked leading dims.
        FSDP then adds the data axes on the largest remaining free dim."""
        for tail in list(candidates) + [[None] * rank]:
            entries = [None] * (rank - len(tail)) + list(tail)
            if _divisible(shape, entries, mesh):
                spec = P(*entries)
                return _add_dp_checked(spec, shape, dp, mesh) if dp else spec
        return P(*([None] * rank))

    if _in_experts(path):
        e = cfg.moe.n_experts
        if e % model_size == 0:
            # EP: shard the expert dim (dim -3 of (E, d, f) matrices)
            return pad(["model", None, None])
        # TP within experts
        if name in ("up", "gate"):
            return pad([None, None, "model"], [None, "model", None])
        return pad([None, "model", None], [None, None, "model"])

    if name == "embed":
        # vocab-sharded; odd vocabs (whisper 51865) fall back to d_model
        return pad(["model", None], [None, "model"])
    if name in _REPLICATE:
        return P(*([None] * rank))
    if name in _SHARD_LAST_1D:
        return pad(["model"])
    if name in _SHARD_LAST:
        return pad([None, "model"], ["model", None])
    if name in _SHARD_PENULT_LAST:
        return pad(["model", None], [None, "model"])
    # default: replicate (biases, scalars, anything unrecognized)
    return P(*([None] * rank))


def _add_dp_checked(spec: P, shape, dp, mesh) -> P:
    return _add_dp(spec, shape, dp, mesh)


def param_specs(cfg: ArchConfig, params: Any, mesh: Mesh, fsdp: bool = False):
    """PartitionSpec pytree matching ``params`` (works on shapes too).

    ``fsdp=True`` additionally shards every large parameter over the data
    axes (ZeRO-3 / weight-gather) -- required for the >50B archs, where
    TP-16 alone leaves tens of GB of parameters per chip."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _spec_for(path, leaf, cfg, mesh, fsdp), params
    )


def opt_state_specs(cfg: ArchConfig, params: Any, mesh: Mesh, fsdp: bool = False):
    """ZeRO-1: moments = param spec + data axes on the largest free dim.
    (With fsdp=True the param spec already includes the data axes.)"""
    dp = data_axes(mesh)
    dp_size = int(np.prod([_axis_size(mesh, a) for a in dp]))

    def zero1(path, leaf):
        spec = _spec_for(path, leaf, cfg, mesh, fsdp)
        if dp_size == 1:
            return spec
        return _add_dp(spec, leaf.shape, dp, mesh)

    return jax.tree_util.tree_map_with_path(zero1, params)


def batch_specs(cfg: ArchConfig, mesh: Mesh, batch_size: int = 0) -> Dict[str, P]:
    """Input shardings: batch over the data axes (replicated when the batch
    is smaller than the data extent, e.g. long_500k's global_batch=1)."""
    dp = data_axes(mesh)
    dp_size = int(np.prod([_axis_size(mesh, a) for a in dp]))
    b = dp if len(dp) > 1 else dp[0]
    if batch_size and batch_size % max(dp_size, 1):
        b = None
    specs = {
        "tokens": P(b, None),
        "labels": P(b, None),
        "positions": P(b, None),
    }
    if cfg.frontend or cfg.enc_dec:
        specs["frontend"] = P(b, None, None)
    if cfg.rope == "mrope":
        specs["positions"] = P(b, None, None)
    return specs


def _cache_leaf_spec(path, leaf, cfg, mesh, b, seq_axis):
    """Cache shardings with divisibility-guarded fallbacks.

    * batch over the data axes when it divides; otherwise (long_500k B=1)
      the cache *length* dim is sharded over data instead -- context
      parallelism over the KV/ring cache;
    * kv-heads over model when divisible (llama KH=8 on model=16 falls back
      to head_dim); SSM states shard heads, falling back to head_dim.
    """
    name = _name_of(path)
    rank = leaf.ndim
    shape = tuple(leaf.shape)

    def pad(*tails):
        for tail in list(tails) + [[None] * rank]:
            entries = [None] * (rank - len(tail)) + list(tail)
            if _divisible(shape, entries, mesh):
                return P(*entries)
        return P(*([None] * rank))

    sa = seq_axis  # 'data' axes when batch cannot shard, else None
    if name == "idx":
        return P(*([None] * rank))
    if name in ("k", "v"):  # (reps?, B, L, KH, Dh)
        return pad([b, sa, "model", None], [b, sa, None, "model"], [b, sa, None, None])
    if name in ("ckv", "krope"):  # (reps?, B, L, r)
        return pad([b, sa, None])
    if name in ("conv_x",):  # (reps?, B, K-1, d_inner)
        return pad([b, None, "model"])
    if name in ("conv_bc",):
        return pad([b, None, None])
    if name == "ssm":  # (reps?, B, H, P, N)
        return pad([b, "model", None, None], [b, None, "model", None], [b, None, None, "model"])
    if name == "enc_out":  # (B, S_enc, d)
        return pad([b, None, None])
    return P(*([None] * rank))


def cache_specs(cfg: ArchConfig, caches: Any, mesh: Mesh, batch_size: int = 0):
    dp = data_axes(mesh)
    dp_size = int(np.prod([_axis_size(mesh, a) for a in dp]))
    b = dp if len(dp) > 1 else dp[0]
    seq_axis = None
    if batch_size and batch_size % max(dp_size, 1):
        b, seq_axis = None, (dp if len(dp) > 1 else dp[0])
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _cache_leaf_spec(path, leaf, cfg, mesh, b, seq_axis),
        caches,
    )
