"""Tail-exemplar rings: deterministic top-N retention under heavy
multi-threaded writes (no lost slots, no interleaving-dependent
outcomes), error-ring recency semantics, and the snapshot shape the
``/v1/debug/exemplars`` endpoint serves."""

import threading

import pytest

from repro.obs.exemplar import ExemplarStore


def test_constructor_validation():
    with pytest.raises(ValueError):
        ExemplarStore(slow_n=0)
    with pytest.raises(ValueError):
        ExemplarStore(max_errors=0)


def test_slow_ring_retains_top_n():
    ex = ExemplarStore(slow_n=3, max_errors=4, clock=lambda: 0.0)
    for i, d in enumerate([0.010, 0.050, 0.001, 0.030, 0.020, 0.002]):
        ex.offer("/v1/query", f"t{i}", d, 200)
    snap = ex.snapshot()["routes"]["/v1/query"]
    # slowest first: 50ms, 30ms, 20ms
    assert [e["trace_id"] for e in snap["slow"]] == ["t1", "t3", "t4"]
    assert [e["dur_us"] for e in snap["slow"]] == [50000, 30000, 20000]
    assert snap["errors"] == []


def test_error_ring_keeps_newest():
    ex = ExemplarStore(slow_n=2, max_errors=3, clock=lambda: 0.0)
    for i in range(5):
        ex.offer("/v1/query", f"e{i}", 0.001, 503, code="shed")
    snap = ex.snapshot()["routes"]["/v1/query"]
    # arrival order, oldest retained first, capped at 3
    assert [e["trace_id"] for e in snap["errors"]] == ["e2", "e3", "e4"]
    assert all(e["code"] == "shed" and e["status"] == 503
               for e in snap["errors"])
    # errors never consume slow slots
    assert snap["slow"] == []


def test_trace_tree_rides_along():
    ex = ExemplarStore(slow_n=2, max_errors=2, clock=lambda: 42.0)
    tree = {"trace_id": "abc", "name": "gateway.request", "dur_us": 900,
            "children": [{"name": "server.answer", "dur_us": 800}]}
    ex.offer("/v1/query", "abc", 0.0009, 200, trace=tree)
    e = ex.snapshot()["routes"]["/v1/query"]["slow"][0]
    assert e["trace"] == tree
    assert e["at"] == 42.0


def test_snapshot_route_filter():
    ex = ExemplarStore(slow_n=2, max_errors=2)
    ex.offer("/v1/query", "a", 0.001, 200)
    ex.offer("/v1/route", "b", 0.001, 200)
    snap = ex.snapshot(route="/v1/query")
    assert list(snap["routes"]) == ["/v1/query"]
    # a known-but-quiet route yields the empty shape, not a KeyError
    empty = ex.snapshot(route="/v1/query_many")
    assert empty["routes"]["/v1/query_many"] == {"slow": [], "errors": []}


def test_concurrent_writers_no_lost_slots():
    """8 writer threads, globally distinct durations: the retained set
    must be exactly the top-N by duration -- any interleaving that
    dropped or duplicated a slot would miss that oracle."""
    N = 16
    ex = ExemplarStore(slow_n=N, max_errors=8, clock=lambda: 0.0)
    threads = 8
    per = 500
    # duration encodes (thread, i) uniquely
    def work(t):
        for i in range(per):
            d = (t * per + i + 1) * 1e-6
            ex.offer("/v1/query", f"{t}:{i}", d, 200)
            if i % 97 == 0:
                ex.offer("/v1/query", f"err{t}:{i}", d, 500)

    ts = [threading.Thread(target=work, args=(t,)) for t in range(threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    snap = ex.snapshot()["routes"]["/v1/query"]
    got = [e["dur_us"] for e in snap["slow"]]
    top = sorted(range(1, threads * per + 1), reverse=True)[:N]
    assert got == top, "retained set is not the deterministic top-N"
    # the error ring stayed capped
    assert len(snap["errors"]) == 8


def test_equal_durations_evict_deterministically():
    """Ties on duration break by arrival sequence: the earliest-offered
    tie is the one evicted (min-heap orders (duration, seq))."""
    ex = ExemplarStore(slow_n=2, max_errors=2, clock=lambda: 0.0)
    ex.offer("/v1/query", "first", 0.005, 200)
    ex.offer("/v1/query", "second", 0.005, 200)
    ex.offer("/v1/query", "third", 0.006, 200)  # evicts "first"
    snap = ex.snapshot()["routes"]["/v1/query"]
    assert [e["trace_id"] for e in snap["slow"]] == ["third", "second"]
    # an equal-duration offer on a full ring does NOT evict (strict >)
    ex.offer("/v1/query", "fourth", 0.005, 200)
    snap = ex.snapshot()["routes"]["/v1/query"]
    assert [e["trace_id"] for e in snap["slow"]] == ["third", "second"]
