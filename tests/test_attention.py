"""Attention variants vs naive references: GQA, SWA masking, chunked == plain,
MLA prefill/decode consistency, M-RoPE."""

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.configs.base import ArchConfig, AttnConfig
from repro.models.attention import attention, attn_init, _sdpa_chunked
from repro.models.layers import apply_rope


def _base_cfg(**kw):
    d = dict(
        name="t", family="dense", n_layers=1, d_model=32, n_heads=4,
        n_kv_heads=2, d_ff=64, vocab=64, head_dim=8, rope="standard",
    )
    d.update(kw)
    return ArchConfig(**d)


def _naive_attention(params, cfg, x, window=0):
    """Direct O(S^2) reference with explicit per-head K/V replication."""
    b, s, _ = x.shape
    h, kh, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    q = (x @ params["wq"]).reshape(b, s, h, dh)
    k = (x @ params["wk"]).reshape(b, s, kh, dh)
    v = (x @ params["wv"]).reshape(b, s, kh, dh)
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    if cfg.rope == "standard":
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
    k = jnp.repeat(k, h // kh, axis=2)
    v = jnp.repeat(v, h // kh, axis=2)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / math.sqrt(dh)
    mask = jnp.tril(jnp.ones((s, s), bool))
    if window:
        qi, ki = jnp.mgrid[0:s, 0:s]
        mask &= (qi - ki) < window
    scores = jnp.where(mask[None, None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", w, v).reshape(b, s, h * dh)
    return out @ params["wo"]


@pytest.mark.parametrize("kv_heads", [1, 2, 4])
def test_gqa_matches_naive(kv_heads):
    cfg = _base_cfg(n_kv_heads=kv_heads)
    params = attn_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 24, cfg.d_model), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(24)[None], (2, 24))
    got, _ = attention(params, cfg, x, positions=pos)
    want = _naive_attention(params, cfg, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_swa_matches_naive_windowed():
    cfg = _base_cfg(attn=AttnConfig(kind="swa", window=5))
    params = attn_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 20, cfg.d_model), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(20)[None], (1, 20))
    got, _ = attention(params, cfg, x, positions=pos)
    want = _naive_attention(params, cfg, x, window=5)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_chunked_matches_plain():
    cfg = _base_cfg()
    params = attn_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(64)[None], (2, 64))
    plain, _ = attention(params, cfg, x, positions=pos, impl="plain")
    chunked, _ = attention(params, cfg, x, positions=pos, impl="chunked")
    np.testing.assert_allclose(
        np.asarray(chunked), np.asarray(plain), rtol=2e-4, atol=2e-4
    )


def test_decode_stream_matches_full():
    """prefill + token-by-token decode == full causal forward."""
    cfg = _base_cfg()
    params = attn_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    b, s, split = 2, 16, 9
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, cfg.d_model), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    full, _ = attention(params, cfg, x, positions=pos)

    cache = {
        "k": jnp.zeros((b, s, cfg.n_kv_heads, cfg.head_dim_), jnp.float32),
        "v": jnp.zeros((b, s, cfg.n_kv_heads, cfg.head_dim_), jnp.float32),
        "idx": jnp.int32(0),
    }
    pre, cache = attention(params, cfg, x[:, :split], positions=pos[:, :split], cache=cache)
    outs = [pre]
    for t in range(split, s):
        yt, cache = attention(
            params, cfg, x[:, t : t + 1], positions=pos[:, t : t + 1], cache=cache
        )
        outs.append(yt)
    got = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full), rtol=2e-4, atol=2e-4)


def test_swa_ring_cache_decode():
    """Ring-buffered SWA cache: decode equals full SWA forward."""
    w = 6
    cfg = _base_cfg(attn=AttnConfig(kind="swa", window=w))
    params = attn_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    b, s = 1, 25
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, cfg.d_model), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    full, _ = attention(params, cfg, x, positions=pos)

    cache = {
        "k": jnp.zeros((b, w, cfg.n_kv_heads, cfg.head_dim_), jnp.float32),
        "v": jnp.zeros((b, w, cfg.n_kv_heads, cfg.head_dim_), jnp.float32),
        "idx": jnp.int32(0),
    }
    split = 13  # prefill longer than the window exercises the ring rollover
    pre, cache = attention(params, cfg, x[:, :split], positions=pos[:, :split], cache=cache)
    outs = [pre]
    for t in range(split, s):
        yt, cache = attention(
            params, cfg, x[:, t : t + 1], positions=pos[:, t : t + 1], cache=cache
        )
        outs.append(yt)
    got = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full), rtol=2e-4, atol=2e-4)


def test_mla_decode_matches_prefill_logits():
    """Absorbed-matmul MLA decode == expanded MLA forward (last position)."""
    cfg = get_arch("deepseek-v3-671b").reduced()
    params = attn_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    b, s = 2, 12
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, cfg.d_model), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    full, _ = attention(params, cfg, x, positions=pos)

    cache = {
        "ckv": jnp.zeros((b, s, cfg.attn.kv_lora_rank), jnp.float32),
        "krope": jnp.zeros((b, s, cfg.attn.rope_head_dim), jnp.float32),
        "idx": jnp.int32(0),
    }
    _, cache = attention(params, cfg, x[:, : s - 1], positions=pos[:, : s - 1], cache=cache)
    last, cache = attention(
        params, cfg, x[:, s - 1 :], positions=pos[:, s - 1 :], cache=cache
    )
    np.testing.assert_allclose(
        np.asarray(last[:, 0]), np.asarray(full[:, -1]), rtol=5e-4, atol=5e-4
    )


def test_mrope_runs_and_differs_from_standard():
    cfg = _base_cfg(rope="mrope", mrope_sections=(2, 1, 1))
    params = attn_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    b, s = 1, 10
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, cfg.d_model), jnp.float32)
    p3 = jnp.broadcast_to(jnp.arange(s)[None, None], (b, 3, s)).astype(jnp.int32)
    out, _ = attention(params, cfg, x, positions=p3)
    assert out.shape == x.shape and np.all(np.isfinite(np.asarray(out)))
    # diverging h/w ids must change the result
    p3b = p3.at[:, 1].set(0)
    out_b, _ = attention(params, cfg, x, positions=p3b)
    assert not np.allclose(np.asarray(out), np.asarray(out_b))
