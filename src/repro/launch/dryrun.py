import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Test hook only: a smaller fake-device count, set BEFORE jax locks devices.
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count="
        + os.environ["REPRO_DRYRUN_DEVICES"]
    )

"""Multi-pod dry-run (assignment deliverable e).

For every (architecture x input shape) cell and both production meshes
(single-pod 16x16 = 256 chips, multi-pod 2x16x16 = 512 chips):

    with mesh:
        lowered  = jax.jit(step, in_shardings=..., out_shardings=...) \
                       .lower(**input_specs(arch))
        compiled = lowered.compile()
        print(compiled.memory_analysis())   # proves it fits
        print(compiled.cost_analysis())     # FLOPs/bytes for the roofline

plus a parse of the optimized HLO for collective operand bytes (the
collective roofline term is not in cost_analysis). Results land as one JSON
per cell under --out; the run is resumable (existing JSONs are skipped)
and `repro.launch.roofline` consumes the artifacts.

train_4k lowers the *train step* (fwd+bwd+AdamW); prefill_32k lowers the
prefill; decode_32k / long_500k lower serve_step (one token against a
seq_len-deep cache). long_500k runs only for sub-quadratic archs (ssm /
hybrid / SWA) -- skips are recorded, not silently dropped.
"""

import argparse
import dataclasses
import json
import re
import time
import traceback
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, SHAPES
from repro.configs.base import ArchConfig, ShapeSpec, get_arch
from repro.models.model import (
    _head,
    active_params,
    count_params,
    forward,
    forward_hidden,
    init_model,
)
from repro.optim.adamw import AdamWConfig
from repro.serve.kvcache import init_caches
from repro.sharding.partition import batch_specs, cache_specs, param_specs
from repro.train.train_step import TrainConfig, make_train_step
from repro.launch.mesh import make_mesh, make_production_mesh

#: archs whose attention cost is sub-quadratic in context (may run long_500k)
SUBQUADRATIC = {"mamba2-780m", "jamba-v0.1-52b", "mixtral-8x22b"}

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8, "s32": 4,
    "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1, "c64": 8,
    "c128": 16,
}
_COLL_RE = re.compile(
    r"\b(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
_TYPE_RE = re.compile(r"\b(f64|f32|f16|bf16|s64|u64|s32|u32|s16|u16|s8|u8|pred|c64|c128)\[([0-9,]*)\]")


def applicable(arch: str, shape_name: str) -> Tuple[bool, str]:
    if shape_name == "long_500k" and arch not in SUBQUADRATIC:
        return False, (
            "full-attention arch: 500k decode is quadratic-cost; skipped per "
            "assignment note (DESIGN.md §Arch-applicability)"
        )
    return True, ""


# ---------------------------------------------------------------------------
# Per-cell plan: the pre-hillclimb defaults (meshopt refines these in §Perf)
# ---------------------------------------------------------------------------
def plan_cell(cfg: ArchConfig, shape: ShapeSpec, mesh) -> Dict:
    """Pre-hillclimb defaults.

    * fsdp: on when TP-only parameter shards exceed ~4 GB/chip;
    * remat 'full': 'dots' saves attention probability matrices
      (B*H*S^2 -- 34 GB/chip at train_4k) -- recompute-everything keeps only
      the per-layer residual carry;
    * microbatches sized so the saved residual stash (~3x tokens_local *
      d_model * 2 B per layer) stays under ~4 GB/chip. Tokens shard over the
      data axes only, so the estimate uses data shards, not total chips.
    """
    axis = dict(zip(mesh.axis_names, mesh.devices.shape))
    data_shards = axis.get("data", 1) * axis.get("pod", 1)
    model_size = axis.get("model", 1)
    p_bytes = 2 * count_params(cfg)
    fsdp = p_bytes / model_size > 4e9
    microbatches = 1
    if shape.kind == "train":
        tokens_local = shape.tokens / data_shards
        saved = cfg.n_layers * tokens_local * cfg.d_model * 2 * 3
        # cap: each microbatch must still shard over the data axes, or
        # GSPMD pads/replicates the whole attention path
        mb_cap = max(1, shape.global_batch // data_shards)
        while saved / microbatches > 4e9 and microbatches < mb_cap:
            microbatches *= 2
    return {
        "fsdp": bool(fsdp),
        "microbatches": int(microbatches),
        "remat": "full",
        "attn_impl": "auto",
    }


# ---------------------------------------------------------------------------
# input_specs: ShapeDtypeStruct stand-ins for every model input
# ---------------------------------------------------------------------------
def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(x) for x in shape), dtype)


def input_specs(cfg: ArchConfig, shape: ShapeSpec) -> Dict[str, jax.ShapeDtypeStruct]:
    """Stand-ins for the *batch* inputs of the lowered step."""
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        return {"tokens": _sds((b, 1), jnp.int32)}
    specs = {"tokens": _sds((b, s), jnp.int32)}
    if shape.kind == "train":
        s_lab = s + (cfg.n_frontend_tokens if cfg.frontend == "vision" else 0)
        specs["labels"] = _sds((b, s_lab), jnp.int32)
    if cfg.frontend or cfg.enc_dec:
        specs["frontend"] = _sds((b, cfg.n_frontend_tokens, cfg.d_model), jnp.float32)
    return specs


def _abstract_params(cfg: ArchConfig):
    return jax.eval_shape(lambda: init_model(cfg, jax.random.PRNGKey(0)))


def _abstract_state(cfg: ArchConfig, tcfg: TrainConfig):
    params = _abstract_params(cfg)
    mdt = jnp.dtype(tcfg.opt.moment_dtype)
    f32 = lambda t: jax.tree.map(lambda x: _sds(x.shape, jnp.float32), t)
    mom = lambda t: jax.tree.map(lambda x: _sds(x.shape, mdt), t)
    state = {
        "params": params,
        "opt": {"m": mom(params), "v": mom(params), "step": _sds((), jnp.int32)},
    }
    if tcfg.compress_grads:
        state["comp"] = f32(params)
    return state


# ---------------------------------------------------------------------------
# Lowering per shape kind
# ---------------------------------------------------------------------------
def lower_cell(cfg: ArchConfig, shape: ShapeSpec, mesh, plan: Dict):
    to_sh = lambda tree: jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree, is_leaf=lambda x: isinstance(x, P)
    )
    p_abs = _abstract_params(cfg)
    p_sh = to_sh(param_specs(cfg, p_abs, mesh, fsdp=plan["fsdp"]))
    b_specs_all = batch_specs(cfg, mesh, batch_size=shape.global_batch)
    batch_sds = input_specs(cfg, shape)
    b_sh = {k: NamedSharding(mesh, b_specs_all.get(k, b_specs_all["tokens"])) for k in batch_sds}

    if shape.kind == "train":
        tcfg = TrainConfig(
            microbatches=plan["microbatches"],
            remat=plan["remat"],
            attn_impl=plan["attn_impl"],
            fsdp=plan["fsdp"],
            opt=AdamWConfig(moment_dtype=plan.get("moments", "float32")),
        )
        step = make_train_step(cfg, tcfg, mesh)
        state = _abstract_state(cfg, tcfg)
        return step.lower(state, batch_sds)

    if shape.kind == "prefill":
        # vlm: vision embeddings prepend n_frontend_tokens to the sequence
        cache_len = shape.seq_len + (
            cfg.n_frontend_tokens if cfg.frontend == "vision" else 0
        )

        def prefill(params, batch):
            b = batch["tokens"].shape[0]
            caches = init_caches(cfg, b, cache_len, dtype=jnp.dtype(cfg.dtype))
            hidden, caches, _ = forward_hidden(
                params, cfg, batch, caches=caches, impl=plan["attn_impl"]
            )
            return _head(cfg, params, hidden[:, -1:])[:, 0], caches

        return jax.jit(prefill, in_shardings=(p_sh, b_sh)).lower(p_abs, batch_sds)

    # decode: one token against a seq_len-deep cache
    caches_abs = jax.eval_shape(
        lambda: init_caches(
            cfg, shape.global_batch, shape.seq_len, dtype=jnp.dtype(cfg.dtype),
            include_enc=cfg.enc_dec,
        )
    )
    c_sh = to_sh(cache_specs(cfg, caches_abs, mesh, batch_size=shape.global_batch))

    def decode(params, tokens, caches, cache_index):
        batch = {"tokens": tokens, "cache_index": cache_index}
        logits, caches, _ = forward(params, cfg, batch, caches=caches, impl=plan["attn_impl"])
        return logits[:, -1], caches

    return jax.jit(
        decode,
        in_shardings=(p_sh, b_sh["tokens"], c_sh, None),
        donate_argnums=(2,),
    ).lower(
        p_abs,
        input_specs(cfg, shape)["tokens"],
        caches_abs,
        _sds((), jnp.int32),
    )


# ---------------------------------------------------------------------------
# Analysis of the compiled artifact
# ---------------------------------------------------------------------------
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


def parse_collectives(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Sum *operand* bytes of every collective op in the optimized HLO.

    XLA's optimized dump types the result (lhs of '='), not the operands,
    so operand bytes are derived from result bytes per op semantics:
    all-reduce/all-to-all/collective-permute have operand == result;
    all-gather's operand is result / group_size; reduce-scatter's operand
    is result * group_size.
    """
    out: Dict[str, Dict[str, float]] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        op = m.group(1)
        eq = line.find("=")
        if eq < 0 or eq > m.start():
            continue
        result_part = line[eq + 1 : m.start()]
        nbytes = 0.0
        for t, dims in _TYPE_RE.findall(result_part):
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[t]
        g = _group_size(line)
        if op == "all-gather":
            nbytes /= max(g, 1)
        elif op == "reduce-scatter":
            nbytes *= max(g, 1)
        rec = out.setdefault(op, {"count": 0, "bytes": 0.0})
        rec["count"] += 1
        rec["bytes"] += nbytes
    return out


def analyze(lowered) -> Dict:
    t0 = time.time()
    compiled = lowered.compile()
    compile_s = time.time() - t0
    rec: Dict = {"compile_s": round(compile_s, 2)}

    try:
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        rec["flops"] = float(cost.get("flops", -1.0))
        rec["bytes_accessed"] = float(cost.get("bytes accessed", -1.0))
        rec["transcendentals"] = float(cost.get("transcendentals", 0.0))
    except Exception as e:  # noqa: BLE001
        rec["cost_error"] = repr(e)

    try:
        mem = compiled.memory_analysis()
        rec["memory"] = {
            k: int(getattr(mem, k))
            for k in (
                "argument_size_in_bytes",
                "output_size_in_bytes",
                "temp_size_in_bytes",
                "alias_size_in_bytes",
                "generated_code_size_in_bytes",
            )
            if hasattr(mem, k)
        }
    except Exception as e:  # noqa: BLE001
        rec["memory_error"] = repr(e)

    try:
        text = compiled.as_text()
        # scan-aware accounting: while bodies (layer scans, microbatch
        # accumulation, chunked attention) multiplied by their trip counts
        from repro.launch.hloanalysis import analyze_hlo

        totals = analyze_hlo(text)
        rec["dot_flops_expanded"] = totals.dot_flops
        rec["collectives"] = totals.per_collective
        rec["collective_bytes"] = totals.collective_bytes
        rec["materialized_bytes"] = totals.materialized_bytes
        rec["while_trips"] = totals.while_trips[:32]
        # raw single-visit parse kept for reference/debugging
        colls_raw = parse_collectives(text)
        rec["collective_bytes_raw"] = sum(v["bytes"] for v in colls_raw.values())
    except Exception as e:  # noqa: BLE001
        rec["collective_error"] = repr(e)
    return rec


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------
def run_cell(
    arch: str, shape_name: str, mesh_kind: str, outdir: str, tiny: bool = False,
    plan_overrides: Optional[Dict] = None,
) -> Dict:
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    if tiny:
        cfg = cfg.reduced()
        shape = dataclasses.replace(
            shape, seq_len=min(shape.seq_len, 128), global_batch=min(shape.global_batch, 8)
        )
        mesh = make_mesh(
            (2, 2, 2) if mesh_kind == "multi" else (2, 2),
            ("pod", "data", "model") if mesh_kind == "multi" else ("data", "model"),
        )
    else:
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))

    rec: Dict = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_kind,
        "chips": int(mesh.devices.size),
        "kind": shape.kind,
        "tiny": tiny,
    }
    ok, reason = applicable(arch, shape_name)
    if not ok:
        rec.update(skipped=True, reason=reason)
        return rec

    rec["params"] = count_params(cfg)
    rec["active_params"] = active_params(cfg)
    plan = plan_cell(cfg, shape, mesh)
    if plan_overrides:
        plan.update(plan_overrides)
    rec["plan"] = plan
    t0 = time.time()
    with mesh:
        lowered = lower_cell(cfg, shape, mesh, plan)
    rec["lower_s"] = round(time.time() - t0, 2)
    rec.update(analyze(lowered))
    rec["skipped"] = False
    return rec


def _out_path(outdir, mesh_kind, arch, shape_name):
    d = os.path.join(outdir, mesh_kind)
    os.makedirs(d, exist_ok=True)
    return os.path.join(d, f"{arch}__{shape_name}.json")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all", help="arch id or 'all'")
    ap.add_argument("--shape", default="all", help="shape id or 'all'")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="benchmarks/artifacts/dryrun")
    ap.add_argument("--tiny", action="store_true", help="reduced configs (CI)")
    ap.add_argument("--force", action="store_true", help="recompute existing")
    ap.add_argument("--fsdp", default=None, choices=[None, "on", "off"])
    ap.add_argument("--microbatches", type=int, default=0)
    ap.add_argument("--remat", default=None)
    ap.add_argument("--moments", default=None, help="optimizer moment dtype")
    args = ap.parse_args()

    import repro.configs._register_all  # noqa: F401

    archs = sorted(ARCHS) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    overrides = {}
    if args.fsdp:
        overrides["fsdp"] = args.fsdp == "on"
    if args.microbatches:
        overrides["microbatches"] = args.microbatches
    if args.remat:
        overrides["remat"] = args.remat
    if args.moments:
        overrides["moments"] = args.moments

    n_ok = n_skip = n_fail = 0
    for mesh_kind in meshes:
        for arch in archs:
            for shape_name in shapes:
                path = _out_path(args.out, mesh_kind, arch, shape_name)
                if os.path.exists(path) and not args.force:
                    print(f"[cached] {mesh_kind}/{arch}/{shape_name}")
                    continue
                t0 = time.time()
                try:
                    rec = run_cell(
                        arch, shape_name, mesh_kind, args.out, tiny=args.tiny,
                        plan_overrides=overrides or None,
                    )
                    status = "SKIP" if rec.get("skipped") else "ok"
                    n_skip += rec.get("skipped", False)
                    n_ok += not rec.get("skipped", False)
                except Exception as e:  # noqa: BLE001
                    rec = {
                        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
                        "error": repr(e), "traceback": traceback.format_exc(),
                        "skipped": False,
                    }
                    status = "FAIL"
                    n_fail += 1
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                dt = time.time() - t0
                extra = ""
                if "flops" in rec:
                    extra = (
                        f" flops={rec['flops']:.3e}"
                        f" coll={rec.get('collective_bytes', 0):.3e}B"
                    )
                print(
                    f"[{status}] {mesh_kind}/{arch}/{shape_name} ({dt:.0f}s)"
                    f"{extra}",
                    flush=True,
                )
    print(f"done: {n_ok} ok, {n_skip} skipped, {n_fail} failed")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
