"""Shared benchmark utilities: timing + CSV emission + artifact cache."""

from __future__ import annotations

import json
import os
import time
from typing import Callable, Dict

ARTIFACTS = os.path.join(os.path.dirname(__file__), "artifacts")


def timed(fn: Callable, *args, repeats: int = 3, **kw):
    """(result, best microseconds per call)."""
    best = float("inf")
    out = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        best = min(best, (time.perf_counter() - t0) * 1e6)
    return out, best


def emit(name: str, us_per_call: float, derived: str) -> None:
    """The harness CSV contract: name,us_per_call,derived."""
    print(f"{name},{us_per_call:.1f},{derived}")


def cache_json(key: str, compute: Callable[[], Dict], force: bool = False) -> Dict:
    os.makedirs(ARTIFACTS, exist_ok=True)
    path = os.path.join(ARTIFACTS, key + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    out = compute()
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    return out
