"""The paper's own workload configs: the six stencils x the SZ grid,
re-exported so launch scripts can select them with --arch-like names."""

from repro.core.timemodel import STENCILS  # noqa: F401
from repro.core.workload import paper_sizes, paper_workload  # noqa: F401
