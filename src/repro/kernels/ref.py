"""Pure-jnp oracles for the stencil kernels.

Written independently of the Pallas kernel bodies (interior slicing on the
full array, Dirichlet borders via ``.at[...]``) so the allclose tests are a
genuine cross-check, not a tautology. Like the kernels, arithmetic is done
in f32 (bf16 inputs are upcast) and the result stored in the input dtype.
"""

from __future__ import annotations

from typing import Callable, Dict

import jax
import jax.numpy as jnp

__all__ = ["REF_STEPS", "run_ref"]


def jacobi2d(x0: jax.Array) -> jax.Array:
    x = x0.astype(jnp.float32)
    i = x[1:-1, 1:-1]
    new = 0.2 * (i + x[:-2, 1:-1] + x[2:, 1:-1] + x[1:-1, :-2] + x[1:-1, 2:])
    return x.at[1:-1, 1:-1].set(new).astype(x0.dtype)


def heat2d(x0: jax.Array) -> jax.Array:
    x = x0.astype(jnp.float32)
    i = x[1:-1, 1:-1]
    lap = x[:-2, 1:-1] + x[2:, 1:-1] + x[1:-1, :-2] + x[1:-1, 2:] - 4.0 * i
    return x.at[1:-1, 1:-1].set(i + 0.125 * lap).astype(x0.dtype)


def laplacian2d(x0: jax.Array) -> jax.Array:
    x = x0.astype(jnp.float32)
    i = x[1:-1, 1:-1]
    new = x[:-2, 1:-1] + x[2:, 1:-1] + x[1:-1, :-2] + x[1:-1, 2:] - 4.0 * i
    return x.at[1:-1, 1:-1].set(new).astype(x0.dtype)


def gradient2d(x0: jax.Array) -> jax.Array:
    x = x0.astype(jnp.float32)
    gx = 0.5 * (x[1:-1, 2:] - x[1:-1, :-2])
    gy = 0.5 * (x[2:, 1:-1] - x[:-2, 1:-1])
    new = jnp.sqrt(gx * gx + gy * gy)
    return x.at[1:-1, 1:-1].set(new).astype(x0.dtype)


def heat3d(x0: jax.Array) -> jax.Array:
    x = x0.astype(jnp.float32)
    i = x[1:-1, 1:-1, 1:-1]
    lap = (
        x[:-2, 1:-1, 1:-1]
        + x[2:, 1:-1, 1:-1]
        + x[1:-1, :-2, 1:-1]
        + x[1:-1, 2:, 1:-1]
        + x[1:-1, 1:-1, :-2]
        + x[1:-1, 1:-1, 2:]
        - 6.0 * i
    )
    return x.at[1:-1, 1:-1, 1:-1].set(i + 0.125 * lap).astype(x0.dtype)


def laplacian3d(x0: jax.Array) -> jax.Array:
    x = x0.astype(jnp.float32)
    i = x[1:-1, 1:-1, 1:-1]
    new = (
        x[:-2, 1:-1, 1:-1]
        + x[2:, 1:-1, 1:-1]
        + x[1:-1, :-2, 1:-1]
        + x[1:-1, 2:, 1:-1]
        + x[1:-1, 1:-1, :-2]
        + x[1:-1, 1:-1, 2:]
        - 6.0 * i
    )
    return x.at[1:-1, 1:-1, 1:-1].set(new).astype(x0.dtype)


REF_STEPS: Dict[str, Callable] = {
    "jacobi2d": jacobi2d,
    "heat2d": heat2d,
    "laplacian2d": laplacian2d,
    "gradient2d": gradient2d,
    "heat3d": heat3d,
    "laplacian3d": laplacian3d,
}


def run_ref(name: str, x: jax.Array, steps: int = 1) -> jax.Array:
    f = REF_STEPS[name]
    for _ in range(steps):
        x = f(x)
    return x
