"""Persistent usage ledger + kind-aware retention: record/flush/merge
round trips, restart survival across two gateway lifetimes, client-bucket
folding, corrupt-file tolerance, the retention plan's protection rules
(never evict the sweep behind a portfolio; telemetry ages out first),
telemetry-cap pruning on the gateway, deterministic ``gc --dry-run``
bytes, and the process-level gauges."""

import contextlib
import io
import json
import os
import tempfile
import threading

import pytest

from repro.core import MAXWELL, enumerate_hw_space
from repro.core.timemodel import MAXWELL_GPU
from repro.core.workload import paper_workload
from repro.service import ArtifactStore, CodesignServer, Gateway, QueryRequest
from repro.service import cli
from repro.service.usage import (
    LEDGER_FILENAME,
    MAX_CLIENT_BUCKETS,
    UsageLedger,
    retention_plan,
)


@pytest.fixture(scope="module")
def sweep_store():
    """One tiny numpy sweep artifact in a fresh store root."""
    root = tempfile.mkdtemp(prefix="usagestore-")
    store = ArtifactStore(root)
    srv = CodesignServer(
        store,
        workload=paper_workload(["heat2d"]),
        gpu=MAXWELL_GPU,
        hw=enumerate_hw_space(MAXWELL, max_area=650.0).downsample(64),
        engine="numpy",
        batch_window=0.0,
    )
    srv.ensure_artifact()
    return root, store, srv.key


# ---------------------------------------------------------------------------
# ledger unit behavior
# ---------------------------------------------------------------------------


def test_record_flush_reload_round_trip(tmp_path):
    root = str(tmp_path)
    led = UsageLedger(root, clock=lambda: 100.0)
    led.record("k1", n=2, nbytes=300, client="alice")
    led.record("k1", n=1, nbytes=100, client="bob")
    led.record("k2")
    assert led.flush() is True
    # a second ledger (new process) sees the persisted state
    led2 = UsageLedger(root, clock=lambda: 200.0)
    rec = led2.get("k1")
    assert rec == {"hits": 3, "bytes": 400, "last_access": 100.0,
                   "clients": {"alice": 2, "bob": 1}}
    # its own deltas MERGE (sum hits, max last_access) on flush
    led2.record("k1", n=1)
    led2.flush()
    led3 = UsageLedger(root)
    assert led3.get("k1")["hits"] == 4
    assert led3.get("k1")["last_access"] == 200.0
    assert led3.get("k2")["hits"] == 1


def test_flush_is_atomic_and_dotfile_invisible_to_store(tmp_path):
    root = str(tmp_path)
    store = ArtifactStore(root)
    led = UsageLedger(root)
    led.record("k1")
    led.flush()
    assert os.path.exists(os.path.join(root, LEDGER_FILENAME))
    # the ledger (and its lock) never show up as artifacts
    assert store.keys() == []


def test_corrupt_or_foreign_ledger_is_ignored(tmp_path):
    root = str(tmp_path)
    path = os.path.join(root, LEDGER_FILENAME)
    with open(path, "w") as f:
        f.write("not json{{{")
    led = UsageLedger(root)
    assert led.snapshot() == {}
    with open(path, "w") as f:
        json.dump({"v": 999, "artifacts": {"k": {"hits": 5}}}, f)
    assert UsageLedger(root).snapshot() == {}


def test_client_buckets_fold_deterministically(tmp_path):
    led = UsageLedger(str(tmp_path), clock=lambda: 1.0)
    # many distinct clients, traffic proportional to index
    for i in range(3 * MAX_CLIENT_BUCKETS):
        led.record("k", n=i + 1, client=f"c{i:03d}")
    led.flush()
    rec = UsageLedger(str(tmp_path)).get("k")
    clients = rec["clients"]
    assert len(clients) <= MAX_CLIENT_BUCKETS
    assert "other" in clients
    # total traffic is conserved through the fold
    total = 3 * MAX_CLIENT_BUCKETS * (3 * MAX_CLIENT_BUCKETS + 1) // 2
    assert sum(clients.values()) == total
    # the highest-traffic buckets survived by name
    assert f"c{3 * MAX_CLIENT_BUCKETS - 1:03d}" in clients


def test_maybe_flush_honors_interval(tmp_path):
    t = [0.0]
    led = UsageLedger(str(tmp_path), flush_interval_s=60.0, clock=lambda: t[0])
    led.record("k")
    assert led.maybe_flush() is False  # interval not elapsed
    t[0] = 61.0
    assert led.maybe_flush() is True
    assert led.maybe_flush() is False  # nothing pending


def test_concurrent_recorders_lose_nothing(tmp_path):
    led = UsageLedger(str(tmp_path))
    def work():
        for _ in range(1000):
            led.record("k", n=1, nbytes=2)
    ts = [threading.Thread(target=work) for _ in range(8)]
    for th in ts:
        th.start()
    for th in ts:
        th.join()
    led.flush()
    rec = UsageLedger(str(tmp_path)).get("k")
    assert rec["hits"] == 8000 and rec["bytes"] == 16000


# ---------------------------------------------------------------------------
# retention plan
# ---------------------------------------------------------------------------


def _entries():
    return [
        {"key": "sweep-a", "kind": "sweep"},
        {"key": "sweep-b", "kind": "sweep"},
        {"key": "portfolio-1", "kind": "portfolio", "sweep_key": "sweep-a"},
        {"key": "tele-1", "kind": "telemetry", "collected_at": 10.0},
        {"key": "tele-2", "kind": "telemetry", "collected_at": 20.0},
        {"key": "tele-3", "kind": "telemetry", "collected_at": 30.0},
    ]


def test_plan_protects_portfolio_and_its_sweep():
    plan = retention_plan(_entries(), {}, telemetry_cap=0, max_artifacts=0)
    evicted = {e["key"] for e in plan["evict"]}
    assert "portfolio-1" not in evicted
    assert "sweep-a" not in evicted  # the member sweep is load-bearing
    assert "sweep-b" in evicted      # unreferenced sweep is fair game
    assert plan["protected"]["sweep-a"].startswith("sweep behind portfolio")


def test_plan_telemetry_ages_out_oldest_first():
    plan = retention_plan(_entries(), {}, telemetry_cap=1)
    evicted = [e["key"] for e in plan["evict"]]
    assert sorted(evicted) == ["tele-1", "tele-2"]  # newest (tele-3) kept
    assert all(e["kind"] == "telemetry" for e in plan["evict"])
    assert "tele-3" in plan["kept"]


def test_plan_total_cap_evicts_coldest_by_ledger():
    usage = {
        "sweep-b": {"hits": 100, "last_access": 50.0},
        "tele-3": {"hits": 0, "last_access": None},
    }
    # cap of 3 over {sweep-a, sweep-b, portfolio-1, tele-3} after the
    # telemetry cap evicts tele-1/2; protected sweep-a and portfolio-1
    # stay, so the cold tele-3 goes before the hot sweep-b
    plan = retention_plan(_entries(), usage, telemetry_cap=1, max_artifacts=3)
    evicted = [e["key"] for e in plan["evict"]]
    assert "tele-3" in evicted
    assert "sweep-b" not in evicted


def test_plan_is_deterministic_and_json_stable():
    a = retention_plan(_entries(), {}, telemetry_cap=1, max_artifacts=2)
    b = retention_plan(list(reversed(_entries())), {}, telemetry_cap=1,
                       max_artifacts=2)
    assert json.dumps(a, sort_keys=True) == json.dumps(b, sort_keys=True)
    with pytest.raises(ValueError):
        retention_plan(_entries(), {}, telemetry_cap=-1)


# ---------------------------------------------------------------------------
# gateway integration: restart survival, telemetry cap, gc CLI
# ---------------------------------------------------------------------------


def test_ledger_survives_two_gateway_lifetimes(sweep_store):
    root, store, key = sweep_store
    req = QueryRequest(freqs={"heat2d": 1.0})
    # lifetime 1: three hits, flushed on shutdown (what cmd_serve does)
    gw1 = Gateway(root, batch_window=0.0, usage_flush_interval=1e9)
    for _ in range(3):
        gw1.query(req, artifact=key)
    gw1.flush_usage()
    # lifetime 2: resumes the persisted counts, adds two more
    gw2 = Gateway(root, batch_window=0.0, usage_flush_interval=1e9)
    row = next(r for r in gw2.entries() if r["key"] == key)
    assert row["hits"] == 3 and row["last_access"] is not None
    for _ in range(2):
        gw2.query(req, artifact=key)
    row = next(r for r in gw2.entries() if r["key"] == key)
    assert row["hits"] == 5  # merged view: persisted 3 + buffered 2
    gw2.flush_usage()
    assert UsageLedger(root).get(key)["hits"] == 5


def test_gateway_telemetry_cap_prunes_snapshot_series(sweep_store):
    root, store, key = sweep_store
    gw = Gateway(root, batch_window=0.0, telemetry_cap=2)
    for _ in range(5):
        gw.persist_telemetry()
    tele = [k for k in store.keys()
            if store.get(k).kind == "telemetry"]
    assert len(tele) == 2
    # newest survive: collected_at strictly increasing across persists
    ats = sorted(store.get(k).payload["collected_at"] for k in tele)
    all_ats = ats  # remaining two are the two largest by construction
    assert all_ats == sorted(all_ats)
    # clean up for other tests sharing the module store
    for k in tele:
        store.delete(k)
    gw.refresh()


def test_gc_dry_run_bytes_are_deterministic(sweep_store, capsys):
    root, store, key = sweep_store
    for i in range(3):
        store.put_json("telemetry", {"collected_at": float(i), "gateway": {}},
                       routing={"workload": "gateway-telemetry"})
    try:
        cli.main(["gc", "--store", root, "--dry-run", "--telemetry-cap", "1"])
        first = capsys.readouterr().out
        cli.main(["gc", "--store", root, "--dry-run", "--telemetry-cap", "1"])
        second = capsys.readouterr().out
        assert first == second
        doc = json.loads(first)
        plan = doc[0]["plan"]
        assert [e["kind"] for e in plan["evict"]] == ["telemetry", "telemetry"]
        assert doc[0]["applied"] is False and doc[0]["deleted"] == []
        assert key in plan["kept"]
        # --apply executes exactly the printed plan
        cli.main(["gc", "--store", root, "--apply", "--telemetry-cap", "1"])
        applied = json.loads(capsys.readouterr().out)
        assert sorted(applied[0]["deleted"]) == sorted(
            e["key"] for e in plan["evict"]
        )
    finally:
        for k in list(store.keys()):
            if store.get(k).kind == "telemetry":
                store.delete(k)


# ---------------------------------------------------------------------------
# process gauges
# ---------------------------------------------------------------------------


def test_process_gauges_sample_without_raising():
    from repro.obs.process import M_RSS, rss_bytes, sample_process

    rss = rss_bytes()
    if rss is not None:  # Linux/macOS: a real positive byte count
        assert rss > 1 << 20
    sample_process()  # must never raise regardless of platform
    if rss is not None:
        assert M_RSS.value > 0
