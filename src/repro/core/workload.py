"""Workload characterization (paper §II, §IV.A).

A workload is a set of (stencil, problem-size) cells with occurrence
frequencies. The paper's experiments use the six-stencil suite over

    SZ_S = {4096, 8192, 12288, 16384},  SZ_T = {1024, ..., 16384},
    SZ   = {(S, T) | S in SZ_S, T in SZ_T, T <= S}      (|SZ| = 16)

with uniform frequencies ("we assumed all six stencils equally likely, and
that each size combination also equally likely", §IV.B).

Eq. (17)/(18) never look inside a cell: the objective only needs each
cell's occurrence frequency and a per-design-point time/feasibility
function that the sweep engine can trace. That contract is the
:class:`Cell` protocol below. ``(stencil, size)`` cells
(:class:`WorkloadCell`, family ``"stencil"``) are one instance; LM op-graph
cells over real model configs (:mod:`repro.core.lmcells`, family ``"lm"``)
are another, and ``codesign()`` dispatches on :attr:`Workload.family`.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Protocol, Sequence, Tuple, runtime_checkable

from .timemodel import STENCILS, ProblemSize, StencilSpec

__all__ = [
    "Cell",
    "WorkloadCell",
    "Workload",
    "paper_sizes",
    "paper_workload",
]

SZ_S = (4096, 8192, 12288, 16384)
SZ_T = (1024, 2048, 4096, 8192, 16384)


@runtime_checkable
class Cell(Protocol):
    """What eq. (18)'s inner minimization needs from a workload cell.

    A cell is one independently-optimized unit of work: it exposes its
    occurrence frequency (``freq``), a ``family`` tag the sweep engine
    dispatches on, and a stable ``label`` used for grouping in query-time
    frequency overrides and artifact manifests. The per-design-point time
    model itself lives with the family's sweep implementation (it is
    vectorized over the whole lattice, not evaluated cell-by-cell) and must
    be traceable by ``jax.vmap``/``jit`` — static Python branching on cell
    *structure* only, never on array values.
    """

    freq: float

    @property
    def family(self) -> str: ...

    @property
    def label(self) -> str: ...


@dataclasses.dataclass(frozen=True)
class WorkloadCell:
    """The paper's original cell: one stencil at one problem size."""

    stencil: StencilSpec
    size: ProblemSize
    freq: float  # fr(c) * fr(c, Sz), already combined

    @property
    def family(self) -> str:
        return "stencil"

    @property
    def label(self) -> str:
        return self.stencil.name


@dataclasses.dataclass(frozen=True)
class Workload:
    """A frequency-weighted set of cells; eq. (17)'s objective is
    ``sum_cell freq * min_tiles T_alg(cell)`` (separability, eq. (18)).

    All cells must share one ``family`` — the sweep engines vectorize over
    homogeneous lattices, so a mixed workload has no single design space.
    """

    name: str
    cells: Tuple[WorkloadCell, ...]

    def __post_init__(self):
        total = sum(c.freq for c in self.cells)
        if not 0.999 <= total <= 1.001:
            raise ValueError(f"cell frequencies sum to {total}, expected 1")
        families = {getattr(c, "family", "stencil") for c in self.cells}
        if len(families) > 1:
            raise ValueError(f"mixed cell families in one workload: {sorted(families)}")

    @property
    def family(self) -> str:
        """Cell family ("stencil" for the paper's suite, "lm" for op-graph
        cells); drives the ``codesign()`` dispatch and artifact routing."""
        if not self.cells:
            return "stencil"
        return getattr(self.cells[0], "family", "stencil")

    @property
    def stencils(self) -> List[StencilSpec]:
        seen: Dict[str, StencilSpec] = {}
        for c in self.cells:
            seen.setdefault(c.stencil.name, c.stencil)
        return list(seen.values())


def paper_sizes(dims: int) -> List[ProblemSize]:
    """The 16-element SZ grid; for 3D stencils the three spatial extents are
    all S (the paper reuses the same SZ set for both classes)."""
    sizes = []
    for s in SZ_S:
        for t in SZ_T:
            if t <= s:
                sizes.append(
                    ProblemSize(s1=s, s2=s, t=t, s3=s if dims == 3 else 1)
                )
    assert len(sizes) == 16
    return sizes


def paper_workload(
    stencil_names: Sequence[str] | None = None, name: str = "paper-uniform"
) -> Workload:
    """Uniform-frequency workload over the chosen stencils (default: all six,
    as in Fig. 3 / §IV.B). Single-stencil workloads (Table II) are built by
    passing one name -- the §V.B 'workload sensitivity for free' trick."""
    names = list(stencil_names or STENCILS.keys())
    cells: List[WorkloadCell] = []
    for n in names:
        st = STENCILS[n]
        sizes = paper_sizes(st.dims)
        for sz in sizes:
            cells.append(WorkloadCell(st, sz, 1.0 / (len(names) * len(sizes))))
    return Workload(name=name, cells=tuple(cells))
